"""Record the fault-trace fixture (fault_trace.npz).

    python tests/fixtures/record_fault_trace.py

Runs a REAL fault-injected engine — 8 fake host devices, (2, 4) mesh,
the 20-expert fault-test arch, a storm ``FaultSpec`` — and converts
each decode step's psum'd fault-stats vector (``GenerationServer.
last_fault_stats``: per-kind counters + the per-peer detected tail)
into timestamped ``FaultTrace`` events: one event per kind seen on the
step, attributed to the hottest peer of the step's detected tail. A
``rank_death`` event is stamped three quarters of the way through —
rank death is a host-level fail-stop (it cannot be injected inside
jit), so the recorder places it the way an operator's incident log
would: at a wall-clock step, against a flat gen rank.

tests/test_rank_death.py replays the fixture through
``ClusterSimulator`` (SimConfig.fault_trace) and the ``HealthMonitor``
(FaultTrace.stat_vector) and asserts replayed pressure drives the same
ladder the Bernoulli storm does; re-run this script only when the
injector or the stats layout changes the recorded semantics.
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
)

import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

import jax.numpy as jnp  # noqa: E402

from repro.configs.base import ArchConfig, MoEConfig  # noqa: E402
from repro.core.faults import (  # noqa: E402
    FAULT_STAT_BASE,
    RANK_DEATH,
    _TRACE_STAT_INDEX,
    FaultTrace,
)
from repro.launch.serve import build_engine  # noqa: E402
from repro.runtime.engine import Request  # noqa: E402

CFG = ArchConfig(
    name="fault-trace", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)
MESH = (2, 4)
SPEC = "seed=1,drop=0.004,zero=0.002,corrupt=0.003,cache=0.005"
STEPS = 32
OUT = os.path.join(os.path.dirname(__file__), "fault_trace.npz")

# fault-stats prefix index -> trace kind (inverse of _TRACE_STAT_INDEX)
_KIND_AT = {v: k for k, v in _TRACE_STAT_INDEX.items()}


def main():
    engine, _ = build_engine(
        CFG, mesh_shape=MESH, prefill_len=8, cache_len=48, max_batch=4,
        gen_mode="dwdp",
        policy={"moe_experts": "split:predictive:allgather:4:4:8"},
        fault_spec=SPEC,
    )
    rng = np.random.default_rng(0)
    for i in range(4):
        engine.submit(Request(
            req_id=i,
            tokens=rng.integers(0, CFG.vocab_size, 8).astype(np.int32),
            target_len=STEPS,
        ))
    engine.ctx.warmup(engine.params)
    while engine.queue and any(
        r is None for r in engine.gen.slot_req
    ):
        req = engine.queue.pop(0)
        slot = engine.gen.slot_req.index(None)
        first, state = engine.ctx.prefill(engine.params, req.tokens)
        engine.gen.admit(slot, req.req_id, first, state)
    events = []
    for step in range(STEPS):
        engine.gen.decode_step(engine.params)
        fs = engine.gen.last_fault_stats
        if fs is None:
            continue
        tail = np.asarray(fs[FAULT_STAT_BASE:])
        peer = int(tail.argmax()) if tail.size and tail.max() > 0 else 0
        for idx, kind in _KIND_AT.items():
            if fs[idx] > 0:
                events.append((step, kind, peer))
    # host-level fail-stop incident: flat gen rank 3 dies at 3/4 run
    events.append((3 * STEPS // 4, RANK_DEATH, 3))
    trace = FaultTrace.from_events(events)
    trace.save(OUT)
    payload = sum(1 for k in trace.kinds if k != RANK_DEATH)
    print(f"saved {OUT}: {len(trace)} events over {STEPS} steps "
          f"({payload} payload, fallback rate "
          f"{trace.fallback_rate(STEPS):.3f})")


if __name__ == "__main__":
    main()
