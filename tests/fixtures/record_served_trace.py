"""Record the served-routing fixture (served_routing_trace.npz).

    python tests/fixtures/record_served_trace.py

Runs a REAL sync-free engine — 8 fake host devices, (2, 4) mesh, a
32-expert test arch so routing is non-trivial — through the continuous
batching ``ServingScheduler`` with a ``RoutedTraceRecorder`` hooked on
``on_step``, and saves every decode step's per-rank routed-expert
bitmaps (``GenerationServer.routed_bitmaps``: the mirrored sync-free
predictor's ground-truth rows). tests/test_serving.py replays the
fixture through ``core.traces.from_served_trace`` +
``predictor_hit_rate`` and asserts the sync-free predictor's hit rate
on real served routing; re-run this script only when the routing or
predictor stack changes the recorded semantics (then re-baseline the
test's threshold).
"""
import os
import sys

os.environ["XLA_FLAGS"] = (
    os.environ.get("XLA_FLAGS", "")
    + " --xla_force_host_platform_device_count=8"
)

import numpy as np  # noqa: E402

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "..", "src"))

from repro.configs.base import ArchConfig, MoEConfig  # noqa: E402
from repro.launch.serve import build_engine  # noqa: E402
from repro.runtime.serving import (  # noqa: E402
    LiveReplicaClient,
    RoutedTraceRecorder,
    ServingScheduler,
    WorkloadConfig,
    synthesize_workload,
)

CFG = ArchConfig(
    name="served-trace", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=32, top_k=2, d_ff=48),
)
MESH = (2, 4)
OUT = os.path.join(os.path.dirname(__file__), "served_routing_trace.npz")


def main():
    engine, _ = build_engine(
        CFG, mesh_shape=MESH, prefill_len=8, cache_len=64, max_batch=4,
        gen_mode="dwdp",
        policy={"moe_experts": "split:sync_free:allgather:4:4:8"},
    )
    client = LiveReplicaClient.from_engine(engine)
    recorder = RoutedTraceRecorder()
    sched = ServingScheduler(client, on_step=recorder)
    wl = WorkloadConfig(num_requests=8, isl_buckets=(8,), osl=24, seed=3)
    sched.submit(synthesize_workload(wl, vocab_size=CFG.vocab_size))
    sched.run()
    bitmaps = recorder.as_array()
    recorder.save(OUT)
    print(f"saved {OUT}: bitmaps {bitmaps.shape} "
          f"({bitmaps.mean():.4f} mean routed density)")


if __name__ == "__main__":
    main()
