"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; multi-device tests spawn subprocesses."""
import warnings

import jax
import jax.numpy as jnp
import pytest

warnings.filterwarnings("ignore", category=DeprecationWarning)


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh

    return make_smoke_mesh()


@pytest.fixture(scope="session")
def mesh_sizes_1x1():
    return {"data": 1, "model": 1}


def tiny_batch(cfg, batch=2, seq=64, *, train=False, key=0):
    k = jax.random.key(key)
    if cfg.modality == "text":
        toks = jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)
        out = {"tokens": toks}
        if train:
            out["labels"] = jnp.roll(toks, -1, axis=1)
    else:
        out = {"embeds": jax.random.normal(k, (batch, seq, cfg.d_model)) * 0.02}
        if train:
            out["labels"] = jax.random.randint(
                jax.random.key(key + 1), (batch, seq), 0, cfg.vocab_size
            )
    return out
