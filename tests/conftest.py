"""Shared fixtures. NOTE: no XLA_FLAGS here — smoke tests must see the
real single CPU device; multi-device tests spawn subprocesses.

Two collection guards live here so `python -m pytest` works out of the box:

- ``src/`` is inserted onto ``sys.path`` (pyproject's ``pythonpath = src``
  covers pytest>=7; the explicit insert also covers direct imports of the
  test modules).
- ``hypothesis`` is optional (see requirements-dev.txt). When it is not
  installed, a deterministic mini-shim is registered in ``sys.modules``
  before test modules import it: ``@given`` runs the test on a small fixed
  grid of boundary/midpoint examples instead of randomized search, and
  ``@settings`` is a no-op. Property tests keep real coverage either way.
"""
import os
import sys
import types
import warnings

sys.path.insert(
    0, os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))
)

warnings.filterwarnings("ignore", category=DeprecationWarning)


def _install_hypothesis_shim():
    class _Strategy:
        """A strategy reduced to a deterministic list of examples."""

        def __init__(self, examples):
            seen, out = set(), []
            for e in examples:
                if e not in seen:
                    seen.add(e)
                    out.append(e)
            self.examples = out

    def integers(min_value=0, max_value=100, **_kw):
        return _Strategy([min_value, max_value, (min_value + max_value) // 2])

    def floats(min_value=0.0, max_value=1.0, **_kw):
        return _Strategy([min_value, max_value, (min_value + max_value) / 2])

    def sampled_from(seq):
        return _Strategy(list(seq))

    def booleans():
        return _Strategy([False, True])

    def given(*_args, **kwargs):
        assert not _args, "the shim supports keyword-style @given only"

        def deco(fn):
            keys = list(kwargs)
            lens = [len(kwargs[k].examples) for k in keys]
            n_runs = min(10, 2 * max(lens, default=1))

            def wrapper(*a, **kw):
                seen = set()
                for i in range(n_runs):
                    # decorrelated diagonal walk over each strategy's examples
                    ex = {
                        k: kwargs[k].examples[(i + j) % lens[j]]
                        for j, k in enumerate(keys)
                    }
                    sig = tuple(sorted(ex.items()))
                    if sig in seen:
                        continue
                    seen.add(sig)
                    fn(*a, **dict(kw, **ex))

            wrapper.__name__ = fn.__name__
            wrapper.__doc__ = fn.__doc__
            wrapper.__module__ = fn.__module__
            return wrapper

        return deco

    def settings(*_a, **_kw):
        return lambda fn: fn

    mod = types.ModuleType("hypothesis")
    st_mod = types.ModuleType("hypothesis.strategies")
    st_mod.integers = integers
    st_mod.floats = floats
    st_mod.sampled_from = sampled_from
    st_mod.booleans = booleans
    mod.given = given
    mod.settings = settings
    mod.strategies = st_mod
    mod.HealthCheck = types.SimpleNamespace(all=lambda: [])
    sys.modules["hypothesis"] = mod
    sys.modules["hypothesis.strategies"] = st_mod


try:
    import hypothesis  # noqa: F401
except ModuleNotFoundError:
    _install_hypothesis_shim()

import jax
import jax.numpy as jnp
import pytest


@pytest.fixture(scope="session")
def smoke_mesh():
    from repro.launch.mesh import make_smoke_mesh

    return make_smoke_mesh()


@pytest.fixture(scope="session")
def mesh_sizes_1x1():
    return {"data": 1, "model": 1}


def tiny_batch(cfg, batch=2, seq=64, *, train=False, key=0):
    k = jax.random.key(key)
    if cfg.modality == "text":
        toks = jax.random.randint(k, (batch, seq), 0, cfg.vocab_size)
        out = {"tokens": toks}
        if train:
            out["labels"] = jnp.roll(toks, -1, axis=1)
    else:
        out = {"embeds": jax.random.normal(k, (batch, seq, cfg.d_model)) * 0.02}
        if train:
            out["labels"] = jax.random.randint(
                jax.random.key(key + 1), (batch, seq), 0, cfg.vocab_size
            )
    return out
