"""Sync-free decode: pure-predictor and mirror-consistency tests
(docs/syncfree.md).

Single-device, host-side coverage of the tentpole's two contracts:

- **Endpoint identity**: with zero index exchange, both transfer
  endpoints (and every mirror) must derive bit-identical speculative
  schedules from the mirrored PredictState. Property-tested over random
  routing histories: the engine's vmapped mirror fold against a
  per-position Python-loop reference (different lowering, same bits),
  the requester-side ``plan_from_bitmap`` against the sender-side
  per-slice compaction, and the schedule digest's single-bit
  sensitivity.
- **Predictor quality**: on seeded Zipf/affinity-skewed routing traces
  (:mod:`repro.core.traces`) at the R1 decode shape the speculative hit
  rate must reach the >= 0.9 acceptance bar, the richer signals
  (per-row affinity + position buckets) must not hurt, and uniform
  routing must honestly stay bad (the generator isn't rigged).

The multi-device bitwise-exactness and lowering claims live in
test_multidevice.py; fault injection in test_faults.py.
"""
import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

import jax
import jax.numpy as jnp

from repro.core import prefetch, traces
from repro.core.placement import make_placement


# --------------------------------------------------------------------------
# trace generator
# --------------------------------------------------------------------------
def test_zipf_trace_shapes_and_determinism():
    t1 = traces.zipf_routing_trace(12, 4, 64, 8, seed=3)
    t2 = traces.zipf_routing_trace(12, 4, 64, 8, seed=3)
    t3 = traces.zipf_routing_trace(12, 4, 64, 8, seed=4)
    assert t1.shape == (12, 4, 8) and t1.dtype == np.int32
    assert (t1 == t2).all()          # seeded: bit-reproducible
    assert (t1 != t3).any()          # seed actually matters
    assert t1.min() >= 0 and t1.max() < 64
    # without replacement: top-k ids distinct within each (step, row)
    for s in range(12):
        for r in range(4):
            assert len(set(t1[s, r])) == 8


def test_zipf_trace_is_skewed_uniform_is_not():
    skew = traces.zipf_routing_trace(
        64, 8, 256, 8, alpha=1.3, affinity=0.8, seed=0
    )
    flat = traces.zipf_routing_trace(
        64, 8, 256, 8, alpha=0.0, affinity=0.0, seed=0
    )
    s_skew = traces.trace_skew(skew, 256)
    s_flat = traces.trace_skew(flat, 256)
    assert s_skew > 3 * s_flat, (s_skew, s_flat)
    assert s_flat < 0.15, s_flat     # uniform: ~k/E + sampling noise
    with pytest.raises(ValueError):
        traces.zipf_routing_trace(4, 2, 8, 16)
    with pytest.raises(ValueError):
        traces.zipf_routing_trace(4, 2, 8, 2, affinity=1.5)


# --------------------------------------------------------------------------
# endpoint identity (the zero-index-exchange contract)
# --------------------------------------------------------------------------
def _mirror_states(steps, g, e, rows, k, seed):
    """Run the mirrored predictor fold two ways over one random exchanged
    history: the engine's ``jax.vmap`` over subgroup positions vs a
    plain Python loop (the 'other endpoint'). Returns both state tuples
    after ``steps`` folds of identical payloads."""
    rng = np.random.default_rng(seed)
    nb = prefetch.N_POS_BUCKETS

    def init():
        return (
            jnp.zeros((g, e)),                 # ema
            jnp.zeros((g, rows, e)),           # aff
            jnp.zeros((g, nb, e)),             # posb
            jnp.zeros((g, 2)),                 # sigw
        )

    vm, lp = init(), init()
    for s in range(steps):
        ids = rng.integers(0, e, size=(g, rows, k))
        routed = np.zeros((g, rows, e), bool)
        for q in range(g):
            for r in range(rows):
                routed[q, r, ids[q, r]] = True
        pos = rng.integers(0, 4 * prefetch.POS_BUCKET_SIZE, size=(g, rows))
        routed = jnp.asarray(routed)
        buckets = jnp.stack(
            [prefetch.position_buckets(jnp.asarray(pos[q])) for q in range(g)]
        )
        outs = jax.vmap(prefetch.update_predictor)(
            vm[0], vm[1], vm[2], vm[3], routed, buckets
        )
        vm = (outs[1], outs[2], outs[3], outs[5])
        per_q = [
            prefetch.update_predictor(
                lp[0][q], lp[1][q], lp[2][q], lp[3][q],
                routed[q], buckets[q],
            )
            for q in range(g)
        ]
        lp = tuple(
            jnp.stack([o[i] for o in per_q]) for i in (1, 2, 3, 5)
        )
    return vm, lp


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=31),
    g=st.sampled_from([2, 4]),
    rows=st.sampled_from([1, 3]),
)
def test_mirror_fold_vmap_matches_loop_bitwise(seed, g, rows):
    """Both endpoints fold the identical exchanged payload — one vmapped
    (the engine), one looped (the reference) — and every mirrored leaf
    must stay BIT-identical: the fold is deterministic in the exchanged
    bits alone, which is what lets the spec round ship no index
    metadata."""
    vm, lp = _mirror_states(steps=5, g=g, e=24, rows=rows, k=3, seed=seed)
    for a, b in zip(vm, lp):
        assert a.shape == b.shape
        assert bool(jnp.all(a == b)), "mirror fold diverged"


@settings(max_examples=6, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=31),
    budget=st.integers(min_value=1, max_value=5),
    want_frac=st.floats(min_value=0.0, max_value=1.0),
)
def test_plan_from_bitmap_requester_matches_sender(seed, budget, want_frac):
    """The spec round's wire contract: for every (requester q, sender o)
    pair, the requester-side ``plan_from_bitmap`` compaction of q's
    bitmap must equal the sender-side per-slice compaction of the SAME
    bitmap — ascending ids, identical padding, identical validity — so
    payload rows land exactly where the requester's remap expects them."""
    g, local = 4, 5
    e = g * local
    rng = np.random.default_rng(seed)
    masks = jnp.asarray(rng.random((g, e)) < want_frac)
    for q in range(g):
        ids, valid, _ = prefetch.plan_from_bitmap(
            masks[q], q, g, local, budget
        )
        for t in range(1, g):
            o = (q + t) % g
            mslice = masks[q, o * local:(o + 1) * local]
            idx_s, valid_s, _ = prefetch._compact_requests(mslice, budget)
            lo = (t - 1) * budget
            assert bool(
                jnp.all(ids[lo:lo + budget] == o * local + idx_s)
            )
            assert bool(jnp.all(valid[lo:lo + budget] == valid_s))


@settings(max_examples=8, deadline=None)
@given(
    seed=st.integers(min_value=0, max_value=63),
    flip=st.integers(min_value=0, max_value=79),
)
def test_schedule_digest_single_bit_sensitivity(seed, flip):
    """The divergence cross-check's detection floor: flipping any single
    bit of a derived schedule changes its digest (the weights are
    distinct positive integers), and equal schedules always agree — so
    ``|G' * own - psum| > 0.5`` catches every single-schedule desync."""
    rng = np.random.default_rng(seed)
    masks = jnp.asarray(rng.random((4, 20)) < 0.3)
    d0 = prefetch.schedule_digest(masks)
    assert float(d0) == int(d0)  # integer-valued: the check is exact
    flipped = masks.reshape(-1).at[flip].set(~masks.reshape(-1)[flip])
    d1 = prefetch.schedule_digest(flipped.reshape(4, 20))
    assert float(d0) != float(d1)
    assert float(prefetch.schedule_digest(masks)) == float(d0)


def test_pack_unpack_mirror_roundtrip():
    rng = np.random.default_rng(0)
    e, rows = 20, 3
    routed = jnp.asarray(rng.random((rows, e)) < 0.2)
    buckets = prefetch.position_buckets(jnp.asarray([0, 70, 999]))
    packed = prefetch.pack_mirror_payload(routed, buckets)
    assert packed.shape == (rows * (e + prefetch.N_POS_BUCKETS),)
    m2, b2 = prefetch.unpack_mirror_payload(packed, e)
    assert bool(jnp.all(m2 == routed))
    assert bool(jnp.all(b2 == buckets))
    # leading dims pass through (the all-gathered (G', total) form) and
    # rows is recovered from the packed length
    stacked = jnp.stack([packed, packed])
    m3, b3 = prefetch.unpack_mirror_payload(stacked, e)
    assert m3.shape == (2, rows, e)
    assert b3.shape == (2, rows, prefetch.N_POS_BUCKETS)
    assert bool(jnp.all(m3[1] == routed))


def test_sync_free_mirror_bytes_per_step():
    """The per-step mirror round's wire accounting matches the packed
    payload the fold actually gathers, and the per-layer correction
    meta shrank to the residual bitmap alone."""
    from repro.core.placement import make_placement

    pl = make_placement(20, 4)
    rows = 3
    packed = prefetch.pack_mirror_payload(
        jnp.zeros((rows, pl.num_padded), bool),
        jnp.zeros((rows, prefetch.N_POS_BUCKETS), bool),
    )
    assert prefetch.sync_free_mirror_bytes(pl, rows) == (
        (pl.subgroup_size - 1) * packed.shape[0]
    )
    by = prefetch.sync_free_fetch_bytes(pl, 4, 4, rows, 100)
    by_v = prefetch.sync_free_fetch_bytes(pl, 4, 4, rows, 100, validate=True)
    g, e = pl.subgroup_size, pl.num_padded
    assert by["corr"] == (g - 1) * (4 * 100 + e)
    assert by_v["corr"] - by["corr"] == (g - 1) * 4 * e  # checksum table


# --------------------------------------------------------------------------
# predictor quality on skewed traces (the hit-rate acceptance)
# --------------------------------------------------------------------------
def _spec_hit_rate(trace, placement, spec_budget, *, rich=True):
    """Replay one rank's mirror over a routing trace: predict BEFORE each
    step from state folded on the steps so far, score hits against the
    step's actual remote wanted set. Pure prefetch functions — exactly
    the arithmetic both endpoints run."""
    e = placement.num_padded
    local = placement.local_count
    steps, rows, _ = trace.shape
    own = jnp.arange(e) // local == 0  # position p=0's resident slice
    ema = jnp.zeros(e)
    prev = jnp.zeros(e, bool)
    aff = jnp.zeros((rows, e))
    posb = jnp.zeros((prefetch.N_POS_BUCKETS, e))
    sigw = jnp.zeros(2)
    sig = jnp.zeros((2, e))
    hit = want = 0.0
    for s in range(steps):
        extra = prefetch.predict_extra_score(sig, sigw) if rich else None
        spec = prefetch.predict_bitmap(
            prev, ema, placement, budget=spec_budget, extra_score=extra
        )
        routed = prefetch.routed_bitmaps(jnp.asarray(trace[s]), e)
        buckets = prefetch.position_buckets(jnp.full((rows,), s))
        wanted_remote = jnp.any(routed, axis=0) & ~own
        if s > 0:  # cold-start step can't hit anything: don't score it
            hit += float(jnp.sum(wanted_remote & spec))
            want += float(jnp.sum(wanted_remote))
        prev, ema, aff, posb, sig, sigw = prefetch.update_predictor(
            ema, aff, posb, sigw, routed, buckets
        )
    return hit / max(want, 1.0)


def test_spec_hit_rate_meets_acceptance_on_skewed_trace():
    """The R1 decode acceptance shape — E=256 over G'=4 (local 64),
    8 rows x top-8 — with Zipf/affinity-skewed routing: the mirrored
    predictor's speculative hit rate must reach 0.9 with the default
    speculative budget (16 rows/peer, the roofline's auto sizing), and
    the richer signals must not do worse than hotness alone."""
    pl = make_placement(256, 4)
    assert pl.local_count == 64
    trace = traces.zipf_routing_trace(
        48, 8, 256, 8, alpha=1.3, affinity=0.8, drift_every=24, seed=7
    )
    rate = _spec_hit_rate(trace, pl, spec_budget=16, rich=True)
    assert rate >= 0.9, f"spec hit rate {rate:.3f} < 0.9"
    plain = _spec_hit_rate(trace, pl, spec_budget=16, rich=False)
    assert rate >= plain - 0.02, (rate, plain)


def test_spec_hit_rate_honest_on_uniform_routing():
    """No predictor beats uniform routing with a budget far below E —
    the generator and the harness aren't rigged: uniform traces stay
    well under the acceptance bar at the same budget."""
    pl = make_placement(256, 4)
    trace = traces.zipf_routing_trace(
        32, 8, 256, 8, alpha=0.0, affinity=0.0, seed=7
    )
    rate = _spec_hit_rate(trace, pl, spec_budget=16, rich=True)
    assert rate < 0.6, rate
