"""Unit + property tests for the paper's core: placement, contention
model, roofline model, copy plan (Listing 1), and MoE dispatch."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import get_arch
from repro.core import contention, roofline
from repro.core.placement import expand_to_storage, make_placement
from repro.models import moe as moe_lib


# --------------------------------------------------------------------------
# placement (paper §2: weak placement constraint)
# --------------------------------------------------------------------------
@settings(deadline=None, max_examples=60)
@given(e=st.integers(1, 300), g=st.integers(1, 64))
def test_placement_invariants(e, g):
    pl = make_placement(e, g)
    # every rank stores the same number of experts (paper: uniform local)
    assert pl.local_count * pl.subgroup_size == pl.num_padded >= e
    assert pl.subgroup_size * pl.redundancy == pl.group_size == g
    table = pl.table()
    assert table.shape == (g, pl.local_count)
    # every subgroup collectively covers every real expert exactly once
    for s in range(pl.redundancy):
        rows = table[s * pl.subgroup_size : (s + 1) * pl.subgroup_size]
        ids = sorted(rows.reshape(-1).tolist())
        assert ids == list(range(pl.num_padded))


@settings(deadline=None, max_examples=30)
@given(e=st.integers(1, 64), g=st.integers(1, 32))
def test_placement_expand_roundtrip(e, g):
    pl = make_placement(e, g)
    experts = np.arange(pl.num_padded * 3).reshape(pl.num_padded, 3)
    stor = expand_to_storage(experts, pl)
    assert stor.shape == (pl.storage_size, 3)
    # rank r's shard equals the experts its table row names
    t = pl.table()
    for r in range(g):
        np.testing.assert_array_equal(
            stor[r * pl.local_count : (r + 1) * pl.local_count], experts[t[r]]
        )


def test_placement_grok_case():
    """Paper's motivating case: 8 experts, group sizes that don't divide."""
    pl3 = make_placement(8, 3)   # DWDP3 from Table 3d
    assert pl3.redundancy == 1 and pl3.num_padded == 9
    pl16 = make_placement(8, 16)  # grok on the 16-wide model axis
    assert pl16.redundancy == 2 and pl16.subgroup_size == 8
    assert pl16.remote_fraction == pytest.approx(7 / 8)


# --------------------------------------------------------------------------
# contention model (paper §4.3, Table 2)
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "n,expected",
    [
        (3, {1: 0.5, 2: 0.5}),
        (4, {1: 4 / 9, 2: 4 / 9, 3: 1 / 9}),
    ],
)
def test_contention_table2_exact(n, expected):
    got = contention.contention_probabilities(n)
    for c, p in expected.items():
        assert got[c] == pytest.approx(p, abs=1e-12)


@settings(deadline=None, max_examples=20)
@given(n=st.integers(2, 24))
def test_contention_is_distribution(n):
    pr = contention.contention_probabilities(n)
    assert sum(pr.values()) == pytest.approx(1.0, abs=1e-9)
    assert all(p >= 0 for p in pr.values())
    # paper's observation: C=1 and C=2 are the most likely outcomes
    if n >= 3:
        top = max(pr, key=pr.get)
        assert top in (1, 2)


def test_contention_table2_row_dwdp8():
    pr = contention.contention_probabilities(8)
    # Table 2, DWDP8 row (percent, 2dp)
    assert round(100 * pr[1], 2) == 39.66
    assert round(100 * pr[2], 2) == 39.66
    assert round(100 * pr[3], 2) == 16.52
    assert round(100 * pr[4], 2) == 3.67


def test_copy_plan_listing1():
    plan = contention.build_copy_plan({"w": 10}, [1, 2, 3], slice_bytes=4)
    # slices interleave peers round-robin; all bytes covered per peer
    per_peer = {}
    for name, peer, off, chunk in plan:
        per_peer.setdefault(peer, []).append((off, chunk))
    for peer, chunks in per_peer.items():
        assert sorted(chunks) == [(0, 4), (4, 4), (8, 2)]
    # round-robin rotation: first slice order 1,2,3; second 2,3,1
    order = [p for (_, p, o, _) in plan if o == 0]
    order2 = [p for (_, p, o, _) in plan if o == 4]
    assert order == [1, 2, 3] and order2 == [2, 3, 1]


def test_tdm_mitigation_helps_when_contended():
    out = contention.tdm_speedup(8, pull_bytes=64 << 20, bw=900e9)
    assert out["speedup"] >= 1.0  # slicing never hurts in the model


# --------------------------------------------------------------------------
# roofline model (paper §3, Fig. 3)
# --------------------------------------------------------------------------
def test_fig3_crossover_near_paper():
    """Paper: DWDP4 prefetch fully hidden at ~16K ISL for R1 ctx, bs=1."""
    cfg = get_arch("deepseek-r1")
    x = roofline.crossover_isl(cfg, group=4, batch=1)
    assert x is not None and 4096 <= x <= 40960, x


def test_fig3_speedup_shape():
    """DEP/DWDP speedup >1 past crossover and decreasing at very long ISL."""
    cfg = get_arch("deepseek-r1")
    rows = roofline.figure3_sweep(cfg, group=4)
    sp = {r["isl"]: r["dep_to_dwdp"] for r in rows}
    assert sp[32768] > 1.0
    assert sp[131072] < sp[32768]  # marginal gain shrinks with ISL (paper)
    ratios = [r["compute_to_prefetch"] for r in rows]
    assert ratios == sorted(ratios)  # monotone in ISL


# --------------------------------------------------------------------------
# MoE dispatch math
# --------------------------------------------------------------------------
@settings(deadline=None, max_examples=20)
@given(
    t=st.sampled_from([16, 64]),
    e=st.sampled_from([4, 8, 16]),
    k=st.integers(1, 4),
)
def test_moe_dispatch_combine_identity(t, e, k):
    """With infinite capacity, dispatch->identity-experts->combine equals
    the input (combine weights sum to 1)."""
    k = min(k, e)
    d = 8
    key = jax.random.key(t + e + k)
    x = jax.random.normal(key, (t, d))
    w_router = jax.random.normal(jax.random.key(1), (d, e)) * 0.3
    disp = moe_lib.route_topk(x, w_router, k, capacity=t * k)
    xe = moe_lib.dispatch_tokens(x, disp, e, t * k)
    y = moe_lib.combine_tokens(xe, disp, t)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), rtol=2e-5, atol=2e-5)


def test_moe_padded_experts_never_routed():
    t, e_real, e_pad, d = 32, 5, 8, 16
    x = jax.random.normal(jax.random.key(0), (t, d))
    w_router = jax.random.normal(jax.random.key(1), (d, e_pad))
    disp = moe_lib.route_topk(x, w_router, 2, capacity=16, num_real=e_real)
    assert int(disp.top_experts.max()) < e_real


@settings(deadline=None, max_examples=15)
@given(
    rows=st.sampled_from([2, 4, 8]),
    s=st.sampled_from([8, 16]),
    k=st.integers(1, 2),
)
def test_route_topk_rows_is_row_local(rows, s, k):
    """The capacity_from="global" invariant at the dispatch level: with
    per-row routing, a row's (keep, weight, expert) assignment is the
    same whether it is dispatched alone or co-batched with other rows —
    the property that makes drops identical across batch-sharding
    layouts."""
    e, d = 4, 8
    cap = max(2, s * k // e)  # tight: some tokens drop
    key = jax.random.key(rows * 31 + s + k)
    x = jax.random.normal(key, (rows, s, d))
    w_router = jax.random.normal(jax.random.key(1), (d, e)) * 0.5
    full = moe_lib.route_topk_rows(x, w_router, k, cap)
    for r in range(rows):
        solo = moe_lib.route_topk_rows(x[r : r + 1], w_router, k, cap)
        sl = slice(r * s * k, (r + 1) * s * k)
        np.testing.assert_array_equal(
            np.asarray(full.keep[sl]), np.asarray(solo.keep)
        )
        np.testing.assert_allclose(
            np.asarray(full.weight[sl]), np.asarray(solo.weight), atol=1e-6
        )
        np.testing.assert_array_equal(
            np.asarray(full.top_experts[r * s : (r + 1) * s]),
            np.asarray(solo.top_experts),
        )


def test_route_topk_rows_dispatch_combine_identity():
    """Per-row dispatch through the (E, R*cap) slot grid round-trips like
    the flat dispatch: with ample capacity, dispatch -> identity-experts
    -> combine reproduces the input."""
    rows, s, d, e, k = 3, 8, 6, 4, 2
    x = jax.random.normal(jax.random.key(0), (rows, s, d))
    w_router = jax.random.normal(jax.random.key(1), (d, e)) * 0.3
    cap = s * k  # no drops
    disp = moe_lib.route_topk_rows(x, w_router, k, cap)
    x2d = x.reshape(rows * s, d)
    xe = moe_lib.dispatch_tokens(x2d, disp, e, rows * cap)
    y = moe_lib.combine_tokens(xe, disp, rows * s)
    np.testing.assert_allclose(
        np.asarray(y), np.asarray(x2d), rtol=2e-5, atol=2e-5
    )


def _plan_fixture():
    import jax.numpy as jnp

    from repro.configs import reduced_variant
    from repro.configs.base import InputShape
    from repro.models.transformer import build_model

    cfg = reduced_variant(get_arch("yi-9b"))
    ms = {"data": 1, "model": 1}
    m = build_model(cfg, ms, dtype=jnp.float32)
    shape = InputShape("p", 32, 2, "prefill")
    return m, shape, ms


# --------------------------------------------------------------------------
# GatherPolicy / PolicyTable: the per-family policy surface
# --------------------------------------------------------------------------
def test_gather_policy_parse_and_validation():
    from repro.core.strategy import GatherPolicy

    p = GatherPolicy.parse("split:demand:ring_sliced:8:16")
    assert p == GatherPolicy("split", "demand", "ring_sliced", 8, 16)
    assert GatherPolicy.parse(p.spec()) == p  # spec round-trips
    assert GatherPolicy.parse("merged") == GatherPolicy(layout="merged")
    assert GatherPolicy.parse({"layout": "merged"}).layout == "merged"
    for bad in ("bogus", "split:bogus", "split:all:bogus", "split:all::",
                "split:all:ring:x"):
        with pytest.raises(ValueError):
            GatherPolicy.parse(bad)
    with pytest.raises(ValueError, match="split layout"):
        GatherPolicy(layout="merged", fetch="demand")
    with pytest.raises(ValueError):
        GatherPolicy.parse({"layot": "split"})  # unknown field


def test_policy_table_lookup_overrides_and_roundtrip():
    from repro.core.strategy import GatherPolicy, PolicyTable

    demand = GatherPolicy(layout="split", fetch="demand")
    merged = GatherPolicy(layout="merged")
    t = PolicyTable(
        default=GatherPolicy(),
        families=(("moe_experts", demand), ("attn_qkv", merged)),
        overrides=(("blocks", "moe_experts", merged),),
    )
    # resolution order: (group, family) override > family > default
    assert t.family("moe_experts") == demand
    assert t.family("moe_experts", group="blocks") == merged
    assert t.family("moe_experts", group="other") == demand
    assert t.family("attn_qkv") == merged
    assert t.family("dense_ffn") == t.default
    assert PolicyTable.from_dict(t.to_dict()) == t  # JSON round-trip
    with pytest.raises(ValueError, match="unknown gather family"):
        t.family("bogus")
    with pytest.raises(ValueError, match="unknown gather family"):
        PolicyTable(families=(("bogus", merged),))
    with pytest.raises(ValueError, match="moe_experts"):
        PolicyTable(families=(("attn_qkv", demand),))
    with pytest.raises(ValueError, match="duplicate"):
        PolicyTable(families=(("attn_qkv", merged), ("attn_qkv", merged)))
    # uniform demand = demand experts + all-fetch everything else
    u = PolicyTable.uniform(layout="split", fetch="demand", budget=16)
    assert u.family("moe_experts").fetch == "demand"
    assert u.family("moe_experts").budget == 16
    assert u.family("dense_ffn").fetch == "all"


def test_make_execution_plan_policy_surface():
    """policy= is the canonical surface: tables, per-family dicts, and
    uniform spec strings all resolve; the resolved table is what every
    consumer reads via plan.policy(family)."""
    from repro.core.strategy import PolicyTable, make_execution_plan

    m, shape, ms = _plan_fixture()
    xp = make_execution_plan(m, shape, ms)
    assert xp.policy("moe_experts").layout == "split"
    assert xp.policy("attn_qkv").transport == "allgather"
    assert xp.capacity_from == "local"
    mixed = make_execution_plan(m, shape, ms, policy={
        "moe_experts": "split:demand:ring_sliced",
        "attn_qkv": "merged",
        "default": "split:all:ring",
    })
    assert mixed.policy("moe_experts").fetch == "demand"
    assert mixed.policy("moe_experts").transport == "ring_sliced"
    assert mixed.policy("attn_qkv").layout == "merged"
    assert mixed.policy("dense_ffn").transport == "ring"
    spec = make_execution_plan(m, shape, ms, policy="merged:all:ring")
    assert spec.policy("dense_ffn").layout == "merged"
    assert spec.policy("dense_ffn").transport == "ring"
    tab = make_execution_plan(
        m, shape, ms, policy=PolicyTable.uniform(layout="merged")
    )
    assert tab.policy("attn_out").layout == "merged"
    xp4 = make_execution_plan(m, shape, ms, capacity_from="global")
    assert xp4.capacity_from == "global"
    with pytest.raises(ValueError, match="unknown gather family"):
        make_execution_plan(m, shape, ms, policy={"bogus": "split"})
    # per-layer-group overrides are validated against the model's plan,
    # so a typo'd group errors instead of silently never matching
    gname = m.plan[0].name
    ok = make_execution_plan(
        m, shape, ms, policy={f"{gname}/moe_experts": "merged"}
    )
    assert ok.policy("moe_experts", gname).layout == "merged"
    with pytest.raises(ValueError, match="unknown layer group"):
        make_execution_plan(
            m, shape, ms, policy={"not-a-group/moe_experts": "merged"}
        )


@pytest.mark.filterwarnings("ignore::DeprecationWarning")
def test_legacy_flat_kwargs_build_uniform_table():
    """Every legacy flat kwarg keeps working as a deprecated alias that
    builds the equivalent uniform PolicyTable — identical resolved
    behavior, DeprecationWarning included."""
    from repro.core.strategy import PolicyTable, make_execution_plan

    m, shape, ms = _plan_fixture()
    with pytest.warns(DeprecationWarning, match="deprecated flat knobs"):
        legacy = make_execution_plan(
            m, shape, ms, weight_layout="merged", prefetch="ring",
            num_slices=8,
        )
    assert legacy.policies == PolicyTable.uniform(
        layout="merged", transport="ring", num_slices=8
    )
    with pytest.warns(DeprecationWarning, match="moe_ffn"):
        xp2 = make_execution_plan(m, shape, ms, moe_ffn="merged")
    assert xp2.policy("moe_experts").layout == "merged"
    with pytest.warns(DeprecationWarning):
        dem = make_execution_plan(
            m, shape, ms, expert_fetch="demand", demand_budget=16
        )
    assert dem.policies == PolicyTable.uniform(
        layout="split", fetch="demand", budget=16
    )
    # deprecated reads on the plan reflect the table (and warn — below)
    with pytest.warns(DeprecationWarning, match="ExecutionPlan.prefetch"):
        assert legacy.prefetch == "ring"
    with pytest.warns(DeprecationWarning, match="weight_layout"):
        assert legacy.weight_layout == "merged"
    with pytest.warns(DeprecationWarning, match="expert_fetch"):
        assert dem.expert_fetch == "demand"
    with pytest.warns(DeprecationWarning, match="demand_budget"):
        assert dem.demand_budget == 16
    # conflicts: moe_ffn vs weight_layout, and legacy vs policy=
    with pytest.warns(DeprecationWarning, match="moe_ffn"):
        with pytest.raises(ValueError, match="conflicting"):
            make_execution_plan(
                m, shape, ms, weight_layout="split", moe_ffn="merged"
            )
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="conflicting policy="):
            make_execution_plan(
                m, shape, ms, policy="split", weight_layout="merged"
            )
    # demand still requires the split layout through the legacy spelling
    with pytest.warns(DeprecationWarning):
        with pytest.raises(ValueError, match="demand"):
            make_execution_plan(
                m, shape, ms, weight_layout="merged", expert_fetch="demand"
            )


def test_moe_ffn_property_warns_on_read():
    """The PR 3 gap, closed: ExecutionPlan.moe_ffn warns on *access* too,
    not just when passed as a kwarg."""
    from repro.core.strategy import make_execution_plan

    m, shape, ms = _plan_fixture()
    xp = make_execution_plan(m, shape, ms)
    with pytest.warns(DeprecationWarning, match="moe_ffn"):
        assert xp.moe_ffn == "split"


def test_new_policy_surface_does_not_warn():
    import warnings as _warnings

    from repro.core.strategy import make_execution_plan

    m, shape, ms = _plan_fixture()
    with _warnings.catch_warnings():
        _warnings.simplefilter("error", DeprecationWarning)
        xp = make_execution_plan(
            m, shape, ms, policy={"moe_experts": "split:demand"}
        )
        assert xp.policy("moe_experts").fetch == "demand"
        assert xp.policies.describe()


# --------------------------------------------------------------------------
# The roofline-guided policy="auto" resolver
# --------------------------------------------------------------------------
def _r1_gather_model():
    from repro.models.transformer import build_model

    cfg = get_arch("deepseek-r1")
    ms = {"data": 2, "model": 4}
    # the DWDP4 gather geometry (R1's default on this mesh escalates to
    # the wide rotate placement; the policy API targets the gather path)
    return cfg, ms, build_model(cfg, ms, moe_exec="gather",
                                expert_axes=("model",))


def test_auto_resolver_decision_rules():
    """decode at per-rank rows where the overlap pays -> sync_free
    experts (mirrored predictor: the speculative round drops its index
    exchange, so it prices below plain predictive, with a residency
    cache budget bounded by HBM headroom); single-row decode -> plain
    demand (the speculative round's padded wire would double the
    payload for nothing); long prefill (full coverage) -> all-fetch;
    ring_sliced only for banks above the size threshold (R1's GB-scale
    expert banks yes, tiny banks no)."""
    from repro.configs.base import InputShape
    from repro.core.strategy import resolve_policies

    cfg, ms, m = _r1_gather_model()
    # gen_batch=8 PER RANK (global 64 over the 8-rank mesh): the
    # acceptance decode shape — sync_free wins on the overlapped,
    # metadata-free round
    dec = resolve_policies(m, InputShape("gen", 2048, 64, "decode"), ms)
    assert dec.family("moe_experts").fetch == "sync_free"
    assert dec.family("moe_experts").layout == "split"
    assert dec.family("moe_experts").transport == "ring_sliced"
    # single routed row per rank: the speculative round cannot pay for
    # its padding, the resolver honestly keeps the plain demand round
    dec1 = resolve_policies(m, InputShape("gen", 2048, 8, "decode"), ms)
    assert dec1.family("moe_experts").fetch == "demand"
    ctx = resolve_policies(m, InputShape("ctx", 16384, 1, "prefill"), ms)
    assert ctx.family("moe_experts").fetch == "all"
    assert ctx.family("moe_experts").layout == "split"
    # a tiny MoE's banks fall below the TDM threshold -> allgather
    from repro.configs import reduced_variant
    from repro.models.transformer import build_model
    import jax.numpy as jnp

    small = reduced_variant(get_arch("glm4-9b"))
    ms2 = {"data": 2, "model": 4}
    m2 = build_model(small, ms2, dtype=jnp.float32)
    t2 = resolve_policies(m2, InputShape("gen", 64, 8, "decode"), ms2)
    assert t2.family("moe_experts").transport == "allgather"


def test_auto_beats_every_uniform_policy_r1_decode():
    """The acceptance criterion: at the DeepSeek-R1 gen_batch=8 (per
    rank) / topk=8 / E=256 decode shape, policy="auto" selects
    per-family policies whose modeled (roofline.modeled_step_time over
    layer_times) decode step time is <= EVERY uniform policy's —
    "predictive" included — with each uniform table priced at its
    ENGINE-effective resolution (strategy.effective_policies: split
    demotes to merged where the split path cannot engage, so the
    comparison never credits an unlowerable saving)."""
    from repro.configs.base import InputShape
    from repro.core import roofline
    from repro.core.strategy import (
        PolicyTable, effective_policies, resolve_policies,
    )

    cfg, ms, m = _r1_gather_model()
    assert cfg.moe.num_experts == 256 and cfg.moe.top_k == 8
    shape = InputShape("gen", 2048, 64, "decode")  # 8 rows/rank on 8 ranks
    auto = resolve_policies(m, shape, ms)
    assert auto.family("moe_experts").fetch == "sync_free"
    kw = dict(tokens=8, group=4, kv_len=2048,
              attn_gathered=bool(m.geom.attn_axes))
    t_auto = roofline.modeled_step_time(cfg, policies=auto, **kw)
    uniforms = {}
    for layout in ("merged", "split"):
        fetches = (
            ("all", "demand", "predictive", "sync_free")
            if layout == "split" else ("all",)
        )
        for fetch in fetches:
            for transport in ("allgather", "ring", "ring_sliced"):
                tab = effective_policies(m, shape, ms, PolicyTable.uniform(
                    layout=layout, fetch=fetch, transport=transport
                ))
                uniforms[f"{layout}/{fetch}/{transport}"] = (
                    roofline.modeled_step_time(cfg, policies=tab, **kw)
                )
    worst = max(uniforms, key=uniforms.get)
    assert all(t_auto <= t + 1e-15 for t in uniforms.values()), (
        t_auto, uniforms)
    # and the win is real, not a tie across the board
    assert t_auto < uniforms[worst] * 0.75


def test_layer_times_policies_match_flat_knobs():
    """layer_times(policies=uniform_table) reproduces the flat-knob
    spelling exactly, and a mixed table prices each family's layout
    independently (merged attention raises only the landing bytes)."""
    from repro.core.strategy import PolicyTable

    cfg = get_arch("deepseek-r1")
    moe_layer = cfg.moe.first_dense
    kw = dict(tokens=8, group=4, layer=moe_layer, attn_gathered=True)
    for layout in ("merged", "split"):
        flat = roofline.layer_times(cfg, weight_layout=layout, **kw)
        tab = roofline.layer_times(
            cfg, policies=PolicyTable.uniform(layout=layout), **kw
        )
        assert flat == tab
    mixed = roofline.layer_times(
        cfg,
        policies=PolicyTable.from_dict(
            {"default": "split", "attn_qkv": "merged", "attn_out": "merged"}
        ),
        **kw,
    )
    all_split = roofline.layer_times(
        cfg, policies=PolicyTable.uniform(layout="split"), **kw
    )
    assert mixed.prefetch == all_split.prefetch  # wire bytes unchanged
    assert mixed.land_bytes > all_split.land_bytes  # merged attn re-lands
    assert mixed.compute == all_split.compute


# --------------------------------------------------------------------------
# on-demand expert fetch: expected-coverage closed form + roofline wiring
# --------------------------------------------------------------------------
def test_expected_distinct_experts_closed_form():
    """E[distinct] = E(1 - (1 - 1/E)^n): zero draws hit nothing, the curve
    is monotone in n, bounded by min(n, E), and saturates toward E."""
    f = roofline.expected_distinct_experts
    assert f(0, 256) == 0.0
    prev = 0.0
    for n in (1, 8, 64, 512, 4096):
        cur = f(n, 256)
        assert prev < cur <= min(n, 256) + 1e-9
        prev = cur
    assert f(1, 256) == pytest.approx(1.0)
    assert f(100_000, 256) == pytest.approx(256.0, rel=1e-3)


@settings(deadline=None, max_examples=24)
@given(
    e=st.sampled_from([4, 16, 64, 256]),
    n=st.sampled_from([1, 8, 64, 512]),
)
def test_expected_coverage_matches_empirical_multinomial(e, n):
    """The satellite guard: the ``E(1-(1-1/E)^n)`` closed form — which
    now sizes BOTH the demand auto-budget and the predictive
    speculative/correction budgets — must match the empirical mean
    distinct-expert count of seeded multinomial (uniform) routing draws
    within sampling tolerance."""
    rng = np.random.default_rng(e * 1009 + n)
    trials = 256
    draws = rng.integers(0, e, size=(trials, n))
    distinct = np.array([len(np.unique(row)) for row in draws])
    closed = roofline.expected_distinct_experts(n, e)
    se = distinct.std() / math.sqrt(trials)
    assert abs(distinct.mean() - closed) <= max(4.0 * se, 0.02 * closed + 0.05), (
        distinct.mean(), closed, se,
    )
    # and the budgets the closed form sizes bracket it correctly
    local = max(1, e // 4)
    b = roofline.demand_budget_rows(n, e, local)
    spec, corr = roofline.predictive_budget_rows(n, e, local)
    per_peer = closed / e * local  # expected per-peer coverage
    assert b >= min(local, per_peer)            # demand budget covers 2x
    assert 1 <= spec <= local and 1 <= corr <= local
    assert spec + corr <= 2 * b  # predictive never pads past 2x demand


def test_predictive_budget_rows_below_demand_budget():
    """At the R1 acceptance shape the predictive speculative+correction
    budgets together ship FEWER payload rows than the plain demand
    budget (the wire-bytes <= demand acceptance), while each stays
    8-aligned and positive."""
    e, local = 256, 64
    draws = 8 * 8  # gen_batch=8 rows * top_k=8
    b = roofline.demand_budget_rows(draws, e, local)
    spec, corr = roofline.predictive_budget_rows(draws, e, local)
    assert (spec, corr) == (16, 8) and b == 32
    assert spec + corr < b
    assert spec % 8 == 0 and corr % 8 == 0


def test_demand_prefetch_bytes_below_full_and_capped():
    """Decode-scale routing (gen_batch=8, topk=8, E=256, DWDP4 — the
    acceptance shape) must model strictly fewer wire bytes than the full
    remote gather; at prefill-scale coverage the model caps at the full
    gather, never above. The priced payload is the budget-PADDED one
    (the engine's shared auto rule), so it matches what the lowered
    program ships — budget 32 of 64 local rows at this shape."""
    e, k, group = 256, 8, 4
    local = e // group
    pe = 3 * 7168 * 2048 * 1  # R1-ish expert bytes (NVFP4)
    full = e * pe * (group - 1) / group
    assert roofline.demand_budget_rows(8 * k, e, local) == 32
    demand = roofline.demand_prefetch_bytes(8, k, e, group, pe)
    assert demand == pytest.approx(full * 0.5, rel=1e-3), (demand, full)
    assert demand < full
    # engine parity: an explicit budget prices (G'-1) * budget rows
    explicit = roofline.demand_prefetch_bytes(8, k, e, group, pe, budget=8)
    assert explicit == pytest.approx(
        (group - 1) * (8 * pe + e), rel=1e-9
    )
    # near-full coverage: capped at the full remote gather
    capped = roofline.demand_prefetch_bytes(100_000, k, e, group, pe)
    assert capped == pytest.approx(full)


def test_layer_times_demand_shrinks_decode_prefetch():
    """layer_times(expert_fetch="demand") shrinks the decode prefetch
    term (the dominant decode communication term) and leaves compute
    untouched; at context-phase token counts the term is unchanged
    (coverage is full, demand auto-falls-back)."""
    cfg = get_arch("deepseek-r1")
    moe_layer = cfg.moe.first_dense
    kw = dict(group=4, layer=moe_layer, weight_layout="split")
    dec_all = roofline.layer_times(cfg, tokens=8, **kw)
    dec_dem = roofline.layer_times(cfg, tokens=8, expert_fetch="demand", **kw)
    assert dec_dem.prefetch < dec_all.prefetch
    assert dec_dem.land_bytes < dec_all.land_bytes
    assert dec_dem.compute == dec_all.compute
    ctx_all = roofline.layer_times(cfg, tokens=16384, **kw)
    ctx_dem = roofline.layer_times(
        cfg, tokens=16384, expert_fetch="demand", **kw
    )
    assert ctx_dem.prefetch == ctx_all.prefetch


def test_predictive_modeled_below_demand_r1_decode():
    """The modeled-perf acceptance: at the R1 decode shape (8 rows/rank,
    topk=8, E=256, DWDP4) ``fetch="predictive"`` models a strictly
    smaller step time than ``fetch="demand"`` — the speculative round
    overlaps compute (``max(compute, spec) + correction`` instead of
    ``compute + whole round``) — and its wire bytes never exceed the
    plain demand round's. A residency cache pushes both further down."""
    from repro.core.strategy import PolicyTable

    cfg = get_arch("deepseek-r1")
    kw = dict(tokens=8, group=4, kv_len=2048, attn_gathered=True)
    t = {
        fetch: roofline.modeled_step_time(
            cfg, policies=PolicyTable.uniform(layout="split", fetch=fetch),
            **kw,
        )
        for fetch in ("all", "demand", "predictive", "sync_free")
    }
    assert t["predictive"] < t["demand"] < t["all"], t
    # sync_free prices at or below predictive: the speculative round
    # sheds its per-layer bitmap all-gather (the metadata now rides the
    # correction round, which already prices its packed payload)
    assert t["sync_free"] <= t["predictive"], t
    # per-layer wire: predictive total <= demand total; serial strictly <
    moe_layer = cfg.moe.first_dense
    lt_d = roofline.layer_times(
        cfg, tokens=8, group=4, layer=moe_layer,
        policies=PolicyTable.uniform(layout="split", fetch="demand"),
    )
    lt_p = roofline.layer_times(
        cfg, tokens=8, group=4, layer=moe_layer,
        policies=PolicyTable.uniform(layout="split", fetch="predictive"),
    )
    assert lt_p.prefetch <= lt_d.prefetch
    assert lt_p.serial_fetch < lt_d.serial_fetch
    assert lt_d.serial_fetch == lt_d.prefetch  # demand: whole round serial
    # cache hits shrink the wire further (replayed hit rate)
    lt_c = roofline.layer_times(
        cfg, tokens=8, group=4, layer=moe_layer,
        policies=PolicyTable.uniform(layout="split", fetch="predictive"),
        cache_hit=0.5,
    )
    assert lt_c.prefetch < lt_p.prefetch
    # at context-phase coverage the predictive path falls back to the
    # full prefetch exactly like demand (nothing to predict away)
    lt_ctx = roofline.layer_times(
        cfg, tokens=16384, group=4, layer=moe_layer,
        policies=PolicyTable.uniform(layout="split", fetch="predictive"),
    )
    assert lt_ctx.serial_fetch == 0.0


def test_moe_capacity_drops_tokens():
    t, e, d = 64, 2, 8
    x = jax.random.normal(jax.random.key(0), (t, d))
    x = x.at[:, 0].set(1.0)  # deterministic routing feature
    w_router = jnp.zeros((d, e)).at[0, 0].set(10.0)  # all tokens -> expert 0
    cap = 8
    disp = moe_lib.route_topk(x, w_router, 1, capacity=cap)
    assert int(disp.keep.sum()) == cap
