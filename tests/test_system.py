"""System behaviour tests: substrate layers, runtime engine, analysis."""
import os
import tempfile

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.analysis.stablehlo import analyze_module
from repro.checkpoint import load_pytree, save_pytree
from repro.configs import ARCHS, get_arch, reduced_variant
from repro.data import make_train_batches, pack_documents, SyntheticTextDataset
from repro.optim import adamw_init, adamw_update, cosine_schedule


# --------------------------------------------------------------------------
# data pipeline
# --------------------------------------------------------------------------
def test_data_batches_shapes_and_shift():
    it = make_train_batches(1000, 32, 4, seed=3)
    b = next(it)
    assert b["tokens"].shape == (4, 32) and b["labels"].shape == (4, 32)
    # labels are the next-token shift of the same packed stream
    assert (b["tokens"][:, 1:] == b["labels"][:, :-1]).all()
    assert b["tokens"].max() < 1000 and b["tokens"].min() >= 0


def test_data_sharding_disjoint_and_deterministic():
    a0 = next(make_train_batches(500, 16, 8, shard=0, num_shards=2))
    a1 = next(make_train_batches(500, 16, 8, shard=1, num_shards=2))
    b0 = next(make_train_batches(500, 16, 8, shard=0, num_shards=2))
    assert a0["tokens"].shape == (4, 16)
    assert (a0["tokens"] == b0["tokens"]).all()      # deterministic
    assert not (a0["tokens"] == a1["tokens"]).all()  # shards differ


@settings(deadline=None, max_examples=10)
@given(seq=st.sampled_from([8, 32, 128]))
def test_packing_preserves_stream(seq):
    ds = SyntheticTextDataset(100, mean_doc_len=20, seed=1)
    docs = [ds.document(i) for i in range(50)]
    stream = np.concatenate(docs)
    rows = []
    it = pack_documents(iter(docs), seq)
    for _ in range(3):
        rows.append(next(it))
    got = np.concatenate(rows)
    np.testing.assert_array_equal(got, stream[: len(got)])


# --------------------------------------------------------------------------
# optimizer
# --------------------------------------------------------------------------
def test_adamw_decreases_quadratic():
    params = {"w": jnp.array([5.0, -3.0])}
    opt = adamw_init(params)
    for _ in range(200):
        grads = {"w": 2 * params["w"]}
        params, opt = adamw_update(
            grads, opt, params, lr=0.1, weight_decay=0.0
        )
    assert float(jnp.abs(params["w"]).max()) < 0.5


def test_adamw_clips_global_norm():
    params = {"w": jnp.zeros(3)}
    opt = adamw_init(params)
    g = {"w": jnp.array([1e6, 0.0, 0.0])}
    p2, _ = adamw_update(g, opt, params, lr=1.0, clip_norm=1.0,
                         weight_decay=0.0)
    # post-clip first-step Adam update is bounded by lr
    assert float(jnp.abs(p2["w"]).max()) <= 1.0 + 1e-5


def test_cosine_schedule_shape():
    assert float(cosine_schedule(0, peak_lr=1.0, warmup_steps=10)) == 0.0
    assert float(cosine_schedule(10, peak_lr=1.0, warmup_steps=10)) == pytest.approx(1.0)
    end = float(cosine_schedule(10_000, peak_lr=1.0, warmup_steps=10,
                                total_steps=10_000, final_frac=0.1))
    assert end == pytest.approx(0.1, abs=1e-6)


# --------------------------------------------------------------------------
# checkpointing
# --------------------------------------------------------------------------
def test_checkpoint_roundtrip():
    model_params = {
        "a": jnp.arange(12, dtype=jnp.float32).reshape(3, 4),
        "nested": {"b": jnp.ones((5,), jnp.int32)},
    }
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "ckpt.npz")
        save_pytree(path, model_params)
        got = load_pytree(path, model_params)
    for a, b in zip(jax.tree.leaves(got), jax.tree.leaves(model_params)):
        np.testing.assert_array_equal(np.asarray(a), np.asarray(b))


def test_checkpoint_chunking():
    big = {"w": jnp.arange(4096, dtype=jnp.float32).reshape(64, 64)}
    with tempfile.TemporaryDirectory() as td:
        path = os.path.join(td, "big.npz")
        save_pytree(path, big, max_chunk_bytes=1024)
        got = load_pytree(path, big)
    np.testing.assert_array_equal(np.asarray(got["w"]), np.asarray(big["w"]))


# --------------------------------------------------------------------------
# StableHLO analyzer
# --------------------------------------------------------------------------
def test_analyzer_counts_loop_multiplicity():
    """A scanned matmul must count trip_count x the per-iteration FLOPs."""
    def f(x, w):
        def body(c, _):
            return c @ w, None
        out, _ = jax.lax.scan(body, x, None, length=7)
        return out

    x = jax.ShapeDtypeStruct((8, 16), jnp.float32)
    w = jax.ShapeDtypeStruct((16, 16), jnp.float32)
    txt = jax.jit(f).lower(x, w).as_text()
    mc = analyze_module(txt)
    assert mc.flops == pytest.approx(7 * 2 * 8 * 16 * 16)


def test_analyzer_nested_loops_and_reverse():
    """Nested scans multiply; reverse-mode (countdown) loops count too."""
    import jax
    import jax.numpy as jnp

    def f(x, w):
        def outer(c, _):
            def inner(c2, _):
                return c2 @ w, None
            c, _ = jax.lax.scan(inner, c, None, length=5)
            return c, None
        out, _ = jax.lax.scan(outer, x, None, length=3)
        return out.sum()

    x = jnp.ones((4, 8))
    w = jnp.ones((8, 8))
    fwd = analyze_module(jax.jit(f).lower(x, w).as_text())
    assert fwd.flops == pytest.approx(15 * 2 * 4 * 8 * 8)
    # grad: forward (15) + ~2x backward matmuls, all loop-counted
    bwd = analyze_module(jax.jit(jax.grad(f, argnums=1)).lower(x, w).as_text())
    assert bwd.flops >= 2.5 * fwd.flops, (bwd.flops, fwd.flops)


def test_analyzer_collective_bytes():
    from repro.launch.mesh import make_smoke_mesh
    mesh = make_smoke_mesh(1, 1)

    from repro.compat import shard_map

    # trivially sized mesh: collectives lower but carry group size 1
    def f(x):
        return shard_map(
            lambda y: jax.lax.psum(y, "model"),
            mesh=mesh, in_specs=jax.sharding.PartitionSpec(),
            out_specs=jax.sharding.PartitionSpec(), check_vma=False,
        )(x)

    txt = jax.jit(f).lower(jax.ShapeDtypeStruct((4, 4), jnp.float32)).as_text()
    mc = analyze_module(txt)
    assert mc.collective_bytes == 0.0  # group of 1 moves nothing


# --------------------------------------------------------------------------
# serving engine (reduced scale, live arrays)
# --------------------------------------------------------------------------
def test_disaggregated_engine_end_to_end():
    from repro.launch.serve import build_engine
    from repro.runtime.engine import Request

    cfg = reduced_variant(get_arch("yi-9b"))
    engine, model = build_engine(
        cfg, prefill_len=16, cache_len=32, max_batch=2
    )
    rng = np.random.default_rng(0)
    for i in range(4):
        engine.submit(Request(
            req_id=i,
            tokens=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            target_len=6,
        ))
    metrics = engine.run(steps=30)
    s = metrics.summary(horizon=30.0)
    assert s["completed"] == 4
    for rid in range(4):
        assert len(engine.outputs[rid]) >= 6


def test_engine_continuous_batching_interleaves():
    """A request admitted later must share decode steps with an earlier
    one (no drain-the-batch behaviour)."""
    from repro.launch.serve import build_engine
    from repro.runtime.engine import Request

    cfg = reduced_variant(get_arch("yi-9b"))
    engine, _ = build_engine(cfg, prefill_len=8, cache_len=64, max_batch=2)
    rng = np.random.default_rng(1)
    for i in range(3):
        engine.submit(Request(
            req_id=i,
            tokens=rng.integers(0, cfg.vocab_size, 8).astype(np.int32),
            target_len=8 if i < 2 else 4,
        ))
    engine.run(steps=40)
    recs = {r.req_id: r for r in engine.metrics.records}
    # request 2 starts after 0/1 finish a few tokens but before they end
    assert recs[2].first_token_time > recs[0].first_token_time
    assert recs[2].first_token_time < recs[0].done_time + 8


# --------------------------------------------------------------------------
# on-demand expert fetch: analytic + simulator acceptance (the decode
# communication term the route-before-gather restructure shrinks)
# --------------------------------------------------------------------------
def test_analytic_hbm_bytes_demand_below_full_r1_decode():
    """The acceptance shape: a DeepSeek-R1-like decode step (gen_batch=8,
    topk=8, E=256) on a DWDP4 group must model strictly fewer gathered
    HBM bytes under expert_fetch="demand" than under the full remote
    gather — and the demand-active residency window shrinks with it."""
    from repro.analysis.roofline_report import (
        analytic_hbm_bytes,
        analytic_residency_bytes,
    )
    from repro.configs.base import InputShape
    from repro.core.strategy import make_execution_plan
    from repro.models.transformer import build_model

    cfg = get_arch("deepseek-r1")
    assert cfg.moe.num_experts == 256 and cfg.moe.top_k == 8
    ms = {"data": 2, "model": 4}
    # the DWDP4 gather geometry (R1's default on this mesh escalates to
    # the wide rotate placement; demand fetch is a gather-path feature)
    m = build_model(cfg, ms, moe_exec="gather", expert_axes=("model",))
    shape = InputShape("gen", 2048, 8, "decode")
    xps = {
        fetch: make_execution_plan(
            m, shape, ms, policy={"moe_experts": f"split:{fetch}"}
        )
        for fetch in ("all", "demand")
    }
    from repro.core.execution import demand_fetch_active

    assert demand_fetch_active(cfg, m.geom, xps["demand"])
    hbm = {
        f: analytic_hbm_bytes(cfg, m.geom, xp, shape) for f, xp in xps.items()
    }
    res = {
        f: analytic_residency_bytes(cfg, m.geom, xp, shape)
        for f, xp in xps.items()
    }
    assert hbm["demand"] < hbm["all"], hbm
    assert res["demand"] < res["all"], res


def test_simulator_decode_wire_bytes_demand_below_full():
    """ClusterSimulator models the decode expert-gather wire bytes: the
    demand fetch ships strictly less than the full remote gather at the
    acceptance shape, and the dwdp generation server's step time moves
    with it."""
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    cfg = get_arch("deepseek-r1")
    sims = {
        fetch: ClusterSimulator(SimConfig(
            cfg=cfg, gen_batch=8, gen_mode="dwdp", expert_fetch=fetch,
        ))
        for fetch in ("all", "demand")
    }
    full = sims["all"].decode_wire_bytes(8)
    demand = sims["demand"].decode_wire_bytes(8)
    assert 0 < demand < full, (demand, full)
    assert (
        sims["demand"].gen_step_time(8) <= sims["all"].gen_step_time(8)
    )
    # legacy resident-weight mode is untouched by the fetch knob
    legacy = ClusterSimulator(SimConfig(cfg=cfg, gen_batch=8))
    assert legacy.gen_step_time(8) == ClusterSimulator(
        SimConfig(cfg=cfg, gen_batch=8, expert_fetch="demand")
    ).gen_step_time(8)


def test_simulator_predictive_replay_hit_rates():
    """SimConfig replays predictive hit rates: wire bytes <= the demand
    round, the dwdp generation step time strictly below demand's (the
    speculative round overlaps), and higher replayed hit rates
    monotonically shrink both."""
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    cfg = get_arch("deepseek-r1")
    mk = lambda **kw: ClusterSimulator(SimConfig(
        cfg=cfg, gen_batch=8, gen_mode="dwdp", **kw,
    ))
    dem = mk(expert_fetch="demand")
    pred = mk(expert_fetch="predictive", cache_budget=16)
    assert pred.decode_wire_bytes(8) <= dem.decode_wire_bytes(8)
    assert pred.decode_serial_wire_bytes(8) < dem.decode_serial_wire_bytes(8)
    assert pred.gen_step_time(8) < dem.gen_step_time(8)
    # demand's whole round is serial; all-fetch overlaps everything
    assert dem.decode_serial_wire_bytes(8) == dem.decode_wire_bytes(8)
    assert mk(expert_fetch="all").decode_serial_wire_bytes(8) == 0.0
    # replayed hit rates: more hits -> less wire, less serial
    lo = mk(expert_fetch="predictive", cache_hit_rate=0.2,
            predict_hit_rate=0.2)
    hi = mk(expert_fetch="predictive", cache_hit_rate=0.8,
            predict_hit_rate=0.8)
    assert hi.decode_wire_bytes(8) < lo.decode_wire_bytes(8)
    assert hi.decode_serial_wire_bytes(8) < lo.decode_serial_wire_bytes(8)
    assert hi.gen_step_time(8) <= lo.gen_step_time(8)


def test_engine_predictive_counters_end_to_end():
    """A live (1-device-ineligible-free) multi-rank engine run is covered
    by the multidevice suite; here the metrics layer: measured per-step
    pred_stats rows attribute to requests as predicted/spec-hit/cache-hit/
    miss/evicted bytes and the summary reports the per-round hit split."""
    from repro.runtime.metrics import RequestRecord, ServingMetrics

    rec = RequestRecord(
        req_id=0, arrival=0.0, prompt_len=4, target_len=3,
        first_token_time=1.0, done_time=3.0, tokens_out=3,
    )
    rec.add_predict_share([8.0, 4.0, 2.0, 2.0, 1.0], expert_bytes=1000.0,
                          share=0.5)
    rec.add_predict_share([0.0, 2.0, 2.0, 0.0, 0.0], expert_bytes=1000.0,
                          share=0.5)
    sm = ServingMetrics()
    sm.records.append(rec)
    s = sm.summary(3.0)
    assert s["predict_mb_predicted"] == round(8 * 500 / 1e6, 3)
    assert s["predict_mb_hit"] == round(10 * 500 / 1e6, 3)
    assert s["predict_mb_spec_hit"] == round(6 * 500 / 1e6, 3)
    assert s["predict_mb_cache_hit"] == round(4 * 500 / 1e6, 3)
    assert s["predict_mb_miss"] == round(2 * 500 / 1e6, 3)
    assert s["predict_mb_evicted"] == round(1 * 500 / 1e6, 3)
    # the old aggregate key stays derived: spec + cache over served
    assert s["predict_hit_rate"] == pytest.approx(10 / 12, abs=1e-3)
    assert s["spec_hit_rate"] == pytest.approx(6 / 12, abs=1e-3)
    assert s["cache_hit_rate"] == pytest.approx(4 / 12, abs=1e-3)


def test_engine_reports_gather_fetch_savings():
    """ServingMetrics per-request gathered-weight counters: a demand-fetch
    engine run reports fetched bytes strictly below the full-gather
    counterfactual (the satellite's direct fetch-savings surface)."""
    from repro.core.execution import gathered_wire_bytes_per_step
    from repro.configs.base import ArchConfig, InputShape, MoEConfig
    from repro.core.strategy import make_execution_plan
    from repro.models.transformer import build_model

    cfg = ArchConfig(
        name="demand-metrics", family="moe", num_layers=4, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
        moe=MoEConfig(num_experts=32, top_k=2, d_ff=48),
    )
    ms = {"data": 1, "model": 4}
    m = build_model(cfg, ms)
    shape = InputShape("gen", 64, 4, "decode")
    xp_all = make_execution_plan(m, shape, ms, mode="dwdp")
    xp_dem = make_execution_plan(
        m, shape, ms, mode="dwdp",
        policy={"moe_experts": "split:demand:allgather:4:2"},
    )
    b_all = gathered_wire_bytes_per_step(m, xp_all)
    b_dem = gathered_wire_bytes_per_step(m, xp_dem)
    assert b_all["fetched"] == b_all["full"] > 0
    assert b_dem["full"] == b_all["full"]
    assert 0 < b_dem["fetched"] < b_dem["full"]
    # per-family breakdown: the delta is entirely in the expert bank
    fam = b_dem["families"]["moe_experts"]
    assert 0 < fam["fetched"] < fam["full"]
    assert sum(v["fetched"] for v in b_dem["families"].values()) == (
        b_dem["fetched"]
    )
    # and the metrics surface the ratio + the per-family counters
    from repro.runtime.metrics import RequestRecord, ServingMetrics

    sm = ServingMetrics()
    rec = RequestRecord(
        req_id=0, arrival=0.0, prompt_len=4, target_len=2,
        first_token_time=1.0, done_time=3.0, tokens_out=3,
    )
    rec.add_gather_share(b_dem)
    sm.records.append(rec)
    s = sm.summary(3.0)
    assert 0 < s["gather_fetch_ratio"] < 1
    by_fam = s["gathered_mb_by_family"]
    assert by_fam["moe_experts"]["fetched"] < by_fam["moe_experts"]["full"]


# --------------------------------------------------------------------------
# the CLI policy surface (launch/serve.py --policy / --policy-file)
# --------------------------------------------------------------------------
def test_cli_policy_flags_round_trip(tmp_path):
    """--policy / --policy-file parse into the PolicyTable the engine
    consumes: repeatable per-family flags, JSON files, flag-over-file
    precedence, 'auto' pass-through — and unknown families or values are
    rejected."""
    import json

    from repro.core.strategy import GatherPolicy, PolicyTable
    from repro.launch.serve import parse_policy_flags

    t = parse_policy_flags([
        "moe_experts=split:demand:ring_sliced",
        "attn_qkv=merged",
        "default=split:all:ring",
    ])
    assert t.family("moe_experts") == GatherPolicy(
        "split", "demand", "ring_sliced"
    )
    assert t.family("attn_qkv").layout == "merged"
    assert t.family("dense_ffn").transport == "ring"
    # full round trip through the JSON file format
    f = tmp_path / "policies.json"
    f.write_text(json.dumps(t.to_dict()))
    assert parse_policy_flags([], str(f)) == t
    # flags override file entries
    merged = parse_policy_flags(["moe_experts=split:all"], str(f))
    assert merged.family("moe_experts").fetch == "all"
    assert merged.family("attn_qkv").layout == "merged"
    assert parse_policy_flags(["auto"]) == "auto"
    assert parse_policy_flags([]) is None
    for bad in (["bogus_family=split"], ["moe_experts=bogus"],
                ["moe_experts"], ["auto", "attn_qkv=merged"]):
        with pytest.raises(ValueError):
            parse_policy_flags(bad)
    with pytest.raises(ValueError):
        parse_policy_flags(["auto"], str(f))


def test_cli_legacy_flags_equal_uniform_table():
    """The pre-PolicyTable serve flags resolve to exactly the uniform
    table the equivalent --policy spelling builds (legacy-flag -> table
    equivalence, without deprecation warnings on the internal path)."""
    import warnings

    from repro.core.strategy import PolicyTable
    from repro.runtime.engine import _resolve_policy

    with warnings.catch_warnings():
        warnings.simplefilter("error", DeprecationWarning)
        legacy = _resolve_policy(
            None, prefetch="ring", weight_layout="merged",
            expert_fetch="all", demand_budget=0,
        )
        assert legacy == PolicyTable.uniform(
            layout="merged", transport="ring"
        )
        dem = _resolve_policy(None, expert_fetch="demand", demand_budget=4)
        assert dem.family("moe_experts").fetch == "demand"
        assert dem.family("moe_experts").budget == 4
        # an explicit policy wins outright
        explicit = PolicyTable.uniform(layout="merged")
        assert _resolve_policy(explicit, weight_layout="split") is explicit


def test_simulator_accepts_policy_table():
    """SimConfig.policies is the canonical per-family surface; the flat
    fields remain as the uniform spelling and agree with it."""
    from repro.core.strategy import PolicyTable
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    cfg = get_arch("deepseek-r1")
    flat = SimConfig(cfg=cfg, gen_batch=8, gen_mode="dwdp",
                     expert_fetch="demand")
    tab = SimConfig(
        cfg=cfg, gen_batch=8, gen_mode="dwdp",
        policies=PolicyTable.uniform(layout="split", fetch="demand"),
    )
    assert flat.table() == tab.table()
    assert ClusterSimulator(flat).decode_wire_bytes(8) == (
        ClusterSimulator(tab).decode_wire_bytes(8)
    )
    mixed = SimConfig(
        cfg=cfg,
        policies=PolicyTable.from_dict(
            {"moe_experts": "split:demand", "attn_qkv": "merged"}
        ),
    )
    assert ClusterSimulator(mixed).ctx_time([1024]) > 0


# --------------------------------------------------------------------------
# cluster simulator (paper §5.3 trends)
# --------------------------------------------------------------------------
def test_simulator_dwdp_beats_dep_ctx_throughput():
    """Under ctx-side load (rate where the context server queues), the
    faster DWDP context phase yields higher TPS/GPU and lower TTFT. (At
    light load both keep up and the median TTFT is batching noise.)"""
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    cfg = get_arch("deepseek-r1")
    out = {}
    for mode in ("dep", "dwdp"):
        sc = SimConfig(cfg=cfg, ctx_mode=mode, arrival_rate=4.0,
                       horizon_s=90.0)
        out[mode] = ClusterSimulator(sc).run()
    assert out["dwdp"]["tps_per_gpu"] >= out["dep"]["tps_per_gpu"]
    assert out["dwdp"]["median_ttft_s"] <= out["dep"]["median_ttft_s"]
