"""Fail-stop rank death + recovery (docs/robustness.md).

Three layers of coverage:

- **Unit tests** (single device, fast): FaultTrace construction/
  queries/npz roundtrip, ``FaultSpec`` ``trace=`` plumbing, the
  ``validate_restore_plan`` resume guard, the re-shard row plan and
  recovery pricing (``roofline``), the host re-shard primitive never
  reading the dead peer (``prefetch.reshard_split_bank``), the
  scheduler's resume-rejection fallback, and the always-present
  recovery keys of ``ServingMetrics.summary``.
- **Fixture replay**: the committed ``tests/fixtures/fault_trace.npz``
  (recorded by ``tests/fixtures/record_fault_trace.py`` from a real
  fault-injected engine run) replayed through ``ClusterSimulator``
  (``SimConfig.fault_trace`` — the rank_death event shrinks the gen
  group mid-run) and through the ``HealthMonitor`` (per-step
  ``stat_vector`` tails drive the same demotion pressure the live
  monitor saw).
- **Kill-mid-decode** (subprocess, 8 fake devices, slow): one gen rank
  of a two-replica LIVE fleet fail-stops mid-decode. Migrated streams
  must be BITWISE-identical to the uninterrupted run, requeued streams
  replay their full prompt, ZERO accepted requests are lost, and —
  with the G'-1 standby pre-warmed — recovery triggers no recompile
  (``PolicyVariantCache.compiles()`` stays flat).

The committed ``BENCH_rank_death.json`` acceptance (post-recovery
TPS/GPU >= 0.9x the healthy G'-1 steady state) is re-asserted here so
a stale benchmark file fails the suite, not just the bench run.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FIXTURE = os.path.join(os.path.dirname(__file__), "fixtures",
                       "fault_trace.npz")
BENCH_JSON = os.path.join(os.path.dirname(__file__), "..",
                          "BENCH_rank_death.json")


# ---------------------------------------------------------------------------
# FaultTrace
# ---------------------------------------------------------------------------
def test_fault_trace_roundtrip_and_queries(tmp_path):
    from repro.core.faults import RANK_DEATH, FaultTrace

    tr = FaultTrace.from_events([
        (5, "drop", 1), (2, "zero", 0), (5, "cache", 3),
        (9, RANK_DEATH, 2),
    ])
    assert len(tr) == 4
    assert list(tr.steps) == [2, 5, 5, 9]          # sorted by from_events
    assert tr.events_at(5) == [("cache", 3), ("drop", 1)]
    assert tr.events_at(3) == []
    assert tr.events_in(0, 6) == [
        (2, "zero", 0), (5, "cache", 3), (5, "drop", 1),
    ]
    assert tr.next_event_step(0) == 2
    assert tr.next_event_step(6) == 9
    assert tr.next_event_step(10) is None
    # payload steps {2, 5} over a 10-step horizon; rank_death excluded
    assert tr.fallback_rate(10) == pytest.approx(0.2)
    pp = tr.peer_pressure(4)
    assert pp[0] == pp[1] == pp[3] == 1.0 and pp[2] == 0.0

    path = str(tmp_path / "trace.npz")
    tr.save(path)
    back = FaultTrace.load(path)
    assert list(back.steps) == list(tr.steps)
    assert back.kinds == tr.kinds
    assert list(back.ranks) == list(tr.ranks)


def test_fault_trace_validation():
    from repro.core.faults import FaultTrace

    with pytest.raises(ValueError, match="disagree"):
        FaultTrace(steps=[1, 2], kinds=("drop",), ranks=[0, 0])
    with pytest.raises(ValueError, match="sorted"):
        FaultTrace(steps=[3, 1], kinds=("drop", "zero"), ranks=[0, 0])
    with pytest.raises(ValueError, match=">= 0"):
        FaultTrace(steps=[1], kinds=("drop",), ranks=[-1])
    with pytest.raises(ValueError, match="unknown FaultTrace kinds"):
        FaultTrace(steps=[1], kinds=("meteor",), ranks=[0])


def test_fault_trace_stat_vector():
    from repro.core.faults import (
        FAULT_STAT_BASE, RANK_DEATH, FaultTrace,
    )

    tr = FaultTrace.from_events([
        (4, "drop", 1), (4, "corrupt", 1), (4, "mirror", 2),
        (7, RANK_DEATH, 0),
    ])
    vec = tr.stat_vector(4, n_peers=4)
    assert vec is not None and len(vec) == FAULT_STAT_BASE + 4
    assert vec[0] == 1.0 and vec[2] == 1.0 and vec[6] == 1.0
    assert vec[4] == 3.0 and vec[5] == 3.0       # detected / fallbacks
    tail = vec[FAULT_STAT_BASE:]
    assert tail[1] == 2.0 and tail[2] == 1.0
    # a step carrying only the fail-stop event has no payload stats
    assert tr.stat_vector(7, n_peers=4) is None
    assert tr.stat_vector(5, n_peers=4) is None


def test_fault_spec_trace_plumbing(tmp_path):
    from repro.core.faults import FaultSpec, FaultTrace

    path = str(tmp_path / "t.npz")
    FaultTrace.from_events([(1, "drop", 0)]).save(path)

    spec = FaultSpec.parse(f"seed=3,drop=0.1,trace={path}")
    assert spec.trace == path and spec.drop_rate == 0.1
    loaded = spec.load_trace()
    assert loaded is not None and len(loaded) == 1
    # describe/parse roundtrip keeps the trace key
    again = FaultSpec.parse(spec.describe())
    assert again == spec
    assert FaultSpec().load_trace() is None


# ---------------------------------------------------------------------------
# Resume guard
# ---------------------------------------------------------------------------
def _plan(**over):
    base = {
        "model": "m", "mesh": (("data", 2), ("model", 4)),
        "cache_len": 48,
        "policies": "moe_experts=split:predictive:allgather:4:4:8",
        "excl": (),
    }
    base.update(over)
    return base


def test_validate_restore_plan():
    from repro.runtime.engine import validate_restore_plan

    validate_restore_plan(_plan(), _plan())
    validate_restore_plan(None, _plan())        # pre-plan snapshots pass
    for bad in (
        _plan(mesh=(("data", 2), ("model", 3))),
        _plan(model="other"),
        _plan(cache_len=96),
        _plan(policies="moe_experts=split:all:allgather"),
        _plan(excl=(1,)),
    ):
        with pytest.raises(ValueError, match="requeue"):
            validate_restore_plan(bad, _plan())


# ---------------------------------------------------------------------------
# Re-shard accounting + pricing
# ---------------------------------------------------------------------------
def test_reshard_plan_rows():
    from repro.core import roofline

    plan = roofline.reshard_plan_rows(20, 4, dead=1)
    total = plan["local"] + plan["wire"] + plan["source"]
    # every real expert row lands on exactly one survivor
    assert int(total.sum()) == 20
    # the dead rank's 5 old rows all come from the checkpoint/source
    assert int(plan["source"].sum()) == 5
    assert plan["new_local"] == 7
    with pytest.raises(ValueError, match="group >= 2"):
        roofline.reshard_plan_rows(20, 1, dead=0)


def test_rank_death_recovery_pricing():
    from repro.configs import get_arch
    from repro.configs.base import ArchConfig
    from repro.core import roofline

    cfg = get_arch("deepseek-r1")
    rec = roofline.rank_death_recovery(cfg, group=8)
    assert rec["wire_bytes"] > 0 and rec["source_bytes"] > 0
    assert rec["seconds"] > 2e-4
    assert rec["per_survivor_wire_bytes"] <= (
        rec["wire_bytes"] + rec["source_bytes"]
    )
    # heavier weights -> strictly more wire and a longer stall
    rec2 = roofline.rank_death_recovery(cfg, group=8, weight_bytes=2)
    assert rec2["wire_bytes"] == 2 * rec["wire_bytes"]
    assert rec2["seconds"] > rec["seconds"]
    # a dense model has no expert banks to re-shard: plan-swap cost only
    dense = ArchConfig(
        name="dense", family="dense", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=64,
        vocab_size=128,
    )
    rec3 = roofline.rank_death_recovery(dense, group=8)
    assert rec3["wire_bytes"] == 0.0
    assert rec3["seconds"] == pytest.approx(2e-4)


def test_degraded_step_times_has_reshard_row():
    from repro.configs import get_arch
    from repro.core import roofline
    from repro.core.strategy import PolicyTable

    cfg = get_arch("deepseek-r1")
    table = PolicyTable.uniform(
        layout="split", fetch="predictive", cache_budget=8,
    )
    rows = roofline.degraded_step_times(cfg, table, tokens=8, group=8)
    assert rows[-1]["fetch"] == "reshard"
    assert rows[-1]["reshard_wire_mb"] > 0
    assert rows[-1]["recovery_stall_us"] > 0
    # priced at the SHRUNK group: slower than the healthy top level
    assert rows[-1]["vs_healthy"] > 1.0


# ---------------------------------------------------------------------------
# Host re-shard primitive: the dead peer is NEVER read
# ---------------------------------------------------------------------------
def test_reshard_split_bank_never_reads_dead_peer():
    from repro.core.placement import make_placement
    from repro.core.prefetch import reshard_split_bank

    e = 12
    old = make_placement(e, 4)
    new = make_placement(e, 3)
    assert old.subgroup_size == 4 and new.subgroup_size == 3
    source = {"w": np.arange(e * 2, dtype=np.float32).reshape(e, 2)}
    shards = [
        {"w": source["w"][old.table()[r]].copy()}
        for r in range(old.subgroup_size)
    ]
    dead = 1
    # poison the dead peer's memory: recovery must not trust it
    shards[dead]["w"][:] = np.nan

    out = reshard_split_bank(shards, old, new, dead, source)
    assert len(out) == new.subgroup_size
    for p, tree in enumerate(out):
        got = np.asarray(tree["w"])
        assert np.all(np.isfinite(got)), f"NaN leaked from dead peer @ {p}"
        for j in range(new.local_count):
            r = p * new.local_count + j
            want = source["w"][r] if r < e else 0.0
            np.testing.assert_array_equal(got[j], want)

    with pytest.raises(ValueError, match="expert set"):
        reshard_split_bank(shards, old, make_placement(8, 3), dead, source)
    with pytest.raises(ValueError, match="exactly the dead rank"):
        reshard_split_bank(shards, old, make_placement(e, 2), dead, source)


# ---------------------------------------------------------------------------
# Fixture replay: simulator + health monitor
# ---------------------------------------------------------------------------
def _trace_arch():
    from repro.configs.base import ArchConfig, MoEConfig

    return ArchConfig(
        name="fault-trace", family="moe", num_layers=4, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
        moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
    )


def test_simulator_replays_fixture_trace():
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    kw = dict(
        cfg=_trace_arch(), ctx_gpus=2, gen_gpus=8, gen_mode="dwdp",
        expert_fetch="sync_free", gen_batch=8, isl_max=64, osl=64,
        arrival_rate=8.0, horizon_s=10.0,
    )
    out = ClusterSimulator(SimConfig(fault_trace=FIXTURE, **kw)).run()
    # the fixture's step-24 rank_death fired: the gen group shrank,
    # recovery was priced, and no accepted request was lost
    assert out["rank_deaths"] == 1
    assert out["migrated"] + out["requeued"] >= 1
    assert out["time_to_recover_p50_s"] > 0
    assert out["time_to_recover_p95_s"] >= out["time_to_recover_p50_s"]
    healthy = ClusterSimulator(SimConfig(**kw)).run()
    assert healthy["rank_deaths"] == 0
    assert out["completed"] == healthy["completed"]


def test_simulator_rank_death_requeues_dead_shard_slots():
    from repro.core.faults import FaultTrace
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    # heavy load fills all 8 decode slots before step 24, so the dead
    # rank's slot (slot % 8 == 3) is occupied when the fail-stop fires
    out = ClusterSimulator(SimConfig(
        cfg=_trace_arch(), ctx_gpus=2, gen_gpus=8, gen_mode="dwdp",
        expert_fetch="sync_free", gen_batch=8, isl_max=64, osl=64,
        arrival_rate=5e4, horizon_s=0.01,
        fault_trace=FaultTrace.from_events([(24, "rank_death", 3)]),
    )).run()
    assert out["rank_deaths"] == 1
    assert out["requeued"] >= 1        # the dead shard's slot replayed
    assert out["migrated"] >= 1        # survivors rode through the swap
    assert out["time_to_recover_p50_s"] > 0


def test_simulator_loads_trace_from_path():
    from repro.core.faults import FaultTrace
    from repro.runtime.simulator import SimConfig

    sc = SimConfig(cfg=_trace_arch(), fault_trace=FIXTURE)
    assert isinstance(sc.fault_trace, FaultTrace)
    assert "rank_death" in sc.fault_trace.kinds


def test_health_monitor_replays_fixture_trace():
    from repro.core.faults import FAULT_STAT_BASE, FaultTrace
    from repro.runtime.engine import HealthMonitor

    tr = FaultTrace.load(FIXTURE)
    hm = HealthMonitor(decay=0.5, demote_threshold=0.4,
                       promote_threshold=0.05, min_dwell=2)
    actions = []
    last = int(tr.steps[-1])
    for step in range(last + 1):
        vec = tr.stat_vector(step, n_peers=8)
        if vec is None:
            continue
        act = hm.observe(vec[FAULT_STAT_BASE:])
        if act:
            actions.append(act)
    # the recorded storm carries enough per-peer pressure to demote
    assert "demote" in actions
    # the monitor's blame lands on peers the trace actually implicates
    # (the EMA is recency-weighted, so exact rank order may differ from
    # the whole-trace counts — but never a peer with zero events)
    pressure = tr.peer_pressure(8)
    assert pressure[hm.worst_peer()] > 0
    assert all(pressure[p] > 0 for p in hm.bad_peers())


# ---------------------------------------------------------------------------
# Serving-layer units (modeled client — no arrays)
# ---------------------------------------------------------------------------
def _modeled_fleet(gen_gpus=(4, 4), slots=8):
    from repro.runtime.serving import (
        ModeledReplicaClient, MultiReplicaEngine, ServingScheduler,
    )
    from repro.runtime.simulator import SimConfig

    scheds = []
    for g in gen_gpus:
        client = ModeledReplicaClient(SimConfig(
            cfg=_trace_arch(), ctx_gpus=2, gen_gpus=g,
            gen_mode="dwdp", expert_fetch="sync_free", gen_batch=slots,
            isl_max=64, osl=32,
        ), num_slots=slots)
        scheds.append(ServingScheduler(client))
    return MultiReplicaEngine(scheds)


def _served(n, osl=32):
    from repro.runtime.serving import WorkloadConfig, synthesize_workload

    return synthesize_workload(WorkloadConfig(
        num_requests=n, isl_buckets=(64,), osl=osl, seed=11,
    ))


def test_modeled_fleet_kill_rank_zero_loss():
    fleet = _modeled_fleet()
    fleet.submit(_served(16))
    for _ in range(5):
        for s in fleet.schedulers:
            s.step()
    active_before = fleet.schedulers[0].active_count()
    assert active_before > 0
    report = fleet.kill_rank(0, 2)
    assert report["migrated"] + report["requeued"] == active_before
    assert report["requeued"] >= 1           # slot 2 sat on the dead rank
    summary = fleet.run().summary(fleet.horizon())
    assert summary["completed"] == 16        # zero accepted requests lost
    assert summary["rank_deaths"] == 1
    assert summary["migrated"] == report["migrated"]
    assert summary["requeued"] == report["requeued"]
    assert summary["time_to_recover_p50_s"] > 0
    # the owner re-priced at the shrunk subgroup
    assert fleet.schedulers[0].client.sim_cfg.gen_gpus == 3


def test_modeled_kill_rank_rejects_single_gpu_group():
    from repro.runtime.serving import ModeledReplicaClient
    from repro.runtime.simulator import SimConfig

    client = ModeledReplicaClient(SimConfig(
        cfg=_trace_arch(), ctx_gpus=1, gen_gpus=1, gen_batch=4,
    ))
    with pytest.raises(ValueError, match="1-GPU"):
        client.kill_rank(0)


class _PlanPickyClient:
    """Fake live client: rejects EVERY resume (the destination's plan
    differs), accepts fresh admissions."""

    num_slots = 2
    num_gpus = 1

    def __init__(self):
        self.admits = []

    def admit(self, slot, req):
        if req.resume is not None:
            raise ValueError("snapshot_slot resume rejected — requeue")
        self.admits.append(req.req_id)
        return 7, 0.01

    def step(self, active):
        return None, 0.01

    def step_time(self, batch):
        return 0.01

    def release(self, slot):
        pass

    def evict(self, slot):
        return {}

    def has_bucket(self, prompt_len):
        return True


def test_scheduler_downgrades_rejected_resume_to_requeue():
    from repro.runtime.metrics import RequestRecord
    from repro.runtime.serving import ServedRequest, ServingScheduler

    client = _PlanPickyClient()
    sched = ServingScheduler(client)
    req = ServedRequest(req_id=5, prompt_len=8, target_len=4,
                        resume={"plan": {"model": "other"}}, remaining=2)
    rec = RequestRecord(req_id=5, arrival=0.0, prompt_len=8, target_len=4)
    rec.tokens_out = 2
    rec.first_token_time = 0.5
    sched.adopt(req, rec, [1, 2])
    sched.run()
    # the rejected snapshot fell back to a full prompt replay: TTFT
    # re-accounted, stream restarted, and the request still completed
    assert client.admits == [5]
    assert sched.metrics.admission.get("requeued") == 1
    assert sched.metrics.admission.get("resumed") is None
    done = sched.metrics.records[-1]
    assert done.req_id == 5 and done.tokens_out == 4
    assert done.first_token_time != 0.5
    assert sched.outputs[5][0] == 7              # fresh first token


def test_summary_recovery_keys_always_present():
    from repro.runtime.metrics import ServingMetrics

    m = ServingMetrics()
    s = m.summary(1.0)
    assert s["rank_deaths"] == 0
    assert s["migrated"] == 0 and s["requeued"] == 0
    assert s["time_to_recover_p50_s"] == 0.0
    assert s["time_to_recover_p95_s"] == 0.0

    m.record_rank_death(migrated=3, requeued=1, seconds=0.1)
    m.record_rank_death(migrated=0, requeued=2, seconds=0.3)
    s = m.summary(1.0)
    assert s["rank_deaths"] == 2
    assert s["migrated"] == 3 and s["requeued"] == 3
    assert s["time_to_recover_p50_s"] == pytest.approx(0.1)
    assert s["time_to_recover_p95_s"] == pytest.approx(0.3)


# ---------------------------------------------------------------------------
# Committed bench acceptance
# ---------------------------------------------------------------------------
def test_bench_rank_death_json_acceptance():
    with open(BENCH_JSON) as fh:
        data = json.load(fh)
    assert data["bench"] == "rank_death"
    floor = data["config"]["min_post_vs_shrunk"]
    rows = data["rows"]
    assert {r["tps_user"] for r in rows} == set(
        float(c) for c in data["config"]["concurrency"]
    )
    for r in rows:
        assert r["post_vs_shrunk"] >= floor, r
        assert r["completed"] > 0 and r["migrated"] >= 1
        assert r["recovery_s"] > 0


# ---------------------------------------------------------------------------
# Kill-mid-decode (live fleet, subprocess)
# ---------------------------------------------------------------------------
KILL_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import numpy as np
from repro.configs.base import ArchConfig, MoEConfig
from repro.launch.serve import build_engine
from repro.runtime.serving import (
    LiveReplicaClient, MultiReplicaEngine, ServingScheduler,
    WorkloadConfig, synthesize_workload,
)

CFG = ArchConfig(
    name="rank-death", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)
POLICY = {"moe_experts": "split:predictive:allgather:4:4:8"}
TARGET = 16
PRE_STEPS = 4
DEAD_RANK = 5   # model axis 4 -> data row 1 -> slots 2,3 lose their KV

def build(shape):
    eng, _ = build_engine(
        CFG, mesh_shape=shape, prefill_len=8, cache_len=48, max_batch=4,
        gen_mode="dwdp", policy=POLICY,
    )
    eng.warmup()
    return eng

def reqs():
    return synthesize_workload(
        WorkloadConfig(num_requests=8, isl_buckets=(8,), osl=TARGET,
                       seed=3),
        vocab_size=CFG.vocab_size,
    )

def compiles(engines):
    return sum(e.gen.variants.compiles() + e.ctx.variants.compiles()
               for e in engines)

def outputs_of(fleet):
    out = {}
    for s in fleet.schedulers:
        for rid, toks in s.outputs.items():
            out[rid] = list(toks)
    return out

# --- reference: the same fleet, uninterrupted --------------------------
ref_engines = [build((2, 4)), build((2, 4))]
ref = MultiReplicaEngine([
    ServingScheduler(LiveReplicaClient.from_engine(e, num_gpus=8))
    for e in ref_engines
])
ref.submit(reqs())
ref.run()
ref_out = outputs_of(ref)

# --- kill run: standby pre-built at the survivors' mesh ----------------
engines = [build((2, 4)), build((2, 4))]
standby = build((2, 3))   # 6 of the 8 fake devices: the G'-1 sub-mesh
fleet = MultiReplicaEngine([
    ServingScheduler(LiveReplicaClient.from_engine(
        engines[0], num_gpus=8, standby=standby)),
    ServingScheduler(LiveReplicaClient.from_engine(
        engines[1], num_gpus=8)),
])
all_engines = engines + [standby]
fleet.submit(reqs())
baseline = compiles(all_engines)

for _ in range(PRE_STEPS):
    for s in fleet.schedulers:
        s.step()
active_before = fleet.schedulers[0].active_count()
report = fleet.kill_rank(0, DEAD_RANK)
fleet.run()

merged = fleet.merged_metrics()
summary = merged.summary(fleet.horizon())
out = outputs_of(fleet)
print("RESULT::" + json.dumps({
    "report": report,
    "active_before": active_before,
    "summary_recovery": {k: summary[k] for k in (
        "rank_deaths", "migrated", "requeued",
        "time_to_recover_p50_s", "time_to_recover_p95_s")},
    "completed": summary["completed"],
    "ref_completed": ref.merged_metrics().summary(ref.horizon())["completed"],
    "compiles_before": baseline,
    "compiles_after": compiles(all_engines),
    "requeued_counter": fleet.schedulers[0].metrics.admission.get(
        "requeued", 0),
    "outputs": {str(k): v for k, v in out.items()},
    "ref_outputs": {str(k): v for k, v in ref_out.items()},
    "assignments": {str(k): v for k, v in fleet.assignments.items()},
}))
"""


@pytest.mark.slow
def test_kill_mid_decode_bitwise_migration_zero_recompile():
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", KILL_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [
        l for l in out.stdout.splitlines() if l.startswith("RESULT::")
    ][-1]
    res = json.loads(line[len("RESULT::"):])

    report = res["report"]
    # every active slot landed in exactly one bucket
    assert report["migrated"] + report["requeued"] == res["active_before"]
    assert report["migrated"] >= 1 and report["requeued"] >= 1

    # ZERO accepted requests lost: every request decoded to full length
    assert res["completed"] == 8 == res["ref_completed"]
    outputs = res["outputs"]
    assert len(outputs) == 8
    for rid, toks in outputs.items():
        assert len(toks) == 16, f"req {rid} stream truncated: {len(toks)}"

    # migrated streams are BITWISE-identical to the uninterrupted run
    # (they resumed from their snapshot on the same-plan peer); nothing
    # was re-admitted through the requeue-downgrade path on replica 0's
    # standby beyond the two dead-shard slots
    moved = [
        rid for rid, i in res["assignments"].items() if i == 1
    ]
    migrated_bitwise = 0
    for rid in moved:
        if outputs[rid] == res["ref_outputs"].get(rid):
            migrated_bitwise += 1
    assert migrated_bitwise >= report["migrated"], (
        moved, report,
    )

    # with the G'-1 standby pre-warmed, recovery compiles NOTHING
    assert res["compiles_after"] == res["compiles_before"], (
        "recovery recompiled: "
        f"{res['compiles_before']} -> {res['compiles_after']}"
    )

    sr = res["summary_recovery"]
    assert sr["rank_deaths"] == 1
    assert sr["migrated"] == report["migrated"]
    assert sr["requeued"] == report["requeued"]
    assert sr["time_to_recover_p50_s"] > 0
