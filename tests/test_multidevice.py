"""Multi-device equivalence tests (the framework's strongest invariant):
every strategy/prefetch mode on a sharded mesh must match the 1-device
reference bit-for-nearly-bit. Runs in subprocesses so the 8 fake host
devices don't leak into the other tests' device state."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced_variant
from repro.configs.base import InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh
from repro.optim import adamw_init

def prefill(name, mode, mesh_shape, B, S, prefetch="allgather", **gk):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    cfg = reduced_variant(ARCHS[name])
    m = build_model(cfg, ms, dtype=jnp.float32, **gk)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("t", S, B, "prefill"), ms,
                             mode=mode, prefetch=prefetch)
    step = execution.make_step_fn(m, xp, mesh)
    if cfg.modality == "text":
        batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)}
    else:
        batch = {"embeds": jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.02}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def train_losses(name, mode, mesh_shape, **gk):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    cfg = reduced_variant(ARCHS[name])
    m = build_model(cfg, ms, dtype=jnp.float32, train=True, **gk)
    params = m.init_params(jax.random.key(42))
    opt = adamw_init(params)
    xp = make_execution_plan(m, InputShape("t", 64, 8, "train"), ms, mode=mode)
    step = execution.make_step_fn(m, xp, mesh)
    toks = jax.random.randint(jax.random.key(1), (8, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    with mesh:
        p2, o2, m1 = step(params, opt, batch, jnp.float32(1e-3))
        _, _, m2 = step(p2, o2, batch, jnp.float32(1e-3))
    return float(m1["loss"]), float(m2["loss"])

def decode_tokens(name, mode, mesh_shape, steps=3, decode_attn="gather",
                  shard_attention=None):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    cfg = reduced_variant(ARCHS[name])
    m = build_model(cfg, ms, dtype=jnp.float32,
                    shard_attention=shard_attention)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode=mode, decode_attn=decode_attn)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    tok = jnp.full((4, 1), 7, jnp.int32)
    toks = []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
    return toks

case = json.loads(sys.argv[1])
kind = case.pop("kind")
name = case.pop("arch")
results = {}
if kind == "prefill":
    ref = prefill(name, "dwdp", (1, 1), case["B"], case["S"])
    got = prefill(name, case["mode"], (2, 4), case["B"], case["S"],
                  prefetch=case.get("prefetch", "allgather"),
                  **case.get("gk", {}))
    err = float(np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9))
    results = {"relerr": err}
elif kind == "train":
    ref = train_losses(name, "dwdp", (1, 1))
    got = train_losses(name, case["mode"], (2, 4), **case.get("gk", {}))
    results = {"ref": ref, "got": got}
elif kind == "decode":
    ref = decode_tokens(name, "dwdp", (1, 1))
    got = decode_tokens(name, case["mode"], (2, 4),
                        decode_attn=case.get("decode_attn", "gather"),
                        shard_attention=case.get("shard_attention"))
    results = {"match": got == ref}
print("RESULT::" + json.dumps(results))
"""


def run_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
@pytest.mark.parametrize("mode,prefetch", [
    ("dwdp", "allgather"),
    ("dwdp", "ring"),
    ("dwdp", "ring_sliced"),
    ("dep", "allgather"),
    ("hybrid", "allgather"),
])
@pytest.mark.parametrize("arch", ["yi-9b", "grok-1-314b", "gemma3-27b"])
def test_prefill_equivalence(arch, mode, prefetch):
    r = run_case({"kind": "prefill", "arch": arch, "mode": mode,
                  "prefetch": prefetch, "B": 8, "S": 64})
    assert r["relerr"] < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "deepseek-r1"])
def test_seq_sharded_prefill_equivalence(arch):
    """B=2 forces sequence sharding over the model axis (RG-LRU fix-up,
    KV gather, seq-offset RoPE all exercised)."""
    r = run_case({"kind": "prefill", "arch": arch, "mode": "dwdp",
                  "B": 2, "S": 64})
    assert r["relerr"] < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["grok-1-314b", "llama4-maverick-400b-a17b"])
def test_rotate_equivalence(arch):
    r = run_case({"kind": "prefill", "arch": arch, "mode": "dwdp",
                  "B": 8, "S": 64,
                  "gk": {"moe_exec": "rotate",
                         "expert_axes": ["data", "model"]}})
    assert r["relerr"] < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["dwdp", "dep"])
@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-r1", "xlstm-350m"])
def test_train_equivalence(arch, mode):
    r = run_case({"kind": "train", "arch": arch, "mode": mode})
    assert abs(r["got"][0] - r["ref"][0]) < 2e-4, r
    assert abs(r["got"][1] - r["ref"][1]) < 2e-3, r


@pytest.mark.slow
def test_train_redundant_rotate_equivalence():
    r = run_case({"kind": "train", "arch": "grok-1-314b", "mode": "dwdp",
                  "gk": {"moe_exec": "rotate",
                         "expert_axes": ["data", "model"]}})
    assert abs(r["got"][1] - r["ref"][1]) < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["dwdp", "dep"])
@pytest.mark.parametrize("arch", ["yi-9b", "recurrentgemma-2b"])
def test_decode_equivalence(arch, mode):
    r = run_case({"kind": "decode", "arch": arch, "mode": mode})
    assert r["match"], r


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-27b"])
def test_decode_qgather_equivalence(arch):
    """qgather decode (weights stay sharded; q/k/v move) must match the
    gather-mode reference exactly."""
    r = run_case({"kind": "decode", "arch": arch, "mode": "dep",
                  "decode_attn": "qgather", "shard_attention": True})
    assert r["match"], r
