"""Multi-device equivalence tests (the framework's strongest invariant):
every strategy/prefetch mode on a sharded mesh must match the 1-device
reference bit-for-nearly-bit. Runs in subprocesses so the 8 fake host
devices don't leak into the other tests' device state."""
import json
import os
import subprocess
import sys

import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs import ARCHS, reduced_variant
from repro.configs.base import InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh
from repro.optim import adamw_init

def prefill(name, mode, mesh_shape, B, S, prefetch="allgather", cf=1.25, **gk):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    cfg = reduced_variant(ARCHS[name])
    m = build_model(cfg, ms, dtype=jnp.float32, **gk)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("t", S, B, "prefill"), ms,
                             mode=mode, prefetch=prefetch, capacity_factor=cf)
    step = execution.make_step_fn(m, xp, mesh)
    if cfg.modality == "text":
        batch = {"tokens": jax.random.randint(jax.random.key(1), (B, S), 0, cfg.vocab_size)}
    else:
        batch = {"embeds": jax.random.normal(jax.random.key(1), (B, S, cfg.d_model)) * 0.02}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def train_losses(name, mode, mesh_shape, **gk):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    cfg = reduced_variant(ARCHS[name])
    m = build_model(cfg, ms, dtype=jnp.float32, train=True, **gk)
    params = m.init_params(jax.random.key(42))
    opt = adamw_init(params)
    xp = make_execution_plan(m, InputShape("t", 64, 8, "train"), ms, mode=mode)
    step = execution.make_step_fn(m, xp, mesh)
    toks = jax.random.randint(jax.random.key(1), (8, 64), 0, cfg.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    with mesh:
        p2, o2, m1 = step(params, opt, batch, jnp.float32(1e-3))
        _, _, m2 = step(p2, o2, batch, jnp.float32(1e-3))
    return float(m1["loss"]), float(m2["loss"])

def decode_tokens(name, mode, mesh_shape, steps=3, decode_attn="gather",
                  shard_attention=None):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    cfg = reduced_variant(ARCHS[name])
    m = build_model(cfg, ms, dtype=jnp.float32,
                    shard_attention=shard_attention)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode=mode, decode_attn=decode_attn)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    tok = jnp.full((4, 1), 7, jnp.int32)
    toks = []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
    return toks

case = json.loads(sys.argv[1])
kind = case.pop("kind")
name = case.pop("arch")
results = {}
if kind == "prefill":
    cf = case.get("cf", 1.25)
    ref = prefill(name, "dwdp", (1, 1), case["B"], case["S"], cf=cf)
    got = prefill(name, case["mode"], (2, 4), case["B"], case["S"],
                  prefetch=case.get("prefetch", "allgather"), cf=cf,
                  **case.get("gk", {}))
    err = float(np.abs(got - ref).max() / (np.abs(ref).max() + 1e-9))
    results = {"relerr": err}
elif kind == "train":
    ref = train_losses(name, "dwdp", (1, 1))
    got = train_losses(name, case["mode"], (2, 4), **case.get("gk", {}))
    results = {"ref": ref, "got": got}
elif kind == "decode":
    ref = decode_tokens(name, "dwdp", (1, 1))
    got = decode_tokens(name, case["mode"], (2, 4),
                        decode_attn=case.get("decode_attn", "gather"),
                        shard_attention=case.get("shard_attention"))
    results = {"match": got == ref}
print("RESULT::" + json.dumps(results))
"""


def run_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
@pytest.mark.parametrize("mode,prefetch", [
    ("dwdp", "allgather"),
    ("dwdp", "ring"),
    ("dwdp", "ring_sliced"),
    ("dep", "allgather"),
    ("hybrid", "allgather"),
])
@pytest.mark.parametrize("arch", ["yi-9b", "grok-1-314b", "gemma3-27b"])
def test_prefill_equivalence(arch, mode, prefetch):
    r = run_case({"kind": "prefill", "arch": arch, "mode": mode,
                  "prefetch": prefetch, "B": 8, "S": 64})
    assert r["relerr"] < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["recurrentgemma-2b", "deepseek-r1"])
def test_seq_sharded_prefill_equivalence(arch):
    """B=2 forces sequence sharding over the model axis (RG-LRU fix-up,
    KV gather, seq-offset RoPE all exercised)."""
    r = run_case({"kind": "prefill", "arch": arch, "mode": "dwdp",
                  "B": 2, "S": 64})
    assert r["relerr"] < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["grok-1-314b", "llama4-maverick-400b-a17b"])
def test_rotate_equivalence(arch):
    # capacity is a function of *local* token count by design, so the
    # 1-device and sharded layouts drop different tokens near the capacity
    # edge (llama4's top-1 routing is imbalanced enough to hit it at 1.25);
    # compare in the no-drop regime so the test checks layout equivalence,
    # not drop-set coincidence.
    r = run_case({"kind": "prefill", "arch": arch, "mode": "dwdp",
                  "B": 8, "S": 64, "cf": 4.0,
                  "gk": {"moe_exec": "rotate",
                         "expert_axes": ["data", "model"]}})
    assert r["relerr"] < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["dwdp", "dep"])
@pytest.mark.parametrize("arch", ["yi-9b", "deepseek-r1", "xlstm-350m"])
def test_train_equivalence(arch, mode):
    r = run_case({"kind": "train", "arch": arch, "mode": mode})
    assert abs(r["got"][0] - r["ref"][0]) < 2e-4, r
    assert abs(r["got"][1] - r["ref"][1]) < 2e-3, r


@pytest.mark.slow
def test_train_redundant_rotate_equivalence():
    r = run_case({"kind": "train", "arch": "grok-1-314b", "mode": "dwdp",
                  "gk": {"moe_exec": "rotate",
                         "expert_axes": ["data", "model"]}})
    assert abs(r["got"][1] - r["ref"][1]) < 2e-3, r


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["dwdp", "dep"])
@pytest.mark.parametrize("arch", ["yi-9b", "recurrentgemma-2b"])
def test_decode_equivalence(arch, mode):
    r = run_case({"kind": "decode", "arch": arch, "mode": mode})
    assert r["match"], r


@pytest.mark.slow
@pytest.mark.parametrize("arch", ["yi-9b", "gemma3-27b"])
def test_decode_qgather_equivalence(arch):
    """qgather decode (weights stay sharded; q/k/v move) must match the
    gather-mode reference exactly."""
    r = run_case({"kind": "decode", "arch": arch, "mode": "dep",
                  "decode_attn": "qgather", "shard_attention": True})
    assert r["match"], r


# --------------------------------------------------------------------------
# Split-weight MoE fast path (paper §4.2): remote-only prefetch + fused
# split grouped-SwiGLU, merged path as the reference.
# --------------------------------------------------------------------------
SPLIT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, MoEConfig, InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh
from repro.optim import adamw_init
from repro.analysis import tensor_shape_count

# E=6 over a 4-wide expert axis with R=2: subgroup G'=2, local 3,
# num_padded 6 but storage 12 — the canonical full-bank (6, D, Fe) shape
# can then ONLY appear in a lowering via a gather that merges the banks,
# never from the parameter arrays themselves. D=32, Fe=48, cap=16 are all
# distinct so shape matching is unambiguous.
CFG = ArchConfig(
    name="split-test", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=6, top_k=2, d_ff=48),
)

def setup(mesh_shape, *, train=False):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    red = 2 if ms["model"] > 1 else None
    m = build_model(CFG, ms, dtype=jnp.float32, train=train, redundancy=red)
    return ms, mesh, m

def prefill_logits(moe_ffn, prefetch, mesh_shape):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    # capacity_factor high enough that no token drops on either mesh:
    # per-rank and global capacities differ, so drop sets would otherwise
    # diverge between the 1-device and sharded layouts
    xp = make_execution_plan(m, InputShape("t", 32, 8, "prefill"), ms,
                             mode="dwdp", prefetch=prefetch, moe_ffn=moe_ffn,
                             capacity_factor=4.0)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (8, 32), 0, CFG.vocab_size)}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def train_losses(moe_ffn, mesh_shape):
    ms, mesh, m = setup(mesh_shape, train=True)
    params = m.init_params(jax.random.key(42))
    opt = adamw_init(params)
    xp = make_execution_plan(m, InputShape("t", 64, 8, "train"), ms,
                             mode="dwdp", moe_ffn=moe_ffn,
                             capacity_factor=4.0)
    step = execution.make_step_fn(m, xp, mesh)
    toks = jax.random.randint(jax.random.key(1), (8, 64), 0, CFG.vocab_size)
    batch = {"tokens": toks, "labels": jnp.roll(toks, -1, 1)}
    with mesh:
        p2, o2, m1 = step(params, opt, batch, jnp.float32(1e-3))
        _, _, m2 = step(p2, o2, batch, jnp.float32(1e-3))
    return [float(m1["loss"]), float(m2["loss"])]

def decode_tokens(moe_ffn, mesh_shape, steps=3):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", moe_ffn=moe_ffn)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    tok = jnp.full((4, 1), 7, jnp.int32)
    toks = []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
    return toks

def lowered_text(moe_ffn, prefetch):
    ms, mesh, m = setup((2, 4))
    params = jax.eval_shape(m.init_params, jax.random.key(0))
    xp = make_execution_plan(m, InputShape("t", 32, 8, "prefill"), ms,
                             mode="dwdp", prefetch=prefetch, moe_ffn=moe_ffn)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with mesh:
        return step.lower(params, batch).as_text()

def bank_roundtrip(prefetch):
    # primitive-level: merge_split_bank(gather_split_bank(x)) must equal
    # the canonical merged gather, for every subgroup position
    from repro.compat import shard_map
    from repro.core import prefetch as pf
    from repro.core.placement import make_placement
    from jax.sharding import PartitionSpec as P

    mesh = _mesh((8,), ("model",))
    # redundant placement: R=2 subgroups of G'=4, one slice per rank
    pl = make_placement(4, 8)
    x = jnp.arange(8 * 3 * 5, dtype=jnp.float32).reshape(8, 3, 5)

    def body(xs):
        bank = pf.gather_split_bank(xs, "model", pl, mode=prefetch)
        merged = pf.merge_split_bank(bank, "model", pl)
        canon = pf.gather_shards(xs, "model", pl, mode=prefetch)
        return jnp.abs(merged - canon).max()[None]

    f = shard_map(body, mesh=mesh, in_specs=P("model"),
                  out_specs=P("model"), check_vma=False)
    with mesh:
        return float(jnp.max(f(x)))

def capacity_logits(mesh_shape, capacity_from, cf):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("t", 32, 8, "prefill"), ms,
                             mode="dwdp", capacity_factor=cf,
                             capacity_from=capacity_from)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (8, 32), 0, CFG.vocab_size)}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

case = json.loads(sys.argv[1])
kind = case.pop("kind")
results = {}
if kind == "prefill":
    prefetch = case.get("prefetch", "allgather")
    ref = prefill_logits("merged", "allgather", (1, 1))
    merged = prefill_logits("merged", prefetch, (2, 4))
    split = prefill_logits("split", prefetch, (2, 4))
    scale = np.abs(ref).max() + 1e-9
    results = {
        "split_vs_ref": float(np.abs(split - ref).max() / scale),
        "split_vs_merged": float(np.abs(split - merged).max() / scale),
    }
elif kind == "bank":
    results = {"err": bank_roundtrip(case.get("prefetch", "allgather"))}
elif kind == "capacity":
    # right AT the capacity edge (cf low enough that tokens drop):
    # "global" derives capacity per row from the global shape, so the
    # 1-device and sharded layouts drop the IDENTICAL token set, while
    # "local" legitimately diverges (the diagnosed llama4 case).
    cf = case.get("cf", 1.0)
    ref = capacity_logits((1, 1), "global", cf)
    got = capacity_logits((2, 4), "global", cf)
    loc_ref = capacity_logits((1, 1), "local", cf)
    loc_got = capacity_logits((2, 4), "local", cf)
    scale = np.abs(ref).max() + 1e-9
    results = {
        "global_relerr": float(np.abs(got - ref).max() / scale),
        "local_relerr": float(np.abs(loc_got - loc_ref).max() / scale),
    }
elif kind == "train":
    ref = train_losses("merged", (1, 1))
    merged = train_losses("merged", (2, 4))
    split = train_losses("split", (2, 4))
    results = {"ref": ref, "merged": merged, "split": split}
elif kind == "decode":
    merged = decode_tokens("merged", (2, 4))
    split = decode_tokens("split", (2, 4))
    results = {"match": split == merged, "merged": merged, "split": split}
elif kind == "hlo":
    pl = None
    d, fe = CFG.d_model, CFG.moe.d_ff
    full = [(6, d, fe), (6, fe, d)]
    remote = [(3, d, fe), (3, fe, d)]
    txt_m = lowered_text("merged", case["prefetch"])
    txt_s = lowered_text("split", case["prefetch"])
    results = {
        "merged_full": sum(tensor_shape_count(txt_m, s) for s in full),
        "split_full": sum(tensor_shape_count(txt_s, s) for s in full),
        "split_remote": sum(tensor_shape_count(txt_s, s) for s in remote),
    }
print("RESULT::" + json.dumps(results))
"""


def run_split_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SPLIT_SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring", "ring_sliced"])
def test_split_moe_prefill_equivalence(prefetch):
    """moe_ffn="split" must match both the merged path on the same mesh and
    the 1-device reference, for every remote-only prefetch mode."""
    r = run_split_case({"kind": "prefill", "prefetch": prefetch})
    assert r["split_vs_ref"] < 2e-3, r
    assert r["split_vs_merged"] < 2e-4, r


@pytest.mark.slow
def test_split_moe_train_grad_through_gather():
    """Two train steps through the remote-only gather (ZeRO-style grads
    flow through the ppermutes): split tracks merged bit-for-nearly-bit on
    the sharded mesh, and both track the 1-device reference."""
    r = run_split_case({"kind": "train"})
    for i in (0, 1):
        assert abs(r["split"][i] - r["merged"][i]) < 1e-5, r
        assert abs(r["split"][i] - r["ref"][i]) < 1e-2, r


@pytest.mark.slow
def test_split_moe_decode_equivalence():
    """Decode-scale capacities (below the 8-slot floor) through the split
    kernel: greedy tokens must match the merged path exactly."""
    r = run_split_case({"kind": "decode"})
    assert r["match"], r


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring"])
def test_split_moe_hlo_has_no_merged_bank(prefetch):
    """The §4.2 structural claim, asserted on the lowering: the split
    module contains NO tensor of the full canonical expert-bank shape
    (num_padded, D, Fe)/(num_padded, Fe, D) — only the (num_padded-local)
    remote bank — while the merged module necessarily materializes it."""
    r = run_split_case({"kind": "hlo", "prefetch": prefetch})
    assert r["merged_full"] > 0, r       # detector sanity
    assert r["split_full"] == 0, r       # no merge copy anywhere
    assert r["split_remote"] > 0, r      # remote bank does exist


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring", "ring_sliced"])
def test_merge_split_bank_matches_canonical_gather(prefetch):
    """Primitive-level contract of the SplitBank representation: the
    explicit activation-side merge (roll + concat) of a gathered
    SplitBank equals the canonical merged gather on every rank, in every
    prefetch mode."""
    r = run_split_case({"kind": "bank", "prefetch": prefetch})
    assert r["err"] == 0.0, r


@pytest.mark.slow
def test_capacity_from_global_cross_layout_determinism():
    """ROADMAP capacity decision: at the capacity edge (cf where tokens
    actually drop — the diagnosed llama4 divergence regime),
    capacity_from="global" makes the 1-device and (2,4)-sharded layouts
    drop the identical token set (per-row derivation + per-row
    competition), while the default "local" derivation legitimately
    diverges there."""
    r = run_split_case({"kind": "capacity", "cf": 1.0})
    assert r["global_relerr"] < 2e-3, r
    # sanity that the edge regime is real: local-mode layouts disagree
    # by orders of magnitude more than fp noise
    assert r["local_relerr"] > 10 * r["global_relerr"], r


# --------------------------------------------------------------------------
# Split-weight ATTENTION + dense-FFN path (§4.2 extended): with
# weight_layout="split" (the default) no merged gathered attention or
# dense-FFN weight stack ever exists; merged stays selectable and
# equivalent.
# --------------------------------------------------------------------------
ATTN_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh
from repro.analysis import tensor_shape_count

# 4 attention layers over a model axis of 4 with attention + dense FFN
# sharded: the gathered stacks are (4, 48, 20) qkv, (4, 20, 48) wo,
# (4, 48, 24)/(4, 24, 48) FFN. num_layers=4 makes one scan group, so the
# stored params carry a leading cycle dim (4, 1, ...) inside shard_map
# and the 3-d full-stack shapes can ONLY appear via a merging gather.
# d_model 48 / head_dim 20 / slice dims 20, 24 are all distinct from
# activation dims so shape matching is unambiguous.
CFG = ArchConfig(
    name="attn-split-test", family="dense", num_layers=4, d_model=48,
    num_heads=4, num_kv_heads=2, head_dim=20, d_ff=96, vocab_size=160,
)

def setup(mesh_shape):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    m = build_model(CFG, ms, dtype=jnp.float32, shard_attention=True)
    return ms, mesh, m

def prefill_logits(layout, prefetch, mesh_shape):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("t", 32, 8, "prefill"), ms,
                             mode="dwdp", prefetch=prefetch,
                             weight_layout=layout)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (8, 32), 0, CFG.vocab_size)}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def decode_tokens(layout, mesh_shape, steps=3):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", weight_layout=layout)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    tok = jnp.full((4, 1), 7, jnp.int32)
    toks = []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
    return toks

def lowered_text(layout, prefetch):
    ms, mesh, m = setup((2, 4))
    params = jax.eval_shape(m.init_params, jax.random.key(0))
    xp = make_execution_plan(m, InputShape("t", 32, 8, "prefill"), ms,
                             mode="dwdp", prefetch=prefetch,
                             weight_layout=layout)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((8, 32), jnp.int32)}
    with mesh:
        return step.lower(params, batch).as_text()

case = json.loads(sys.argv[1])
kind = case.pop("kind")
results = {}
if kind == "prefill":
    prefetch = case.get("prefetch", "allgather")
    ref = prefill_logits("merged", "allgather", (1, 1))
    merged = prefill_logits("merged", prefetch, (2, 4))
    split = prefill_logits("split", prefetch, (2, 4))
    scale = np.abs(ref).max() + 1e-9
    results = {
        "split_vs_ref": float(np.abs(split - ref).max() / scale),
        "split_vs_merged": float(np.abs(split - merged).max() / scale),
    }
elif kind == "decode":
    merged = decode_tokens("merged", (2, 4))
    split = decode_tokens("split", (2, 4))
    ref = decode_tokens("merged", (1, 1))
    results = {"match": split == merged, "match_ref": split == ref,
               "split": split, "merged": merged}
elif kind == "hlo":
    d, qd, kvl, ff = 48, 80, 20, 96
    a = 4
    fsq, fsf = qd // a, ff // a
    # stacked full gathers AND the flat merged forms (none may exist in
    # split mode — the engine never reshapes weights to flat either)
    full = [(a, d, fsq), (a, fsq, d), (a, d, kvl), (a, d, fsf), (a, fsf, d),
            (d, qd), (qd, d), (d, ff), (ff, d)]
    remote = [(a - 1, d, fsq), (a - 1, fsq, d), (a - 1, d, kvl),
              (a - 1, d, fsf), (a - 1, fsf, d)]
    txt_m = lowered_text("merged", case["prefetch"])
    txt_s = lowered_text("split", case["prefetch"])
    results = {
        "merged_full": sum(tensor_shape_count(txt_m, s) for s in full),
        "split_full": sum(tensor_shape_count(txt_s, s) for s in full),
        "split_remote": sum(tensor_shape_count(txt_s, s) for s in remote),
    }
print("RESULT::" + json.dumps(results))
"""


def run_attn_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", ATTN_SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring", "ring_sliced"])
def test_split_attn_prefill_equivalence(prefetch):
    """Split-layout attention + dense FFN must match both the merged path
    on the same mesh and the 1-device reference, for every prefetch
    mode (the rotated-bank activation rolls restore canonical heads)."""
    r = run_attn_case({"kind": "prefill", "prefetch": prefetch})
    assert r["split_vs_ref"] < 2e-3, r
    assert r["split_vs_merged"] < 2e-4, r


@pytest.mark.slow
def test_split_attn_decode_equivalence():
    """Greedy decode through split attention projections (per-row KV
    cache writes downstream of the split QKV) matches merged exactly."""
    r = run_attn_case({"kind": "decode"})
    assert r["match"], r
    assert r["match_ref"], r


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring"])
def test_split_attn_hlo_has_no_merged_stack(prefetch):
    """The acceptance claim for the generalized §4.2 path: with
    weight_layout="split" (the default) the lowered DWDP program contains
    ZERO full gathered attention or dense-FFN weight stacks — no
    (A, D, qd/A), (A, qd/A, D), (A, D, kvd/ks) or (S, D, F/S)/(S, F/S, D)
    buffer — only (A-1)-slice remote banks, while merged mode necessarily
    materializes every one of them."""
    r = run_attn_case({"kind": "hlo", "prefetch": prefetch})
    assert r["merged_full"] > 0, r
    assert r["split_full"] == 0, r
    assert r["split_remote"] > 0, r


# --------------------------------------------------------------------------
# On-demand expert fetch (route-before-gather): demand vs split
# equivalence, overflow fallback exactness, and the lowering claim.
# --------------------------------------------------------------------------
DEMAND_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, MoEConfig, InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh
from repro.analysis import tensor_shape_count

# E=20 over a 4-wide model axis: G'=4, local 5, remote 15. Prefill B=2
# S=8 seq-shards over "model" -> 2 routed tokens/rank * k=2 = 4 < 15, so
# the demand path is coverage-eligible; decode B=4 likewise (2 rows).
# All weight dims (20, 32, 48, 15, and the budget-derived fetched count)
# are distinct from activation dims so HLO shape matching is unambiguous.
CFG = ArchConfig(
    name="demand-split-test", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)

def setup(mesh_shape):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    m = build_model(CFG, ms, dtype=jnp.float32)
    return ms, mesh, m

def prefill_logits(expert_fetch, prefetch, mesh_shape, budget=0):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    # capacity_factor high enough that no token drops on either mesh:
    # 2 routed tokens/rank need capacity >= 2, i.e. cf >= 5 at E=20, k=2
    xp = make_execution_plan(m, InputShape("t", 8, 2, "prefill"), ms,
                             mode="dwdp", prefetch=prefetch,
                             expert_fetch=expert_fetch,
                             demand_budget=budget, capacity_factor=12.0)
    if expert_fetch == "demand":
        assert execution.demand_fetch_active(CFG, m.geom, xp), "not eligible"
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (2, 8), 0, CFG.vocab_size)}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def decode_tokens(expert_fetch, mesh_shape, budget=0, steps=3):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", expert_fetch=expert_fetch,
                             demand_budget=budget)
    if expert_fetch == "demand":
        assert execution.demand_fetch_active(CFG, m.geom, xp), "not eligible"
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    tok = jnp.full((4, 1), 7, jnp.int32)
    toks = []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
    return toks

def lowered_text(expert_fetch, prefetch, budget=0):
    ms, mesh, m = setup((2, 4))
    params = jax.eval_shape(m.init_params, jax.random.key(0))
    xp = make_execution_plan(m, InputShape("t", 8, 2, "prefill"), ms,
                             mode="dwdp", prefetch=prefetch,
                             expert_fetch=expert_fetch, demand_budget=budget)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 8), jnp.int32)}
    with mesh:
        return step.lower(params, batch).as_text()

def demand_primitive(want_per_peer, budget, experts=16):
    # primitive-level: crafted request masks -> deterministic overflow
    # flag + exact fetched rows/ids against the canonical gather.
    # experts=16 -> R=1, G'=8, local 2; experts=4 -> R=2 redundant
    # subgroups of G'=4, local 1 (the index round must stay subgroup-
    # scoped there).
    from repro.compat import shard_map
    from repro.core import prefetch as pf
    from repro.core.placement import make_placement
    from jax.sharding import PartitionSpec as P

    mesh = _mesh((8,), ("model",))
    pl = make_placement(experts, 8)
    g, local = pl.subgroup_size, pl.local_count
    npad = pl.num_padded
    x = jnp.arange(pl.storage_size * 3, dtype=jnp.float32).reshape(-1, 3)

    def body(xs):
        p = jax.lax.axis_index("model") % g
        owner = (p + 1) % g
        # want the first `want_per_peer` experts of the NEXT peer only
        wanted = jnp.zeros((npad,), bool)
        ids = owner * local + jnp.arange(local)
        wanted = wanted.at[ids].set(jnp.arange(local) < want_per_peer)
        plan = pf.plan_demand_fetch(wanted, "model", pl, budget=budget,
                                    agree_axes=("model",))
        bank = pf.gather_demand_payload(xs, plan, "model", pl,
                                        budget=budget)
        canon = pf.gather_shards(xs, "model", pl)
        got = bank.fetched
        want_rows = canon[plan.fetched_ids]
        err = jnp.where(
            plan.valid[:, None], jnp.abs(got - want_rows), 0.0
        ).max()
        n_valid = jnp.sum(plan.valid.astype(jnp.int32))
        return jnp.stack([
            err, plan.overflow.astype(jnp.float32),
            n_valid.astype(jnp.float32)])[None]

    f = shard_map(body, mesh=mesh, in_specs=P("model"),
                  out_specs=P("model"), check_vma=False)
    with mesh:
        out = np.asarray(f(x))
    return {"err": float(out[:, 0].max()),
            "overflow": bool(out[:, 1].max() > 0),
            "n_valid": out[:, 2].tolist()}

case = json.loads(sys.argv[1])
kind = case.pop("kind")
results = {}
if kind == "prefill":
    prefetch = case.get("prefetch", "allgather")
    budget = case.get("budget", 100)   # >= local: budget covers, no overflow
    ref = prefill_logits("all", "allgather", (1, 1))
    split = prefill_logits("all", prefetch, (2, 4))
    demand = prefill_logits("demand", prefetch, (2, 4), budget=budget)
    scale = np.abs(ref).max() + 1e-9
    results = {
        "demand_vs_split_bitwise": bool((demand == split).all()),
        "demand_vs_split": float(np.abs(demand - split).max() / scale),
        "demand_vs_ref": float(np.abs(demand - ref).max() / scale),
    }
elif kind == "decode":
    budget = case.get("budget", 100)
    split = decode_tokens("all", (2, 4))
    demand = decode_tokens("demand", (2, 4), budget=budget)
    results = {"match": demand == split, "split": split, "demand": demand}
elif kind == "prim":
    results = demand_primitive(case["want"], case["budget"],
                               experts=case.get("experts", 16))
elif kind == "hlo":
    d, fe = CFG.d_model, CFG.moe.d_ff
    budget = 4                       # n_fetch = 3 * 4 = 12 rows
    full = [(20, d, fe), (20, fe, d)]
    fetched = [(12, d, fe), (12, fe, d)]
    txt_all = lowered_text("all", case["prefetch"])
    txt_dem = lowered_text("demand", case["prefetch"], budget=budget)
    results = {
        "all_full": sum(tensor_shape_count(txt_all, s) for s in full),
        "demand_full": sum(tensor_shape_count(txt_dem, s) for s in full),
        "demand_fetched": sum(tensor_shape_count(txt_dem, s) for s in fetched),
    }
print("RESULT::" + json.dumps(results))
"""


def run_demand_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", DEMAND_SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring", "ring_sliced"])
def test_demand_prefill_matches_split_bitwise(prefetch):
    """When the budget covers the activated set, expert_fetch="demand"
    must produce BITWISE-identical prefill outputs to the all-fetch split
    path (same per-expert streaming, same accumulation order — only the
    weights' transport differs), in every prefetch mode; both track the
    1-device reference."""
    r = run_demand_case({"kind": "prefill", "prefetch": prefetch})
    assert r["demand_vs_split_bitwise"], r
    assert r["demand_vs_ref"] < 2e-3, r


@pytest.mark.slow
def test_demand_decode_matches_split():
    """Greedy decode through the route-before-gather path (per-row KV
    positions downstream of demand-fetched experts) matches the all-fetch
    split path exactly."""
    r = run_demand_case({"kind": "decode"})
    assert r["match"], r


@pytest.mark.slow
def test_demand_overflow_falls_back_exactly():
    """budget=1 per peer cannot cover 8 ranks' activated sets: the
    axis-agreed overflow flag engages the full-remote-gather fallback and
    results stay exactly equal to the all-fetch path (exactness is never
    a function of the budget)."""
    r = run_demand_case({"kind": "prefill", "budget": 1})
    assert r["demand_vs_split_bitwise"], r
    r = run_demand_case({"kind": "decode", "budget": 1})
    assert r["match"], r


@pytest.mark.slow
def test_demand_primitive_plan_and_payload():
    """Primitive-level contract of the two-round demand gather with
    crafted request masks: fetched rows equal the canonical gather's rows
    at fetched_ids, per-peer valid counts are exact, and the overflow
    flag fires exactly when a peer's request exceeds the budget."""
    ok = run_demand_case({"kind": "prim", "want": 1, "budget": 1})
    assert ok["err"] == 0.0, ok
    assert not ok["overflow"], ok
    assert all(v == 1.0 for v in ok["n_valid"]), ok  # 1 row from 1 peer
    over = run_demand_case({"kind": "prim", "want": 2, "budget": 1})
    assert over["overflow"], over
    full = run_demand_case({"kind": "prim", "want": 2, "budget": 2})
    assert full["err"] == 0.0, full
    assert not full["overflow"], full
    assert all(v == 2.0 for v in full["n_valid"]), full
    # redundant placement (R=2 subgroups of G'=4): the index round stays
    # subgroup-scoped and payloads come from the right copy
    red = run_demand_case(
        {"kind": "prim", "want": 1, "budget": 1, "experts": 4}
    )
    assert red["err"] == 0.0, red
    assert not red["overflow"], red
    assert all(v == 1.0 for v in red["n_valid"]), red


# --------------------------------------------------------------------------
# Mixed per-family PolicyTable plans (the GatherPolicy API acceptance):
# demand-fetched split MoE + merged-allgather attention + split-ring dense
# FFN in ONE forward, bitwise-equal to the uniform-transport reference.
# --------------------------------------------------------------------------
MIXED_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, MoEConfig, InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import PolicyTable, make_execution_plan
from repro.core import execution, prefetch as pf
from repro.launch.mesh import _mesh
from repro.analysis import tensor_shape_count

# Every policy family in one model: E=20 routed experts over the 4-wide
# model axis (G'=4, local 5, remote 15 — demand-eligible at 2 routed
# tokens/rank), a shared always-on expert (the dense_ffn family), and
# sharded attention (attn_qkv/attn_out families; heads 4 over A=4).
# Prefill B=2 S=8 seq-shards over "model" -> 2 rows * k=2 = 4 < 15.
CFG = ArchConfig(
    name="mixed-policy-test", family="moe", num_layers=4, d_model=48,
    num_heads=4, num_kv_heads=2, head_dim=20, d_ff=0, vocab_size=160,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=56, shared_d_ff=40),
)

# The acceptance plan: three families, three different policies, ONE
# forward. budget=100 >= local, so the demand path never overflows.
MIXED = {
    "moe_experts": "split:demand:allgather:4:100",
    "attn_qkv": "merged:all:allgather",
    "attn_out": "merged:all:allgather",
    "dense_ffn": "split:all:ring",
}
# The uniform-transport reference: demand->all and ring->allgather are
# bitwise-invariant (identical bank content, identical kernel streaming),
# while layouts stay per-family — so MIXED must equal COMPOSED bit for
# bit, which is exactly the heterogeneous-plumbing claim.
COMPOSED = {
    "moe_experts": "split:all:allgather",
    "attn_qkv": "merged:all:allgather",
    "attn_out": "merged:all:allgather",
    "dense_ffn": "split:all:allgather",
}

def setup(mesh_shape):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    m = build_model(CFG, ms, dtype=jnp.float32, shard_attention=True)
    return ms, mesh, m

def prefill_logits(policy, mesh_shape, check_demand=False):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("t", 8, 2, "prefill"), ms,
                             mode="dwdp", policy=policy,
                             capacity_factor=12.0)
    if check_demand:
        assert execution.demand_fetch_active(CFG, m.geom, xp), "not eligible"
        assert execution.split_bank_active(m.geom, xp, "moe/shared")
        assert not execution.split_bank_active(m.geom, xp, "attn_qkv")
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (2, 8), 0, CFG.vocab_size)}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def decode_tokens(policy, mesh_shape, steps=3):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", policy=policy)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    tok = jnp.full((4, 1), 7, jnp.int32)
    toks = []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
    return toks

def lowered_text(policy):
    ms, mesh, m = setup((2, 4))
    params = jax.eval_shape(m.init_params, jax.random.key(0))
    xp = make_execution_plan(m, InputShape("t", 8, 2, "prefill"), ms,
                             mode="dwdp", policy=policy)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.ShapeDtypeStruct((2, 8), jnp.int32)}
    with mesh:
        return step.lower(params, batch).as_text()

case = json.loads(sys.argv[1])
kind = case.pop("kind")
results = {}
if kind == "prefill":
    ref = prefill_logits(None, (1, 1))
    uniform = prefill_logits(None, (2, 4))
    mixed = prefill_logits(MIXED, (2, 4), check_demand=True)
    composed = prefill_logits(COMPOSED, (2, 4))
    # the intra-attention mix AttnBank exists for: split QKV feeding a
    # merged output projection (one part SplitBank, one merged dict)
    half = prefill_logits({"attn_qkv": "split", "attn_out": "merged"},
                          (2, 4))
    scale = np.abs(ref).max() + 1e-9
    results = {
        "mixed_vs_composed_bitwise": bool((mixed == composed).all()),
        "mixed_vs_uniform": float(np.abs(mixed - uniform).max() / scale),
        "mixed_vs_ref": float(np.abs(mixed - ref).max() / scale),
        "halfattn_vs_uniform": float(np.abs(half - uniform).max() / scale),
        "halfattn_vs_ref": float(np.abs(half - ref).max() / scale),
    }
elif kind == "decode":
    mixed = decode_tokens(MIXED, (2, 4))
    composed = decode_tokens(COMPOSED, (2, 4))
    uniform = decode_tokens(None, (2, 4))
    results = {"match": mixed == composed, "match_uniform": mixed == uniform,
               "mixed": mixed, "composed": composed}
elif kind == "hlo":
    d, fe, sh = CFG.d_model, CFG.moe.d_ff, CFG.moe.shared_d_ff
    a, fsq = 4, CFG.num_heads * CFG.head_dim // 4
    mixed = dict(MIXED)
    mixed["moe_experts"] = "split:demand:allgather:4:4"  # n_fetch = 12
    txt = lowered_text(mixed)
    results = {
        # merged attention stacks DO exist (the attn families are merged)
        "attn_merged": tensor_shape_count(txt, (a, d, fsq)),
        # the full canonical expert bank does NOT (demand split path)
        "expert_full": tensor_shape_count(txt, (20, d, fe))
        + tensor_shape_count(txt, (20, fe, d)),
        # the compact budget-padded fetched bank DOES
        "expert_fetched": tensor_shape_count(txt, (12, d, fe)),
        # and the shared expert's merged (S, D, F/S) stack does NOT
        # (dense_ffn is split): S=4 slices of 40/4=10
        "shared_full": tensor_shape_count(txt, (4, d, sh // 4)),
        "shared_remote": tensor_shape_count(txt, (3, d, sh // 4)),
    }
print("RESULT::" + json.dumps(results))
"""


def run_mixed_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", MIXED_SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
def test_mixed_policy_prefill_bitwise_vs_composed_reference():
    """The api_redesign acceptance: a mixed per-family plan (MoE
    split+demand, attention merged+allgather, dense FFN split+ring) runs
    in ONE forward and is BITWISE-equal to its uniform-transport
    reference (demand->all, ring->allgather are content-identical), while
    tracking the all-split uniform plan and the 1-device reference within
    fp tolerance (merged vs split attention legitimately reorders float
    accumulation)."""
    r = run_mixed_case({"kind": "prefill"})
    assert r["mixed_vs_composed_bitwise"], r
    assert r["mixed_vs_uniform"] < 2e-4, r
    assert r["mixed_vs_ref"] < 2e-3, r
    # split-QKV + merged-out (the one-part-split AttnBank) tracks the
    # all-split uniform plan and the reference too
    assert r["halfattn_vs_uniform"] < 2e-4, r
    assert r["halfattn_vs_ref"] < 2e-3, r


@pytest.mark.slow
def test_mixed_policy_decode_matches_composed_reference():
    """Greedy decode through the mixed plan (demand-fetched experts +
    merged attention + split shared FFN downstream of per-row KV writes)
    matches the uniform-transport reference exactly."""
    r = run_mixed_case({"kind": "decode"})
    assert r["match"], r
    assert r["match_uniform"], r


@pytest.mark.slow
def test_mixed_policy_hlo_structure():
    """The lowering shows true per-family heterogeneity in one module:
    merged attention weight stacks exist, the full canonical expert bank
    does not (demand's compact fetched bank does), and the shared
    expert's dense slices keep the split remote-only form."""
    r = run_mixed_case({"kind": "hlo"})
    assert r["attn_merged"] > 0, r
    assert r["expert_full"] == 0, r
    assert r["expert_fetched"] > 0, r
    assert r["shared_full"] == 0, r
    assert r["shared_remote"] > 0, r


# --------------------------------------------------------------------------
# Predictive demand prefetch + cross-step expert residency cache: bitwise
# exactness for any predictor state / cache budget, and the lowering
# claims (no full bank; budget-bounded speculative + correction rounds).
# --------------------------------------------------------------------------
PREDICT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, MoEConfig, InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh
from repro.analysis import tensor_shape_count

# Same geometry as the demand tests: E=20 over a 4-wide model axis
# (G'=4, local 5, remote 15); decode B=4 routes 2 rows/rank * k=2 = 4
# draws < 15 remote, so demand/predictive are coverage-eligible.
CFG = ArchConfig(
    name="predict-fetch-test", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)

def setup(mesh_shape):
    ms = {"data": mesh_shape[0], "model": mesh_shape[1]}
    mesh = _mesh(mesh_shape, ("data", "model"))
    m = build_model(CFG, ms, dtype=jnp.float32)
    return ms, mesh, m

def decode_tokens(policy, mesh_shape, steps=6):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", policy=policy)
    if policy and ("predictive" in str(policy) or "sync_free" in str(policy)):
        assert execution.predictive_fetch_active(CFG, m.geom, xp)
    if policy and "sync_free" in str(policy):
        assert execution.sync_free_active(CFG, m.geom, xp)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    state = execution.attach_predict_state(state, m, xp)
    # start rows at DIFFERENT tokens so routing shifts across steps
    # (predictor warms, then partially misses)
    tok = jnp.asarray([[7], [23], [55], [90]], jnp.int32)
    toks, stats = [], []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
            if "pred_stats" in o:
                stats.append(np.asarray(o["pred_stats"]).tolist())
    return toks, stats

def prefill_logits(policy, mesh_shape):
    ms, mesh, m = setup(mesh_shape)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("t", 8, 2, "prefill"), ms,
                             mode="dwdp", policy=policy,
                             capacity_factor=12.0)
    step = execution.make_step_fn(m, xp, mesh)
    batch = {"tokens": jax.random.randint(
        jax.random.key(1), (2, 8), 0, CFG.vocab_size)}
    with mesh:
        out = step(params, batch)
    return np.asarray(out["last_logits"], np.float64)

def lowered_decode_text(policy):
    ms, mesh, m = setup((2, 4))
    params = jax.eval_shape(m.init_params, jax.random.key(0))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", policy=policy)
    step = execution.make_step_fn(m, xp, mesh)
    state = jax.eval_shape(
        lambda: execution.attach_predict_state(
            init_decode_state(m, 4, 64), m, xp
        )
    )
    batch = {"token": jax.ShapeDtypeStruct((4, 1), jnp.int32)}
    with mesh:
        return step.lower(params, batch, state).as_text()

case = json.loads(sys.argv[1])
kind = case.pop("kind")
results = {}
if kind == "decode":
    spec = case.get("spec", "split:predictive")
    ref, _ = decode_tokens({"moe_experts": "split:all"}, (2, 4))
    dem, _ = decode_tokens({"moe_experts": "split:demand"}, (2, 4))
    got, stats = decode_tokens({"moe_experts": spec}, (2, 4))
    results = {
        "pred_vs_all": got == ref,
        "demand_vs_all": dem == ref,
        "stats": stats,
    }
elif kind == "prefill":
    # outside decode, fetch="predictive"/"sync_free" must lower exactly
    # as "demand" (no PredictState, no mirrors, no packed round)
    dem = prefill_logits({"moe_experts": "split:demand"}, (2, 4))
    pred = prefill_logits({"moe_experts": "split:predictive"}, (2, 4))
    sync = prefill_logits({"moe_experts": "split:sync_free"}, (2, 4))
    allf = prefill_logits({"moe_experts": "split:all"}, (2, 4))
    results = {
        "pred_vs_demand_bitwise": bool((pred == dem).all()),
        "pred_vs_all_bitwise": bool((pred == allf).all()),
        "sync_vs_demand_bitwise": bool((sync == dem).all()),
    }
elif kind == "hlo_syncfree":
    import re
    def count_allgather(txt, dims, dtype):
        shp = "x".join(str(d) for d in dims)
        pats = [
            re.compile(r"all[_-]gather[^\n]*tensor<" + shp + "x"
                       + dtype + ">"),
            re.compile({"i1": "pred", "f32": "f32"}[dtype]
                       + r"\[" + ",".join(str(d) for d in dims)
                       + r"\][^\n]*all-gather"),
        ]
        return sum(len(p.findall(txt)) for p in pats)
    d, fe = CFG.d_model, CFG.moe.d_ff
    e = CFG.moe.num_experts
    # decode B=4 over data=2 -> 2 routed rows/rank; the LEGACY per-layer
    # packed correction vector was E*(1+rows) + rows*N_POS_BUCKETS = 68
    # bools (must be GONE); the per-STEP mirror payload is
    # rows*E + rows*N_POS_BUCKETS = 48 bools (exactly one gather)
    rows = 2
    legacy_packed = e * (1 + rows) + rows * 4
    mirror = rows * e + rows * 4
    txt_sf = lowered_decode_text(
        {"moe_experts": "split:sync_free:allgather:4:4:8"}
    )
    txt_pred = lowered_decode_text(
        {"moe_experts": "split:predictive:allgather:4:4:8"}
    )
    results = {
        # per-layer (G', E) bool bitmap exchanges: predictive ships one
        # per round (speculative + correction); sync_free keeps ONLY the
        # correction residual — the speculative index exchange is gone
        "pred_bitmap_gathers": count_allgather(txt_pred, (4, e), "i1"),
        "sync_bitmap_gathers": count_allgather(txt_sf, (4, e), "i1"),
        # the legacy per-layer packed correction gather must not appear
        "sync_legacy_packed_gathers": count_allgather(
            txt_sf, (4, legacy_packed), "i1"
        ),
        # the ONE per-step mirror-fold gather is the only other index
        # traffic
        "sync_mirror_gathers": count_allgather(txt_sf, (4, mirror), "i1"),
        # and no full expert bank anywhere (the spec round adds none)
        "sync_full_bank": tensor_shape_count(txt_sf, (e, d, fe))
        + tensor_shape_count(txt_sf, (e, fe, d)),
    }
elif kind == "hlo":
    d, fe = CFG.d_model, CFG.moe.d_ff
    # budget=4 rows/peer -> speculative AND correction banks are each
    # (3*4=12, D, Fe); cache 8 rows
    txt = lowered_decode_text(
        {"moe_experts": "split:predictive:allgather:4:4:8"}
    )
    full = [(20, d, fe), (20, fe, d)]
    spec_corr = [(12, d, fe), (12, fe, d)]
    results = {
        "full_bank": sum(tensor_shape_count(txt, s) for s in full),
        "budget_banks": sum(tensor_shape_count(txt, s) for s in spec_corr),
        # the concatenated (cache 8 | spec 12 | corr 12) fetched bank the
        # kernel consumes next to the 5-row resident bank
        "combined_bank": tensor_shape_count(txt, (32, d, fe)),
    }
print("RESULT::" + json.dumps(results))
"""


def run_predict_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", PREDICT_SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


@pytest.mark.slow
@pytest.mark.parametrize("spec", [
    "split:predictive",                       # auto budgets, cache off
    "split:predictive:allgather:4:0:8",       # cache on (forced eviction:
                                              # 8 rows << the per-step
                                              # fetched set)
    "split:predictive:allgather:4:5:4",       # explicit budget + tiny cache
    "split:predictive:allgather:4:1:4",       # budget 1: forced overflow
                                              # fallback on most steps
])
def test_predictive_decode_bitwise_vs_all_fetch(spec):
    """The tentpole acceptance: N decode steps with the predictive fetch
    — speculative round + residency cache + correction round — are
    BITWISE-identical to the all-fetch split path for any predictor
    state (cold start, warm, shifted routing) and any cache budget
    (0 included), with the budget-overflow fallback exercised too."""
    r = run_predict_case({"kind": "decode", "spec": spec})
    assert r["demand_vs_all"], r
    assert r["pred_vs_all"], r
    # the predictor actually engaged: the stats stream is present and the
    # speculative round predicted something after warm-up
    assert r["stats"] and len(r["stats"]) == 6, r
    warm = r["stats"][-1]
    assert warm[0] > 0 or warm[1] > 0, r  # predicted or hit rows


@pytest.mark.slow
@pytest.mark.parametrize("spec", [
    "split:predictive:ring:4:0:8",
    "split:predictive:ring_sliced:4:0:8",
])
def test_predictive_decode_bitwise_other_transports(spec):
    """Cache + speculative + correction rounds stay bitwise-exact when
    the payload permutes ride the ring / ring_sliced (TDM) schedules."""
    r = run_predict_case({"kind": "decode", "spec": spec})
    assert r["pred_vs_all"], r


@pytest.mark.slow
def test_predictive_cache_hits_skip_the_wire():
    """With a warm cache the measured per-step counters show real hits
    (rows served without the correction round) and eviction pressure at
    a small cache budget."""
    r = run_predict_case(
        {"kind": "decode", "spec": "split:predictive:allgather:4:0:8"}
    )
    stats = r["stats"]  # [predicted, spec_hit, cache_hit, corr, evicted]
    assert stats[0][1] == 0.0 and stats[0][2] == 0.0, stats  # cold: no hits
    assert sum(s[1] + s[2] for s in stats[1:]) > 0, stats  # warm: hits appear
    assert sum(s[4] for s in stats) > 0, stats             # eviction happened
    # hits replace misses: the warm steps' correction round is smaller
    # than the cold step's
    assert min(s[3] for s in stats[1:]) < stats[0][3], stats


@pytest.mark.slow
def test_predictive_prefill_lowers_as_demand():
    """Outside decode there is no PredictState, so fetch="predictive"
    must be bitwise-identical to the plain demand path (and to all-fetch
    when the budget covers)."""
    r = run_predict_case({"kind": "prefill"})
    assert r["pred_vs_demand_bitwise"], r
    assert r["pred_vs_all_bitwise"], r


@pytest.mark.slow
def test_predictive_hlo_budget_bounded_rounds():
    """Lowering claims: the predictive decode module contains NO full
    (num_padded, D, Fe) expert bank anywhere — the speculative round
    introduces none — and both the speculative and correction payloads
    are budget-bounded (12 = 3 peers x 4 rows) rather than sized by E;
    the kernel consumes the compact combined (local+cache+spec+corr)
    bank."""
    r = run_predict_case({"kind": "hlo"})
    assert r["full_bank"] == 0, r
    assert r["budget_banks"] > 0, r
    assert r["combined_bank"] > 0, r


@pytest.mark.slow
@pytest.mark.parametrize("spec", [
    "split:sync_free",                        # auto budgets
    "split:sync_free:allgather:4:4:8",        # explicit budgets + cache
    "split:sync_free:allgather:4:5:0",        # cache budget 0
    "split:sync_free:allgather:4:1:4",        # budget 1: forced overflow
                                              # fallback on most steps
])
def test_syncfree_decode_bitwise_vs_all_fetch(spec):
    """The sync-free tentpole acceptance: N decode steps with the
    mirrored-predictor fetch — zero-index-metadata speculative round,
    mirrored residency caches, packed correction round — are
    BITWISE-identical to the all-fetch split path for any predictor
    state (cold start, warm, shifted routing / forced mispredicts) and
    any budget (cache 0 and overflow-forcing spec budgets included)."""
    r = run_predict_case({"kind": "decode", "spec": spec})
    assert r["demand_vs_all"], r
    assert r["pred_vs_all"], r
    assert r["stats"] and len(r["stats"]) == 6, r
    warm = r["stats"][-1]
    assert warm[0] > 0 or warm[2] > 0, r  # predicted or cache-hit rows


@pytest.mark.slow
@pytest.mark.parametrize("spec", [
    "split:sync_free:ring:4:4:8",
    "split:sync_free:ring_sliced:4:4:8",
])
def test_syncfree_decode_bitwise_other_transports(spec):
    """Mirrored speculative + packed correction rounds stay bitwise-exact
    when the payload permutes ride the ring / ring_sliced (TDM)
    schedules."""
    r = run_predict_case({"kind": "decode", "spec": spec})
    assert r["pred_vs_all"], r


@pytest.mark.slow
def test_syncfree_prefill_lowers_as_demand():
    """Outside decode there are no mirrors to keep in sync, so
    fetch="sync_free" must be bitwise-identical to the plain demand
    path (exactly like predictive)."""
    r = run_predict_case({"kind": "prefill"})
    assert r["sync_vs_demand_bitwise"], r


@pytest.mark.slow
def test_syncfree_hlo_no_bitmap_exchange():
    """The structural claim, asserted on the lowering: the sync_free
    decode module ships STRICTLY fewer per-layer (G', E) bool bitmap
    all-gathers than plain predictive — only the correction round's
    residual bitmap remains (the senders compact the payload against
    it); the speculative round's index exchange is gone, not moved. The
    routing/position mirror payload rides ONE per-step all-gather
    (rows*E + rows*N_POS_BUCKETS bools) instead of the legacy per-layer
    packed vector (E*(1+rows) + ... bools — must not appear), and no
    full (E, D, Fe) expert bank appears anywhere."""
    r = run_predict_case({"kind": "hlo_syncfree"})
    assert r["pred_bitmap_gathers"] > 0, r   # detector sanity
    # correction residual only: fewer index gathers than predictive's
    # two-per-layer (speculative plan + correction plan)
    assert 0 < r["sync_bitmap_gathers"] < r["pred_bitmap_gathers"], r
    assert r["sync_legacy_packed_gathers"] == 0, r  # per-layer fold gone
    assert r["sync_mirror_gathers"] > 0, r          # per-step fold exists
    assert r["sync_full_bank"] == 0, r


# --------------------------------------------------------------------------
# Disaggregated ctx-server prefill on a (2,4) mesh: the seq-sharded KV
# capture (regression — previously tripped an unsharded-sequence assert).
# --------------------------------------------------------------------------
CTX_SHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import numpy as np
from repro.configs import get_arch, reduced_variant
from repro.launch.serve import build_engine
from repro.runtime.engine import Request

cfg = reduced_variant(get_arch("yi-9b"))
outs = {}
for mesh in [(1, 1), (2, 4)]:
    engine, model = build_engine(
        cfg, mesh_shape=mesh, prefill_len=16, cache_len=32, max_batch=2
    )
    rng = np.random.default_rng(0)
    for i in range(3):
        engine.submit(Request(
            req_id=i,
            tokens=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            target_len=6,
        ))
    engine.run(steps=16)
    outs[mesh] = {k: v for k, v in engine.outputs.items()}
print("RESULT::" + json.dumps({
    "match": outs[(1, 1)] == outs[(2, 4)],
    "n_done": len(outs[(2, 4)]),
}))
"""


@pytest.mark.slow
def test_ctx_server_prefill_seq_sharded_kv_capture():
    """Regression: a ContextServer prefill on a (2,4) mesh (batch-1
    prompts force full sequence sharding) used to trip the
    "KV capture requires unsharded sequence" assert. The capture now
    keeps each rank's owned ring slots (the decode cache layout) and the
    engine's greedy tokens match the 1-device engine exactly, admits and
    continuous batching included."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", CTX_SHARD_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    r = json.loads(line[len("RESULT::"):])
    assert r["n_done"] == 3, r
    assert r["match"], r


@pytest.mark.slow
@pytest.mark.parametrize("prefetch", ["allgather", "ring"])
def test_demand_hlo_has_no_full_expert_bank(prefetch):
    """The lowering claim for route-before-gather: the demand module
    contains NO tensor of the full canonical expert-bank shape
    (num_padded, D, Fe)/(num_padded, Fe, D) — the compacted
    budget-padded fetched bank exists instead — while even the all-fetch
    split module never materializes the full bank either (its remote bank
    is the biggest buffer)."""
    r = run_demand_case({"kind": "hlo", "prefetch": prefetch})
    assert r["all_full"] == 0, r      # split path already merge-free
    assert r["demand_full"] == 0, r   # demand adds no full bank
    assert r["demand_fetched"] > 0, r  # compacted fetched bank exists
