"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; same kernels compile for TPU with interpret=False)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention, flash_attention_ref
from repro.kernels.split_gemm.ops import (
    split_dense_ffn,
    split_dense_ffn_jnp,
    split_dense_swiglu_ref,
    split_gemm,
    split_grouped_gemm_ref,
    split_grouped_swiglu_demand_ref,
    split_grouped_swiglu_ref,
    split_reduce_gemm_ref,
    split_reduce_matmul,
    split_stack_gemm_ref,
    split_stack_matmul,
    split_swiglu,
    split_swiglu_demand,
    split_swiglu_demand_jnp,
    split_swiglu_jnp,
)

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


def _swiglu_operands(e, e_l, c, d, f, dtype, wdtype=None, key=0):
    wdtype = wdtype or dtype
    ks = jax.random.split(jax.random.key(key + e * 31 + e_l * 7 + c), 7)
    x = (jax.random.normal(ks[0], (e, c, d)) * 0.1).astype(dtype)
    mk = lambda k, sh: (jax.random.normal(k, sh) * 0.1).astype(wdtype)
    return (
        x,
        mk(ks[1], (e_l, d, f)), mk(ks[2], (e_l, d, f)), mk(ks[3], (e_l, f, d)),
        mk(ks[4], (e - e_l, d, f)), mk(ks[5], (e - e_l, d, f)),
        mk(ks[6], (e - e_l, f, d)),
    )


# --------------------------------------------------------------------------
# split-weight grouped GEMM (paper §4.2)
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "e,e_l,c,d,f",
    [
        (4, 2, 128, 256, 128),
        (8, 3, 64, 128, 256),
        (8, 8, 64, 128, 128),   # all-local (no remote fetch needed)
        (2, 0, 64, 128, 128),   # all-remote
        (16, 5, 128, 512, 384),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_gemm_shapes(e, e_l, c, d, f, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    x = (jax.random.normal(ks[0], (e, c, d)) * 0.1).astype(dtype)
    wl = (jax.random.normal(ks[1], (e_l, d, f)) * 0.1).astype(dtype)
    wr = (jax.random.normal(ks[2], (e - e_l, d, f)) * 0.1).astype(dtype)
    got = split_gemm(x, wl, wr, block_c=64, block_f=128, block_d=128)
    ref = split_grouped_gemm_ref(x, wl, wr)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@settings(deadline=None, max_examples=15)
@given(
    e=st.integers(1, 6),
    split=st.floats(0.0, 1.0),
    cb=st.sampled_from([64, 128]),
    db=st.sampled_from([128, 256]),
)
def test_split_gemm_property(e, split, cb, db):
    """Property: result is independent of WHERE the local/remote split
    falls — the kernel's whole point (no merge, no layout dependence)."""
    c, d, f = 64, 128, 128
    e_l = int(round(split * e))
    ks = jax.random.split(jax.random.key(e * 7 + e_l), 2)
    x = jax.random.normal(ks[0], (e, c, d)) * 0.1
    w = jax.random.normal(ks[1], (e, d, f)) * 0.1
    got = split_gemm(x, w[:e_l], w[e_l:], block_c=cb, block_d=db)
    ref = jnp.einsum("ecd,edf->ecf", x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------
# fused split grouped SwiGLU (§4.2 fast path)
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "e,e_l,c,d,f",
    [
        (4, 2, 128, 256, 128),   # even split, aligned shapes
        (8, 3, 64, 128, 256),    # uneven split
        (6, 6, 64, 128, 128),    # all-local (empty remote bank)
        (6, 0, 64, 128, 128),    # all-remote (empty local bank)
        (8, 5, 24, 96, 160),     # capacity 24: not a multiple of 128
        (4, 1, 7, 64, 128),      # decode-scale capacity below the 8 floor
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_swiglu_shapes(e, e_l, c, d, f, dtype):
    ops = _swiglu_operands(e, e_l, c, d, f, dtype)
    got = split_swiglu(*ops, block_c=64, block_f=128, block_d=128)
    ref = split_grouped_swiglu_ref(*ops)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize("e,e_l", [(4, 2), (4, 0), (4, 4)])
def test_split_swiglu_jnp_impl_matches(e, e_l):
    """The differentiable no-merge formulation equals the merged oracle."""
    ops = _swiglu_operands(e, e_l, 32, 64, 96, jnp.float32)
    got = split_swiglu(*ops, impl="jnp")
    ref = split_grouped_swiglu_ref(*ops)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


@pytest.mark.parametrize("wdtype", [jnp.float8_e4m3fn, jnp.float8_e5m2])
def test_split_swiglu_fp8_storage(wdtype):
    """fp8-stored banks dequantize on use; kernel matches the merged oracle
    (which casts the same way) in the bf16 activation dtype."""
    ops = _swiglu_operands(6, 4, 64, 128, 128, jnp.bfloat16, wdtype=wdtype)
    got = split_swiglu(*ops)
    ref = split_grouped_swiglu_ref(*ops)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[jnp.bfloat16], rtol=TOL[jnp.bfloat16],
    )


@settings(deadline=None, max_examples=12)
@given(
    e=st.integers(1, 6),
    split=st.floats(0.0, 1.0),
    c=st.sampled_from([8, 24, 64]),
)
def test_split_swiglu_property(e, split, c):
    """Property: the result is independent of WHERE the local/remote split
    falls — the §4.2 kernel's whole point (no merge, no layout change)."""
    d, f = 64, 96
    e_l = int(round(split * e))
    ks = jax.random.split(jax.random.key(e * 7 + e_l + c), 4)
    x = jax.random.normal(ks[0], (e, c, d)) * 0.1
    wg = jax.random.normal(ks[1], (e, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (e, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (e, f, d)) * 0.1
    got = split_swiglu(
        x, wg[:e_l], wu[:e_l], wd[:e_l], wg[e_l:], wu[e_l:], wd[e_l:]
    )
    ref = split_grouped_swiglu_ref(
        x, wg[:e_l], wu[:e_l], wd[:e_l], wg[e_l:], wu[e_l:], wd[e_l:]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


def test_split_swiglu_grad_matches_merged():
    """Grad of the no-merge formulation w.r.t. BOTH banks and the tokens
    equals the grad of the merged baseline — the property that lets the
    ZeRO-style train gathers ride the split path."""
    ops = _swiglu_operands(6, 2, 32, 64, 96, jnp.float32)

    def loss_split(args):
        return jnp.sum(jnp.sin(split_swiglu_jnp(*args)))

    def loss_merged(args):
        return jnp.sum(jnp.sin(split_grouped_swiglu_ref(*args)))

    g_split = jax.grad(loss_split)(ops)
    g_merged = jax.grad(loss_merged)(ops)
    for gs, gm in zip(g_split, g_merged):
        np.testing.assert_allclose(
            np.asarray(gs), np.asarray(gm), atol=2e-5, rtol=2e-5
        )


def test_split_swiglu_down_proj_output_blocking():
    """block_o blocks the down-projection output dim (the VMEM-budget
    lowering path): every blocking choice — including a non-dividing one
    that falls back — matches the unblocked result and the merged
    oracle."""
    ops = _swiglu_operands(4, 2, 64, 256, 128, jnp.float32)
    ref = split_grouped_swiglu_ref(*ops)
    for bo in (None, 64, 128, 100, 256):
        got = split_swiglu(
            *ops, block_c=64, block_f=64, block_d=128, block_o=bo
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), atol=2e-5,
            err_msg=f"block_o={bo}",
        )


# --------------------------------------------------------------------------
# demand-fetched split SwiGLU (on-demand expert fetch, route-before-gather)
# --------------------------------------------------------------------------
def _demand_valid(e_f, pattern, key=0):
    if pattern == "all":
        return jnp.ones((e_f,), bool)
    if pattern == "none":
        return jnp.zeros((e_f,), bool)
    return jax.random.bernoulli(jax.random.key(key), 0.6, (e_f,))


@pytest.mark.parametrize(
    "e_l,e_f,c,d,f,pattern",
    [
        (4, 4, 128, 256, 128, "all"),    # budget fully used
        (3, 5, 64, 128, 256, "mixed"),   # partial validity (budget slack)
        (2, 6, 24, 96, 160, "none"),     # nothing fetched was needed
        (4, 1, 7, 64, 128, "all"),       # decode-scale capacity
        (6, 0, 64, 128, 128, "all"),     # empty fetched bank
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_swiglu_demand_shapes(e_l, e_f, c, d, f, pattern, dtype):
    """The demand kernel over (resident, budget-padded fetched) banks
    matches the masked oracle; invalid rows (clamped junk weights by
    contract) flush exact zeros."""
    ops = _swiglu_operands(e_l + e_f, e_l, c, d, f, dtype)
    valid = _demand_valid(e_f, pattern, key=e_l + e_f)
    got = split_swiglu_demand(
        *ops, valid, block_c=64, block_f=128, block_d=128
    )
    ref = split_grouped_swiglu_demand_ref(*ops, valid)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    if not np.asarray(valid).all():
        invalid = ~np.asarray(valid)
        np.testing.assert_array_equal(
            np.asarray(got, np.float32)[e_l:][invalid], 0.0
        )
    jnp_got = split_swiglu_demand(*ops, valid, impl="jnp")
    np.testing.assert_allclose(
        np.asarray(jnp_got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def test_split_swiglu_demand_matches_split_on_routed_experts():
    """The bitwise contract the engine's demand path relies on: a routed
    expert's (C, D) block computes identically whether its weights arrive
    via the all-fetch split bank or the compacted demand bank (same
    streaming structure, same accumulation order)."""
    e, e_l, c, d, f = 8, 4, 24, 64, 96
    ops = _swiglu_operands(e, e_l, c, d, f, jnp.float32)
    full = split_swiglu(*ops, block_c=8, block_f=32, block_d=32)
    # demand-compact the remote bank: fetch remote experts [1, 3] only
    x = ops[0]
    take = jnp.array([1, 3])
    xd = jnp.concatenate([x[:e_l], x[e_l:][take]], 0)
    banks = [w[take] for w in ops[4:]]
    got = split_swiglu_demand(
        xd, *ops[1:4], *banks, jnp.ones((2,), bool),
        block_c=8, block_f=32, block_d=32,
    )
    np.testing.assert_array_equal(
        np.asarray(got[:e_l]), np.asarray(full[:e_l])
    )
    np.testing.assert_array_equal(
        np.asarray(got[e_l:]), np.asarray(full[e_l:][take])
    )


def test_split_swiglu_demand_grad_matches_masked_merged():
    """Grad of the differentiable demand formulation w.r.t. both banks
    and the tokens equals the masked merged baseline's — what lets the
    route-before-gather path ride the ZeRO-style train gathers."""
    ops = _swiglu_operands(6, 2, 32, 64, 96, jnp.float32)
    valid = jnp.array([1, 0, 1, 1], bool)

    def loss_demand(args):
        return jnp.sum(jnp.sin(split_swiglu_demand_jnp(*args, valid)))

    def loss_merged(args):
        return jnp.sum(
            jnp.sin(split_grouped_swiglu_demand_ref(*args, valid))
        )

    g_demand = jax.grad(loss_demand)(ops)
    g_merged = jax.grad(loss_merged)(ops)
    for gd, gm in zip(g_demand, g_merged):
        np.testing.assert_allclose(
            np.asarray(gd), np.asarray(gm), atol=2e-5, rtol=2e-5
        )


# --------------------------------------------------------------------------
# split dense matmul family (attention QKV/O, dense FFN slices)
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "s,s_l,t,d,f",
    [
        (4, 1, 128, 64, 32),    # the attention-shard shape (1 resident)
        (8, 3, 64, 128, 64),
        (4, 4, 64, 48, 16),     # all-local
        (3, 0, 64, 48, 16),     # all-remote
        (5, 2, 7, 64, 128),     # decode-scale token count
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_stack_gemm_shapes(s, s_l, t, d, f, dtype):
    ks = jax.random.split(jax.random.key(s * 13 + s_l), 2)
    x = (jax.random.normal(ks[0], (t, d)) * 0.1).astype(dtype)
    w = (jax.random.normal(ks[1], (s, d, f)) * 0.1).astype(dtype)
    got = split_stack_matmul(
        x, w[:s_l], w[s_l:], block_c=64, block_d=64, impl="pallas"
    )
    ref = split_stack_gemm_ref(x, w[:s_l], w[s_l:])
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    jnp_got = split_stack_matmul(x, w[:s_l], w[s_l:], impl="jnp")
    np.testing.assert_allclose(
        np.asarray(jnp_got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@pytest.mark.parametrize(
    "s,s_l,t,d,f",
    [
        (4, 1, 128, 64, 32),
        (8, 3, 64, 128, 64),
        (4, 4, 64, 48, 16),
        (3, 0, 64, 48, 16),
        (5, 2, 7, 64, 128),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_reduce_gemm_shapes(s, s_l, t, d, f, dtype):
    ks = jax.random.split(jax.random.key(s * 17 + s_l), 2)
    x = (jax.random.normal(ks[0], (s, t, f)) * 0.1).astype(dtype)
    w = (jax.random.normal(ks[1], (s, f, d)) * 0.1).astype(dtype)
    got = split_reduce_matmul(
        x, w[:s_l], w[s_l:], block_c=64, block_k=64, impl="pallas"
    )
    ref = split_reduce_gemm_ref(x, w[:s_l], w[s_l:])
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    jnp_got = split_reduce_matmul(x, w[:s_l], w[s_l:], impl="jnp")
    np.testing.assert_allclose(
        np.asarray(jnp_got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


def _dense_swiglu_operands(s, s_l, t, d, f, dtype, wdtype=None, key=0):
    wdtype = wdtype or dtype
    ks = jax.random.split(jax.random.key(key + s * 11 + s_l * 3 + t), 7)
    x = (jax.random.normal(ks[0], (t, d)) * 0.1).astype(dtype)
    mk = lambda k, sh: (jax.random.normal(k, sh) * 0.1).astype(wdtype)
    return (
        x,
        mk(ks[1], (s_l, d, f)), mk(ks[2], (s_l, d, f)), mk(ks[3], (s_l, f, d)),
        mk(ks[4], (s - s_l, d, f)), mk(ks[5], (s - s_l, d, f)),
        mk(ks[6], (s - s_l, f, d)),
    )


@pytest.mark.parametrize(
    "s,s_l,t,d,f",
    [
        (4, 1, 64, 48, 32),     # the dense-FFN shard shape
        (2, 0, 32, 32, 40),     # all-remote
        (3, 3, 24, 64, 16),     # all-local
        (8, 5, 7, 64, 32),      # decode-scale token count, uneven split
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_dense_swiglu_shapes(s, s_l, t, d, f, dtype):
    ops = _dense_swiglu_operands(s, s_l, t, d, f, dtype)
    got = split_dense_ffn(
        *ops, block_c=32, block_f=16, block_d=32, impl="pallas"
    )
    ref = split_dense_swiglu_ref(*ops)
    np.testing.assert_allclose(
        np.asarray(got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )
    jnp_got = split_dense_ffn(*ops, impl="jnp")
    np.testing.assert_allclose(
        np.asarray(jnp_got, np.float32), np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@settings(deadline=None, max_examples=10)
@given(
    s=st.integers(1, 5),
    split=st.floats(0.0, 1.0),
    t=st.sampled_from([8, 24, 64]),
)
def test_split_dense_swiglu_property(s, split, t):
    """Property: the dense split FFN is independent of WHERE the
    local/remote split falls AND of slice order — which is exactly why
    the rotated remote bank needs no canonicalization on this path."""
    d, f = 64, 32
    s_l = int(round(split * s))
    ks = jax.random.split(jax.random.key(s * 7 + s_l + t), 4)
    x = jax.random.normal(ks[0], (t, d)) * 0.1
    wg = jax.random.normal(ks[1], (s, d, f)) * 0.1
    wu = jax.random.normal(ks[2], (s, d, f)) * 0.1
    wd = jax.random.normal(ks[3], (s, f, d)) * 0.1
    got = split_dense_ffn(
        x, wg[:s_l], wu[:s_l], wd[:s_l], wg[s_l:], wu[s_l:], wd[s_l:]
    )
    ref = split_dense_swiglu_ref(
        x, wg, wu, wd, wg[:0], wu[:0], wd[:0]
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
    # slice order independence (the rotated-bank property)
    perm = np.random.RandomState(s).permutation(s)
    got_p = split_dense_ffn(
        x, wg[perm][:s_l], wu[perm][:s_l], wd[perm][:s_l],
        wg[perm][s_l:], wu[perm][s_l:], wd[perm][s_l:]
    )
    np.testing.assert_allclose(np.asarray(got_p), np.asarray(ref), atol=2e-5)


def test_split_dense_swiglu_down_proj_output_blocking():
    """block_o ported from the grouped kernel to the dense fused SwiGLU
    (ROADMAP's last open split-bank item): every blocking choice —
    including a non-dividing one that falls back — matches the unblocked
    result and the merged oracle."""
    ops = _dense_swiglu_operands(4, 2, 64, 256, 32, jnp.float32)
    ref = split_dense_swiglu_ref(*ops)
    for bo in (None, 64, 128, 100, 256):
        got = split_dense_ffn(
            *ops, block_c=32, block_f=16, block_d=64, block_o=bo,
            impl="pallas",
        )
        np.testing.assert_allclose(
            np.asarray(got), np.asarray(ref), atol=2e-5,
            err_msg=f"block_o={bo}",
        )


def test_split_dense_ffn_grad_matches_merged():
    """Grad of the dense no-merge formulation w.r.t. both banks and the
    tokens equals the merged baseline's — the property that lets the
    ZeRO-style train gathers ride the split dense path."""
    ops = _dense_swiglu_operands(4, 2, 32, 48, 32, jnp.float32)

    def loss_split(args):
        return jnp.sum(jnp.sin(split_dense_ffn_jnp(*args)))

    def loss_merged(args):
        return jnp.sum(jnp.sin(split_dense_swiglu_ref(*args)))

    g_split = jax.grad(loss_split)(ops)
    g_merged = jax.grad(loss_merged)(ops)
    for gs, gm in zip(g_split, g_merged):
        np.testing.assert_allclose(
            np.asarray(gs), np.asarray(gm), atol=2e-5, rtol=2e-5
        )


def test_split_gemm_auto_blocks_non_128_capacity():
    """Block auto-selection: capacities that are not multiples of 128 (or
    even of 8) stream correctly with the default block sizes."""
    for c in (24, 7, 200):
        ks = jax.random.split(jax.random.key(c), 3)
        x = jax.random.normal(ks[0], (4, c, 96)) * 0.1
        wl = jax.random.normal(ks[1], (3, 96, 160)) * 0.1
        wr = jax.random.normal(ks[2], (1, 96, 160)) * 0.1
        got = split_gemm(x, wl, wr)
        ref = split_grouped_gemm_ref(x, wl, wr)
        np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,sq,sk,h,kh,hd,window,q_offset",
    [
        (2, 128, 128, 4, 2, 64, 0, 0),
        (1, 128, 384, 8, 8, 128, 0, 256),
        (2, 256, 256, 4, 1, 64, 100, 0),
        (1, 128, 128, 6, 3, 64, 33, 0),
        (1, 64, 320, 4, 4, 64, 64, 256),
        (1, 128, 128, 4, 2, 128, 0, 0),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, sq, sk, h, kh, hd, window, q_offset, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kh, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kh, hd)).astype(dtype)
    got = flash_attention(
        q, k, v, window=window, q_offset=q_offset, block_q=64, block_k=64
    )
    ref = flash_attention_ref(q, k, v, window=window, q_offset=q_offset)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@settings(deadline=None, max_examples=12)
@given(
    sq=st.sampled_from([64, 128]),
    sk_extra=st.integers(0, 3),
    rep=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([0, 17, 64, 1000]),
)
def test_flash_attention_property(sq, sk_extra, rep, window):
    """Property sweep over GQA ratios, KV overhang and window sizes."""
    kh, hd = 2, 64
    sk = sq + sk_extra * 64
    q_offset = sk - sq
    ks = jax.random.split(jax.random.key(sq + sk + rep), 3)
    q = jax.random.normal(ks[0], (1, sq, kh * rep, hd))
    k = jax.random.normal(ks[1], (1, sk, kh, hd))
    v = jax.random.normal(ks[2], (1, sk, kh, hd))
    got = flash_attention(
        q, k, v, window=window, q_offset=q_offset, block_q=64, block_k=64
    )
    ref = flash_attention_ref(q, k, v, window=window, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
