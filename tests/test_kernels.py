"""Per-kernel shape/dtype sweeps against the pure-jnp oracles
(interpret mode on CPU; same kernels compile for TPU with interpret=False)."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.kernels.flash_attention.ops import flash_attention, flash_attention_ref
from repro.kernels.split_gemm.ops import split_gemm, split_grouped_gemm_ref

TOL = {jnp.float32: 2e-5, jnp.bfloat16: 2e-2}


# --------------------------------------------------------------------------
# split-weight grouped GEMM (paper §4.2)
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "e,e_l,c,d,f",
    [
        (4, 2, 128, 256, 128),
        (8, 3, 64, 128, 256),
        (8, 8, 64, 128, 128),   # all-local (no remote fetch needed)
        (2, 0, 64, 128, 128),   # all-remote
        (16, 5, 128, 512, 384),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_split_gemm_shapes(e, e_l, c, d, f, dtype):
    ks = jax.random.split(jax.random.key(0), 3)
    x = (jax.random.normal(ks[0], (e, c, d)) * 0.1).astype(dtype)
    wl = (jax.random.normal(ks[1], (e_l, d, f)) * 0.1).astype(dtype)
    wr = (jax.random.normal(ks[2], (e - e_l, d, f)) * 0.1).astype(dtype)
    got = split_gemm(x, wl, wr, block_c=64, block_f=128, block_d=128)
    ref = split_grouped_gemm_ref(x, wl, wr)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@settings(deadline=None, max_examples=15)
@given(
    e=st.integers(1, 6),
    split=st.floats(0.0, 1.0),
    cb=st.sampled_from([64, 128]),
    db=st.sampled_from([128, 256]),
)
def test_split_gemm_property(e, split, cb, db):
    """Property: result is independent of WHERE the local/remote split
    falls — the kernel's whole point (no merge, no layout dependence)."""
    c, d, f = 64, 128, 128
    e_l = int(round(split * e))
    ks = jax.random.split(jax.random.key(e * 7 + e_l), 2)
    x = jax.random.normal(ks[0], (e, c, d)) * 0.1
    w = jax.random.normal(ks[1], (e, d, f)) * 0.1
    got = split_gemm(x, w[:e_l], w[e_l:], block_c=cb, block_d=db)
    ref = jnp.einsum("ecd,edf->ecf", x, w)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)


# --------------------------------------------------------------------------
# flash attention
# --------------------------------------------------------------------------
@pytest.mark.parametrize(
    "b,sq,sk,h,kh,hd,window,q_offset",
    [
        (2, 128, 128, 4, 2, 64, 0, 0),
        (1, 128, 384, 8, 8, 128, 0, 256),
        (2, 256, 256, 4, 1, 64, 100, 0),
        (1, 128, 128, 6, 3, 64, 33, 0),
        (1, 64, 320, 4, 4, 64, 64, 256),
        (1, 128, 128, 4, 2, 128, 0, 0),
    ],
)
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_shapes(b, sq, sk, h, kh, hd, window, q_offset, dtype):
    ks = jax.random.split(jax.random.key(1), 3)
    q = jax.random.normal(ks[0], (b, sq, h, hd)).astype(dtype)
    k = jax.random.normal(ks[1], (b, sk, kh, hd)).astype(dtype)
    v = jax.random.normal(ks[2], (b, sk, kh, hd)).astype(dtype)
    got = flash_attention(
        q, k, v, window=window, q_offset=q_offset, block_q=64, block_k=64
    )
    ref = flash_attention_ref(q, k, v, window=window, q_offset=q_offset)
    np.testing.assert_allclose(
        np.asarray(got, np.float32),
        np.asarray(ref, np.float32),
        atol=TOL[dtype], rtol=TOL[dtype],
    )


@settings(deadline=None, max_examples=12)
@given(
    sq=st.sampled_from([64, 128]),
    sk_extra=st.integers(0, 3),
    rep=st.sampled_from([1, 2, 4]),
    window=st.sampled_from([0, 17, 64, 1000]),
)
def test_flash_attention_property(sq, sk_extra, rep, window):
    """Property sweep over GQA ratios, KV overhang and window sizes."""
    kh, hd = 2, 64
    sk = sq + sk_extra * 64
    q_offset = sk - sq
    ks = jax.random.split(jax.random.key(sq + sk + rep), 3)
    q = jax.random.normal(ks[0], (1, sq, kh * rep, hd))
    k = jax.random.normal(ks[1], (1, sk, kh, hd))
    v = jax.random.normal(ks[2], (1, sk, kh, hd))
    got = flash_attention(
        q, k, v, window=window, q_offset=q_offset, block_q=64, block_k=64
    )
    ref = flash_attention_ref(q, k, v, window=window, q_offset=q_offset)
    np.testing.assert_allclose(np.asarray(got), np.asarray(ref), atol=2e-5)
