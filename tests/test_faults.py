"""Fault-tolerant remote-weight fetch (docs/robustness.md).

Three layers of coverage:

- **Bitwise repair** (subprocess, 8 fake devices): deterministic fault
  injection into the demand/predictive payload rounds and the residency
  cache must leave decoded tokens bitwise-identical to the healthy run —
  the checksum-detect -> mask-invalid -> correction/full-gather repair
  path is exact, not approximate. Every injection kind, every fetch
  mode, 6 decode steps (enough for cache-eviction pressure at the small
  cache budget).
- **Property test**: randomized fault schedules (hypothesis when
  installed, the conftest shim's deterministic grid otherwise) across
  fetch modes keep the bitwise invariant and never detect fewer rows
  than were injected into consumed slots.
- **Unit tests** (single device, fast): checksum sensitivity,
  FaultSpec parsing/validation, HealthMonitor hysteresis, Request/
  engine-shape validation, ServingMetrics fault accounting and
  zero-denominator guards, SimConfig scenario replay.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest
from hypothesis import given, settings
from hypothesis import strategies as st

SRC = os.path.join(os.path.dirname(__file__), "..", "src")

# policy under test -> does it exercise payload rounds / a residency
# cache (the "all" rung has no per-peer fetch rounds, so injection has
# no sites and no stats are emitted — the trivially-healthy baseline)
POLICIES = {
    "demand": "split:demand:allgather:4",
    "predictive": "split:predictive:allgather:4:4:8",
    "sync_free": "split:sync_free:allgather:4:4:8",
    "all": "split:all:allgather",
}

FAULT_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, sys
import jax, jax.numpy as jnp, numpy as np
from repro.configs.base import ArchConfig, MoEConfig, InputShape
from repro.models.transformer import build_model
from repro.models.cache import init_decode_state
from repro.core.strategy import make_execution_plan
from repro.core import execution
from repro.launch.mesh import _mesh

# 20 experts over a (2, 4) mesh: 5 subgroup positions are remote per
# rank, so demand rounds, the speculative round, and the size-8 cache
# all see real traffic and eviction pressure within 6 decode steps
CFG = ArchConfig(
    name="fault-test", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)

def decode_tokens(policy, fault_spec=None, validate=False, steps=6):
    ms = {"data": 2, "model": 4}
    mesh = _mesh((2, 4), ("data", "model"))
    m = build_model(CFG, ms, dtype=jnp.float32)
    params = m.init_params(jax.random.key(42))
    xp = make_execution_plan(m, InputShape("d", 64, 4, "decode"), ms,
                             mode="dwdp", policy={"moe_experts": policy},
                             fault_spec=fault_spec, validate_fetch=validate)
    step = execution.make_step_fn(m, xp, mesh)
    state = init_decode_state(m, 4, 64)
    state = execution.attach_predict_state(state, m, xp)
    tok = jnp.asarray([[7], [23], [55], [90]], jnp.int32)
    toks, fstats = [], []
    with mesh:
        for _ in range(steps):
            o = step(params, {"token": tok}, state)
            tok, state = o["next_token"], o["state"]
            toks += np.asarray(tok).ravel().tolist()
            if "fault_stats" in o:
                fstats.append(np.asarray(o["fault_stats"]).tolist())
    return toks, (np.sum(np.asarray(fstats), axis=0).tolist()
                  if fstats else None)

case = json.loads(sys.argv[1])
ref = case.get("ref")
if ref is None:
    ref, _ = decode_tokens(case["policy"])
results = {"ref": ref, "runs": []}
if case.get("validate_run"):
    toks, fs = decode_tokens(case["policy"], validate=True)
    results["validated_match"] = toks == ref
    results["validated_fstats"] = fs
for spec in case.get("specs", []):
    toks, fs = decode_tokens(case["policy"], fault_spec=spec)
    results["runs"].append({"spec": spec, "match": toks == ref,
                            "fstats": fs})
print("RESULT::" + json.dumps(results))
"""


def run_case(case: dict) -> dict:
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", FAULT_SCRIPT, json.dumps(case)],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines() if l.startswith("RESULT::")][-1]
    return json.loads(line[len("RESULT::"):])


# per-kind specs: each isolates one injection mechanism; the storm
# composes all of them plus two persistent bad peers (and, for the
# sync_free rung, a mirror-desync on top)
KIND_SPECS = {
    "drop": "seed=5,drop=0.3",
    "zero": "seed=5,zero=0.3",
    "corrupt": "seed=5,corrupt=0.3",
    "cache": "seed=5,cache=0.4",
    "peers": "seed=5,peers=1",
    "mirror": "seed=5,mirror=0.5",
    "storm": ("seed=1,drop=0.25,zero=0.2,corrupt=0.2,cache=0.25,"
              "mirror=0.3,peers=1|2"),
}
# fstats vector layout (faults.FAULT_STAT_NAMES prefix)
I_DROP, I_ZERO, I_CORRUPT, I_CACHE, I_DET, I_FB, I_MIRROR = range(7)


@pytest.mark.slow
@pytest.mark.parametrize("mode", ["demand", "predictive", "sync_free", "all"])
def test_fault_bitwise_repair(mode):
    """Every injection kind, bitwise-exact decode, detected == injected
    consumed rows. One subprocess per fetch mode; the healthy reference
    is decoded once and reused for every spec."""
    r = run_case({"policy": POLICIES[mode],
                  "validate_run": True,
                  "specs": list(KIND_SPECS.values())})
    if mode == "all":
        # no fetch rounds -> no injection sites, no stats, trivially
        # identical (the bottom-of-ladder degradation target)
        assert r["validated_fstats"] is None
        for run in r["runs"]:
            assert run["match"], run
            assert run["fstats"] is None
        return
    # validation alone must not perturb tokens and must stay clean
    assert r["validated_match"], "validated healthy run diverged"
    v = r["validated_fstats"]
    assert v is not None and max(v) == 0.0, f"healthy run flagged: {v}"
    for kind, run in zip(KIND_SPECS, r["runs"]):
        assert run["match"], f"{mode}/{kind}: fault run diverged"
        fs = run["fstats"]
        injected = sum(fs[I_DROP:I_CACHE + 1])
        if kind == "cache" and mode == "demand":
            # no residency cache on the demand rung: nothing to corrupt
            assert fs[I_CACHE] == 0.0
        elif kind == "mirror":
            # mirror desync perturbs no payload rows; only the sync_free
            # rung has mirrored schedules to diverge, and its psum'd
            # digest cross-check must catch every desynced layer step
            assert injected == 0.0, fs
            if mode == "sync_free":
                assert fs[I_MIRROR] > 0, f"mirror desync undetected: {fs}"
            else:
                assert fs[I_MIRROR] == 0.0, fs
        else:
            assert injected > 0, f"{mode}/{kind}: no rows injected ({fs})"
        assert fs[I_DET] >= injected - 1e-6, (
            f"{mode}/{kind}: detected {fs[I_DET]} < injected {injected}"
        )
        # per-peer attribution tail sums to the detected count
        assert abs(sum(fs[I_MIRROR + 1:]) - fs[I_DET]) < 1e-6, fs
        if kind == "peers":
            # bad peers force drops on every round they serve
            assert fs[I_DROP] > 0, fs
        if kind == "storm" and mode == "sync_free":
            assert fs[I_MIRROR] > 0, fs


# healthy-reference memo so each property example only decodes the
# fault run (the reference per policy is shared across examples)
_REF_CACHE: dict = {}


@pytest.mark.slow
@settings(max_examples=6, deadline=None)
@given(
    mode=st.sampled_from(["demand", "predictive", "sync_free", "all"]),
    seed=st.integers(min_value=0, max_value=7),
    drop=st.floats(min_value=0.0, max_value=0.3),
    corrupt=st.floats(min_value=0.0, max_value=0.3),
    cache=st.floats(min_value=0.0, max_value=0.4),
)
def test_fault_schedule_property(mode, seed, drop, corrupt, cache):
    """Randomized fault schedules never change decoded tokens, and the
    detector never under-counts the injected-and-consumed rows."""
    policy = POLICIES[mode]
    spec = (f"seed={seed},drop={drop:.3f},corrupt={corrupt:.3f},"
            f"cache={cache:.3f}")
    case = {"policy": policy, "specs": [spec]}
    if policy in _REF_CACHE:
        case["ref"] = _REF_CACHE[policy]
    r = run_case(case)
    _REF_CACHE[policy] = r["ref"]
    run = r["runs"][0]
    assert run["match"], f"{mode} spec={spec}: fault run diverged"
    fs = run["fstats"]
    if mode == "all":
        assert fs is None
        return
    injected = sum(fs[I_DROP:I_CACHE + 1])
    assert fs[I_DET] >= injected - 1e-6, (spec, fs)
    assert all(v >= -1e-6 for v in fs), (spec, fs)


ENGINE_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json, numpy as np
from repro.configs.base import ArchConfig, MoEConfig
from repro.launch.serve import build_engine
from repro.runtime.engine import HealthMonitor, Request

CFG = ArchConfig(
    name="fault-engine", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)
SPEC = "seed=1,drop=0.4,zero=0.2,corrupt=0.2,cache=0.3,peers=1|2"
engine, _ = build_engine(
    CFG, mesh_shape=(2, 4), prefill_len=8, cache_len=48, max_batch=4,
    gen_mode="dwdp",
    policy={"moe_experts": "split:predictive:allgather:4:4:8"},
    fault_spec=SPEC, health=HealthMonitor(),
)
rng = np.random.default_rng(0)
for i in range(4):
    engine.submit(Request(req_id=i,
                          tokens=rng.integers(0, 128, 8).astype(np.int32),
                          target_len=24))
s = engine.run(40).summary(horizon=40.0)
s["final_level"] = engine.gen.level
s["final_fetch"] = engine.gen.fetch_label
print("RESULT::" + json.dumps(
    {k: s.get(k) for k in ("faults", "detected_by_peer",
                           "policy_transitions", "final_level",
                           "final_fetch", "completed")}
))
"""


@pytest.mark.slow
def test_engine_fault_storm_ladder():
    """End-to-end acceptance: a sustained fault storm demotes the
    policy ladder (predictive -> demand -> all), the all-gather floor
    runs clean so the HealthMonitor re-promotes, and the whole walk is
    visible in ServingMetrics."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", ENGINE_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT::")][-1]
    s = json.loads(line[len("RESULT::"):])
    f = s["faults"]
    injected = sum(v for k, v in f.items() if k.startswith("injected"))
    assert injected > 0 and f["detected"] >= injected - 1e-6, f
    assert abs(sum(s["detected_by_peer"]) - f["detected"]) < 1e-6, s
    kinds = [t["kind"] for t in s["policy_transitions"]]
    assert "demote" in kinds, s["policy_transitions"]
    assert "promote" in kinds, s["policy_transitions"]
    # the storm reaches the all-gather floor at least once
    assert any(t["fetch"] == "all" for t in s["policy_transitions"]), s
    assert s["completed"] == 4


# --------------------------------------------------------------------------
# fast single-device unit tests
# --------------------------------------------------------------------------

def test_checksum_detects_tamper():
    import jax
    import jax.numpy as jnp
    from repro.core import prefetch

    k = jax.random.key(0)
    tree = {"wi": jax.random.normal(k, (6, 4, 8)),
            "wo": jax.random.normal(jax.random.key(1), (6, 8, 4))}
    table = prefetch.row_checksums(tree)  # 1 device: local == global
    ids = jnp.arange(6)
    valid = jnp.ones(6, bool)
    ok, bad = prefetch.verify_rows(tree, ids, valid, table)
    assert bool(ok.all()) and not bool(bad.any())
    # corrupt one row (the injector's w -> 1-w tamper), zero another
    bad_tree = jax.tree.map(lambda w: w.at[2].set(1.0 - w[2]), tree)
    bad_tree = jax.tree.map(lambda w: w.at[4].set(0.0), bad_tree)
    ok, bad = prefetch.verify_rows(bad_tree, ids, valid, table)
    assert np.asarray(bad).tolist() == [False, False, True, False, True,
                                        False]
    assert np.asarray(ok).tolist() == [True, True, False, True, False, True]
    # padding rows are never flagged
    ok, bad = prefetch.verify_rows(bad_tree, ids, jnp.zeros(6, bool), table)
    assert not bool(bad.any())


def test_fault_spec_parse_and_validate():
    from repro.core.faults import FaultSpec

    s = FaultSpec.parse("seed=3,drop=0.1,corrupt=0.05,peers=2|5")
    assert s.seed == 3 and s.drop_rate == 0.1 and s.corrupt_rate == 0.05
    assert s.bad_peers == (2, 5) and s.any_faults
    assert "drop=0.1" in s.describe()
    assert FaultSpec.parse(s.describe()) == s  # describe round-trips
    assert not FaultSpec(seed=1).any_faults
    with pytest.raises(ValueError):
        FaultSpec.parse("drop=1.5")
    with pytest.raises(ValueError):
        FaultSpec.parse("frobnicate=1")
    with pytest.raises(ValueError):
        FaultSpec(seed=0, drop_rate=-0.1)


def test_health_monitor_hysteresis():
    from repro.runtime.engine import HealthMonitor

    h = HealthMonitor(decay=0.5, demote_threshold=0.5,
                      promote_threshold=0.1, min_dwell=2)
    storm = np.array([3.0, 0.0, 1.0, 0.0])
    moves = [h.observe(storm) for _ in range(4)]
    assert "demote" in moves, moves
    # dwell: the step right after a move may not move again
    i = moves.index("demote")
    assert all(m is None for m in moves[:i])
    # recovery: clean observations decay the EMAs below the promote bar
    moves = [h.observe(np.zeros(4)) for _ in range(8)]
    assert "promote" in moves, moves
    # hysteresis band: intermittent pressure settles the EMA between the
    # thresholds and moves nothing
    h2 = HealthMonitor(decay=0.5, demote_threshold=0.9,
                       promote_threshold=0.01, min_dwell=0)
    assert all(
        h2.observe(np.array([float((i + 1) % 2), 0.0])) is None
        for i in range(8)
    )
    with pytest.raises(ValueError):
        HealthMonitor(decay=1.5)
    with pytest.raises(ValueError):
        HealthMonitor(demote_threshold=0.1, promote_threshold=0.5)


def test_request_validation():
    from repro.runtime.engine import Request

    ok = Request(req_id=0, tokens=[1, 2, 3], target_len=4)
    assert ok.tokens.shape == (3,)
    with pytest.raises(ValueError, match="non-empty 1-d"):
        Request(req_id=1, tokens=np.zeros((2, 2), np.int32), target_len=4)
    with pytest.raises(ValueError, match="non-empty 1-d"):
        Request(req_id=2, tokens=np.zeros((0,), np.int32), target_len=4)
    with pytest.raises(ValueError, match="target_len"):
        Request(req_id=3, tokens=[1, 2], target_len=0)


def test_engine_shape_validation():
    """submit() rejects prompt-length and ring-capacity mismatches
    without touching the servers (attribute-shaped stand-ins suffice)."""
    import types

    from repro.runtime.engine import DisaggregatedEngine, Request

    ctx = types.SimpleNamespace(prefill_len=8)
    gen = types.SimpleNamespace(cache_len=16)
    eng = DisaggregatedEngine(None, ctx, gen)
    with pytest.raises(ValueError, match="prefill_len"):
        eng.submit(Request(req_id=0, tokens=np.arange(5), target_len=4))
    with pytest.raises(ValueError, match="cache_len"):
        eng.submit(Request(req_id=1, tokens=np.arange(8), target_len=100))
    eng.submit(Request(req_id=2, tokens=np.arange(8), target_len=9))
    assert len(eng.queue) == 1


def test_metrics_zero_denominator_guards():
    """Empty / fault-aborted runs report 0.0 ratios, not KeyErrors or
    ZeroDivisionErrors (the satellite regression this PR hardens)."""
    from repro.runtime.metrics import ServingMetrics

    s = ServingMetrics().summary(horizon=1.0)
    assert s["gather_fetch_ratio"] == 0.0
    assert s["predict_hit_rate"] == 0.0
    assert "gathered_mb_fetched" not in s
    assert "faults" not in s


def test_metrics_fault_accounting():
    from repro.core.faults import FAULT_STAT_BASE, FAULT_STAT_NAMES
    from repro.runtime.metrics import ServingMetrics

    m = ServingMetrics()
    # 7-entry base (…, fault_fallbacks, mirror_divergence) + 2-peer tail
    vec = [2.0, 1.0, 0.0, 1.0, 4.0, 1.0, 2.0, 3.0, 1.0]
    m.record_fault_stats(vec)
    m.record_fault_stats(vec)
    m.record_transition(3, "demote", 1, "demand")
    s = m.summary(horizon=1.0)
    assert s["faults"]["detected"] == 8.0
    assert s["faults"]["injected_drop"] == 4.0
    assert s["faults"]["mirror_divergence"] == 4.0
    assert s["detected_by_peer"] == [6.0, 2.0]
    assert s["policy_transitions"][0]["kind"] == "demote"
    assert len(FAULT_STAT_NAMES) == FAULT_STAT_BASE == 7


def test_degradation_ladder():
    from repro.core.strategy import (
        GatherPolicy,
        PolicyTable,
        degradation_ladder,
    )

    t = PolicyTable(default=GatherPolicy(layout="split"), families=(
        ("moe_experts", GatherPolicy(layout="split", fetch="predictive",
                                     budget=4, cache_budget=8)),
    ))
    ladder = degradation_ladder(t)
    assert [label for label, _, _ in ladder] == [
        "predictive", "predictive+excl", "demand", "all", "reshard",
    ]
    # the +excl rung keeps the root table; its peer set is the engine's
    # runtime choice (None = "fill in the HealthMonitor's worst peer"),
    # every other rung excludes nobody
    assert [excl for _, _, excl in ladder] == [(), None, (), (), ()]
    assert ladder[1][1] is ladder[0][1]
    assert ladder[2][1].family("moe_experts").fetch == "demand"
    assert ladder[3][1].family("moe_experts").fetch == "all"
    # the terminal fail-stop rung runs the all-gather table (no
    # per-peer payload rounds during recovery)
    assert ladder[4][1].family("moe_experts").fetch == "all"
    # sync_free roots walk the same shape
    ts = PolicyTable(default=GatherPolicy(layout="split"), families=(
        ("moe_experts", GatherPolicy(layout="split", fetch="sync_free",
                                     budget=4, cache_budget=8)),
    ))
    assert [label for label, _, _ in degradation_ladder(ts)] == [
        "sync_free", "sync_free+excl", "demand", "all", "reshard",
    ]
    # a demand-rooted table has no predictive or exclusion rung
    t2 = PolicyTable(default=GatherPolicy(layout="split"), families=(
        ("moe_experts", GatherPolicy(layout="split", fetch="demand")),
    ))
    assert [lab for lab, _, _ in degradation_ladder(t2)] == [
        "demand", "all", "reshard",
    ]


def test_checksum_overhead_under_2pct():
    """The validation protocol's healthy-path price at the R1 decode
    acceptance shape: the f32 checksum table rides the index round, so
    the modeled step-time overhead must stay under 2%."""
    from repro.configs import get_arch
    from repro.core import roofline
    from repro.core.strategy import PolicyTable

    cfg = get_arch("deepseek-r1")
    policies = PolicyTable.uniform(layout="split", fetch="predictive")
    kw = dict(tokens=8, group=4, kv_len=2048, policies=policies)
    t_plain = roofline.modeled_step_time(cfg, **kw)
    t_val = roofline.modeled_step_time(cfg, validate=True, **kw)
    assert t_val >= t_plain
    assert t_val / t_plain - 1.0 < 0.02


def test_simulator_scenario_replay():
    from repro.configs import get_arch
    from repro.runtime.simulator import ClusterSimulator, SimConfig

    cfg = get_arch("deepseek-r1")
    base = dict(cfg=cfg, gen_mode="dwdp", expert_fetch="predictive",
                cache_budget=16, gen_gpus=8)
    t0 = ClusterSimulator(SimConfig(**base)).gen_step_time(64)
    t1 = ClusterSimulator(
        SimConfig(**base, validate_fetch=True)
    ).gen_step_time(64)
    storm = ClusterSimulator(SimConfig(
        **base, validate_fetch=True, fault_rate=0.3,
        straggler_ranks=2, straggler_slowdown=3.0,
    ))
    t2 = storm.gen_step_time(64)
    assert t1 >= t0          # checksum metadata never makes steps faster
    assert t2 > t1           # fallback + straggler replay costs real time
    rows = storm.degraded_table()
    assert [r["fetch"] for r in rows] == [
        "predictive", "predictive+excl", "demand", "all", "reshard",
    ]
    assert all(r["t_scenario_us"] > 0 for r in rows)
    # the fail-stop rung prices the survivor subgroup and carries the
    # one-off recovery cost columns
    assert rows[-1]["reshard_wire_mb"] > 0
    assert rows[-1]["recovery_stall_us"] > 0
    # sync_free replays through the same ladder, rooted at its own rung
    sf = ClusterSimulator(SimConfig(
        **{**base, "expert_fetch": "sync_free"}, validate_fetch=True,
        fault_rate=0.3,
    ))
    sf_rows = sf.degraded_table()
    assert [r["fetch"] for r in sf_rows] == [
        "sync_free", "sync_free+excl", "demand", "all", "reshard",
    ]
    with pytest.raises(ValueError):
        SimConfig(cfg=cfg, fault_rate=1.5)
    with pytest.raises(ValueError):
        SimConfig(cfg=cfg, straggler_slowdown=0.5)
    with pytest.raises(ValueError):
        SimConfig(cfg=cfg, straggler_ranks=-2)
