"""Zero-recompile online policy switching (PR 8).

Three contracts:

1. ``variant_key`` is exactly as fine as the canonical PolicyTable form:
   two keys collide iff ``PolicyTable.to_dict()`` + the shape bucket +
   the exclusion set are equal (hypothesis property).
2. After ``DisaggregatedEngine.warmup()``, switching the generation
   server between warmed policy tables across >= 3
   prefill -> decode -> prefill cycles adds ZERO jit executables — the
   variant cache's ``compiles()`` and the ctx step's cache stay flat
   (subprocess, 8 fake host devices, the real sharded fetch paths).
3. The served greedy-token trace under ``--policy auto-online``
   switching is bitwise identical to the best static resolved table
   (the fetch paths are exact — a policy switch may move bytes, never
   values).
"""
import json
import os
import subprocess
import sys

import pytest
from hypothesis import given, settings, strategies as st

SRC = os.path.join(os.path.dirname(__file__), os.pardir, "src")


# --------------------------------------------------------------------------
# 1. the variant-cache key: collides iff canonical table + bucket equal
# --------------------------------------------------------------------------
# (layout, fetch) pairs GatherPolicy accepts: demand-class fetches imply
# the split layout (merged + non-all is rejected at construction)
_POLS = (
    ("split", "all"), ("merged", "all"), ("split", "demand"),
    ("split", "predictive"), ("split", "sync_free"),
)


def _table(pol, budget):
    from repro.core.strategy import PolicyTable

    layout, fetch = pol
    return PolicyTable.uniform(layout=layout, fetch=fetch, budget=budget)


@settings(max_examples=60)
@given(
    pol_a=st.sampled_from(_POLS),
    pol_b=st.sampled_from(_POLS),
    budget_a=st.sampled_from((0, 8, 16)),
    budget_b=st.sampled_from((0, 8, 16)),
    batch_a=st.sampled_from((1, 2, 4, 8)),
    batch_b=st.sampled_from((1, 2, 4, 8)),
    excl_a=st.sampled_from(((), (1,), (1, 3))),
    excl_b=st.sampled_from(((), (1,), (1, 3))),
)
def test_variant_key_collides_iff_canonical_form_equal(
    pol_a, pol_b, budget_a, budget_b,
    batch_a, batch_b, excl_a, excl_b,
):
    from repro.configs.base import InputShape
    from repro.runtime.engine import variant_key

    ta = _table(pol_a, budget_a)
    tb = _table(pol_b, budget_b)
    sa = InputShape("gen", 32, batch_a, "decode")
    sb = InputShape("gen", 32, batch_b, "decode")
    ka = variant_key(ta, sa, excl_a)
    kb = variant_key(tb, sb, excl_b)
    same = (
        ta.to_dict() == tb.to_dict()
        and (sa.phase, sa.seq_len, sa.global_batch)
        == (sb.phase, sb.seq_len, sb.global_batch)
        and excl_a == excl_b
    )
    assert (ka == kb) == same, (ka, kb)


def test_variant_key_ignores_non_bucket_shape_fields():
    """The key buckets on (phase, seq_len, global_batch) — the name is
    presentation, not a compile axis."""
    from repro.configs.base import InputShape
    from repro.runtime.engine import variant_key

    t = _table(("split", "demand"), 8)
    a = InputShape("gen", 32, 4, "decode")
    b = InputShape("renamed", 32, 4, "decode")
    assert variant_key(t, a) == variant_key(t, b)
    c = InputShape("gen", 32, 4, "prefill")
    assert variant_key(t, a) != variant_key(t, c)


def test_variant_key_equivalent_spellings_collide():
    """Two differently-constructed tables with the same canonical
    ``to_dict()`` form map to ONE variant (no duplicate compiles)."""
    from repro.configs.base import InputShape
    from repro.core.strategy import PolicyTable, GatherPolicy
    from repro.runtime.engine import variant_key

    a = PolicyTable.uniform(layout="split", fetch="demand")
    b = PolicyTable(
        default=GatherPolicy(layout="split"),
        families=(
            ("moe_experts", GatherPolicy(layout="split", fetch="demand")),
        ),
    )
    assert a.to_dict() == b.to_dict()
    shape = InputShape("gen", 32, 4, "decode")
    assert variant_key(a, shape) == variant_key(b, shape)


# --------------------------------------------------------------------------
# 2 + 3. zero recompiles across switches; bitwise trace equivalence
# (subprocess: needs the 8 fake host devices for the sharded fetch paths)
# --------------------------------------------------------------------------
SWITCH_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json
import numpy as np
from repro.configs import get_arch, reduced_variant
from repro.core.strategy import PolicyTable
from repro.launch.serve import build_engine
from repro.runtime.engine import Request

cfg = reduced_variant(get_arch("deepseek-r1"))
MESH = (2, 4)


def reqs(n, target=5):
    rng = np.random.default_rng(7)
    return [
        Request(
            req_id=i,
            tokens=rng.integers(0, cfg.vocab_size, 16).astype(np.int32),
            target_len=target,
        )
        for i in range(n)
    ]


res = {}

# --- forced-switch engine: >= 3 prefill -> decode -> prefill cycles ----
engine, model = build_engine(
    cfg, mesh_shape=MESH, prefill_len=16, cache_len=32, max_batch=4,
    ctx_mode="dwdp", gen_mode="dwdp", policy="auto",
)
gen, ctx = engine.gen, engine.ctx
boot = gen.xp.policies
alt = PolicyTable.uniform(layout="split", fetch="demand")
if alt.describe() == boot.describe():
    alt = PolicyTable.uniform(layout="split", fetch="all")
ctx.warmup(engine.params)
gen.warmup(engine.params, tables=[alt])
res["warm_variants"] = len(gen.variants)
c0 = gen.variants.compiles()
x0 = ctx.step.cache_size()
res["warm_compiles"] = c0

tables = [alt, boot, alt, boot]
switches = 0
for i, req in enumerate(reqs(4)):
    switches += bool(gen.set_policy(tables[i % len(tables)]))
    engine.submit(req)
    engine.run(steps=4)           # prefill admit + decode steps
res["switches"] = switches
res["compiles_after"] = gen.variants.compiles()
res["ctx_cache_delta"] = ctx.step.cache_size() - x0
res["variant_hits"] = gen.variants.stats["hits"]
res["variant_misses"] = gen.variants.stats["misses"]
res["boot_describe_ne_alt"] = boot.describe() != alt.describe()

# --- bitwise: auto-online switching vs the best static table -----------
def serve(policy, steps=30):
    eng, _ = build_engine(
        cfg, mesh_shape=MESH, prefill_len=16, cache_len=32, max_batch=4,
        ctx_mode="dwdp", gen_mode="dwdp", policy=policy, seed=0,
        switch_interval=2,
    )
    eng.warmup()
    for r in reqs(6, target=5):
        eng.submit(r)
    metrics = eng.run(steps=steps)
    return eng, metrics.summary(horizon=float(steps))

online_eng, online_sum = serve("auto-online")
static_eng, static_sum = serve("auto")
res["online_completed"] = online_sum["completed"]
res["static_completed"] = static_sum["completed"]
res["trace_match"] = online_eng.outputs == static_eng.outputs
res["online_transitions"] = online_sum.get("policy_switches", 0) + \
    online_sum.get("budget_resizes", 0)
res["online_compiles_flat"] = (
    online_eng.gen.variants.stats["misses"] == len(online_eng.gen.variants)
)
print("RESULT::" + json.dumps(res))
"""


@pytest.mark.slow
def test_policy_switching_zero_recompile_and_bitwise():
    """(a) After warmup, >= 3 forced policy switches interleaved with
    prefill -> decode -> prefill traffic add ZERO jit executables on
    either server (the zero-recompile contract, asserted via the jit
    cache probes). (b) The full auto-online engine serves a greedy-token
    trace bitwise identical to the static resolved table."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", SWITCH_SCRIPT],
        capture_output=True, text=True, env=env, timeout=900,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [
        l for l in out.stdout.splitlines() if l.startswith("RESULT::")
    ][-1]
    r = json.loads(line[len("RESULT::"):])
    # two genuinely distinct warmed tables, switched between >= 3 times
    assert r["boot_describe_ne_alt"], r
    assert r["warm_variants"] >= 2, r
    assert r["switches"] >= 3, r
    # ZERO recompiles: the executable counts never moved after warmup
    assert r["compiles_after"] == r["warm_compiles"], r
    assert r["ctx_cache_delta"] == 0, r
    # every switch was a cache hit (misses only ever built new entries)
    assert r["variant_misses"] == r["warm_variants"], r
    assert r["variant_hits"] >= r["switches"], r
    # bitwise: switching moved bytes, never values
    assert r["online_completed"] == r["static_completed"] >= 1, r
    assert r["trace_match"], r
