"""Per-arch smoke tests (required deliverable f): each assigned
architecture's REDUCED variant (2 layers, d_model<=512, <=4 experts) runs
one forward + one train step on CPU; shapes and finiteness asserted."""
import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCHS, ASSIGNED_ARCHS, reduced_variant
from repro.configs.base import InputShape
from repro.core import execution
from repro.core.strategy import make_execution_plan
from repro.models.cache import init_decode_state
from repro.models.transformer import build_model
from repro.optim import adamw_init

from conftest import tiny_batch

MS = {"data": 1, "model": 1}


def _model(name):
    return build_model(reduced_variant(ARCHS[name]), MS, dtype=jnp.float32)


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS + ["deepseek-r1"])
def test_prefill_forward(arch, smoke_mesh):
    model = _model(arch)
    cfg = model.cfg
    params = model.init_params(jax.random.key(0))
    xp = make_execution_plan(model, InputShape("p", 64, 2, "prefill"), MS)
    step = execution.make_step_fn(model, xp, smoke_mesh)
    out = step(params, tiny_batch(cfg))
    logits = np.asarray(out["last_logits"])
    assert logits.shape == (2, model.geom.vocab_pad)
    assert np.isfinite(logits[:, : cfg.vocab_size]).all()


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_train_step(arch, smoke_mesh):
    model = build_model(
        reduced_variant(ARCHS[arch]), MS, dtype=jnp.float32, train=True
    )
    cfg = model.cfg
    params = model.init_params(jax.random.key(0))
    opt = adamw_init(params)
    xp = make_execution_plan(model, InputShape("t", 64, 2, "train"), MS)
    step = execution.make_step_fn(model, xp, smoke_mesh)
    batch = tiny_batch(cfg, train=True)
    params2, opt2, metrics = step(params, opt, batch, jnp.float32(1e-3))
    loss = float(metrics["loss"])
    assert np.isfinite(loss) and loss > 0
    # params are donated into the next step — check finiteness first
    for leaf in jax.tree.leaves(params2):
        assert np.isfinite(np.asarray(leaf, np.float32)).all()
    # one more step must strictly reduce loss on the same batch
    _, _, m2 = step(params2, opt2, batch, jnp.float32(1e-3))
    assert float(m2["loss"]) < loss


@pytest.mark.parametrize("arch", ASSIGNED_ARCHS)
def test_decode_steps(arch, smoke_mesh):
    model = _model(arch)
    cfg = model.cfg
    params = model.init_params(jax.random.key(0))
    xp = make_execution_plan(model, InputShape("d", 64, 2, "decode"), MS)
    step = execution.make_step_fn(model, xp, smoke_mesh)
    state = init_decode_state(model, 2, 64)
    tok = jnp.zeros((2, 1), jnp.int32)
    seen = []
    for _ in range(4):
        out = step(params, {"token": tok}, state)
        tok, state = out["next_token"], out["state"]
        assert tok.shape == (2, 1)
        t = np.asarray(tok)
        assert (t >= 0).all() and (t < cfg.vocab_size).all()
        seen.append(t.copy())
    assert int(state["pos"][0]) == 4


@pytest.mark.parametrize("arch", ["yi-9b", "recurrentgemma-2b", "xlstm-350m"])
def test_prefill_decode_consistency(arch, smoke_mesh):
    """Greedy decode after a captured prefill must equal token-by-token
    decode from scratch (KV-transfer correctness)."""
    model = _model(arch)
    cfg = model.cfg
    params = model.init_params(jax.random.key(0))
    prompt_len, gen_len = 16, 6
    cache_len = prompt_len + gen_len + 2

    toks = jax.random.randint(jax.random.key(1), (1, prompt_len), 0, cfg.vocab_size)

    # path A: prefill with capture, then decode
    xp_p = make_execution_plan(model, InputShape("p", prompt_len, 1, "prefill"), MS)
    pstep = execution.make_step_fn(model, xp_p, smoke_mesh, capture_len=cache_len)
    out = pstep(params, {"tokens": toks})
    first_a = int(jnp.argmax(out["last_logits"][0]))
    state = out["state"]

    xp_d = make_execution_plan(model, InputShape("d", cache_len, 1, "decode"), MS)
    dstep = execution.make_step_fn(model, xp_d, smoke_mesh)
    seq_a = [first_a]
    tok = jnp.asarray([[first_a]], jnp.int32)
    for _ in range(gen_len):
        o = dstep(params, {"token": tok}, state)
        tok, state = o["next_token"], o["state"]
        seq_a.append(int(tok[0, 0]))

    # path B: feed the prompt token-by-token through decode, then generate
    state_b = init_decode_state(model, 1, cache_len)
    tok = toks[:, :1]
    nxt = None
    for i in range(prompt_len):
        o = dstep(params, {"token": toks[:, i : i + 1]}, state_b)
        state_b = o["state"]
        nxt = o["next_token"]
    first_b = int(nxt[0, 0])
    seq_b = [first_b]
    tok = nxt
    for _ in range(gen_len):
        o = dstep(params, {"token": tok}, state_b)
        tok, state_b = o["next_token"], o["state"]
        seq_b.append(int(tok[0, 0]))

    assert seq_a == seq_b, (seq_a, seq_b)


def test_long_variant_swaps_global_for_sliding():
    cfg = ARCHS["yi-9b"]
    m = build_model(cfg, MS, long_variant=True)
    assert all(s.window == cfg.long_context_window for g in m.plan for s in g.sigs)


def test_block_causal_prefill_equivalence(smoke_mesh):
    """block_causal skips masked KV blocks but must be numerically
    identical to the masked-full path (full-model check)."""
    import jax
    import jax.numpy as jnp
    import numpy as np
    from repro.configs import ARCHS, reduced_variant
    from repro.configs.base import InputShape
    from repro.core import execution
    from repro.core.strategy import make_execution_plan
    from repro.models.transformer import build_model

    ms = {"data": 1, "model": 1}
    for arch in ("yi-9b", "gemma3-27b"):
        cfg = reduced_variant(ARCHS[arch])
        m = build_model(cfg, ms, dtype=jnp.float32)
        params = m.init_params(jax.random.key(0))
        toks = jax.random.randint(jax.random.key(1), (2, 128), 0, cfg.vocab_size)
        outs = []
        for bc in (False, True):
            xp = make_execution_plan(
                m, InputShape("p", 128, 2, "prefill"), ms, block_causal=bc
            )
            step = execution.make_step_fn(m, xp, smoke_mesh)
            outs.append(np.asarray(step(params, {"tokens": toks})["last_logits"]))
        np.testing.assert_allclose(outs[0], outs[1], rtol=2e-4, atol=2e-4)


def test_window_ring_capture_consistency(smoke_mesh):
    """Prompt longer than the sliding window: the captured ring cache must
    continue decoding identically to a token-by-token decode."""
    cfg = reduced_variant(ARCHS["gemma3-27b"])  # window=64 in the variant
    model = build_model(cfg, MS, dtype=jnp.float32)
    params = model.init_params(jax.random.key(0))
    prompt_len, gen_len = 96, 5          # prompt > window -> ring wraps
    cache_len = prompt_len + gen_len + 3
    toks = jax.random.randint(jax.random.key(1), (1, prompt_len), 0,
                              cfg.vocab_size)

    xp_p = make_execution_plan(
        model, InputShape("p", prompt_len, 1, "prefill"), MS
    )
    pstep = execution.make_step_fn(model, xp_p, smoke_mesh,
                                   capture_len=cache_len)
    out = pstep(params, {"tokens": toks})
    state = out["state"]
    seq_a = [int(jnp.argmax(out["last_logits"][0]))]

    xp_d = make_execution_plan(
        model, InputShape("d", cache_len, 1, "decode"), MS
    )
    dstep = execution.make_step_fn(model, xp_d, smoke_mesh)
    tok = jnp.asarray([[seq_a[0]]], jnp.int32)
    for _ in range(gen_len):
        o = dstep(params, {"token": tok}, state)
        tok, state = o["next_token"], o["state"]
        seq_a.append(int(tok[0, 0]))

    state_b = init_decode_state(model, 1, cache_len)
    nxt = None
    for i in range(prompt_len):
        o = dstep(params, {"token": toks[:, i : i + 1]}, state_b)
        state_b, nxt = o["state"], o["next_token"]
    seq_b = [int(nxt[0, 0])]
    tok = nxt
    for _ in range(gen_len):
        o = dstep(params, {"token": tok}, state_b)
        tok, state_b = o["next_token"], o["state"]
        seq_b.append(int(tok[0, 0]))
    assert seq_a == seq_b, (seq_a, seq_b)


def test_fp8_storage_decode_smoke(smoke_mesh):
    """fp8-stored weights (NVFP4 analogue) decode without NaNs and with
    tokens in range; dequant-on-use is exercised in every consumer."""
    cfg = reduced_variant(ARCHS["deepseek-67b"])
    model = build_model(cfg, MS, dtype=jnp.float8_e4m3fn)
    params = model.init_params(jax.random.key(0))
    xp = make_execution_plan(model, InputShape("d", 32, 2, "decode"), MS)
    step = execution.make_step_fn(model, xp, smoke_mesh)
    state = init_decode_state(model, 2, 32)
    tok = jnp.zeros((2, 1), jnp.int32)
    for _ in range(3):
        out = step(params, {"token": tok}, state)
        tok, state = out["next_token"], out["state"]
        t = np.asarray(tok)
        assert (t >= 0).all() and (t < cfg.vocab_size).all()
