"""Unit tests for model primitives: norms, RoPE, RG-LRU, xLSTM, attention
decode math, layer plan."""
import math

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from hypothesis import given, settings, strategies as st

from repro.configs import ARCHS, get_arch
from repro.configs.base import BlockKind
from repro.models import attention as attn_lib
from repro.models.layers import apply_rope, causal_conv1d, rms_norm, softcap
from repro.models.recurrent import init_recurrent_params, recurrent_block, rglru
from repro.models.transformer import make_layer_plan, signature
from repro.models.xlstm import (
    init_mlstm_params, init_slstm_params, mlstm_block, slstm_block,
)


# --------------------------------------------------------------------------
# primitives
# --------------------------------------------------------------------------
def test_rms_norm_normalizes():
    x = jax.random.normal(jax.random.key(0), (4, 32)) * 7
    y = rms_norm(x, jnp.zeros(32), 1e-6)
    rms = jnp.sqrt(jnp.mean(jnp.square(y), axis=-1))
    np.testing.assert_allclose(np.asarray(rms), 1.0, rtol=1e-3)


def test_rope_preserves_norm_and_relative_phase():
    q = jax.random.normal(jax.random.key(0), (1, 8, 2, 64))
    pos = jnp.arange(8)[None]
    r = apply_rope(q, pos, 10_000.0)
    np.testing.assert_allclose(
        np.linalg.norm(np.asarray(r), axis=-1),
        np.linalg.norm(np.asarray(q), axis=-1),
        rtol=1e-5,
    )
    # dot(q_i, k_j) depends only on i-j: shift both positions by 5
    k = jax.random.normal(jax.random.key(1), (1, 8, 2, 64))
    r2q = apply_rope(q, pos + 5, 10_000.0)
    r2k = apply_rope(k, pos + 5, 10_000.0)
    rk = apply_rope(k, pos, 10_000.0)
    d1 = jnp.einsum("bshd,bthd->bhst", r, rk)
    d2 = jnp.einsum("bshd,bthd->bhst", r2q, r2k)
    np.testing.assert_allclose(np.asarray(d1), np.asarray(d2), atol=1e-3)


def test_softcap_bounds():
    x = jnp.array([-1e5, -1.0, 0.0, 1.0, 1e5])
    y = softcap(x, 30.0)
    assert float(jnp.abs(y).max()) <= 30.0
    np.testing.assert_allclose(float(y[2]), 0.0, atol=1e-6)


def test_causal_conv1d_matches_numpy_and_streams():
    x = jax.random.normal(jax.random.key(0), (2, 10, 3))
    w = jax.random.normal(jax.random.key(1), (4, 3))
    full, _ = causal_conv1d(x, w)
    # streaming: run in two halves carrying the state
    a, st = causal_conv1d(x[:, :6], w)
    b, _ = causal_conv1d(x[:, 6:], w, st)
    np.testing.assert_allclose(
        np.asarray(full), np.concatenate([np.asarray(a), np.asarray(b)], 1),
        rtol=1e-5, atol=1e-6,
    )


# --------------------------------------------------------------------------
# RG-LRU / xLSTM: parallel form == streaming form
# --------------------------------------------------------------------------
@settings(deadline=None, max_examples=8)
@given(s=st.sampled_from([4, 16]), split=st.integers(1, 3))
def test_rglru_streaming_consistency(s, split):
    d = 16
    p = init_recurrent_params(jax.random.key(0), d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (2, s, d)) * 0.5
    h0 = jnp.zeros((2, d))
    full, hf = rglru(x, p["w_r"], p["w_i"], p["a_param"], h0)
    cut = min(split * s // 4, s - 1) or 1
    a, ha = rglru(x[:, :cut], p["w_r"], p["w_i"], p["a_param"], h0)
    b, hb = rglru(x[:, cut:], p["w_r"], p["w_i"], p["a_param"], ha)
    np.testing.assert_allclose(
        np.asarray(full),
        np.concatenate([np.asarray(a), np.asarray(b)], 1),
        rtol=1e-4, atol=1e-5,
    )
    np.testing.assert_allclose(np.asarray(hf), np.asarray(hb), rtol=1e-4, atol=1e-5)


def test_recurrent_block_decode_streaming():
    d = 16
    p = init_recurrent_params(jax.random.key(0), d, jnp.float32)
    x = jax.random.normal(jax.random.key(1), (1, 6, d)) * 0.5
    full, _ = recurrent_block(x, p, None)
    st_ = None
    outs = []
    for t in range(6):
        o, st_ = recurrent_block(x[:, t : t + 1], p, st_)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(full), np.concatenate([np.asarray(o) for o in outs], 1),
        rtol=1e-4, atol=1e-5,
    )


@pytest.mark.parametrize("block,init", [
    (mlstm_block, lambda k, d: init_mlstm_params(k, d, 2, jnp.float32)),
    (slstm_block, lambda k, d: init_slstm_params(k, d, 2, jnp.float32)),
])
def test_xlstm_blocks_decode_streaming(block, init):
    d = 16
    p = init(jax.random.key(0), d)
    x = jax.random.normal(jax.random.key(1), (1, 5, d)) * 0.5
    full, _ = block(x, p, None)
    st_, outs = None, []
    for t in range(5):
        o, st_ = block(x[:, t : t + 1], p, st_)
        outs.append(o)
    np.testing.assert_allclose(
        np.asarray(full), np.concatenate([np.asarray(o) for o in outs], 1),
        rtol=1e-4, atol=1e-5,
    )


# --------------------------------------------------------------------------
# attention decode partials
# --------------------------------------------------------------------------
def test_decode_partial_combine_equals_full():
    """Sharded LSE combine over two KV halves == attention over the whole."""
    b, h, kh, hd, L = 2, 4, 2, 32, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    kc = jax.random.normal(ks[1], (b, L, kh, hd))
    vc = jax.random.normal(ks[2], (b, L, kh, hd))
    kv_pos = jnp.broadcast_to(jnp.arange(L), (b, L))
    q_pos = jnp.full((b,), L - 1)
    full, _ = attn_lib.mha_decode_partial(q, kc, vc, kv_pos, q_pos)
    o1, l1 = attn_lib.mha_decode_partial(
        q, kc[:, : L // 2], vc[:, : L // 2], kv_pos[:, : L // 2], q_pos
    )
    o2, l2 = attn_lib.mha_decode_partial(
        q, kc[:, L // 2 :], vc[:, L // 2 :], kv_pos[:, L // 2 :], q_pos
    )
    got = attn_lib.combine_partials(
        jnp.stack([o1, o2]), jnp.stack([l1, l2])
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_decode_partial_empty_shard_is_neutral():
    b, h, kh, hd, L = 1, 2, 2, 16, 8
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, h, hd))
    kc = jax.random.normal(ks[1], (b, L, kh, hd))
    vc = jax.random.normal(ks[2], (b, L, kh, hd))
    kv_pos = jnp.broadcast_to(jnp.arange(L), (b, L))
    empty_pos = jnp.full((b, L), -1)
    q_pos = jnp.full((b,), L - 1)
    full, lfull = attn_lib.mha_decode_partial(q, kc, vc, kv_pos, q_pos)
    oe, le = attn_lib.mha_decode_partial(q, kc, vc, empty_pos, q_pos)
    got = attn_lib.combine_partials(
        jnp.stack([full, oe]), jnp.stack([lfull, le])
    )
    np.testing.assert_allclose(np.asarray(got), np.asarray(full), rtol=1e-5, atol=1e-5)


def test_mha_prefill_matches_decode_chain():
    """Prefill attention row t == decode attention at position t."""
    b, s, h, kh, hd = 1, 8, 2, 1, 16
    ks = jax.random.split(jax.random.key(0), 3)
    q = jax.random.normal(ks[0], (b, s, h, hd))
    k = jax.random.normal(ks[1], (b, s, kh, hd))
    v = jax.random.normal(ks[2], (b, s, kh, hd))
    pre = attn_lib.mha_prefill(q, k, v, block_kv=4)
    kv_pos = jnp.broadcast_to(jnp.arange(s), (b, s))
    for t in range(s):
        dec, _ = attn_lib.mha_decode_partial(
            q[:, t], k, v, kv_pos, jnp.full((b,), t)
        )
        np.testing.assert_allclose(
            np.asarray(pre[:, t]), np.asarray(dec), rtol=1e-4, atol=1e-5
        )


# --------------------------------------------------------------------------
# layer plan
# --------------------------------------------------------------------------
@pytest.mark.parametrize("arch", list(ARCHS))
def test_layer_plan_covers_all_layers(arch):
    cfg = ARCHS[arch]
    plan = make_layer_plan(cfg)
    total = sum(
        g.n_cycles * len(g.sigs) if g.scan else len(g.sigs) for g in plan
    )
    assert total == cfg.num_layers
    # signatures in the plan match per-layer signatures
    i = 0
    for g in plan:
        reps = g.n_cycles if g.scan else 1
        for _ in range(reps):
            for s in g.sigs:
                assert s == signature(cfg, i)
                i += 1


def test_gemma3_pattern_five_to_one():
    cfg = get_arch("gemma3-27b")
    kinds = [cfg.block_kind(i) for i in range(12)]
    assert kinds.count(BlockKind.GLOBAL_ATTN) == 2
    assert kinds[5] == kinds[11] == BlockKind.GLOBAL_ATTN


def test_moe_interleave_llama4():
    cfg = get_arch("llama4-maverick-400b-a17b")
    moe_layers = [l for l in range(cfg.num_layers) if cfg.is_moe_layer(l)]
    assert len(moe_layers) == 24 and all(l % 2 == 1 for l in moe_layers)
