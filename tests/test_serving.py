"""Serving subsystem tests (docs/serving.md): continuous-batching
scheduler, SLO admission, multi-replica scale-out, percentile metrics,
served-routing traces, and the committed serving-sweep bench JSON.

The rolling-vs-epoch bitwise equivalence runs in a subprocess (8 fake
host devices, (2, 4) mesh) like tests/test_multidevice.py; everything
else is in-process.
"""
import json
import os
import subprocess
import sys

import numpy as np
import pytest

SRC = os.path.join(os.path.dirname(__file__), "..", "src")
FIXTURE = os.path.join(
    os.path.dirname(__file__), "fixtures", "served_routing_trace.npz"
)
BENCH_JSON = os.path.join(
    os.path.dirname(__file__), "..", "BENCH_serving_sweep.json"
)


# ---------------------------------------------------------------- workload

def test_workload_deterministic():
    from repro.runtime.serving import WorkloadConfig, synthesize_workload

    wl = WorkloadConfig(num_requests=16, isl_buckets=(32, 64),
                        isl_weights=(0.5, 0.5), osl=8, osl_jitter=0.5,
                        arrival_rate=2.0, seed=11)
    a = synthesize_workload(wl, vocab_size=128)
    b = synthesize_workload(wl, vocab_size=128)
    assert [r.prompt_len for r in a] == [r.prompt_len for r in b]
    assert [r.target_len for r in a] == [r.target_len for r in b]
    assert [r.arrival for r in a] == [r.arrival for r in b]
    for ra, rb in zip(a, b):
        np.testing.assert_array_equal(ra.tokens, rb.tokens)
        assert len(ra.tokens) == ra.prompt_len
    # Poisson arrivals are nondecreasing; lengths come from the buckets
    arr = [r.arrival for r in a]
    assert arr == sorted(arr) and arr[-1] > 0
    assert {r.prompt_len for r in a} <= {32, 64}


def test_workload_weights_and_no_arrivals():
    from repro.runtime.serving import WorkloadConfig, synthesize_workload

    wl = WorkloadConfig(num_requests=12, isl_buckets=(32, 64),
                        isl_weights=(1.0, 0.0), osl=8)
    reqs = synthesize_workload(wl)
    assert all(r.prompt_len == 32 for r in reqs)
    assert all(r.arrival == 0.0 for r in reqs)
    assert all(r.tokens is None for r in reqs)
    with pytest.raises(ValueError):
        WorkloadConfig(num_requests=-1, isl_buckets=(32,))
    with pytest.raises(ValueError):
        WorkloadConfig(num_requests=1, isl_buckets=(32,),
                       isl_weights=(0.5, 0.5))


# --------------------------------------------------------------- admission

def test_admission_decisions():
    from repro.runtime.serving import (
        ADMIT, QUEUE, REJECT, AdmissionController, SLOConfig,
    )

    # no SLO: everything admits
    free = AdmissionController(SLOConfig(), lambda b: 1.0)
    assert free.decide(active=5, queue_len=9, queued_for=99.0) == ADMIT

    # rate gate: projected tps/user = 1 / (0.1 * batch)
    slo = SLOConfig(target_tps_user=2.0, ttft_budget_s=10.0, max_queue=2)
    adm = AdmissionController(slo, lambda b: 0.1 * b)
    assert adm.decide(active=3, queue_len=0, queued_for=0.0) == ADMIT
    assert adm.decide(active=8, queue_len=0, queued_for=0.0) == QUEUE
    # idle replica always admits, however bad the projection
    assert adm.decide(active=0, queue_len=0, queued_for=0.0) == ADMIT
    # full queue sheds instead of queueing deeper
    assert adm.decide(active=8, queue_len=2, queued_for=0.0) == REJECT
    # blown TTFT budget sheds even when the rate would admit
    assert adm.decide(active=3, queue_len=0, queued_for=11.0) == REJECT


def test_admission_eviction_streak():
    from repro.runtime.serving import AdmissionController, SLOConfig

    slo = SLOConfig(target_tps_user=10.0, evict_after=3)
    adm = AdmissionController(slo, lambda b: 0.01)
    bad, good = 0.5, 0.05  # 2 tps/user vs 20
    assert not adm.observe_step(bad, active=4)
    assert not adm.observe_step(bad, active=4)
    assert adm.observe_step(bad, active=4)      # streak of 3 fires
    assert not adm.observe_step(bad, active=4)  # ...and resets
    # a good step resets the streak
    assert not adm.observe_step(bad, active=4)
    assert not adm.observe_step(good, active=4)
    assert not adm.observe_step(bad, active=4)
    assert not adm.observe_step(bad, active=4)
    # single-user batches never evict (nothing to shed to)
    for _ in range(5):
        assert not adm.observe_step(bad, active=1)


# --------------------------------------------------------------- scheduler

class FakeClient:
    """Deterministic replica client: fixed durations, token = req_id*100
    + step index, full call log."""

    def __init__(self, num_slots=2, step_dur=1.0, admit_dur=0.25):
        self.num_slots = num_slots
        self.num_gpus = 1
        self.step_dur = step_dur
        self.admit_dur = admit_dur
        self.log = []
        self._n = 0

    def admit(self, slot, req):
        self.log.append(("admit", slot, req.req_id,
                         req.resume is not None))
        return 7, self.admit_dur

    def step(self, active):
        self.log.append(("step", tuple(active)))
        self._n += 1
        return [100 * (i + 1) + self._n for i in range(self.num_slots)], \
            self.step_dur

    def step_time(self, batch):
        return self.step_dur

    def release(self, slot):
        self.log.append(("release", slot))

    def evict(self, slot):
        self.log.append(("evict", slot))
        return {"fake": True}

    def has_bucket(self, prompt_len):
        return True


def _reqs(lens, arrival=0.0):
    from repro.runtime.serving import ServedRequest

    return [ServedRequest(req_id=i, prompt_len=8, target_len=n,
                          arrival=arrival)
            for i, n in enumerate(lens)]


def test_rolling_admission_beats_epoch():
    from repro.runtime.serving import ServingScheduler

    # unequal lengths: slot 0 frees early; rolling refills it, epoch
    # waits for the whole batch to drain
    lens = [2, 8, 2, 8]
    roll = ServingScheduler(FakeClient(num_slots=2))
    roll.submit(_reqs(lens))
    roll.run()
    epoch = ServingScheduler(FakeClient(num_slots=2), epoch_mode=True)
    epoch.submit(_reqs(lens))
    epoch.run()
    assert roll.metrics.summary(1.0)["completed"] == 4
    assert epoch.metrics.summary(1.0)["completed"] == 4
    assert roll.steps < epoch.steps  # freed slots decode useful tokens
    # every request got exactly target_len tokens under both schedules
    for s in (roll, epoch):
        for r in s.metrics.records:
            assert r.tokens_out == lens[r.req_id]
            assert len(s.outputs[r.req_id]) == lens[r.req_id]


def test_scheduler_respects_arrivals():
    from repro.runtime.serving import ServingScheduler

    sched = ServingScheduler(FakeClient(num_slots=2))
    sched.submit(_reqs([3], arrival=10.0))
    sched.run()
    rec = sched.metrics.records[0]
    assert rec.first_token_time >= 10.0  # idled until the arrival
    assert sched.metrics.summary(1.0)["completed"] == 1


def test_evict_to_queue_and_resume():
    from repro.runtime.serving import (
        AdmissionController, ServingScheduler, SLOConfig,
    )

    # the projection is optimistic (0.01s steps -> 100 tps/user), so
    # both requests admit; MEASURED steps run at 1s -> 1 tps/user, a
    # sustained violation that evicts the youngest slot to the queue,
    # which later resumes and completes
    client = FakeClient(num_slots=2, step_dur=1.0)
    adm = AdmissionController(SLOConfig(target_tps_user=10.0,
                                        evict_after=2),
                              lambda b: 0.01)
    sched = ServingScheduler(client, admission=adm)
    sched.submit(_reqs([6, 6]))
    sched.run()
    s = sched.metrics.summary(1.0)
    assert s["completed"] == 2
    assert s["admission"]["evicted"] >= 1
    assert s["admission"]["resumed"] >= 1
    assert ("evict", 1) in client.log or ("evict", 0) in client.log
    for r in sched.metrics.records:
        assert r.tokens_out == 6
        assert len(sched.outputs[r.req_id]) == 6


# ------------------------------------------------------------------ router

def test_router_least_loaded_then_locality():
    from repro.runtime.serving import ReplicaRouter, ServingScheduler

    class Warm(FakeClient):
        def has_bucket(self, prompt_len):
            return True

    class Cold(FakeClient):
        def has_bucket(self, prompt_len):
            return False

    warm = ServingScheduler(Warm(num_slots=2))
    cold = ServingScheduler(Cold(num_slots=2))
    router = ReplicaRouter()
    req = _reqs([4])[0]
    # equal load: locality tie-break prefers the warm bucket
    assert router.pick([cold, warm], req) == 1
    # load dominates locality: pile backlog onto the warm replica
    warm.submit(_reqs([4, 4, 4]))
    assert router.pick([cold, warm], req) == 0


def test_multi_replica_merge_and_assignment():
    from repro.runtime.serving import (
        MultiReplicaEngine, ServingScheduler,
    )

    scheds = [ServingScheduler(FakeClient(num_slots=2)) for _ in range(2)]
    fleet = MultiReplicaEngine(scheds)
    fleet.submit(_reqs([4, 4, 4, 4]))
    metrics = fleet.run()
    assert metrics.summary(fleet.horizon())["completed"] == 4
    # least-loaded routing split the backlog evenly
    by_rep = [sum(1 for r in fleet.assignments.values() if r == i)
              for i in range(2)]
    assert by_rep == [2, 2]
    assert metrics.num_gpus == 2


# --------------------------------------------------------------- metrics

def test_summary_percentiles():
    from repro.runtime.metrics import RequestRecord, ServingMetrics

    m = ServingMetrics(num_gpus=1)
    for i in range(10):
        ttft = float(i + 1)
        m.records.append(RequestRecord(
            req_id=i, arrival=0.0, prompt_len=8, target_len=5,
            first_token_time=ttft, done_time=ttft + 4.0 * (i + 1),
            tokens_out=5,
        ))
    s = m.summary(horizon=100.0)
    # nearest-rank percentiles over ttfts 1..10
    assert s["ttft_p50_s"] == 5.0
    assert s["ttft_p95_s"] == 10.0
    assert s["ttft_p99_s"] == 10.0
    # tpot_i = 4*(i+1)/(5-1) = (i+1); same ladder
    assert s["tpot_p50_s"] == 5.0
    assert s["tpot_p99_s"] == 10.0


def test_summary_percentiles_zero_denominators():
    from repro.runtime.metrics import RequestRecord, ServingMetrics

    # no completed requests at all: keys still present, zeros
    s = ServingMetrics(num_gpus=1).summary(horizon=1.0)
    for k in ("ttft_p50_s", "ttft_p95_s", "ttft_p99_s",
              "tpot_p50_s", "tpot_p95_s", "tpot_p99_s"):
        assert s[k] == 0.0
    assert "gather_fetch_ratio" in s
    # single-token outputs: tpot undefined (no inter-token gap), ttft not
    m = ServingMetrics(num_gpus=1)
    m.records.append(RequestRecord(
        req_id=0, arrival=0.0, prompt_len=8, target_len=1,
        first_token_time=2.0, done_time=2.0, tokens_out=1,
    ))
    s = m.summary(horizon=1.0)
    assert s["ttft_p50_s"] == 2.0
    assert s["tpot_p50_s"] == 0.0


def test_admission_counters_in_summary():
    from repro.runtime.metrics import ServingMetrics

    m = ServingMetrics(num_gpus=1)
    m.record_admission("admitted", 3)
    m.record_admission("rejected")
    s = m.summary(horizon=1.0)
    assert s["admission"] == {"admitted": 3, "rejected": 1}


# ---------------------------------------------- modeled replicas (roofline)

def _modeled_fleet(fetch, straggle=True):
    import dataclasses

    from repro.configs import get_arch
    from repro.core.strategy import GatherPolicy, PolicyTable
    from repro.runtime.serving import (
        ModeledReplicaClient, MultiReplicaEngine, ServingScheduler,
        WorkloadConfig, synthesize_workload,
    )
    from repro.runtime.simulator import SimConfig

    cfg = get_arch("deepseek-r1")
    cfg = dataclasses.replace(
        cfg, name="r1-serving-test", num_layers=6,
        moe=dataclasses.replace(cfg.moe, first_dense=1),
    )
    table = PolicyTable(
        default=GatherPolicy(layout="split"),
        families=(("moe_experts", GatherPolicy(
            layout="split", fetch=fetch,
            cache_budget=128 if fetch == "sync_free" else 0)),),
    )
    scheds = []
    for i in range(2):
        sim = SimConfig(
            cfg=cfg, ctx_gpus=2, gen_gpus=8, ctx_mode="dwdp",
            gen_mode="dwdp", gen_batch=8, gen_policies=table,
            predict_hit_rate=0.9, cache_hit_rate=0.5,
            isl_max=8192, osl=1024,
            straggler_ranks=1 if (straggle and i == 1) else 0,
            straggler_slowdown=2.5,
        )
        scheds.append(ServingScheduler(
            ModeledReplicaClient(sim, num_slots=8)
        ))
    fleet = MultiReplicaEngine(scheds)
    wl = WorkloadConfig(num_requests=16, isl_buckets=(4096, 8192),
                        isl_weights=(0.3, 0.7), osl=64, seed=5)
    fleet.submit(synthesize_workload(wl))
    metrics = fleet.run()
    return fleet, metrics.summary(fleet.horizon())


def test_modeled_straggler_replica_is_independent():
    fleet, _ = _modeled_fleet("demand", straggle=True)
    healthy, straggler = fleet.schedulers
    # the straggler's clock runs long; the healthy replica is untouched
    assert straggler.t > 1.5 * healthy.t
    ref, _ = _modeled_fleet("demand", straggle=False)
    assert abs(ref.schedulers[0].t - healthy.t) < 1e-9


def test_modeled_syncfree_beats_demand():
    sf = _modeled_fleet("sync_free")[1]
    dm = _modeled_fleet("demand")[1]
    assert sf["completed"] == dm["completed"] == 16
    assert sf["tps_per_gpu"] >= 1.05 * dm["tps_per_gpu"]
    assert sf["mean_tps_user"] >= dm["mean_tps_user"]


# ------------------------------------------------- served routing traces

def test_from_served_trace_shapes_and_rows():
    from repro.core.traces import from_served_trace

    steps, ranks, E, k = 6, 4, 16, 2
    rng = np.random.default_rng(0)
    bm = np.zeros((steps, ranks, E), bool)
    for t in range(steps):
        for r in range(ranks):
            bm[t, r, rng.choice(E, size=k, replace=False)] = True
    tr = from_served_trace(bm, top_k=k)
    assert tr.ndim == 3 and tr.shape[0] == steps and tr.shape[2] == k
    assert tr.dtype == np.int32
    assert tr.min() >= 0 and tr.max() < E
    # every routed expert appears in its step's rows
    for t in range(steps):
        routed = set(np.flatnonzero(bm[t].any(axis=0)))
        assert routed <= set(tr[t].ravel())
    # deterministic
    np.testing.assert_array_equal(tr, from_served_trace(bm, top_k=k))
    # (steps, E) single-rank shorthand accepted
    tr1 = from_served_trace(bm[:, 0], top_k=k)
    assert tr1.shape[0] == steps and tr1.shape[2] == k


def test_from_served_trace_pads_without_dup_rows():
    from repro.core.traces import from_served_trace

    # one hot step sizes the row span; quiet steps pad with distinct ids
    bm = np.zeros((3, 2, 8), bool)
    bm[0, 0, [0, 1, 2, 3]] = True   # 4 experts -> 2 rows of top_k=2
    bm[1, 0, 5] = True
    bm[2, 1, [6, 7]] = True
    tr = from_served_trace(bm, top_k=2)
    for t in range(3):
        for row in tr[t]:
            assert len(set(row.tolist())) == len(row)  # no dup in a row


def test_served_fixture_predictor_hit_rate():
    """The committed fixture: REAL routed bitmaps recorded from a live
    sync-free (2, 4) engine through the serving scheduler
    (tests/fixtures/record_served_trace.py). The mirrored predictor must
    keep its speculative hit rate on real served routing, not just on
    synthetic traces."""
    from repro.core.traces import from_served_trace, predictor_hit_rate

    bm = np.load(FIXTURE)["bitmaps"]
    assert bm.ndim == 3 and bm.shape[1] == 8 and bm.shape[2] == 32
    assert bm.shape[0] >= 20  # enough decode steps to warm the EMA
    trace = from_served_trace(bm, top_k=2)
    hit = predictor_hit_rate(trace, num_experts=32, subgroup_size=4,
                             budget=8)
    assert hit >= 0.9, f"sync-free predictor hit rate {hit:.3f} on the " \
                       "served fixture fell below 0.9"


# ------------------------------------------------------- committed bench

def test_committed_serving_sweep_acceptance():
    """The acceptance gates, asserted on the committed JSON (CI
    regenerates it and diffs, so this is the contract of record): >= 4
    fixed-TPS/user points in the paper's 20-100 band, sync-free >= 1.05x
    demand TPS/GPU at every point, and every point within 2x of the
    pareto_sweep modeled frontier."""
    with open(BENCH_JSON) as f:
        data = json.load(f)
    rows = data["rows"]
    assert len(rows) >= 4
    for r in rows:
        assert 20.0 <= r["tps_user"] <= 100.0
        assert r["syncfree_vs_demand"] >= 1.05, r
        assert 0.5 <= r["measured_vs_modeled"] <= 2.0, r
    cfg = data["config"]
    assert cfg["replicas"] == 2
    assert cfg["straggler"]["slowdown"] > 1.0
    assert len(cfg["isl_buckets"]) >= 2  # skewed-ISL workload


def test_bench_diff_guard():
    import importlib.util

    spec = importlib.util.spec_from_file_location(
        "bench_diff",
        os.path.join(os.path.dirname(__file__), "..", "benchmarks",
                     "bench_diff.py"),
    )
    mod = importlib.util.module_from_spec(spec)
    spec.loader.exec_module(mod)
    base = {"rows": [{"tps_user": 30.0, "syncfree_tps_per_gpu": 100.0,
                      "demand_tps_per_gpu": 20.0}]}

    def fresh(**over):
        row = dict(base["rows"][0], **over)
        return {"rows": [row]}

    import unittest.mock as mock

    def run(fresh_doc, committed_doc=base):
        with mock.patch.object(mod, "_committed",
                               return_value=committed_doc), \
             mock.patch("builtins.open",
                        mock.mock_open(read_data=json.dumps(fresh_doc))):
            return mod.diff_bench("BENCH_x.json", 0.10)

    assert run(fresh()) == []                                  # unchanged
    assert run(fresh(syncfree_tps_per_gpu=95.0)) == []         # within tol
    assert run(fresh(syncfree_tps_per_gpu=150.0)) == []        # improved
    assert len(run(fresh(syncfree_tps_per_gpu=80.0))) == 1     # regressed
    assert run(fresh(tps_user=31.0)) == []                     # re-gridded
    assert run(fresh(), committed_doc=None) == []              # new bench


# ----------------------------------- live engine: buckets + bitwise equiv

def test_ctx_prefill_buckets_zero_recompile():
    """Warmup pre-compiles every pow2 prefill bucket; mixed-length
    serving then never traces (the PolicyVariantCache compiles counter
    stays flat)."""
    import jax.numpy as jnp  # noqa: F401  (ensures jax is importable)

    from repro.configs.base import ArchConfig, MoEConfig
    from repro.launch.serve import build_engine

    cfg = ArchConfig(
        name="bucket-test", family="moe", num_layers=2, d_model=32,
        num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=64,
        moe=MoEConfig(num_experts=4, top_k=2, d_ff=48),
    )
    engine, _ = build_engine(
        cfg, prefill_len=16, prefill_buckets=(8, 16), cache_len=32,
        max_batch=2, gen_mode="dep",
    )
    assert engine.ctx.prefill_lens == (8, 16)
    engine.warmup()
    compiled = engine.ctx.variants.compiles()
    assert compiled >= 2  # one forward per bucket
    rng = np.random.default_rng(0)
    for length in (8, 16, 8, 16, 8):
        toks = rng.integers(0, cfg.vocab_size, length).astype(np.int32)
        engine.ctx.prefill(engine.params, toks)
    assert engine.ctx.variants.compiles() == compiled  # zero recompiles
    with pytest.raises(AssertionError):
        engine.ctx.prefill(engine.params, np.zeros(12, np.int32))
    with pytest.raises(ValueError):
        build_engine(cfg, prefill_len=16, prefill_buckets=(12,),
                     cache_len=32, max_batch=2)


ROLLING_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import warnings; warnings.filterwarnings("ignore")
import json
import jax, numpy as np
from repro.configs.base import ArchConfig, MoEConfig
from repro.launch.serve import build_engine
from repro.runtime.serving import (
    LiveReplicaClient, ServedRequest, ServingScheduler,
)

CFG = ArchConfig(
    name="rolling-test", family="moe", num_layers=4, d_model=32,
    num_heads=2, num_kv_heads=2, head_dim=16, d_ff=0, vocab_size=128,
    moe=MoEConfig(num_experts=20, top_k=2, d_ff=48),
)

def serve(epoch_mode):
    engine, _ = build_engine(
        CFG, mesh_shape=(2, 4), prefill_len=8, cache_len=48, max_batch=4,
        gen_mode="dwdp",
        policy={"moe_experts": "split:sync_free:allgather:4:4:8"},
    )
    client = LiveReplicaClient.from_engine(engine)
    sched = ServingScheduler(client, epoch_mode=epoch_mode)
    rng = np.random.default_rng(0)
    # unequal lengths so rolling admission interleaves mid-batch
    reqs = [
        ServedRequest(
            req_id=i,
            prompt_len=8,
            target_len=[4, 4, 8, 8, 12, 12][i],
            arrival=0.0,
            tokens=rng.integers(0, CFG.vocab_size, 8).astype(np.int32),
        )
        for i in range(6)
    ]
    sched.submit(reqs)
    sched.run()
    outputs = {rid: list(map(int, toks))
               for rid, toks in sched.outputs.items()}
    return outputs, sched.steps, engine

def admit_preserves_pred(engine):
    # the shared sync-free predictor state must survive an admit
    # BITWISE: a mid-decode admission must not flush what the other
    # slots' speculative fetches are hitting
    rng = np.random.default_rng(9)
    toks = rng.integers(0, CFG.vocab_size, 8).astype(np.int32)
    first, state = engine.ctx.prefill(engine.params, toks)
    before = [np.asarray(x).copy()
              for x in jax.tree.leaves(engine.gen.state["pred"])]
    engine.gen.admit(0, 99, first, state)
    after = [np.asarray(x)
             for x in jax.tree.leaves(engine.gen.state["pred"])]
    return (len(before) > 0 and len(before) == len(after)
            and all(np.array_equal(a, b)
                    for a, b in zip(before, after)))

rolling, steps_r, eng = serve(epoch_mode=False)
epoch, steps_e, _ = serve(epoch_mode=True)
results = {
    "match": rolling == epoch,
    "admit_preserves_pred": bool(admit_preserves_pred(eng)),
    "rolling_steps": steps_r,
    "epoch_steps": steps_e,
    "n_requests": len(rolling),
    "lens_ok": all(len(v) == [4, 4, 8, 8, 12, 12][k]
                   for k, v in rolling.items()),
}
print("RESULT::" + json.dumps(results))
"""


@pytest.mark.slow
def test_rolling_admission_bitwise_vs_epoch_2x4():
    """Acceptance: served token streams under continuous batching are
    BITWISE identical to fixed-slot (epoch) serving on a (2, 4) mesh —
    admit/release interleavings must not perturb other slots' decode
    (KV residency, sync-free predictor state)."""
    env = dict(os.environ, PYTHONPATH=SRC)
    out = subprocess.run(
        [sys.executable, "-c", ROLLING_SCRIPT],
        capture_output=True, text=True, env=env, timeout=600,
    )
    assert out.returncode == 0, out.stderr[-3000:]
    line = [l for l in out.stdout.splitlines()
            if l.startswith("RESULT::")][-1]
    res = json.loads(line[len("RESULT::"):])
    assert res["match"], res
    assert res["admit_preserves_pred"]
    assert res["n_requests"] == 6 and res["lens_ok"]
    assert res["rolling_steps"] < res["epoch_steps"]
