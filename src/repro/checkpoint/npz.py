"""npz-based sharded checkpointing.

Each pytree leaf is stored under its "/".join(path) key; large leaves are
split into row-chunks (``max_chunk_bytes``) so a multi-hundred-GB expert
bank streams to disk without a full-tensor host copy. Structure and dtype
metadata ride along so ``load_pytree`` restores exactly.
"""
from __future__ import annotations

import json
import os
from typing import Any

import jax
import numpy as np

PyTree = Any
_META = "__tree_meta__"


def _flatten_with_paths(tree: PyTree) -> list[tuple[str, Any]]:
    flat, _ = jax.tree_util.tree_flatten_with_path(tree)
    out = []
    for path, leaf in flat:
        key = "/".join(
            str(getattr(p, "key", getattr(p, "idx", p))) for p in path
        )
        out.append((key, leaf))
    return out


def save_pytree(path: str, tree: PyTree, *, max_chunk_bytes: int = 1 << 30) -> None:
    os.makedirs(os.path.dirname(path) or ".", exist_ok=True)
    arrays: dict[str, np.ndarray] = {}
    meta: dict[str, dict] = {}
    for key, leaf in _flatten_with_paths(tree):
        arr = np.asarray(jax.device_get(leaf))
        nbytes = arr.nbytes
        if nbytes > max_chunk_bytes and arr.ndim >= 1 and arr.shape[0] > 1:
            rows_per = max(1, int(max_chunk_bytes // max(1, nbytes // arr.shape[0])))
            chunks = [
                arr[i : i + rows_per] for i in range(0, arr.shape[0], rows_per)
            ]
            for ci, c in enumerate(chunks):
                arrays[f"{key}@chunk{ci}"] = c
            meta[key] = {"chunks": len(chunks), "dtype": str(arr.dtype)}
        else:
            arrays[key] = arr
            meta[key] = {"chunks": 0, "dtype": str(arr.dtype)}
    treedef = jax.tree_util.tree_structure(tree)
    arrays[_META] = np.frombuffer(
        json.dumps({"meta": meta, "treedef": str(treedef)}).encode(), np.uint8
    )
    np.savez(path, **arrays)


def load_pytree(path: str, like: PyTree) -> PyTree:
    """Restore into the structure of ``like`` (shapes/dtypes validated)."""
    with np.load(path) as data:
        blob = json.loads(bytes(data[_META].tobytes()).decode())
        meta = blob["meta"]

        def read(key):
            info = meta[key]
            if info["chunks"]:
                return np.concatenate(
                    [data[f"{key}@chunk{i}"] for i in range(info["chunks"])]
                )
            return data[key]

        leaves = []
        for key, ref_leaf in _flatten_with_paths(like):
            arr = read(key)
            assert arr.shape == tuple(ref_leaf.shape), (
                key, arr.shape, ref_leaf.shape,
            )
            leaves.append(arr.astype(ref_leaf.dtype))
        treedef = jax.tree_util.tree_structure(like)
        return jax.tree_util.tree_unflatten(treedef, leaves)
