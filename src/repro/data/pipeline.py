"""Synthetic token pipeline: deterministic host-side feed with document
packing, next-token label shifting, and per-shard slicing so each data
host only materializes its slice of the global batch.

Documents follow a Zipfian unigram draw seeded per document id, so loss
curves are reproducible run-to-run and across shardings — good enough to
exercise the training path end to end (the paper's technique is about
inference parallelism; the data layer just has to be real and sharded).
"""
from __future__ import annotations

import dataclasses
from typing import Iterator

import numpy as np


@dataclasses.dataclass
class SyntheticTextDataset:
    vocab_size: int
    mean_doc_len: int = 512
    seed: int = 0
    zipf_a: float = 1.3

    def document(self, doc_id: int) -> np.ndarray:
        rng = np.random.default_rng((self.seed << 32) ^ doc_id)
        length = max(8, int(rng.exponential(self.mean_doc_len)))
        # Zipf over the vocab, clipped; token 0 reserved as BOS
        toks = rng.zipf(self.zipf_a, size=length) % (self.vocab_size - 1) + 1
        toks[0] = 0
        return toks.astype(np.int32)

    def documents(self, start: int = 0) -> Iterator[np.ndarray]:
        i = start
        while True:
            yield self.document(i)
            i += 1


def pack_documents(
    docs: Iterator[np.ndarray], seq_len: int
) -> Iterator[np.ndarray]:
    """Concatenate documents into fixed seq_len+1 rows (for label shift)."""
    buf = np.empty(0, np.int32)
    need = seq_len + 1
    for d in docs:
        buf = np.concatenate([buf, d])
        while len(buf) >= need:
            yield buf[:need]
            buf = buf[need:]


def make_train_batches(
    vocab_size: int,
    seq_len: int,
    global_batch: int,
    *,
    shard: int = 0,
    num_shards: int = 1,
    seed: int = 0,
) -> Iterator[dict]:
    """Yield {"tokens": (B_local, S), "labels": (B_local, S)} batches.

    Each shard draws a disjoint document stream (striped by shard id), the
    standard host-sharded input layout for pjit'd training.
    """
    assert global_batch % num_shards == 0
    b_local = global_batch // num_shards
    ds = SyntheticTextDataset(vocab_size, seed=seed + shard)
    rows = pack_documents(ds.documents(start=shard), seq_len)
    while True:
        block = np.stack([next(rows) for _ in range(b_local)])
        yield {
            "tokens": block[:, :-1].copy(),
            "labels": block[:, 1:].copy(),
        }
