from repro.data.pipeline import SyntheticTextDataset, make_train_batches, pack_documents

__all__ = ["SyntheticTextDataset", "make_train_batches", "pack_documents"]
