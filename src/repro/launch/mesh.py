"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the
first device query.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def _mesh(shape, axes):
    """A mesh of ``prod(shape)`` devices. When the shape covers every
    visible device this is :func:`repro.compat.make_mesh`; a SMALLER
    shape builds a SUB-mesh over the first ``prod(shape)`` devices —
    what a rank-death standby replica runs on (the shrunk ``G'-1``
    subgroup excludes the quarantined device)."""
    import math

    n = math.prod(shape)
    devices = jax.devices()
    if n == len(devices):
        return make_mesh(shape, axes)
    if n > len(devices):
        raise ValueError(
            f"mesh shape {shape} needs {n} devices; only "
            f"{len(devices)} visible"
        )
    import numpy as np
    from jax.sharding import Mesh

    return Mesh(np.asarray(devices[:n]).reshape(shape), axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU smoke tests (axis names match production)."""
    return _mesh((data, model), ("data", "model"))
