"""Production meshes.

Functions (never module-level constants) so importing this module never
touches jax device state — the dry-run must set XLA_FLAGS before the
first device query.
"""
from __future__ import annotations

import jax

from repro.compat import make_mesh


def _mesh(shape, axes):
    return make_mesh(shape, axes)


def make_production_mesh(*, multi_pod: bool = False):
    shape = (2, 16, 16) if multi_pod else (16, 16)
    axes = ("pod", "data", "model") if multi_pod else ("data", "model")
    return _mesh(shape, axes)


def mesh_sizes(mesh) -> dict[str, int]:
    return dict(zip(mesh.axis_names, mesh.devices.shape))


def make_smoke_mesh(data: int = 1, model: int = 1):
    """Tiny mesh for CPU smoke tests (axis names match production)."""
    return _mesh((data, model), ("data", "model"))
