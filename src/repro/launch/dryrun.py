import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=512"

"""Multi-pod dry-run: prove every (arch x shape x mesh x mode) lowers and
compiles on the production mesh, and extract the §Roofline terms from the
compiled artifact. No arrays are ever materialized — params, batches and
decode state are ShapeDtypeStructs.

Usage:
    python -m repro.launch.dryrun --arch yi-9b --shape prefill_32k
    python -m repro.launch.dryrun --all --mesh single --out dryrun.jsonl
"""

import argparse
import dataclasses
import json
import sys
import time
import traceback

import jax
import jax.numpy as jnp

from repro.analysis.roofline_report import report_from_lowered
from repro.configs import ASSIGNED_ARCHS, get_arch, get_shape, SHAPES
from repro.configs.base import ArchConfig, BlockKind, InputShape
from repro.core import execution
from repro.core.strategy import PolicyTable, make_execution_plan
from repro.launch.mesh import make_production_mesh, mesh_sizes
from repro.models.cache import init_decode_state
from repro.models.transformer import build_model
from repro.optim.adamw import AdamWState


def needs_long_variant(cfg: ArchConfig, shape: InputShape) -> bool:
    """Pure full-attention archs run long_500k as their sliding-window
    variant (recorded as a variant, not the paper arch — DESIGN.md §6)."""
    return shape.name == "long_500k" and all(
        k == BlockKind.GLOBAL_ATTN for k in cfg.block_pattern
    )


def default_mode(shape: InputShape) -> str:
    """Paper-faithful assignment: DWDP on context/train, DEP on decode."""
    return "dep" if shape.phase == "decode" else "dwdp"


ICI_INTENSITY = 197e12 / 200e9  # FLOP per ICI byte a chip can absorb


def optimized_policy(cfg: ArchConfig, shape: InputShape) -> dict:
    """Beyond-paper defaults distilled from EXPERIMENTS.md §Perf:

    - decode: qgather attention (weights stay sharded; move q/k/v) and,
      where bf16 storage forced wide sharding, fp8 weights+KV;
    - context/train: hybrid (DEP experts + DWDP dense) whenever the MoE
      arithmetic intensity 2*T_rank*k/E falls below the ICI roofline —
      the paper's Fig. 3 window criterion evaluated per layer family;
    - block-causal attention whenever the sequence is unsharded;
    - capacity factor 1.0; bf16 Adam moments for train.
    """
    out: dict = {"plan_kwargs": {}, "kwargs": {}, "mode": None}
    if shape.phase == "decode":
        out["mode"] = "dep"
        out["plan_kwargs"]["decode_attn"] = "qgather"
        if cfg.name == "deepseek-67b":  # bf16 residency busts 16GB
            out["kwargs"].update(
                dtype=jnp.float8_e4m3fn,
                ffn_axes_override=("model",),
                attn_axes_override=("model",),
            )
        return out
    tokens_per_rank = shape.tokens / 256
    mode = "dwdp"
    if cfg.moe is not None:
        intensity = 2 * tokens_per_rank * cfg.moe.top_k / cfg.moe.num_experts
        if intensity < ICI_INTENSITY:
            mode = "hybrid"
    out["mode"] = mode
    out["plan_kwargs"]["block_causal"] = True
    out["plan_kwargs"]["capacity_factor"] = 1.0
    if shape.phase == "train":
        out["kwargs"]["moment_dtype"] = jnp.bfloat16
    return out


def input_specs(cfg: ArchConfig, shape: InputShape, model) -> dict:
    """ShapeDtypeStruct stand-ins for every model input."""
    b, s = shape.global_batch, shape.seq_len
    i32 = jnp.int32
    if shape.phase == "decode":
        return {"token": jax.ShapeDtypeStruct((b, 1), i32)}
    specs: dict = {}
    if cfg.modality == "text":
        specs["tokens"] = jax.ShapeDtypeStruct((b, s), i32)
    else:
        # modality frontends are stubbed: precomputed frame/patch embeddings
        specs["embeds"] = jax.ShapeDtypeStruct((b, s, cfg.d_model), jnp.bfloat16)
    if shape.phase == "train":
        specs["labels"] = jax.ShapeDtypeStruct((b, s), i32)
    return specs


def dryrun_one(
    arch: str,
    shape_name: str,
    *,
    multi_pod: bool = False,
    mode: str | None = None,
    prefetch: str = "allgather",
    verbose: bool = True,
    dtype=None,
    plan_kwargs: dict | None = None,
    moment_dtype=None,
    **geom_overrides,
):
    cfg = get_arch(arch)
    shape = get_shape(shape_name)
    mode = mode or default_mode(shape)
    mesh = make_production_mesh(multi_pod=multi_pod)
    sizes = mesh_sizes(mesh)
    long_variant = needs_long_variant(cfg, shape)
    model = build_model(
        cfg,
        sizes,
        dtype=dtype if dtype is not None else jnp.bfloat16,
        train=(shape.phase == "train"),
        long_variant=long_variant,
        **geom_overrides,
    )
    pk = dict(plan_kwargs or {})
    if "policy" not in pk:
        # the flat prefetch= convenience arg and any legacy flat knobs in
        # plan_kwargs (perf.py experiments pass num_slices=) fold into one
        # uniform table — never forwarded as the deprecated aliases
        pk["policy"] = PolicyTable.uniform(
            transport=pk.pop("prefetch", prefetch),
            num_slices=pk.pop("num_slices", 4),
            layout=pk.pop("weight_layout", "split"),
            fetch=pk.pop("expert_fetch", "all"),
            budget=pk.pop("demand_budget", 0),
            cache_budget=pk.pop("cache_budget", 0),
        )
    xp = make_execution_plan(model, shape, sizes, mode=mode, **pk)
    step = execution.make_step_fn(model, xp, mesh)

    params = model.param_struct()
    batch = input_specs(cfg, shape, model)
    t0 = time.time()
    if shape.phase == "train":
        mdt = moment_dtype or jnp.float32
        opt = jax.eval_shape(
            lambda: AdamWState(
                step=jnp.int32(0),
                m=jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
                v=jax.tree.map(lambda p: jnp.zeros(p.shape, mdt), params),
            )
        )
        lowered = step.lower(params, opt, batch, jax.ShapeDtypeStruct((), jnp.float32))
    elif shape.phase == "prefill":
        lowered = step.lower(params, batch)
    else:
        state = jax.eval_shape(
            lambda: execution.attach_predict_state(
                init_decode_state(
                    model, shape.global_batch, shape.seq_len
                ),
                model, xp,
            )
        )
        lowered = step.lower(params, batch, state)
    compiled = lowered.compile()
    dt = time.time() - t0

    mesh_name = "2x16x16" if multi_pod else "16x16"
    rep = report_from_lowered(
        lowered,
        compiled,
        arch=arch + ("+swa" if long_variant else ""),
        shape=shape,
        cfg=cfg,
        mesh_name=mesh_name,
        mode=mode,
        chips=int(jax.device_count()) if multi_pod else 256,
        geom=model.geom,
        xp=xp,
        dtype_bytes=jnp.dtype(model.dtype).itemsize,
        opt_bytes_per_param=(
            jnp.dtype(model.dtype).itemsize
            + 2 * jnp.dtype(moment_dtype or jnp.float32).itemsize
        ),
    )
    row = rep.row()
    row["compile_s"] = round(dt, 1)
    row["prefetch"] = prefetch
    row["geom"] = {
        "expert_axes": model.geom.expert_axes,
        "moe_exec": model.geom.moe_exec,
        "ffn_axes": model.geom.ffn_axes,
        "attn_axes": model.geom.attn_axes,
        "batch_axes": xp.batch_axes,
        "seq_axes": xp.seq_axes,
    }
    if verbose:
        mem = compiled.memory_analysis()
        print(f"== {arch} x {shape_name} x {mesh_name} [{mode}/{prefetch}] ==")
        print("  memory_analysis:", mem)
        print("  roofline:", json.dumps(row, default=str))
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default=None)
    ap.add_argument("--shape", default=None)
    ap.add_argument("--mesh", choices=["single", "multi", "both"], default="single")
    ap.add_argument("--mode", default=None, choices=[None, "dwdp", "dep", "replicated"])
    ap.add_argument("--prefetch", default="allgather")
    ap.add_argument("--all", action="store_true")
    ap.add_argument("--optimized", action="store_true",
                    help="apply the beyond-paper §Perf policy instead of "
                         "the paper-faithful defaults")
    ap.add_argument("--out", default=None)
    args = ap.parse_args(argv)

    archs = ASSIGNED_ARCHS if (args.all or not args.arch) else [args.arch]
    shapes = list(SHAPES) if (args.all or not args.shape) else [args.shape]
    meshes = {"single": [False], "multi": [True], "both": [False, True]}[args.mesh]

    rows, failures = [], []
    for arch in archs:
        for shape_name in shapes:
            for multi in meshes:
                try:
                    extra: dict = {"mode": args.mode}
                    if args.optimized:
                        pol = optimized_policy(
                            get_arch(arch), get_shape(shape_name)
                        )
                        extra = {
                            "mode": args.mode or pol["mode"],
                            "plan_kwargs": pol["plan_kwargs"],
                            **pol["kwargs"],
                        }
                    rows.append(
                        dryrun_one(
                            arch,
                            shape_name,
                            multi_pod=multi,
                            prefetch=args.prefetch,
                            **extra,
                        )
                    )
                except Exception as e:  # noqa: BLE001 — report, keep going
                    traceback.print_exc()
                    failures.append((arch, shape_name, multi, repr(e)))
    if args.out:
        with open(args.out, "a") as f:
            for r in rows:
                f.write(json.dumps(r, default=str) + "\n")
    print(f"\n{len(rows)} ok, {len(failures)} failed")
    for f in failures:
        print("FAILED:", f)
    return 1 if failures else 0


if __name__ == "__main__":
    sys.exit(main())
