"""Serving driver: disaggregated context/generation demo on live arrays.

``python -m repro.launch.serve --arch yi-9b --requests 8`` runs the full
stack at reduced scale: DWDP context server (prefill + KV capture), slot
based continuous-batching generation server, and reports TPS/TTFT.
"""
from __future__ import annotations

import argparse

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_variant
from repro.models.transformer import build_model
from repro.runtime.engine import (
    ContextServer,
    DisaggregatedEngine,
    GenerationServer,
    Request,
)


def build_engine(
    cfg,
    *,
    mesh_shape=(1, 1),
    prefill_len: int = 64,
    cache_len: int = 128,
    max_batch: int = 4,
    ctx_mode: str = "dwdp",
    gen_mode: str = "dep",
    prefetch: str = "allgather",
    weight_layout: str | None = None,
    capacity_from: str = "local",
    expert_fetch: str = "all",
    demand_budget: int = 0,
    dtype=jnp.float32,
    seed: int = 0,
):
    from repro.launch.mesh import _mesh
    mesh = _mesh(mesh_shape, ("data", "model"))
    sizes = {"data": mesh_shape[0], "model": mesh_shape[1]}
    model = build_model(cfg, sizes, dtype=dtype)
    params = model.init_params(jax.random.key(seed))
    ctx = ContextServer(
        model, mesh, sizes, mode=ctx_mode, prefill_len=prefill_len,
        cache_len=cache_len, prefetch=prefetch,
        weight_layout=weight_layout, capacity_from=capacity_from,
        expert_fetch=expert_fetch, demand_budget=demand_budget,
    )
    gen = GenerationServer(
        model, mesh, sizes, mode=gen_mode, max_batch=max_batch,
        cache_len=cache_len,
        weight_layout=weight_layout, capacity_from=capacity_from,
        expert_fetch=expert_fetch, demand_budget=demand_budget,
    )
    return DisaggregatedEngine(params, ctx, gen), model


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--output-len", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--ctx-mode", default="dwdp")
    ap.add_argument("--weight-layout", default="split",
                    choices=["merged", "split"],
                    help="gathered-weight representation for every DWDP "
                         "family (experts, attention, dense FFN)")
    ap.add_argument("--capacity-from", default="local",
                    choices=["local", "global"],
                    help="MoE capacity derivation: local shard count or "
                         "layout-invariant per-row global shape")
    ap.add_argument("--gen-mode", default="dep", choices=["dep", "dwdp"],
                    help="generation-server strategy (dwdp shards the "
                         "weights and gathers per layer — the mode the "
                         "on-demand expert fetch accelerates)")
    ap.add_argument("--expert-fetch", default="all",
                    choices=["all", "demand"],
                    help="MoE expert-gather selection: every remote "
                         "expert, or route-before-gather demand fetch of "
                         "only the activated ones (exact fallback on "
                         "budget overflow)")
    ap.add_argument("--demand-budget", type=int, default=0,
                    help="per-peer demand-fetch row budget (0 = auto: 2x "
                         "the expected distinct-expert coverage)")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: reduced smoke)")
    args = ap.parse_args(argv)
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced_variant(cfg)
    engine, model = build_engine(
        cfg,
        prefill_len=args.prefill_len,
        cache_len=args.prefill_len + args.output_len,
        max_batch=args.max_batch,
        ctx_mode=args.ctx_mode,
        gen_mode=args.gen_mode,
        weight_layout=args.weight_layout,
        capacity_from=args.capacity_from,
        expert_fetch=args.expert_fetch,
        demand_budget=args.demand_budget,
    )
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(
            Request(
                req_id=i,
                tokens=rng.integers(
                    0, cfg.vocab_size, args.prefill_len
                ).astype(np.int32),
                target_len=args.output_len,
            )
        )
    steps = args.output_len * (args.requests // args.max_batch + 2)
    metrics = engine.run(steps)
    print("summary:", metrics.summary(horizon=float(steps)))
    for rid, toks in list(engine.outputs.items())[:4]:
        print(f"req {rid}: {toks[:10]}{'...' if len(toks) > 10 else ''}")


if __name__ == "__main__":
    main()
