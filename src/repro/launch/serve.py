"""Serving driver: disaggregated context/generation demo on live arrays.

``python -m repro.launch.serve --arch yi-9b --requests 8`` runs the full
stack at reduced scale: DWDP context server (prefill + KV capture), slot
based continuous-batching generation server, and reports TPS/TTFT.

Gather policies are configured per weight family (the GatherPolicy API):

    --policy moe_experts=split:demand:ring_sliced \
    --policy attn_qkv=merged:all:allgather        \
    --policy dense_ffn=split:all:ring

or ``--policy-file policies.json`` (the ``PolicyTable.to_dict`` JSON
shape, ``{"family_or_default": "layout[:fetch[:transport...]]"}``), or
``--policy auto`` for the roofline-guided resolver. Expert fetch modes:
``all`` (every remote expert every layer), ``demand``
(route-before-gather), ``predictive`` (speculative layer-ahead round
+ cross-step residency cache — ``--cache-budget`` rows per layer; auto
picks it at decode shapes where the overlap pays) and ``sync_free``
(mirrored-predictor decode: the speculative round ships zero index
metadata; see docs/syncfree.md). The pre-PolicyTable flags
(``--weight-layout`` / ``--expert-fetch`` / ``--demand-budget`` /
``--cache-budget``) keep working as the uniform-table spelling and may
not be combined with ``--policy``.

Fault tolerance (docs/robustness.md): ``--fault-spec`` injects
deterministic peer faults into the fetch rounds (outputs stay
bitwise-exact through the checksum-repair path), ``--validate-fetch``
turns on validation without injection, and the ``--health-*`` knobs
tune the HealthMonitor that walks the gather policy down the
sync_free/predictive -> per-peer exclusion -> demand -> all-gather
ladder under persistent peer badness (and back up on recovery).
"""
from __future__ import annotations

import argparse
import json

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import get_arch, reduced_variant
from repro.core.strategy import PolicyTable
from repro.models.transformer import build_model
from repro.runtime.engine import (
    ContextServer,
    DisaggregatedEngine,
    GenerationServer,
    HealthMonitor,
    OnlinePolicyScheduler,
    Request,
)


def parse_policy_flags(flags, policy_file=None):
    """``--policy`` / ``--policy-file`` -> a PolicyTable, ``"auto"``,
    ``"auto-online"``, or None (nothing given). Each ``--policy`` value
    is either a standalone literal (``auto`` — roofline-resolved once at
    boot; ``auto-online`` — additionally re-resolved online between
    pre-compiled variants) or ``family=layout[:fetch[:transport
    [:num_slices[:budget]]]]``; the file is the PolicyTable JSON dict.
    Flags override file entries for the same family. Unknown families or
    values raise ``ValueError`` (argparse surfaces them as CLI
    errors)."""
    flags = list(flags or ())
    for lit in ("auto", "auto-online"):
        if lit in flags:
            if len(flags) > 1 or policy_file:
                raise ValueError(
                    f"--policy {lit} stands alone (it resolves every "
                    "family); drop the other --policy/--policy-file "
                    "arguments"
                )
            return lit
    spec: dict = {}
    if policy_file:
        with open(policy_file) as f:
            loaded = json.load(f)
        if not isinstance(loaded, dict):
            raise ValueError(
                f"--policy-file {policy_file!r} must hold a JSON object "
                "mapping families to policy specs"
            )
        spec.update(loaded)
    for flag in flags:
        if "=" not in flag:
            raise ValueError(
                f"--policy expects family=layout[:fetch[:transport...]] "
                f"or the literal 'auto'; got {flag!r}"
            )
        fam, pol = flag.split("=", 1)
        spec[fam] = pol
    if not spec:
        return None
    return PolicyTable.from_dict(spec)


def resolve_cli_policy(args) -> object:
    """Shared CLI resolution for serve-style drivers: parse --policy /
    --policy-file and reject combining them with the explicit uniform
    flags (--weight-layout / --expert-fetch / --demand-budget). Returns
    a PolicyTable, "auto", or None; raises ValueError on conflicts or
    bad specs."""
    legacy_given = [
        name for name, v in (
            ("--weight-layout", args.weight_layout),
            ("--expert-fetch", args.expert_fetch),
            ("--demand-budget", args.demand_budget),
            ("--cache-budget", getattr(args, "cache_budget", None)),
        ) if v is not None
    ]
    policy = parse_policy_flags(args.policy, args.policy_file)
    if policy is not None and legacy_given:
        raise ValueError(
            f"conflicting --policy and uniform flags "
            f"{', '.join(legacy_given)} — pass only --policy"
        )
    return policy


def build_engine(
    cfg,
    *,
    mesh_shape=(1, 1),
    prefill_len: int = 64,
    prefill_buckets: tuple = (),
    cache_len: int = 128,
    max_batch: int = 4,
    ctx_mode: str = "dwdp",
    gen_mode: str = "dep",
    prefetch: str = "allgather",
    weight_layout: str | None = None,
    capacity_from: str = "local",
    expert_fetch: str = "all",
    demand_budget: int = 0,
    cache_budget: int = 0,
    policy=None,
    dtype=jnp.float32,
    seed: int = 0,
    fault_spec=None,
    validate_fetch: bool = False,
    health: "HealthMonitor | None" = None,
    variant_cache_size: int = 16,
    switch_interval: int = 8,
):
    from repro.launch.mesh import _mesh
    mesh = _mesh(mesh_shape, ("data", "model"))
    sizes = {"data": mesh_shape[0], "model": mesh_shape[1]}
    # seq-sharded KV capture / decode caches split the ring over up to
    # all mesh ranks: round the cache up so every shard degree divides
    n_ranks = max(1, mesh_shape[0] * mesh_shape[1])
    cache_len = -(-cache_len // n_ranks) * n_ranks
    model = build_model(cfg, sizes, dtype=dtype)
    params = model.init_params(jax.random.key(seed))
    ctx = ContextServer(
        model, mesh, sizes, mode=ctx_mode, prefill_len=prefill_len,
        prefill_buckets=prefill_buckets,
        cache_len=cache_len, prefetch=prefetch,
        weight_layout=weight_layout, capacity_from=capacity_from,
        expert_fetch=expert_fetch, demand_budget=demand_budget,
        cache_budget=cache_budget, policy=policy,
        fault_spec=fault_spec, validate_fetch=validate_fetch,
    )
    gen = GenerationServer(
        model, mesh, sizes, mode=gen_mode, max_batch=max_batch,
        cache_len=cache_len,
        weight_layout=weight_layout, capacity_from=capacity_from,
        expert_fetch=expert_fetch, demand_budget=demand_budget,
        cache_budget=cache_budget, policy=policy,
        fault_spec=fault_spec, validate_fetch=validate_fetch,
        variant_cache_size=variant_cache_size,
    )
    scheduler = None
    if policy == "auto-online":
        scheduler = OnlinePolicyScheduler(
            model, sizes, gen._shape, interval=switch_interval,
        )
    return DisaggregatedEngine(
        params, ctx, gen, health=health, scheduler=scheduler
    ), model


def run_serving(args, cfg, policy):
    """The --serving path: N live replicas (same weights, independent
    clocks) behind the least-loaded router, continuous-batching rolling
    admission, optional SLO gate. Prints the percentile summary
    (TTFT/TPOT p50/p95/p99) and the admission counters."""
    from repro.runtime.serving import (
        AdmissionController,
        LiveReplicaClient,
        MultiReplicaEngine,
        ServingScheduler,
        SLOConfig,
        WorkloadConfig,
        synthesize_workload,
    )

    if args.isl_buckets:
        buckets = tuple(
            sorted({int(b) for b in args.isl_buckets.split(",")})
        )
    else:
        buckets = (args.prefill_len,)
    slo = SLOConfig(
        target_tps_user=args.slo_tps_user,
        ttft_budget_s=args.slo_ttft,
        max_queue=args.max_queue,
    )
    gated = args.slo_tps_user or args.slo_ttft or args.max_queue
    schedulers = []
    for i in range(args.replicas):
        engine, _ = build_engine(
            cfg,
            prefill_len=max(buckets),
            prefill_buckets=buckets,
            cache_len=max(buckets) + args.output_len,
            max_batch=args.max_batch,
            ctx_mode=args.ctx_mode,
            gen_mode=args.gen_mode,
            weight_layout=args.weight_layout,
            capacity_from=args.capacity_from,
            expert_fetch=args.expert_fetch or "all",
            demand_budget=args.demand_budget or 0,
            cache_budget=args.cache_budget or 0,
            policy=policy,
            variant_cache_size=args.variant_cache_size,
            switch_interval=args.switch_interval,
        )
        client = LiveReplicaClient.from_engine(engine)
        if not args.no_warmup:
            client.warmup()
        admission = (
            AdmissionController(slo, client.step_time) if gated else None
        )
        schedulers.append(ServingScheduler(client, admission=admission))
    if not args.no_warmup:
        print(f"warmup: {args.replicas} replica(s), prefill buckets "
              f"{list(buckets)} pre-compiled")
    fleet = MultiReplicaEngine(schedulers)
    wl = WorkloadConfig(
        num_requests=args.requests,
        isl_buckets=buckets,
        osl=args.output_len,
        arrival_rate=args.arrival_rate,
    )
    fleet.submit(synthesize_workload(wl, vocab_size=cfg.vocab_size))
    metrics = fleet.run()
    s = metrics.summary(horizon=fleet.horizon())
    print("serving summary:", s)
    print("ttft p50/p95/p99:",
          s["ttft_p50_s"], s["ttft_p95_s"], s["ttft_p99_s"])
    print("tpot p50/p95/p99:",
          s["tpot_p50_s"], s["tpot_p95_s"], s["tpot_p99_s"])
    print("recovery:", {k: s[k] for k in (
        "rank_deaths", "migrated", "requeued",
        "time_to_recover_p50_s", "time_to_recover_p95_s")})
    for i, sched in enumerate(schedulers):
        n = sum(1 for r in fleet.assignments.values() if r == i)
        print(f"replica {i}: {n} request(s), {sched.steps} decode "
              f"step(s), horizon {sched.t:.3f}s")
    for rid, toks in list(schedulers[0].outputs.items())[:4]:
        print(f"req {rid}: {toks[:10]}{'...' if len(toks) > 10 else ''}")


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--requests", type=int, default=8)
    ap.add_argument("--prefill-len", type=int, default=64)
    ap.add_argument("--output-len", type=int, default=16)
    ap.add_argument("--max-batch", type=int, default=4)
    ap.add_argument("--ctx-mode", default="dwdp")
    ap.add_argument("--policy", action="append", default=None,
                    metavar="FAMILY=SPEC",
                    help="per-family gather policy (repeatable): "
                         "family=layout[:fetch[:transport[:num_slices"
                         "[:budget]]]] with families moe_experts, "
                         "attn_qkv, attn_out, dense_ffn, default — or "
                         "the literal 'auto' for the roofline-guided "
                         "resolver, or 'auto-online' to additionally "
                         "re-resolve online (phase/batch buckets + "
                         "measured hit-rate drift) switching between "
                         "pre-compiled forward variants with zero "
                         "recompiles (docs/policy_switching.md)")
    ap.add_argument("--policy-file", default=None,
                    help="JSON file mapping families to policy specs "
                         "(PolicyTable.to_dict shape); --policy flags "
                         "override file entries")
    ap.add_argument("--weight-layout", default=None,
                    choices=["merged", "split"],
                    help="uniform gathered-weight representation for "
                         "every DWDP family (the pre-PolicyTable "
                         "spelling of --policy default=LAYOUT)")
    ap.add_argument("--capacity-from", default="local",
                    choices=["local", "global"],
                    help="MoE capacity derivation: local shard count or "
                         "layout-invariant per-row global shape")
    ap.add_argument("--gen-mode", default="dep", choices=["dep", "dwdp"],
                    help="generation-server strategy (dwdp shards the "
                         "weights and gathers per layer — the mode the "
                         "on-demand expert fetch accelerates)")
    ap.add_argument("--expert-fetch", default=None,
                    choices=["all", "demand", "predictive", "sync_free"],
                    help="uniform MoE expert-gather selection (the "
                         "pre-PolicyTable spelling of --policy "
                         "moe_experts=split:FETCH); 'predictive' adds "
                         "the layer-ahead speculative round + cross-step "
                         "residency cache at decode; 'sync_free' mirrors "
                         "the predictor on every rank so the speculative "
                         "round carries zero index metadata")
    ap.add_argument("--demand-budget", type=int, default=None,
                    help="per-peer demand-fetch row budget (0 = auto: 2x "
                         "the expected distinct-expert coverage; for "
                         "predictive, the speculative/correction rounds)")
    ap.add_argument("--cache-budget", type=int, default=None,
                    help="expert rows of the predictive fetch's "
                         "cross-step residency cache per layer (0 = "
                         "cache off; --policy auto sizes it from HBM "
                         "headroom)")
    ap.add_argument("--fault-spec", default=None,
                    metavar="SPEC",
                    help="inject deterministic fetch faults, e.g. "
                         "'seed=3,drop=0.1,corrupt=0.05,peers=2|5' "
                         "(keys: seed/drop/zero/corrupt/cache/peers). "
                         "Implies payload validation; outputs stay "
                         "bitwise-exact via the repair path")
    ap.add_argument("--validate-fetch", action="store_true",
                    help="checksum-validate fetched expert rows without "
                         "injecting faults (the production hardening "
                         "switch; implied by --fault-spec)")
    ap.add_argument("--health-decay", type=float, default=0.7,
                    help="HealthMonitor per-peer fault-event EMA decay")
    ap.add_argument("--health-demote", type=float, default=0.5,
                    help="per-peer EMA above which the policy ladder "
                         "demotes (predictive -> demand -> all)")
    ap.add_argument("--health-promote", type=float, default=0.1,
                    help="all-peer EMA below which the ladder re-promotes")
    ap.add_argument("--health-dwell", type=int, default=2,
                    help="min decode steps between ladder transitions")
    ap.add_argument("--no-health", action="store_true",
                    help="disable the HealthMonitor even when validating")
    ap.add_argument("--variant-cache-size", type=int, default=16,
                    help="max pre-compiled forward variants the "
                         "generation server retains (policy tables x "
                         "exclusion sets, LRU)")
    ap.add_argument("--switch-interval", type=int, default=8,
                    help="decode steps between auto-online drift "
                         "re-resolutions (bucket boundaries re-resolve "
                         "immediately)")
    ap.add_argument("--no-warmup", action="store_true",
                    help="skip pre-compiling the scheduler's candidate "
                         "variants before serving (first switches then "
                         "pay a trace+compile on the serving path)")
    ap.add_argument("--full", action="store_true",
                    help="use the full config (default: reduced smoke)")
    serving = ap.add_argument_group(
        "serving", "continuous-batching serving path (docs/serving.md): "
        "rolling admission into decode slots as they free, SLO-aware "
        "admission control, N independent data-parallel replicas behind "
        "the least-loaded router"
    )
    serving.add_argument("--serving", action="store_true",
                         help="serve through ServingScheduler / "
                              "MultiReplicaEngine instead of the "
                              "fixed-slot engine loop")
    serving.add_argument("--replicas", type=int, default=1,
                         help="independent engine replicas (no "
                              "cross-replica synchronization; the "
                              "router balances by backlog)")
    serving.add_argument("--isl-buckets", default=None,
                         metavar="L1,L2,...",
                         help="prompt-length mix for the synthesized "
                              "workload (each a pow2 prefill bucket, "
                              "pre-compiled at warmup; default: one "
                              "bucket of --prefill-len)")
    serving.add_argument("--arrival-rate", type=float, default=0.0,
                         help="Poisson arrival rate, requests/s of "
                              "simulated queue time (0 = all requests "
                              "queued at t=0)")
    serving.add_argument("--slo-tps-user", type=float, default=0.0,
                         help="per-user decode-rate floor: admissions "
                              "projected below it queue; sustained "
                              "violation evicts-to-queue (0 = off)")
    serving.add_argument("--slo-ttft", type=float, default=0.0,
                         help="TTFT budget in seconds: queued requests "
                              "whose wait alone exceeds it are shed "
                              "(0 = off)")
    serving.add_argument("--max-queue", type=int, default=0,
                         help="queued requests beyond which arrivals "
                              "are shed (0 = unbounded)")
    args = ap.parse_args(argv)
    try:
        policy = resolve_cli_policy(args)
    except ValueError as e:
        ap.error(str(e))
    cfg = get_arch(args.arch)
    if not args.full:
        cfg = reduced_variant(cfg)
    if args.serving:
        return run_serving(args, cfg, policy)
    health = None
    if (args.fault_spec or args.validate_fetch) and not args.no_health:
        health = HealthMonitor(
            decay=args.health_decay,
            demote_threshold=args.health_demote,
            promote_threshold=args.health_promote,
            min_dwell=args.health_dwell,
        )
    engine, model = build_engine(
        cfg,
        prefill_len=args.prefill_len,
        cache_len=args.prefill_len + args.output_len,
        max_batch=args.max_batch,
        ctx_mode=args.ctx_mode,
        gen_mode=args.gen_mode,
        weight_layout=args.weight_layout,
        capacity_from=args.capacity_from,
        expert_fetch=args.expert_fetch or "all",
        demand_budget=args.demand_budget or 0,
        cache_budget=args.cache_budget or 0,
        policy=policy,
        fault_spec=args.fault_spec,
        validate_fetch=args.validate_fetch,
        health=health,
        variant_cache_size=args.variant_cache_size,
        switch_interval=args.switch_interval,
    )
    if not args.no_warmup:
        n = engine.warmup()
        print(f"warmup: {n} decode variant(s) pre-compiled")
    print("ctx policies:", engine.ctx.xp.policies.describe())
    print("gen policies:", engine.gen.xp.policies.describe())
    rng = np.random.default_rng(0)
    for i in range(args.requests):
        engine.submit(
            Request(
                req_id=i,
                tokens=rng.integers(
                    0, cfg.vocab_size, args.prefill_len
                ).astype(np.int32),
                target_len=args.output_len,
            )
        )
    steps = args.output_len * (args.requests // args.max_batch + 2)
    metrics = engine.run(steps)
    print("summary:", metrics.summary(horizon=float(steps)))
    if engine.gen.level or metrics.policy_transitions:
        print(
            f"ladder level: {engine.gen.level} ({engine.gen.fetch_label})"
        )
    if engine.scheduler is not None:
        print(
            "variant cache:", dict(engine.gen.variants.stats),
            f"entries={len(engine.gen.variants)}",
            f"signatures={engine.gen.variants.compiles()}",
        )
    for rid, toks in list(engine.outputs.items())[:4]:
        print(f"req {rid}: {toks[:10]}{'...' if len(toks) > 10 else ''}")


if __name__ == "__main__":
    main()
