"""Training driver: ``python -m repro.launch.train --arch yi-9b ...``.

Runs real steps on whatever devices exist (CPU smoke scale by default);
the production-mesh path is exercised by dryrun.py. The ~100M end-to-end
example in examples/train_small.py uses this module's ``train_loop``.
"""
from __future__ import annotations

import argparse
import dataclasses
import time

import jax
import jax.numpy as jnp

from repro.configs import get_arch, reduced_variant
from repro.configs.base import ArchConfig, InputShape
from repro.core import execution
from repro.core.strategy import PolicyTable, make_execution_plan
from repro.data import make_train_batches
from repro.models.transformer import build_model
from repro.optim import adamw_init, cosine_schedule


def train_loop(
    cfg: ArchConfig,
    *,
    steps: int = 100,
    seq_len: int = 256,
    global_batch: int = 8,
    mesh_shape: tuple[int, int] = (1, 1),
    mode: str = "dwdp",
    prefetch: str = "allgather",
    peak_lr: float = 3e-4,
    dtype=jnp.float32,
    log_every: int = 10,
    seed: int = 0,
):
    from repro.launch.mesh import _mesh
    mesh = _mesh(mesh_shape, ("data", "model"))
    sizes = {"data": mesh_shape[0], "model": mesh_shape[1]}
    model = build_model(cfg, sizes, dtype=dtype, train=True)
    shape = InputShape("train", seq_len, global_batch, "train")
    xp = make_execution_plan(model, shape, sizes, mode=mode,
                             policy=PolicyTable.uniform(transport=prefetch))
    step_fn = execution.make_step_fn(model, xp, mesh)

    params = model.init_params(jax.random.key(seed))
    opt = adamw_init(params)
    batches = make_train_batches(
        cfg.vocab_size, seq_len, global_batch, seed=seed
    )
    history = []
    t0 = time.time()
    with mesh:
        for i in range(steps):
            batch = next(batches)
            lr = cosine_schedule(
                i, peak_lr=peak_lr, warmup_steps=max(1, steps // 10),
                total_steps=steps,
            )
            batch = {k: jnp.asarray(v) for k, v in batch.items()}
            params, opt, metrics = step_fn(
                params, opt, batch, jnp.float32(lr)
            )
            loss = float(metrics["loss"])
            history.append(loss)
            if log_every and i % log_every == 0:
                tok_s = (i + 1) * shape.tokens / (time.time() - t0)
                print(
                    f"step {i:5d} loss {loss:8.4f} aux "
                    f"{float(metrics['aux_loss']):.4f} tok/s {tok_s:,.0f}"
                )
    return params, opt, history


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", required=True)
    ap.add_argument("--steps", type=int, default=100)
    ap.add_argument("--seq-len", type=int, default=256)
    ap.add_argument("--global-batch", type=int, default=8)
    ap.add_argument("--mode", default="dwdp")
    ap.add_argument("--reduced", action="store_true",
                    help="train the 2-layer smoke variant")
    args = ap.parse_args(argv)
    cfg = get_arch(args.arch)
    if args.reduced:
        cfg = reduced_variant(cfg)
    _, _, hist = train_loop(
        cfg,
        steps=args.steps,
        seq_len=args.seq_len,
        global_batch=args.global_batch,
        mode=args.mode,
    )
    print(f"final loss {hist[-1]:.4f} (from {hist[0]:.4f})")


if __name__ == "__main__":
    main()
