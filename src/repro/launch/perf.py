import os
os.environ.setdefault(
    "XLA_FLAGS", "--xla_force_host_platform_device_count=512"
)

"""§Perf hillclimbing driver: named experiments over dryrun_one.

Each experiment is (pair, variation kwargs); the driver lowers, compiles,
extracts roofline terms and appends hypothesis/result rows to
results/perf_log.jsonl. The narrative lives in EXPERIMENTS.md §Perf.

    python -m repro.launch.perf --list
    python -m repro.launch.perf ds67b_decode_baseline ds67b_decode_fp8
"""

import argparse
import json
import sys

from repro.launch.dryrun import dryrun_one

try:
    import jax.numpy as jnp
except Exception:  # pragma: no cover
    jnp = None


EXPERIMENTS: dict[str, dict] = {
    # ---- pair 1: deepseek-67b x decode_32k (most collective-bound) -------
    "ds67b_decode_baseline": dict(
        arch="deepseek-67b", shape="decode_32k",
        hypothesis="baseline: wide (data,model) weight storage forces a "
                   "per-layer full-weight gather every decode step; the "
                   "collective term should dwarf compute (~150ms vs ~2ms).",
    ),
    "ds67b_decode_fp8": dict(
        arch="deepseek-67b", shape="decode_32k",
        kwargs=dict(
            dtype="float8_e4m3fn",
            ffn_axes_override=("model",),
            attn_axes_override=("model",),
        ),
        hypothesis="fp8 storage (the paper's NVFP4 analogue) halves "
                   "resident bytes so FFN+attention fit model-only "
                   "sharding; decode becomes DEP partial-psum with NO "
                   "weight gathers: collective term ~150ms -> <1ms, "
                   "memory term also halves (fp8 weight reads).",
    ),
    "ds67b_decode_fp8_qgather": dict(
        arch="deepseek-67b", shape="decode_32k",
        kwargs=dict(
            dtype="float8_e4m3fn",
            ffn_axes_override=("model",),
            attn_axes_override=("model",),
        ),
        plan_kwargs=dict(decode_attn="qgather"),
        hypothesis="the remaining 75ms collective = per-layer attention "
                   "weight gathers (95 x 134MB fp8). qgather keeps weights "
                   "local and gathers the projected q/k/v activations "
                   "instead (~0.5MB/layer) + a psum after wo: collective "
                   "term should drop to ~1ms; decode becomes memory-bound "
                   "on the KV cache (the right regime).",
    ),
    # ---- pair 2: llama4 x prefill_32k (memory-bound: expert streaming) ---
    "llama4_prefill_baseline": dict(
        arch="llama4-maverick-400b-a17b", shape="prefill_32k",
        hypothesis="baseline: rotate-mode DWDP streams the full 25GB/layer "
                   "expert bank through every rank; memory term ~2s "
                   "dominates compute ~1.4s.",
    ),
    "llama4_prefill_dep": dict(
        arch="llama4-maverick-400b-a17b", shape="prefill_32k",
        mode="dep",
        hypothesis="pure DEP moves only routed activations "
                   "(2*T*D*topk ~ 168MB/layer vs 25GB/layer weights): "
                   "memory term collapses; the cost is the paper's "
                   "synchronizing all-to-all on the critical path.",
    ),
    "llama4_prefill_hybrid": dict(
        arch="llama4-maverick-400b-a17b", shape="prefill_32k",
        mode="hybrid",
        hypothesis="beyond-paper hybrid: DEP all-to-all for experts only, "
                   "DWDP async gather for dense FFN + attention — keeps "
                   "the memory win of DEP while the only sync collective "
                   "left is the MoE dispatch pair.",
    ),
    # ---- pair 3: grok x prefill_32k (paper-representative: redundant
    #      placement MoE DWDP) ---------------------------------------------
    "grok_prefill_baseline": dict(
        arch="grok-1-314b", shape="prefill_32k",
        hypothesis="baseline: rotate DWDP with R=2 redundancy; compute "
                   "term dominated by capacity-padded grouped GEMM "
                   "(cf=1.25 -> +25% expert FLOPs) + masked-full "
                   "attention.",
    ),
    "grok_prefill_cf1": dict(
        arch="grok-1-314b", shape="prefill_32k",
        plan_kwargs=dict(capacity_factor=1.0),
        hypothesis="capacity factor 1.25 -> 1.0 cuts grouped-GEMM slots "
                   "20%: expert FLOPs are ~75% of the compute term, so "
                   "expect ~15% lower compute term (inference-lossy only "
                   "under extreme routing skew).",
    ),
    "grok_prefill_ring": dict(
        arch="grok-1-314b", shape="prefill_32k",
        prefetch="ring",
        hypothesis="ring prefetch moves the same bytes as allgather in "
                   "G'-1 pairwise neighbor permutes (contention-free on "
                   "the ICI torus) — collective TERM unchanged, but the "
                   "schedule is the paper's serial-P2P analogue; verify "
                   "byte parity from the HLO.",
    ),
    "grok_prefill_r64": dict(
        arch="grok-1-314b", shape="prefill_32k",
        kwargs=dict(redundancy=64),
        hypothesis="rotate traffic per layer = (G'-1)/G' x layer set. "
                   "Default R=32 gives G'=8 (7/8 = 8.5GB/layer/rank). "
                   "R=64 -> G'=4 subgroups: 3/4 x 9.7GB = 7.3GB (-14%) at "
                   "2.4GB/rank resident (still fits) — the paper's "
                   "redundant-placement lever, pushed further than the "
                   "paper's R examples.",
    ),
    "grok_prefill_hybrid": dict(
        arch="grok-1-314b", shape="prefill_32k",
        mode="hybrid",
        hypothesis="A2A moves 2*T*k*D activations (~0.4GB/layer) instead "
                   "of 8.5GB/layer of expert weights: collective term "
                   "~3.2s -> ~0.2s. Trade: the all-to-all synchronizes "
                   "ranks at every MoE layer (the paper's Fig.1 cost "
                   "returns for the expert path only).",
    ),
    # ---- qgather generalization: other collective-bound decodes ----------
    "gemma3_decode_baseline": dict(
        arch="gemma3-27b", shape="decode_32k",
        hypothesis="gemma3 decode gathers its (model-sharded) attention "
                   "weights per step: Tcoll 38ms vs Tc 0.5ms.",
    ),
    "gemma3_decode_qgather": dict(
        arch="gemma3-27b", shape="decode_32k",
        plan_kwargs=dict(decode_attn="qgather"),
        hypothesis="gemma3 has 32 heads % 16 == 0 and kv=16: qgather "
                   "eligible; expect collective -> ~0 and memory-bound "
                   "decode.",
    ),
    "chameleon_decode_qgather": dict(
        arch="chameleon-34b", shape="decode_32k",
        plan_kwargs=dict(decode_attn="qgather"),
        hypothesis="same mechanism for chameleon (64 heads, kv=8): "
                   "75.5ms collective -> ~0.",
    ),
    # ---- llama4 train: rotate traffic also dominates train ----------------
    "llama4_train_baseline": dict(
        arch="llama4-maverick-400b-a17b", shape="train_4k",
        hypothesis="train_4k rotate streams the bank fwd AND re-streams "
                   "in remat'd backward: Tcoll 12.7s dominates Tc 3.2s.",
    ),
    "llama4_train_hybrid": dict(
        arch="llama4-maverick-400b-a17b", shape="train_4k",
        mode="hybrid",
        hypothesis="hybrid moves routed activations (A2A transposes to "
                   "A2A in backward): expect Tcoll ~< 1s, compute-bound "
                   "training.",
    ),
    # ---- ring_sliced: the §4.3 TDM analogue on ICI -------------------------
    "yi_prefill_ring_sliced": dict(
        arch="yi-9b", shape="prefill_32k",
        prefetch="ring_sliced",
        hypothesis="ring_sliced splits each permute into 4 slices: same "
                   "bytes, 4x the permute count (finer overlap units for "
                   "the scheduler) — verify byte parity + count from HLO.",
    ),
    "yi_prefill_ring": dict(
        arch="yi-9b", shape="prefill_32k", prefetch="ring",
        hypothesis="ring vs allgather byte parity for the dense FFN "
                   "gathers.",
    ),
    "yi_prefill_baseline": dict(
        arch="yi-9b", shape="prefill_32k",
        hypothesis="allgather reference for the prefetch-mode comparison.",
    ),
    # ---- beyond-paper global: block-causal attention ---------------------
    "grok_train_baseline": dict(
        arch="grok-1-314b", shape="train_4k",
        hypothesis="train_4k keeps the sequence unsharded (batch covers "
                   "the mesh): masked-full attention computes 2x the "
                   "causal FLOPs.",
    ),
    "grok_train_block_causal": dict(
        arch="grok-1-314b", shape="train_4k",
        plan_kwargs=dict(block_causal=True),
        hypothesis="block-causal KV skipping halves attention FLOPs; "
                   "attention is ~20% of grok's train compute term -> "
                   "expect ~10% lower compute term.",
    ),
    "gemma3_train_block_causal": dict(
        arch="gemma3-27b", shape="train_4k",
        plan_kwargs=dict(block_causal=True),
        hypothesis="gemma3's 5:1 sliding:global pattern also skips "
                   "out-of-window KV blocks: local layers at 4K seq with "
                   "window 1024 drop ~60% of their attention FLOPs.",
    ),
    "gemma3_train_baseline": dict(
        arch="gemma3-27b", shape="train_4k",
        hypothesis="baseline for gemma3 block-causal comparison.",
    ),
}


EXPERIMENTS.update({
    # ---- deepseek-r1: the paper's own model, on the TPU roofline ----------
    "r1_prefill_dwdp": dict(
        arch="deepseek-r1", shape="prefill_32k",
        hypothesis="the paper's model on our mesh: rotate-DWDP context. "
                   "Expect compute-bound (top-8 of 256 experts at 2048 "
                   "tok/rank: intensity 2*T*k/E = 128 FLOP/byte < 985 "
                   "— marginal; measure which side it lands).",
    ),
    "r1_prefill_dep": dict(
        arch="deepseek-r1", shape="prefill_32k", mode="dep",
        hypothesis="DEP reference for the paper's model: activation "
                   "all-to-all volume 2*T*k*D.",
    ),
    "r1_prefill_hybrid": dict(
        arch="deepseek-r1", shape="prefill_32k", mode="hybrid",
        hypothesis="hybrid expected best-bound for R1 too (fine-grained "
                   "256-expert bank is the llama4 regime, not grok's).",
    ),
    "grok_train_bf16_moments": dict(
        arch="grok-1-314b", shape="train_4k",
        kwargs=dict(moment_dtype="bfloat16"),
        hypothesis="bf16 Adam moments: per-param train bytes 14 -> 6; "
                   "grok single-pod residency 25.8GB -> ~13GB (fits).",
    ),
})


EXPERIMENTS.update({
    # ---- §4.3 TDM analogue ablation: slice count -------------------------
    "grok_prefill_ring_s2": dict(
        arch="grok-1-314b", shape="prefill_32k", prefetch="ring_sliced",
        plan_kwargs=dict(num_slices=2),
        hypothesis="slice count changes granularity only: byte parity "
                   "with ring, 2x the permute count on sliced tensors.",
    ),
    "grok_prefill_ring_s8": dict(
        arch="grok-1-314b", shape="prefill_32k", prefetch="ring_sliced",
        plan_kwargs=dict(num_slices=8),
        hypothesis="8 slices: same bytes, 8x permute count — the TPU "
                   "ring_sliced lever mirrors the paper's 1MB-slice TDM.",
    ),
    # ---- complete deepseek-r1 coverage (paper's model, 4 shapes) ----------
    "r1_train": dict(
        arch="deepseek-r1", shape="train_4k",
        kwargs=dict(moment_dtype="bfloat16"),
        hypothesis="R1 train on 256 chips with bf16 moments: rotate "
                   "traffic large (like llama4) — expect collective-heavy; "
                   "hybrid would fix (same mechanism).",
    ),
    "r1_train_hybrid": dict(
        arch="deepseek-r1", shape="train_4k", mode="hybrid",
        kwargs=dict(moment_dtype="bfloat16"),
        hypothesis="hybrid fixes R1 train like llama4: rotate's 21.3s "
                   "fwd+bwd expert streaming replaced by A2A pairs.",
    ),
    "r1_decode": dict(
        arch="deepseek-r1", shape="decode_32k",
        plan_kwargs=dict(decode_attn="qgather"),
        hypothesis="R1 decode with qgather: attention weight gathers "
                   "avoided; MoE A2A small at 8 tokens/rank — expect "
                   "memory-bound.",
    ),
    "r1_long": dict(
        arch="deepseek-r1", shape="long_500k",
        plan_kwargs=dict(decode_attn="qgather"),
        hypothesis="R1 long_500k (sliding variant): KV sharded 256-way; "
                   "memory-bound decode.",
    ),
})


def run_experiment(name: str) -> dict:
    exp = dict(EXPERIMENTS[name])
    hypothesis = exp.pop("hypothesis", "")
    arch = exp.pop("arch")
    shape = exp.pop("shape")
    mode = exp.pop("mode", None)
    prefetch = exp.pop("prefetch", "allgather")
    plan_kwargs = exp.pop("plan_kwargs", {})
    kwargs = exp.pop("kwargs", {})
    for k in ("dtype", "moment_dtype"):
        if k in kwargs and jnp is not None:
            kwargs[k] = getattr(jnp, kwargs[k])
    row = dryrun_one(
        arch, shape, mode=mode, prefetch=prefetch, verbose=False,
        plan_kwargs=plan_kwargs, **kwargs,
    )
    row["experiment"] = name
    row["hypothesis"] = hypothesis
    return row


def main(argv=None):
    ap = argparse.ArgumentParser()
    ap.add_argument("names", nargs="*")
    ap.add_argument("--list", action="store_true")
    ap.add_argument("--out", default="results/perf_log.jsonl")
    args = ap.parse_args(argv)
    if args.list:
        for k, v in EXPERIMENTS.items():
            print(f"{k:32s} {v['arch']} x {v['shape']}")
        return 0
    names = args.names or list(EXPERIMENTS)
    for name in names:
        row = run_experiment(name)
        print(json.dumps(
            {k: row[k] for k in
             ("experiment", "t_compute_ms", "t_memory_ms",
              "t_collective_ms", "dominant", "useful_flop_ratio",
              "residency_gb")},
            default=str,
        ))
        with open(args.out, "a") as f:
            f.write(json.dumps(row, default=str) + "\n")
    return 0


if __name__ == "__main__":
    sys.exit(main())
