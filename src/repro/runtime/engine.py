"""Disaggregated serving engine (paper §5 setting, CPU-demo scale).

- ``ContextServer``: runs DWDP (or DEP) prefill with KV capture — the
  captured decode state is the ctx->gen transfer payload.
- ``GenerationServer``: slot-based continuous batching over the decode
  step. Each slot has its own position (the per-row position machinery in
  core/execution); requests join whenever a slot frees, without draining
  the batch — the paper's independent-worker property.
- ``DisaggregatedEngine``: queues, rate-matching and metrics glue.

Real arrays throughout: this is what examples/serve_demo.py runs on CPU
with a reduced model; the cluster-scale behaviour is explored by
runtime/simulator.py with roofline-modelled service times.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execution
from repro.core.strategy import (
    PolicyTable, degradation_ladder, make_execution_plan,
)
from repro.configs.base import InputShape
from repro.models.cache import init_decode_state
from repro.models.transformer import Model
from repro.runtime.metrics import RequestRecord, ServingMetrics


def _resolve_policy(policy, *, prefetch="allgather", weight_layout=None,
                    expert_fetch="all", demand_budget=0, cache_budget=0):
    """Server-level policy resolution: an explicit ``policy`` (a
    PolicyTable, per-family dict, spec string, or "auto") wins; otherwise
    the simple per-knob kwargs spell a uniform table — WITHOUT routing
    through the deprecated make_execution_plan aliases, so internal
    callers stay warning-free."""
    if policy is not None:
        return policy
    return PolicyTable.uniform(
        layout=weight_layout if weight_layout is not None else "split",
        fetch=expert_fetch,
        transport=prefetch,
        budget=demand_budget,
        cache_budget=cache_budget,
    )


@dataclasses.dataclass
class Request:
    req_id: int
    tokens: np.ndarray        # (prompt_len,)
    target_len: int           # output tokens to generate
    arrival: float = 0.0

    def __post_init__(self):
        # fail at construction, not as downstream shape garbage
        self.tokens = np.asarray(self.tokens)
        if self.tokens.ndim != 1 or self.tokens.size == 0:
            raise ValueError(
                f"Request {self.req_id}: tokens must be a non-empty 1-d "
                f"prompt, got shape {self.tokens.shape}"
            )
        if int(self.target_len) < 1:
            raise ValueError(
                f"Request {self.req_id}: target_len must be >= 1 "
                f"(the prefill emits the first token), got {self.target_len}"
            )


class HealthMonitor:
    """Per-peer fault-pressure tracker with hysteresis.

    Consumes the per-source-position detected tail of each decode
    step's fault-stats vector; keeps an EMA of the "this peer served a
    bad row this step" event per peer. A peer whose EMA crosses
    ``demote_threshold`` requests a ladder demotion (predictive ->
    demand -> all-gather: each level leans less on per-peer payload
    rounds); once EVERY peer's EMA falls below ``promote_threshold``
    the monitor requests re-promotion. ``min_dwell`` steps must pass
    between transitions so one bad step cannot flap the policy."""

    def __init__(self, *, decay: float = 0.7, demote_threshold: float = 0.5,
                 promote_threshold: float = 0.1, min_dwell: int = 2):
        if not 0.0 < decay < 1.0:
            raise ValueError(f"decay must be in (0, 1), got {decay}")
        if promote_threshold >= demote_threshold:
            raise ValueError(
                "promote_threshold must sit below demote_threshold "
                f"(hysteresis), got {promote_threshold} >= {demote_threshold}"
            )
        self.decay = decay
        self.demote_threshold = demote_threshold
        self.promote_threshold = promote_threshold
        self.min_dwell = min_dwell
        self.ema = np.zeros(0)
        self._since_move = min_dwell  # free to act immediately

    def observe(self, detected_by_peer) -> Optional[str]:
        """Feed one step's per-peer detected counts; returns "demote",
        "promote", or None."""
        ev = (np.asarray(detected_by_peer, np.float64) > 0).astype(np.float64)
        if self.ema.shape != ev.shape:
            self.ema = np.zeros_like(ev)
        self.ema = self.decay * self.ema + (1.0 - self.decay) * ev
        self._since_move += 1
        if self._since_move <= self.min_dwell or self.ema.size == 0:
            return None
        if np.max(self.ema) > self.demote_threshold:
            self._since_move = 0
            return "demote"
        if np.max(self.ema) < self.promote_threshold:
            self._since_move = 0
            return "promote"
        return None

    def worst_peer(self) -> Optional[int]:
        """Subgroup position with the highest fault-pressure EMA (None
        before any observation) — the peer the ladder's per-peer
        exclusion rung drops from the speculative/cache plans."""
        if self.ema.size == 0:
            return None
        return int(np.argmax(self.ema))


class ContextServer:
    """Prefill worker: returns (first_token, captured decode state)."""

    def __init__(self, model: Model, mesh, mesh_sizes, *, mode="dwdp",
                 prefill_len: int, cache_len: int, prefetch="allgather",
                 weight_layout: Optional[str] = None,
                 capacity_from: str = "local",
                 expert_fetch: str = "all", demand_budget: int = 0,
                 cache_budget: int = 0, policy=None,
                 fault_spec=None, validate_fetch: bool = False):
        self.model = model
        self.prefill_len = prefill_len
        shape = InputShape("ctx", prefill_len, 1, "prefill")
        self.xp = make_execution_plan(
            model, shape, mesh_sizes, mode=mode,
            policy=_resolve_policy(
                policy, prefetch=prefetch, weight_layout=weight_layout,
                expert_fetch=expert_fetch, demand_budget=demand_budget,
                cache_budget=cache_budget,
            ),
            capacity_from=capacity_from,
            fault_spec=fault_spec, validate_fetch=validate_fetch,
        )
        self.step = execution.make_step_fn(
            model, self.xp, mesh, capture_len=cache_len
        )
        # static gathered-weight wire bytes of one prefill call (fetched =
        # what the lowered program ships, full = the expert_fetch="all"
        # counterfactual) — attributed per request by the engine
        self.gather_bytes = execution.gathered_wire_bytes_per_step(
            model, self.xp
        )

    def prefill(self, params, tokens: np.ndarray):
        """tokens: (prompt_len,) -> (first_token, state). The demo engine
        uses fixed-length prompts (the request generator packs/clips);
        variable lengths are exercised by the cluster simulator."""
        assert len(tokens) == self.prefill_len, (
            len(tokens), self.prefill_len,
        )
        row = jnp.asarray(tokens[None, :], jnp.int32)
        out = self.step(params, {"tokens": row})
        logits = out["last_logits"]
        first = int(jnp.argmax(logits[0]))
        return first, out["state"]


class GenerationServer:
    """Slot-based continuous-batching decode worker."""

    def __init__(self, model: Model, mesh, mesh_sizes, *, mode="dep",
                 max_batch: int, cache_len: int,
                 weight_layout: Optional[str] = None,
                 capacity_from: str = "local",
                 expert_fetch: str = "all", demand_budget: int = 0,
                 cache_budget: int = 0, policy=None,
                 fault_spec=None, validate_fetch: bool = False):
        self.model = model
        self.max_batch = max_batch
        self.cache_len = cache_len
        shape = InputShape("gen", cache_len, max_batch, "decode")
        self._mesh = mesh
        self._mesh_sizes = mesh_sizes
        self._mode = mode
        self._shape = shape
        self._capacity_from = capacity_from
        self.fault_spec = fault_spec
        self.validate_fetch = validate_fetch
        self.xp = make_execution_plan(
            model, shape, mesh_sizes, mode=mode,
            policy=_resolve_policy(
                policy, weight_layout=weight_layout,
                expert_fetch=expert_fetch, demand_budget=demand_budget,
                cache_budget=cache_budget,
            ),
            capacity_from=capacity_from,
            fault_spec=fault_spec, validate_fetch=validate_fetch,
        )
        self.step = execution.make_step_fn(model, self.xp, mesh)
        # static gathered-weight wire bytes per decode step (see
        # ContextServer.gather_bytes) — shared by the step's active slots
        self.gather_bytes = execution.gathered_wire_bytes_per_step(
            model, self.xp
        )
        # graceful-degradation ladder over the resolved policy table:
        # level 0 is the configured table; each further level leans one
        # notch less on per-peer payload rounds (predictive/sync_free ->
        # per-peer exclusion -> demand -> all-gather). Plans/steps are
        # built lazily per (level, excluded peers) and cached; see
        # set_level for the predictive-state handoff.
        self.ladder = degradation_ladder(self.xp.policies)
        self.level = 0
        self._level_cache = {
            (0, ()): (self.xp, self.step, self.gather_bytes)
        }
        self.state = execution.attach_predict_state(
            init_decode_state(model, max_batch, cache_len), model, self.xp
        )
        # bytes of one expert's weight rows — converts the predictive
        # fetch's per-step row counters into the byte counters the
        # serving metrics report
        cfg = model.cfg
        self.expert_bytes = (
            3 * cfg.d_model * cfg.moe.d_ff * jnp.dtype(model.dtype).itemsize
            if cfg.moe is not None else 0
        )
        self.last_pred_stats: Optional[np.ndarray] = None
        self.last_fault_stats: Optional[np.ndarray] = None
        # inactive slots: pos points at an empty cache; emitted tokens junk
        self.slot_req: list[Optional[int]] = [None] * max_batch
        self.slot_remaining = np.zeros(max_batch, np.int64)
        self.cur_token = jnp.zeros((max_batch, 1), jnp.int32)

    @property
    def fetch_label(self) -> str:
        """The current ladder rung's label ("sync_free" / "predictive" /
        "<root>+excl" / "demand" / "all")."""
        return self.ladder[self.level][0]

    def set_level(self, level: int,
                  worst_peer: Optional[int] = None) -> bool:
        """Move to a degradation-ladder level (clamped); returns whether
        the level changed. Swaps in that level's (plan, step fn, wire
        model) — built lazily on first use — and re-attaches a COLD
        predictive state shaped for the new plan: the residency cache /
        predictor do not survive a policy change (their budgets differ),
        which is exactly the safe behaviour when a peer went bad. KV /
        recurrent slot state carries over untouched.

        A per-peer-exclusion rung (excl ``None`` in the ladder) is
        instantiated against ``worst_peer`` — the HealthMonitor's
        hottest subgroup position — and cached per (level, exclusion),
        so re-entering the rung against a different bad peer rebuilds
        the plan for that peer."""
        level = max(0, min(int(level), len(self.ladder) - 1))
        if level == self.level:
            return False
        _, table, excl = self.ladder[level]
        if excl is None:
            excl = (worst_peer,) if worst_peer is not None else ()
        key = (level, tuple(int(p) for p in excl))
        if key not in self._level_cache:
            xp = make_execution_plan(
                self.model, self._shape, self._mesh_sizes, mode=self._mode,
                policy=table, capacity_from=self._capacity_from,
                fault_spec=self.fault_spec,
                validate_fetch=self.validate_fetch,
                exclude_peers=excl,
            )
            self._level_cache[key] = (
                xp,
                execution.make_step_fn(self.model, xp, self._mesh),
                execution.gathered_wire_bytes_per_step(self.model, xp),
            )
        self.xp, self.step, self.gather_bytes = self._level_cache[key]
        bare = {k: v for k, v in self.state.items() if k != "pred"}
        self.state = execution.attach_predict_state(
            bare, self.model, self.xp
        )
        self.level = level
        self.last_pred_stats = None
        self.last_fault_stats = None
        return True

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def admit(self, slot: int, req_id: int, first_token: int, ctx_state):
        """Install a context-server state into one batch slot. Scan groups
        carry a leading cycle axis, so the batch axis is 1 there. The
        predictive-fetch state ("pred" — per-RANK predictor + residency
        cache, shared by every slot) is untouched: admitting a request
        must not flush the cache the other slots are hitting."""
        new_layers = {}
        for group in self.model.plan:
            stacked = group.scan and group.n_cycles > 1
            bax = 1 if stacked else 0

            def write(dst, src, bax=bax):
                idx = (slice(None),) * bax + (slot,)
                src_row = src[(slice(None),) * bax + (0,)]
                return dst.at[idx].set(src_row.astype(dst.dtype))

            new_layers[group.name] = jax.tree.map(
                write,
                self.state["layers"][group.name],
                ctx_state["layers"][group.name],
            )
        new_state = {
            "pos": self.state["pos"].at[slot].set(ctx_state["pos"][0]),
            "layers": new_layers,
        }
        if "pred" in self.state:
            new_state["pred"] = self.state["pred"]
        self.state = new_state
        self.cur_token = self.cur_token.at[slot, 0].set(first_token)
        self.slot_req[slot] = req_id

    def decode_step(self, params):
        out = self.step(params, {"token": self.cur_token}, self.state)
        self.state = out["state"]
        self.cur_token = out["next_token"]
        if "pred_stats" in out:
            # [predicted, spec_hit, cache_hit, miss, evicted] expert rows
            # this step, summed over layers and ranks (psum'd in-step)
            self.last_pred_stats = np.asarray(out["pred_stats"])
        # per-kind fault counters + per-peer detected tail (only emitted
        # by validated plans whose layers run the demand/predictive path)
        self.last_fault_stats = (
            np.asarray(out["fault_stats"]) if "fault_stats" in out else None
        )
        return np.asarray(out["next_token"][:, 0])

    def release(self, slot: int):
        self.slot_req[slot] = None


class DisaggregatedEngine:
    """Queues + rate matching between context and generation servers."""

    def __init__(self, params, ctx: ContextServer, gen: GenerationServer,
                 health: Optional[HealthMonitor] = None):
        self.params = params
        self.ctx = ctx
        self.gen = gen
        self.health = health
        self.queue: list[Request] = []
        self.records: dict[int, RequestRecord] = {}
        self.outputs: dict[int, list[int]] = {}
        self.metrics = ServingMetrics(num_gpus=1)
        self.t = 0.0

    def submit(self, req: Request):
        # engine-shape validation (the Request itself checked basic
        # well-formedness at construction)
        if len(req.tokens) != self.ctx.prefill_len:
            raise ValueError(
                f"Request {req.req_id}: prompt length {len(req.tokens)} != "
                f"context server prefill_len {self.ctx.prefill_len}"
            )
        if self.ctx.prefill_len + req.target_len - 1 > self.gen.cache_len:
            raise ValueError(
                f"Request {req.req_id}: prompt ({self.ctx.prefill_len}) + "
                f"output ({req.target_len}) tokens exceed the decode ring "
                f"capacity cache_len={self.gen.cache_len}"
            )
        self.queue.append(req)
        self.records[req.req_id] = RequestRecord(
            req_id=req.req_id,
            arrival=self.t,
            prompt_len=len(req.tokens),
            target_len=req.target_len,
        )
        self.outputs[req.req_id] = []

    def run(self, steps: int) -> ServingMetrics:
        """Drive the engine: each step = one decode iteration; free slots
        pull queued requests through the context server first."""
        for _ in range(steps):
            for slot in self.gen.free_slots():
                if not self.queue:
                    break
                req = self.queue.pop(0)
                first, state = self.ctx.prefill(self.params, req.tokens)
                rec = self.records[req.req_id]
                rec.first_token_time = self.t
                rec.tokens_out = 1
                rec.add_gather_share(self.ctx.gather_bytes)
                self.outputs[req.req_id].append(first)
                self.gen.admit(slot, req.req_id, first, state)
                self.gen.slot_remaining[slot] = req.target_len - 1
            toks = self.gen.decode_step(self.params)
            self.t += 1.0
            from repro.core.faults import FAULT_STAT_BASE

            fs = self.gen.last_fault_stats
            if fs is not None:
                self.metrics.record_fault_stats(fs)
            if self.health is not None:
                if fs is not None:
                    tail = fs[FAULT_STAT_BASE:]
                elif self.health.ema.size:
                    # bottom-of-ladder ("all") plans run no per-peer
                    # payload rounds, so there is no fault signal — feed
                    # a clean observation so the EMAs decay and recovery
                    # can re-promote
                    tail = np.zeros_like(self.health.ema)
                else:
                    tail = None
                move = (
                    self.health.observe(tail) if tail is not None else None
                )
                if move == "demote":
                    if self.gen.set_level(
                        self.gen.level + 1,
                        worst_peer=self.health.worst_peer(),
                    ):
                        self.metrics.record_transition(
                            int(self.t), "demote", self.gen.level,
                            self.gen.fetch_label,
                        )
                elif move == "promote" and self.gen.level > 0:
                    if self.gen.set_level(
                        self.gen.level - 1,
                        worst_peer=self.health.worst_peer(),
                    ):
                        self.metrics.record_transition(
                            int(self.t), "promote", self.gen.level,
                            self.gen.fetch_label,
                        )
            active = [r for r in self.gen.slot_req if r is not None]
            for slot, rid in enumerate(self.gen.slot_req):
                if rid is None:
                    continue
                rec = self.records[rid]
                # the decode step's gather traffic is shared by its
                # active slots: attribute each request its share
                rec.add_gather_share(
                    self.gen.gather_bytes, 1.0 / len(active)
                )
                if self.gen.last_pred_stats is not None and active:
                    # measured predictive counters (rows -> bytes), the
                    # step's share split over its active slots
                    rec.add_predict_share(
                        self.gen.last_pred_stats, self.gen.expert_bytes,
                        1.0 / len(active),
                    )
                self.outputs[rid].append(int(toks[slot]))
                rec.tokens_out += 1
                self.gen.slot_remaining[slot] -= 1
                if self.gen.slot_remaining[slot] <= 0:
                    rec.done_time = self.t
                    self.metrics.records.append(rec)
                    self.gen.release(slot)
        return self.metrics
