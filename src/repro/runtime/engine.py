"""Disaggregated serving engine (paper §5 setting, CPU-demo scale).

- ``ContextServer``: runs DWDP (or DEP) prefill with KV capture — the
  captured decode state is the ctx->gen transfer payload.
- ``GenerationServer``: slot-based continuous batching over the decode
  step. Each slot has its own position (the per-row position machinery in
  core/execution); requests join whenever a slot frees, without draining
  the batch — the paper's independent-worker property.
- ``DisaggregatedEngine``: queues, rate-matching and metrics glue.

Real arrays throughout: this is what examples/serve_demo.py runs on CPU
with a reduced model; the cluster-scale behaviour is explored by
runtime/simulator.py with roofline-modelled service times.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import execution, roofline
from repro.core.strategy import (
    PolicyTable, degradation_ladder, make_execution_plan, resolve_policies,
)
from repro.configs.base import InputShape
from repro.models.cache import init_decode_state
from repro.models.transformer import Model
from repro.runtime.metrics import RequestRecord, ServingMetrics


def validate_restore_plan(snapshot_plan: Optional[dict],
                          current_plan: dict) -> None:
    """Reject restoring a ``snapshot_slot`` payload into a server whose
    active plan no longer matches the one the snapshot was taken under.

    A snapshot's KV/position layout is only re-admittable verbatim when
    the destination runs the SAME model at the SAME mesh sizes and
    cache length with the SAME policy table and exclusion set — e.g. a
    post-rank-death shrunk replica, a health-demoted ladder rung, or a
    different ``PolicyTable`` would all restore into a mismatched
    variant and silently corrupt the stream. Raises ``ValueError``
    naming every mismatched field; the serving scheduler converts that
    into a requeue-from-prompt. ``None`` (a pre-plan-stamp snapshot)
    passes — legacy payloads keep working within one server."""
    if snapshot_plan is None:
        return
    bad = [
        f"{k}: snapshot {snapshot_plan.get(k)!r} != active "
        f"{current_plan.get(k)!r}"
        for k in sorted(set(snapshot_plan) | set(current_plan))
        if snapshot_plan.get(k) != current_plan.get(k)
    ]
    if bad:
        raise ValueError(
            "snapshot_slot resume rejected — the destination's active "
            "plan differs from the snapshot's ("
            + "; ".join(bad)
            + "); requeue the request from its prompt instead"
        )


def variant_key(table: PolicyTable, shape: InputShape,
                excl: tuple = ()) -> tuple:
    """The pre-compiled forward-variant cache key: canonicalized policy
    table (``describe()`` — sorted ``to_dict()`` JSON, so two tables
    collide iff their ``to_dict()`` forms are equal) + the shape bucket
    the variant was compiled for + the peer-exclusion set. Everything
    else that shapes the lowered program (model, mesh, mode) is fixed
    per cache instance."""
    return (
        table.describe(),
        (shape.phase, shape.seq_len, shape.global_batch),
        tuple(int(p) for p in excl),
    )


class CountingStep:
    """A jitted step function with a call counter and a compile-cache
    probe, preserving the jit surface (``.lower``) the AOT tests use.

    ``cache_size()`` reads the underlying jit executable cache — after a
    variant is warmed, the serving path asserts this number stays flat
    across policy switches (the zero-recompile contract)."""

    def __init__(self, fn):
        self._fn = fn
        self.calls = 0

    def __call__(self, *args, **kwargs):
        self.calls += 1
        return self._fn(*args, **kwargs)

    @property
    def lower(self):
        return self._fn.lower

    def cache_size(self) -> int:
        return int(self._fn._cache_size())


class PolicyVariantCache:
    """Pre-compiled forward-variant cache for one server.

    Maps :func:`variant_key` -> ``(plan, CountingStep, wire model)``,
    built lazily (or eagerly via the warmup path) and retained LRU up to
    ``max_entries`` so an online scheduler can flip between policy
    tables without re-tracing: a switch to a cached+warmed variant costs
    a dict lookup. Eviction only drops COLD state (the jitted callable
    and its executables) — correctness never depends on an entry being
    present."""

    def __init__(self, model: Model, mesh, mesh_sizes, shape: InputShape,
                 *, mode: str, capacity_from: str = "local",
                 fault_spec=None, validate_fetch: bool = False,
                 capture_len: int = 0, max_entries: int = 16):
        self.model = model
        self._mesh = mesh
        self._mesh_sizes = mesh_sizes
        self.shape = shape
        self._mode = mode
        self._capacity_from = capacity_from
        self._fault_spec = fault_spec
        self._validate_fetch = validate_fetch
        self._capture_len = capture_len
        self.max_entries = max(1, int(max_entries))
        self._entries: dict = {}
        self.stats = {"hits": 0, "misses": 0, "evictions": 0}

    def __len__(self) -> int:
        return len(self._entries)

    def compiles(self) -> int:
        """Total jit executables across cached variants — flat after
        warmup iff the serving path never recompiles."""
        return sum(step.cache_size() for _, step, _ in
                   self._entries.values())

    def get(self, table: PolicyTable, excl: tuple = (),
            shape: Optional[InputShape] = None):
        """The (plan, step, wire-bytes) variant for a policy table,
        building it on miss. ``shape`` overrides the cache's home shape
        bucket — the ctx server's pow2 prefill-length buckets key in
        through here (decode buckets keep the home shape and vary the
        table instead)."""
        shape = shape if shape is not None else self.shape
        key = variant_key(table, shape, excl)
        if key in self._entries:
            self.stats["hits"] += 1
            # refresh LRU position
            self._entries[key] = self._entries.pop(key)
            return self._entries[key]
        self.stats["misses"] += 1
        xp = make_execution_plan(
            self.model, shape, self._mesh_sizes, mode=self._mode,
            policy=table, capacity_from=self._capacity_from,
            fault_spec=self._fault_spec,
            validate_fetch=self._validate_fetch,
            exclude_peers=tuple(int(p) for p in excl),
        )
        step = CountingStep(execution.make_step_fn(
            self.model, xp, self._mesh, capture_len=self._capture_len
        ))
        entry = (
            xp, step, execution.gathered_wire_bytes_per_step(self.model, xp)
        )
        while len(self._entries) >= self.max_entries:
            self._entries.pop(next(iter(self._entries)))
            self.stats["evictions"] += 1
        self._entries[key] = entry
        return entry

    def adopt(self, table: PolicyTable, excl: tuple, entry,
              shape: Optional[InputShape] = None):
        """Seed the cache with an already-built variant (the server's
        boot-time plan) without charging a miss."""
        key = variant_key(table, shape if shape is not None else self.shape,
                          excl)
        self._entries.setdefault(key, entry)


class BudgetTuner:
    """Online speculative-budget resizing over pre-compiled rungs.

    Watches the measured per-step predictive counters (``pred_stats``:
    ``[predicted, spec_hit, cache_hit, miss, evicted]`` expert rows) and
    snaps the speculative/correction row budget to the nearest rung of
    :func:`repro.core.roofline.predictive_budget_rungs`:

    - miss fraction above ``raise_miss_frac`` -> the correction round is
      doing the work, the speculative round is under-provisioned: go up
      one rung;
    - speculative utilization (``spec_hit / predicted``) below
      ``lower_util`` while misses are rare -> the speculative round
      ships rows nobody routes to: come down one rung.

    ``min_dwell`` observed steps must pass between moves (one bursty
    step must not flap the budget), and every emitted budget is a rung
    value — so a serving engine that pre-compiled one variant per rung
    resizes with zero recompiles."""

    def __init__(self, rungs, *, start: Optional[int] = None,
                 raise_miss_frac: float = 0.25, lower_util: float = 0.5,
                 lower_miss_frac: float = 0.1, min_dwell: int = 4):
        rungs = tuple(sorted(int(r) for r in rungs))
        if not rungs:
            raise ValueError("BudgetTuner needs at least one rung")
        self.rungs = rungs
        if start is None:
            self.idx = min(len(rungs) - 1, 1)
        else:
            self.idx = min(
                range(len(rungs)), key=lambda i: abs(rungs[i] - start)
            )
        self.raise_miss_frac = raise_miss_frac
        self.lower_util = lower_util
        self.lower_miss_frac = lower_miss_frac
        self.min_dwell = min_dwell
        self._since = min_dwell  # free to act on the first signal

    @property
    def budget(self) -> int:
        return self.rungs[self.idx]

    def observe(self, pred_stats) -> Optional[int]:
        """Feed one decode step's measured counters; returns the new
        rung budget when a resize fires, else None."""
        if pred_stats is None:
            return None
        pred, spec_hit, cache_hit, miss, _ = (
            float(s) for s in pred_stats
        )
        denom = spec_hit + cache_hit + miss
        self._since += 1
        if denom <= 0 or self._since <= self.min_dwell:
            return None
        miss_frac = miss / denom
        util = spec_hit / pred if pred > 0 else 1.0
        if (miss_frac > self.raise_miss_frac
                and self.idx + 1 < len(self.rungs)):
            self.idx += 1
            self._since = 0
            return self.rungs[self.idx]
        if (miss_frac < self.lower_miss_frac and util < self.lower_util
                and self.idx > 0):
            self.idx -= 1
            self._since = 0
            return self.rungs[self.idx]
        return None


def _with_spec_budget(table: PolicyTable, budget: int) -> PolicyTable:
    """``table`` with every speculative-fetch moe_experts entry (family
    AND per-layer-group overrides) pinned to ``budget`` rows — the
    compile-stable spelling of one budget rung."""

    def upd(name, pol):
        if name == "moe_experts" and pol.fetch in (
                "predictive", "sync_free"):
            return dataclasses.replace(pol, budget=int(budget))
        return pol

    return dataclasses.replace(
        table,
        families=tuple((n, upd(n, p)) for n, p in table.families),
        overrides=tuple(
            (g, n, upd(n, p)) for g, n, p in table.overrides
        ),
    )


class OnlinePolicyScheduler:
    """Zero-recompile online policy switching (``--policy auto-online``).

    Drives the generation server's :meth:`GenerationServer.set_policy`
    between pre-compiled forward variants, re-resolving the PolicyTable
    from three online signals:

    - **batch-shape buckets** — the decode step always runs the compiled
      ``max_batch`` shape, but the roofline-optimal table depends on how
      many slots are ACTIVE (the resolver scores with the routed-row
      count). Active-slot counts are bucketed to powers of two; crossing
      a bucket boundary re-resolves immediately at the new bucket's row
      count.
    - **measured hit-rate drift** — the served ``pred_stats`` split
      (speculative hits vs residency-cache hits vs correction rows) is
      EMA-tracked, quantized, and replayed into
      :func:`repro.core.strategy.resolve_policies` via ``hit_rates=``
      every ``interval`` decode steps; drifted rates can flip the
      resolved winner (e.g. sync_free -> demand when the predictor
      stops hitting) including per-layer-group overrides.
    - **speculative-budget resizing** — a :class:`BudgetTuner` snaps the
      speculative/correction row budget to the nearest pre-compiled rung
      (:func:`repro.core.roofline.predictive_budget_rungs`).

    Every emitted table is canonical (same resolver, quantized inputs),
    so a revisited operating point hits the variant cache — after
    :meth:`DisaggregatedEngine.warmup` the whole decision loop runs with
    ZERO recompiles. The scheduler only acts at degradation-ladder
    level 0: a health-demoted server belongs to the HealthMonitor until
    it re-promotes."""

    def __init__(self, model: Model, mesh_sizes, shape: InputShape, *,
                 interval: int = 8, ema_decay: float = 0.8, hw=None,
                 tuner: Optional[BudgetTuner] = None):
        self.model = model
        self.mesh_sizes = dict(mesh_sizes)
        self.shape = shape
        self.interval = max(1, int(interval))
        self.ema_decay = ema_decay
        self.hw = hw
        self.tuner = tuner
        self._tuner_resolved = tuner is not None
        self._hit_ema: Optional[tuple] = None  # (predict_hit, cache_hit)
        self._bucket: Optional[int] = None
        self._steps = 0
        self._resolved: dict = {}  # (bucket, quantized rates) -> table

    # -- signals ---------------------------------------------------------

    def _bucket_of(self, active_rows: int) -> int:
        b = 1
        while b < active_rows:
            b *= 2
        return min(b, self.shape.global_batch)

    def _observe_rates(self, pred_stats) -> None:
        if pred_stats is None:
            return
        _, spec_hit, cache_hit, miss, _ = (float(s) for s in pred_stats)
        denom = spec_hit + cache_hit + miss
        if denom <= 0:
            return
        # factor the measured split the way the roofline composes it:
        # (1 - cache_hit) * (1 - predict_hit) = correction fraction
        cache = cache_hit / denom
        non_cache = spec_hit + miss
        predict = spec_hit / non_cache if non_cache > 0 else 1.0
        rates = (predict, cache)
        if self._hit_ema is None:
            self._hit_ema = rates
        else:
            d = self.ema_decay
            self._hit_ema = tuple(
                d * e + (1.0 - d) * r
                for e, r in zip(self._hit_ema, rates)
            )

    def _quantized_rates(self) -> Optional[tuple]:
        """EMA rates on a 0.05 grid — the resolver-cache key, so jitter
        between steps cannot mint a new table per step."""
        if self._hit_ema is None:
            return None
        return tuple(round(r * 20) / 20 for r in self._hit_ema)

    # -- resolution ------------------------------------------------------

    def _resolve(self, bucket: int) -> PolicyTable:
        q = self._quantized_rates()
        key = (bucket, q)
        if key not in self._resolved:
            shape = dataclasses.replace(self.shape, global_batch=bucket)
            hit_rates = None
            if q is not None:
                predict, cache = q
                groups = set(roofline.layer_group_names(self.model.cfg))
                hit_rates = {
                    g: {"predict_hit": predict, "cache_hit": cache}
                    for g in groups
                }
            self._resolved[key] = resolve_policies(
                self.model, shape, self.mesh_sizes, "auto",
                hw=self.hw, hit_rates=hit_rates,
            )
        return self._resolved[key]

    def _ensure_tuner(self, gen: "GenerationServer") -> None:
        if self._tuner_resolved:
            return
        self._tuner_resolved = True
        cfg, pl = self.model.cfg, self.model.geom.moe_placement
        if cfg.moe is None or pl is None or pl.subgroup_size <= 1:
            return
        rows = max(1, gen.xp.local_batch)
        rungs = roofline.predictive_budget_rungs(
            rows * cfg.moe.top_k, cfg.moe.num_experts, pl.local_count
        )
        start = gen.xp.policies.family("moe_experts").budget or None
        self.tuner = BudgetTuner(rungs, start=start)

    def _snap_budget(self, table: PolicyTable) -> PolicyTable:
        if self.tuner is None:
            return table
        return _with_spec_budget(table, self.tuner.budget)

    # -- the decision loop ----------------------------------------------

    def step(self, gen: "GenerationServer",
             active_rows: int) -> Optional[str]:
        """One pre-decode-step decision: returns "switch" / "resize" /
        None (what, if anything, the server moved to)."""
        if gen.level != 0:
            return None
        self._ensure_tuner(gen)
        self._steps += 1
        self._observe_rates(gen.last_pred_stats)
        resized = (
            self.tuner.observe(gen.last_pred_stats) is not None
            if self.tuner is not None else False
        )
        bucket = self._bucket_of(max(1, active_rows))
        boundary = bucket != self._bucket
        if boundary or self._steps % self.interval == 0 or resized:
            self._bucket = bucket
            table = self._snap_budget(self._resolve(bucket))
            if gen.set_policy(table):
                return "resize" if resized and not boundary else "switch"
        return None

    def candidate_tables(self, gen: "GenerationServer") -> list:
        """The tables a warmup pass should pre-compile: the resolved
        table per batch bucket (at default drift) x the budget rungs,
        deduplicated, capped at the variant cache size so warming never
        evicts what it just compiled."""
        self._ensure_tuner(gen)
        out, seen = [], set()
        budgets: tuple = (None,)
        if self.tuner is not None:
            budgets = (None, *self.tuner.rungs)
        bucket, buckets = 1, []
        while bucket <= self.shape.global_batch:
            buckets.append(bucket)
            bucket *= 2
        for b in buckets:
            base = self._resolve(b)
            for budget in budgets:
                t = base if budget is None else _with_spec_budget(
                    base, budget
                )
                d = t.describe()
                if d not in seen:
                    seen.add(d)
                    out.append(t)
        return out[: gen.variants.max_entries]


def _resolve_policy(policy, *, prefetch="allgather", weight_layout=None,
                    expert_fetch="all", demand_budget=0, cache_budget=0):
    """Server-level policy resolution: an explicit ``policy`` (a
    PolicyTable, per-family dict, spec string, or "auto") wins; otherwise
    the simple per-knob kwargs spell a uniform table — WITHOUT routing
    through the deprecated make_execution_plan aliases, so internal
    callers stay warning-free."""
    if policy is not None:
        return policy
    return PolicyTable.uniform(
        layout=weight_layout if weight_layout is not None else "split",
        fetch=expert_fetch,
        transport=prefetch,
        budget=demand_budget,
        cache_budget=cache_budget,
    )


def _resolve_policy_table(model, shape, mesh_sizes, policy) -> PolicyTable:
    """A CONCRETE PolicyTable for the variant cache's canonical key:
    explicit tables pass through; dicts/specs/"auto"/"auto-online" run
    through :func:`resolve_policies` (idempotent with what
    make_execution_plan resolves internally)."""
    if isinstance(policy, PolicyTable):
        return policy
    return resolve_policies(model, shape, mesh_sizes, policy)


@dataclasses.dataclass
class Request:
    req_id: int
    tokens: np.ndarray        # (prompt_len,)
    target_len: int           # output tokens to generate
    arrival: float = 0.0

    def __post_init__(self):
        # fail at construction, not as downstream shape garbage
        self.tokens = np.asarray(self.tokens)
        if self.tokens.ndim != 1 or self.tokens.size == 0:
            raise ValueError(
                f"Request {self.req_id}: tokens must be a non-empty 1-d "
                f"prompt, got shape {self.tokens.shape}"
            )
        if int(self.target_len) < 1:
            raise ValueError(
                f"Request {self.req_id}: target_len must be >= 1 "
                f"(the prefill emits the first token), got {self.target_len}"
            )


class HealthMonitor:
    """Per-peer fault-pressure tracker with hysteresis.

    Consumes the per-source-position detected tail of each decode
    step's fault-stats vector; keeps an EMA of the "this peer served a
    bad row this step" event per peer. A peer whose EMA crosses
    ``demote_threshold`` requests a ladder demotion (predictive ->
    demand -> all-gather: each level leans less on per-peer payload
    rounds); once EVERY peer's EMA falls below ``promote_threshold``
    the monitor requests re-promotion. ``min_dwell`` steps must pass
    between transitions so one bad step cannot flap the policy."""

    def __init__(self, *, decay: float = 0.7, demote_threshold: float = 0.5,
                 promote_threshold: float = 0.1, min_dwell: int = 2):
        if not 0.0 < decay < 1.0:
            raise ValueError(f"decay must be in (0, 1), got {decay}")
        if promote_threshold >= demote_threshold:
            raise ValueError(
                "promote_threshold must sit below demote_threshold "
                f"(hysteresis), got {promote_threshold} >= {demote_threshold}"
            )
        self.decay = decay
        self.demote_threshold = demote_threshold
        self.promote_threshold = promote_threshold
        self.min_dwell = min_dwell
        self.ema = np.zeros(0)
        self._since_move = min_dwell  # free to act immediately

    def observe(self, detected_by_peer) -> Optional[str]:
        """Feed one step's per-peer detected counts; returns "demote",
        "promote", or None."""
        ev = (np.asarray(detected_by_peer, np.float64) > 0).astype(np.float64)
        if self.ema.shape != ev.shape:
            self.ema = np.zeros_like(ev)
        self.ema = self.decay * self.ema + (1.0 - self.decay) * ev
        self._since_move += 1
        if self._since_move <= self.min_dwell or self.ema.size == 0:
            return None
        if np.max(self.ema) > self.demote_threshold:
            self._since_move = 0
            return "demote"
        if np.max(self.ema) < self.promote_threshold:
            self._since_move = 0
            return "promote"
        return None

    def worst_peer(self) -> Optional[int]:
        """Subgroup position with the highest fault-pressure EMA (None
        before any observation) — the peer the ladder's per-peer
        exclusion rung drops from the speculative/cache plans."""
        if self.ema.size == 0:
            return None
        return int(np.argmax(self.ema))

    def bad_peers(self) -> tuple:
        """The peer SET the ladder's exclusion rung drops from the
        speculative/cache plans: every subgroup position whose
        fault-pressure EMA sits above ``demote_threshold``, hottest
        first. Falls back to the single worst peer when a demotion
        fired on a step whose decay already pulled every EMA back under
        the threshold. Never names every position — at least one peer
        stays in the speculative schedule, so the exclusion rung
        degrades toward (not past) plain demand fetch."""
        if self.ema.size == 0:
            return ()
        order = np.argsort(-self.ema, kind="stable")
        hot = [int(p) for p in order if self.ema[p] > self.demote_threshold]
        if not hot:
            hot = [int(order[0])]
        return tuple(hot[: max(1, self.ema.size - 1)])


class ContextServer:
    """Prefill worker: returns (first_token, captured decode state).

    Prompt lengths are served from pow2 seq-len BUCKETS: each configured
    bucket is one pre-compilable variant of the prefill step, keyed into
    the same :func:`variant_key` cache the decode server's policy
    variants use (the shape leg of the key varies instead of the table).
    ``prefill_len`` is the home bucket (and the only one by default —
    the pre-bucket behaviour); ``prefill_buckets`` adds more lengths,
    each a power of two. :meth:`warmup` pre-compiles every bucket, so
    serving mixed prompt lengths never traces on the request path."""

    def __init__(self, model: Model, mesh, mesh_sizes, *, mode="dwdp",
                 prefill_len: int, cache_len: int, prefetch="allgather",
                 weight_layout: Optional[str] = None,
                 capacity_from: str = "local",
                 expert_fetch: str = "all", demand_budget: int = 0,
                 cache_budget: int = 0, policy=None,
                 fault_spec=None, validate_fetch: bool = False,
                 prefill_buckets: tuple = ()):
        self.model = model
        self.prefill_len = prefill_len
        for b in prefill_buckets:
            b = int(b)
            if b < 1 or b & (b - 1):
                raise ValueError(
                    f"prefill_buckets must be powers of two, got {b}"
                )
        self.prefill_lens = tuple(sorted(
            {int(prefill_len), *(int(b) for b in prefill_buckets)}
        ))
        shape = InputShape("ctx", prefill_len, 1, "prefill")
        self._table = _resolve_policy_table(
            model, shape, mesh_sizes,
            _resolve_policy(
                policy, prefetch=prefetch, weight_layout=weight_layout,
                expert_fetch=expert_fetch, demand_budget=demand_budget,
                cache_budget=cache_budget,
            ),
        )
        self.variants = PolicyVariantCache(
            model, mesh, mesh_sizes, shape, mode=mode,
            capacity_from=capacity_from, fault_spec=fault_spec,
            validate_fetch=validate_fetch, capture_len=cache_len,
            max_entries=max(16, len(self.prefill_lens)),
        )
        self.xp, self.step, self.gather_bytes = self._bucket(prefill_len)

    def _bucket(self, length: int):
        """The (plan, step, wire-bytes) variant of one prefill-length
        bucket (built on first use; warm after :meth:`warmup`)."""
        return self.variants.get(
            self._table,
            shape=InputShape("ctx", int(length), 1, "prefill"),
        )

    def warmup(self, params) -> None:
        """Trace+compile the prefill step of EVERY configured bucket off
        the serving path (the first real request of any bucketed length
        then hits a warm jit cache)."""
        for length in self.prefill_lens:
            _, step, _ = self._bucket(length)
            if step.calls == 0:
                self.prefill(params, np.zeros(length, np.int32))
                step.calls = 0

    def prefill(self, params, tokens: np.ndarray):
        """tokens: (prompt_len,) -> (first_token, state). The prompt
        length must exactly match a configured bucket (the request
        generator packs/clips); variable lengths beyond the bucket set
        are exercised by the cluster simulator."""
        length = len(tokens)
        assert length in self.prefill_lens, (length, self.prefill_lens)
        self.xp, self.step, self.gather_bytes = self._bucket(length)
        row = jnp.asarray(tokens[None, :], jnp.int32)
        out = self.step(params, {"tokens": row})
        logits = out["last_logits"]
        first = int(jnp.argmax(logits[0]))
        return first, out["state"]


class GenerationServer:
    """Slot-based continuous-batching decode worker."""

    def __init__(self, model: Model, mesh, mesh_sizes, *, mode="dep",
                 max_batch: int, cache_len: int,
                 weight_layout: Optional[str] = None,
                 capacity_from: str = "local",
                 expert_fetch: str = "all", demand_budget: int = 0,
                 cache_budget: int = 0, policy=None,
                 fault_spec=None, validate_fetch: bool = False,
                 variant_cache_size: int = 16):
        self.model = model
        self.max_batch = max_batch
        self.cache_len = cache_len
        shape = InputShape("gen", cache_len, max_batch, "decode")
        self._mesh = mesh
        self._mesh_sizes = mesh_sizes
        self._mode = mode
        self._shape = shape
        self._capacity_from = capacity_from
        self.fault_spec = fault_spec
        self.validate_fetch = validate_fetch
        # every (policy table, exclusion set) the server runs — the boot
        # table, degradation-ladder rungs, online-scheduler switches —
        # is one entry of the pre-compiled forward-variant cache; a
        # switch to a warmed entry costs a dict lookup, zero recompiles
        self.variants = PolicyVariantCache(
            model, mesh, mesh_sizes, shape, mode=mode,
            capacity_from=capacity_from, fault_spec=fault_spec,
            validate_fetch=validate_fetch, max_entries=variant_cache_size,
        )
        self.xp, self.step, self.gather_bytes = self.variants.get(
            _resolve_policy_table(
                model, shape, mesh_sizes,
                _resolve_policy(
                    policy, weight_layout=weight_layout,
                    expert_fetch=expert_fetch, demand_budget=demand_budget,
                    cache_budget=cache_budget,
                ),
            )
        )
        self.excl: tuple = ()
        # graceful-degradation ladder over the resolved policy table:
        # level 0 is the configured table; each further level leans one
        # notch less on per-peer payload rounds (predictive/sync_free ->
        # per-peer exclusion -> demand -> all-gather). Plans/steps are
        # built lazily per (table, excluded peers) via the variant
        # cache; see set_level for the predictive-state handoff.
        self.ladder = degradation_ladder(self.xp.policies)
        self.level = 0
        self.state = self._committed(execution.attach_predict_state(
            init_decode_state(model, max_batch, cache_len), model, self.xp
        ), self.xp)
        # bytes of one expert's weight rows — converts the predictive
        # fetch's per-step row counters into the byte counters the
        # serving metrics report
        cfg = model.cfg
        self.expert_bytes = (
            3 * cfg.d_model * cfg.moe.d_ff * jnp.dtype(model.dtype).itemsize
            if cfg.moe is not None else 0
        )
        self.last_pred_stats: Optional[np.ndarray] = None
        self.last_fault_stats: Optional[np.ndarray] = None
        # inactive slots: pos points at an empty cache; emitted tokens junk
        self.slot_req: list[Optional[int]] = [None] * max_batch
        self.slot_remaining = np.zeros(max_batch, np.int64)
        self.cur_token = self._committed_token(
            jnp.zeros((max_batch, 1), jnp.int32)
        )

    def _committed(self, state, xp):
        """The decode state committed to the step's OUTPUT shardings.
        The jit executable cache keys on input shardings, so a
        freshly-built host-backed state would compile a throwaway
        executable distinct from the steady-state one whose inputs are
        the previous step's (committed) outputs — committing here gives
        boot, warmup and serving calls ONE signature, which is what lets
        the warmup pass guarantee zero serving-path recompiles."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        specs = execution.state_pspecs(self.model, xp)
        pred = execution.predict_state_pspecs(self.model, xp)
        if "pred" in state:
            specs = dict(specs)
            specs["pred"] = pred

        def canon(s):
            # the jit's output shardings carry trailing-None-stripped
            # specs; commit to the same canonical form or the cache
            # keys won't collide
            parts = tuple(s)
            while parts and parts[-1] is None:
                parts = parts[:-1]
            return P(*parts)

        return jax.tree.map(
            # optional PredictState leaves (the richer-predictor fields)
            # are None in plain predictive mode — in both the state and
            # its spec tree — and stay None
            lambda x, s: x if x is None else jax.device_put(
                x, NamedSharding(self._mesh, canon(s))
            ),
            state, specs,
            is_leaf=lambda x: x is None,
        )

    def _committed_token(self, tok):
        """The token row committed to the decode step's next_token
        output sharding (same signature-stability argument as
        :meth:`_committed`)."""
        from jax.sharding import NamedSharding
        from jax.sharding import PartitionSpec as P

        return jax.device_put(
            tok, NamedSharding(self._mesh, P(self.xp.batch_spec(), None))
        )

    @property
    def fetch_label(self) -> str:
        """The current ladder rung's label ("sync_free" / "predictive" /
        "<root>+excl" / "demand" / "all" / "reshard")."""
        return self.ladder[self.level][0]

    @property
    def max_silent_level(self) -> int:
        """The deepest ladder level FAIL-SILENT demotions may reach:
        the all-gather floor. The terminal ``"reshard"`` rung is the
        fail-stop response — only an explicit rank-death quarantine
        (the serving layer's ``kill_rank`` path) steps past this cap,
        and it does so by swapping in a shrunk-mesh standby engine, not
        by ``set_level``."""
        top = len(self.ladder) - 1
        if self.ladder[top][0] == "reshard":
            return max(0, top - 1)
        return top

    def restore_plan(self) -> dict:
        """The active plan descriptor stamped into every
        :meth:`snapshot_slot` payload and checked by
        :func:`validate_restore_plan` on re-admission."""
        return {
            "model": self.model.cfg.name,
            "mesh": tuple(sorted(
                (str(a), int(s)) for a, s in self._mesh_sizes.items()
            )),
            "cache_len": int(self.cache_len),
            "policies": self.xp.policies.describe(),
            "excl": tuple(int(p) for p in self.excl),
        }

    def set_level(self, level: int, bad_peers: tuple = ()) -> bool:
        """Move to a degradation-ladder level (clamped); returns whether
        the level changed. Swaps in that level's (plan, step fn, wire
        model) — built lazily on first use — and re-attaches a COLD
        predictive state shaped for the new plan: the residency cache /
        predictor do not survive a policy change (their budgets differ),
        which is exactly the safe behaviour when a peer went bad. KV /
        recurrent slot state carries over untouched.

        A per-peer-exclusion rung (excl ``None`` in the ladder) is
        instantiated against ``bad_peers`` — the HealthMonitor's
        over-threshold subgroup positions (:meth:`HealthMonitor.
        bad_peers`) — and cached per (table, exclusion set) in the
        variant cache, so re-entering the rung against a different bad
        set rebuilds the plan for exactly those peers."""
        level = max(0, min(int(level), len(self.ladder) - 1))
        if level == self.level:
            return False
        _, table, excl = self.ladder[level]
        if excl is None:
            excl = tuple(bad_peers)
        self._swap(table, tuple(int(p) for p in excl))
        self.level = level
        return True

    def set_policy(self, table: PolicyTable) -> bool:
        """Online policy SWITCH (the auto-online scheduler's entry
        point): move the decode step to a different resolved policy
        table — a pre-compiled variant when warmed, a lazy build
        otherwise — and rebase the degradation ladder on it. Only legal
        at ladder level 0 (a health-degraded server keeps its rung until
        the monitor re-promotes); returns whether anything changed."""
        if self.level != 0:
            return False
        if (table.describe() == self.xp.policies.describe()
                and not self.excl):
            return False
        self._swap(table, ())
        self.ladder = degradation_ladder(table)
        self.level = 0
        return True

    def _swap(self, table: PolicyTable, excl: tuple) -> None:
        """Install the (table, exclusion set) variant and re-attach a
        COLD predictive state shaped for it (see set_level)."""
        self.xp, self.step, self.gather_bytes = self.variants.get(
            table, excl
        )
        self.excl = excl
        bare = {k: v for k, v in self.state.items() if k != "pred"}
        self.state = self._committed(execution.attach_predict_state(
            bare, self.model, self.xp
        ), self.xp)
        self.last_pred_stats = None
        self.last_fault_stats = None

    def warmup(self, params, tables=()) -> int:
        """Pre-compile forward variants OFF the serving path: for each
        policy table (plus the currently-installed one) build its plan
        and run one decode step on a THROWAWAY state (the decode jit
        donates its state argument, so warming must not consume the live
        slots). After this, switching to any warmed table is
        trace-free and compile-free — the no-recompile contract the
        serving tests assert via ``variants.compiles()``. Returns the
        number of variants compiled."""
        compiled = 0
        tok = self._committed_token(
            jnp.zeros((self.max_batch, 1), jnp.int32)
        )
        seen = set()
        for table in (self.xp.policies, *tables):
            key = variant_key(table, self._shape, ())
            if key in seen:
                continue
            seen.add(key)
            xp, step, _ = self.variants.get(table, ())
            if step.calls:
                continue
            state = self._committed(execution.attach_predict_state(
                init_decode_state(self.model, self.max_batch,
                                  self.cache_len),
                self.model, xp,
            ), xp)
            # two chained calls: the first runs the boot-state signature
            # (freshly-committed inputs — what the server sees right
            # after a switch re-commits its state), the second runs the
            # steady-state signature (the previous step's outputs, whose
            # sharding spellings the jit normalizes differently). Both
            # land in the dispatch cache, so neither the first
            # post-switch step nor any later step re-keys.
            out = step(params, {"token": tok}, state)
            step(params, {"token": out["next_token"]}, out["state"])
            step.calls = 0
            compiled += 1
        return compiled

    def free_slots(self) -> list[int]:
        return [i for i, r in enumerate(self.slot_req) if r is None]

    def admit(self, slot: int, req_id: int, first_token: int, ctx_state):
        """Install a context-server state into one batch slot. Scan groups
        carry a leading cycle axis, so the batch axis is 1 there. The
        predictive-fetch state ("pred" — per-RANK predictor + residency
        cache, shared by every slot) is untouched: admitting a request
        must not flush the cache the other slots are hitting.

        A ``snapshot_slot`` payload carries its origin plan descriptor
        under ``"plan"`` and is validated against the ACTIVE plan
        before any state is written (``validate_restore_plan`` raises
        ``ValueError`` on mismatch — the serving layer converts that
        into a requeue-from-prompt)."""
        if isinstance(ctx_state, dict) and "plan" in ctx_state:
            validate_restore_plan(ctx_state["plan"], self.restore_plan())
        new_layers = {}
        for group in self.model.plan:
            stacked = group.scan and group.n_cycles > 1
            bax = 1 if stacked else 0

            def write(dst, src, bax=bax):
                idx = (slice(None),) * bax + (slot,)
                src_row = src[(slice(None),) * bax + (0,)]
                return dst.at[idx].set(src_row.astype(dst.dtype))

            new_layers[group.name] = jax.tree.map(
                write,
                self.state["layers"][group.name],
                ctx_state["layers"][group.name],
            )
        new_state = {
            "pos": self.state["pos"].at[slot].set(ctx_state["pos"][0]),
            "layers": new_layers,
        }
        if "pred" in self.state:
            new_state["pred"] = self.state["pred"]
        self.state = new_state
        self.cur_token = self.cur_token.at[slot, 0].set(first_token)
        self.slot_req[slot] = req_id

    def decode_step(self, params):
        out = self.step(params, {"token": self.cur_token}, self.state)
        self.state = out["state"]
        self.cur_token = out["next_token"]
        if "pred_stats" in out:
            # [predicted, spec_hit, cache_hit, miss, evicted] expert rows
            # this step, summed over layers and ranks (psum'd in-step)
            self.last_pred_stats = np.asarray(out["pred_stats"])
        # per-kind fault counters + per-peer detected tail (only emitted
        # by validated plans whose layers run the demand/predictive path)
        self.last_fault_stats = (
            np.asarray(out["fault_stats"]) if "fault_stats" in out else None
        )
        return np.asarray(out["next_token"][:, 0])

    def release(self, slot: int):
        self.slot_req[slot] = None

    def snapshot_slot(self, slot: int) -> dict:
        """Host-side copy of one slot's decode state in the ctx-transfer
        layout (batch dim 1), re-admittable verbatim via :meth:`admit` —
        the serving layer's evict-to-queue hook. ``token`` is the slot's
        pending input token (the last one it emitted). The shared
        predictive state ("pred") is per-RANK, not per-slot, and is
        deliberately not captured: eviction must not disturb the
        predictor/cache the other slots are hitting."""
        layers = {}
        for group in self.model.plan:
            stacked = group.scan and group.n_cycles > 1
            bax = 1 if stacked else 0

            def read(src, bax=bax):
                idx = (slice(None),) * bax + (slice(slot, slot + 1),)
                return np.asarray(src[idx])

            layers[group.name] = jax.tree.map(
                read, self.state["layers"][group.name]
            )
        return {
            "pos": np.asarray(self.state["pos"][slot:slot + 1]),
            "layers": layers,
            "token": int(np.asarray(self.cur_token[slot, 0])),
            "plan": self.restore_plan(),
        }

    def _subgroup_positions(self) -> np.ndarray:
        """Each flat rank's position within its expert-gather subgroup
        (the ``axis_index % subgroup_size`` the mirrored predictor
        indexes by), in the per-rank state-dim order."""
        sizes = self._mesh_sizes
        n = int(np.prod(list(sizes.values())))
        rem, coords = np.arange(n), {}
        for ax in reversed(list(sizes)):
            coords[ax] = rem % sizes[ax]
            rem = rem // sizes[ax]
        idx = np.zeros(n, np.int64)
        for ax in self.model.geom.expert_axes:
            idx = idx * sizes[ax] + coords[ax]
        return idx % self.model.geom.moe_placement.subgroup_size

    def routed_bitmaps(self, group: Optional[str] = None):
        """The LAST decode step's per-rank routed-expert bitmaps,
        ``(n_ranks, num_experts)`` bool, read from the predictive
        state's ``prev`` leaf (the serving trace-capture hook; None when
        the installed plan runs no predictive/sync-free layers).

        ``group`` picks the layer group (first predictive group in plan
        order by default); scan-stacked groups report their first cycle
        (one layer's routing — the shape the trace tooling consumes).
        Sync-free plans carry the mirrored per-subgroup-position view;
        each rank's OWN row is selected by its subgroup position."""
        pred = self.state.get("pred")
        if not pred:
            return None
        if group is None:
            group = next(
                g.name for g in self.model.plan if g.name in pred
            )
        gdict = pred[group]
        st = gdict[sorted(gdict)[0]]
        gobj = next(g for g in self.model.plan if g.name == group)
        prev = np.asarray(st.prev)
        if gobj.scan and gobj.n_cycles > 1:
            prev = prev[0]
        if prev.ndim == 3:  # mirrored: (n_ranks, G', e_pad) -> own row
            n_ranks = prev.shape[0]
            pos = self._subgroup_positions()
            prev = prev[np.arange(n_ranks), pos]
        return prev[:, : self.model.cfg.moe.num_experts].astype(bool)


class DisaggregatedEngine:
    """Queues + rate matching between context and generation servers."""

    def __init__(self, params, ctx: ContextServer, gen: GenerationServer,
                 health: Optional[HealthMonitor] = None,
                 scheduler: Optional[OnlinePolicyScheduler] = None):
        self.params = params
        self.ctx = ctx
        self.gen = gen
        self.health = health
        self.scheduler = scheduler
        self.queue: list[Request] = []
        self.records: dict[int, RequestRecord] = {}
        self.outputs: dict[int, list[int]] = {}
        self.metrics = ServingMetrics(num_gpus=1)
        self.t = 0.0

    def warmup(self) -> int:
        """Pre-compile the serving variants OFF the serving path: the
        prefill step plus every decode-policy variant the online
        scheduler can switch to (its bucket tables x budget rungs).
        After this, request traffic — including every scheduler switch
        and budget resize — runs with zero recompiles
        (``gen.variants.compiles()`` stays flat). Returns the number of
        decode variants compiled."""
        self.ctx.warmup(self.params)
        tables = (
            self.scheduler.candidate_tables(self.gen)
            if self.scheduler is not None else ()
        )
        return self.gen.warmup(self.params, tables)

    def submit(self, req: Request):
        # engine-shape validation (the Request itself checked basic
        # well-formedness at construction)
        buckets = getattr(self.ctx, "prefill_lens",
                          (self.ctx.prefill_len,))
        if len(req.tokens) not in buckets:
            raise ValueError(
                f"Request {req.req_id}: prompt length {len(req.tokens)} "
                f"matches no context-server bucket (prefill_lens="
                f"{buckets})"
            )
        if len(req.tokens) + req.target_len - 1 > self.gen.cache_len:
            raise ValueError(
                f"Request {req.req_id}: prompt ({len(req.tokens)}) + "
                f"output ({req.target_len}) tokens exceed the decode ring "
                f"capacity cache_len={self.gen.cache_len}"
            )
        self.queue.append(req)
        self.records[req.req_id] = RequestRecord(
            req_id=req.req_id,
            arrival=self.t,
            prompt_len=len(req.tokens),
            target_len=req.target_len,
        )
        self.outputs[req.req_id] = []

    def run(self, steps: int) -> ServingMetrics:
        """Drive the engine: each step = one decode iteration; free slots
        pull queued requests through the context server first."""
        for _ in range(steps):
            for slot in self.gen.free_slots():
                if not self.queue:
                    break
                req = self.queue.pop(0)
                first, state = self.ctx.prefill(self.params, req.tokens)
                rec = self.records[req.req_id]
                rec.first_token_time = self.t
                rec.tokens_out = 1
                rec.add_gather_share(self.ctx.gather_bytes)
                self.outputs[req.req_id].append(first)
                self.gen.admit(slot, req.req_id, first, state)
                self.gen.slot_remaining[slot] = req.target_len - 1
            if self.scheduler is not None:
                # re-resolve BEFORE the step so the bucket matches the
                # slots about to decode; drift input (last_pred_stats)
                # is the previous step's measured split
                moved = self.scheduler.step(
                    self.gen,
                    sum(r is not None for r in self.gen.slot_req),
                )
                if moved:
                    self.metrics.record_transition(
                        int(self.t), moved, self.gen.level,
                        self.gen.fetch_label,
                    )
            toks = self.gen.decode_step(self.params)
            self.t += 1.0
            from repro.core.faults import FAULT_STAT_BASE

            fs = self.gen.last_fault_stats
            if fs is not None:
                self.metrics.record_fault_stats(fs)
            if self.health is not None:
                if fs is not None:
                    tail = fs[FAULT_STAT_BASE:]
                elif self.health.ema.size:
                    # bottom-of-ladder ("all") plans run no per-peer
                    # payload rounds, so there is no fault signal — feed
                    # a clean observation so the EMAs decay and recovery
                    # can re-promote
                    tail = np.zeros_like(self.health.ema)
                else:
                    tail = None
                move = (
                    self.health.observe(tail) if tail is not None else None
                )
                if move == "demote":
                    # fail-silent demotions cap at the all-gather floor:
                    # the terminal "reshard" rung is reserved for the
                    # fail-stop (rank-death) path
                    if self.gen.set_level(
                        min(self.gen.level + 1,
                            self.gen.max_silent_level),
                        bad_peers=self.health.bad_peers(),
                    ):
                        self.metrics.record_transition(
                            int(self.t), "demote", self.gen.level,
                            self.gen.fetch_label,
                        )
                elif move == "promote" and self.gen.level > 0:
                    if self.gen.set_level(
                        self.gen.level - 1,
                        bad_peers=self.health.bad_peers(),
                    ):
                        self.metrics.record_transition(
                            int(self.t), "promote", self.gen.level,
                            self.gen.fetch_label,
                        )
            active = [r for r in self.gen.slot_req if r is not None]
            for slot, rid in enumerate(self.gen.slot_req):
                if rid is None:
                    continue
                rec = self.records[rid]
                # the decode step's gather traffic is shared by its
                # active slots: attribute each request its share
                rec.add_gather_share(
                    self.gen.gather_bytes, 1.0 / len(active)
                )
                if self.gen.last_pred_stats is not None and active:
                    # measured predictive counters (rows -> bytes), the
                    # step's share split over its active slots
                    rec.add_predict_share(
                        self.gen.last_pred_stats, self.gen.expert_bytes,
                        1.0 / len(active),
                    )
                self.outputs[rid].append(int(toks[slot]))
                rec.tokens_out += 1
                self.gen.slot_remaining[slot] -= 1
                if self.gen.slot_remaining[slot] <= 0:
                    rec.done_time = self.t
                    self.metrics.records.append(rec)
                    self.gen.release(slot)
        return self.metrics
