"""Serving metrics: TPS/user, TPS/GPU, TTFT (median, incl. queueing),
and per-request gathered-weight wire-byte counters — totals (full vs
demand-fetched) plus a per-family breakdown (moe_experts / attn_qkv /
attn_out / dense_ffn), so engine runs report both the on-demand fetch
savings and WHERE the gathered bytes go under a mixed PolicyTable."""
from __future__ import annotations

import dataclasses
import math
import statistics
from typing import Optional


def _pct(xs: list, q: float) -> float:
    """Nearest-rank percentile (q in (0, 1]); 0.0 on an empty sample —
    the zero-denominator contract every summary ratio follows."""
    if not xs:
        return 0.0
    s = sorted(xs)
    i = max(0, min(len(s) - 1, int(math.ceil(q * len(s))) - 1))
    return float(s[i])


@dataclasses.dataclass
class RequestRecord:
    req_id: int
    arrival: float
    prompt_len: int
    target_len: int
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    tokens_out: int = 0
    # gathered-weight wire bytes attributed to this request (its share of
    # every prefill/decode step it participated in): what the program
    # actually shipped vs the all-fetch counterfactual
    gathered_fetch_bytes: float = 0.0
    gathered_full_bytes: float = 0.0
    # the same, per gathered-weight family (execution.
    # gathered_wire_bytes_per_step's "families" breakdown)
    family_fetch_bytes: dict = dataclasses.field(default_factory=dict)
    family_full_bytes: dict = dataclasses.field(default_factory=dict)
    # predictive-fetch counters (MEASURED per decode step, not static):
    # bytes of expert rows speculatively prefetched, served from the
    # speculative round / residency cache (hits — these skipped the
    # post-routing wire round, counted separately so the sync-free bench
    # can attribute the win), correction-fetched (misses), and evicted
    # from the residency cache
    predicted_bytes: float = 0.0
    spec_hit_bytes: float = 0.0
    cache_hit_bytes: float = 0.0
    miss_bytes: float = 0.0
    evicted_bytes: float = 0.0
    # per-ROUND wire split of the gathered traffic (the "rounds" entry
    # of execution.gathered_wire_bytes_per_step): overlappable
    # speculative round vs on-critical-path correction round
    round_bytes: dict = dataclasses.field(default_factory=dict)

    @property
    def hit_bytes(self) -> float:
        """Aggregate hit bytes (speculative + cache) — the pre-split
        counter, kept as a derived value for compatibility."""
        return self.spec_hit_bytes + self.cache_hit_bytes

    def add_gather_share(self, gather_bytes: dict, share: float = 1.0):
        """Attribute ``share`` of one step's gathered-weight traffic
        (an ``execution.gathered_wire_bytes_per_step`` dict) to this
        request — totals, the per-family breakdown, and the per-round
        split together."""
        self.gathered_fetch_bytes += gather_bytes["fetched"] * share
        self.gathered_full_bytes += gather_bytes["full"] * share
        for fam, b in gather_bytes.get("families", {}).items():
            self.family_fetch_bytes[fam] = (
                self.family_fetch_bytes.get(fam, 0.0) + b["fetched"] * share
            )
            self.family_full_bytes[fam] = (
                self.family_full_bytes.get(fam, 0.0) + b["full"] * share
            )
        for rnd, b in gather_bytes.get("rounds", {}).items():
            self.round_bytes[rnd] = (
                self.round_bytes.get(rnd, 0.0) + b * share
            )

    def add_predict_share(self, stats, expert_bytes: float,
                          share: float = 1.0):
        """Attribute ``share`` of one decode step's measured predictive
        counters (``[predicted, spec_hit, cache_hit, corr, evicted]``
        expert ROWS — the engine's ``pred_stats`` output) to this
        request, in bytes."""
        pred, spec_hit, cache_hit, miss, evicted = (
            float(s) for s in stats
        )
        self.predicted_bytes += pred * expert_bytes * share
        self.spec_hit_bytes += spec_hit * expert_bytes * share
        self.cache_hit_bytes += cache_hit * expert_bytes * share
        self.miss_bytes += miss * expert_bytes * share
        self.evicted_bytes += evicted * expert_bytes * share

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def tps_user(self) -> Optional[float]:
        if self.done_time is None or self.first_token_time is None:
            return None
        dur = self.done_time - self.first_token_time
        if dur <= 0:
            return None
        return (self.tokens_out - 1) / dur

    @property
    def tpot(self) -> Optional[float]:
        """Mean time-per-output-token over the decode phase (excludes
        the prefill-emitted first token); None until the request is done
        or when it produced a single token."""
        if self.done_time is None or self.first_token_time is None:
            return None
        if self.tokens_out < 2:
            return None
        return (self.done_time - self.first_token_time) / (
            self.tokens_out - 1
        )


@dataclasses.dataclass
class ServingMetrics:
    records: list = dataclasses.field(default_factory=list)
    num_gpus: int = 1
    # cumulative per-kind fault counters from the validated fetch path
    # (faults.FAULT_STAT_NAMES) + per-subgroup-position detected counts
    fault_counts: dict = dataclasses.field(default_factory=dict)
    detected_by_peer: list = dataclasses.field(default_factory=list)
    # HealthMonitor ladder moves + online-scheduler policy switches /
    # budget resizes: {"step", "kind", "level", "fetch"}
    policy_transitions: list = dataclasses.field(default_factory=list)
    # SLO-admission outcome counters fed by the serving layer
    # (admitted / queued / rejected / evicted / resumed)
    admission: dict = dataclasses.field(default_factory=dict)
    # fail-stop recovery accounting (rank_death events): cumulative
    # counters + per-event recovery stalls in seconds
    recovery: dict = dataclasses.field(default_factory=dict)
    recovery_times: list = dataclasses.field(default_factory=list)

    def record_admission(self, kind: str, n: int = 1):
        self.admission[kind] = self.admission.get(kind, 0) + int(n)

    def record_rank_death(self, *, migrated: int = 0, requeued: int = 0,
                          seconds: float = 0.0):
        """Account one gen-rank fail-stop recovery: how many in-flight
        slots migrated bitwise (survivor KV) vs requeued from prompt
        (their KV shard died), and the measured/modeled time from kill
        to the first post-recovery decode step."""
        for k, v in (("rank_deaths", 1), ("migrated", int(migrated)),
                     ("requeued", int(requeued))):
            self.recovery[k] = self.recovery.get(k, 0) + v
        self.recovery_times.append(float(seconds))

    def record_fault_stats(self, vec):
        """Accumulate one decode step's psum'd fault-stats vector
        (``out["fault_stats"]``: the named counters followed by the
        per-source-position detected tail)."""
        from repro.core.faults import FAULT_STAT_BASE, FAULT_STAT_NAMES

        vec = [float(v) for v in vec]
        for name, v in zip(FAULT_STAT_NAMES, vec[:FAULT_STAT_BASE]):
            self.fault_counts[name] = self.fault_counts.get(name, 0.0) + v
        tail = vec[FAULT_STAT_BASE:]
        if len(self.detected_by_peer) < len(tail):
            self.detected_by_peer += [0.0] * (
                len(tail) - len(self.detected_by_peer)
            )
        for i, v in enumerate(tail):
            self.detected_by_peer[i] += v

    def record_transition(self, step: int, kind: str, level: int,
                          fetch: str):
        self.policy_transitions.append(
            {"step": step, "kind": kind, "level": level, "fetch": fetch}
        )

    def summary(self, horizon: float) -> dict:
        done = [r for r in self.records if r.done_time is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tps_users = [t for t in (r.tps_user for r in done) if t]
        total_tokens = sum(r.tokens_out for r in done)
        fetch_b = sum(r.gathered_fetch_bytes for r in done)
        full_b = sum(r.gathered_full_bytes for r in done)
        out = {
            "completed": len(done),
            "median_ttft_s": statistics.median(ttfts) if ttfts else None,
            "mean_tps_user": (
                sum(tps_users) / len(tps_users) if tps_users else None
            ),
            "tps_per_gpu": total_tokens / horizon / self.num_gpus,
            "total_output_tokens": total_tokens,
        }
        # TTFT / TPOT tail percentiles: ALWAYS present and 0.0 on an
        # empty sample (the gather_fetch_ratio contract) so SLO
        # dashboards and the serving bench never branch on key presence
        tpots = [t for t in (r.tpot for r in done) if t is not None]
        for stat, xs in (("ttft", ttfts), ("tpot", tpots)):
            for q in (0.50, 0.95, 0.99):
                out[f"{stat}_p{int(q * 100)}_s"] = round(_pct(xs, q), 6)
        # fail-stop recovery counters: ALWAYS present (0 / 0.0 when no
        # rank ever died — the same zero-denominator contract as the
        # percentiles above, so dashboards never branch on key
        # presence)
        for key in ("rank_deaths", "migrated", "requeued"):
            out[key] = int(self.recovery.get(key, 0))
        for q in (0.50, 0.95):
            out[f"time_to_recover_p{int(q * 100)}_s"] = round(
                _pct(self.recovery_times, q), 6
            )
        if self.admission:
            out["admission"] = dict(sorted(self.admission.items()))
        # ratio fields are ALWAYS present and 0.0 on a zero denominator
        # (empty or fault-aborted runs must not divide by zero or make
        # downstream consumers branch on key presence)
        out["gather_fetch_ratio"] = (
            round(fetch_b / full_b, 4) if full_b else 0.0
        )
        if full_b:
            out["gathered_mb_fetched"] = round(fetch_b / 1e6, 3)
            out["gathered_mb_full"] = round(full_b / 1e6, 3)
            by_fam: dict = {}
            for r in done:
                for fam, b in r.family_fetch_bytes.items():
                    by_fam.setdefault(fam, [0.0, 0.0])[0] += b
                for fam, b in r.family_full_bytes.items():
                    by_fam.setdefault(fam, [0.0, 0.0])[1] += b
            if by_fam:
                out["gathered_mb_by_family"] = {
                    fam: {
                        "fetched": round(fb / 1e6, 3),
                        "full": round(fl / 1e6, 3),
                    }
                    for fam, (fb, fl) in sorted(by_fam.items())
                    if fl > 0
                }
        pred_b = sum(r.predicted_bytes for r in done)
        spec_b = sum(r.spec_hit_bytes for r in done)
        cache_b = sum(r.cache_hit_bytes for r in done)
        hit_b = spec_b + cache_b
        miss_b = sum(r.miss_bytes for r in done)
        evic_b = sum(r.evicted_bytes for r in done)
        # fraction of the wanted remote rows served without the
        # post-routing correction round (cache + speculative hits);
        # 0.0 — not a KeyError or a ZeroDivisionError — when nothing
        # decoded predictively. The aggregate stays for compatibility;
        # the split rates attribute the win between the speculative
        # round and the residency cache.
        denom = hit_b + miss_b
        out["predict_hit_rate"] = (
            round(hit_b / denom, 4) if denom else 0.0
        )
        out["spec_hit_rate"] = round(spec_b / denom, 4) if denom else 0.0
        out["cache_hit_rate"] = round(cache_b / denom, 4) if denom else 0.0
        if pred_b or hit_b or miss_b:
            out["predict_mb_predicted"] = round(pred_b / 1e6, 3)
            out["predict_mb_hit"] = round(hit_b / 1e6, 3)
            out["predict_mb_spec_hit"] = round(spec_b / 1e6, 3)
            out["predict_mb_cache_hit"] = round(cache_b / 1e6, 3)
            out["predict_mb_miss"] = round(miss_b / 1e6, 3)
            out["predict_mb_evicted"] = round(evic_b / 1e6, 3)
        rounds: dict = {}
        for r in done:
            for rnd, b in r.round_bytes.items():
                rounds[rnd] = rounds.get(rnd, 0.0) + b
        if rounds:
            out["gathered_mb_by_round"] = {
                rnd: round(b / 1e6, 3) for rnd, b in sorted(rounds.items())
            }
        if self.fault_counts and any(self.fault_counts.values()):
            out["faults"] = {
                k: round(v, 1) for k, v in sorted(self.fault_counts.items())
            }
            out["detected_by_peer"] = [
                round(v, 1) for v in self.detected_by_peer
            ]
        if self.policy_transitions:
            out["policy_transitions"] = list(self.policy_transitions)
            # decision-loop counters: health-ladder moves vs the online
            # scheduler's zero-recompile switches / budget resizes
            for kind, field in (("switch", "policy_switches"),
                                ("resize", "budget_resizes"),
                                ("demote", "ladder_demotions"),
                                ("promote", "ladder_promotions")):
                n = sum(
                    1 for t in self.policy_transitions
                    if t["kind"] == kind
                )
                if n:
                    out[field] = n
        return out
