"""Serving metrics: TPS/user, TPS/GPU, TTFT (median, incl. queueing)."""
from __future__ import annotations

import dataclasses
import statistics
from typing import Optional


@dataclasses.dataclass
class RequestRecord:
    req_id: int
    arrival: float
    prompt_len: int
    target_len: int
    first_token_time: Optional[float] = None
    done_time: Optional[float] = None
    tokens_out: int = 0

    @property
    def ttft(self) -> Optional[float]:
        if self.first_token_time is None:
            return None
        return self.first_token_time - self.arrival

    @property
    def tps_user(self) -> Optional[float]:
        if self.done_time is None or self.first_token_time is None:
            return None
        dur = self.done_time - self.first_token_time
        if dur <= 0:
            return None
        return (self.tokens_out - 1) / dur


@dataclasses.dataclass
class ServingMetrics:
    records: list = dataclasses.field(default_factory=list)
    num_gpus: int = 1

    def summary(self, horizon: float) -> dict:
        done = [r for r in self.records if r.done_time is not None]
        ttfts = [r.ttft for r in done if r.ttft is not None]
        tps_users = [t for t in (r.tps_user for r in done) if t]
        total_tokens = sum(r.tokens_out for r in done)
        return {
            "completed": len(done),
            "median_ttft_s": statistics.median(ttfts) if ttfts else None,
            "mean_tps_user": (
                sum(tps_users) / len(tps_users) if tps_users else None
            ),
            "tps_per_gpu": total_tokens / horizon / self.num_gpus,
            "total_output_tokens": total_tokens,
        }
