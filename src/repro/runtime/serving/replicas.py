"""Multi-replica data-parallel scale-out: router + independent clocks.

N replicas — each its own ``ServingScheduler`` over its own client —
behind a least-loaded router with a warm-bucket locality tie-break
(prefer the replica whose context server already pre-compiled the
request's prefill-length bucket). Replicas NEVER synchronize: each runs
to drain on its own clock (simulated or wall), so a straggler replica
slows only its own users — the data-parallel independence DWDP's
sync-free decode preserves inside each replica, lifted one level up.

The merged metrics normalize by the fleet's total GPUs and the SLOWEST
replica's horizon (the fleet is "done" when its last replica is), which
is exactly what makes skewed/straggler fleets show up in TPS/GPU.
"""
from __future__ import annotations

from typing import Optional

from repro.runtime.metrics import ServingMetrics


class ReplicaRouter:
    """Least-loaded routing with warm-bucket locality tie-break."""

    def pick(self, schedulers, req) -> int:
        def key(i):
            s = schedulers[i]
            cold = not s.client.has_bucket(req.prompt_len)
            # load first (least-loaded), then locality (warm prefill
            # bucket), then index (stable)
            return (s.load(), cold, i)

        return min(range(len(schedulers)), key=key)


class MultiReplicaEngine:
    def __init__(self, schedulers, router: Optional[ReplicaRouter] = None):
        if not schedulers:
            raise ValueError("MultiReplicaEngine needs >= 1 replica")
        self.schedulers = list(schedulers)
        self.router = router if router is not None else ReplicaRouter()
        self.assignments: dict[int, int] = {}  # req_id -> replica

    def submit(self, reqs) -> None:
        """Route requests (arrival order) to replicas. Routing reads
        each replica's CURRENT backlog, so an imbalanced fleet fills the
        fast replicas first."""
        for req in sorted(reqs, key=lambda r: (r.arrival, r.req_id)):
            i = self.router.pick(self.schedulers, req)
            self.assignments[req.req_id] = i
            self.schedulers[i].submit([req])

    def run(self, max_steps: Optional[int] = None) -> ServingMetrics:
        """Run every replica to drain, each on its OWN clock — no
        cross-replica barrier of any kind — then merge."""
        for s in self.schedulers:
            s.run(max_steps)
        return self.merged_metrics()

    def horizon(self) -> float:
        return max(s.t for s in self.schedulers)

    def kill_rank(self, replica_idx: int, dead_rank: int) -> dict:
        """Fail-stop one gen rank of one replica. The owner quarantines
        the rank and re-plans onto its survivors
        (``ServingScheduler.quarantine_rank``); its migrated in-flight
        requests — bitwise snapshots attached — re-route through the
        router to the LEAST-LOADED replica whose client can admit the
        snapshot's plan (``client.can_resume``; the re-planned owner is
        back in the pool when plan-compatible, which is what keeps the
        post-recovery fleet balanced). Record and emitted stream travel
        with the migrant, so TTFT stands and the stream resumes
        mid-sentence. Requeued requests stay at the head of the owner's
        queue and replay from their prompt. When NO replica accepts the
        plan the migrant falls back to the owner, whose admit path
        (``validate_restore_plan``) downgrades it to a prompt replay.
        No accepted request is ever dropped."""
        src = self.schedulers[replica_idx]
        moved = src.quarantine_rank(dead_rank)
        for req, rec, outputs in moved:
            plan = (req.resume or {}).get("plan")
            cands = [
                i for i, s in enumerate(self.schedulers)
                if getattr(s.client, "can_resume", lambda p: True)(plan)
            ]
            if cands:
                i = min(cands, key=lambda j: self.schedulers[j].load())
            else:
                i = replica_idx
            self.schedulers[i].adopt(req, rec, outputs)
            self.assignments[req.req_id] = i
        return {
            "migrated": len(moved),
            "requeued": int(src.metrics.recovery.get("requeued", 0)),
        }

    def merged_metrics(self) -> ServingMetrics:
        out = ServingMetrics(
            num_gpus=sum(s.metrics.num_gpus for s in self.schedulers)
        )
        for s in self.schedulers:
            out.records.extend(s.metrics.records)
            for k, v in s.metrics.admission.items():
                out.record_admission(k, v)
            for k, v in s.metrics.recovery.items():
                out.recovery[k] = out.recovery.get(k, 0) + v
            out.recovery_times.extend(s.metrics.recovery_times)
        return out
