"""Multi-replica data-parallel scale-out: router + independent clocks.

N replicas — each its own ``ServingScheduler`` over its own client —
behind a least-loaded router with a warm-bucket locality tie-break
(prefer the replica whose context server already pre-compiled the
request's prefill-length bucket). Replicas NEVER synchronize: each runs
to drain on its own clock (simulated or wall), so a straggler replica
slows only its own users — the data-parallel independence DWDP's
sync-free decode preserves inside each replica, lifted one level up.

The merged metrics normalize by the fleet's total GPUs and the SLOWEST
replica's horizon (the fleet is "done" when its last replica is), which
is exactly what makes skewed/straggler fleets show up in TPS/GPU.
"""
from __future__ import annotations

from typing import Optional

from repro.runtime.metrics import ServingMetrics


class ReplicaRouter:
    """Least-loaded routing with warm-bucket locality tie-break."""

    def pick(self, schedulers, req) -> int:
        def key(i):
            s = schedulers[i]
            cold = not s.client.has_bucket(req.prompt_len)
            # load first (least-loaded), then locality (warm prefill
            # bucket), then index (stable)
            return (s.load(), cold, i)

        return min(range(len(schedulers)), key=key)


class MultiReplicaEngine:
    def __init__(self, schedulers, router: Optional[ReplicaRouter] = None):
        if not schedulers:
            raise ValueError("MultiReplicaEngine needs >= 1 replica")
        self.schedulers = list(schedulers)
        self.router = router if router is not None else ReplicaRouter()
        self.assignments: dict[int, int] = {}  # req_id -> replica

    def submit(self, reqs) -> None:
        """Route requests (arrival order) to replicas. Routing reads
        each replica's CURRENT backlog, so an imbalanced fleet fills the
        fast replicas first."""
        for req in sorted(reqs, key=lambda r: (r.arrival, r.req_id)):
            i = self.router.pick(self.schedulers, req)
            self.assignments[req.req_id] = i
            self.schedulers[i].submit([req])

    def run(self, max_steps: Optional[int] = None) -> ServingMetrics:
        """Run every replica to drain, each on its OWN clock — no
        cross-replica barrier of any kind — then merge."""
        for s in self.schedulers:
            s.run(max_steps)
        return self.merged_metrics()

    def horizon(self) -> float:
        return max(s.t for s in self.schedulers)

    def merged_metrics(self) -> ServingMetrics:
        out = ServingMetrics(
            num_gpus=sum(s.metrics.num_gpus for s in self.schedulers)
        )
        for s in self.schedulers:
            out.records.extend(s.metrics.records)
            for k, v in s.metrics.admission.items():
                out.record_admission(k, v)
        return out
