"""Production serving layer above the disaggregated engine (paper §5).

The engine (`runtime/engine.py`) is one correct replica: a context
server, a slot-based generation server, and bitwise-exact fetch paths.
This package is the system the paper actually evaluates on top of that:

- :mod:`workload` — seeded request synthesis from configurable ISL/OSL
  distributions (per-replica skew included) + the request lifecycle
  dataclass.
- :mod:`admission` — the SLO-aware admission controller: target
  TPS/user and TTFT budget, queue/reject decisions from the projected
  per-user decode rate, evict-to-queue on sustained violation.
- :mod:`scheduler` — the continuous-batching scheduler: admits into
  decode slots as they free (no fixed-slot epochs; ``epoch_mode``
  keeps the fixed-slot reference for the bitwise regression tests).
- :mod:`replicas` — multi-replica data-parallel scale-out: N
  independent replicas behind a least-loaded / warm-bucket-locality
  router, each progressing on its OWN clock with zero cross-replica
  synchronization (the imbalance scenario sync-free decode exists for).
  ``MultiReplicaEngine.kill_rank`` is the fail-stop entry point: the
  owning replica quarantines the dead gen rank and re-plans onto its
  survivors, migrated in-flight requests resume bitwise on other
  replicas, requeued ones replay from their prompt (docs/
  robustness.md) — no accepted request is ever dropped.
- :mod:`modeled` — a replica client backed by the roofline-modelled
  ``ClusterSimulator`` service times (what the serving bench sweeps).
- :mod:`live` — a replica client over live ctx/gen servers (real
  arrays; used by ``launch/serve.py --serving`` and the trace-capture
  fixture recorder).

See docs/serving.md for the admission state machine and how
``BENCH_serving_sweep.json`` maps to the paper's TPS/GPU-at-fixed-
TPS/user claim.
"""
from repro.runtime.serving.admission import (            # noqa: F401
    ADMIT, QUEUE, REJECT, AdmissionController, SLOConfig,
)
from repro.runtime.serving.live import (                 # noqa: F401
    LiveReplicaClient, RoutedTraceRecorder,
)
from repro.runtime.serving.modeled import ModeledReplicaClient  # noqa: F401
from repro.runtime.serving.replicas import (             # noqa: F401
    MultiReplicaEngine, ReplicaRouter,
)
from repro.runtime.serving.scheduler import ServingScheduler    # noqa: F401
from repro.runtime.serving.workload import (             # noqa: F401
    ServedRequest, WorkloadConfig, synthesize_workload,
)
