"""SLO-aware admission control (docs/serving.md state machine).

The controller gates every admission on the PROJECTED per-user decode
rate: admitting into a batch of ``active + 1`` slots gives every user
``1 / step_time(active + 1)`` tokens/s (one token per user per decode
step — the engine's slot semantics), so an admission that would drag
the fleet below ``target_tps_user`` holds the request in the queue
instead. A queued request whose wait has already blown the TTFT budget
is shed (rejected) rather than served dead-on-arrival, as is anything
beyond ``max_queue``. On the drain side, ``evict_after`` consecutive
decode steps measured below target trip an evict-to-queue of the
youngest slot — shrinking the batch until the surviving users meet the
target again.

``step_time_fn(batch) -> seconds`` is the projection: the modeled
client hands in the roofline simulator's ``gen_step_time``, the live
client an EMA of measured step durations per batch bucket.
"""
from __future__ import annotations

import dataclasses
from typing import Callable

ADMIT = "admit"
QUEUE = "queue"
REJECT = "reject"


@dataclasses.dataclass(frozen=True)
class SLOConfig:
    target_tps_user: float = 0.0   # tokens/s/user floor (0 = no gate)
    ttft_budget_s: float = 0.0     # max queue wait before shedding
                                   # (0 = never shed on wait)
    max_queue: int = 0             # queued requests before shedding
                                   # (0 = unbounded queue)
    evict_after: int = 8           # consecutive violating steps before
                                   # an evict-to-queue fires

    def __post_init__(self):
        if self.target_tps_user < 0 or self.ttft_budget_s < 0:
            raise ValueError("SLO targets must be >= 0")
        if self.evict_after < 1:
            raise ValueError(
                f"evict_after must be >= 1, got {self.evict_after}"
            )


class AdmissionController:
    """One replica's admission gate + sustained-violation detector."""

    def __init__(self, slo: SLOConfig,
                 step_time_fn: Callable[[int], float]):
        self.slo = slo
        self.step_time_fn = step_time_fn
        self._violations = 0
        self.counters = {
            "admitted": 0, "queued": 0, "rejected": 0,
            "evicted": 0, "resumed": 0,
        }

    def projected_tps_user(self, batch: int) -> float:
        t = self.step_time_fn(max(1, batch))
        return 1.0 / t if t > 0 else float("inf")

    def decide(self, *, active: int, queue_len: int,
               queued_for: float) -> str:
        """ADMIT / QUEUE / REJECT for the queue's head request.
        ``queued_for`` is how long it has already waited."""
        slo = self.slo
        # shed what can no longer meet its TTFT budget — the queue wait
        # alone has blown it, serving the request would report a dead SLO
        if slo.ttft_budget_s and queued_for > slo.ttft_budget_s:
            return REJECT
        rate_ok = (
            not slo.target_tps_user
            or self.projected_tps_user(active + 1) >= slo.target_tps_user
        )
        # an idle replica always admits: batch-1 is the best rate any
        # user can get here — holding the queue would starve forever
        if rate_ok or active == 0:
            return ADMIT
        if slo.max_queue and queue_len >= slo.max_queue:
            return REJECT
        return QUEUE

    def observe_step(self, step_time: float, active: int) -> bool:
        """Feed one measured decode step; True when the sustained-
        violation eviction should fire (the streak then resets)."""
        slo = self.slo
        if not slo.target_tps_user or active < 2 or step_time <= 0:
            self._violations = 0
            return False
        if 1.0 / step_time < slo.target_tps_user:
            self._violations += 1
        else:
            self._violations = 0
        if self._violations >= slo.evict_after:
            self._violations = 0
            return True
        return False

    def count(self, kind: str, n: int = 1) -> None:
        self.counters[kind] = self.counters.get(kind, 0) + n
