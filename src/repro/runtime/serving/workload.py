"""Seeded serving workloads: per-request ISL/OSL draws + lifecycle.

Requests draw their prompt length from configurable BUCKETS (the pow2
prefill-length buckets the context server pre-compiles) with optional
weights — skewing the weights per replica is how the bench builds the
imbalanced fleet — and their output length from a jittered mean.
Arrivals are Poisson at ``arrival_rate`` (0 = closed loop: everything
arrives at t=0 and concurrency is capped by the decode slots).
Everything is deterministic from ``seed``.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

import numpy as np


@dataclasses.dataclass
class ServedRequest:
    """One request's serving lifecycle (see docs/serving.md for the
    state machine): arrived -> admitted | queued | rejected; active ->
    evicted (back to the queue, decode state snapshotted in ``resume``)
    -> resumed; active -> done."""

    req_id: int
    prompt_len: int
    target_len: int
    arrival: float = 0.0
    tokens: Optional[np.ndarray] = None   # live clients prefill these
    # evict-to-queue bookkeeping: the GenerationServer.snapshot_slot
    # payload + output tokens still owed when the snapshot was taken
    resume: Optional[dict] = None
    remaining: Optional[int] = None

    def __post_init__(self):
        if self.prompt_len < 1:
            raise ValueError(
                f"Request {self.req_id}: prompt_len must be >= 1, "
                f"got {self.prompt_len}"
            )
        if self.target_len < 1:
            raise ValueError(
                f"Request {self.req_id}: target_len must be >= 1, "
                f"got {self.target_len}"
            )


@dataclasses.dataclass(frozen=True)
class WorkloadConfig:
    """Distribution spec of one replica's traffic."""

    num_requests: int
    isl_buckets: tuple = (64,)     # prompt-length buckets (pow2 on live
                                   # engines — the ctx variant buckets)
    isl_weights: tuple = ()        # bucket draw weights (uniform if empty)
    osl: int = 16                  # mean output tokens
    osl_jitter: float = 0.0        # uniform +/- fraction of the mean
    arrival_rate: float = 0.0      # Poisson req/s; 0 = closed loop (t=0)
    seed: int = 0

    def __post_init__(self):
        if self.num_requests < 0:
            raise ValueError(f"num_requests >= 0, got {self.num_requests}")
        if not self.isl_buckets:
            raise ValueError("isl_buckets must name at least one bucket")
        if self.isl_weights and len(self.isl_weights) != len(
                self.isl_buckets):
            raise ValueError(
                f"isl_weights ({len(self.isl_weights)}) must match "
                f"isl_buckets ({len(self.isl_buckets)})"
            )
        if not 0.0 <= self.osl_jitter < 1.0:
            raise ValueError(
                f"osl_jitter must lie in [0, 1), got {self.osl_jitter}"
            )


def synthesize_workload(
    cfg: WorkloadConfig,
    *,
    vocab_size: int = 0,
    req_id_base: int = 0,
) -> list[ServedRequest]:
    """Deterministic request list from a workload spec, arrival-sorted.
    ``vocab_size > 0`` additionally materializes prompt token arrays
    (live engines need them; modeled clients only price lengths)."""
    rng = np.random.default_rng(cfg.seed)
    weights = None
    if cfg.isl_weights:
        w = np.asarray(cfg.isl_weights, np.float64)
        weights = w / w.sum()
    lens = rng.choice(
        np.asarray(cfg.isl_buckets, np.int64),
        size=cfg.num_requests, p=weights,
    )
    if cfg.osl_jitter > 0.0:
        osls = np.maximum(1, np.round(
            cfg.osl * rng.uniform(
                1.0 - cfg.osl_jitter, 1.0 + cfg.osl_jitter,
                cfg.num_requests,
            )
        ).astype(np.int64))
    else:
        osls = np.full(cfg.num_requests, max(1, cfg.osl), np.int64)
    if cfg.arrival_rate > 0.0:
        arrivals = np.cumsum(
            rng.exponential(1.0 / cfg.arrival_rate, cfg.num_requests)
        )
    else:
        arrivals = np.zeros(cfg.num_requests)
    out = []
    for i in range(cfg.num_requests):
        tokens = None
        if vocab_size > 0:
            tokens = rng.integers(
                0, vocab_size, int(lens[i])
            ).astype(np.int32)
        out.append(ServedRequest(
            req_id=req_id_base + i,
            prompt_len=int(lens[i]),
            target_len=int(osls[i]),
            arrival=float(arrivals[i]),
            tokens=tokens,
        ))
    return out
