"""Replica client over live ctx/gen servers (real arrays).

Wraps one (params, ContextServer, GenerationServer) trio — usually an
existing ``DisaggregatedEngine``'s — behind the scheduler's client
surface: admissions run a real bucketed prefill, decode ticks run the
real jitted step, evictions snapshot the slot's decode state
bitwise (``GenerationServer.snapshot_slot``) for later re-admission,
and the per-request gathered/predictive byte attribution matches the
engine loop's. Durations are measured wall time; the admission
projection is an EMA of measured step durations per batch size.

``RoutedTraceRecorder`` is the trace-capture hook: pass one as the
scheduler's ``on_step`` to collect each decode step's REAL per-rank
routed-expert bitmaps (``GenerationServer.routed_bitmaps``), then feed
``core.traces.from_served_trace`` — how the committed served-routing
fixture was recorded.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np


class LiveReplicaClient:
    def __init__(self, params, ctx, gen, *, num_gpus: int = 1,
                 standby=None):
        self.params = params
        self.ctx = ctx
        self.gen = gen
        self.num_slots = gen.max_batch
        self.num_gpus = num_gpus
        self.standby = standby      # pre-built shrunk-mesh engine the
                                    # fail-stop path swaps to (see
                                    # kill_rank); pre-warm its variant
                                    # cache for zero-recompile recovery
        self._step_ema: dict[int, float] = {}

    @classmethod
    def from_engine(cls, engine, *, num_gpus: int = 1, standby=None):
        return cls(engine.params, engine.ctx, engine.gen,
                   num_gpus=num_gpus, standby=standby)

    def warmup(self, tables=()) -> int:
        self.ctx.warmup(self.params)
        return self.gen.warmup(self.params, tables)

    def admit(self, slot: int, req) -> tuple:
        t0 = time.perf_counter()
        if req.resume is not None:
            self.gen.admit(slot, req.req_id, req.resume["token"],
                           req.resume)
            return None, time.perf_counter() - t0
        first, state = self.ctx.prefill(self.params, req.tokens)
        self.gen.admit(slot, req.req_id, first, state)
        return first, time.perf_counter() - t0

    def attribute_admit(self, rec) -> None:
        rec.add_gather_share(self.ctx.gather_bytes)

    def step(self, active: list) -> tuple:
        t0 = time.perf_counter()
        toks = self.gen.decode_step(self.params)
        dur = time.perf_counter() - t0
        b = len(active)
        ema = self._step_ema.get(b)
        self._step_ema[b] = dur if ema is None else 0.7 * ema + 0.3 * dur
        return toks, dur

    def attribute_step(self, recs) -> None:
        share = 1.0 / max(1, len(recs))
        for rec in recs:
            rec.add_gather_share(self.gen.gather_bytes, share)
            if self.gen.last_pred_stats is not None:
                rec.add_predict_share(
                    self.gen.last_pred_stats, self.gen.expert_bytes,
                    share,
                )

    def step_time(self, batch: int) -> float:
        b = max(1, int(batch))
        if b in self._step_ema:
            return self._step_ema[b]
        if self._step_ema:
            # nearest measured batch — decode steps vary slowly in batch
            near = min(self._step_ema, key=lambda k: abs(k - b))
            return self._step_ema[near]
        return 0.0  # no measurement yet: admission never blocks on it

    def release(self, slot: int) -> None:
        self.gen.release(slot)

    def evict(self, slot: int) -> dict:
        snap = self.gen.snapshot_slot(slot)
        self.gen.release(slot)
        return snap

    def kill_rank(self, dead_rank: int, active_slots=()) -> dict:
        """Fail-stop one gen rank: quarantine it and swap to the
        pre-built ``standby`` engine — ``strategy.resolve_policies``
        re-resolved at the survivors' mesh sizes, split banks
        re-sharded from SOURCE weights (checkpoint recovery — the dead
        peer is never read), and its own pre-warmed variant cache so
        the swap triggers no recompile.

        The decode batch is sharded over the mesh's data axis, so a
        slot's KV rows live on one data shard: slots on the dead rank's
        data row lost their KV and requeue from their prompt; every
        other active slot is snapshotted BITWISE from its surviving
        shard (``snapshot_slot`` before the swap) and migrates. Returns
        ``{"migrate": {slot: snapshot}, "requeue": [slots], "seconds",
        "wire_bytes"}``; seconds is measured swap wall time floored by
        the modeled re-shard stall."""
        if self.standby is None:
            raise ValueError(
                "kill_rank needs a pre-built standby engine "
                "(LiveReplicaClient(..., standby=...))"
            )
        from repro.core import roofline

        t0 = time.perf_counter()
        gen = self.gen
        sizes = dict(gen._mesh_sizes)
        data = int(sizes.get("data", 1))
        model_size = max(
            1, int(np.prod([v for a, v in sizes.items() if a != "data"]))
        )
        g = data * model_size
        dead = int(dead_rank) % g
        dead_row = dead // model_size  # flat ranks are data-major
        rows_per = max(1, gen.max_batch // max(1, data))
        migrate, requeue = {}, []
        for slot in active_slots:
            if slot // rows_per == dead_row:
                requeue.append(int(slot))
            else:
                migrate[int(slot)] = gen.snapshot_slot(slot)
        sb = self.standby
        if sb.gen.max_batch != gen.max_batch:
            raise ValueError(
                "standby engine must keep the decode slot count: "
                f"{sb.gen.max_batch} != {gen.max_batch}"
            )
        self.params, self.ctx, self.gen = sb.params, sb.ctx, sb.gen
        self.standby = None
        self.num_gpus = max(1, self.num_gpus - 1)
        self._step_ema.clear()
        rec = roofline.rank_death_recovery(gen.model.cfg, group=g)
        return {
            "migrate": migrate,
            "requeue": requeue,
            "seconds": max(time.perf_counter() - t0, rec["seconds"]),
            "wire_bytes": rec["wire_bytes"] + rec["source_bytes"],
        }

    def can_resume(self, plan) -> bool:
        """True when a snapshot stamped with ``plan`` restores bitwise
        on THIS replica's active plan — the router's probe for routing
        migrants after a fail-stop (a re-planned owner rejects its own
        pre-death snapshots; a same-plan peer accepts them)."""
        from repro.runtime.engine import validate_restore_plan

        try:
            validate_restore_plan(plan, self.gen.restore_plan())
        except ValueError:
            return False
        return True

    def has_bucket(self, prompt_len: int) -> bool:
        return prompt_len in self.ctx.prefill_lens


class RoutedTraceRecorder:
    """Scheduler ``on_step`` hook collecting per-step routed bitmaps."""

    def __init__(self, group: Optional[str] = None):
        self.group = group
        self.bitmaps: list = []

    def __call__(self, client) -> None:
        bm = client.gen.routed_bitmaps(self.group)
        if bm is not None:
            self.bitmaps.append(bm)

    def as_array(self) -> np.ndarray:
        """(steps, ranks, num_experts) bool."""
        return np.stack(self.bitmaps)

    def save(self, path: str) -> None:
        np.savez_compressed(path, bitmaps=self.as_array())
