"""Replica client over live ctx/gen servers (real arrays).

Wraps one (params, ContextServer, GenerationServer) trio — usually an
existing ``DisaggregatedEngine``'s — behind the scheduler's client
surface: admissions run a real bucketed prefill, decode ticks run the
real jitted step, evictions snapshot the slot's decode state
bitwise (``GenerationServer.snapshot_slot``) for later re-admission,
and the per-request gathered/predictive byte attribution matches the
engine loop's. Durations are measured wall time; the admission
projection is an EMA of measured step durations per batch size.

``RoutedTraceRecorder`` is the trace-capture hook: pass one as the
scheduler's ``on_step`` to collect each decode step's REAL per-rank
routed-expert bitmaps (``GenerationServer.routed_bitmaps``), then feed
``core.traces.from_served_trace`` — how the committed served-routing
fixture was recorded.
"""
from __future__ import annotations

import time
from typing import Optional

import numpy as np


class LiveReplicaClient:
    def __init__(self, params, ctx, gen, *, num_gpus: int = 1):
        self.params = params
        self.ctx = ctx
        self.gen = gen
        self.num_slots = gen.max_batch
        self.num_gpus = num_gpus
        self._step_ema: dict[int, float] = {}

    @classmethod
    def from_engine(cls, engine, *, num_gpus: int = 1):
        return cls(engine.params, engine.ctx, engine.gen,
                   num_gpus=num_gpus)

    def warmup(self, tables=()) -> int:
        self.ctx.warmup(self.params)
        return self.gen.warmup(self.params, tables)

    def admit(self, slot: int, req) -> tuple:
        t0 = time.perf_counter()
        if req.resume is not None:
            self.gen.admit(slot, req.req_id, req.resume["token"],
                           req.resume)
            return None, time.perf_counter() - t0
        first, state = self.ctx.prefill(self.params, req.tokens)
        self.gen.admit(slot, req.req_id, first, state)
        return first, time.perf_counter() - t0

    def attribute_admit(self, rec) -> None:
        rec.add_gather_share(self.ctx.gather_bytes)

    def step(self, active: list) -> tuple:
        t0 = time.perf_counter()
        toks = self.gen.decode_step(self.params)
        dur = time.perf_counter() - t0
        b = len(active)
        ema = self._step_ema.get(b)
        self._step_ema[b] = dur if ema is None else 0.7 * ema + 0.3 * dur
        return toks, dur

    def attribute_step(self, recs) -> None:
        share = 1.0 / max(1, len(recs))
        for rec in recs:
            rec.add_gather_share(self.gen.gather_bytes, share)
            if self.gen.last_pred_stats is not None:
                rec.add_predict_share(
                    self.gen.last_pred_stats, self.gen.expert_bytes,
                    share,
                )

    def step_time(self, batch: int) -> float:
        b = max(1, int(batch))
        if b in self._step_ema:
            return self._step_ema[b]
        if self._step_ema:
            # nearest measured batch — decode steps vary slowly in batch
            near = min(self._step_ema, key=lambda k: abs(k - b))
            return self._step_ema[near]
        return 0.0  # no measurement yet: admission never blocks on it

    def release(self, slot: int) -> None:
        self.gen.release(slot)

    def evict(self, slot: int) -> dict:
        snap = self.gen.snapshot_slot(slot)
        self.gen.release(slot)
        return snap

    def has_bucket(self, prompt_len: int) -> bool:
        return prompt_len in self.ctx.prefill_lens


class RoutedTraceRecorder:
    """Scheduler ``on_step`` hook collecting per-step routed bitmaps."""

    def __init__(self, group: Optional[str] = None):
        self.group = group
        self.bitmaps: list = []

    def __call__(self, client) -> None:
        bm = client.gen.routed_bitmaps(self.group)
        if bm is not None:
            self.bitmaps.append(bm)

    def as_array(self) -> np.ndarray:
        """(steps, ranks, num_experts) bool."""
        return np.stack(self.bitmaps)

    def save(self, path: str) -> None:
        np.savez_compressed(path, bitmaps=self.as_array())
