"""Continuous-batching scheduler: one replica, rolling admission.

Drives one replica CLIENT — anything exposing the small duck-typed
surface below — admitting queued requests into decode slots the moment
they free (no fixed-slot epochs), with an optional SLO admission
controller gating every admit and firing evict-to-queue on sustained
violation. ``epoch_mode=True`` keeps the fixed-slot reference behaviour
(admit only when EVERY slot is free — the pre-serving engine loop) for
the bitwise regression tests.

Client surface::

    num_slots: int                  # decode slots
    num_gpus: int                   # for ServingMetrics normalization
    admit(slot, req)  -> (first_token | None, seconds)
    step(active)      -> (tokens | None, seconds)   # tokens per slot
    release(slot)
    evict(slot)       -> dict       # snapshot payload, slot freed
    step_time(batch)  -> seconds    # admission-projection estimate
    has_bucket(len)   -> bool       # warm prefill bucket (router hint)

The scheduler owns the slot table and the request records; the client
owns the arrays (live) or the service-time model (modeled). Time is the
sum of client-reported durations, so modeled replicas advance simulated
clocks and live replicas advance measured wall time — each replica's
clock is its OWN (the multi-replica engine never synchronizes them).
"""
from __future__ import annotations

from typing import Optional

from repro.runtime.metrics import RequestRecord, ServingMetrics
from repro.runtime.serving.admission import (
    ADMIT, QUEUE, REJECT, AdmissionController,
)
from repro.runtime.serving.workload import ServedRequest


class ServingScheduler:
    def __init__(self, client, *,
                 admission: Optional[AdmissionController] = None,
                 epoch_mode: bool = False,
                 metrics: Optional[ServingMetrics] = None,
                 on_step=None):
        self.client = client
        self.admission = admission
        self.epoch_mode = epoch_mode
        self.metrics = metrics if metrics is not None else ServingMetrics(
            num_gpus=getattr(client, "num_gpus", 1)
        )
        self.on_step = on_step      # e.g. a RoutedTraceRecorder
        self.t = 0.0
        self.queue: list[ServedRequest] = []
        self._pending: list[ServedRequest] = []  # arrival-sorted future
        self.slots: list[Optional[ServedRequest]] = (
            [None] * client.num_slots
        )
        self.remaining = [0] * client.num_slots
        self.records: dict[int, RequestRecord] = {}
        self.outputs: dict[int, list[int]] = {}
        self.steps = 0

    # -- load accounting (the router's signal) ---------------------------

    def active_count(self) -> int:
        return sum(r is not None for r in self.slots)

    def load(self) -> float:
        """Active + queued + future work, per slot — the least-loaded
        router's comparison key."""
        backlog = self.active_count() + len(self.queue) + len(self._pending)
        return backlog / max(1, self.client.num_slots)

    # -- intake ----------------------------------------------------------

    def submit(self, reqs) -> None:
        for req in reqs:
            self.records[req.req_id] = RequestRecord(
                req_id=req.req_id,
                arrival=req.arrival,
                prompt_len=req.prompt_len,
                target_len=req.target_len,
            )
            self.outputs[req.req_id] = []
            self._pending.append(req)
        self._pending.sort(key=lambda r: (r.arrival, r.req_id))
        self._release_arrivals()

    def _release_arrivals(self) -> None:
        while self._pending and self._pending[0].arrival <= self.t:
            self.queue.append(self._pending.pop(0))

    # -- admission -------------------------------------------------------

    def _count(self, kind: str) -> None:
        self.metrics.record_admission(kind)
        if self.admission is not None:
            self.admission.count(kind)

    def _admit_into(self, slot: int, req: ServedRequest) -> None:
        rec = self.records[req.req_id]
        try:
            first, dur = self.client.admit(slot, req)
        except ValueError:
            if req.resume is None:
                raise
            # the destination's active plan differs from the snapshot's
            # (``engine.validate_restore_plan``): a bitwise resume would
            # decode against the wrong table/mesh, so fall back to a
            # full replay from the prompt — fresh TTFT accounting, the
            # emitted stream restarts
            req.resume = None
            rec.tokens_out = 0
            rec.first_token_time = None
            self.outputs[req.req_id] = []
            self._count("requeued")
            first, dur = self.client.admit(slot, req)
        self.t += dur
        if req.resume is not None:
            self._count("resumed")
            req.resume = None
        else:
            rec.first_token_time = self.t
            rec.tokens_out = 1
            req.remaining = req.target_len - 1
            if first is not None:
                self.outputs[req.req_id].append(int(first))
            attr = getattr(self.client, "attribute_admit", None)
            if attr is not None:
                attr(rec)
        self.slots[slot] = req
        self.remaining[slot] = int(req.remaining)
        self._count("admitted")

    def _admit_phase(self) -> None:
        free = [i for i, r in enumerate(self.slots) if r is None]
        if self.epoch_mode and len(free) < len(self.slots):
            return  # fixed-slot epochs: drain the whole batch first
        for slot in free:
            if not self.queue:
                break
            req = self.queue[0]
            if self.admission is None or req.resume is not None:
                decision = ADMIT
            else:
                decision = self.admission.decide(
                    active=self.active_count(),
                    queue_len=len(self.queue) - 1,
                    queued_for=self.t - req.arrival,
                )
            if decision == QUEUE:
                self._count("queued")
                break
            self.queue.pop(0)
            if decision == REJECT:
                self._count("rejected")
                continue
            self._admit_into(slot, req)

    # -- the decode tick -------------------------------------------------

    def step(self) -> bool:
        """One scheduler tick: release arrivals, admit, decode once.
        Returns False when fully drained (nothing active, queued, or
        pending)."""
        self._release_arrivals()
        self._admit_phase()
        active = [i for i, r in enumerate(self.slots) if r is not None]
        if not active:
            if self._pending:
                # idle until the next arrival (open-loop gap)
                self.t = max(self.t, self._pending[0].arrival)
                return True
            return bool(self.queue)
        toks, dur = self.client.step(active)
        self.t += dur
        self.steps += 1
        recs = [self.records[self.slots[i].req_id] for i in active]
        attr = getattr(self.client, "attribute_step", None)
        if attr is not None:
            attr(recs)
        for slot in active:
            req = self.slots[slot]
            rec = self.records[req.req_id]
            if toks is not None:
                self.outputs[req.req_id].append(int(toks[slot]))
            rec.tokens_out += 1
            self.remaining[slot] -= 1
            req.remaining = self.remaining[slot]
            if self.remaining[slot] <= 0:
                rec.done_time = self.t
                self.metrics.records.append(rec)
                self.slots[slot] = None
                self.client.release(slot)
        if self.on_step is not None:
            self.on_step(self.client)
        self._maybe_evict(dur)
        return True

    def _maybe_evict(self, dur: float) -> None:
        if self.admission is None:
            return
        if not self.admission.observe_step(dur, self.active_count()):
            return
        live = [i for i, r in enumerate(self.slots) if r is not None]
        if len(live) < 2:
            return
        # evict the YOUNGEST slot (most work left): it has the least
        # sunk decode time and the most to gain from a later, faster
        # batch; survivors immediately decode one slot lighter
        slot = max(live, key=lambda i: (self.remaining[i],
                                        self.slots[i].req_id))
        req = self.slots[slot]
        req.resume = self.client.evict(slot)
        req.remaining = self.remaining[slot]
        self.slots[slot] = None
        self.queue.insert(0, req)  # it already waited: head of queue
        self._count("evicted")

    # -- fail-stop recovery ----------------------------------------------

    def quarantine_rank(self, dead_rank: int) -> list:
        """Fail-stop one gen rank of this replica's client
        (``client.kill_rank``) and sort the in-flight slots by the
        report: migrated slots leave with their bitwise snapshot
        attached (returned as ``(req, record, outputs)`` triples for
        the fleet to :meth:`adopt` elsewhere — record and emitted
        stream travel WITH the request, TTFT stands); requeued slots
        (their KV shard died) restart from their prompt at the head of
        this replica's queue with TTFT re-accounted. Accepted requests
        are never dropped — every active slot lands in exactly one of
        the two buckets."""
        active = [i for i, r in enumerate(self.slots) if r is not None]
        report = self.client.kill_rank(dead_rank, active)
        self.t += float(report.get("seconds", 0.0))
        migrated = []
        for slot, snap in sorted(report.get("migrate", {}).items()):
            req = self.slots[slot]
            req.resume = snap
            req.remaining = self.remaining[slot]
            self.slots[slot] = None
            self.remaining[slot] = 0
            migrated.append((
                req,
                self.records.pop(req.req_id),
                self.outputs.pop(req.req_id),
            ))
        requeued = sorted(report.get("requeue", ()), reverse=True)
        for slot in requeued:
            req = self.slots[slot]
            rec = self.records[req.req_id]
            req.resume = None
            rec.tokens_out = 0
            rec.first_token_time = None
            self.outputs[req.req_id] = []
            self.slots[slot] = None
            self.remaining[slot] = 0
            self.queue.insert(0, req)
            self._count("requeued")
        self.metrics.record_rank_death(
            migrated=len(migrated), requeued=len(requeued),
            seconds=float(report.get("seconds", 0.0)),
        )
        return migrated

    def adopt(self, req: ServedRequest, rec: RequestRecord,
              outputs: list) -> None:
        """Take over a migrated in-flight request from another replica:
        its record (arrival/TTFT already accounted) and emitted stream
        move with it; it resumes from its snapshot at the head of this
        replica's queue (resumes bypass SLO admission — the request
        already earned its slot)."""
        self.records[req.req_id] = rec
        self.outputs[req.req_id] = list(outputs)
        self.queue.insert(0, req)

    def run(self, max_steps: Optional[int] = None) -> ServingMetrics:
        """Tick until drained (or ``max_steps`` decode steps)."""
        while self.step():
            if max_steps is not None and self.steps >= max_steps:
                break
        return self.metrics
