"""Replica client backed by the roofline-modelled cluster simulator.

One ``ModeledReplicaClient`` prices a (ctx pool + gen group) replica
with ``ClusterSimulator`` service times — the same §3 roofline the
resolver and the pareto sweep use — so the serving scheduler can sweep
concurrency at cluster scale without arrays. A straggler replica is
just a ``SimConfig`` with ``straggler_ranks``/``straggler_slowdown``
set (the `core/faults.py` scenario-replay hooks): every fetch round of
that replica completes at its slowest peer, which is exactly the
imbalance sync-free decode rides out and demand fetch serializes on.

Prefill is charged inline at admission (matching the live engine's
loop); decode steps price the ACTIVE batch, so a draining replica
speeds up as slots free — the continuous-batching effect the bench
measures.
"""
from __future__ import annotations

import dataclasses
from typing import Optional

from repro.core import roofline
from repro.runtime.simulator import ClusterSimulator, SimConfig


class ModeledReplicaClient:
    def __init__(self, sim_cfg: SimConfig,
                 num_slots: Optional[int] = None):
        self.sim_cfg = sim_cfg
        self.sim = ClusterSimulator(sim_cfg)
        self.num_slots = int(
            num_slots if num_slots is not None else sim_cfg.gen_batch
        )
        self.num_gpus = sim_cfg.ctx_gpus + sim_cfg.gen_gpus
        self._step_time: dict[int, float] = {}
        self._ctx_time: dict[int, float] = {}

    def admit(self, slot: int, req) -> tuple:
        if req.resume is not None:
            return None, 0.0
        L = int(req.prompt_len)
        if L not in self._ctx_time:
            self._ctx_time[L] = self.sim.ctx_time([L])
        return None, self._ctx_time[L]

    def step(self, active: list) -> tuple:
        return None, self.step_time(len(active))

    def step_time(self, batch: int) -> float:
        b = max(1, int(batch))
        if b not in self._step_time:
            self._step_time[b] = self.sim.gen_step_time(b)
        return self._step_time[b]

    def release(self, slot: int) -> None:
        pass

    def evict(self, slot: int) -> dict:
        # modeled slots carry no array state; the scheduler keeps the
        # remaining-token bookkeeping, which is all a resume needs
        return {}

    def kill_rank(self, dead_rank: int, active_slots=()) -> dict:
        """Fail-stop one gen rank of this modeled replica: the gen
        group shrinks to the survivors (``gen_gpus - 1``) and every
        service time re-prices at the shrunk subgroup. Decode slots are
        batch-sharded over the group, so the dead rank's slots
        (``slot % g == dead``) lose their KV shard and must requeue
        from their prompt; every other active slot migrates (an empty
        snapshot — the scheduler's bookkeeping is the whole modeled
        state). Returns the recovery report the scheduler consumes:
        ``{"migrate": {slot: snapshot}, "requeue": [slots], "seconds",
        "wire_bytes"}`` with the re-shard stall and wire bytes priced
        by ``roofline.rank_death_recovery``."""
        g = self.sim_cfg.gen_gpus
        if g < 2:
            raise ValueError(
                f"cannot kill a rank of a {g}-GPU generation group"
            )
        dead = int(dead_rank) % g
        rec = roofline.rank_death_recovery(
            self.sim_cfg.cfg, group=g, hw=self.sim_cfg.hw
        )
        migrate = {int(s): {} for s in active_slots if s % g != dead}
        requeue = [int(s) for s in active_slots if s % g == dead]
        self.sim_cfg = dataclasses.replace(self.sim_cfg, gen_gpus=g - 1)
        self.sim = ClusterSimulator(self.sim_cfg)
        self._step_time.clear()
        self._ctx_time.clear()
        self.num_gpus = self.sim_cfg.ctx_gpus + self.sim_cfg.gen_gpus
        return {
            "migrate": migrate,
            "requeue": requeue,
            "seconds": rec["seconds"],
            "wire_bytes": rec["wire_bytes"] + rec["source_bytes"],
        }

    def can_resume(self, plan) -> bool:
        # modeled slots carry no array state, so any snapshot restores
        return True

    def has_bucket(self, prompt_len: int) -> bool:
        return True
