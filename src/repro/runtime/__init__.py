from repro.runtime.engine import ContextServer, GenerationServer, DisaggregatedEngine
from repro.runtime.metrics import ServingMetrics
from repro.runtime.simulator import ClusterSimulator, SimConfig

__all__ = [
    "ContextServer",
    "GenerationServer",
    "DisaggregatedEngine",
    "ServingMetrics",
    "ClusterSimulator",
    "SimConfig",
]
