"""Discrete-event cluster simulator for disaggregated serving (paper §5.3).

Service times come from the §3 roofline model (core/roofline.py): a
context server of ``ctx_gpus`` runs DWDP or DEP prefill with per-layer
latency ``T_DWDP = max(T_compute, T_prefetch)`` vs ``T_DEP = T_compute +
T_all2all`` (+ a synchronization penalty proportional to per-rank
imbalance for DEP — paper Fig. 1b); generation servers run a simple
batch-latency decode model. The simulator reproduces the *shape* of the
paper's end-to-end results: the Pareto frontier of TPS/user vs TPS/GPU
(Table 5, Fig. 5) and the TTFT trade-off (Table 6).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
import random
from typing import Optional

from repro.configs.base import ArchConfig
from repro.core import roofline
from repro.core.strategy import GatherPolicy, PolicyTable
from repro.runtime.metrics import RequestRecord, ServingMetrics


@dataclasses.dataclass
class SimConfig:
    cfg: ArchConfig
    ctx_gpus: int = 4
    gen_gpus: int = 8
    ctx_mode: str = "dwdp"              # dwdp | dep
    policies: Optional[PolicyTable] = None
                                        # per-family gather policies for
                                        # every DWDP phase (the canonical
                                        # surface). None = build a uniform
                                        # table from the flat fields below
                                        # (kept as the simple spelling for
                                        # sweeps).
    gen_policies: Optional[PolicyTable] = None
                                        # PHASE SPLIT: a separate table
                                        # for the generation (decode)
                                        # servers — what a phase-aware
                                        # scheduler resolves per phase
                                        # (prefill keeps ``policies``).
                                        # None = the generation side uses
                                        # ``policies`` too.
    weight_layout: str = "split"        # gathered-weight representation of
                                        # the DWDP context phase (engine
                                        # default): "split" lands only the
                                        # remote bank, "merged" pays the
                                        # §4.2 merge-copy HBM write
    attn_gathered: bool = False         # model DWDP-gathered attention
                                        # (escalated sharding) land-bytes
    expert_fetch: str = "all"           # "all" | "demand" | "predictive"
                                        # | "sync_free": expert-gather
                                        # selection for every DWDP
                                        # phase. "demand" models
                                        # route-before-gather via the
                                        # expected-coverage closed form
                                        # (its round sits ON the decode
                                        # critical path); "predictive"
                                        # overlaps the speculative round
                                        # and shrinks the serial
                                        # correction by the replayed hit
                                        # rates below; "sync_free"
                                        # additionally drops the
                                        # per-layer index exchange from
                                        # the speculative round (mirrored
                                        # predictor)
    cache_budget: int = 0               # predictive residency-cache rows
                                        # per layer (0 = cache off)
    cache_hit_rate: Optional[float] = None
                                        # replay a MEASURED cache hit
                                        # rate (e.g. an engine run's
                                        # predict_hit_rate) instead of
                                        # the closed-form default
    predict_hit_rate: Optional[float] = None
                                        # likewise for the speculative
                                        # round's predictor hit rate
    gen_mode: str = "local"             # generation-server weight place-
                                        # ment: "local" = fully resident
                                        # per GPU group (the legacy
                                        # model), "dwdp" = sharded over
                                        # the gen group with a per-layer
                                        # expert gather on the decode
                                        # critical path (where
                                        # expert_fetch="demand" pays off)
    gen_batch: int = 64
    validate_fetch: bool = False        # price the checksum-validated
                                        # fetch protocol (docs/
                                        # robustness.md): the metadata
                                        # round carries a per-row f32
                                        # checksum table alongside the
                                        # index bitmap
    fault_rate: float = 0.0             # scenario replay: fraction of
                                        # decode steps on which a
                                        # detected payload fault forces
                                        # the axis-agreed full-gather
                                        # fallback (replay a MEASURED
                                        # engine run's fault_fallbacks /
                                        # steps here to price what the
                                        # HealthMonitor saw)
    straggler_ranks: int = 0            # scenario replay: persistently
                                        # slow peers in the gen group —
                                        # peer-parallel gather rounds
                                        # complete at the slowest
                                        # contributor, so any straggler
                                        # stretches every fetch round by
                                        # ``straggler_slowdown``
    straggler_slowdown: float = 1.0     # link-bandwidth degradation
                                        # factor of a straggler peer
                                        # (>= 1; 3.0 = a third of the
                                        # healthy link)
    fault_trace: object = None          # a faults.FaultTrace (or .npz
                                        # path): timestamped (step,
                                        # kind, rank) events replayed
                                        # in place of the synthetic
                                        # Bernoulli fault_rate —
                                        # payload kinds price a forced
                                        # full-gather fallback on their
                                        # exact step; rank_death
                                        # shrinks the gen group to the
                                        # survivors mid-run (re-shard
                                        # stall + dead-shard slot
                                        # requeue, docs/robustness.md)
    isl_max: int = 8192
    isl_ratio: float = 0.8              # lengths U[ratio*max, max]
    osl: int = 1024
    arrival_rate: float = 1.0           # requests/s
    max_num_tokens: int = 32768         # context-phase token budget (MNT)
    hw: roofline.Hardware = roofline.GB200
    imbalance_sync_frac: float = 0.12   # Fig. 1b: DEP sync overhead at cv~20%
    seed: int = 0
    horizon_s: float = 300.0

    def __post_init__(self):
        if not 0.0 <= self.fault_rate <= 1.0:
            raise ValueError(
                f"fault_rate must lie in [0, 1]; got {self.fault_rate}"
            )
        if self.straggler_slowdown < 1.0:
            raise ValueError(
                "straggler_slowdown is a degradation factor (>= 1); "
                f"got {self.straggler_slowdown}"
            )
        if self.straggler_ranks < 0:
            raise ValueError(
                f"straggler_ranks must be >= 0; got {self.straggler_ranks}"
            )
        if isinstance(self.fault_trace, str):
            from repro.core.faults import FaultTrace

            self.fault_trace = FaultTrace.load(self.fault_trace)

    def table(self) -> PolicyTable:
        """The resolved per-family policy table: ``policies`` verbatim,
        or the table the flat fields spell. The flat fields were
        historically independent (``weight_layout`` priced the ctx
        landing, ``expert_fetch`` the gen wire), so ``merged`` +
        ``demand`` stays constructible: the expert family goes split
        (demand implies it in the engine) while every other family keeps
        the flat layout."""
        if self.policies is not None:
            return self.policies
        fams = ()
        if self.expert_fetch in ("demand", "predictive", "sync_free"):
            fams = (
                ("moe_experts", GatherPolicy(
                    layout="split", fetch=self.expert_fetch,
                    cache_budget=(
                        self.cache_budget
                        if self.expert_fetch in ("predictive", "sync_free")
                        else 0
                    ),
                )),
            )
        return PolicyTable(
            default=GatherPolicy(layout=self.weight_layout), families=fams
        )

    def gen_table(self) -> PolicyTable:
        """The policy table the GENERATION servers run: the phase split a
        phase-aware scheduler produces (ctx keeps :meth:`table`). Defaults
        to :meth:`table` when no split is configured."""
        if self.gen_policies is not None:
            return self.gen_policies
        return self.table()


class ClusterSimulator:
    def __init__(self, sc: SimConfig):
        self.sc = sc
        self.rng = random.Random(sc.seed)

    # ---- service-time models ---------------------------------------------
    def ctx_time(self, batch_isls: list[int]) -> float:
        """One context-server forward over a packed batch of prompts."""
        sc = self.sc
        tokens = sum(batch_isls)
        moe_layer = sc.cfg.moe.first_dense if sc.cfg.moe else 0
        lt = roofline.layer_times(
            sc.cfg, tokens=tokens, group=sc.ctx_gpus, hw=sc.hw,
            layer=moe_layer, policies=sc.table(),
            attn_gathered=sc.attn_gathered, validate=sc.validate_fetch,
        )
        n_layers = sc.cfg.num_layers
        if sc.ctx_mode == "dwdp":
            # the gathered-bank landing write is HBM work on the DWDP
            # critical path (DEP lands nothing), so the modeled frontier
            # moves with the weight_layout: split's smaller landing shows
            # up as context-phase throughput.
            per_layer = max(lt.compute + lt.land_time, lt.prefetch)
        else:
            # DEP pays all2all + imbalance-induced sync (paper Fig. 1)
            cv = _cv(batch_isls)
            sync = lt.compute * sc.imbalance_sync_frac * min(1.0, cv / 0.2)
            per_layer = lt.t_dep + sync
        return per_layer * n_layers

    def decode_wire_bytes(self, batch: int) -> float:
        """Per-GPU wire bytes of one DWDP decode step on the generation
        server (``gen_mode="dwdp"``): the per-layer expert gather summed
        over MoE layers. ``expert_fetch="all"`` ships the full remote
        bank; ``"demand"`` ships the budget-PADDED demand payload
        (``roofline.demand_prefetch_bytes`` with the engine's shared
        auto-budget rule — exactly what the lowered program moves, not
        the raw coverage expectation) — the dominant decode
        communication term the route-before-gather path shrinks;
        ``"predictive"`` ships the speculative + correction rounds with
        cache hits (replayed or closed-form) skipping the wire entirely.
        Dense models gather nothing at decode scale worth modeling here
        (experts dominate)."""
        sc = self.sc
        cfg = sc.cfg
        if cfg.moe is None or sc.gen_gpus <= 1:
            return 0.0
        moe = cfg.moe
        per_expert = 3 * cfg.d_model * moe.d_ff * 1.0  # NVFP4-ish
        n_moe = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
        g = sc.gen_gpus
        pol = sc.gen_table().family("moe_experts")
        if pol.fetch in ("predictive", "sync_free"):
            per_layer, _ = roofline.predictive_fetch_terms(
                batch, moe.top_k, moe.num_experts, g, per_expert,
                budget=pol.budget, cache_rows=pol.cache_budget,
                cache_hit=sc.cache_hit_rate,
                predict_hit=sc.predict_hit_rate,
                validate=sc.validate_fetch,
                sync_free=pol.fetch == "sync_free",
            )
        elif pol.fetch == "demand":
            per_layer = roofline.demand_prefetch_bytes(
                batch, moe.top_k, moe.num_experts, g, per_expert,
                budget=pol.budget, validate=sc.validate_fetch,
            )
        else:
            per_layer = moe.num_experts * per_expert * (g - 1) / g
        return n_moe * per_layer

    def decode_serial_wire_bytes(self, batch: int) -> float:
        """The part of :meth:`decode_wire_bytes` that sits ON the decode
        critical path (cannot overlap compute): the whole round for
        ``"demand"`` (it waits on routing), the correction round only for
        ``"predictive"`` (the speculative round is issued a layer ahead),
        zero for the layer-ahead ``"all"`` prefetch."""
        sc = self.sc
        cfg = sc.cfg
        if cfg.moe is None or sc.gen_gpus <= 1:
            return 0.0
        moe = cfg.moe
        per_expert = 3 * cfg.d_model * moe.d_ff * 1.0
        n_moe = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
        pol = sc.gen_table().family("moe_experts")
        if pol.fetch in ("predictive", "sync_free"):
            _, serial = roofline.predictive_fetch_terms(
                batch, moe.top_k, moe.num_experts, sc.gen_gpus, per_expert,
                budget=pol.budget, cache_rows=pol.cache_budget,
                cache_hit=sc.cache_hit_rate,
                predict_hit=sc.predict_hit_rate,
                validate=sc.validate_fetch,
                sync_free=pol.fetch == "sync_free",
            )
            return n_moe * serial
        if pol.fetch == "demand":
            return self.decode_wire_bytes(batch)
        return 0.0

    def gen_step_time(
        self, batch: int, fault_rate: Optional[float] = None
    ) -> float:
        """One decode iteration on a generation server (memory-bound).

        Weight traffic counts every *routed* expert: with batch B and
        top-k routing the expected fraction of experts touched per layer
        is 1-(1-k/E)^B, which approaches 1 well before B=64 — decode
        streams nearly the full model each step. Under
        ``gen_mode="dwdp"`` the per-layer expert gather's wire time
        joins the max (DWDP overlaps prefetch with compute), which is
        where ``expert_fetch="demand"`` moves the decode frontier.

        ``fault_rate`` overrides the config's Bernoulli blend for trace
        replay: 0.0 prices a clean step, 1.0 the forced full-gather
        fallback step an actual payload-fault event costs."""
        sc = self.sc
        fr = sc.fault_rate if fault_rate is None else fault_rate
        cfg = sc.cfg
        w_params = cfg.active_param_count()
        if cfg.moe is not None:
            e, k = cfg.moe.num_experts, cfg.moe.top_k
            frac = 1.0 - (1.0 - k / e) ** batch
            w_params = cfg.active_param_count() + frac * (
                cfg.param_count() - cfg.active_param_count()
            ) * (k and 1.0)
            w_params = min(w_params, cfg.param_count())
        w_bytes = w_params * 1.0  # NVFP4-ish
        # KV-cache read: every active row re-reads its context KV
        kv_bytes = (
            batch * sc.isl_max * cfg.kv_dim * 2 * cfg.num_layers * 1.0
        )
        t_mem = (w_bytes + kv_bytes) / (sc.hw.hbm_bw * sc.gen_gpus)
        t_flops = 2 * cfg.active_param_count() * batch / (
            sc.hw.flops * sc.gen_gpus
        )
        t = max(t_mem, t_flops)
        if sc.gen_mode == "dwdp":
            wire = self.decode_wire_bytes(batch) / sc.hw.link_bw
            serial = self.decode_serial_wire_bytes(batch) / sc.hw.link_bw
            # scenario replay: peer-parallel gather rounds complete at
            # the slowest contributor, so ANY straggler in the group
            # stretches every fetch round by its link-degradation
            # factor (straggler_ranks > g-1 peers is clamped — you
            # cannot have more slow peers than peers)
            if min(sc.straggler_ranks, sc.gen_gpus - 1) > 0:
                wire *= sc.straggler_slowdown
                serial *= sc.straggler_slowdown
            # overlappable prefetch joins the max (the DWDP critical
            # path); a round that waits on routing adds serially — which
            # is exactly what the predictive fetch takes back off the
            # critical path
            t = max(t, wire - serial) + serial
            # scenario replay: a detected payload fault forces the
            # axis-agreed full-gather fallback for that step — the whole
            # remote bank ships and it all sits serially behind routing
            # (the fallback is taken post-validation). Blend by the
            # replayed per-step fallback probability.
            if fr > 0.0 and cfg.moe is not None:
                moe = cfg.moe
                per_expert = 3 * cfg.d_model * moe.d_ff * 1.0
                n_moe = sum(
                    cfg.is_moe_layer(l) for l in range(cfg.num_layers)
                )
                full_wire = (
                    n_moe * moe.num_experts * per_expert
                    * (sc.gen_gpus - 1) / sc.gen_gpus / sc.hw.link_bw
                )
                if min(sc.straggler_ranks, sc.gen_gpus - 1) > 0:
                    full_wire *= sc.straggler_slowdown
                t_fault = max(t_mem, t_flops) + full_wire
                t = (1.0 - fr) * t + fr * t_fault
        return t + 2e-4  # + fixed step overhead

    def degraded_table(self, peer_badness=None) -> list[dict]:
        """Price every rung of the policy degradation ladder
        (predictive -> demand -> all-gather, plus the terminal
        fail-stop ``"reshard"`` rung priced at the survivor subgroup) at
        this deployment's decode shape — ``roofline.degraded_step_times``
        over the resolved policy table, with this scenario's
        validation/straggler/fault-rate replay applied on top of each
        rung via :meth:`gen_step_time` semantics. Returns one row per
        rung: {"level", "fetch", "t_step_us", "vs_healthy",
        "t_scenario_us"}.

        ``peer_badness`` (optional): per-peer fault-pressure weights in
        [0, 1] — e.g. a replayed ``HealthMonitor.ema`` — pricing the
        ``+excl`` rung under ASYMMETRIC badness. Every peer above the
        monitor's default demote threshold (0.5) joins the exclusion
        set (falling back to the single hottest when none cross it yet,
        and never naming every peer), and the rung's predictor-hit
        haircut scales with the set's share of the remote bank. The
        rung's row gains ``excluded_peers`` listing the set."""
        sc = self.sc
        bad: tuple = ()
        if peer_badness is not None:
            arr = [float(x) for x in peer_badness]
            order = sorted(range(len(arr)), key=lambda i: (-arr[i], i))
            bad = tuple(i for i in order if arr[i] > 0.5)
            if not bad and any(a > 0.0 for a in arr):
                bad = (order[0],)
            bad = bad[: max(1, len(arr) - 1)]
        rows = roofline.degraded_step_times(
            sc.cfg, sc.gen_table(), tokens=sc.gen_batch, group=sc.gen_gpus,
            hw=sc.hw, validate=sc.validate_fetch or sc.fault_rate > 0,
            excluded_peers=max(1, len(bad)),
        )
        from repro.core.strategy import degradation_ladder

        # rows come row-for-row from the same ladder; zip the rung
        # tables back in rather than re-deriving from the label (the
        # "+excl" rung keeps the root table, only the engine-side
        # speculative plan shrinks)
        ladder = degradation_ladder(sc.gen_table())
        assert len(rows) == len(ladder)
        for row, (label, rung_table, rung_excl) in zip(rows, ladder):
            # replay the scenario at this rung: swap the rung's table in
            # GEN-side only (the ladder is a decode-path response; the
            # ctx servers keep their table) and re-price the full gen
            # step (memory/compute + wire + straggler stretch +
            # fault-fallback blend)
            sub = dataclasses.replace(sc, gen_policies=rung_table)
            if label == "reshard":
                # fail-stop terminal rung: post-recovery steady state
                # runs the survivor subgroup (one fewer gen GPU and a
                # dead straggler no longer in the group); the one-off
                # re-shard stall is priced separately in the row
                sub = dataclasses.replace(
                    sub,
                    gen_gpus=max(1, sc.gen_gpus - 1),
                    straggler_ranks=max(0, sc.straggler_ranks - 1),
                )
                row["t_scenario_us"] = round(
                    ClusterSimulator(sub).gen_step_time(sc.gen_batch) * 1e6,
                    3,
                )
                continue
            if rung_excl is None or rung_excl:
                row["excluded_peers"] = list(bad)
                # the exclusion set's share of the remote bank re-routes
                # through the serial correction round: replay the same
                # predictor-hit haircut into the scenario pricing
                ph = sc.predict_hit_rate
                if ph is None and sc.cfg.moe is not None:
                    moe = sc.cfg.moe
                    ph = 1.0 - (
                        1.0 - 1.0 / max(1, moe.num_experts)
                    ) ** (sc.gen_batch * moe.top_k)
                if ph is not None:
                    n_excl = max(1, len(bad))
                    scale = max(0, sc.gen_gpus - 1 - n_excl) / max(
                        1, sc.gen_gpus - 1
                    )
                    sub = dataclasses.replace(sub, predict_hit_rate=ph * scale)
            row["t_scenario_us"] = round(
                ClusterSimulator(sub).gen_step_time(sc.gen_batch) * 1e6, 3
            )
        return rows

    # ---- simulation --------------------------------------------------------
    def run(self) -> dict:
        sc = self.sc
        t = 0.0
        req_id = 0
        queue: list[RequestRecord] = []
        metrics = ServingMetrics(num_gpus=sc.ctx_gpus + sc.gen_gpus)
        # generation slots
        gen_active: list[Optional[RequestRecord]] = [None] * sc.gen_batch
        gen_remaining = [0] * sc.gen_batch

        next_arrival = self.rng.expovariate(sc.arrival_rate)
        ctx_free_at = 0.0
        events: list[tuple[float, str]] = [(next_arrival, "arrival")]
        ready: list[RequestRecord] = []  # prefilled, waiting for a slot
        t_gen = 0.0
        tr = sc.fault_trace
        steps_done = 0  # decode steps taken — the trace's clock

        while events and t < sc.horizon_s:
            t, kind = heapq.heappop(events)
            if kind == "arrival":
                rec = RequestRecord(
                    req_id=req_id,
                    arrival=t,
                    prompt_len=int(
                        self.rng.uniform(sc.isl_ratio, 1.0) * sc.isl_max
                    ),
                    target_len=sc.osl,
                )
                req_id += 1
                queue.append(rec)
                heapq.heappush(
                    events, (t + self.rng.expovariate(sc.arrival_rate), "arrival")
                )
                if ctx_free_at <= t and queue:
                    heapq.heappush(events, (t, "ctx_start"))
            elif kind == "ctx_start":
                if not queue or ctx_free_at > t:
                    continue
                # pack prompts up to MNT
                batch, total = [], 0
                while queue and total + queue[0].prompt_len <= sc.max_num_tokens:
                    r = queue.pop(0)
                    batch.append(r)
                    total += r.prompt_len
                if not batch:
                    r = queue.pop(0)
                    batch = [r]
                dur = self.ctx_time([r.prompt_len for r in batch])
                ctx_free_at = t + dur
                for r in batch:
                    r.first_token_time = ctx_free_at
                    r.tokens_out = 1
                heapq.heappush(events, (ctx_free_at, "ctx_done:" + ",".join(
                    str(r.req_id) for r in batch)))
                self._batchmap = getattr(self, "_batchmap", {})
                for r in batch:
                    self._batchmap[r.req_id] = r
            elif kind.startswith("ctx_done"):
                ids = [int(x) for x in kind.split(":")[1].split(",")]
                for rid in ids:
                    ready.append(self._batchmap.pop(rid))
                if queue:
                    heapq.heappush(events, (t, "ctx_start"))
                heapq.heappush(events, (t, "gen_step"))
            elif kind == "gen_step":
                if t < t_gen:
                    continue
                # admit ready requests into free slots
                for i in range(sc.gen_batch):
                    if gen_active[i] is None and ready:
                        gen_active[i] = ready.pop(0)
                        gen_remaining[i] = gen_active[i].target_len - 1
                active_idx = [
                    i for i in range(sc.gen_batch) if gen_active[i] is not None
                ]
                if not active_idx:
                    continue
                # multi-step advance: when nothing is waiting to join, jump
                # ahead to the next slot completion (event-count reduction;
                # admission granularity coarsens to <=64 decode steps)
                n = 1
                if not ready:
                    n = max(1, min(64, min(gen_remaining[i] for i in active_idx)))
                if tr is None:
                    dur = self.gen_step_time(len(active_idx)) * n
                else:
                    # trace replay: clamp the multi-step advance to the
                    # next recorded event so none is skipped, then price
                    # this window's LEADING step by what the trace says
                    # actually happened on it (subsequent steps in the
                    # window are clean by construction of the clamp)
                    nxt = tr.next_event_step(steps_done + 1)
                    if nxt is not None:
                        n = max(1, min(n, nxt - steps_done))
                    stall = 0.0
                    vec = tr.stat_vector(steps_done, self.sc.gen_gpus)
                    k_fault = 0
                    if vec is not None:
                        metrics.record_fault_stats(vec)
                        k_fault = 1
                    for kind_ev, rank_ev in tr.events_at(steps_done):
                        if kind_ev != "rank_death" or self.sc.gen_gpus < 2:
                            continue
                        g = self.sc.gen_gpus
                        dead = int(rank_ev) % g
                        rec = roofline.rank_death_recovery(
                            self.sc.cfg, group=g, hw=self.sc.hw
                        )
                        stall += rec["seconds"]
                        # the dead rank's KV shard is gone: slots batch-
                        # sharded onto it requeue from their prompt
                        # (back through the context phase — TTFT
                        # re-accounts); survivor slots keep their decode
                        # state bitwise and ride through the swap
                        migrated = requeued = 0
                        for i in active_idx:
                            if i % g == dead:
                                r = gen_active[i]
                                r.tokens_out = 0
                                r.first_token_time = None
                                gen_active[i] = None
                                gen_remaining[i] = 0
                                queue.append(r)
                                requeued += 1
                            else:
                                migrated += 1
                        self.sc = dataclasses.replace(
                            self.sc, gen_gpus=g - 1
                        )
                        metrics.record_rank_death(
                            migrated=migrated, requeued=requeued,
                            seconds=rec["seconds"],
                        )
                        if ctx_free_at <= t and queue:
                            heapq.heappush(events, (t, "ctx_start"))
                        active_idx = [
                            i for i in active_idx
                            if gen_active[i] is not None
                        ]
                    if not active_idx:
                        steps_done += n
                        t_gen = t + stall
                        if ready:
                            heapq.heappush(events, (t_gen, "gen_step"))
                        continue
                    t_clean = self.gen_step_time(
                        len(active_idx), fault_rate=0.0
                    )
                    t_fault = self.gen_step_time(
                        len(active_idx), fault_rate=1.0
                    )
                    dur = t_fault * k_fault + t_clean * (n - k_fault) + stall
                steps_done += n
                t_gen = t + dur
                for i in active_idx:
                    gen_active[i].tokens_out += n
                    gen_remaining[i] -= n
                    if gen_remaining[i] <= 0:
                        gen_active[i].done_time = t_gen
                        metrics.records.append(gen_active[i])
                        gen_active[i] = None
                if any(x is not None for x in gen_active) or ready:
                    heapq.heappush(events, (t_gen, "gen_step"))
        return metrics.summary(max(t, 1e-9))


def _cv(xs: list[int]) -> float:
    if len(xs) < 2:
        return 0.0
    m = sum(xs) / len(xs)
    var = sum((x - m) ** 2 for x in xs) / len(xs)
    return math.sqrt(var) / m if m else 0.0


def pareto_sweep(
    cfg: ArchConfig,
    *,
    ctx_mode: str,
    ctx_gpu_options=(2, 3, 4, 6, 8),
    rate_options=(0.5, 1.0, 2.0, 4.0, 8.0),
    **kw,
) -> list[dict]:
    """Sweep deployment points -> (TPS/user, TPS/GPU, TTFT) frontier."""
    rows = []
    for ctx_gpus in ctx_gpu_options:
        for rate in rate_options:
            sc = SimConfig(
                cfg=cfg, ctx_gpus=ctx_gpus, ctx_mode=ctx_mode,
                arrival_rate=rate, **kw,
            )
            out = ClusterSimulator(sc).run()
            out.update(ctx_gpus=ctx_gpus, rate=rate, ctx_mode=ctx_mode)
            rows.append(out)
    return rows
