"""HLO text analysis: collective byte accounting.

``cost_analysis()`` does not report collective traffic, so we parse the
(optimized or unoptimized) HLO text and sum operand sizes of every
all-gather / all-reduce / reduce-scatter / all-to-all / collective-permute
instruction. Async pairs count once (the -start op carries the operands;
-done is skipped).
"""
from __future__ import annotations

import re
from collections import defaultdict

_DTYPE_BYTES = {
    "pred": 1, "s8": 1, "u8": 1, "f8e4m3fn": 1, "f8e5m2": 1, "f8e4m3": 1,
    "s16": 2, "u16": 2, "f16": 2, "bf16": 2,
    "s32": 4, "u32": 4, "f32": 4,
    "s64": 8, "u64": 8, "f64": 8, "c64": 8, "c128": 16,
}

COLLECTIVE_OPS = (
    "all-gather",
    "all-reduce",
    "reduce-scatter",
    "all-to-all",
    "collective-permute",
)

# one HLO instruction: `%name = TYPE[SHAPE]{layout} opcode(...operands...)`
_INSTR_RE = re.compile(
    r"=\s*(?:\([^)]*\)|[a-z0-9]+\[[0-9,]*\][^\s]*)\s+([a-z0-9-]+)(?:-start)?\("
)
_SHAPE_RE = re.compile(r"([a-z0-9]+)\[([0-9,]*)\]")


def _shape_bytes(dtype: str, dims: str) -> int:
    n = 1
    if dims:
        for d in dims.split(","):
            n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def parse_collectives(hlo_text: str) -> dict[str, dict[str, float]]:
    """Per collective kind: {"bytes": operand bytes, "count": #ops}."""
    out: dict[str, dict[str, float]] = defaultdict(
        lambda: {"bytes": 0.0, "count": 0.0}
    )
    for line in hlo_text.splitlines():
        line = line.strip()
        if "=" not in line:
            continue
        m = _INSTR_RE.search(line)
        if not m:
            continue
        op = m.group(1)
        if op.endswith("-done"):
            continue
        base = op[:-6] if op.endswith("-start") else op
        if base not in COLLECTIVE_OPS:
            continue
        # operand shapes: everything inside the top-level parens
        call = line[m.end():]
        depth = 1
        i = 0
        for i, ch in enumerate(call):
            if ch == "(":
                depth += 1
            elif ch == ")":
                depth -= 1
                if depth == 0:
                    break
        operands = call[:i]
        nbytes = sum(
            _shape_bytes(d, dims) for d, dims in _SHAPE_RE.findall(operands)
        )
        out[base]["bytes"] += nbytes
        out[base]["count"] += 1
    return dict(out)


def collective_bytes(hlo_text: str) -> float:
    """Total collective operand bytes (per device) in the module."""
    return sum(v["bytes"] for v in parse_collectives(hlo_text).values())


def tensor_shape_count(text: str, dims) -> int:
    """Occurrences of a tensor type with exactly these dims (any dtype) in
    HLO (``f32[6,32,48]``) or StableHLO (``tensor<6x32x48xf32>``) text.

    The §4.2 structural assertion is built on this: a module lowered with
    ``weight_layout="split"`` must contain zero tensors of the full
    canonical gathered shape of ANY weight family — the
    ``(num_padded, D, F)`` expert bank, the ``(A, D, qd/A)`` /
    ``(A, qd/A, D)`` attention stacks, the ``(S, D, F/S)`` dense-FFN
    stack — only the resident shard and the remote bank may appear —
    while the merged path necessarily materializes them."""
    dims = tuple(int(d) for d in dims)
    stable = re.compile(
        r"tensor<" + r"x".join(str(d) for d in dims) + r"x[a-z]"
    )
    hlo = re.compile(
        r"\[" + r",".join(str(d) for d in dims) + r"\]"
    )
    return len(stable.findall(text)) + len(hlo.findall(text))
