from repro.analysis.hlo import (
    collective_bytes,
    parse_collectives,
    tensor_shape_count,
)
from repro.analysis.roofline_report import RooflineReport, report_from_lowered
from repro.analysis.stablehlo import analyze_module, ModuleCost

__all__ = [
    "collective_bytes",
    "parse_collectives",
    "tensor_shape_count",
    "RooflineReport",
    "report_from_lowered",
    "analyze_module",
    "ModuleCost",
]
