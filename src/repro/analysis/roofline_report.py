"""§Roofline: derive the three roofline terms from a compiled dry-run.

    compute term    = HLO_FLOPs / peak_FLOP/s          (per chip)
    memory term     = HLO_bytes / HBM_bw               (per chip)
    collective term = collective_bytes / link_bw       (per chip)

cost_analysis() reports per-device FLOPs/bytes for the SPMD module;
collective bytes come from the HLO parser. Hardware constants per the
brief: 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI (we credit 4
usable torus links -> 200 GB/s/chip aggregate).
"""
from __future__ import annotations

import dataclasses
import json

from repro.analysis.hlo import collective_bytes, parse_collectives

PEAK_FLOPS = 197e12
HBM_BW = 819e9
ICI_BW_PER_LINK = 50e9
ICI_LINKS = 4
LINK_BW = ICI_BW_PER_LINK * ICI_LINKS


@dataclasses.dataclass
class RooflineReport:
    arch: str
    shape: str
    mesh: str
    mode: str
    chips: int
    hlo_flops: float           # per chip
    hlo_bytes: float           # per chip
    coll_bytes: float          # per chip
    coll_detail: dict
    t_compute: float
    t_memory: float
    t_collective: float
    model_flops: float         # analytic useful FLOPs for the whole step
    bytes_per_device: float    # peak memory from memory_analysis (CPU backend)
    residency_bytes: float = 0.0  # analytic TPU-target residency
    utilization_note: str = ""

    @property
    def dominant(self) -> str:
        terms = {
            "compute": self.t_compute,
            "memory": self.t_memory,
            "collective": self.t_collective,
        }
        return max(terms, key=terms.get)

    @property
    def t_bound(self) -> float:
        return max(self.t_compute, self.t_memory, self.t_collective)

    @property
    def useful_flop_ratio(self) -> float:
        total = self.hlo_flops * self.chips
        return self.model_flops / total if total else 0.0

    def row(self) -> dict:
        return {
            "arch": self.arch,
            "shape": self.shape,
            "mesh": self.mesh,
            "mode": self.mode,
            "t_compute_ms": self.t_compute * 1e3,
            "t_memory_ms": self.t_memory * 1e3,
            "t_collective_ms": self.t_collective * 1e3,
            "dominant": self.dominant,
            "model_gflops": self.model_flops / 1e9,
            "useful_flop_ratio": self.useful_flop_ratio,
            "hbm_gb_per_device": self.bytes_per_device / 1e9,
            "residency_gb": self.residency_bytes / 1e9,
            "coll_detail": {
                k: round(v["bytes"] / 1e6, 2)
                for k, v in self.coll_detail.items()
            },
        }


def _moe_layer_groups(cfg) -> list[tuple[str, int]]:
    """``(layer_group_name, moe_layer_count)`` per execution-plan layer
    group — the iteration both analytic accountings below use to
    resolve per-layer-group PolicyTable overrides exactly as the engine
    lowers them (each group prices ITS OWN resolved moe policy)."""
    from repro.core.roofline import layer_group_names

    names = layer_group_names(cfg)
    out: dict[str, int] = {}
    for layer in range(cfg.num_layers):
        if cfg.is_moe_layer(layer):
            out[names[layer]] = out.get(names[layer], 0) + 1
    return list(out.items())


def analytic_residency_bytes(cfg, geom, xp, shape, dtype_bytes: int = 2,
                             opt_bytes_per_param: int = 12) -> float:
    """Per-device steady-state residency on the TARGET (TPU bf16): params
    (+grads+adam fp32 for train) at their sharded layout, KV cache, double
    buffered gather window, activation checkpoints. The CPU backend's
    memory_analysis over-reports (f32 conversion, conservative liveness),
    so the fit claim uses this analytic number; both are recorded.

    Per-layer-group PolicyTable overrides resolve exactly (like
    analytic_hbm_bytes below): the expert gather window and residency
    cache are priced group by group under each group's own policy, so
    a mixed table (e.g. ``fetch="demand"`` scoped to one group) reports
    the bytes the engine actually buffers."""
    import math as _m

    chips = _m.prod(xp.mesh_sizes.values())
    n = cfg.param_count()
    shard = max(
        1,
        _m.prod(
            xp.mesh_sizes.get(a, 1)
            for a in set(geom.ffn_axes + geom.attn_axes + geom.expert_axes)
        ),
    )
    per_param = dtype_bytes + (
        opt_bytes_per_param if shape.phase == "train" else 0
    )
    weights = n * per_param / shard
    # double-buffered gather window: 2x the largest single layer set.
    # A split-active family buffers only the remote bank — the resident
    # shard is consumed in place by the split kernels, shrinking the
    # window by 1/G' (experts) / 1/shards (attention, dense FFN).
    from repro.core.execution import (
        _qgather_ok,
        demand_fetch_active,
        predictive_fetch_active,
        resolve_cache_rows,
        resolve_demand_budget,
        resolve_spec_budget,
        split_bank_active,
    )

    layer_sets = [0.0]
    cache_bytes = 0.0
    if cfg.moe is not None and geom.moe_exec == "gather" and geom.moe_placement:
        pl = geom.moe_placement
        expert_row = 3 * cfg.d_model * cfg.moe.d_ff * dtype_bytes
        for gname, n_moe_g in _moe_layer_groups(cfg):
            window_experts = pl.num_padded
            if demand_fetch_active(cfg, geom, xp, gname):
                # route-before-gather: the layer holds only the
                # budget-padded fetched rows (the resident shard is
                # consumed in place)
                budget = resolve_demand_budget(cfg, geom, xp, gname)
                window_experts = (pl.subgroup_size - 1) * min(
                    budget, pl.local_count
                )
                if predictive_fetch_active(cfg, geom, xp, gname):
                    # speculative + correction rounds both buffer, and
                    # the cross-step residency cache is PERSISTENT per
                    # MoE layer (not double-buffered — priced
                    # separately below)
                    spec = resolve_spec_budget(cfg, geom, xp, gname)
                    window_experts += (pl.subgroup_size - 1) * min(
                        spec, pl.local_count
                    )
                    cache_bytes += (
                        n_moe_g * resolve_cache_rows(cfg, geom, xp, gname)
                        * expert_row
                    )
            elif split_bank_active(geom, xp, "moe/experts", gname):
                # gate on the engine's own predicate (not the knob
                # alone) so the report never claims a saving for plans
                # that fall back to the merged path
                window_experts = pl.num_padded - pl.local_count
            layer_sets.append(window_experts * expert_row)
    if cfg.moe is not None and geom.moe_exec == "rotate" and geom.moe_placement:
        # rotate holds the resident shard + the in-flight one (the 2x
        # double-buffer is applied uniformly below)
        layer_sets.append(
            geom.moe_placement.local_count * 3 * cfg.d_model
            * cfg.moe.d_ff * dtype_bytes
        )
    if geom.ffn_axes and cfg.d_ff:
        ffn_set = 3 * cfg.d_model * cfg.d_ff * dtype_bytes
        if split_bank_active(geom, xp, "ffn"):
            ffn_set *= 1 - 1 / max(1, geom.ffn_shards)
        layer_sets.append(ffn_set)
    if geom.attn_axes and not _qgather_ok(geom, xp):
        # qgather decode keeps attention weights sharded (no gather
        # window at all) — mirror gather_set, like the moe gate above.
        # qkv and out are separate policy families: each part's window
        # shrinks only when ITS policy runs split.
        attn_set = 0.0
        for fam, part in (
            ("attn_qkv",
             cfg.d_model * (cfg.q_dim + 2 * cfg.kv_dim) * dtype_bytes),
            ("attn_out", cfg.q_dim * cfg.d_model * dtype_bytes),
        ):
            if split_bank_active(geom, xp, fam):
                part *= 1 - 1 / max(1, geom.attn_shards)
            attn_set += part
        layer_sets.append(attn_set)
    gather_buf = 2 * max(layer_sets)
    # KV cache (decode) / activations
    kv = 0.0
    if shape.phase == "decode" and cfg.has_attention:
        l_local = shape.seq_len // max(1, xp.seq_shards)
        kv = (
            cfg.num_layers * xp.local_batch * l_local * 2 * cfg.kv_dim
            * dtype_bytes
        )
    t_local = (
        (shape.seq_len if shape.phase != "decode" else 1)
        * max(1, xp.local_batch)
        // max(1, xp.seq_shards if shape.phase != "decode" else 1)
    )
    act_factor = 4 if shape.phase == "train" else 2
    acts = act_factor * t_local * cfg.d_model * 4
    if shape.phase == "train":
        # one checkpoint per scan cycle
        acts += (cfg.num_layers + 1) * t_local * cfg.d_model * dtype_bytes
    return weights + gather_buf + cache_bytes + kv + acts


def analytic_hbm_bytes(cfg, geom, xp, shape, dtype_bytes: int = 2) -> float:
    """Per-device HBM traffic estimate for one step.

    The unoptimized-HLO byte count is useless here (XLA fuses the flash
    softmax chain into VMEM), so the memory term is analytic:
      resident weight reads + gathered-weight write+read + layer-boundary
      activation traffic + KV-cache traffic + head logits.
    Documented in DESIGN.md §5.
    """
    import math as _m

    l = cfg.num_layers
    d = cfg.d_model
    # --- weights: every resident shard read once; gathered weights are
    # additionally written once after landing (2x) ------------------------
    n_params = cfg.param_count()
    chips = _m.prod(xp.mesh_sizes.values())
    model_shards = max(
        1,
        _m.prod(
            xp.mesh_sizes.get(a, 1)
            for a in set(geom.ffn_axes + geom.attn_axes + geom.expert_axes)
        ),
    )
    resident = n_params * dtype_bytes / model_shards
    gathered_extra = 0.0
    if xp.mode == "dwdp":
        # Per-family gathered landing + read-back, each family paying its
        # own layout: merged lands+reads the full canonical buffer (the
        # §4.2 merge copy — resident shard re-written too); a split-active
        # family lands+reads only its remote bank, the resident shard is
        # read in place (already counted in `resident`).
        from repro.core.execution import (
            _qgather_ok,
            demand_fetch_active,
            predictive_fetch_active,
            resolve_cache_rows,
            resolve_demand_budget,
            resolve_spec_budget,
            split_bank_active,
        )

        _ATTN = ("global_attn", "local_attn")

        def _land(total_bytes, shards, split):
            if shards <= 1:
                return 0.0
            frac = (1 - 1 / shards) if split else 1.0
            return 2.0 * total_bytes * frac

        def axsize(axes):
            return max(1, _m.prod(xp.mesh_sizes.get(a, 1) for a in axes))

        # vocab family (embed gather / train head gather): always merged
        vocab_params = cfg.vocab_size * cfg.d_model * (
            1 if cfg.tie_embeddings else 2
        )
        gathered_extra += _land(
            vocab_params * dtype_bytes, xp.mesh_sizes.get("model", 1), False
        )
        # attention projections / recurrent cells (mixer family)
        attn_w = sum(
            cfg._mixer_params(l) for l in range(cfg.num_layers)
            if cfg.block_kind(l).value in _ATTN
        ) * dtype_bytes
        cell_w = sum(
            cfg._mixer_params(l) for l in range(cfg.num_layers)
            if cfg.block_kind(l).value not in _ATTN
        ) * dtype_bytes
        if geom.attn_axes and not _qgather_ok(geom, xp):
            # qgather decode never gathers attention weights (it moves
            # q/k/v activations instead) — mirror gather_set. The mixer
            # bytes split between the attn_qkv / attn_out families in
            # projection-size proportion, each landing per ITS layout.
            qkv_dims = cfg.q_dim + 2 * cfg.kv_dim
            qkv_frac = qkv_dims / (qkv_dims + cfg.q_dim)
            for fam, frac in (
                ("attn_qkv", qkv_frac), ("attn_out", 1.0 - qkv_frac)
            ):
                gathered_extra += _land(
                    attn_w * frac, axsize(geom.attn_axes),
                    split_bank_active(geom, xp, fam),
                )
        if geom.cell_axes:
            gathered_extra += _land(cell_w, axsize(geom.cell_axes), False)
        # dense FFN slices (+ always-on shared experts)
        dense_w = sum(
            3 * cfg.d_model * cfg.ffn_dim(l)
            for l in range(cfg.num_layers) if cfg.ffn_dim(l)
        ) * dtype_bytes
        if cfg.moe is not None and cfg.moe.shared_d_ff:
            n_moe_l = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
            dense_w += (
                n_moe_l * 3 * cfg.d_model * cfg.moe.shared_d_ff * dtype_bytes
            )
        if geom.ffn_axes:
            gathered_extra += _land(
                dense_w, axsize(geom.ffn_axes),
                split_bank_active(geom, xp, "ffn"),
            )
        # expert bank, exactly: the padded canonical bank lands (merged)
        # or only the (G'-1)/G' remote fraction (split); subgroup 1 =
        # fully resident, no expert gather at all (gather_set skips it).
        # Priced PER LAYER GROUP so per-layer-group PolicyTable
        # overrides land exactly the rows the engine fetches for those
        # layers.
        if cfg.moe is not None and geom.moe_placement:
            pl = geom.moe_placement
            n_moe = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
            per_layer = 3 * cfg.d_model * cfg.moe.d_ff
            bank_landed = n_moe * pl.num_padded * per_layer
            if geom.moe_exec == "gather" and pl.subgroup_size > 1:
                for gname, n_moe_g in _moe_layer_groups(cfg):
                    if demand_fetch_active(cfg, geom, xp, gname):
                        # demand lands + reads back only the
                        # budget-padded fetched rows — strictly below
                        # the full remote bank whenever the budget is
                        # (rows * top_k under-full)
                        budget = resolve_demand_budget(
                            cfg, geom, xp, gname
                        )
                        fetch_rows = (pl.subgroup_size - 1) * min(
                            budget, pl.local_count
                        )
                        if predictive_fetch_active(cfg, geom, xp, gname):
                            # speculative round lands+reads too; cached
                            # rows are read in place (one read, no
                            # landing)
                            spec = resolve_spec_budget(
                                cfg, geom, xp, gname
                            )
                            fetch_rows += (pl.subgroup_size - 1) * min(
                                spec, pl.local_count
                            )
                            gathered_extra += (
                                n_moe_g
                                * resolve_cache_rows(cfg, geom, xp, gname)
                                * per_layer * dtype_bytes
                            )
                        gathered_extra += (
                            2.0 * n_moe_g * fetch_rows * per_layer
                            * dtype_bytes
                        )
                    elif split_bank_active(
                        geom, xp, "moe/experts", gname
                    ):
                        gathered_extra += (
                            2.0 * n_moe_g * pl.num_padded * per_layer
                            * dtype_bytes * pl.remote_fraction
                        )
                    else:
                        gathered_extra += (
                            2.0 * n_moe_g * pl.num_padded * per_layer
                            * dtype_bytes
                        )
            elif geom.moe_exec == "rotate" and pl.subgroup_size > 1:
                # rotate streams every non-resident shard through HBM
                # once per layer (transient landing + read) — same remote
                # fraction as the split gather, never the full merge
                gathered_extra += (
                    2.0 * bank_landed * dtype_bytes * pl.remote_fraction
                )
    if cfg.moe is not None and shape.phase == "decode":
        # decode touches only routed experts' weights
        moe = cfg.moe
        frac_active = min(
            1.0,
            (xp.local_batch * moe.top_k) / max(1, moe.num_experts),
        )
        inactive = (1 - frac_active) * (
            cfg.param_count() - cfg.active_param_count()
        ) * dtype_bytes
        resident = max(0.0, resident - inactive / model_shards)
        gathered_extra *= frac_active

    # --- activations: ~10 layer-boundary (T_local, D) streams per layer --
    t_local = (shape.seq_len if shape.phase != "decode" else 1) * max(
        1, xp.local_batch
    ) // max(1, xp.seq_shards if shape.phase != "decode" else 1)
    act = 10.0 * l * t_local * d * dtype_bytes
    if shape.phase == "train":
        act *= 3.0  # fwd + bwd + recompute-ish

    # --- attention KV traffic --------------------------------------------
    kv = 0.0
    if cfg.has_attention:
        if shape.phase == "decode":
            l_local = shape.seq_len // max(1, xp.seq_shards)
            kv = l * xp.local_batch * l_local * 2 * cfg.kv_dim * dtype_bytes
        else:
            kv = l * xp.local_batch * shape.seq_len * 2 * cfg.kv_dim * dtype_bytes

    # --- head logits -------------------------------------------------------
    if shape.phase == "train":
        head = t_local * cfg.vocab_size * 4.0
    elif shape.phase == "prefill":
        head = xp.local_batch * cfg.vocab_size / max(1, xp.mesh_sizes.get("model", 1)) * 4.0
    else:
        head = xp.local_batch * cfg.vocab_size / max(1, xp.mesh_sizes.get("model", 1)) * 4.0
    return resident + gathered_extra + act + kv + head


def model_flops_for(cfg, shape, train: bool) -> float:
    """Analytic useful FLOPs: 6·N·T train, 2·N·T inference (N = active)."""
    n_active = cfg.active_param_count()
    if shape.phase == "train":
        return 6.0 * n_active * shape.tokens
    if shape.phase == "prefill":
        return 2.0 * n_active * shape.tokens
    # decode: one token per sequence + attention over each layer's cache
    # (sliding-window layers only attend to <= window keys)
    per_tok = 2.0 * n_active
    attn = 0.0
    for l in range(cfg.num_layers):
        kind = cfg.block_kind(l)
        if kind.value == "global_attn":
            span = shape.seq_len
        elif kind.value == "local_attn":
            span = min(cfg.window, shape.seq_len)
        else:
            continue
        attn += 4.0 * cfg.num_heads * cfg.head_dim * span
    return (per_tok + attn) * shape.global_batch


def report_from_lowered(
    lowered,
    compiled,
    *,
    arch: str,
    shape,
    cfg,
    mesh_name: str,
    mode: str,
    chips: int,
    geom=None,
    xp=None,
    dtype_bytes: int = 2,
    opt_bytes_per_param: int = 12,
) -> RooflineReport:
    """Roofline terms from the lowered StableHLO (loop-aware interprocedural
    analysis — see analysis/stablehlo.py) + compiled memory_analysis."""
    from repro.analysis.stablehlo import analyze_module

    mc = analyze_module(lowered.as_text())
    flops = mc.flops
    residency = 0.0
    if geom is not None and xp is not None:
        byts = analytic_hbm_bytes(cfg, geom, xp, shape, dtype_bytes)
        residency = analytic_residency_bytes(
            cfg, geom, xp, shape, dtype_bytes, opt_bytes_per_param
        )
    else:
        byts = mc.dot_bytes
    coll = mc.coll
    cbytes = mc.collective_bytes
    mem = compiled.memory_analysis()
    peak = float(
        getattr(mem, "temp_size_in_bytes", 0)
        + getattr(mem, "argument_size_in_bytes", 0)
        + getattr(mem, "output_size_in_bytes", 0)
        - getattr(mem, "alias_size_in_bytes", 0)
    )
    train = shape.phase == "train"
    return RooflineReport(
        arch=arch,
        shape=shape.name,
        mesh=mesh_name,
        mode=mode,
        chips=chips,
        hlo_flops=flops,
        hlo_bytes=byts,
        coll_bytes=cbytes,
        coll_detail=coll,
        t_compute=flops / PEAK_FLOPS,
        t_memory=byts / HBM_BW,
        t_collective=cbytes / LINK_BW,
        model_flops=model_flops_for(cfg, shape, train),
        bytes_per_device=peak,
        residency_bytes=residency,
    )
