"""Interprocedural StableHLO cost analyzer.

XLA's ``compiled.cost_analysis()`` does not multiply while-loop trip
counts (a 48-layer ``lax.scan`` counts as one layer), and the optimized
HLO drops operand shapes from collective instructions. We therefore
analyze the *lowered* StableHLO text, which keeps full type signatures,
original dtypes, and an explicit loop/call structure:

- every ``func.func`` is parsed into events (dot_generals, collectives,
  op results) each tagged with the product of enclosing while trip counts
  (trip = the loop-bound constant in the ``cond`` block);
- ``func.call`` edges propagate multipliers through the call graph.

Outputs per device: matmul FLOPs, bytes touched (sum of op result bytes —
an upper-ish estimate since XLA fuses elementwise chains; documented in
DESIGN.md §5), and per-kind collective wire bytes using ring-algorithm
costs:

    all_gather        operand x (G-1)
    all_reduce        2 x operand x (G-1)/G
    reduce_scatter    operand x (G-1)/G
    all_to_all        operand x (G-1)/G
    collective_permute operand
"""
from __future__ import annotations

import dataclasses
import math
import re
from collections import defaultdict

_DTYPE_BYTES = {
    "i1": 1, "i8": 1, "ui8": 1, "f8E4M3FN": 1, "f8E5M2": 1,
    "i16": 2, "ui16": 2, "f16": 2, "bf16": 2,
    "i32": 4, "ui32": 4, "f32": 4,
    "i64": 8, "ui64": 8, "f64": 8,
}

_TENSOR_RE = re.compile(r"tensor<([0-9x]*)\s*x?\s*([A-Za-z0-9]+)>")
_FUNC_RE = re.compile(r"func\.func\s+(?:public|private)?\s*@([\w.]+)")
_CALL_RE = re.compile(r"(?:func\.)?call\s+@([\w.]+)")
_COLLECTIVE_RE = re.compile(
    r'"stablehlo\.(all_gather|all_reduce|reduce_scatter|all_to_all|'
    r"collective_permute)\""
)
_GROUPS_RE = re.compile(r"replica_groups = dense<.*?> : tensor<(\d+)x(\d+)xi64>")
_DENSE_INT_RE = re.compile(r"dense<(-?\d+)>")
_CONTRACT_RE = re.compile(r"contracting_dims = \[([0-9, ]*)\] x \[([0-9, ]*)\]")


def _tensor_bytes(dims: str, dtype: str) -> int:
    n = 1
    if dims:
        for d in dims.rstrip("x").split("x"):
            if d:
                n *= int(d)
    return n * _DTYPE_BYTES.get(dtype, 4)


def _sig_tensors(line: str):
    """Tensors in the trailing type signature `: (ops) -> res`."""
    idx = line.rfind(" : ")
    if idx < 0:
        return [], []
    sig = line[idx + 3:]
    if "->" in sig:
        ops_s, res_s = sig.split("->", 1)
    else:
        ops_s, res_s = "", sig
    return _TENSOR_RE.findall(ops_s), _TENSOR_RE.findall(res_s)


@dataclasses.dataclass
class FuncCost:
    dot_flops: float = 0.0
    dot_bytes: float = 0.0
    result_bytes: float = 0.0
    coll: dict = dataclasses.field(
        default_factory=lambda: defaultdict(lambda: {"bytes": 0.0, "count": 0.0})
    )
    calls: list = dataclasses.field(default_factory=list)  # (name, mult)


def _collective_wire_bytes(kind: str, op_bytes: float, group: int) -> float:
    if kind == "collective_permute":
        return op_bytes  # no replica_groups attr; always moves the operand
    if group <= 1:
        return 0.0
    if kind == "all_gather":
        return op_bytes * (group - 1)
    if kind == "all_reduce":
        return 2.0 * op_bytes * (group - 1) / group
    if kind in ("reduce_scatter", "all_to_all"):
        return op_bytes * (group - 1) / group
    return op_bytes  # collective_permute


_CONST_DEF_RE = re.compile(
    r"%(\w+)\s*=\s*stablehlo\.constant\s+dense<(-?\d+)>"
)
_ITER_INIT_RE = re.compile(r"%iterArg\w*\s*=\s*%(\w+)")


def _parse_func(lines: list[str]) -> FuncCost:
    fc = FuncCost()
    mult_stack: list[tuple[int, float]] = []  # (depth, trip)
    depth = 0
    mode = "normal"  # normal | cond
    cond_consts: list[int] = []
    init_consts: list[int] = []
    const_table: dict[str, int] = {}

    for line in lines:
        s = line.strip()
        cur_mult = math.prod(m for _, m in mult_stack) if mult_stack else 1.0

        cm = _CONST_DEF_RE.match(s)
        if cm:
            const_table[cm.group(1)] = int(cm.group(2))

        if mode == "cond":
            if s.startswith("} do {"):
                # count-up loops carry the bound in the cond; countdown
                # loops (reverse-mode scans) start at N-1 and compare >= 0,
                # so also consider the iterArg init constants.
                up = max([c for c in cond_consts if c > 0] or [0])
                down = max([c + 1 for c in init_consts if c > 0] or [0])
                # prefer the explicit cond bound; fall back to the
                # iterArg init for countdown (reverse-scan) loops
                trip = up if up > 1 else max(down, 1)
                # `} do {` closes cond and opens do at the same depth
                mult_stack.append((depth, float(trip)))
                mode = "normal"
                continue
            cond_consts += [int(v) for v in _DENSE_INT_RE.findall(s)]
            continue

        if "stablehlo.while" in s:
            init_consts = [
                const_table[name]
                for name in _ITER_INIT_RE.findall(s)
                if name in const_table
            ]
            # next structural line is `cond {`
            mode = "await_cond"
            continue
        if mode == "await_cond":
            if s.startswith("cond {"):
                mode = "cond"
                cond_consts = []
                continue
            mode = "normal"  # defensive

        net = s.count("{") - s.count("}")
        if net:
            depth += net
            if net < 0 and mult_stack and depth < mult_stack[-1][0]:
                mult_stack.pop()
            # fall through: a closing line may still carry an op? (rare)

        m = _CALL_RE.search(s)
        if m and "stablehlo" not in m.group(0):
            fc.calls.append((m.group(1), cur_mult))
            continue

        mc = _COLLECTIVE_RE.search(s)
        if mc:
            kind = mc.group(1)
            ops, _res = _sig_tensors(s)
            op_bytes = sum(_tensor_bytes(d, t) for d, t in ops)
            gm = _GROUPS_RE.search(s)
            group = int(gm.group(2)) if gm else 1
            fc.coll[kind]["bytes"] += cur_mult * _collective_wire_bytes(
                kind, op_bytes, group
            )
            fc.coll[kind]["count"] += cur_mult
            continue

        if "stablehlo.dot_general" in s:
            ops, res = _sig_tensors(s)
            if res:
                out_elems = 1
                dims = res[0][0]
                if dims:
                    for d in dims.rstrip("x").split("x"):
                        if d:
                            out_elems *= int(d)
                contract = 1
                cm = _CONTRACT_RE.search(s)
                if cm and ops:
                    lhs_dims = [
                        int(d)
                        for d in ops[0][0].rstrip("x").split("x")
                        if d
                    ]
                    for ci in cm.group(1).split(","):
                        ci = ci.strip()
                        if ci:
                            contract *= lhs_dims[int(ci)]
                fc.dot_flops += cur_mult * 2.0 * out_elems * contract
                fc.dot_bytes += cur_mult * (
                    sum(_tensor_bytes(d, t) for d, t in ops)
                    + sum(_tensor_bytes(d, t) for d, t in res)
                )
        if "stablehlo." in s and " : " in s and "=" in s:
            _ops, res = _sig_tensors(s)
            fc.result_bytes += cur_mult * sum(
                _tensor_bytes(d, t) for d, t in res
            )
    return fc


@dataclasses.dataclass
class ModuleCost:
    flops: float
    dot_bytes: float
    result_bytes: float
    coll: dict  # kind -> {"bytes", "count"}

    @property
    def collective_bytes(self) -> float:
        return sum(v["bytes"] for v in self.coll.values())


def analyze_module(text: str, entry: str = "main") -> ModuleCost:
    # split funcs
    funcs: dict[str, list[str]] = {}
    name = None
    for line in text.splitlines():
        m = _FUNC_RE.search(line)
        if m:
            name = m.group(1)
            funcs[name] = []
        if name is not None:
            funcs[name].append(line)
    costs = {n: _parse_func(ls) for n, ls in funcs.items()}

    memo: dict[str, ModuleCost] = {}

    def resolve(n: str) -> ModuleCost:
        if n in memo:
            return memo[n]
        fc = costs.get(n)
        if fc is None:
            return ModuleCost(0, 0, 0, {})
        coll = {
            k: {"bytes": v["bytes"], "count": v["count"]}
            for k, v in fc.coll.items()
        }
        total = ModuleCost(fc.dot_flops, fc.dot_bytes, fc.result_bytes, coll)
        for callee, mult in fc.calls:
            sub = resolve(callee)
            total.flops += mult * sub.flops
            total.dot_bytes += mult * sub.dot_bytes
            total.result_bytes += mult * sub.result_bytes
            for k, v in sub.coll.items():
                slot = total.coll.setdefault(k, {"bytes": 0.0, "count": 0.0})
                slot["bytes"] += mult * v["bytes"]
                slot["count"] += mult * v["count"]
        memo[n] = total
        return total

    return resolve(entry)
