"""Composable decoder-only model zoo.

Pure per-device math lives here (attention, MoE expert compute, RG-LRU,
xLSTM, norms, RoPE); all cross-device movement is orchestrated by
``repro.core`` (the paper's contribution) and ``repro.launch``.
"""
from repro.models.transformer import (
    Model,
    build_model,
)
from repro.models.cache import DecodeState, init_decode_state

__all__ = ["Model", "build_model", "DecodeState", "init_decode_state"]
