"""MoE routing + grouped expert FFN (local math, capacity-based dispatch).

Dispatch uses scatter/gather with flat (expert, slot) indices instead of a
dense (T, E, C) one-hot so memory stays O(T*k + E*C*D). Cross-rank MoE
execution (DEP all-to-all, DWDP weight gather) is orchestrated in
``repro.core``; this module is purely per-device.
"""
from __future__ import annotations

from typing import NamedTuple

import jax
import jax.numpy as jnp


class Dispatch(NamedTuple):
    flat_slot: jax.Array   # (T*k,) int32 index into (E*C) expert slots
    weight: jax.Array      # (T*k,) f32 combine weight (0 for dropped tokens)
    keep: jax.Array        # (T*k,) bool
    gates: jax.Array       # (T, E) full softmax gates (for aux loss)
    top_experts: jax.Array  # (T, k)


def capacity_for(tokens: int, num_experts: int, top_k: int, factor: float) -> int:
    cap = int(tokens * top_k / num_experts * factor) + 1
    if cap >= 8:
        return -(-cap // 8) * 8  # round up to a lane-aligned multiple of 8
    # decode-scale batches: an 8-slot floor would compute 8x the routed
    # tokens per expert (EXPERIMENTS.md §Perf, r1 decode) — keep it exact
    return cap


def route_topk(
    x: jax.Array, w_router: jax.Array, top_k: int, capacity: int,
    num_real: int | None = None,
) -> Dispatch:
    """x: (T, D); w_router: (D, E). Experts >= num_real are padding slots
    (from the weak placement constraint) and are masked out of routing."""
    T = x.shape[0]
    E = w_router.shape[1]
    if w_router.dtype != x.dtype:
        w_router = w_router.astype(x.dtype)
    logits = (x @ w_router).astype(jnp.float32)
    if num_real is not None and num_real < E:
        mask = jnp.arange(E) < num_real
        logits = jnp.where(mask, logits, -1e30)
    gates = jax.nn.softmax(logits, axis=-1)  # (T, E)
    top_vals, top_idx = jax.lax.top_k(gates, top_k)  # (T, k)
    top_vals = top_vals / jnp.maximum(
        jnp.sum(top_vals, axis=-1, keepdims=True), 1e-9
    )

    flat_exp = top_idx.reshape(-1)  # (T*k,) token-major priority
    oh = jax.nn.one_hot(flat_exp, E, dtype=jnp.int32)
    pos = jnp.sum((jnp.cumsum(oh, axis=0) - 1) * oh, axis=-1)  # slot within expert
    keep = pos < capacity
    flat_slot = flat_exp * capacity + jnp.minimum(pos, capacity - 1)
    weight = top_vals.reshape(-1) * keep
    return Dispatch(flat_slot, weight, keep, gates, top_idx)


def route_topk_rows(
    x: jax.Array, w_router: jax.Array, top_k: int, capacity_per_row: int,
    num_real: int | None = None,
) -> Dispatch:
    """Row-independent routing for layout-invariant drops
    (``ExecutionPlan.capacity_from == "global"``).

    x: (R, S, D). Each row competes only with itself for its own
    ``capacity_per_row`` slots per expert, so whether a token is dropped
    is a function of its row alone — under batch sharding rows never
    split across ranks, hence every DWDP layout of the same global batch
    drops the *identical* token set (1-device included). This is the
    "global" capacity derivation: ``capacity_per_row`` comes from the
    global per-row token count, never from the local shard size.

    Returns a Dispatch over the flattened (R*S) tokens whose
    ``flat_slot`` indexes an ``(E, R * capacity_per_row)`` slot grid
    (row-major within each expert), directly consumable by
    ``dispatch_tokens(..., capacity=R * capacity_per_row)``.
    """
    r, s, _ = x.shape
    e = w_router.shape[1]
    cap = capacity_per_row
    d = jax.vmap(
        lambda xb: route_topk(xb, w_router, top_k, cap, num_real=num_real)
    )(x)
    exp = d.flat_slot // cap                       # (R, S*k)
    pos = d.flat_slot - exp * cap
    flat = exp * (r * cap) + jnp.arange(r)[:, None] * cap + pos
    return Dispatch(
        flat.reshape(-1),
        d.weight.reshape(-1),
        d.keep.reshape(-1),
        d.gates.reshape(r * s, e),
        d.top_experts.reshape(r * s, top_k),
    )


def dispatch_tokens(x: jax.Array, d: Dispatch, num_experts: int, capacity: int):
    """Scatter tokens into (E, C, D) expert batches."""
    T, D = x.shape
    k = d.flat_slot.shape[0] // T
    xk = jnp.repeat(x, k, axis=0) * d.keep[:, None].astype(x.dtype)
    xe = jnp.zeros((num_experts * capacity, D), x.dtype).at[d.flat_slot].add(xk)
    return xe.reshape(num_experts, capacity, D)


def combine_tokens(ye: jax.Array, d: Dispatch, tokens: int) -> jax.Array:
    """Gather expert outputs back to (T, D) with combine weights."""
    E, C, D = ye.shape
    k = d.flat_slot.shape[0] // tokens
    yk = ye.reshape(E * C, D)[d.flat_slot] * d.weight[:, None].astype(ye.dtype)
    return yk.reshape(tokens, k, D).sum(axis=1)


def grouped_ffn(xe: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array):
    """Batched per-expert SwiGLU. xe: (E,C,D); w_*: (E,D,F)/(E,F,D).
    fp8-stored weights dequantize to the activation dtype on use."""
    cast = lambda w: w.astype(xe.dtype) if w.dtype != xe.dtype else w
    h = jax.nn.silu(jnp.einsum("ecd,edf->ecf", xe, cast(w_gate))) * jnp.einsum(
        "ecd,edf->ecf", xe, cast(w_up)
    )
    return jnp.einsum("ecf,efd->ecd", h, cast(w_down))


def moe_ffn(
    x: jax.Array,
    w_router: jax.Array,
    w_gate: jax.Array,
    w_up: jax.Array,
    w_down: jax.Array,
    *,
    top_k: int,
    capacity_factor: float = 1.25,
    capacity: int | None = None,
    num_real: int | None = None,
):
    """Full local MoE FFN over flattened tokens x: (T, D) -> (T, D), aux."""
    T = x.shape[0]
    E = w_router.shape[1]
    if capacity is None:
        capacity = capacity_for(T, num_real or E, top_k, capacity_factor)
    d = route_topk(x, w_router, top_k, capacity, num_real=num_real)
    xe = dispatch_tokens(x, d, E, capacity)
    ye = grouped_ffn(xe, w_gate, w_up, w_down)
    y = combine_tokens(ye, d, T)
    return y, load_balance_loss(d, E)


def load_balance_loss(d: Dispatch, num_experts: int) -> jax.Array:
    """Switch-style auxiliary load-balance loss."""
    T = d.gates.shape[0]
    k = d.top_experts.shape[1]
    frac_tokens = jnp.zeros(num_experts).at[d.top_experts.reshape(-1)].add(1.0) / (
        T * k
    )
    frac_gates = jnp.mean(d.gates, axis=0)
    return num_experts * jnp.sum(frac_tokens * frac_gates)
