"""Decode-time state: KV caches (ring-buffered for sliding-window layers)
and recurrent states, structured to mirror the layer plan (stacked leading
cycle axis for scan groups) so the same ``lax.scan`` drives decode.
"""
from __future__ import annotations

from typing import Any

import jax
import jax.numpy as jnp
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind
from repro.models.transformer import LayerSig, Model
from repro.models.xlstm import init_mlstm_state, init_slstm_state

PyTree = Any


def _attn_cache_len(sig: LayerSig, seq_len: int) -> int:
    if sig.window:
        return min(sig.window, seq_len)
    return seq_len


def init_layer_state(
    cfg: ArchConfig, sig: LayerSig, batch: int, seq_len: int, dtype
) -> dict:
    if sig.kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN):
        length = _attn_cache_len(sig, seq_len)
        kh, hd = cfg.num_kv_heads, cfg.head_dim
        return {
            "k": jnp.zeros((batch, length, kh, hd), dtype),
            "v": jnp.zeros((batch, length, kh, hd), dtype),
            "slot_pos": jnp.full((batch, length), -1, jnp.int32),
        }
    if sig.kind == BlockKind.RECURRENT:
        return {
            "conv": jnp.zeros((batch, 3, cfg.d_model), dtype),
            "h": jnp.zeros((batch, cfg.d_model), dtype),
        }
    if sig.kind == BlockKind.MLSTM:
        return init_mlstm_state(batch, cfg.num_heads, cfg.d_model // cfg.num_heads, dtype)
    if sig.kind == BlockKind.SLSTM:
        return init_slstm_state(batch, cfg.d_model, dtype)
    raise ValueError(sig.kind)


def layer_state_pspecs(
    sig: LayerSig, batch_axes: tuple[str, ...], seq_axes: tuple[str, ...]
) -> dict:
    b = batch_axes if batch_axes else None
    s = seq_axes if seq_axes else None
    if sig.kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN):
        return {
            "k": P(b, s, None, None),
            "v": P(b, s, None, None),
            "slot_pos": P(b, s),
        }
    if sig.kind == BlockKind.RECURRENT:
        return {"conv": P(b, None, None), "h": P(b, None)}
    if sig.kind == BlockKind.MLSTM:
        return {"C": P(b, None, None, None), "n": P(b, None, None), "m": P(b, None)}
    if sig.kind == BlockKind.SLSTM:
        return {k: P(b, None) for k in ("c", "n", "h", "m")}
    raise ValueError(sig.kind)


# A decode state is a plain dict pytree:
#   {"pos": int32 scalar, "layers": {group: {posJ: state}}}
DecodeState = dict


def init_decode_state(
    model: Model, batch: int, seq_len: int, *, prefilled: int = 0
) -> DecodeState:
    """``prefilled`` may be a scalar or a (batch,) per-row fill depth
    (continuous batching serves rows at different positions)."""
    cfg = model.cfg
    layers: dict = {}
    for group in model.plan:
        gdict = {}
        for j, sig in enumerate(group.sigs):
            st = init_layer_state(cfg, sig, batch, seq_len, model.dtype)
            if group.scan:
                st = jax.tree.map(
                    lambda x: jnp.broadcast_to(
                        x[None], (group.n_cycles,) + x.shape
                    ),
                    st,
                )
            gdict[f"pos{j}"] = st
        layers[group.name] = gdict
    pos = jnp.broadcast_to(jnp.asarray(prefilled, jnp.int32), (batch,))
    return {"pos": pos, "layers": layers}


def decode_state_pspecs(
    model: Model, batch_axes: tuple[str, ...], seq_axes: tuple[str, ...]
) -> DecodeState:
    layers: dict = {}
    for group in model.plan:
        gdict = {}
        for j, sig in enumerate(group.sigs):
            sp = layer_state_pspecs(sig, batch_axes, seq_axes)
            if group.scan:
                sp = jax.tree.map(
                    lambda s: P(None, *s), sp,
                    is_leaf=lambda x: isinstance(x, P),
                )
            gdict[f"pos{j}"] = sp
        layers[group.name] = gdict
    b = batch_axes if batch_axes else None
    return {"pos": P(b), "layers": layers}


def decode_state_struct(model: Model, batch: int, seq_len: int) -> PyTree:
    return jax.eval_shape(
        lambda: init_decode_state(model, batch, seq_len)
    )
