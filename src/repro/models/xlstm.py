"""xLSTM blocks: mLSTM (matrix memory) and sLSTM (scalar memory).

Both use exponential gating with the log-space max stabilizer from the
xLSTM paper (arXiv:2405.04517). Projections run outside the time scan;
only the (cheap, elementwise / outer-product) recurrence is sequential,
so HLO FLOP accounting stays projection-dominated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

NEG_INF = -1e30


# --------------------------------------------------------------------------
# mLSTM: per-head matrix memory C (hd x hd), parallel-friendly recurrence.
# --------------------------------------------------------------------------
def mlstm_block(x: jax.Array, p: dict, state: dict | None):
    """x: (B,S,D). state: {"C": (B,H,hd,hd), "n": (B,H,hd), "m": (B,H)}."""
    B, S, D = x.shape
    H = p["w_if"].shape[1] // 2
    hd = D // H
    if state is None:
        state = init_mlstm_state(B, H, hd, x.dtype)

    q = (x @ p["w_q"]).reshape(B, S, H, hd)
    k = (x @ p["w_k"]).reshape(B, S, H, hd) * (hd**-0.5)
    v = (x @ p["w_v"]).reshape(B, S, H, hd)
    gates = x @ p["w_if"]  # (B,S,2H): [i_raw, f_raw]
    i_raw = gates[..., :H].astype(jnp.float32)
    f_raw = gates[..., H:].astype(jnp.float32)
    log_f = jax.nn.log_sigmoid(f_raw)
    o_gate = jax.nn.sigmoid(x @ p["w_og"])  # (B,S,D)

    def step(carry, inp):
        C, n, m = carry
        qt, kt, vt, it, lft = inp  # (B,H,hd) x3, (B,H) x2
        m_new = jnp.maximum(lft + m, it)
        i_p = jnp.exp(it - m_new)
        f_p = jnp.exp(lft + m - m_new)
        C = f_p[..., None, None] * C + i_p[..., None, None] * (
            kt[..., :, None] * vt[..., None, :]
        )
        n = f_p[..., None] * n + i_p[..., None] * kt
        num = jnp.einsum("bhde,bhd->bhe", C, qt)
        den = jnp.maximum(
            jnp.abs(jnp.einsum("bhd,bhd->bh", n, qt)), jnp.exp(-m_new)
        )
        h = num / den[..., None]
        return (C, n, m_new), h

    xs = (
        q.transpose(1, 0, 2, 3).astype(jnp.float32),
        k.transpose(1, 0, 2, 3).astype(jnp.float32),
        v.transpose(1, 0, 2, 3).astype(jnp.float32),
        i_raw.transpose(1, 0, 2),
        log_f.transpose(1, 0, 2),
    )
    (C, n, m), hs = jax.lax.scan(step, (state["C"], state["n"], state["m"]), xs)
    h = hs.transpose(1, 0, 2, 3).reshape(B, S, D).astype(x.dtype)
    out = (h * o_gate) @ p["w_out"]
    return out, {"C": C, "n": n, "m": m}


def init_mlstm_state(batch: int, heads: int, head_dim: int, dtype) -> dict:
    return {
        "C": jnp.zeros((batch, heads, head_dim, head_dim), jnp.float32),
        "n": jnp.zeros((batch, heads, head_dim), jnp.float32),
        "m": jnp.full((batch, heads), 0.0, jnp.float32),
    }


def init_mlstm_params(key: jax.Array, d_model: int, heads: int, dtype) -> dict:
    ks = jax.random.split(key, 6)
    s = d_model**-0.5
    return {
        "w_q": (jax.random.normal(ks[0], (d_model, d_model)) * s).astype(dtype),
        "w_k": (jax.random.normal(ks[1], (d_model, d_model)) * s).astype(dtype),
        "w_v": (jax.random.normal(ks[2], (d_model, d_model)) * s).astype(dtype),
        "w_if": (jax.random.normal(ks[3], (d_model, 2 * heads)) * s).astype(dtype),
        "w_og": (jax.random.normal(ks[4], (d_model, d_model)) * s).astype(dtype),
        "w_out": (jax.random.normal(ks[5], (d_model, d_model)) * s).astype(dtype),
    }


# --------------------------------------------------------------------------
# sLSTM: scalar memory with per-head block-diagonal recurrent weights.
# Strictly sequential (h_{t-1} feeds the gates) — scan over time.
# --------------------------------------------------------------------------
def slstm_block(x: jax.Array, p: dict, state: dict | None):
    """x: (B,S,D). state: {"c","n","h": (B,D), "m": (B,D)}."""
    B, S, D = x.shape
    H = p["r_z"].shape[0]
    hd = D // H
    if state is None:
        state = init_slstm_state(B, D, x.dtype)

    # input contributions for all gates, computed outside the scan
    zx = x @ p["w_z"]
    ix = x @ p["w_i"]
    fx = x @ p["w_f"]
    ox = x @ p["w_o"]

    def rmul(h, r):  # per-head block-diagonal recurrent matmul
        hh = h.reshape(B, H, hd)
        return jnp.einsum("bhd,hde->bhe", hh, r).reshape(B, D)

    def step(carry, inp):
        c, n, h, m = carry
        zxt, ixt, fxt, oxt = inp
        z = jnp.tanh(zxt + rmul(h, p["r_z"])).astype(jnp.float32)
        i_raw = (ixt + rmul(h, p["r_i"])).astype(jnp.float32)
        f_raw = (fxt + rmul(h, p["r_f"])).astype(jnp.float32)
        o = jax.nn.sigmoid(oxt + rmul(h, p["r_o"])).astype(jnp.float32)
        log_f = jax.nn.log_sigmoid(f_raw)
        m_new = jnp.maximum(log_f + m, i_raw)
        i_p = jnp.exp(i_raw - m_new)
        f_p = jnp.exp(log_f + m - m_new)
        c = f_p * c + i_p * z
        n = f_p * n + i_p
        h_new = (o * c / jnp.maximum(n, 1e-12)).astype(x.dtype)
        return (c, n, h_new, m_new), h_new

    xs = tuple(a.transpose(1, 0, 2) for a in (zx, ix, fx, ox))
    (c, n, h, m), hs = jax.lax.scan(
        step, (state["c"], state["n"], state["h"], state["m"]), xs
    )
    out = hs.transpose(1, 0, 2) @ p["w_out"]
    return out, {"c": c, "n": n, "h": h, "m": m}


def init_slstm_state(batch: int, d_model: int, dtype) -> dict:
    return {
        "c": jnp.zeros((batch, d_model), jnp.float32),
        "n": jnp.zeros((batch, d_model), jnp.float32),
        "h": jnp.zeros((batch, d_model), dtype),
        "m": jnp.zeros((batch, d_model), jnp.float32),
    }


def init_slstm_params(key: jax.Array, d_model: int, heads: int, dtype) -> dict:
    ks = jax.random.split(key, 9)
    s = d_model**-0.5
    hd = d_model // heads
    sr = hd**-0.5
    p = {
        f"w_{g}": (jax.random.normal(k, (d_model, d_model)) * s).astype(dtype)
        for g, k in zip("zifo", ks[:4])
    }
    p.update(
        {
            f"r_{g}": (jax.random.normal(k, (heads, hd, hd)) * sr).astype(dtype)
            for g, k in zip("zifo", ks[4:8])
        }
    )
    p["w_out"] = (jax.random.normal(ks[8], (d_model, d_model)) * s).astype(dtype)
    return p
