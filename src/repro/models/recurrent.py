"""RG-LRU recurrent block (RecurrentGemma / Griffin).

The diagonal linear recurrence h_t = a_t*h_{t-1} + b_t is evaluated with an
associative scan (log-depth, O(S*D) memory); qkv-style projections stay
outside the scan so HLO FLOP accounting remains matmul-dominated.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.models.layers import causal_conv1d

_RGLRU_C = 8.0


def rglru_parts(x: jax.Array, w_r: jax.Array, w_i: jax.Array, a_param: jax.Array):
    """Real-Gated LRU pieces for h_t = a_t*h_{t-1} + b_t with h0 = 0.

    Returns (A, h_loc): A (B,S,D) is the cumulative decay prod_{s<=t} a_s
    and h_loc the zero-state solution. Because the recurrence is linear
    and diagonal, the solution for any h0 is ``h_loc + A * h0`` — this is
    what makes cross-shard sequence sharding a local fix-up (execution.py).
    """
    r = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, w_r))
    i = jax.nn.sigmoid(jnp.einsum("bsd,de->bse", x, w_i))
    log_a = -_RGLRU_C * jax.nn.softplus(a_param) * r.astype(jnp.float32)
    a = jnp.exp(log_a)
    gated = (x * i).astype(jnp.float32)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.square(a), 1e-12)) * gated

    def comb(c1, c2):
        a1, b1 = c1
        a2, b2 = c2
        return a1 * a2, a2 * b1 + b2

    A, h = jax.lax.associative_scan(comb, (a, b), axis=1)
    return A, h


def rglru(x: jax.Array, w_r: jax.Array, w_i: jax.Array, a_param: jax.Array, h0: jax.Array):
    """Real-Gated LRU. x: (B,S,D); h0: (B,D). Returns (y, h_last)."""
    A, h_loc = rglru_parts(x, w_r, w_i, a_param)
    h = h_loc + A * h0.astype(jnp.float32)[:, None]
    return h.astype(x.dtype), h[:, -1].astype(x.dtype)


def recurrent_block(x: jax.Array, p: dict, state: dict | None):
    """Griffin recurrent block: (linear->conv->RG-LRU) * gelu(linear) -> out.

    x: (B,S,D). state: {"conv": (B,K-1,D), "h": (B,D)} or None (zeros).
    Returns (out, new_state).
    """
    B, S, D = x.shape
    if state is None:
        state = {
            "conv": jnp.zeros((B, p["conv_w"].shape[0] - 1, D), x.dtype),
            "h": jnp.zeros((B, D), x.dtype),
        }
    branch = x @ p["w_x"]
    branch, conv_state = causal_conv1d(branch, p["conv_w"], state["conv"])
    branch, h_last = rglru(branch, p["w_r"], p["w_i"], p["a_param"], state["h"])
    gate = jax.nn.gelu(x @ p["w_gate"], approximate=True)
    out = (branch * gate) @ p["w_o"]
    return out, {"conv": conv_state, "h": h_last}


def init_recurrent_params(key: jax.Array, d_model: int, dtype, conv_width: int = 4) -> dict:
    ks = jax.random.split(key, 6)
    s = d_model**-0.5
    # a_param init so that a ~ U[0.9, 0.999]^(1/c) band (Griffin's init)
    u = jax.random.uniform(ks[5], (d_model,), jnp.float32, 0.9, 0.999)
    a_param = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    return {
        "w_x": (jax.random.normal(ks[0], (d_model, d_model)) * s).astype(dtype),
        "w_gate": (jax.random.normal(ks[1], (d_model, d_model)) * s).astype(dtype),
        "w_o": (jax.random.normal(ks[2], (d_model, d_model)) * s).astype(dtype),
        "w_r": (jax.random.normal(ks[3], (d_model, d_model)) * s).astype(dtype),
        "w_i": (jax.random.normal(ks[4], (d_model, d_model)) * s).astype(dtype),
        "conv_w": jnp.zeros((conv_width, d_model), dtype).at[-1].set(1.0),
        "a_param": a_param,
    }
