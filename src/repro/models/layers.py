"""Shared primitive layers: norms, RoPE, gated MLPs, embeddings.

Everything here is local math on per-device arrays — no collectives.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def rms_norm(x: jax.Array, scale: jax.Array, eps: float) -> jax.Array:
    dtype = x.dtype
    x = x.astype(jnp.float32)
    var = jnp.mean(jnp.square(x), axis=-1, keepdims=True)
    out = x * jax.lax.rsqrt(var + eps) * (1.0 + scale.astype(jnp.float32))
    return out.astype(dtype)


def rope_frequencies(head_dim: int, theta: float) -> np.ndarray:
    return 1.0 / (theta ** (np.arange(0, head_dim, 2, dtype=np.float32) / head_dim))


def apply_rope(x: jax.Array, positions: jax.Array, theta: float) -> jax.Array:
    """Rotary embedding. x: (..., S, H, head_dim); positions: (..., S)."""
    head_dim = x.shape[-1]
    freqs = jnp.asarray(rope_frequencies(head_dim, theta))
    angles = positions[..., None].astype(jnp.float32) * freqs  # (..., S, hd/2)
    angles = angles[..., None, :]  # broadcast over heads
    cos, sin = jnp.cos(angles), jnp.sin(angles)
    x1, x2 = jnp.split(x.astype(jnp.float32), 2, axis=-1)
    out = jnp.concatenate([x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1)
    return out.astype(x.dtype)


def swiglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    """Gated MLP: silu(x @ w_gate) * (x @ w_up) @ w_down."""
    h = jax.nn.silu(x @ w_gate) * (x @ w_up)
    return h @ w_down


def geglu(x: jax.Array, w_gate: jax.Array, w_up: jax.Array, w_down: jax.Array) -> jax.Array:
    h = jax.nn.gelu(x @ w_gate, approximate=True) * (x @ w_up)
    return h @ w_down


def softcap(logits: jax.Array, cap: float) -> jax.Array:
    if cap <= 0.0:
        return logits
    return cap * jnp.tanh(logits / cap)


def causal_conv1d(x: jax.Array, w: jax.Array, state: jax.Array | None = None):
    """Depthwise causal conv. x: (B, S, D), w: (K, D).

    Returns (out, new_state) where state carries the trailing K-1 inputs for
    decode continuation.
    """
    k = w.shape[0]
    if state is None:
        pad = jnp.zeros(x.shape[:-2] + (k - 1, x.shape[-1]), x.dtype)
    else:
        pad = state
    xp = jnp.concatenate([pad, x], axis=-2)  # (B, S+K-1, D)
    out = sum(xp[..., i : i + x.shape[-2], :] * w[i] for i in range(k))
    new_state = xp[..., -(k - 1) :, :] if k > 1 else jnp.zeros_like(pad)
    return out.astype(x.dtype), new_state
