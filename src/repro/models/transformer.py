"""Layer plan, parameter init/specs, and local projection helpers.

Storage layout convention (the backbone of the whole framework): every
shardable weight carries an explicit leading *shard axis*:

- dense FFN:       (S, D, F/S) / (S, F/S, D)      S = geom.ffn_shards
- MoE experts:     (G*local, D, Fe) / (..., Fe, D) placement-expanded
- attention:       (A, D, qdim/A) etc.             A = geom.attn_shards
- embed/lm_head:   vocab-sharded over "model"

With shard axis 1 the tensor is replicated. The same einsum consumes the
tensor whether it arrives replicated, locally sharded (TP), or freshly
gathered (DWDP) — this uniformity is the TPU analogue of the paper's §4.2
split-weight TensorList kernel: no layout change is ever needed between
"resident" and "fetched" weights.

Heterogeneous stacks (sliding/global mixes, RG-LRU hybrids, xLSTM) are
grouped into scan-able cycles by ``make_layer_plan`` so 95-layer models
lower as a short ``lax.scan`` over stacked params, not 95 inlined layers.
"""
from __future__ import annotations

import dataclasses
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind
from repro.core.placement import Placement, expand_to_storage, make_placement
from repro.models.recurrent import init_recurrent_params
from repro.models.xlstm import init_mlstm_params, init_slstm_params

PyTree = Any
AXIS_MODEL = "model"


# --------------------------------------------------------------------------
# Geometry: how weights are laid out for a given mesh (mode-independent).
# --------------------------------------------------------------------------
HBM_BYTES = 16e9  # TPU v5e


@dataclasses.dataclass(frozen=True)
class Geometry:
    """Weight storage geometry for one mesh.

    Each weight family gets a tuple of mesh axes it is sharded over
    (empty tuple = replicated — the paper-faithful layout for attention):

    - ``expert_axes``: the MoE expert bank. On v5e the big banks (grok
      294B, R1 656GB, llama4 383GB of expert weights) bust 16GB HBM when
      sharded over "model" alone, so the planner widens the DWDP group to
      ("data","model"). ``moe_exec`` selects per-layer execution: "gather"
      (paper-faithful full-layer prefetch; needs 2x the layer's expert
      bytes resident) or "rotate" (ring-rotate weight shards through
      ranks, computing each resident shard's contribution — the TPU
      memory-hierarchy adaptation, DESIGN.md §2/§7).
    - ``ffn_axes`` / ``attn_axes`` / ``cell_axes``: dense FFN ("virtual
      experts" — the DWDP generalization), attention projections, and
      recurrent-cell weights. Serve mode shards FFN over "model" and
      escalates attention only when replication busts HBM; train mode
      shards everything over ("data","model") (ZeRO-3-style — the gather
      machinery doubles as the train-time weight fetch).
    """

    model_size: int
    expert_axes: tuple[str, ...]
    moe_placement: Optional[Placement]
    moe_exec: str                    # "gather" | "rotate"
    ffn_axes: tuple[str, ...]
    ffn_shards: int
    attn_axes: tuple[str, ...]
    attn_shards: int
    kv_shard: int                    # distinct kv groups when attention sharded
    cell_axes: tuple[str, ...]
    cell_shards: int
    vocab_pad: int
    train: bool
    attn_tp_ok: bool = False   # heads divide the model axis (DEP TP legal)

    @classmethod
    def build(
        cls,
        cfg: ArchConfig,
        mesh_sizes: dict[str, int],
        *,
        dtype_bytes: int = 2,
        train: bool = False,
        shard_ffn: bool = True,
        shard_attention: Optional[bool] = None,
        redundancy: Optional[int] = None,
        moe_exec: Optional[str] = None,
        expert_axes: Optional[tuple[str, ...]] = None,
        ffn_axes_override: Optional[tuple[str, ...]] = None,
        attn_axes_override: Optional[tuple[str, ...]] = None,
    ) -> "Geometry":
        g_model = mesh_sizes.get("model", 1)
        wide = tuple(a for a in ("data", "model") if a in mesh_sizes)
        n_wide = math.prod(mesh_sizes[a] for a in wide)

        def axsize(axes):
            return math.prod(mesh_sizes.get(a, 1) for a in axes)

        # --- per-rank byte pressure estimates (bf16-equivalent) -----------
        bytes_per_param = dtype_bytes + (12 if train else 0)  # + grads/adam
        attn_bytes = sum(
            cfg._mixer_params(l) for l in range(cfg.num_layers)
        ) * bytes_per_param
        dense_ffn_bytes = sum(
            3 * cfg.d_model * cfg.ffn_dim(l)
            for l in range(cfg.num_layers)
            if cfg.ffn_dim(l)
        ) * bytes_per_param

        # --- MoE expert bank ----------------------------------------------
        placement = None
        chosen_exec = "gather"
        if cfg.moe is not None:
            moe_cfg = cfg.moe
            n_moe = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
            per_expert = 3 * cfg.d_model * moe_cfg.d_ff * dtype_bytes
            bank = n_moe * moe_cfg.num_experts * per_expert * (
                bytes_per_param / dtype_bytes
            )
            if expert_axes is None:
                expert_axes = ("model",) if g_model > 1 else wide[-1:] or ("model",)
                if bank / g_model > 0.55 * HBM_BYTES and len(wide) > 1:
                    expert_axes = wide
                if train and len(wide) > 1 and bank / g_model > 0.3 * HBM_BYTES:
                    expert_axes = wide
            placement = make_placement(
                moe_cfg.num_experts, axsize(expert_axes), redundancy=redundancy
            )
            layer_set = placement.num_padded * per_expert
            chosen_exec = moe_exec or (
                "gather" if 2 * layer_set < 0.3 * HBM_BYTES else "rotate"
            )
            if len(expert_axes) > 1 and chosen_exec == "gather" and moe_exec is None:
                # gather mode keeps 2x a full layer resident; multi-axis
                # groups only arise for banks that need rotate anyway.
                chosen_exec = "rotate" if 2 * layer_set > 0.3 * HBM_BYTES else "gather"
        else:
            expert_axes = expert_axes or ("model",)

        # --- dense FFN ("virtual experts") ---------------------------------
        has_dense = any(cfg.ffn_dim(l) for l in range(cfg.num_layers)) or (
            cfg.moe is not None and cfg.moe.shared_d_ff
        )
        if not has_dense or not shard_ffn or g_model == 1:
            ffn_axes: tuple[str, ...] = ()
        elif (train and dense_ffn_bytes / n_wide * len(wide) > 0.3 * HBM_BYTES) or (
            dense_ffn_bytes / g_model > 0.6 * HBM_BYTES
        ):
            ffn_axes = wide
        else:
            ffn_axes = ("model",)
        if train and has_dense and g_model > 1:
            ffn_axes = ffn_axes or ("model",)
        if ffn_axes_override is not None:
            ffn_axes = ffn_axes_override

        # --- attention ------------------------------------------------------
        if shard_attention is None:
            if train:
                shard_attention = attn_bytes > 0.3 * HBM_BYTES * g_model / n_wide
            else:
                shard_attention = attn_bytes > 0.35 * HBM_BYTES
        attn_axes: tuple[str, ...] = ()
        if shard_attention and cfg.has_attention and g_model > 1:
            attn_axes = ("model",)
            if train or attn_bytes / g_model > 0.6 * HBM_BYTES:
                attn_axes = wide
        if attn_axes_override is not None:
            attn_axes = attn_axes_override
        a_sh = axsize(attn_axes)
        if attn_axes and cfg.q_dim % a_sh:
            attn_axes = ()
            a_sh = 1
        kv_shard = math.gcd(a_sh, cfg.num_kv_heads) if attn_axes else 1
        attn_tp_ok = bool(
            attn_axes == ("model",)
            and cfg.num_heads % g_model == 0
            and kv_shard
            and cfg.num_kv_heads % kv_shard == 0
        )

        # --- recurrent cells (train-time ZeRO only) -------------------------
        cell_kinds = {BlockKind.RECURRENT, BlockKind.MLSTM, BlockKind.SLSTM}
        has_cells = any(k in cell_kinds for k in cfg.block_pattern)
        cell_axes: tuple[str, ...] = ()
        if train and has_cells and attn_axes:
            cell_axes = attn_axes

        vocab_pad = -(-cfg.vocab_size // max(g_model, 1)) * max(g_model, 1)
        return cls(
            model_size=g_model,
            expert_axes=tuple(expert_axes),
            moe_placement=placement,
            moe_exec=chosen_exec,
            ffn_axes=ffn_axes,
            ffn_shards=axsize(ffn_axes),
            attn_axes=attn_axes,
            attn_shards=a_sh,
            kv_shard=kv_shard,
            cell_axes=cell_axes,
            cell_shards=axsize(cell_axes),
            vocab_pad=vocab_pad,
            train=train,
            attn_tp_ok=attn_tp_ok,
        )


# --------------------------------------------------------------------------
# Layer plan: group layers into scan-able cycles.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class LayerSig:
    kind: BlockKind
    window: int          # 0 = full attention
    is_moe: bool
    ffn_dim: int         # dense FFN dim on this layer (0 = none/MoE)
    shared_d_ff: int = 0  # always-on shared expert dim (MoE layers)


@dataclasses.dataclass(frozen=True)
class LayerGroup:
    name: str
    scan: bool
    n_cycles: int                 # 1 for unrolled groups
    sigs: tuple[LayerSig, ...]    # one per position in the cycle
    first_layer: int


def signature(cfg: ArchConfig, layer: int, *, long_variant: bool = False) -> LayerSig:
    kind = cfg.block_kind(layer)
    window = cfg.window if kind == BlockKind.LOCAL_ATTN else 0
    if long_variant and kind == BlockKind.GLOBAL_ATTN:
        kind = BlockKind.LOCAL_ATTN
        window = cfg.long_context_window
    is_moe = cfg.is_moe_layer(layer)
    return LayerSig(
        kind=kind,
        window=window,
        is_moe=is_moe,
        ffn_dim=cfg.ffn_dim(layer),
        shared_d_ff=(cfg.moe.shared_d_ff if (is_moe and cfg.moe) else 0),
    )


def make_layer_plan(cfg: ArchConfig, *, long_variant: bool = False) -> list[LayerGroup]:
    prefix = cfg.moe.first_dense if cfg.moe else 0
    pat = len(cfg.block_pattern)
    if prefix and pat > 1 and prefix % pat:
        raise ValueError(f"{cfg.name}: first_dense must align with block pattern")
    period = pat
    if cfg.moe is not None:
        period = math.lcm(pat, cfg.moe.every)
    groups: list[LayerGroup] = []
    sig = lambda l: signature(cfg, l, long_variant=long_variant)
    if prefix:
        groups.append(
            LayerGroup(
                "prefix", False, 1, tuple(sig(l) for l in range(prefix)), 0
            )
        )
    body = cfg.num_layers - prefix
    n_cycles, rem = divmod(body, period)
    if n_cycles:
        sigs = tuple(sig(prefix + j) for j in range(period))
        # verify periodicity holds across the whole body
        for c in range(n_cycles):
            for j in range(period):
                assert sig(prefix + c * period + j) == sigs[j], (cfg.name, c, j)
        groups.append(LayerGroup("body", n_cycles > 1, n_cycles, sigs, prefix))
    if rem:
        start = prefix + n_cycles * period
        groups.append(
            LayerGroup(
                "suffix",
                False,
                1,
                tuple(sig(l) for l in range(start, cfg.num_layers)),
                start,
            )
        )
    return groups


# --------------------------------------------------------------------------
# Parameter init + PartitionSpecs (built together, same tree structure).
# --------------------------------------------------------------------------
def _norm(shape, dtype):
    return jnp.zeros(shape, dtype)


def _dense(key, shape, dtype, scale=None):
    if scale is None:
        scale = shape[-2] ** -0.5 if len(shape) >= 2 else 1.0
    return (jax.random.normal(key, shape, jnp.float32) * scale).astype(dtype)


def init_attn_params(key, cfg: ArchConfig, geom: Geometry, dtype) -> dict:
    """Init from canonical (D, dim) tensors, then reshape into the stacked
    storage layout — identical values for every mesh/sharding geometry."""
    a = geom.attn_shards
    ks = jax.random.split(key, 4)
    d, qd, kvd = cfg.d_model, cfg.q_dim, cfg.kv_dim
    scale = d**-0.5
    wq_c = _dense(ks[0], (d, qd), dtype, scale)
    wo_c = _dense(ks[3], (qd, d), dtype, qd**-0.5)
    wk_c = _dense(ks[1], (d, kvd), dtype, scale)
    wv_c = _dense(ks[2], (d, kvd), dtype, scale)
    wq = wq_c.reshape(d, a, qd // a).transpose(1, 0, 2)
    wo = wo_c.reshape(a, qd // a, d)
    ksd = geom.kv_shard
    table = np.arange(a) // (a // ksd)
    wk = wk_c.reshape(d, ksd, kvd // ksd).transpose(1, 0, 2)[table]
    wv = wv_c.reshape(d, ksd, kvd // ksd).transpose(1, 0, 2)[table]
    return {"wq": wq, "wk": wk, "wv": wv, "wo": wo}


def _axes_entry(axes: tuple[str, ...]):
    if not axes:
        return None
    return axes if len(axes) > 1 else axes[0]


def attn_pspecs(geom: Geometry) -> dict:
    ax = _axes_entry(geom.attn_axes)
    w = P(ax, None, None)
    return {"wq": w, "wk": w, "wv": w, "wo": w}


def init_ffn_params(key, cfg: ArchConfig, geom: Geometry, ffn_dim: int, dtype) -> dict:
    s = geom.ffn_shards
    f_pad = -(-ffn_dim // s) * s
    ks = jax.random.split(key, 3)
    d = cfg.d_model
    wg = _dense(ks[0], (d, f_pad), dtype, d**-0.5)
    wu = _dense(ks[1], (d, f_pad), dtype, d**-0.5)
    wd = _dense(ks[2], (f_pad, d), dtype, f_pad**-0.5)
    if f_pad != ffn_dim:  # padded hidden units must not contribute
        wd = wd.at[ffn_dim:].set(0.0)
    return {
        "w_gate": wg.reshape(d, s, f_pad // s).transpose(1, 0, 2),
        "w_up": wu.reshape(d, s, f_pad // s).transpose(1, 0, 2),
        "w_down": wd.reshape(s, f_pad // s, d),
    }


def ffn_pspecs(geom: Geometry) -> dict:
    ax = _axes_entry(geom.ffn_axes)
    return {
        "w_gate": P(ax, None, None),
        "w_up": P(ax, None, None),
        "w_down": P(ax, None, None),
    }


def init_moe_params(key, cfg: ArchConfig, geom: Geometry, dtype) -> dict:
    moe, pl = cfg.moe, geom.moe_placement
    assert moe is not None and pl is not None
    ks = jax.random.split(key, 5)
    d, fe = cfg.d_model, moe.d_ff

    storage_table = jnp.asarray(pl.table().reshape(-1))  # (G*local,)

    def expert_bank(k, shape_tail, scale):
        base = _dense(k, (pl.num_padded,) + shape_tail, jnp.float32, scale)
        # zero padded (dummy) experts, then expand to the placement layout
        valid = (jnp.arange(pl.num_padded) < moe.num_experts).astype(base.dtype)
        base = base * valid.reshape((-1,) + (1,) * len(shape_tail))
        return jnp.take(base, storage_table, axis=0).astype(dtype)

    out = {
        "router": _dense(ks[0], (d, pl.num_padded), dtype, d**-0.5),
        "experts": {
            "w_gate": expert_bank(ks[1], (d, fe), d**-0.5),
            "w_up": expert_bank(ks[2], (d, fe), d**-0.5),
            "w_down": expert_bank(ks[3], (fe, d), fe**-0.5),
        },
    }
    if moe.shared_d_ff:
        out["shared"] = init_ffn_params(ks[4], cfg, geom, moe.shared_d_ff, dtype)
    return out


def moe_pspecs(cfg: ArchConfig, geom: Geometry) -> dict:
    w = P(_axes_entry(geom.expert_axes), None, None)
    out = {
        "router": P(None, None),
        "experts": {"w_gate": w, "w_up": w, "w_down": w},
    }
    assert cfg.moe is not None
    if cfg.moe.shared_d_ff:
        out["shared"] = ffn_pspecs(geom)
    return out


def init_layer_params(key, cfg: ArchConfig, geom: Geometry, sig: LayerSig, dtype) -> dict:
    ks = jax.random.split(key, 3)
    p: dict = {"norm1": _norm((cfg.d_model,), dtype)}
    if sig.kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN):
        p["attn"] = init_attn_params(ks[0], cfg, geom, dtype)
    elif sig.kind == BlockKind.RECURRENT:
        p["rec"] = init_recurrent_params(ks[0], cfg.d_model, dtype)
    elif sig.kind == BlockKind.MLSTM:
        p["cell"] = init_mlstm_params(ks[0], cfg.d_model, cfg.num_heads, dtype)
    elif sig.kind == BlockKind.SLSTM:
        p["cell"] = init_slstm_params(ks[0], cfg.d_model, cfg.num_heads, dtype)
    if sig.is_moe:
        p["norm2"] = _norm((cfg.d_model,), dtype)
        p["moe"] = init_moe_params(ks[1], cfg, geom, dtype)
    elif sig.ffn_dim:
        p["norm2"] = _norm((cfg.d_model,), dtype)
        p["ffn"] = init_ffn_params(ks[1], cfg, geom, sig.ffn_dim, dtype)
    return p


def layer_pspecs(cfg: ArchConfig, geom: Geometry, sig: LayerSig) -> dict:
    p: dict = {"norm1": P(None)}
    if sig.kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN):
        p["attn"] = attn_pspecs(geom)
    elif sig.kind == BlockKind.RECURRENT:
        ax = _axes_entry(geom.cell_axes)
        big = P(None, ax)  # (D, D) mats: ZeRO-shard the last dim in train
        p["rec"] = {
            "w_x": big, "w_gate": big, "w_o": big, "w_r": big, "w_i": big,
            "conv_w": P(None, None), "a_param": P(None),
        }
    elif sig.kind in (BlockKind.MLSTM, BlockKind.SLSTM):
        ax = _axes_entry(geom.cell_axes)
        big = P(None, ax)
        names = (
            ["w_q", "w_k", "w_v", "w_if", "w_og", "w_out"]
            if sig.kind == BlockKind.MLSTM
            else ["w_z", "w_i", "w_f", "w_o", "w_out"]
        )
        p["cell"] = {k: big for k in names}
        if sig.kind == BlockKind.SLSTM:
            p["cell"].update({f"r_{g}": P(None, None, None) for g in "zifo"})
    if sig.is_moe:
        p["norm2"] = P(None)
        p["moe"] = moe_pspecs(cfg, geom)
    elif sig.ffn_dim:
        p["norm2"] = P(None)
        p["ffn"] = ffn_pspecs(geom)
    return p


# --------------------------------------------------------------------------
# Whole-model init / specs / abstract shapes.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class Model:
    cfg: ArchConfig
    geom: Geometry
    plan: tuple[LayerGroup, ...]
    dtype: Any

    def init_params(self, key: jax.Array) -> PyTree:
        cfg, geom, dtype = self.cfg, self.geom, self.dtype
        k_embed, k_head, k_layers = jax.random.split(key, 3)
        params: dict = {
            "embed": _dense(
                k_embed, (geom.vocab_pad, cfg.d_model), dtype, 1.0
            ),
            "final_norm": _norm((cfg.d_model,), dtype),
        }
        if not cfg.tie_embeddings:
            params["lm_head"] = _dense(
                k_head, (cfg.d_model, geom.vocab_pad), dtype
            )
        layers: dict = {}
        keys = jax.random.split(k_layers, len(self.plan))
        for group, gk in zip(self.plan, keys):
            gdict: dict = {}
            pos_keys = jax.random.split(gk, len(group.sigs))
            for j, (sig, pk) in enumerate(zip(group.sigs, pos_keys)):
                if group.scan:
                    cyc_keys = jax.random.split(pk, group.n_cycles)
                    stacked = [
                        init_layer_params(ck, cfg, geom, sig, self.dtype)
                        for ck in cyc_keys
                    ]
                    gdict[f"pos{j}"] = jax.tree.map(
                        lambda *xs: jnp.stack(xs), *stacked
                    )
                else:
                    gdict[f"pos{j}"] = init_layer_params(
                        pk, cfg, geom, sig, self.dtype
                    )
            layers[group.name] = gdict
        params["layers"] = layers
        return params

    def param_pspecs(self) -> PyTree:
        cfg, geom = self.cfg, self.geom
        specs: dict = {
            "embed": P(AXIS_MODEL, None),
            "final_norm": P(None),
        }
        if not cfg.tie_embeddings:
            specs["lm_head"] = P(None, AXIS_MODEL)
        layers: dict = {}
        for group in self.plan:
            gdict = {}
            for j, sig in enumerate(group.sigs):
                sp = layer_pspecs(cfg, geom, sig)
                if group.scan:
                    sp = jax.tree.map(
                        lambda s: P(None, *s), sp,
                        is_leaf=lambda x: isinstance(x, P),
                    )
                gdict[f"pos{j}"] = sp
            layers[group.name] = gdict
        specs["layers"] = layers
        return specs

    def param_struct(self) -> PyTree:
        """ShapeDtypeStruct tree without allocating (for the dry-run)."""
        return jax.eval_shape(self.init_params, jax.random.key(0))


def build_model(
    cfg: ArchConfig,
    mesh_sizes: dict[str, int],
    *,
    dtype=jnp.float32,
    long_variant: bool = False,
    **geom_kwargs,
) -> Model:
    dtype_bytes = jnp.dtype(dtype).itemsize
    geom = Geometry.build(
        cfg, mesh_sizes, dtype_bytes=dtype_bytes, **geom_kwargs
    )
    plan = tuple(make_layer_plan(cfg, long_variant=long_variant))
    return Model(cfg=cfg, geom=geom, plan=plan, dtype=dtype)
