"""GQA attention: chunked (flash-style) prefill + cache decode with LSE.

All functions are local per-device math. Sequence-sharded decode returns
``(out_local, lse)`` pairs so ``repro.core.strategy`` can combine shards
with a psum-LSE reduction.

The prefill path scans over KV blocks with an online softmax so the
(S_q x S_k) score matrix is never materialized — required for the 32K
shapes to fit. Note the HLO FLOP count of this path is the full S^2
(masked blocks are still multiplied); the analysis layer applies the
causal 0.5 correction factor (see DESIGN.md §5).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp

NEG_INF = -1e30


def _gqa_logits(q: jax.Array, k: jax.Array) -> jax.Array:
    """q: (B,K,rep,Sq,hd), k: (B,K,L,hd) -> (B,K,rep,Sq,L)."""
    return jnp.einsum("bkrqd,bkld->bkrql", q, k, preferred_element_type=jnp.float32)


def mha_prefill(
    q: jax.Array,
    k: jax.Array,
    v: jax.Array,
    *,
    window: int = 0,
    q_offset=0,
    kv_offset: int = 0,
    block_kv: int = 512,
    block_causal: bool = False,
    block_q: int = 512,
) -> jax.Array:
    """Chunked causal attention. q: (B,Sq,H,hd); k,v: (B,Sk,Kh,hd).

    window=0 means full causal; window=w limits attention to the last w
    keys. ``kv_offset`` is the absolute position of k[:, 0].

    ``block_causal=True`` (requires a static ``q_offset``) skips fully
    masked KV blocks: each q block only visits keys up to its own end (and
    above its window start), halving causal FLOPs/traffic vs the masked
    full rectangle. Sequence-sharded ranks cannot use it (their kv extent
    is rank-dependent, which SPMD cannot express) — see DESIGN.md §9.
    Returns (B,Sq,H,hd).
    """
    B, Sq, H, hd = q.shape
    if block_causal and isinstance(q_offset, int):
        bq = min(block_q, Sq)
        sk = k.shape[1]
        outs = []
        for qi in range(-(-Sq // bq)):
            lo_q = qi * bq
            hi_q = min(Sq, lo_q + bq)
            abs_hi = q_offset + hi_q          # last key this block can see
            kv_hi = min(sk, abs_hi - kv_offset)
            kv_lo = 0
            if window:
                kv_lo = max(0, q_offset + lo_q - window + 1 - kv_offset)
                kv_lo = (kv_lo // block_kv) * block_kv
            outs.append(
                mha_prefill(
                    q[:, lo_q:hi_q],
                    k[:, kv_lo:kv_hi],
                    v[:, kv_lo:kv_hi],
                    window=window,
                    q_offset=q_offset + lo_q,
                    kv_offset=kv_offset + kv_lo,
                    block_kv=block_kv,
                )
            )
        return jnp.concatenate(outs, axis=1)
    Sk, Kh = k.shape[1], k.shape[2]
    rep = H // Kh
    scale = 1.0 / math.sqrt(hd)

    qt = (q * scale).transpose(0, 2, 1, 3).reshape(B, Kh, rep, Sq, hd)
    kt = k.transpose(0, 2, 1, 3)  # (B,Kh,Sk,hd)
    vt = v.transpose(0, 2, 1, 3)

    block_kv = min(block_kv, Sk)
    nblk = -(-Sk // block_kv)
    pad = nblk * block_kv - Sk
    if pad:
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad), (0, 0)))

    q_pos = q_offset + jnp.arange(Sq)

    def body(carry, blk):
        acc, m_run, l_run = carry
        start = blk * block_kv
        kj = jax.lax.dynamic_slice_in_dim(kt, start, block_kv, axis=2)
        vj = jax.lax.dynamic_slice_in_dim(vt, start, block_kv, axis=2)
        logits = _gqa_logits(qt, kj)  # (B,Kh,rep,Sq,block)
        k_pos = kv_offset + start + jnp.arange(block_kv)
        mask = (k_pos[None, :] <= q_pos[:, None]) & (
            k_pos[None, :] < kv_offset + Sk
        )
        if window:
            mask &= q_pos[:, None] - k_pos[None, :] < window
        logits = jnp.where(mask, logits, NEG_INF)
        m_new = jnp.maximum(m_run, jnp.max(logits, axis=-1))
        p = jnp.exp(logits - m_new[..., None])
        corr = jnp.exp(m_run - m_new)
        l_new = l_run * corr + jnp.sum(p, axis=-1)
        acc = acc * corr[..., None] + jnp.einsum(
            "bkrql,bkld->bkrqd", p, vj, preferred_element_type=jnp.float32
        )
        return (acc, m_new, l_new), None

    acc0 = jnp.zeros((B, Kh, rep, Sq, hd), jnp.float32)
    m0 = jnp.full((B, Kh, rep, Sq), NEG_INF, jnp.float32)
    l0 = jnp.zeros((B, Kh, rep, Sq), jnp.float32)
    (acc, _, l), _ = jax.lax.scan(body, (acc0, m0, l0), jnp.arange(nblk))
    out = acc / jnp.maximum(l[..., None], 1e-30)
    return out.reshape(B, H, Sq, hd).transpose(0, 2, 1, 3).astype(q.dtype)


def mha_decode_partial(
    q: jax.Array,
    k_cache: jax.Array,
    v_cache: jax.Array,
    kv_positions: jax.Array,
    q_position: jax.Array,
    *,
    window: int = 0,
):
    """Single-token attention over a (possibly sequence-sharded) KV cache.

    q: (B,H,hd); k_cache,v_cache: (B,L,Kh,hd); kv_positions: (B,L) absolute
    positions of cache slots (negative = empty); q_position: (B,) per-row
    decode positions (continuous batching serves rows at different depths).

    Returns (out_local, lse): out_local (B,H,hd) is the softmax output over
    *local* keys only; lse (B,H) the local logsumexp. Shards combine as
      out = sum_i softmax_i(lse) * out_local_i.
    """
    B, H, hd = q.shape
    L, Kh = k_cache.shape[1], k_cache.shape[2]
    rep = H // Kh
    scale = 1.0 / math.sqrt(hd)

    qt = (q * scale).reshape(B, Kh, rep, hd)
    kt = k_cache.transpose(0, 2, 1, 3)  # (B,Kh,L,hd)
    vt = v_cache.transpose(0, 2, 1, 3)

    logits = jnp.einsum("bkrd,bkld->bkrl", qt, kt, preferred_element_type=jnp.float32)
    mask = (kv_positions >= 0) & (kv_positions <= q_position[:, None])
    if window:
        mask &= q_position[:, None] - kv_positions < window
    logits = jnp.where(mask[:, None, None, :], logits, NEG_INF)
    m = jnp.max(logits, axis=-1)
    p = jnp.exp(logits - m[..., None])
    denom = jnp.sum(p, axis=-1)
    out = jnp.einsum("bkrl,bkld->bkrd", p, vt, preferred_element_type=jnp.float32)
    out = out / jnp.maximum(denom, 1e-30)[..., None]
    empty = denom <= 0.0
    lse = jnp.where(empty, NEG_INF, m + jnp.log(jnp.maximum(denom, 1e-30)))
    return (
        out.reshape(B, H, hd).astype(q.dtype),
        lse.reshape(B, H),
    )


def combine_partials(outs: jax.Array, lses: jax.Array) -> jax.Array:
    """Combine stacked shard partials. outs: (P,B,H,hd), lses: (P,B,H)."""
    w = jax.nn.softmax(lses, axis=0)
    return jnp.sum(outs * w[..., None], axis=0).astype(outs.dtype)


def mha_decode(q, k_cache, v_cache, kv_positions, q_position, *, window: int = 0):
    """Unsharded decode convenience wrapper."""
    out, _ = mha_decode_partial(
        q, k_cache, v_cache, kv_positions, q_position, window=window
    )
    return out


def attention_flops(seq_q: int, seq_k: int, heads: int, head_dim: int, causal: bool) -> int:
    """Analytic attention FLOPs (for roofline): 2 matmuls, causal halves."""
    f = 2 * 2 * heads * head_dim * seq_q * seq_k
    return f // 2 if causal else f
