"""AdamW with global-norm clipping, pytree-native (no optax dependency).

Moments inherit each param's sharding (elementwise ops preserve layout),
so with ZeRO-sharded weights the optimizer state is ZeRO-sharded for free.
"""
from __future__ import annotations

from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

PyTree = Any


class AdamWState(NamedTuple):
    step: jax.Array
    m: PyTree
    v: PyTree


def adamw_init(params: PyTree, moment_dtype=jnp.float32) -> AdamWState:
    """moment_dtype=bfloat16 halves optimizer residency (used for the
    single-pod grok/llama4 train fits — EXPERIMENTS.md §Roofline)."""
    zeros = lambda p: jnp.zeros(p.shape, moment_dtype)
    return AdamWState(
        step=jnp.int32(0),
        m=jax.tree.map(zeros, params),
        v=jax.tree.map(zeros, params),
    )


def global_norm(tree: PyTree) -> jax.Array:
    return jnp.sqrt(
        sum(jnp.sum(jnp.square(x.astype(jnp.float32))) for x in jax.tree.leaves(tree))
    )


def adamw_update(
    grads: PyTree,
    state: AdamWState,
    params: PyTree,
    *,
    lr: float | jax.Array = 3e-4,
    b1: float = 0.9,
    b2: float = 0.95,
    eps: float = 1e-8,
    weight_decay: float = 0.1,
    clip_norm: float = 1.0,
) -> tuple[PyTree, AdamWState]:
    """Returns (new_params, new_state)."""
    if clip_norm:
        gn = global_norm(grads)
        scale = jnp.minimum(1.0, clip_norm / jnp.maximum(gn, 1e-9))
        grads = jax.tree.map(lambda g: g * scale, grads)
    step = state.step + 1
    b1c = 1.0 - b1 ** step.astype(jnp.float32)
    b2c = 1.0 - b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g32 = g.astype(jnp.float32)
        mdt = m.dtype
        m32 = m.astype(jnp.float32)
        v32 = v.astype(jnp.float32)
        m32 = b1 * m32 + (1 - b1) * g32
        v32 = b2 * v32 + (1 - b2) * jnp.square(g32)
        mh = m32 / b1c
        vh = v32 / b2c
        delta = mh / (jnp.sqrt(vh) + eps) + weight_decay * p.astype(jnp.float32)
        new_p = (p.astype(jnp.float32) - lr * delta).astype(p.dtype)
        return new_p, m32.astype(mdt), v32.astype(mdt)

    flat = jax.tree.map(upd, grads, state.m, state.v, params)
    new_params = jax.tree.map(lambda t: t[0], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_m = jax.tree.map(lambda t: t[1], flat, is_leaf=lambda x: isinstance(x, tuple))
    new_v = jax.tree.map(lambda t: t[2], flat, is_leaf=lambda x: isinstance(x, tuple))
    return new_params, AdamWState(step=step, m=new_m, v=new_v)
