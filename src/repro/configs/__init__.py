"""Architecture + input-shape registry.

Every assigned architecture is a module in this package exposing ``CONFIG``;
``get_arch(name)`` resolves ``--arch <id>`` CLI ids. ``SHAPES`` carries the
four assigned input shapes.
"""
from repro.configs.base import (
    ArchConfig,
    AttentionKind,
    BlockKind,
    InputShape,
    MoEConfig,
    SHAPES,
    reduced_variant,
)

from repro.configs import (
    recurrentgemma_2b,
    gemma3_27b,
    grok_1_314b,
    yi_9b,
    deepseek_67b,
    musicgen_medium,
    xlstm_350m,
    glm4_9b,
    llama4_maverick_400b_a17b,
    chameleon_34b,
    deepseek_r1,
)

ARCHS: dict[str, ArchConfig] = {
    m.CONFIG.name: m.CONFIG
    for m in (
        recurrentgemma_2b,
        gemma3_27b,
        grok_1_314b,
        yi_9b,
        deepseek_67b,
        musicgen_medium,
        xlstm_350m,
        glm4_9b,
        llama4_maverick_400b_a17b,
        chameleon_34b,
        deepseek_r1,
    )
}

ASSIGNED_ARCHS = [n for n in ARCHS if n != "deepseek-r1"]


def get_arch(name: str) -> ArchConfig:
    try:
        return ARCHS[name]
    except KeyError:
        raise KeyError(
            f"unknown arch {name!r}; available: {sorted(ARCHS)}"
        ) from None


def get_shape(name: str) -> InputShape:
    try:
        return SHAPES[name]
    except KeyError:
        raise KeyError(
            f"unknown shape {name!r}; available: {sorted(SHAPES)}"
        ) from None


__all__ = [
    "ARCHS",
    "ASSIGNED_ARCHS",
    "ArchConfig",
    "AttentionKind",
    "BlockKind",
    "InputShape",
    "MoEConfig",
    "SHAPES",
    "get_arch",
    "get_shape",
    "reduced_variant",
]
