"""RecurrentGemma-2B — RG-LRU hybrid, 1 local-attn : 2 recurrent [arXiv:2402.19427]."""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    num_layers=26,
    d_model=2560,
    num_heads=10,
    num_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    # Griffin pattern: (recurrent, recurrent, local attention) repeated.
    block_pattern=(BlockKind.RECURRENT, BlockKind.RECURRENT, BlockKind.LOCAL_ATTN),
    window=2048,
    tie_embeddings=True,
    logit_softcap=30.0,
    citation="arXiv:2402.19427 (RecurrentGemma / Griffin)",
)
