"""Grok-1 314B — MoE 8 experts top-2 [hf:xai-org/grok-1]."""
from repro.configs.base import ArchConfig, BlockKind, MoEConfig

CONFIG = ArchConfig(
    name="grok-1-314b",
    family="moe",
    num_layers=64,
    d_model=6144,
    num_heads=48,
    num_kv_heads=8,
    head_dim=128,
    d_ff=32768,
    vocab_size=131_072,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    moe=MoEConfig(num_experts=8, top_k=2, d_ff=32768, every=1),
    citation="hf:xai-org/grok-1 model card",
)
