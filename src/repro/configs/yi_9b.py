"""Yi-9B — dense llama-arch GQA [arXiv:2403.04652]."""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="yi-9b",
    family="dense",
    num_layers=48,
    d_model=4096,
    num_heads=32,
    num_kv_heads=4,
    head_dim=128,
    d_ff=11008,
    vocab_size=64_000,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    rope_theta=10_000.0,
    citation="arXiv:2403.04652 (Yi)",
)
