"""MusicGen-medium — decoder-only over EnCodec tokens [arXiv:2306.05284].

The EnCodec conv codec frontend is a STUB per the brief: ``input_specs``
supplies precomputed frame embeddings; this config is the transformer
backbone that consumes them.
"""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="musicgen-medium",
    family="audio",
    num_layers=48,
    d_model=1536,
    num_heads=24,
    num_kv_heads=24,
    head_dim=64,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    modality="audio",
    citation="arXiv:2306.05284 (MusicGen)",
)
