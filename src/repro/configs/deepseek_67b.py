"""DeepSeek-67B — dense llama-arch, 95 layers [arXiv:2401.02954]."""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="deepseek-67b",
    family="dense",
    num_layers=95,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=102_400,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    citation="arXiv:2401.02954 (DeepSeek LLM)",
)
