"""xLSTM-350M — sLSTM + mLSTM blocks, attention-free, d_ff=0 [arXiv:2405.04517]."""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="xlstm-350m",
    family="ssm",
    num_layers=24,
    d_model=1024,
    num_heads=4,
    num_kv_heads=4,
    head_dim=256,
    d_ff=0,  # xLSTM blocks carry their own up/down projections; no separate FFN
    vocab_size=50_304,
    block_pattern=(BlockKind.MLSTM, BlockKind.MLSTM, BlockKind.MLSTM, BlockKind.SLSTM),
    citation="arXiv:2405.04517 (xLSTM)",
)
