"""DeepSeek-R1 — the paper's evaluation model [arXiv:2501.12948 / 2412.19437].

Not an assigned architecture: included as the benchmark reference config so
the paper's tables (1, 3, 4) can be reproduced against the model they used.
MLA is approximated as GQA(kv=8) with the same KV-cache byte footprint
(see DESIGN.md §7) since MLA's low-rank projections are orthogonal to the
DWDP mechanism under study.
"""
from repro.configs.base import ArchConfig, BlockKind, MoEConfig

CONFIG = ArchConfig(
    name="deepseek-r1",
    family="moe",
    num_layers=61,
    d_model=7168,
    num_heads=128,
    num_kv_heads=8,
    head_dim=128,
    d_ff=18432,
    vocab_size=129_280,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    moe=MoEConfig(
        num_experts=256,
        top_k=8,
        d_ff=2048,
        every=1,
        shared_d_ff=2048,
        first_dense=3,
    ),
    citation="arXiv:2412.19437 (DeepSeek-V3/R1)",
)
