"""Gemma-3-27B — dense, 5 sliding : 1 global, 128K context [hf:google/gemma-3-1b-pt family]."""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="gemma3-27b",
    family="dense",
    num_layers=62,
    d_model=5376,
    num_heads=32,
    num_kv_heads=16,
    head_dim=128,
    d_ff=21504,
    vocab_size=262_144,
    # Gemma-3: five sliding-window layers per global layer.
    block_pattern=(
        BlockKind.LOCAL_ATTN,
        BlockKind.LOCAL_ATTN,
        BlockKind.LOCAL_ATTN,
        BlockKind.LOCAL_ATTN,
        BlockKind.LOCAL_ATTN,
        BlockKind.GLOBAL_ATTN,
    ),
    window=1024,
    rope_theta=1_000_000.0,
    tie_embeddings=True,
    citation="hf:google/gemma-3-1b-pt model card (scaled to 27B table entry)",
)
