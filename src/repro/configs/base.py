"""Config dataclasses shared by every architecture.

``ArchConfig`` is a frozen, hashable description of a decoder-only stack —
enough to build parameters, the forward step, and the sharding plan without
any further per-arch code. Heterogeneous stacks (sliding/global mixes,
RG-LRU hybrids, xLSTM) are expressed as a ``block_pattern`` cycle tiled over
``num_layers``.
"""
from __future__ import annotations

import dataclasses
import enum
import math
from typing import Optional


class BlockKind(str, enum.Enum):
    """Kind of the token-mixing sub-block of one layer."""

    GLOBAL_ATTN = "global_attn"    # full causal attention
    LOCAL_ATTN = "local_attn"      # sliding-window causal attention
    RECURRENT = "recurrent"        # RG-LRU linear recurrence (RecurrentGemma)
    MLSTM = "mlstm"                # matrix-memory LSTM (xLSTM)
    SLSTM = "slstm"                # scalar-memory LSTM (xLSTM)


class AttentionKind(str, enum.Enum):
    FULL = "full"
    SLIDING = "sliding"


@dataclasses.dataclass(frozen=True)
class MoEConfig:
    """Mixture-of-experts settings for the FFN sub-block."""

    num_experts: int
    top_k: int
    d_ff: int                      # per-expert hidden dim
    every: int = 1                 # MoE on layers where (layer % every == every-1)
    dense_d_ff: int = 0            # FFN dim of the non-MoE interleaved layers
    shared_d_ff: int = 0           # always-on shared expert (DeepSeek-style)
    first_dense: int = 0           # leading layers that stay dense (DeepSeek-style)

    def is_moe_layer(self, layer: int) -> bool:
        if layer < self.first_dense:
            return False
        return (layer - self.first_dense) % self.every == self.every - 1


@dataclasses.dataclass(frozen=True)
class ArchConfig:
    """Full description of a decoder-only architecture."""

    name: str
    family: str                    # dense | moe | hybrid | ssm | audio | vlm
    num_layers: int
    d_model: int
    num_heads: int
    num_kv_heads: int
    d_ff: int                      # dense FFN hidden dim (0 for pure-SSM)
    vocab_size: int
    head_dim: int = 0              # 0 → d_model // num_heads
    block_pattern: tuple[BlockKind, ...] = (BlockKind.GLOBAL_ATTN,)
    window: int = 4096             # sliding window for LOCAL_ATTN blocks
    moe: Optional[MoEConfig] = None
    rope_theta: float = 10_000.0
    norm_eps: float = 1e-6
    modality: str = "text"         # text | audio | vlm — non-text stubs frontend
    tie_embeddings: bool = False
    logit_softcap: float = 0.0
    citation: str = ""
    # Sub-quadratic fallback used only for the long_500k decode shape on archs
    # whose pattern is otherwise pure full attention (recorded as a variant).
    long_context_window: int = 8192

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.num_heads)
        assert self.num_heads % self.num_kv_heads == 0, self.name

    # ---- derived ---------------------------------------------------------
    def block_kind(self, layer: int) -> BlockKind:
        return self.block_pattern[layer % len(self.block_pattern)]

    def is_moe_layer(self, layer: int) -> bool:
        return self.moe is not None and self.moe.is_moe_layer(layer)

    def ffn_dim(self, layer: int) -> int:
        """Hidden dim of the dense FFN on this layer (0 if MoE or absent)."""
        if self.is_moe_layer(layer):
            return 0
        if self.moe is not None and self.moe.dense_d_ff:
            return self.moe.dense_d_ff
        return self.d_ff

    @property
    def q_dim(self) -> int:
        return self.num_heads * self.head_dim

    @property
    def kv_dim(self) -> int:
        return self.num_kv_heads * self.head_dim

    @property
    def has_attention(self) -> bool:
        return any(
            k in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN)
            for k in self.block_pattern
        )

    @property
    def is_subquadratic(self) -> bool:
        """True if no block needs O(S^2) state — long_500k runs natively."""
        return BlockKind.GLOBAL_ATTN not in self.block_pattern

    def param_count(self) -> int:
        """Exact parameter count of the decoder stack + embeddings."""
        n = self.vocab_size * self.d_model  # embed
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model  # lm head
        for layer in range(self.num_layers):
            n += self._mixer_params(layer) + self._ffn_params(layer)
            n += 2 * self.d_model  # two RMSNorm gains
        n += self.d_model  # final norm
        return n

    def active_param_count(self) -> int:
        """Params touched per token (MoE: top_k + shared experts only)."""
        n = self.vocab_size * self.d_model
        if not self.tie_embeddings:
            n += self.vocab_size * self.d_model
        for layer in range(self.num_layers):
            n += self._mixer_params(layer) + 2 * self.d_model
            if self.is_moe_layer(layer):
                assert self.moe is not None
                per = 3 * self.d_model * self.moe.d_ff
                n += self.moe.top_k * per
                n += self.d_model * self.moe.num_experts  # router
                if self.moe.shared_d_ff:
                    n += 3 * self.d_model * self.moe.shared_d_ff
            else:
                n += self._ffn_params(layer)
        n += self.d_model
        return n

    def _mixer_params(self, layer: int) -> int:
        kind = self.block_kind(layer)
        d = self.d_model
        if kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN):
            return d * self.q_dim + 2 * d * self.kv_dim + self.q_dim * d
        if kind == BlockKind.RECURRENT:
            # RG-LRU block: in/out linear (d->d each), conv1d(4), gates 2*d*d
            return 2 * d * d + 4 * d + 2 * d * d + 2 * d
        # m/sLSTM: qkv + i/f/o gates + out proj, all d x d scale
        return 4 * d * d + 3 * d * d + d * d

    def _ffn_params(self, layer: int) -> int:
        if self.is_moe_layer(layer):
            assert self.moe is not None
            per = 3 * self.d_model * self.moe.d_ff  # gate/up/down
            n = self.moe.num_experts * per + self.d_model * self.moe.num_experts
            if self.moe.shared_d_ff:
                n += 3 * self.d_model * self.moe.shared_d_ff
            return n
        dff = self.ffn_dim(layer)
        return 3 * self.d_model * dff if dff else 0


@dataclasses.dataclass(frozen=True)
class InputShape:
    """One assigned (seq_len, global_batch) workload."""

    name: str
    seq_len: int
    global_batch: int
    phase: str  # "train" | "prefill" | "decode"

    @property
    def tokens(self) -> int:
        return self.seq_len * self.global_batch


SHAPES: dict[str, InputShape] = {
    s.name: s
    for s in (
        InputShape("train_4k", 4_096, 256, "train"),
        InputShape("prefill_32k", 32_768, 32, "prefill"),
        InputShape("decode_32k", 32_768, 128, "decode"),
        InputShape("long_500k", 524_288, 1, "decode"),
    )
}


def reduced_variant(cfg: ArchConfig) -> ArchConfig:
    """2-layer, d_model<=512, <=4-expert smoke variant of the same family.

    Keeps one instance of the first and last block kind in the pattern so the
    smoke test exercises every code path the full model uses.
    """
    pattern = (cfg.block_pattern[0], cfg.block_pattern[-1])
    if pattern[0] == pattern[1]:
        pattern = pattern[:1]
    heads = 4
    kv = max(1, heads * cfg.num_kv_heads // cfg.num_heads)
    moe = None
    if cfg.moe is not None:
        e = min(4, cfg.moe.num_experts)
        moe = MoEConfig(
            num_experts=e,
            top_k=min(cfg.moe.top_k, e),
            d_ff=256,
            every=min(cfg.moe.every, 2),
            dense_d_ff=256 if cfg.moe.dense_d_ff else 0,
            shared_d_ff=128 if cfg.moe.shared_d_ff else 0,
            first_dense=min(cfg.moe.first_dense, 1),
        )
    return dataclasses.replace(
        cfg,
        name=cfg.name + "-smoke",
        num_layers=2,
        d_model=256,
        num_heads=heads,
        num_kv_heads=kv,
        head_dim=64,
        d_ff=256 if cfg.d_ff else 0,
        vocab_size=512,
        block_pattern=pattern,
        window=64,
        moe=moe,
        long_context_window=64,
    )
