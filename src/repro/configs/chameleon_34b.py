"""Chameleon-34B — early-fusion VLM over VQ image tokens [arXiv:2405.09818].

The VQ-VAE image tokenizer / vision frontend is a STUB per the brief:
``input_specs`` supplies precomputed patch-token embeddings; this config is
the early-fusion decoder backbone.
"""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="chameleon-34b",
    family="vlm",
    num_layers=48,
    d_model=8192,
    num_heads=64,
    num_kv_heads=8,
    head_dim=128,
    d_ff=22016,
    vocab_size=65_536,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    modality="vlm",
    citation="arXiv:2405.09818 (Chameleon)",
)
