"""GLM-4-9B — dense, RoPE, GQA kv=2 [hf:THUDM/glm-4-9b]."""
from repro.configs.base import ArchConfig, BlockKind

CONFIG = ArchConfig(
    name="glm4-9b",
    family="dense",
    num_layers=40,
    d_model=4096,
    num_heads=32,
    num_kv_heads=2,
    head_dim=128,
    d_ff=13696,
    vocab_size=151_552,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    citation="hf:THUDM/glm-4-9b model card",
)
