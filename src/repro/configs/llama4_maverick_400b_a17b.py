"""Llama-4 Maverick 400B-A17B — MoE 128 experts top-1, alternating dense/MoE
layers, shared expert, early fusion [hf:meta-llama/Llama-4-Scout-17B-16E family].
"""
from repro.configs.base import ArchConfig, BlockKind, MoEConfig

CONFIG = ArchConfig(
    name="llama4-maverick-400b-a17b",
    family="moe",
    num_layers=48,
    d_model=5120,
    num_heads=40,
    num_kv_heads=8,
    head_dim=128,
    d_ff=8192,
    vocab_size=202_048,
    block_pattern=(BlockKind.GLOBAL_ATTN,),
    # Maverick interleaves MoE every other layer; dense layers use a wider FFN.
    moe=MoEConfig(
        num_experts=128,
        top_k=1,
        d_ff=8192,
        every=2,
        dense_d_ff=16384,
        shared_d_ff=8192,
    ),
    rope_theta=500_000.0,
    citation="hf:meta-llama/Llama-4-Scout-17B-16E model card (Maverick table entry)",
)
