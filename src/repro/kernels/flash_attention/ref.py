"""Pure-jnp oracle for blockwise causal/sliding GQA attention."""
from __future__ import annotations

import math

import jax.numpy as jnp


def flash_attention_ref(
    q: jnp.ndarray,  # (B, Sq, H, hd)
    k: jnp.ndarray,  # (B, Sk, K, hd)
    v: jnp.ndarray,
    *,
    window: int = 0,
    q_offset: int = 0,
) -> jnp.ndarray:
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    rep = h // kh
    scale = 1.0 / math.sqrt(hd)
    kx = jnp.repeat(k, rep, axis=2)
    vx = jnp.repeat(v, rep, axis=2)
    logits = jnp.einsum(
        "bqhd,bkhd->bhqk", q * scale, kx, preferred_element_type=jnp.float32
    )
    qpos = q_offset + jnp.arange(sq)[:, None]
    kpos = jnp.arange(sk)[None, :]
    mask = kpos <= qpos
    if window:
        mask &= qpos - kpos < window
    logits = jnp.where(mask[None, None], logits, -1e30)
    p = jnp.exp(logits - logits.max(axis=-1, keepdims=True))
    p = p / jnp.maximum(p.sum(axis=-1, keepdims=True), 1e-30)
    out = jnp.einsum("bhqk,bkhd->bqhd", p, vx)
    return out.astype(q.dtype)
