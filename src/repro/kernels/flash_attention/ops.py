"""jit'd public wrapper for the flash attention kernel."""
from __future__ import annotations

from repro.kernels import resolve_interpret
from repro.kernels.flash_attention.flash_attention import flash_attention as _fa
from repro.kernels.flash_attention.ref import flash_attention_ref


def flash_attention(q, k, v, **kw):
    kw.setdefault("interpret", resolve_interpret())
    return _fa(q, k, v, **kw)


__all__ = ["flash_attention", "flash_attention_ref"]
