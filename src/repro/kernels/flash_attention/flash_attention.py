"""Blockwise causal/sliding-window GQA flash attention (Pallas TPU).

Grid: (B*Kh, rep, Sq/bq, Sk/bk) — the KV-block loop is the innermost grid
dimension so the online-softmax state (m, l, acc) carries across it in
VMEM scratch. Block sizes are MXU-aligned (multiples of 128 on the lane
dim). The causal + sliding-window mask is applied per tile from absolute
positions, so the same kernel serves the full-attention archs and the
local-attention layers of gemma3 / recurrentgemma.

This is the context-phase compute window that hides DWDP's weight
prefetch — on real hardware it and the grouped GEMM dominate the layer
time (paper Table 1: Attention + GroupedGEMM ~= 56% of DWDP4 iteration).
"""
from __future__ import annotations

import functools
import math

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

NEG_INF = -1e30


def _kernel(scale, window, q_offset, sk_valid, q_ref, k_ref, v_ref, o_ref,
             m_ref, l_ref, acc_ref):
    qi = pl.program_id(2)
    kj = pl.program_id(3)
    bq = q_ref.shape[2]
    bk = k_ref.shape[1]

    @pl.when(kj == 0)
    def _init():
        m_ref[...] = jnp.full_like(m_ref, NEG_INF)
        l_ref[...] = jnp.zeros_like(l_ref)
        acc_ref[...] = jnp.zeros_like(acc_ref)

    q = q_ref[0, 0] * scale                   # (bq, hd)
    k = k_ref[0]                              # (bk, hd)
    v = v_ref[0]
    logits = jnp.dot(q, k.T, preferred_element_type=jnp.float32)  # (bq, bk)

    q_pos = q_offset + qi * bq + jax.lax.broadcasted_iota(
        jnp.int32, (bq, bk), 0
    )
    k_pos = kj * bk + jax.lax.broadcasted_iota(jnp.int32, (bq, bk), 1)
    mask = (k_pos <= q_pos) & (k_pos < sk_valid)
    if window:
        mask &= q_pos - k_pos < window
    logits = jnp.where(mask, logits, NEG_INF)

    m_prev = m_ref[...]
    m_new = jnp.maximum(m_prev, logits.max(axis=-1))
    p = jnp.exp(logits - m_new[:, None])
    corr = jnp.exp(m_prev - m_new)
    l_ref[...] = l_ref[...] * corr + p.sum(axis=-1)
    acc_ref[...] = acc_ref[...] * corr[:, None] + jnp.dot(
        p.astype(v.dtype), v, preferred_element_type=jnp.float32
    )
    m_ref[...] = m_new

    @pl.when(kj == pl.num_programs(3) - 1)
    def _flush():
        l = jnp.maximum(l_ref[...], 1e-30)
        o_ref[0, 0] = (acc_ref[...] / l[:, None]).astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("window", "q_offset", "block_q", "block_k", "interpret"),
)
def flash_attention(
    q: jax.Array,  # (B, Sq, H, hd)
    k: jax.Array,  # (B, Sk, Kh, hd)
    v: jax.Array,
    *,
    window: int = 0,
    q_offset: int = 0,
    block_q: int = 128,
    block_k: int = 128,
    interpret: bool = True,
) -> jax.Array:
    b, sq, h, hd = q.shape
    sk, kh = k.shape[1], k.shape[2]
    rep = h // kh
    scale = 1.0 / math.sqrt(hd)

    bq = min(block_q, sq)
    bk = min(block_k, sk)
    sk_pad = -(-sk // bk) * bk
    if sq % bq:
        raise ValueError(f"Sq={sq} must divide block_q={bq}")
    if sk_pad != sk:
        pad = sk_pad - sk
        k = jnp.pad(k, ((0, 0), (0, pad), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad), (0, 0), (0, 0)))

    # (B, S, H, hd) -> (B*Kh, rep, S, hd) so GQA groups share a KV block
    qx = (
        q.reshape(b, sq, kh, rep, hd)
        .transpose(0, 2, 3, 1, 4)
        .reshape(b * kh, rep, sq, hd)
    )
    kx = k.transpose(0, 2, 1, 3).reshape(b * kh, sk_pad, hd)
    vx = v.transpose(0, 2, 1, 3).reshape(b * kh, sk_pad, hd)

    grid = (b * kh, rep, sq // bq, sk_pad // bk)

    out = pl.pallas_call(
        functools.partial(_kernel, scale, window, q_offset, sk),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, bq, hd), lambda g, r, qi, kj: (g, r, qi, 0)),
            pl.BlockSpec((1, bk, hd), lambda g, r, qi, kj: (g, kj, 0)),
            pl.BlockSpec((1, bk, hd), lambda g, r, qi, kj: (g, kj, 0)),
        ],
        out_specs=pl.BlockSpec(
            (1, 1, bq, hd), lambda g, r, qi, kj: (g, r, qi, 0)
        ),
        out_shape=jax.ShapeDtypeStruct((b * kh, rep, sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq,), jnp.float32),
            pltpu.VMEM((bq, hd), jnp.float32),
        ],
        interpret=interpret,
    )(qx, kx, vx)

    return (
        out.reshape(b, kh, rep, sq, hd)
        .transpose(0, 3, 1, 2, 4)
        .reshape(b, sq, h, hd)
    )
