"""Pallas TPU kernels for the DWDP hot spots.

- ``split_gemm``: §4.2 split-weight grouped GEMM — consumes the resident
  local expert bank and the freshly-landed remote bank as *separate* HBM
  buffers, selecting per expert inside the kernel (no merge copy).
- ``flash_attention``: blockwise causal/sliding-window GQA attention for
  the context phase (the compute window that hides DWDP prefetch).

Each kernel ships ``ops.py`` (jit'd wrapper, interpret-mode on CPU) and
``ref.py`` (pure-jnp oracle used by the allclose test sweeps).
"""
