"""Pallas TPU kernels for the DWDP hot spots.

- ``split_gemm``: §4.2 split-weight grouped GEMM — consumes the resident
  local expert bank and the freshly-landed remote bank as *separate* HBM
  buffers, selecting per expert inside the kernel (no merge copy).
- ``flash_attention``: blockwise causal/sliding-window GQA attention for
  the context phase (the compute window that hides DWDP prefetch).

Each kernel ships ``ops.py`` (jit'd wrapper; ``interpret`` defaults from
the backend — interpret mode off TPU, Mosaic on TPU) and ``ref.py``
(pure-jnp oracle used by the allclose test sweeps).

Split-weight fast path (§4.2, end to end)
-----------------------------------------

A family's ``GatherPolicy.layout = "split"`` (the engine default; the
flat ``weight_layout=`` / PR 1 ``moe_ffn=`` spellings survive as
deprecated uniform-table aliases) makes the
``(local_bank, remote_bank)`` SplitBank the canonical gathered-weight
representation for EVERY DWDP-prefetched family: MoE expert banks route
through the fused ``split_grouped_swiglu`` kernel, attention QKV/O and
dense-FFN projections through the ``split_gemm.dense`` family
(``split_stack_gemm`` / ``split_reduce_gemm`` / ``split_dense_swiglu``):

- **Remote-only gather contract**: ``prefetch.gather_remote_shards``
  returns the ``(local_bank, remote_bank)`` pair for all three prefetch
  modes (allgather / ring / ring_sliced). The resident shard never enters
  the wire buffer; the remote bank arrives in *rotated canonical order*
  (the caller's own experts lead, then subgroup neighbors p+1, p+2, ...),
  so the engine only rolls its dispatch indices — integer arithmetic, no
  data movement — to line tokens up with the banks.
- **Fused kernel**: gate/up/down stream both banks via predicated
  BlockSpecs (index maps clamp, ``pl.when`` on the expert coordinate
  selects), silu·mul fuses on the fp32 VMEM accumulators between stages,
  and the (E, C, F) hidden activation never round-trips HBM. Block sizes
  auto-select per dimension, so non-128-multiple (even sub-8 decode)
  capacities stream.
- **Memory**: the prefetched window shrinks from the full canonical
  ``num_padded`` bank to the ``(G'-1)/G'`` remote fraction, and the
  merged buffer's landing write is eliminated — accounted per family in
  ``core.roofline.layer_times(weight_layout=...)`` and
  ``analysis.roofline_report``; asserted structurally in
  ``tests/test_multidevice.py`` (no full-bank / full-stack tensor shape
  of ANY gathered family in the split lowering).
- **Down-proj blocking**: ``split_grouped_swiglu(block_o=...)`` blocks
  the down projection's output dim so d_model beyond the VMEM
  accumulator budget lowers (auto-selected; gate/up recompute only when
  blocking engages).
- **Training**: the ``impl="jnp"`` formulations are differentiable and
  merge-free (per-bank compute, activations combined) — grads flow
  through the remote-only gather for the ZeRO-style train shapes;
  ``pallas_call`` itself has no VJP.
- **Order fix-ups are index-only**: MoE rolls dispatch indices,
  attention rolls projected activations back to canonical head order,
  the dense FFN needs nothing (slice sum commutes) — weights are never
  reordered or copied.
"""
from __future__ import annotations

import jax


def resolve_interpret(interpret: bool | None = None) -> bool:
    """The one backend-derived interpret policy for every kernel family:
    compile to Mosaic on a real TPU, interpret everywhere else. ``None``
    means "decide from the backend"; an explicit bool wins."""
    if interpret is None:
        return jax.default_backend() != "tpu"
    return bool(interpret)
