from repro.kernels.split_gemm.ops import (
    split_gemm,
    split_grouped_gemm,
    split_grouped_gemm_ref,
    split_grouped_swiglu,
    split_grouped_swiglu_ref,
    split_swiglu,
    split_swiglu_jnp,
)

__all__ = [
    "split_gemm",
    "split_grouped_gemm",
    "split_grouped_gemm_ref",
    "split_grouped_swiglu",
    "split_grouped_swiglu_ref",
    "split_swiglu",
    "split_swiglu_jnp",
]
