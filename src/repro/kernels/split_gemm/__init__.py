from repro.kernels.split_gemm.ops import split_grouped_gemm
from repro.kernels.split_gemm.ref import split_grouped_gemm_ref

__all__ = ["split_grouped_gemm", "split_grouped_gemm_ref"]
