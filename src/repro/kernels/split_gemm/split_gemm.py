"""Split-weight grouped GEMM / grouped SwiGLU Pallas kernels (paper §4.2,
TPU adaptation).

The CUDA original extends a CuTeDSL grouped GEMM with TensorList inputs so
the kernel can read each expert's weights from either the resident-local
bank or the prefetched-remote bank. On TPU the analogous structure is two
HBM operands with *predicated BlockSpec streaming*: both banks are blocked
into VMEM tiles by the same grid, their index_maps clamp to a valid tile,
and the kernel body selects the correct tile with ``pl.when`` on the
expert coordinate — so only the selected bank's tile participates in the
MXU matmul and no merged contiguous buffer ever exists in HBM.

Three kernels:

- ``split_grouped_gemm``: one GEMM stage (kept as the minimal §4.2 unit).
- ``split_grouped_swiglu``: the full MoE FFN fused into one kernel —
  gate and up GEMMs stream both banks predicated, silu·mul runs on the
  fp32 VMEM accumulators between stages, and the down GEMM accumulates
  straight into a per-(expert, token-block) fp32 output accumulator. The
  intermediate (E, C, F) hidden activation never round-trips HBM.
- ``split_grouped_swiglu_demand``: the on-demand variant. The remote
  operand is the *compacted* demand-fetched bank — ``(budget, D, F)``
  rows of exactly the routing-activated experts, padded to the static
  budget — plus a per-row validity mask streamed through SMEM. Invalid
  (padding) rows hold clamped junk weights; the mask predicates every
  MXU stage for them, so their output blocks flush the zero-initialized
  accumulator and the padding costs no FLOPs.

Grid: (E, C/bc, F/bf, D/bd) for the single GEMM and
(E, C/bc, D/bo, F/bf, D/bd) for the fused SwiGLU, with fp32 VMEM
accumulator scratch; the reduction loop is the innermost grid dimension
so the accumulator carries across it (standard Pallas matmul pipelining).
The SwiGLU's D/bo coordinate blocks the down-projection *output* dim so
d_model beyond the VMEM accumulator budget lowers (bo = D, i.e. a single
output block, whenever it fits — gate/up recompute only kicks in when
blocking does). Block sizes are auto-selected per dimension (largest
lane-friendly divisor), so capacities that are not multiples of 128 —
e.g. decode-scale MoE capacities, which ``capacity_for`` only rounds to
8 — stream correctly; a dimension with no aligned divisor falls back to
a single block.

The dense (non-grouped) siblings — ``split_stack_gemm``,
``split_reduce_gemm``, ``split_dense_swiglu`` in ``dense.py`` — extend
the same predicated two-bank streaming to attention QKV/O and dense-FFN
projections for ``weight_layout="split"``.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret

_BLOCK_CANDIDATES = (1024, 512, 256, 128, 64, 32, 16, 8)


def _pick_block(n: int, preferred: int) -> int:
    """Largest candidate <= preferred that divides n (fallback: n itself,
    i.e. a single unblocked step). Keeps tiles lane-aligned when possible
    without asserting 128-divisibility on the caller."""
    if preferred >= n:
        return n
    if n % preferred == 0:
        return preferred
    for b in _BLOCK_CANDIDATES:
        if b <= preferred and n % b == 0:
            return b
    return n


def _cast(w, like):
    """fp8-stored bank tiles dequantize to the activation dtype on use."""
    return w.astype(like.dtype) if w.dtype != like.dtype else w


def _dummy_banks(e_l, e_r, w_local, w_remote, shape):
    """Empty banks (fully-local or fully-remote layers) still need a
    streamable dummy tile; the expert predicate keeps it out of the MXU."""
    if e_l == 0:
        w_local = jnp.zeros(shape, w_remote.dtype)
    if e_r == 0:
        w_remote = jnp.zeros(shape, w_local.dtype)
    return w_local, w_remote


# ==========================================================================
# Single predicated GEMM (the minimal §4.2 unit).
# ==========================================================================
def _gemm_kernel(n_local: int, x_ref, wl_ref, wr_ref, o_ref, acc_ref):
    e = pl.program_id(0)
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bc, bd)

    @pl.when(e < n_local)
    def _local():
        acc_ref[...] += jnp.dot(
            x, _cast(wl_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(e >= n_local)
    def _remote():
        acc_ref[...] += jnp.dot(
            x, _cast(wr_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(kd == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_f", "block_d", "interpret"),
)
def split_grouped_gemm(
    x: jax.Array,         # (E, C, D)
    w_local: jax.Array,   # (E_l, D, F)
    w_remote: jax.Array,  # (E - E_l, D, F)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    e, c, d = x.shape
    e_l, _, f = w_local.shape
    e_r = w_remote.shape[0]
    assert e_l + e_r == e, (e_l, e_r, e)
    w_local, w_remote = _dummy_banks(e_l, e_r, w_local, w_remote, (1, d, f))
    n_wl = w_local.shape[0]
    n_wr = w_remote.shape[0]

    bc = _pick_block(c, block_c)
    bf = _pick_block(f, block_f)
    bd = _pick_block(d, block_d)

    grid = (e, c // bc, f // bf, d // bd)

    def x_map(ei, ci, fi, di):
        return (ei, ci, di)

    def wl_map(ei, ci, fi, di):
        # clamp: when this expert is remote, stream tile 0 (discarded)
        return (jnp.clip(ei, 0, n_wl - 1), di, fi)

    def wr_map(ei, ci, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wr - 1), di, fi)

    def o_map(ei, ci, fi, di):
        return (ei, ci, fi)

    return pl.pallas_call(
        functools.partial(_gemm_kernel, e_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), x_map),
            pl.BlockSpec((1, bd, bf), wl_map),
            pl.BlockSpec((1, bd, bf), wr_map),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), o_map),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, w_local, w_remote)


# ==========================================================================
# Fused split grouped SwiGLU: gate/up/down over the two banks.
# ==========================================================================
def _swiglu_kernel(
    n_local: int,
    x_ref, gl_ref, ul_ref, dl_ref, gr_ref, ur_ref, dr_ref,
    o_ref,
    acc_g, acc_u, acc_y,
):
    e = pl.program_id(0)
    fi = pl.program_id(3)
    di = pl.program_id(4)
    last_f = fi == pl.num_programs(3) - 1
    last_d = di == pl.num_programs(4) - 1
    is_local = e < n_local

    @pl.when(jnp.logical_and(fi == 0, di == 0))
    def _init_y():
        acc_y[...] = jnp.zeros_like(acc_y)

    @pl.when(di == 0)
    def _init_gu():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[0]  # (bc, bd)

    @pl.when(is_local)
    def _first_local():
        acc_g[...] += jnp.dot(
            x, _cast(gl_ref[0], x), preferred_element_type=jnp.float32
        )
        acc_u[...] += jnp.dot(
            x, _cast(ul_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_not(is_local))
    def _first_remote():
        acc_g[...] += jnp.dot(
            x, _cast(gr_ref[0], x), preferred_element_type=jnp.float32
        )
        acc_u[...] += jnp.dot(
            x, _cast(ur_ref[0], x), preferred_element_type=jnp.float32
        )

    # gate/up tiles complete at the last D step: fuse silu·mul on the fp32
    # accumulators and push this F-tile through the matching down bank.
    @pl.when(jnp.logical_and(last_d, is_local))
    def _down_local():
        h = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(x.dtype)
        acc_y[...] += jnp.dot(
            h, _cast(dl_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_d, jnp.logical_not(is_local)))
    def _down_remote():
        h = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(x.dtype)
        acc_y[...] += jnp.dot(
            h, _cast(dr_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_f, last_d))
    def _flush():
        o_ref[0] = acc_y[...].astype(o_ref.dtype)


# fp32 scratch budget for the fused SwiGLU accumulators. When the
# unblocked (bc, D) down accumulator (+ gate/up tiles) would exceed it,
# the down projection's output dim is blocked automatically.
_ACC_BUDGET_BYTES = 8 * 1024 * 1024


def _auto_block_o(d: int, bc: int, bf: int) -> int:
    """Largest output block keeping the fp32 scratch (gate + up + y) and
    the streamed down tile inside ``_ACC_BUDGET_BYTES``."""
    fixed = 2 * bc * bf * 4                 # gate + up accumulators
    avail = max(_ACC_BUDGET_BYTES - fixed, 4 * (bc + bf) * 128)
    limit = max(avail // (4 * (bc + bf)), 128)  # y acc + down tile per col
    return _pick_block(d, int(limit))


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_f", "block_d", "block_o", "interpret"),
)
def split_grouped_swiglu(
    x: jax.Array,          # (E, C, D)
    wg_local: jax.Array,   # (E_l, D, F)
    wu_local: jax.Array,   # (E_l, D, F)
    wd_local: jax.Array,   # (E_l, F, D)
    wg_remote: jax.Array,  # (E - E_l, D, F)
    wu_remote: jax.Array,  # (E - E_l, D, F)
    wd_remote: jax.Array,  # (E - E_l, F, D)
    *,
    block_c: int = 128,
    block_f: int = 256,
    block_d: int = 512,
    block_o: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused per-expert SwiGLU over split weight banks: (E, C, D) -> (E, C, D).

    Experts [0, E_l) read the local bank, [E_l, E) the remote bank. The
    down-projection accumulates into a (bc, block_o) fp32 scratch.
    ``block_o`` blocks the down projection's *output* dim so d_model
    beyond the VMEM accumulator budget still lowers: with n_o = D/block_o
    output blocks the gate/up stages are recomputed once per block (the
    standard recompute-vs-residency trade), and ``block_o=None``
    auto-selects — the full D (today's single-pass schedule) whenever it
    fits ``_ACC_BUDGET_BYTES``, the largest fitting divisor otherwise.
    """
    e, c, d = x.shape
    e_l, _, f = wg_local.shape
    e_r = wg_remote.shape[0]
    assert e_l + e_r == e, (e_l, e_r, e)
    wg_local, wg_remote = _dummy_banks(e_l, e_r, wg_local, wg_remote, (1, d, f))
    wu_local, wu_remote = _dummy_banks(e_l, e_r, wu_local, wu_remote, (1, d, f))
    wd_local, wd_remote = _dummy_banks(e_l, e_r, wd_local, wd_remote, (1, f, d))
    n_wl = wg_local.shape[0]
    n_wr = wg_remote.shape[0]

    bc = _pick_block(c, block_c)
    bf = _pick_block(f, block_f)
    bd = _pick_block(d, block_d)
    bo = _auto_block_o(d, bc, bf) if block_o is None else _pick_block(d, block_o)

    grid = (e, c // bc, d // bo, f // bf, d // bd)

    def x_map(ei, ci, oi, fi, di):
        return (ei, ci, di)

    def up_l_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei, 0, n_wl - 1), di, fi)

    def up_r_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wr - 1), di, fi)

    def down_l_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei, 0, n_wl - 1), fi, oi)

    def down_r_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wr - 1), fi, oi)

    def o_map(ei, ci, oi, fi, di):
        return (ei, ci, oi)

    return pl.pallas_call(
        functools.partial(_swiglu_kernel, e_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), x_map),
            pl.BlockSpec((1, bd, bf), up_l_map),
            pl.BlockSpec((1, bd, bf), up_l_map),
            pl.BlockSpec((1, bf, bo), down_l_map),
            pl.BlockSpec((1, bd, bf), up_r_map),
            pl.BlockSpec((1, bd, bf), up_r_map),
            pl.BlockSpec((1, bf, bo), down_r_map),
        ],
        out_specs=pl.BlockSpec((1, bc, bo), o_map),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bo), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x, wg_local, wu_local, wd_local, wg_remote, wu_remote, wd_remote)


# ==========================================================================
# Demand-fetched split SwiGLU: compacted fetched bank, validity-predicated.
# ==========================================================================
def _swiglu_demand_kernel(
    n_local: int,
    x_ref, v_ref, gl_ref, ul_ref, dl_ref, gf_ref, uf_ref, df_ref,
    o_ref,
    acc_g, acc_u, acc_y,
):
    e = pl.program_id(0)
    fi = pl.program_id(3)
    di = pl.program_id(4)
    last_f = fi == pl.num_programs(3) - 1
    last_d = di == pl.num_programs(4) - 1
    is_local = e < n_local
    # fetched rows past the requester's valid count are clamped junk: the
    # mask keeps them off the MXU entirely, so the budget padding costs
    # no FLOPs and their output blocks flush the zeroed accumulator.
    is_fetched = jnp.logical_and(
        jnp.logical_not(is_local), v_ref[0, 0] != 0
    )

    @pl.when(jnp.logical_and(fi == 0, di == 0))
    def _init_y():
        acc_y[...] = jnp.zeros_like(acc_y)

    @pl.when(di == 0)
    def _init_gu():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[0]  # (bc, bd)

    @pl.when(is_local)
    def _first_local():
        acc_g[...] += jnp.dot(
            x, _cast(gl_ref[0], x), preferred_element_type=jnp.float32
        )
        acc_u[...] += jnp.dot(
            x, _cast(ul_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(is_fetched)
    def _first_fetched():
        acc_g[...] += jnp.dot(
            x, _cast(gf_ref[0], x), preferred_element_type=jnp.float32
        )
        acc_u[...] += jnp.dot(
            x, _cast(uf_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_d, is_local))
    def _down_local():
        h = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(x.dtype)
        acc_y[...] += jnp.dot(
            h, _cast(dl_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_d, is_fetched))
    def _down_fetched():
        h = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(x.dtype)
        acc_y[...] += jnp.dot(
            h, _cast(df_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_f, last_d))
    def _flush():
        o_ref[0] = acc_y[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_f", "block_d", "block_o", "interpret"),
)
def split_grouped_swiglu_demand(
    x: jax.Array,           # (E_l + E_f, C, D) compact dispatch batches
    wg_local: jax.Array,    # (E_l, D, F) resident bank
    wu_local: jax.Array,
    wd_local: jax.Array,    # (E_l, F, D)
    wg_fetched: jax.Array,  # (E_f, D, F) demand-fetched (budget-padded)
    wu_fetched: jax.Array,
    wd_fetched: jax.Array,  # (E_f, F, D)
    valid: jax.Array,       # (E_f,) bool/int — False rows are padding
    *,
    block_c: int = 128,
    block_f: int = 256,
    block_d: int = 512,
    block_o: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused SwiGLU over the (resident, demand-fetched) bank pair:
    (E_l + E_f, C, D) -> (E_l + E_f, C, D).

    Identical streaming structure to :func:`split_grouped_swiglu` —
    same grid, same accumulators, same auto block selection, so a
    routed expert's (C, D) block computes bit-identically to the
    all-fetch split path — plus the per-row validity scalar (SMEM)
    predicating every MXU stage of the fetched bank. No buffer wider
    than ``E_l + E_f`` experts exists anywhere."""
    e, c, d = x.shape
    e_l, _, f = wg_local.shape
    e_f = wg_fetched.shape[0]
    assert e_l + e_f == e, (e_l, e_f, e)
    assert valid.shape == (e_f,), (valid.shape, e_f)
    wg_local, wg_fetched = _dummy_banks(e_l, e_f, wg_local, wg_fetched, (1, d, f))
    wu_local, wu_fetched = _dummy_banks(e_l, e_f, wu_local, wu_fetched, (1, d, f))
    wd_local, wd_fetched = _dummy_banks(e_l, e_f, wd_local, wd_fetched, (1, f, d))
    n_wl = wg_local.shape[0]
    n_wf = wg_fetched.shape[0]
    v = valid.astype(jnp.int32).reshape(-1, 1)
    if e_f == 0:
        v = jnp.zeros((1, 1), jnp.int32)

    bc = _pick_block(c, block_c)
    bf = _pick_block(f, block_f)
    bd = _pick_block(d, block_d)
    bo = _auto_block_o(d, bc, bf) if block_o is None else _pick_block(d, block_o)

    grid = (e, c // bc, d // bo, f // bf, d // bd)

    def x_map(ei, ci, oi, fi, di):
        return (ei, ci, di)

    def v_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wf - 1), 0)

    def up_l_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei, 0, n_wl - 1), di, fi)

    def up_f_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wf - 1), di, fi)

    def down_l_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei, 0, n_wl - 1), fi, oi)

    def down_f_map(ei, ci, oi, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wf - 1), fi, oi)

    def o_map(ei, ci, oi, fi, di):
        return (ei, ci, oi)

    return pl.pallas_call(
        functools.partial(_swiglu_demand_kernel, e_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), x_map),
            pl.BlockSpec((1, 1), v_map, memory_space=pltpu.SMEM),
            pl.BlockSpec((1, bd, bf), up_l_map),
            pl.BlockSpec((1, bd, bf), up_l_map),
            pl.BlockSpec((1, bf, bo), down_l_map),
            pl.BlockSpec((1, bd, bf), up_f_map),
            pl.BlockSpec((1, bd, bf), up_f_map),
            pl.BlockSpec((1, bf, bo), down_f_map),
        ],
        out_specs=pl.BlockSpec((1, bc, bo), o_map),
        out_shape=jax.ShapeDtypeStruct((e, c, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bo), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x, v, wg_local, wu_local, wd_local, wg_fetched, wu_fetched, wd_fetched)
