"""Split-weight grouped GEMM Pallas kernel (paper §4.2, TPU adaptation).

The CUDA original extends a CuTeDSL grouped GEMM with TensorList inputs so
the kernel can read each expert's weights from either the resident-local
bank or the prefetched-remote bank. On TPU the analogous structure is two
HBM operands with *predicated BlockSpec streaming*: both banks are blocked
into VMEM tiles by the same grid, their index_maps clamp to a valid tile,
and the kernel body selects the correct tile with ``pl.when`` on the
expert coordinate — so only the selected bank's tile participates in the
MXU matmul and no merged contiguous buffer ever exists in HBM.

Grid: (E, C/bc, F/bf, D/bd) with an fp32 VMEM accumulator scratch;
the K (=D) loop is the innermost grid dimension so the accumulator
carries across it (standard Pallas matmul pipelining).
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu


def _kernel(n_local: int, x_ref, wl_ref, wr_ref, o_ref, acc_ref):
    e = pl.program_id(0)
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bc, bd)

    @pl.when(e < n_local)
    def _local():
        acc_ref[...] += jnp.dot(
            x, wl_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(e >= n_local)
    def _remote():
        acc_ref[...] += jnp.dot(
            x, wr_ref[0], preferred_element_type=jnp.float32
        )

    @pl.when(kd == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_f", "block_d", "interpret"),
)
def split_grouped_gemm(
    x: jax.Array,         # (E, C, D)
    w_local: jax.Array,   # (E_l, D, F)
    w_remote: jax.Array,  # (E - E_l, D, F)
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    interpret: bool = True,
) -> jax.Array:
    e, c, d = x.shape
    e_l, _, f = w_local.shape
    e_r = w_remote.shape[0]
    assert e_l + e_r == e, (e_l, e_r, e)
    # empty banks (fully-local or fully-remote layers) still need a
    # streamable dummy tile; the e<e_l predicate keeps it out of the MXU
    if e_l == 0:
        w_local = jnp.zeros((1, d, f), w_remote.dtype)
    if e_r == 0:
        w_remote = jnp.zeros((1, d, f), w_local.dtype)
    n_wl = w_local.shape[0]
    n_wr = w_remote.shape[0]

    bc = min(block_c, c)
    bf = min(block_f, f)
    bd = min(block_d, d)
    assert c % bc == 0 and f % bf == 0 and d % bd == 0, (c, f, d, bc, bf, bd)

    grid = (e, c // bc, f // bf, d // bd)

    def x_map(ei, ci, fi, di):
        return (ei, ci, di)

    def wl_map(ei, ci, fi, di):
        # clamp: when this expert is remote, stream tile 0 (discarded)
        return (jnp.clip(ei, 0, n_wl - 1), di, fi)

    def wr_map(ei, ci, fi, di):
        return (jnp.clip(ei - e_l, 0, n_wr - 1), di, fi)

    def o_map(ei, ci, fi, di):
        return (ei, ci, fi)

    return pl.pallas_call(
        functools.partial(_kernel, e_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bd), x_map),
            pl.BlockSpec((1, bd, bf), wl_map),
            pl.BlockSpec((1, bd, bf), wr_map),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), o_map),
        out_shape=jax.ShapeDtypeStruct((e, c, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=interpret,
    )(x, w_local, w_remote)
