"""Pure-jnp oracles for the split-weight kernels.

The references implement the *naive baseline* the paper's §4.2 removes:
merge local + remote banks into one contiguous buffer (the D2D copy),
then run the canonical grouped GEMM / grouped SwiGLU
(``repro.models.moe.grouped_ffn`` — the same routine the merged engine
path executes, so kernel tests compare against exactly what production
merged mode computes, fp8 dequant policy included).
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.split_gemm.split_gemm import _cast
from repro.models.moe import grouped_ffn


def merge_banks(w_local: jnp.ndarray, w_remote: jnp.ndarray) -> jnp.ndarray:
    """The D2D merge copy DWDP's kernel eliminates. w_local: (E_l, ...);
    w_remote: (E_r, ...) -> (E_l + E_r, ...)."""
    return jnp.concatenate([w_local, w_remote], axis=0)


def split_grouped_gemm_ref(
    x: jnp.ndarray,        # (E, C, D) per-expert token batches
    w_local: jnp.ndarray,  # (E_l, D, F) resident experts
    w_remote: jnp.ndarray,  # (E - E_l, D, F) prefetched experts
) -> jnp.ndarray:
    # fp8-stored weights dequantize on use (the one shared cast policy)
    w = _cast(merge_banks(w_local, w_remote), x)
    return jnp.einsum(
        "ecd,edf->ecf", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def split_stack_gemm_ref(
    x: jnp.ndarray,        # (T, D) shared activations
    w_local: jnp.ndarray,  # (S_l, D, Fs)
    w_remote: jnp.ndarray,  # (S - S_l, D, Fs)
) -> jnp.ndarray:
    """Merged-baseline column-split projection: concatenate the slice
    banks (the copy §4.2 eliminates), then one stacked einsum."""
    w = _cast(merge_banks(w_local, w_remote), x)
    return jnp.einsum(
        "td,sdf->stf", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def split_reduce_gemm_ref(
    x: jnp.ndarray,        # (S, T, Fs) per-slice activations
    w_local: jnp.ndarray,  # (S_l, Fs, D)
    w_remote: jnp.ndarray,  # (S - S_l, Fs, D)
) -> jnp.ndarray:
    """Merged-baseline row-split reduction: concatenate, then contract the
    slice axis in one einsum."""
    w = _cast(merge_banks(w_local, w_remote), x)
    return jnp.einsum(
        "stf,sfd->td", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)


def split_dense_swiglu_ref(
    x: jnp.ndarray,          # (T, D)
    wg_local: jnp.ndarray,   # (S_l, D, Fs)
    wu_local: jnp.ndarray,
    wd_local: jnp.ndarray,   # (S_l, Fs, D)
    wg_remote: jnp.ndarray,  # (S - S_l, D, Fs)
    wu_remote: jnp.ndarray,
    wd_remote: jnp.ndarray,  # (S - S_l, Fs, D)
) -> jnp.ndarray:
    """Merged-baseline stacked-slice dense SwiGLU — exactly the math the
    merged engine path (``execution._ffn_full``) runs on a gathered
    (S, D, F/S) buffer, fp8 dequant policy included."""
    wg = _cast(merge_banks(wg_local, wg_remote), x)
    wu = _cast(merge_banks(wu_local, wu_remote), x)
    wd = _cast(merge_banks(wd_local, wd_remote), x)
    h = jax.nn.silu(jnp.einsum("td,sdf->tsf", x, wg)) * jnp.einsum(
        "td,sdf->tsf", x, wu
    )
    return jnp.einsum("tsf,sfd->td", h, wd)


def split_grouped_swiglu_ref(
    x: jnp.ndarray,          # (E, C, D)
    wg_local: jnp.ndarray,   # (E_l, D, F)
    wu_local: jnp.ndarray,
    wd_local: jnp.ndarray,   # (E_l, F, D)
    wg_remote: jnp.ndarray,  # (E - E_l, D, F)
    wu_remote: jnp.ndarray,
    wd_remote: jnp.ndarray,  # (E - E_l, F, D)
) -> jnp.ndarray:
    """Merged-baseline SwiGLU: concatenate both banks (the copy §4.2
    eliminates), then run the canonical grouped FFN."""
    return grouped_ffn(
        x,
        merge_banks(wg_local, wg_remote),
        merge_banks(wu_local, wu_remote),
        merge_banks(wd_local, wd_remote),
    )


def split_grouped_swiglu_demand_ref(
    x: jnp.ndarray,           # (E_l + E_f, C, D)
    wg_local: jnp.ndarray,    # (E_l, D, F)
    wu_local: jnp.ndarray,
    wd_local: jnp.ndarray,    # (E_l, F, D)
    wg_fetched: jnp.ndarray,  # (E_f, D, F) demand-fetched, budget-padded
    wu_fetched: jnp.ndarray,
    wd_fetched: jnp.ndarray,  # (E_f, F, D)
    valid: jnp.ndarray,       # (E_f,)
) -> jnp.ndarray:
    """Oracle for the demand variant: merged grouped FFN over the compact
    (resident + fetched) bank, invalid (budget-padding) rows zeroed —
    their weights are clamped junk by contract, so the kernel flushes
    zeros for them."""
    e_l = wg_local.shape[0]
    y = split_grouped_swiglu_ref(
        x, wg_local, wu_local, wd_local, wg_fetched, wu_fetched, wd_fetched
    )
    mask = jnp.concatenate(
        [jnp.ones((e_l,), bool), valid.astype(bool)]
    )
    return y * mask[:, None, None].astype(y.dtype)
