"""Pure-jnp oracle for the split-weight grouped GEMM.

The reference implements the *naive baseline* the paper's §4.2 removes:
merge local + remote banks into one contiguous buffer (the D2D copy),
then run a standard grouped GEMM.
"""
from __future__ import annotations

import jax.numpy as jnp


def merge_banks(w_local: jnp.ndarray, w_remote: jnp.ndarray) -> jnp.ndarray:
    """The D2D merge copy DWDP's kernel eliminates. w_local: (E_l, D, F);
    w_remote: (E_r, D, F) -> (E_l + E_r, D, F)."""
    return jnp.concatenate([w_local, w_remote], axis=0)


def split_grouped_gemm_ref(
    x: jnp.ndarray,        # (E, C, D) per-expert token batches
    w_local: jnp.ndarray,  # (E_l, D, F) resident experts
    w_remote: jnp.ndarray,  # (E - E_l, D, F) prefetched experts
) -> jnp.ndarray:
    w = merge_banks(w_local, w_remote)
    return jnp.einsum(
        "ecd,edf->ecf", x, w, preferred_element_type=jnp.float32
    ).astype(x.dtype)
