"""Split-weight *dense* matmul Pallas kernels (paper §4.2 generalized).

PR 1 applied the split-bank technique to MoE expert banks only. These
kernels extend it to every stacked-storage dense weight family the DWDP
prefetch pipeline gathers — attention QKV/O projections and dense-FFN
("virtual expert") projections — so the engine's ``weight_layout="split"``
mode never materializes a merged ``(S, D, F/S)`` weight buffer for *any*
gathered family.

All three kernels consume the ``(resident shard, rotated remote bank)``
pair produced by ``prefetch.gather_split_bank``: slices ``[0, S_l)`` read
the local bank, ``[S_l, S)`` the remote bank, selected per grid step with
``pl.when`` on the slice coordinate over predicated (clamped) BlockSpecs —
the same two-operand streaming structure as ``split_grouped_gemm``, with
no merge copy anywhere.

- ``split_stack_gemm``: column-split projection. One shared activation
  ``x (T, D)`` against S stacked slices ``(S_*, D, Fs)`` -> ``(S, T, Fs)``
  (one output block per slice; the engine canonicalizes slice order with
  an activation-level roll — weights are never reordered).
- ``split_reduce_gemm``: row-split projection. Per-slice activations
  ``x (S, T, Fs)`` against ``(S_*, Fs, D)`` -> ``(T, D)`` accumulating the
  slice contributions in a fp32 VMEM tile (order-independent, so the
  rotated bank order needs no fix-up at all).
- ``split_dense_swiglu``: the fused dense FFN. Because SwiGLU slices are
  independent through the elementwise stage and summed by the down
  projection, the whole stacked FFN is ``y = sum_s swiglu_s(x)`` — gate
  and up stream both banks predicated, silu-mul runs on the fp32
  accumulators, and the down GEMM accumulates straight into a per-token-
  block output accumulator. Slice order cancels in the sum, so the dense
  split path needs no roll whatsoever.

Block sizes auto-select per dimension exactly like the grouped kernels
(largest lane-friendly divisor, single-block fallback), so decode-scale
token counts stream correctly. The fused SwiGLU also shares the grouped
kernel's down-projection output-dim blocking (``block_o``, auto-selected
against the 8 MiB fp32 VMEM accumulator budget), so d_model beyond the
single-pass accumulator envelope lowers on the dense path too.
"""
from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

from repro.kernels import resolve_interpret
from repro.kernels.split_gemm.split_gemm import (
    _auto_block_o,
    _cast,
    _dummy_banks,
    _pick_block,
)


# ==========================================================================
# Column-split stacked GEMM: shared x, one output block per slice.
# ==========================================================================
def _stack_kernel(n_local: int, x_ref, wl_ref, wr_ref, o_ref, acc_ref):
    s = pl.program_id(0)
    kd = pl.program_id(3)

    @pl.when(kd == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[...]  # (bc, bd)

    @pl.when(s < n_local)
    def _local():
        acc_ref[...] += jnp.dot(
            x, _cast(wl_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(s >= n_local)
    def _remote():
        acc_ref[...] += jnp.dot(
            x, _cast(wr_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(kd == pl.num_programs(3) - 1)
    def _flush():
        o_ref[0] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_f", "block_d", "interpret"),
)
def split_stack_gemm(
    x: jax.Array,         # (T, D) shared activations
    w_local: jax.Array,   # (S_l, D, Fs) resident slices
    w_remote: jax.Array,  # (S - S_l, D, Fs) rotated remote slices
    *,
    block_c: int = 128,
    block_f: int = 128,
    block_d: int = 512,
    interpret: bool | None = None,
) -> jax.Array:
    """Column-split stacked projection over split banks: (T, D) -> (S, T, Fs)."""
    t, d = x.shape
    s_l = w_local.shape[0]
    s_r = w_remote.shape[0]
    s = s_l + s_r
    f = (w_local if s_l else w_remote).shape[2]
    w_local, w_remote = _dummy_banks(s_l, s_r, w_local, w_remote, (1, d, f))
    n_wl = w_local.shape[0]
    n_wr = w_remote.shape[0]

    bc = _pick_block(t, block_c)
    bf = _pick_block(f, block_f)
    bd = _pick_block(d, block_d)

    grid = (s, t // bc, f // bf, d // bd)

    def x_map(si, ci, fi, di):
        return (ci, di)

    def wl_map(si, ci, fi, di):
        return (jnp.clip(si, 0, n_wl - 1), di, fi)

    def wr_map(si, ci, fi, di):
        return (jnp.clip(si - s_l, 0, n_wr - 1), di, fi)

    def o_map(si, ci, fi, di):
        return (si, ci, fi)

    return pl.pallas_call(
        functools.partial(_stack_kernel, s_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bd), x_map),
            pl.BlockSpec((1, bd, bf), wl_map),
            pl.BlockSpec((1, bd, bf), wr_map),
        ],
        out_specs=pl.BlockSpec((1, bc, bf), o_map),
        out_shape=jax.ShapeDtypeStruct((s, t, f), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bf), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, w_local, w_remote)


# ==========================================================================
# Row-split reduce GEMM: per-slice x, contributions summed over slices.
# ==========================================================================
def _reduce_kernel(n_local: int, x_ref, wl_ref, wr_ref, o_ref, acc_ref):
    si = pl.program_id(2)
    ki = pl.program_id(3)

    @pl.when(jnp.logical_and(si == 0, ki == 0))
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)

    x = x_ref[0]  # (bc, bk)

    @pl.when(si < n_local)
    def _local():
        acc_ref[...] += jnp.dot(
            x, _cast(wl_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(si >= n_local)
    def _remote():
        acc_ref[...] += jnp.dot(
            x, _cast(wr_ref[0], x), preferred_element_type=jnp.float32
        )

    last = jnp.logical_and(
        si == pl.num_programs(2) - 1, ki == pl.num_programs(3) - 1
    )

    @pl.when(last)
    def _flush():
        o_ref[...] = acc_ref[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_o", "block_k", "interpret"),
)
def split_reduce_gemm(
    x: jax.Array,         # (S, T, Fs) per-slice activations
    w_local: jax.Array,   # (S_l, Fs, D)
    w_remote: jax.Array,  # (S - S_l, Fs, D)
    *,
    block_c: int = 128,
    block_o: int = 512,
    block_k: int = 256,
    interpret: bool | None = None,
) -> jax.Array:
    """Row-split reduction over split banks: sum_s x[s] @ w[s] -> (T, D).

    The slice sum is order-independent, so the rotated remote bank order
    never needs canonicalizing on this side."""
    s, t, f = x.shape
    s_l = w_local.shape[0]
    s_r = w_remote.shape[0]
    assert s_l + s_r == s, (s_l, s_r, s)
    d = (w_local if s_l else w_remote).shape[2]
    w_local, w_remote = _dummy_banks(s_l, s_r, w_local, w_remote, (1, f, d))
    n_wl = w_local.shape[0]
    n_wr = w_remote.shape[0]

    bc = _pick_block(t, block_c)
    bo = _pick_block(d, block_o)
    bk = _pick_block(f, block_k)

    grid = (t // bc, d // bo, s, f // bk)

    def x_map(ci, oi, si, ki):
        return (si, ci, ki)

    def wl_map(ci, oi, si, ki):
        return (jnp.clip(si, 0, n_wl - 1), ki, oi)

    def wr_map(ci, oi, si, ki):
        return (jnp.clip(si - s_l, 0, n_wr - 1), ki, oi)

    def o_map(ci, oi, si, ki):
        return (ci, oi)

    return pl.pallas_call(
        functools.partial(_reduce_kernel, s_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, bc, bk), x_map),
            pl.BlockSpec((1, bk, bo), wl_map),
            pl.BlockSpec((1, bk, bo), wr_map),
        ],
        out_specs=pl.BlockSpec((bc, bo), o_map),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[pltpu.VMEM((bc, bo), jnp.float32)],
        interpret=resolve_interpret(interpret),
    )(x, w_local, w_remote)


# ==========================================================================
# Fused dense split SwiGLU: y = sum_s swiglu_s(x), both banks predicated.
# ==========================================================================
def _dense_swiglu_kernel(
    n_local: int,
    x_ref, gl_ref, ul_ref, dl_ref, gr_ref, ur_ref, dr_ref,
    o_ref,
    acc_g, acc_u, acc_y,
):
    si = pl.program_id(2)
    fi = pl.program_id(3)
    di = pl.program_id(4)
    last_s = si == pl.num_programs(2) - 1
    last_f = fi == pl.num_programs(3) - 1
    last_d = di == pl.num_programs(4) - 1
    is_local = si < n_local

    @pl.when(jnp.logical_and(si == 0, jnp.logical_and(fi == 0, di == 0)))
    def _init_y():
        acc_y[...] = jnp.zeros_like(acc_y)

    @pl.when(di == 0)
    def _init_gu():
        acc_g[...] = jnp.zeros_like(acc_g)
        acc_u[...] = jnp.zeros_like(acc_u)

    x = x_ref[...]  # (bc, bd)

    @pl.when(is_local)
    def _first_local():
        acc_g[...] += jnp.dot(
            x, _cast(gl_ref[0], x), preferred_element_type=jnp.float32
        )
        acc_u[...] += jnp.dot(
            x, _cast(ul_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_not(is_local))
    def _first_remote():
        acc_g[...] += jnp.dot(
            x, _cast(gr_ref[0], x), preferred_element_type=jnp.float32
        )
        acc_u[...] += jnp.dot(
            x, _cast(ur_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_d, is_local))
    def _down_local():
        h = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(x.dtype)
        acc_y[...] += jnp.dot(
            h, _cast(dl_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_d, jnp.logical_not(is_local)))
    def _down_remote():
        h = (jax.nn.silu(acc_g[...]) * acc_u[...]).astype(x.dtype)
        acc_y[...] += jnp.dot(
            h, _cast(dr_ref[0], x), preferred_element_type=jnp.float32
        )

    @pl.when(jnp.logical_and(last_s, jnp.logical_and(last_f, last_d)))
    def _flush():
        o_ref[...] = acc_y[...].astype(o_ref.dtype)


@functools.partial(
    jax.jit,
    static_argnames=("block_c", "block_f", "block_d", "block_o", "interpret"),
)
def split_dense_swiglu(
    x: jax.Array,          # (T, D)
    wg_local: jax.Array,   # (S_l, D, Fs)
    wu_local: jax.Array,   # (S_l, D, Fs)
    wd_local: jax.Array,   # (S_l, Fs, D)
    wg_remote: jax.Array,  # (S - S_l, D, Fs)
    wu_remote: jax.Array,  # (S - S_l, D, Fs)
    wd_remote: jax.Array,  # (S - S_l, Fs, D)
    *,
    block_c: int = 128,
    block_f: int = 256,
    block_d: int = 512,
    block_o: int | None = None,
    interpret: bool | None = None,
) -> jax.Array:
    """Fused stacked-slice SwiGLU over split banks: (T, D) -> (T, D).

    Slices [0, S_l) read the local bank, [S_l, S) the remote bank; the
    (T, Fs) hidden activations never round-trip HBM and the slice sum
    makes bank order irrelevant. ``block_o`` blocks the down
    projection's *output* dim (ported from the grouped kernel) so
    d_model beyond the VMEM accumulator budget still lowers: with
    n_o = D/block_o output blocks the gate/up stages are recomputed once
    per block (the standard recompute-vs-residency trade), and
    ``block_o=None`` auto-selects — the full D (the previous single-pass
    (bc, D) schedule) whenever it fits the shared ``_ACC_BUDGET_BYTES``,
    the largest fitting divisor otherwise."""
    t, d = x.shape
    s_l = wg_local.shape[0]
    s_r = wg_remote.shape[0]
    s = s_l + s_r
    f = (wg_local if s_l else wg_remote).shape[2]
    wg_local, wg_remote = _dummy_banks(s_l, s_r, wg_local, wg_remote, (1, d, f))
    wu_local, wu_remote = _dummy_banks(s_l, s_r, wu_local, wu_remote, (1, d, f))
    wd_local, wd_remote = _dummy_banks(s_l, s_r, wd_local, wd_remote, (1, f, d))
    n_wl = wg_local.shape[0]
    n_wr = wg_remote.shape[0]

    bc = _pick_block(t, block_c)
    bf = _pick_block(f, block_f)
    bd = _pick_block(d, block_d)
    bo = _auto_block_o(d, bc, bf) if block_o is None else _pick_block(d, block_o)

    grid = (t // bc, d // bo, s, f // bf, d // bd)

    def x_map(ci, oi, si, fi, di):
        return (ci, di)

    def up_l_map(ci, oi, si, fi, di):
        return (jnp.clip(si, 0, n_wl - 1), di, fi)

    def up_r_map(ci, oi, si, fi, di):
        return (jnp.clip(si - s_l, 0, n_wr - 1), di, fi)

    def down_l_map(ci, oi, si, fi, di):
        return (jnp.clip(si, 0, n_wl - 1), fi, oi)

    def down_r_map(ci, oi, si, fi, di):
        return (jnp.clip(si - s_l, 0, n_wr - 1), fi, oi)

    def o_map(ci, oi, si, fi, di):
        return (ci, oi)

    return pl.pallas_call(
        functools.partial(_dense_swiglu_kernel, s_l),
        grid=grid,
        in_specs=[
            pl.BlockSpec((bc, bd), x_map),
            pl.BlockSpec((1, bd, bf), up_l_map),
            pl.BlockSpec((1, bd, bf), up_l_map),
            pl.BlockSpec((1, bf, bo), down_l_map),
            pl.BlockSpec((1, bd, bf), up_r_map),
            pl.BlockSpec((1, bd, bf), up_r_map),
            pl.BlockSpec((1, bf, bo), down_r_map),
        ],
        out_specs=pl.BlockSpec((bc, bo), o_map),
        out_shape=jax.ShapeDtypeStruct((t, d), x.dtype),
        scratch_shapes=[
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bf), jnp.float32),
            pltpu.VMEM((bc, bo), jnp.float32),
        ],
        interpret=resolve_interpret(interpret),
    )(x, wg_local, wu_local, wd_local, wg_remote, wu_remote, wd_remote)
