"""jit'd public wrappers for the split-weight grouped kernels.

On CPU (this container) the kernels execute in Pallas interpret mode; on
a real TPU backend they compile to Mosaic (``interpret`` defaults from
the backend — pass ``interpret=...`` explicitly to override).

``split_swiglu`` is the engine-facing op. ``impl`` selects:

- ``"pallas"`` — the fused §4.2 kernel (inference hot path).
- ``"jnp"``    — a differentiable formulation that computes each bank's
  expert slice separately and concatenates the *outputs* (activations,
  (E, C, D)) — never the weight banks. Grad-through-gather for the train
  shapes routes here, since ``pallas_call`` has no registered VJP.
- ``None``     — "pallas".

Both impls honor the same contract: experts [0, E_l) read the local bank,
[E_l, E) the remote bank; no merged (E, D, F) weight buffer is ever
materialized.
"""
from __future__ import annotations

import jax.numpy as jnp

from repro.kernels.split_gemm.split_gemm import (
    split_grouped_gemm,
    split_grouped_swiglu,
)
from repro.kernels.split_gemm.ref import (
    split_grouped_gemm_ref,
    split_grouped_swiglu_ref,
)
from repro.models.moe import grouped_ffn


def split_gemm(x, w_local, w_remote, **kw):
    """Grouped GEMM over split expert banks. x: (E, C, D);
    w_local: (E_l, D, F); w_remote: (E-E_l, D, F) -> (E, C, F)."""
    return split_grouped_gemm(x, w_local, w_remote, **kw)


def split_swiglu_jnp(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r):
    """Differentiable split SwiGLU without a bank merge: per-bank grouped
    FFN over the matching expert slice of ``x``, outputs concatenated.
    The concat is over (E, C, D) activations — a factor d_ff/d_model
    smaller than the weight merge the paper eliminates — and gradients
    flow to both banks (and through any gather that produced them)."""
    e_l = wg_l.shape[0]
    y_l = grouped_ffn(x[:e_l], wg_l, wu_l, wd_l)
    y_r = grouped_ffn(x[e_l:], wg_r, wu_r, wd_r)
    return jnp.concatenate([y_l, y_r], axis=0)


def split_swiglu(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r, *, impl=None, **kw):
    """Fused split grouped SwiGLU. x: (E, C, D); gate/up banks (E_*, D, F),
    down banks (E_*, F, D) -> (E, C, D). See module docstring for impl."""
    if impl in (None, "pallas"):
        return split_grouped_swiglu(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r, **kw)
    if impl == "jnp":
        return split_swiglu_jnp(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r)
    raise ValueError(f"unknown split_swiglu impl {impl!r}")


__all__ = [
    "split_gemm",
    "split_grouped_gemm",
    "split_grouped_gemm_ref",
    "split_swiglu",
    "split_swiglu_jnp",
    "split_grouped_swiglu",
    "split_grouped_swiglu_ref",
]
