"""jit'd public wrappers for the split-weight kernels.

On CPU (this container) the Pallas kernels execute in interpret mode; on
a real TPU backend they compile to Mosaic (``interpret`` defaults from
the backend — pass ``interpret=...`` explicitly to override).

Every engine-facing op takes ``impl``:

- ``"pallas"`` — the fused §4.2 kernels (the TPU inference hot path).
- ``"jnp"``    — a differentiable formulation that computes each bank's
  slice separately and combines the *outputs* (activations) — never the
  weight banks. Grad-through-gather for the train shapes routes here,
  since ``pallas_call`` has no registered VJP.
- ``None``     — "pallas" (the kernel itself; bare calls are kernel
  coverage). The ENGINE never passes None for the dense family — it
  resolves the impl through ``default_dense_impl(phase)`` below.

Both impls honor the same contract: slices/experts [0, n_local) read the
local bank, [n_local, n) the remote bank; no merged weight buffer is ever
materialized.

Impl policy
-----------
``split_swiglu`` (the MoE grouped op, a few layers per model) defaults to
pallas for inference everywhere — interpret mode on CPU doubles as
engine-level kernel coverage. The *dense* family (``split_stack_matmul``
/ ``split_reduce_matmul`` / ``split_dense_ffn``) sits on every attention
and dense-FFN projection of every layer, so ``default_dense_impl`` picks
pallas only on a real TPU and the (equally merge-free, numerically
matching) jnp formulation elsewhere — keeping the CPU test suite's
interpret-mode cost bounded while the kernels themselves stay covered by
the dedicated interpret-mode sweeps in tests/test_kernels.py.
"""
from __future__ import annotations

import jax
import jax.numpy as jnp

from repro.kernels.split_gemm.dense import (
    split_dense_swiglu,
    split_reduce_gemm,
    split_stack_gemm,
)
from repro.kernels.split_gemm.split_gemm import (
    _cast,
    split_grouped_gemm,
    split_grouped_swiglu,
    split_grouped_swiglu_demand,
)
from repro.kernels.split_gemm.ref import (
    split_dense_swiglu_ref,
    split_grouped_gemm_ref,
    split_grouped_swiglu_demand_ref,
    split_grouped_swiglu_ref,
    split_reduce_gemm_ref,
    split_stack_gemm_ref,
)
from repro.models.moe import grouped_ffn


def default_dense_impl(phase: str) -> str:
    """Engine policy for the dense/attention split ops (see module doc)."""
    if phase == "train":
        return "jnp"
    return "pallas" if jax.default_backend() == "tpu" else "jnp"


def split_gemm(x, w_local, w_remote, **kw):
    """Grouped GEMM over split expert banks. x: (E, C, D);
    w_local: (E_l, D, F); w_remote: (E-E_l, D, F) -> (E, C, F)."""
    return split_grouped_gemm(x, w_local, w_remote, **kw)


# --------------------------------------------------------------------------
# MoE grouped SwiGLU (PR 1).
# --------------------------------------------------------------------------
def split_swiglu_jnp(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r):
    """Differentiable split SwiGLU without a bank merge: per-bank grouped
    FFN over the matching expert slice of ``x``, outputs concatenated.
    The concat is over (E, C, D) activations — a factor d_ff/d_model
    smaller than the weight merge the paper eliminates — and gradients
    flow to both banks (and through any gather that produced them)."""
    e_l = wg_l.shape[0]
    y_l = grouped_ffn(x[:e_l], wg_l, wu_l, wd_l)
    y_r = grouped_ffn(x[e_l:], wg_r, wu_r, wd_r)
    return jnp.concatenate([y_l, y_r], axis=0)


def split_swiglu(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r, *, impl=None, **kw):
    """Fused split grouped SwiGLU. x: (E, C, D); gate/up banks (E_*, D, F),
    down banks (E_*, F, D) -> (E, C, D). See module docstring for impl."""
    if impl in (None, "pallas"):
        return split_grouped_swiglu(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r, **kw)
    if impl == "jnp":
        return split_swiglu_jnp(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r)
    raise ValueError(f"unknown split_swiglu impl {impl!r}")


def split_swiglu_demand_jnp(x, wg_l, wu_l, wd_l, wg_f, wu_f, wd_f, valid):
    """Differentiable demand SwiGLU without a bank merge: per-bank
    grouped FFN over the matching slice of the compact dispatch, fetched
    outputs zeroed where the budget padding's validity mask is False
    (their weights are clamped junk by contract). Gradients flow to both
    banks — and through the demand gather's take/ppermute — which is
    what lets the route-before-gather path ride the train shapes."""
    e_l = wg_l.shape[0]
    y_l = grouped_ffn(x[:e_l], wg_l, wu_l, wd_l)
    y_f = grouped_ffn(x[e_l:], wg_f, wu_f, wd_f)
    y_f = y_f * valid[:, None, None].astype(y_f.dtype)
    return jnp.concatenate([y_l, y_f], axis=0)


def split_swiglu_demand(
    x, wg_l, wu_l, wd_l, wg_f, wu_f, wd_f, valid, *, impl=None, **kw
):
    """Fused demand-fetched grouped SwiGLU. x: (E_l + E_f, C, D) compact
    dispatch; local banks (E_l, D, F)/(E_l, F, D); fetched banks
    (E_f, D, F)/(E_f, F, D) budget-padded with ``valid`` (E_f,) marking
    real rows -> (E_l + E_f, C, D)."""
    if impl in (None, "pallas"):
        return split_grouped_swiglu_demand(
            x, wg_l, wu_l, wd_l, wg_f, wu_f, wd_f, valid, **kw
        )
    if impl == "jnp":
        return split_swiglu_demand_jnp(
            x, wg_l, wu_l, wd_l, wg_f, wu_f, wd_f, valid
        )
    raise ValueError(f"unknown split_swiglu_demand impl {impl!r}")


# --------------------------------------------------------------------------
# Dense stacked-slice family (attention QKV/O, dense FFN).
# --------------------------------------------------------------------------
def split_stack_matmul_jnp(x, w_local, w_remote):
    """Column-split projection without a bank merge: per-bank stacked
    einsum, outputs concatenated over the (S, T, Fs) *activation* axis."""
    y_l = jnp.einsum("td,sdf->stf", x, _cast(w_local, x))
    y_r = jnp.einsum("td,sdf->stf", x, _cast(w_remote, x))
    return jnp.concatenate([y_l, y_r], axis=0)


def split_stack_matmul(x, w_local, w_remote, *, impl=None, **kw):
    """Shared-activation stacked projection over split banks.
    x: (T, D); banks (S_l, D, Fs)/(S-S_l, D, Fs) -> (S, T, Fs), slice
    order = bank order (local first, then rotated remote)."""
    if impl in (None, "pallas"):
        return split_stack_gemm(x, w_local, w_remote, **kw)
    if impl == "jnp":
        return split_stack_matmul_jnp(x, w_local, w_remote)
    raise ValueError(f"unknown split_stack_matmul impl {impl!r}")


def split_reduce_matmul_jnp(x, w_local, w_remote):
    """Row-split reduction without a bank merge: per-bank contraction of
    the matching slice range, partial sums added (order-independent)."""
    s_l = w_local.shape[0]
    y_l = jnp.einsum("stf,sfd->td", x[:s_l], _cast(w_local, x))
    y_r = jnp.einsum("stf,sfd->td", x[s_l:], _cast(w_remote, x))
    return y_l + y_r


def split_reduce_matmul(x, w_local, w_remote, *, impl=None, **kw):
    """Per-slice reduction over split banks. x: (S, T, Fs); banks
    (S_l, Fs, D)/(S-S_l, Fs, D) -> (T, D) = sum_s x[s] @ w[s]."""
    if impl in (None, "pallas"):
        return split_reduce_gemm(x, w_local, w_remote, **kw)
    if impl == "jnp":
        return split_reduce_matmul_jnp(x, w_local, w_remote)
    raise ValueError(f"unknown split_reduce_matmul impl {impl!r}")


def split_dense_ffn_jnp(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r):
    """Differentiable dense split SwiGLU without a bank merge: per-bank
    stacked SwiGLU (the same math ``execution._ffn_full`` runs), partial
    sums added. Slice order cancels in the sum, so the rotated remote
    bank never needs canonicalizing."""
    def part(wg, wu, wd):
        h = jax.nn.silu(
            jnp.einsum("td,sdf->tsf", x, _cast(wg, x))
        ) * jnp.einsum("td,sdf->tsf", x, _cast(wu, x))
        return jnp.einsum("tsf,sfd->td", h, _cast(wd, x))

    return part(wg_l, wu_l, wd_l) + part(wg_r, wu_r, wd_r)


def split_dense_ffn(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r, *, impl=None, **kw):
    """Fused dense-FFN SwiGLU over split banks. x: (T, D); gate/up banks
    (S_*, D, Fs), down banks (S_*, Fs, D) -> (T, D)."""
    if impl in (None, "pallas"):
        return split_dense_swiglu(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r, **kw)
    if impl == "jnp":
        return split_dense_ffn_jnp(x, wg_l, wu_l, wd_l, wg_r, wu_r, wd_r)
    raise ValueError(f"unknown split_dense_ffn impl {impl!r}")


__all__ = [
    "default_dense_impl",
    "split_gemm",
    "split_grouped_gemm",
    "split_grouped_gemm_ref",
    "split_swiglu",
    "split_swiglu_jnp",
    "split_swiglu_demand",
    "split_swiglu_demand_jnp",
    "split_grouped_swiglu",
    "split_grouped_swiglu_demand",
    "split_grouped_swiglu_demand_ref",
    "split_grouped_swiglu_ref",
    "split_stack_gemm",
    "split_stack_gemm_ref",
    "split_stack_matmul",
    "split_stack_matmul_jnp",
    "split_reduce_gemm",
    "split_reduce_gemm_ref",
    "split_reduce_matmul",
    "split_reduce_matmul_jnp",
    "split_dense_swiglu",
    "split_dense_swiglu_ref",
    "split_dense_ffn",
    "split_dense_ffn_jnp",
]
