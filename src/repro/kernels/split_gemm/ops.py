"""jit'd public wrapper for the split-weight grouped GEMM.

On CPU (this container) the kernel executes in Pallas interpret mode; on a
real TPU backend set ``interpret=False`` to compile the Mosaic kernel.
"""
from __future__ import annotations

import jax

from repro.kernels.split_gemm.split_gemm import split_grouped_gemm
from repro.kernels.split_gemm.ref import split_grouped_gemm_ref


def on_tpu() -> bool:
    return jax.default_backend() == "tpu"


def split_gemm(x, w_local, w_remote, **kw):
    """Grouped GEMM over split expert banks. x: (E, C, D);
    w_local: (E_l, D, F); w_remote: (E-E_l, D, F) -> (E, C, F)."""
    kw.setdefault("interpret", not on_tpu())
    return split_grouped_gemm(x, w_local, w_remote, **kw)


__all__ = ["split_gemm", "split_grouped_gemm", "split_grouped_gemm_ref"]
