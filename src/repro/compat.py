"""Version compatibility shims for the jax API surface this repo targets.

The codebase is written against the modern spellings (``jax.shard_map``
with ``check_vma``, ``jax.make_mesh(..., axis_types=...)``); older
jaxlibs (< 0.5) expose the same functionality as
``jax.experimental.shard_map.shard_map(..., check_rep=...)`` and a
``make_mesh`` without ``axis_types``. Route every use through here so the
rest of the tree stays on one spelling.
"""
from __future__ import annotations

import jax

if hasattr(jax, "shard_map"):
    _shard_map = jax.shard_map
    _CHECK_KW = "check_vma"
else:  # jax < 0.5
    from jax.experimental.shard_map import shard_map as _shard_map

    _CHECK_KW = "check_rep"


def shard_map(f, *, mesh, in_specs, out_specs, check_vma=False):
    """``jax.shard_map`` with the replication-check kwarg spelled per
    the installed jax version (``check_vma`` >= 0.5, ``check_rep`` before)."""
    return _shard_map(
        f,
        mesh=mesh,
        in_specs=in_specs,
        out_specs=out_specs,
        **{_CHECK_KW: check_vma},
    )


def make_mesh(shape, axes):
    """``jax.make_mesh`` with explicit Auto axis types where supported."""
    try:
        from jax.sharding import AxisType

        return jax.make_mesh(
            shape, axes, axis_types=(AxisType.Auto,) * len(axes)
        )
    except ImportError:
        return jax.make_mesh(shape, axes)
