"""Paper §3: layer-wise roofline model for DWDP vs DEP.

Reproduces Figure 3 (compute/prefetch ratio and DEP/DWDP speedup vs ISL)
with the paper's GB200 constants, and re-derives the same analysis for the
TPU v5e target so the dry-run §Roofline numbers have an analytic
counterpart.

Model (paper §3):
    T_op      = max(F / P_peak, B / BW_mem)            per operator
    T_compute = sum of attention + MoE operator times
    T_DWDP    = max(T_compute, T_prefetch)
    T_DEP     = T_compute + T_all2all
"""
from __future__ import annotations

import dataclasses
import math
from typing import Optional

from repro.configs.base import ArchConfig


@dataclasses.dataclass(frozen=True)
class Hardware:
    name: str
    flops: float        # peak FLOP/s (dense bf16/fp8 as configured)
    hbm_bw: float       # bytes/s
    link_bw: float      # bytes/s per-direction interconnect per chip
    hbm_bytes: float


# GB200 (paper): ~2.25 PFLOP/s dense FP8 per GPU in practice for these
# kernels (NVFP4 MoE weights), 8 TB/s HBM3e, ~900 GB/s/dir NVLink5.
GB200 = Hardware("GB200", flops=2.25e15, hbm_bw=8e12, link_bw=900e9,
                 hbm_bytes=186e9)
# TPU v5e (target): 197 TFLOP/s bf16, 819 GB/s HBM, ~50 GB/s/link ICI
# (we count ~4 usable links -> 200 GB/s aggregate per chip).
TPU_V5E = Hardware("TPUv5e", flops=197e12, hbm_bw=819e9, link_bw=200e9,
                   hbm_bytes=16e9)


def op_time(flops: float, bytes_: float, hw: Hardware) -> float:
    return max(flops / hw.flops, bytes_ / hw.hbm_bw)


def expected_distinct_experts(n_draws: int, num_experts: int) -> float:
    """E[distinct experts hit] for ``n_draws`` (= rows * top_k) uniform
    routing draws over ``num_experts``: ``E * (1 - (1 - 1/E)^n)`` — the
    closed form the on-demand fetch path's wire bytes follow. At decode
    scale this is far below ``min(n, E)`` (collisions dominate), which is
    exactly the headroom demand fetch converts into saved wire bytes."""
    e = float(num_experts)
    if e <= 0:
        return 0.0
    return e * (1.0 - (1.0 - 1.0 / e) ** n_draws)


def demand_budget_rows(n_draws: int, num_experts: int, local: int) -> int:
    """The auto-budget rule, in closed form: per-peer demand-fetch rows =
    2x the expected per-peer distinct-expert coverage
    (``local * (1 - (1 - 1/E)^n)``), rounded up to a lane-friendly
    multiple of 8, clamped to the per-rank expert count. ONE rule shared
    by the engine (``execution.resolve_demand_budget``), the roofline /
    simulator wire models and the micro-bench, so every accounting
    surface prices the same payload the lowered program actually ships
    (the budget-PADDED rows, not the raw expectation)."""
    if local <= 0:
        return 0
    e = max(1, num_experts)
    expected = local * (1.0 - (1.0 - 1.0 / e) ** n_draws)
    budget = -(-math.ceil(2.0 * expected) // 8) * 8
    return max(1, min(max(8, budget), local))


def predictive_budget_rows(
    n_draws: int, num_experts: int, local: int
) -> tuple[int, int]:
    """The predictive-fetch auto budgets, in closed form: per-peer
    ``(speculative, correction)`` rows. The speculative round sizes to
    1x the expected per-peer distinct-expert coverage (the hot set the
    predictor should cover), the correction round to half of it (the
    expected miss tail once the predictor + cache absorb the recurring
    set) — both 8-aligned and clamped to the per-rank expert count.
    Wherever the coverage expectation clears the 8-row floors (the
    acceptance decode shape included: 16+8 < 32 at R1's 8 rows/rank)
    their sum stays below :func:`demand_budget_rows`'s 2x-coverage
    demand budget, so the predictive path ships less payload than the
    demand round it replaces; at tiny coverage the floors make the two
    rounds up to 2x the demand budget — exactly the regime where the
    auto resolver scores predictive worse and keeps plain demand.
    Under-estimation is handled exactly by the per-layer overflow
    fallback (a cold predictor's first step may fall back — correctness
    never depends on the estimate)."""
    if local <= 0:
        return 0, 0
    e = max(1, num_experts)
    expected = local * (1.0 - (1.0 - 1.0 / e) ** n_draws)
    align = lambda v: -(-math.ceil(v) // 8) * 8
    spec = min(local, max(8, align(expected)))
    corr = min(local, max(8, align(expected / 2.0)))
    return spec, corr


def predictive_budget_rungs(
    n_draws: int,
    num_experts: int,
    local: int,
    factors: tuple = (0.5, 1.0, 1.5, 2.0),
) -> tuple:
    """The online speculative-budget LADDER: explicit per-peer row
    budgets at ``factors`` x the expected per-peer distinct-expert
    coverage, 8-aligned, clamped to the per-rank expert count and
    deduplicated (ascending). Each rung is a compile-stable
    ``GatherPolicy.budget`` value, so a serving engine can pre-compile
    one forward variant per rung off the serving path and snap the
    measured ``spec_hit``/``corr`` split to the nearest rung with ZERO
    recompiles (the zero-recompile online-resizing contract —
    docs/policy_switching.md). The 1.0x rung coincides with the
    speculative half of :func:`predictive_budget_rows` wherever the
    coverage expectation clears the 8-row floor. Budget changes never
    touch correctness: overflow beyond any rung rides the per-layer
    exact fallback."""
    if local <= 0:
        return (0,)
    e = max(1, num_experts)
    expected = local * (1.0 - (1.0 - 1.0 / e) ** n_draws)
    align = lambda v: -(-math.ceil(v) // 8) * 8
    rungs: list[int] = []
    for f in sorted(factors):
        spec = min(local, max(8, align(f * expected)))
        if spec not in rungs:
            rungs.append(spec)
    return tuple(rungs)


def predictive_fetch_terms(
    tokens: int,
    top_k: int,
    num_experts: int,
    group: int,
    bytes_per_expert: float,
    *,
    redundancy: int = 1,
    budget: int = 0,
    cache_rows: int = 0,
    cache_hit: Optional[float] = None,
    predict_hit: Optional[float] = None,
    validate: bool = False,
    sync_free: bool = False,
) -> tuple[float, float]:
    """Per-rank wire terms of the predictive expert fetch as
    ``(total_bytes, serial_bytes)``:

    - ``total``: speculative round + correction round, each a
      budget-padded payload plus its bitmap index round — what the
      lowered program ships, capped at the full remote gather.
    - ``serial``: the part on the decode critical path — the correction
      round only (the speculative round is issued a layer ahead and
      overlaps compute, the §4.3 prefetch-hiding the demand path lost).

    ``cache_hit`` scales both rounds (cache-resident experts need
    neither), ``predict_hit`` scales the correction round only (a
    predictor hit moves bytes from the serial round into the overlapped
    one). Defaults (None) derive conservative closed forms: cache hit =
    cached fraction of the remote bank under uniform routing; predictor
    hit = the per-expert re-activation probability ``1-(1-1/E)^n``
    (uniform-routing steady state — real routing has more temporal
    locality, so measured rates replayed through the simulator can only
    improve on this). ``validate`` prices the fault-tolerant fetch's
    per-row checksum table riding each index round (f32 per expert per
    peer — ``prefetch.demand_fetch_bytes``'s wire format).

    ``sync_free`` models the mirrored-predictor mode: the speculative
    round is PURE payload — both endpoints derive the schedule from
    mirrored PredictState, so its bitmap index round disappears from
    the wire entirely. The correction round keeps only the residual
    (miss) bitmap as index metadata — the senders compact the payload
    against it — plus the checksum table when validated; the
    routing/position mirror payload ships ONCE per step, not per layer
    (``prefetch.sync_free_mirror_bytes``), priced as a per-step term by
    :func:`modeled_step_time`.
    """
    sub = max(1, group // redundancy)
    if sub <= 1:
        return 0.0, 0.0
    local = -(-num_experts // sub)
    full = (sub - 1) * local * bytes_per_expert
    if budget > 0:
        spec = corr = min(budget, local)
    else:
        spec, corr = predictive_budget_rows(tokens * top_k, num_experts, local)
    if cache_hit is None:
        # cached fraction of the REMOTE bank ((G'-1) * local rows) —
        # the rows a cache hit can actually save wire on
        remote_rows = (sub - 1) * local
        cache_hit = (
            min(1.0, cache_rows / max(1, remote_rows)) if cache_rows else 0.0
        )
    if predict_hit is None:
        predict_hit = 1.0 - (1.0 - 1.0 / max(1, num_experts)) ** (
            tokens * top_k
        )
    index_round = (sub - 1) * num_experts * (5 if validate else 1)
    spec_index = 0.0 if sync_free else index_round
    spec_b = ((sub - 1) * spec * bytes_per_expert + spec_index) * (
        1.0 - cache_hit
    )
    corr_b = ((sub - 1) * corr * bytes_per_expert + index_round) * (
        1.0 - cache_hit
    ) * (1.0 - predict_hit)
    total = min(full, spec_b + corr_b)
    return total, min(total, corr_b)


def demand_prefetch_bytes(
    tokens: int,
    top_k: int,
    num_experts: int,
    group: int,
    bytes_per_expert: float,
    *,
    redundancy: int = 1,
    budget: int = 0,
    validate: bool = False,
) -> float:
    """Per-rank wire bytes of the on-demand expert fetch: the
    budget-padded payload round — ``(G'-1) * budget`` expert rows, with
    the per-peer ``budget`` following the engine's auto rule
    (:func:`demand_budget_rows`) unless given — plus the (tiny)
    index-exchange round, one bitmap byte per expert per peer. This is
    what the lowered program ships (padding included), so it matches
    ``analytic_hbm_bytes`` and the engine's serving counters. Never
    exceeds the full remote gather (at full budget the two coincide up
    to the index round, which is then dropped by the cap). ``validate``
    adds the fault-tolerant fetch's f32 per-row checksum table to the
    index round (4 more bytes per expert per peer)."""
    sub = max(1, group // redundancy)
    if sub <= 1:
        return 0.0
    local = -(-num_experts // sub)
    full = (sub - 1) * local * bytes_per_expert
    if budget <= 0:
        budget = demand_budget_rows(tokens * top_k, num_experts, local)
    budget = min(budget, local)
    # 1-byte bitmap per peer (+ f32 checksums when validating)
    index_round = (sub - 1) * num_experts * (5 if validate else 1)
    return min(full, (sub - 1) * budget * bytes_per_expert + index_round)


@dataclasses.dataclass(frozen=True)
class LayerTimes:
    compute: float
    prefetch: float
    all2all: float
    land_bytes: float = 0.0   # HBM write of the gathered bank landing:
                              # full layer set (merged) vs remote-only
                              # (split) — the §4.2 merge-copy delta.
    land_time: float = 0.0    # the same, as HBM time. Reported separately
                              # and NOT folded into `compute`: only the
                              # DWDP path lands gathered weights, so
                              # folding it in would inflate t_dep (which
                              # reuses `compute`) and shift the paper's
                              # §3 model; consumers that want the landing
                              # cost add it to the DWDP side explicitly.
    serial_fetch: float = 0.0  # the part of `prefetch` that sits ON the
                               # critical path instead of overlapping
                               # compute. 0 for the all-fetch prefetch
                               # (fully layer-ahead double-buffered); the
                               # WHOLE round for fetch="demand" (the
                               # route-before-gather inversion makes the
                               # exchange+payload wait on routing); the
                               # correction round only for
                               # fetch="predictive" (the speculative
                               # round is issued a layer ahead again).

    @property
    def t_dwdp(self) -> float:
        return max(self.compute, self.prefetch)

    @property
    def t_dep(self) -> float:
        return self.compute + self.all2all

    @property
    def speedup(self) -> float:
        return self.t_dep / self.t_dwdp

    @property
    def compute_to_prefetch(self) -> float:
        return self.compute / max(self.prefetch, 1e-30)


def layer_times(
    cfg: ArchConfig,
    *,
    tokens: int,
    group: int,
    hw: Hardware = GB200,
    weight_bytes: int = 1,     # NVFP4 ~ 1 byte/param in the paper's setup
    act_bytes: int = 2,
    kv_len: Optional[int] = None,
    layer: int = 0,
    redundancy: int = 1,
    weight_layout: Optional[str] = None,
    attn_gathered: bool = False,
    expert_fetch: str = "all",
    moe_ffn: str = "merged",
    policies=None,
    cache_hit: Optional[float] = None,
    predict_hit: Optional[float] = None,
    validate: bool = False,
    layer_group: Optional[str] = None,
) -> LayerTimes:
    """Per-layer roofline terms for the context phase (batch of `tokens`).

    prefetch: each rank pulls the experts it does not hold: (G'-1)/G' of
    the layer's expert bytes over the peer link.
    all2all: DEP exchanges each token's hidden state twice (dispatch +
    combine) across the group: 2 * tokens * D * topk/… bytes (we follow
    the paper and count the full dispatched activation volume).
    weight_layout: gathered-weight landing traffic, reported via the
    ``land_bytes``/``land_time`` fields (DWDP-only cost — see LayerTimes).
    "merged" materializes the full contiguous layer bank (the §4.2 merge
    copy: every slice — resident included — is written once into the
    gather buffer); "split" lands only the (G'-1)/G' remote bank and the
    split kernels read the resident shard in place. Applies uniformly to
    the expert bank, the dense-FFN slices, and (when ``attn_gathered``)
    the attention projections — the layout is one engine-wide switch.
    ``moe_ffn`` is the deprecated PR 1 spelling of the same knob.
    attn_gathered: model DWDP-gathered attention weights (the escalated
    sharded-attention geometry) — adds the attention projections'
    (group-1)/group wire bytes to the prefetch term and their landing
    write per the layout.
    expert_fetch: "all" ships the full remote expert bank (the split /
    merged prefetch); "demand" models the route-before-gather path:
    the budget-PADDED demand payload (per-peer budget = the engine's
    shared auto rule ``demand_budget_rows``, 2x the expected-coverage
    closed form ``expected_distinct_experts``) + the index round cross
    the wire — exactly what the lowered program ships — engaged when
    coverage is partial (``tokens * top_k`` below the remote expert
    count) and never worse than "all". The landing write shrinks with
    it (demand is split-layout by construction). Demand's round waits
    on routing, so it is priced ON the critical path
    (``serial_fetch`` = the whole round); "predictive" splits the
    round into a layer-ahead speculative fetch (overlapped, like the
    all-fetch prefetch) plus a small serial correction round, with
    ``cache_hit`` / ``predict_hit`` replaying measured (or closed-form
    default) hit rates — see :func:`predictive_fetch_terms`. The
    ``moe_experts`` policy's ``cache_budget`` sizes the residency
    cache the hit rates derive from.
    policies: a ``strategy.PolicyTable`` — the per-family replacement for
    the flat knobs above. When given, each family prices its OWN layout
    (moe_experts / attn_qkv / attn_out / dense_ffn), the expert fetch
    mode and demand budget come from the ``moe_experts`` entry, and the
    flat ``weight_layout`` / ``expert_fetch`` / ``moe_ffn`` arguments
    are ignored. This is what lets the model score heterogeneous
    mixed-policy plans (the ``policy="auto"`` resolver's objective).
    ``layer_group`` scopes every family lookup to that execution-plan
    layer group (:func:`layer_group_names`), so per-layer-group
    PolicyTable overrides price exactly the policy the engine lowers
    for this layer.
    """
    budget = 0
    cache_rows = 0
    if policies is not None:
        moe_pol = policies.family("moe_experts", layer_group)
        moe_layout = moe_pol.layout
        expert_fetch = moe_pol.fetch
        budget = moe_pol.budget
        cache_rows = moe_pol.cache_budget
        dense_layout = policies.family("dense_ffn", layer_group).layout
        qkv_layout = policies.family("attn_qkv", layer_group).layout
        out_layout = policies.family("attn_out", layer_group).layout
    else:
        flat = weight_layout if weight_layout is not None else moe_ffn
        moe_layout = dense_layout = qkv_layout = out_layout = flat
    layout = moe_layout
    d = cfg.d_model
    kv_len = kv_len or tokens
    # --- attention ---------------------------------------------------------
    qkv_flops = 2 * tokens * d * (cfg.q_dim + 2 * cfg.kv_dim) + (
        2 * tokens * cfg.q_dim * d
    )
    attn_flops = 2 * 2 * cfg.num_heads * cfg.head_dim * tokens * kv_len // 2
    attn_w_bytes = (
        d * (cfg.q_dim + 2 * cfg.kv_dim) + cfg.q_dim * d
    ) * weight_bytes
    attn_act_bytes = 3 * tokens * d * act_bytes + 2 * tokens * (
        cfg.kv_dim
    ) * act_bytes
    t_attn = op_time(qkv_flops + attn_flops, attn_w_bytes + attn_act_bytes, hw)

    # --- FFN / MoE ----------------------------------------------------------
    if cfg.moe is not None and cfg.is_moe_layer(layer):
        moe = cfg.moe
        e, k, f = moe.num_experts, moe.top_k, moe.d_ff
        ffn_flops = 2 * 3 * tokens * k * d * f
        if moe.shared_d_ff:
            ffn_flops += 2 * 3 * tokens * d * moe.shared_d_ff
        # active expert weights read once each (upper bound: all experts)
        w_bytes = min(e, tokens * k) * 3 * d * f * weight_bytes
        sub = max(1, group // redundancy)
        layer_expert_bytes = e * 3 * d * f * weight_bytes
        prefetch_bytes = layer_expert_bytes * (sub - 1) / sub
        serial_bytes = 0.0
        partial = tokens * k < e * (sub - 1) / sub
        if expert_fetch == "demand" and layout == "split" and partial:
            # route-before-gather: expected-coverage wire bytes — the
            # WHOLE round waits on routing (on the critical path)
            prefetch_bytes = demand_prefetch_bytes(
                tokens, k, e, group, 3 * d * f * weight_bytes,
                redundancy=redundancy, budget=budget, validate=validate,
            )
            serial_bytes = prefetch_bytes
        elif (
            expert_fetch in ("predictive", "sync_free")
            and layout == "split" and partial
        ):
            # speculative round overlapped a layer ahead + serial
            # correction round covering only the (hit-rate-scaled)
            # misses; sync_free additionally drops the speculative
            # round's bitmap exchange (mirrored predictor)
            prefetch_bytes, serial_bytes = predictive_fetch_terms(
                tokens, k, e, group, 3 * d * f * weight_bytes,
                redundancy=redundancy, budget=budget,
                cache_rows=cache_rows, cache_hit=cache_hit,
                predict_hit=predict_hit, validate=validate,
                sync_free=expert_fetch == "sync_free",
            )
        # HBM landing write of the gathered bank: full layer (merged) vs
        # remote-only (split — the eliminated merge copy shows up here;
        # demand lands only what it fetched)
        land_bytes = 0.0
        if sub > 1:
            land_bytes = (
                layer_expert_bytes if layout == "merged" else prefetch_bytes
            )
        a2a_bytes = 2 * tokens * k * d * act_bytes * (sub - 1) / sub
    else:
        f = cfg.ffn_dim(layer) or cfg.d_ff
        ffn_flops = 2 * 3 * tokens * d * f
        w_bytes = 3 * d * f * weight_bytes
        layer_bytes = 3 * d * f * weight_bytes
        prefetch_bytes = layer_bytes * (group - 1) / group
        serial_bytes = 0.0
        # dense-FFN slices land like any other gathered family
        land_bytes = 0.0
        if group > 1:
            land_bytes = (
                layer_bytes if dense_layout == "merged" else prefetch_bytes
            )
        # dense DEP analogue: gather + reduce-scatter of activations
        a2a_bytes = 2 * tokens * d * act_bytes * (group - 1) / group
    t_ffn = op_time(ffn_flops, w_bytes + 2 * tokens * d * act_bytes, hw)

    # attention projections: replicated in the paper-faithful layout
    # (no traffic); when DWDP gathers them (escalated sharding), they pay
    # the same per-mode wire + landing accounting as every other family —
    # the qkv and out projections each under their OWN family's layout.
    if attn_gathered and group > 1:
        qkv_w = d * (cfg.q_dim + 2 * cfg.kv_dim) * weight_bytes
        out_w = cfg.q_dim * d * weight_bytes
        for w, fam_layout in ((qkv_w, qkv_layout), (out_w, out_layout)):
            fam_prefetch = w * (group - 1) / group
            prefetch_bytes += fam_prefetch
            land_bytes += w if fam_layout == "merged" else fam_prefetch

    compute = t_attn + t_ffn
    prefetch = prefetch_bytes / hw.link_bw
    all2all = a2a_bytes / hw.link_bw
    return LayerTimes(
        compute=compute,
        prefetch=prefetch,
        all2all=all2all,
        land_bytes=land_bytes,
        land_time=land_bytes / hw.hbm_bw,
        serial_fetch=serial_bytes / hw.link_bw,
    )


def layer_step_time(lt: LayerTimes) -> float:
    """One layer's modeled DWDP critical-path time under the serial/
    overlapped fetch split: ``max(compute + landing, overlapped
    prefetch) + serial fetch``. The ONE per-layer expression
    :func:`modeled_step_time` sums and the benches report — change it
    here and every consumer moves together."""
    return max(lt.compute + lt.land_time, lt.prefetch - lt.serial_fetch) + (
        lt.serial_fetch
    )


def layer_group_names(cfg: ArchConfig) -> list[str]:
    """Per-layer execution-plan layer-group name ("prefix" / "body" /
    "suffix" — ``models.transformer.make_layer_plan``'s grouping): the
    key space per-layer-group :class:`strategy.PolicyTable` overrides
    are scoped by, so the roofline prices a mixed table exactly as the
    engine lowers it. Lazy model import keeps roofline import-light."""
    from repro.models.transformer import make_layer_plan

    names = [""] * cfg.num_layers
    for g in make_layer_plan(cfg):
        span = g.n_cycles * len(g.sigs)
        for layer in range(g.first_layer, g.first_layer + span):
            names[layer] = g.name
    return names


def _rate_for(rate, group_name: Optional[str]):
    """A replayed hit rate: a scalar applies everywhere; a mapping keys
    by layer-group name (measured per-group rates — the online
    resolver's drift input)."""
    if rate is None or isinstance(rate, (int, float)):
        return rate
    return rate.get(group_name)


def modeled_step_time(
    cfg: ArchConfig,
    *,
    tokens: int,
    group: int,
    hw: Hardware = GB200,
    policies=None,
    weight_layout: Optional[str] = None,
    expert_fetch: str = "all",
    attn_gathered: bool = False,
    kv_len: Optional[int] = None,
    redundancy: int = 1,
    weight_bytes: int = 1,
    act_bytes: int = 2,
    cache_hit=None,
    predict_hit=None,
    validate: bool = False,
) -> float:
    """Modeled one-step wall time of a full DWDP forward under a policy
    table: per layer ``max(compute + landing, overlapped prefetch) +
    serial fetch`` (the §3 critical path — the gathered-bank landing
    write is HBM work only DWDP pays; a route-before-gather round that
    waits on routing cannot be hidden and is added serially, which is
    exactly the demand-path inversion the predictive fetch takes back
    off the critical path), summed over every layer. The
    ``policy="auto"`` resolver's objective and the surface the
    acceptance criterion compares uniform vs mixed tables on.

    Per-layer-group PolicyTable overrides are priced exactly: each
    layer resolves its policies under its own layer group
    (:func:`layer_group_names`), and ``cache_hit`` / ``predict_hit``
    accept a ``{group_name: rate}`` mapping to replay MEASURED
    per-group hit rates (the online resolver's drift input) alongside
    the scalar spelling. When any layer runs ``fetch="sync_free"`` the
    ONE per-step mirror-fold all-gather
    (``prefetch.sync_free_mirror_bytes`` — routing/position signals
    shipped once per step, not per layer) is added once."""
    groups = None
    if policies is not None and (
        getattr(policies, "overrides", ())
        or not isinstance(cache_hit, (int, float, type(None)))
        or not isinstance(predict_hit, (int, float, type(None)))
    ):
        groups = layer_group_names(cfg)
    total = 0.0
    sync_free_used = False
    for layer in range(cfg.num_layers):
        gname = groups[layer] if groups else None
        lt = layer_times(
            cfg, tokens=tokens, group=group, hw=hw, layer=layer,
            policies=policies, weight_layout=weight_layout,
            expert_fetch=expert_fetch, attn_gathered=attn_gathered,
            kv_len=kv_len, redundancy=redundancy,
            weight_bytes=weight_bytes, act_bytes=act_bytes,
            cache_hit=_rate_for(cache_hit, gname),
            predict_hit=_rate_for(predict_hit, gname),
            validate=validate, layer_group=gname,
        )
        total += layer_step_time(lt)
        if cfg.moe is not None and cfg.is_moe_layer(layer):
            fetch = (
                policies.family("moe_experts", gname).fetch
                if policies is not None else expert_fetch
            )
            sync_free_used = sync_free_used or fetch == "sync_free"
    sub = max(1, group // redundancy)
    if sync_free_used and cfg.moe is not None and sub > 1:
        moe = cfg.moe
        partial = tokens * moe.top_k < moe.num_experts * (sub - 1) / sub
        if partial:
            from repro.core import prefetch
            from repro.core.placement import make_placement

            pl = make_placement(moe.num_experts, sub)
            total += prefetch.sync_free_mirror_bytes(pl, tokens) / hw.link_bw
    return total


def reshard_plan_rows(num_experts: int, group: int, dead: int) -> dict:
    """Row accounting of the fail-stop re-shard ``G -> G-1`` on the
    canonical split-bank layout (``prefetch.merge_split_bank`` order:
    old owner of row ``r`` is ``r // ceil(E/G)``): per surviving new
    owner, how many of its new rows are already local, arrive from a
    surviving peer (point-to-point wire), or must come from the
    checkpoint/source copy because the dead rank held them — rows are
    NEVER recovered from the dead peer.

    Returns ``{"local", "wire", "source"}`` row counts as
    ``(group-1,)`` arrays indexed by new owner, plus ``"new_local"``
    (the shrunk layout's rows-per-rank)."""
    import numpy as np

    e, g = int(num_experts), int(group)
    if g < 2:
        raise ValueError(f"reshard needs group >= 2, got {g}")
    dead = int(dead) % g
    old_l = -(-e // g)
    new_l = -(-e // (g - 1))
    survivors = [r for r in range(g) if r != dead]
    local = np.zeros(g - 1, np.int64)
    wire = np.zeros(g - 1, np.int64)
    source = np.zeros(g - 1, np.int64)
    for s, old_rank in enumerate(survivors):
        for r in range(s * new_l, min((s + 1) * new_l, e)):
            owner = min(r // old_l, g - 1)
            if owner == dead:
                source[s] += 1
            elif owner == old_rank:
                local[s] += 1
            else:
                wire[s] += 1
    return {"local": local, "wire": wire, "source": source,
            "new_local": new_l}


def rank_death_recovery(
    cfg: ArchConfig,
    *,
    group: int,
    hw: Hardware = GB200,
    weight_bytes: int = 1,
) -> dict:
    """Price a gen-rank fail-stop recovery: the ``G -> G-1`` re-shard's
    wire bytes and the recovery stall the replica eats before its first
    post-recovery decode step.

    The expert banks re-shard per :func:`reshard_plan_rows`; surviving
    peers exchange their redistributed rows point-to-point in parallel
    (time = the max per-survivor incoming share), and the dead rank's
    rows are re-fetched from the checkpoint/source copy over the same
    fabric (never from the dead peer). Non-expert split families are
    negligible next to the expert banks at MoE scale and are not
    modeled. The stall adds one fixed plan-swap overhead (same constant
    as the simulator's per-step overhead); with the ``G'-1`` variant
    pre-warmed there is no compile term — that is the zero-recompile
    contract the serving tests assert."""
    g = int(group)
    out = {"wire_bytes": 0.0, "source_bytes": 0.0, "seconds": 2e-4,
           "per_survivor_wire_bytes": 0.0}
    if cfg.moe is None or g < 2:
        return out
    moe = cfg.moe
    per_expert = 3 * cfg.d_model * moe.d_ff * float(weight_bytes)
    n_moe = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
    plan = reshard_plan_rows(moe.num_experts, g, dead=g - 1)
    wire_rows = float(plan["wire"].sum())
    source_rows = float(plan["source"].sum())
    worst_in = float((plan["wire"] + plan["source"]).max())
    out["wire_bytes"] = n_moe * wire_rows * per_expert
    out["source_bytes"] = n_moe * source_rows * per_expert
    out["per_survivor_wire_bytes"] = n_moe * worst_in * per_expert
    out["seconds"] += out["per_survivor_wire_bytes"] / hw.link_bw
    return out


def degraded_step_times(
    cfg: ArchConfig,
    policies,
    *,
    tokens: int,
    group: int,
    hw: Hardware = GB200,
    validate: bool = True,
    excluded_peers: int = 1,
    **kw,
) -> list[dict]:
    """Price every level of the graceful-degradation ladder the
    HealthMonitor can walk (``strategy.degradation_ladder``): per level,
    the modeled step time under that level's policy table with payload
    validation priced in (the checksum table on each index round), plus
    the healthy (non-validated) baseline of the TOP level — so the
    engine / bench can report both the validation overhead and the cost
    of each demotion before any fault ever fires.

    ``excluded_peers`` sizes the ``+excl`` rung: the HealthMonitor now
    hands the exclusion rung a peer SET, so asymmetric badness (several
    hot peers at once) is priced by dropping that many peers' shares of
    the remote bank from the speculative schedule.

    The terminal ``"reshard"`` rung (fail-stop: a rank died) is priced
    at the SHRUNK group ``group - 1`` — the post-recovery steady state —
    and its row additionally carries the one-time re-shard cost
    (:func:`rank_death_recovery`): ``reshard_wire_mb`` and
    ``recovery_stall_us``."""
    from repro.core.strategy import degradation_ladder

    n_excl = max(1, min(int(excluded_peers), max(1, group - 1)))
    rows = []
    base = modeled_step_time(
        cfg, tokens=tokens, group=group, hw=hw, policies=policies,
        validate=False, **kw,
    )
    for level, (label, table, excl) in enumerate(
        degradation_ladder(policies)
    ):
        sub_kw = dict(kw)
        if label == "reshard":
            shrunk = max(1, group - 1)
            t = modeled_step_time(
                cfg, tokens=tokens, group=shrunk, hw=hw, policies=table,
                validate=validate, **sub_kw,
            )
            rec = rank_death_recovery(cfg, group=group, hw=hw)
            rows.append({
                "level": level,
                "fetch": label,
                "t_step_us": t * 1e6,
                "vs_healthy": t / max(base, 1e-30),
                "reshard_wire_mb": round(
                    (rec["wire_bytes"] + rec["source_bytes"]) / 1e6, 3
                ),
                "recovery_stall_us": round(rec["seconds"] * 1e6, 3),
            })
            continue
        if excl is None or excl:
            # the per-peer exclusion rung: the bad peers' experts leave
            # the speculative schedule and re-route through the (still
            # validated) correction round — priced as a predictor
            # hit-rate haircut of the excluded peers' share of the
            # remote bank
            ph = sub_kw.get("predict_hit")
            if ph is None and cfg.moe is not None:
                ph = 1.0 - (
                    1.0 - 1.0 / max(1, cfg.moe.num_experts)
                ) ** (tokens * cfg.moe.top_k)
            if ph is not None:
                sub_kw["predict_hit"] = (
                    ph * max(0, group - 1 - n_excl) / max(1, group - 1)
                )
        t = modeled_step_time(
            cfg, tokens=tokens, group=group, hw=hw, policies=table,
            validate=validate, **sub_kw,
        )
        rows.append({
            "level": level,
            "fetch": label,
            "t_step_us": t * 1e6,
            "vs_healthy": t / max(base, 1e-30),
        })
    return rows


def figure3_sweep(
    cfg: ArchConfig,
    *,
    group: int = 4,
    hw: Hardware = GB200,
    isls: tuple[int, ...] = (1024, 2048, 4096, 8192, 16384, 32768, 65536,
                             131072),
    batch: int = 1,
    weight_layout: Optional[str] = None,
    attn_gathered: bool = False,
    expert_fetch: str = "all",
    moe_ffn: str = "merged",
) -> list[dict]:
    """Reproduce Fig. 3: compute/prefetch ratio + DEP/DWDP speedup vs ISL."""
    rows = []
    moe_layer = (cfg.moe.first_dense if cfg.moe else 0)
    layout = weight_layout if weight_layout is not None else moe_ffn
    for isl in isls:
        lt = layer_times(cfg, tokens=batch * isl, group=group, hw=hw,
                         layer=moe_layer, weight_layout=layout,
                         attn_gathered=attn_gathered,
                         expert_fetch=expert_fetch)
        rows.append(
            {
                "isl": isl,
                "compute_to_prefetch": lt.compute_to_prefetch,
                "dep_to_dwdp": lt.speedup,
                "t_compute_us": lt.compute * 1e6,
                "t_prefetch_us": lt.prefetch * 1e6,
                "t_all2all_us": lt.all2all * 1e6,
                "land_mb": lt.land_bytes / 1e6,
                "t_land_us": lt.land_time * 1e6,
            }
        )
    return rows


def crossover_isl(cfg: ArchConfig, *, group: int = 4, hw: Hardware = GB200,
                  batch: int = 1) -> Optional[int]:
    """Smallest ISL where prefetch is fully hidden (ratio >= 1). The paper
    reports ~16K for DeepSeek-R1 ctx at batch 1 on GB200."""
    moe_layer = (cfg.moe.first_dense if cfg.moe else 0)
    for isl in range(1024, 1 << 20, 1024):
        lt = layer_times(cfg, tokens=batch * isl, group=group, hw=hw,
                         layer=moe_layer)
        if lt.compute_to_prefetch >= 1.0:
            return isl
    return None
