"""Flexible / redundant expert placement (paper §2).

DWDP's weak placement constraint: the DWDP group size G need not divide
the expert count E, and redundant placement is allowed. We realize this
as an (R x G') factorization of the group: G = R * G', where G' ranks
form a *subgroup* that collectively stores every expert exactly once
(padding E up to local*G' with dummy experts if needed) and the partition
is tiled R times across the group. Prefetch/all-to-all then run inside
subgroups only — R-fold redundancy cuts remote traffic by (R-1)/R and
lets any G (e.g. DWDP3 for 8 experts) work at single-rank granularity.

The gathered buffer is always in canonical expert order (source-subgroup-
position order == expert-id order), so no post-gather permutation copy is
ever required — the TPU analogue of the paper's §4.2 merge elimination.
"""
from __future__ import annotations

import dataclasses
import math

import numpy as np


@dataclasses.dataclass(frozen=True)
class Placement:
    """Expert-to-rank placement for one DWDP group."""

    num_experts: int          # E: real experts
    group_size: int           # G: ranks in the DWDP group (mesh "model" axis)
    redundancy: int           # R: copies of the full expert set in the group
    subgroup_size: int        # G' = G // R
    num_padded: int           # E_pad = local_count * G' >= E
    local_count: int          # experts stored per rank

    @property
    def storage_size(self) -> int:
        """Leading dim of the *global* expert array: G ranks x local each."""
        return self.group_size * self.local_count

    @property
    def remote_fraction(self) -> float:
        """Fraction of one layer's expert bytes fetched remotely per rank."""
        return (self.subgroup_size - 1) / self.subgroup_size

    def table(self) -> np.ndarray:
        """(G, local_count) expert ids held by each rank (padded ids >= E)."""
        ranks = np.arange(self.group_size) % self.subgroup_size
        base = ranks[:, None] * self.local_count + np.arange(self.local_count)
        return base  # padded expert ids in [0, num_padded)

    def axis_index_groups(self) -> list[list[int]] | None:
        """Subgroups for all_gather/all_to_all (None = whole axis)."""
        if self.redundancy == 1:
            return None
        g = self.subgroup_size
        return [
            [s * g + i for i in range(g)] for s in range(self.redundancy)
        ]

    def ring_pairs(self) -> list[tuple[int, int]]:
        """ppermute (src, dst) pairs: each subgroup forms its own ring
        (each rank's shard moves one position forward = everyone receives
        from neighbor p-1; equivalently ``shift_pairs(-1)``)."""
        return self.shift_pairs(-1)

    def shift_pairs(self, t: int) -> list[tuple[int, int]]:
        """ppermute (src, dst) pairs delivering subgroup neighbor ``p + t``'s
        data to each rank ``p`` (i.e. every rank's shard travels ``t``
        positions *backwards* around its subgroup ring). ``shift_pairs(1)``
        chained G'-1 times walks the ring; ``shift_pairs(t)`` one-shot pulls
        the t-th neighbor directly (remote-only allgather mode)."""
        pairs = []
        g = self.subgroup_size
        for s in range(self.redundancy):
            for i in range(g):
                pairs.append((s * g + i, s * g + (i - t) % g))
        return pairs


def make_placement(
    num_experts: int, group_size: int, *, redundancy: int | None = None
) -> Placement:
    """Choose a placement. Default redundancy: replicate the expert set as
    many times as fits whole subgroups, i.e. R = max R dividing G with
    G/R >= min(G, E') coverage — in practice R > 1 only when E < G."""
    if redundancy is None:
        redundancy = 1
        if num_experts < group_size:
            # largest R dividing G such that subgroup still covers all experts
            for r in range(group_size // max(1, num_experts), 0, -1):
                if group_size % r == 0:
                    redundancy = r
                    break
    if group_size % redundancy:
        raise ValueError(f"redundancy {redundancy} must divide group {group_size}")
    sub = group_size // redundancy
    local = math.ceil(num_experts / sub)
    return Placement(
        num_experts=num_experts,
        group_size=group_size,
        redundancy=redundancy,
        subgroup_size=sub,
        num_padded=local * sub,
        local_count=local,
    )


def expand_to_storage(experts: np.ndarray, placement: Placement) -> np.ndarray:
    """Expand an (E_pad, ...) expert array to the (G*local, ...) storage
    layout (duplicating across redundant subgroups). Used at init/ckpt."""
    table = placement.table().reshape(-1)  # (G*local,)
    return experts[table]
