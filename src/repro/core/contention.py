"""Paper §4.3: many-to-one source-side contention model + TDM mitigation.

Three artifacts:

1. ``contention_probabilities`` — the exact Binomial(N-2, 1/(N-1)) model of
   Table 2: the distribution of the number of concurrent pulls targeting
   the same source rank under random asynchronous execution.
2. ``build_copy_plan`` — Listing 1: the slice-round-robin DMA plan.
3. ``CopyEngineSim`` — a discrete-event simulator of per-source-rank copy
   engines serving pull requests, with and without TDM slicing, used to
   reproduce the Table 4 trends (contention mitigation matters most when
   the compute window is short).

On the TPU target the ring prefetch schedule is contention-free by
construction (each step is a disjoint neighbor permute), so this module
models the *paper's* copy-engine mechanism; ``ring_sliced`` is the
deployable TPU analogue of the TDM mitigation (finer-grained ICI chunks).
"""
from __future__ import annotations

import dataclasses
import heapq
import math
from typing import Iterable


def _binom_pmf(n: int, p: float, k: int) -> float:
    return math.comb(n, k) * p**k * (1 - p) ** (n - k)


def contention_probabilities(group_size: int) -> dict[int, float]:
    """Pr[C = c] for c = 1..N-1: C = X + 1, X ~ Binom(N-2, 1/(N-1))."""
    n = group_size
    if n < 2:
        return {1: 1.0}
    p = 1.0 / (n - 1)
    return {x + 1: _binom_pmf(n - 2, p, x) for x in range(n - 1)}


def expected_contention(group_size: int) -> float:
    return sum(c * pr for c, pr in contention_probabilities(group_size).items())


def build_copy_plan(
    prefetch_sizes: dict[str, int],
    remote_peers: list[int],
    slice_bytes: int,
) -> list[tuple[str, int, int, int]]:
    """Listing 1: batched prefetch-copy plan in round-robin slice order.

    Returns [(param, peer, offset, chunk)] — slices from different source
    ranks interleaved so no destination monopolizes one source.
    """
    plan: list[tuple[str, int, int, int]] = []
    for name, m in prefetch_sizes.items():
        offset = 0
        rr = list(remote_peers)
        while offset < m:
            chunk = min(slice_bytes, m - offset)
            for peer in rr:
                plan.append((name, peer, offset, chunk))
            rr = rr[1:] + rr[:1]  # rotate round-robin order
            offset += chunk
    return plan


@dataclasses.dataclass
class PullRequest:
    dst: int
    src: int
    bytes: int
    issue_time: float = 0.0


class CopyEngineSim:
    """Discrete-event model of source-side copy engines (paper §4.3).

    Each source engine has ``inflight`` pipelined service slots. Every
    transfer samples a path-condition multiplier J (short-lived congestion:
    J=jitter_mult with prob jitter_p, else 1) for its WHOLE duration — so a
    monolithic pull is hostage to a single bad episode, while TDM slices
    (a) re-sample per slice, averaging congestion out, and (b) let the
    other in-flight slice keep the engine busy while one is slowed — the
    paper's "two in flight rides through contention degree 2" argument.
    Destinations issue pulls serially (the DWDP rule), slices of one pull
    serially too; per-destination queues are served FIFO (round-robin
    emerges from the serial re-issue).
    """

    def __init__(self, group_size: int, bw: float, slice_bytes: int | None,
                 inflight: int = 2, jitter_p: float = 0.2,
                 jitter_mult: float = 3.0):
        self.n = group_size
        self.bw = bw
        self.slice_bytes = slice_bytes
        self.inflight = max(1, inflight)
        self.jitter_p = jitter_p
        self.jitter_mult = jitter_mult

    def run(self, pull_bytes: int, order_seed: int = 0) -> float:
        """One round: every rank pulls ``pull_bytes`` from each of the
        other N-1 ranks. Returns the makespan."""
        return max(self.run_per_dst(pull_bytes, order_seed))

    def run_per_dst(
        self, pull_bytes: int, order_seed: int = 0,
        offsets: list[float] | None = None,
    ) -> list[float]:
        """Per-destination pull latencies (completion - start) for one
        layer's prefetch round."""
        rng = _lcg(order_seed)
        orders = []
        for d in range(self.n):
            peers = [s for s in range(self.n) if s != d]
            for i in range(len(peers) - 1, 0, -1):
                j = next(rng) % (i + 1)
                peers[i], peers[j] = peers[j], peers[i]
            orders.append(peers)

        if self.slice_bytes:
            nsl = max(1, math.ceil(pull_bytes / self.slice_bytes))
            sizes = [self.slice_bytes] * (nsl - 1) + [
                pull_bytes - self.slice_bytes * (nsl - 1)
            ]
        else:
            sizes = [pull_bytes]

        def jitter() -> float:
            u = next(rng) / float(1 << 31)
            return self.jitter_mult if u < self.jitter_p else 1.0

        src_queue: list[list[tuple[int, int, int]]] = [[] for _ in range(self.n)]
        src_slots = [0] * self.n          # busy service slots per source
        events: list[tuple[float, int, int, int, int]] = []
        dst_done = [0.0] * self.n
        starts = offsets or [0.0] * self.n

        def start_service(t: float, s: int, d: int, pi: int, si: int):
            src_slots[s] += 1
            dur = sizes[si] / self.bw * jitter()
            heapq.heappush(events, (t + dur, s, d, pi, si))

        def issue(t: float, d: int, pi: int, si: int):
            s = orders[d][pi]
            if src_slots[s] >= self.inflight:
                src_queue[s].append((d, pi, si))
            else:
                start_service(t, s, d, pi, si)

        for d in range(self.n):
            issue(starts[d], d, 0, 0)
        while events:
            t, s, d, pi, si = heapq.heappop(events)
            src_slots[s] -= 1
            dst_done[d] = max(dst_done[d], t)
            if src_queue[s]:
                nd, npi, nsi = src_queue[s].pop(0)
                start_service(t, s, nd, npi, nsi)
            if si + 1 < len(sizes):
                issue(t, d, pi, si + 1)
            elif pi + 1 < len(orders[d]):
                issue(t, d, pi + 1, 0)
        return [dst_done[d] - starts[d] for d in range(self.n)]


def _lcg(seed: int):
    x = seed * 6364136223846793005 + 1442695040888963407
    while True:
        x = (x * 6364136223846793005 + 1442695040888963407) % (1 << 64)
        yield x >> 33


def tdm_speedup(
    group_size: int,
    pull_bytes: int,
    bw: float,
    slice_bytes: int = 1 << 20,
    seeds: Iterable[int] = range(16),
) -> dict[str, float]:
    """Makespan with vs without TDM slicing (Table 4's mechanism).
    Monolithic pulls cannot pipeline (inflight=1); small slices can."""
    mono = CopyEngineSim(group_size, bw, None, inflight=1)
    tdm = CopyEngineSim(group_size, bw, slice_bytes, inflight=2)
    t_mono = sum(mono.run(pull_bytes, s) for s in seeds) / len(list(seeds))
    seeds = list(seeds)
    t_tdm = sum(tdm.run(pull_bytes, s) for s in seeds) / len(seeds)
    return {
        "monolithic_s": t_mono,
        "tdm_s": t_tdm,
        "speedup": t_mono / t_tdm,
    }
