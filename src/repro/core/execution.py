"""DWDP execution engine: the paper's strategy as a first-class feature.

Everything that crosses ranks lives here, inside one whole-forward
``shard_map``. The three strategies share all local math and differ only
in *what moves*:

- **dwdp**: weights move. Expert / FFN / (escalated) attention weights are
  prefetch-gathered per layer — software-pipelined one layer ahead through
  the ``lax.scan`` carry (the paper's double buffering) — or ring-rotated
  through ranks when a full layer set cannot fit HBM. Activations never
  cross ranks for the FFN path; each rank serves its own tokens end to
  end. HOW each family is gathered is a per-family decision now: the
  plan carries a ``strategy.PolicyTable`` (``ExecutionPlan.policies``)
  and every consumer here reads ``xp.policy(family, group)`` —
  ``moe_experts``, ``attn_qkv``, ``attn_out``, ``dense_ffn`` — for its
  ``(layout, fetch, transport, num_slices, budget)``. With
  ``layout == "split"`` (the default) the gather is remote-only (§4.2
  generalized): the prefetch pipeline emits a ``prefetch.SplitBank`` for
  that family, the resident shard never re-lands, the prefetched payload
  is the ``(G'-1)/G'`` remote bank, and the fused split kernels consume
  both banks directly — no merged gathered-weight buffer (``(num_padded,
  D, F)`` expert bank, ``(A, D, qd/A)`` attention stack, ``(S, D, F/S)``
  FFN stack) is ever materialized. ``layout == "merged"`` keeps the
  legacy explicit merge (one canonical contiguous landing) per family;
  multi-axis (ZeRO-wide) gathers fall back to it automatically. Because
  the table is per-family, heterogeneous plans lower into ONE forward:
  e.g. demand-fetched split MoE experts + merged-allgather attention +
  split-ring dense FFN (the mixed plan the tests assert bitwise against
  its uniform-transport reference).
- **dep**: activations move. MoE uses all-to-all dispatch/combine; dense
  layers use gather + reduce-scatter TP (the synchronizing layer-boundary
  collectives of paper Fig. 1).
- **replicated**: nothing moves (pure DP reference; only meaningful when
  the weights fit replicated).

On-demand expert fetch (``xp.policy("moe_experts").fetch == "demand"`` —
the paper's "fetching missing experts on demand") inverts the engine's layer
structure for eligible MoE layers: **route-before-gather**. The
layer-ahead double buffering assumes the gather operand is known before
the layer runs — true for whole weight families, false for the
demand-selected expert subset, which only exists once the current
layer's routing has run. So for demand-active layers ``gather_set``
excludes the expert bank from the prefetch pipeline entirely (every
other family keeps its layer-ahead double buffering), and
``_moe_apply`` runs the inverted order: route (router weights are
local — a cheap (T, D) @ (D, E) matmul), build the activated-expert
bitmap, exchange indices, then fetch exactly the activated remote
experts into a compacted ``prefetch.DemandBank`` (budget-padded). Token
dispatch is remapped through ``fetched_ids`` instead of the PR 1
rotation roll, and the validity-predicated demand kernel consumes the
(resident, fetched) banks. When the activated set overflows the static
budget, an axis-agreed flag falls back per-layer to the full remote
gather (``lax.cond`` — all ranks take the same branch), so results are
always exact and never a function of the budget.

Sequence sharding (when the batch can't cover the mesh), KV-cache decode
with psum-LSE combine, RG-LRU cross-shard fix-up, vocab-sharded heads and
ZeRO-style train gathers are all implemented here so every
(arch x shape x mesh x mode) combination lowers.
"""
from __future__ import annotations

import dataclasses
import functools
import math
from typing import Any, Optional

import jax
import jax.numpy as jnp
from jax import lax
from jax.sharding import PartitionSpec as P

from repro.compat import shard_map
from repro.configs.base import BlockKind
from repro.core import faults
from repro.core import prefetch
from repro.kernels import split_gemm as split_gemm_lib
from repro.core.placement import Placement, make_placement
from repro.core.strategy import ExecutionPlan, input_pspecs, output_pspecs, state_pspecs
from repro.models import attention as attn_lib
from repro.models import moe as moe_lib
from repro.models.cache import init_decode_state
from repro.models.layers import causal_conv1d, rms_norm, apply_rope, softcap
from repro.models.recurrent import recurrent_block, rglru_parts
from repro.models.transformer import AXIS_MODEL, Geometry, LayerSig, Model
from repro.models.xlstm import mlstm_block, slstm_block

PyTree = Any
XENT_CHUNK = 512


# ==========================================================================
# Small axis helpers (all used inside shard_map).
# ==========================================================================
def _axsize(xp: ExecutionPlan, axes: tuple[str, ...]) -> int:
    return math.prod(xp.mesh_sizes[a] for a in axes)


def _shard_index(xp: ExecutionPlan, axes: tuple[str, ...]):
    idx = jnp.int32(0)
    for a in axes:
        idx = idx * xp.mesh_sizes[a] + lax.axis_index(a)
    return idx


def _psum(x, axes):
    return lax.psum(x, axes) if axes else x


def _axes_arg(axes: tuple[str, ...]):
    return axes if len(axes) > 1 else axes[0]


@dataclasses.dataclass
class Ctx:
    model: Model
    xp: ExecutionPlan
    pos: Any = None          # decode: (B,) per-row positions (traced)
    q_offset: Any = 0        # prefill/train: global offset of local seq slice
    capture_len: int = 0     # prefill: also emit a decode state of this len
    group: Optional[str] = None  # current layer-group name (policy overrides)

    @property
    def cfg(self):
        return self.model.cfg

    @property
    def geom(self) -> Geometry:
        return self.model.geom

    @property
    def decode(self) -> bool:
        return self.xp.phase == "decode"


# ==========================================================================
# Gather set: which weight subtrees are prefetched per layer, per mode.
# ==========================================================================
def _dep_tp_ok(geom: Geometry, xp: ExecutionPlan, what: str) -> bool:
    """Can DEP run this weight family as TP instead of gathering?"""
    if what == "ffn":
        return geom.ffn_axes == ("model",)
    if what == "attn":
        return (
            geom.attn_tp_ok
            and xp.phase != "decode"
            and geom.model_size > 1
        )
    return False


def moe_split_active(
    geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> bool:
    """Does the DWDP-gather MoE path run the §4.2 split fast path?"""
    pl = geom.moe_placement
    return (
        xp.policy("moe_experts", group).layout == "split"
        and xp.mode == "dwdp"
        and geom.moe_exec == "gather"
        and pl is not None
        and pl.subgroup_size > 1
    )


def dense_split_active(
    geom: Geometry,
    xp: ExecutionPlan,
    axes: tuple[str, ...],
    family: str = "dense_ffn",
    group: Optional[str] = None,
) -> bool:
    """Does a leading-stacked dense family (attn_qkv / attn_out /
    dense_ffn) gathered over ``axes`` use the split-bank representation?

    Split covers the weights-move modes over a single mesh axis (the
    remote-only permutes are single-axis primitives); multi-axis ZeRO-wide
    train gathers and the DEP fallback gathers keep the legacy merged
    landing."""
    return (
        xp.policy(family, group).layout == "split"
        and xp.mode in ("dwdp", "hybrid")
        and len(axes) == 1
        and _axsize(xp, axes) > 1
    )


def split_bank_active(
    geom: Geometry, xp: ExecutionPlan, key: str, group: Optional[str] = None
) -> bool:
    """Unified per-family predicate: does gather_layer emit a SplitBank
    for this gather-set key / attention sub-family? (The one switch the
    roofline/residency accounting mirrors.)"""
    if key == "moe/experts":
        return moe_split_active(geom, xp, group)
    if key in ("attn_qkv", "attn_out"):
        return dense_split_active(geom, xp, geom.attn_axes, key, group)
    if key in ("ffn", "moe/shared"):
        return dense_split_active(geom, xp, geom.ffn_axes, "dense_ffn", group)
    return False


def _routed_tokens(xp: ExecutionPlan) -> int:
    """Per-rank routed token count (static — must agree between
    ``gather_set`` and the ``x2d`` the layer actually routes)."""
    if xp.phase == "decode":
        return max(1, xp.local_batch)
    return max(1, xp.local_batch) * max(1, xp.local_seq)


def demand_fetch_active(
    cfg, geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> bool:
    """Does the MoE gather run the on-demand route-before-gather path?
    (Covers ``fetch="demand"``, ``"predictive"`` and ``"sync_free"`` —
    the predictive engines are refinements of the demand rounds.)

    Requires the split fast path (the demand bank is a split-bank
    refinement) over a single-axis placement, and engages only when
    expected coverage is partial — ``rows * top_k < remote experts`` —
    i.e. when the activated set *can* be a strict subset of the remote
    bank (decode, small-batch prefill). At full coverage the "all"
    gather is never worse, so the plan silently keeps it."""
    fetch = xp.policy("moe_experts", group).fetch
    if fetch not in ("demand", "predictive", "sync_free"):
        return False
    if cfg.moe is None or not moe_split_active(geom, xp, group):
        return False
    if len(geom.expert_axes) != 1:
        return False
    pl = geom.moe_placement
    num_remote = (pl.subgroup_size - 1) * pl.local_count
    return _routed_tokens(xp) * cfg.moe.top_k < num_remote


def predictive_fetch_active(
    cfg, geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> bool:
    """Does the demand path additionally run the predictive engine —
    layer-ahead speculative round + cross-step residency cache +
    post-routing correction round?

    Decode only: the predictor and the cache live in a ``PredictState``
    threaded through the decode-step state, which only the decode loop
    carries. Everywhere else ``fetch="predictive"`` / ``"sync_free"``
    lowers exactly as ``"demand"`` (same rounds, same bitwise
    results)."""
    return (
        xp.phase == "decode"
        and xp.policy("moe_experts", group).fetch
        in ("predictive", "sync_free")
        and demand_fetch_active(cfg, geom, xp, group)
    )


def sync_free_active(
    cfg, geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> bool:
    """Does the predictive decode engine run the SYNC-FREE variant —
    mirrored global ``PredictState`` on every rank, so the speculative
    round's compaction is derived identically on both transfer
    endpoints and ships ZERO index metadata, with all per-layer index
    traffic (residual bitmaps + predictor signals + checksum table)
    packed into the one correction-round all-gather? Implies
    :func:`predictive_fetch_active`; everywhere that predicate is
    false, ``fetch="sync_free"`` lowers exactly as ``"demand"``."""
    return (
        xp.policy("moe_experts", group).fetch == "sync_free"
        and predictive_fetch_active(cfg, geom, xp, group)
    )


def resolve_demand_budget(
    cfg, geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> int:
    """Static per-peer demand-fetch row budget — for predictive-active
    layers this is the *correction* round's budget (the miss-set
    estimate), for plain demand the whole round's.

    A ``moe_experts`` policy ``budget`` > 0 is honored (clamped to the
    per-rank expert count, at which point overflow is impossible). Auto
    (0) applies ``roofline.demand_budget_rows`` — 2x the expected
    per-peer distinct-expert coverage, 8-aligned — or, predictive, the
    correction half of ``roofline.predictive_budget_rows``: the ONE set
    of closed forms the roofline/simulator wire models price, so the
    analytics and the lowered program always ship the same payload.
    Overflow beyond the budget is handled exactly by the per-layer
    fallback, so the estimate only tunes wire bytes, never correctness
    (in particular the correction payload is budget-bounded by the miss
    estimate, never by the expert count)."""
    from repro.core.roofline import demand_budget_rows, predictive_budget_rows

    pl = geom.moe_placement
    assert pl is not None and cfg.moe is not None
    local = pl.local_count
    user = xp.policy("moe_experts", group).budget
    if user > 0:
        return min(user, local)
    draws = _routed_tokens(xp) * cfg.moe.top_k
    if predictive_fetch_active(cfg, geom, xp, group):
        return predictive_budget_rows(draws, cfg.moe.num_experts, local)[1]
    return demand_budget_rows(draws, cfg.moe.num_experts, local)


def resolve_spec_budget(
    cfg, geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> int:
    """Static per-peer row budget of the predictive SPECULATIVE round
    (the layer-ahead prefetch of the predicted hot set). Policy
    ``budget`` > 0 is honored; auto applies the speculative half of
    ``roofline.predictive_budget_rows`` (1x expected coverage). The
    predictor shapes its bitmap to at most this many rows per peer, so
    the speculative round can never overflow — excess predictions are
    simply left to the correction round."""
    from repro.core.roofline import predictive_budget_rows

    pl = geom.moe_placement
    assert pl is not None and cfg.moe is not None
    local = pl.local_count
    user = xp.policy("moe_experts", group).budget
    if user > 0:
        return min(user, local)
    return predictive_budget_rows(
        _routed_tokens(xp) * cfg.moe.top_k, cfg.moe.num_experts, local
    )[0]


def resolve_cache_rows(
    cfg, geom: Geometry, xp: ExecutionPlan, group: Optional[str] = None
) -> int:
    """Rows of the per-layer cross-step expert residency cache: the
    ``moe_experts`` policy's ``cache_budget``, capped at the remote bank
    (caching more than the remote rows buys nothing). 0 = cache off."""
    pl = geom.moe_placement
    assert pl is not None
    remote = (pl.subgroup_size - 1) * pl.local_count
    return min(xp.policy("moe_experts", group).cache_budget, remote)


def fault_stats_active(model: Model, xp: ExecutionPlan) -> bool:
    """Static twin of the validated fetch path's telemetry output: True
    iff this plan's decode step emits ``out["fault_stats"]`` — payload
    validation is on (``xp.validated``: a fault spec to inject, or the
    production ``validate_fetch`` switch) AND at least one MoE layer
    runs the demand/predictive route-before-gather path (the validated
    surface). The vector layout is :data:`faults.FAULT_STAT_BASE` named
    counters followed by per-source-subgroup-position detected counts
    (length ``subgroup_size``), psum'd over all ranks.

    Exception: a sync-free decode layer emits the vector even
    UNVALIDATED — its mirrored-schedule divergence digest always runs
    (it is the mode's consistency contract, not a fault-injection
    feature), so the ``mirror_divergence`` counter must reach the
    HealthMonitor regardless; the other counters are zero then."""
    if model.cfg.moe is None:
        return False
    sync_free = any(
        sig.is_moe and sync_free_active(model.cfg, model.geom, xp, g.name)
        for g in model.plan
        for sig in g.sigs
    )
    if not xp.validated and not sync_free:
        return False
    return sync_free or any(
        sig.is_moe and demand_fetch_active(model.cfg, model.geom, xp, g.name)
        for g in model.plan
        for sig in g.sigs
    )


def _fault_injector(ctx: Ctx, axis: str) -> Optional[faults.FaultInjector]:
    if ctx.xp.fault_spec is None:
        return None
    return faults.FaultInjector(
        ctx.xp.fault_spec, axis, ctx.geom.moe_placement, ctx.xp.mesh_sizes
    )


def _fault_step(ctx: Ctx):
    """Traced decode-step index for fault-key derivation (0 outside
    decode): faults vary per step but are reproducible per step."""
    if ctx.pos is None:
        return jnp.int32(0)
    return jnp.max(ctx.pos).astype(jnp.int32)


def _injected_counts(inj: faults.FaultInjector, key, budget: int, valid):
    """Requester-side recomputation of one payload round's injected-row
    counts ``[drop, zero, corrupt]`` — same key, same masks as the
    tamper site; only rows the plan marked valid count (tampering
    padding rows consumes nothing)."""
    drop, zero, corrupt = inj.payload_masks(key, budget)

    def f(m):
        return jnp.sum((m & valid).astype(jnp.float32))

    return jnp.stack([f(drop), f(zero), f(corrupt)])


def _per_src_detected(bad, budget: int, g: int, p):
    """Attribute each detected payload row to the subgroup position
    that served it (rows are peer-major: chunk t from position
    ``(p + t) % g``)."""
    rows = bad.shape[0]
    if rows == 0:
        return jnp.zeros((g,), jnp.float32)
    src = (p + 1 + jnp.arange(rows, dtype=jnp.int32) // budget) % g
    return jnp.zeros((g,), jnp.float32).at[src].add(bad.astype(jnp.float32))


def gather_set(
    sig: LayerSig,
    geom: Geometry,
    xp: ExecutionPlan,
    cfg=None,
    group: Optional[str] = None,
) -> tuple[tuple[str, ...], ...]:
    """Key paths within a layer param dict that the prefetch pipeline
    gathers before the layer executes.

    Demand-active MoE layers (route-before-gather) exclude the expert
    bank: their gather depends on the current layer's routing, so it
    runs *inside* ``_moe_apply`` instead of the layer-ahead pipeline.
    PREDICTIVE-active layers (decode) re-join the pipeline: their
    speculative round depends only on the cross-step ``PredictState``,
    so it is issued a layer ahead like any other family — that is what
    puts the payload round back under the previous layer's
    attention/compute window — and only the small correction round stays
    inside ``_moe_apply``. ``cfg`` is needed for those eligibility
    checks only; callers that pass none get the demand-oblivious set.
    ``group`` scopes per-layer-group policy overrides."""
    if xp.mode == "replicated":
        return ()
    out: list[tuple[str, ...]] = []
    weights_move = xp.mode in ("dwdp", "hybrid")
    is_attn = sig.kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN)
    if is_attn and geom.attn_axes and not _qgather_ok(geom, xp):
        if weights_move or not _dep_tp_ok(geom, xp, "attn"):
            out.append(("attn",))
    if sig.kind == BlockKind.RECURRENT and geom.cell_axes:
        out.append(("rec",))
    if sig.kind in (BlockKind.MLSTM, BlockKind.SLSTM) and geom.cell_axes:
        out.append(("cell",))
    if sig.is_moe:
        pl = geom.moe_placement
        assert pl is not None
        if (
            xp.mode == "dwdp"
            and geom.moe_exec == "gather"
            and pl.subgroup_size > 1
            and not (
                cfg is not None
                and demand_fetch_active(cfg, geom, xp, group)
                and not predictive_fetch_active(cfg, geom, xp, group)
            )
        ):
            out.append(("moe", "experts"))
        if sig.shared_d_ff and geom.ffn_axes:
            if weights_move or not _dep_tp_ok(geom, xp, "ffn"):
                out.append(("moe", "shared"))
    elif sig.ffn_dim and geom.ffn_axes:
        if weights_move or not _dep_tp_ok(geom, xp, "ffn"):
            out.append(("ffn",))
    return tuple(out)


def gathered_wire_bytes_per_step(model: Model, xp: ExecutionPlan) -> dict:
    """Static per-rank gathered-weight wire bytes for one forward step:
    ``{"full": ..., "fetched": ..., "families": {family: {"full": ...,
    "fetched": ...}}}``.

    ``fetched`` is what the lowered program actually ships (demand-active
    expert layers pay the budget-padded payload + the index round);
    ``full`` is the same step under an all-fetch ``moe_experts`` policy —
    the counterfactual the serving metrics report savings against.
    ``families`` breaks both down per gathered-weight family
    (``moe_experts``, ``attn_qkv``, ``attn_out``, ``dense_ffn``) so the
    serving metrics can report per-family traffic, not just the MoE
    total. Predictive/sync-free layers additionally report a ``rounds``
    split — ``{"spec": ..., "corr": ...}`` — separating the layer-ahead
    (overlappable) speculative round from the post-routing
    (critical-path) correction round; plain demand's one post-routing
    round counts under ``corr``, and sync-free steps add a per-STEP
    ``mirror`` entry (the one mirror-fold all-gather, counted once —
    not per layer). Counts the stacked transformer families; the rare
    flat cell/rec gathers are not modeled here.
    """
    cfg, geom = model.cfg, model.geom
    ws = jnp.dtype(model.dtype).itemsize
    d = cfg.d_model
    fams = {
        f: {"full": 0.0, "fetched": 0.0}
        for f in ("moe_experts", "attn_qkv", "attn_out", "dense_ffn")
    }
    rounds = {"spec": 0.0, "corr": 0.0}
    any_rounds = False
    any_sync = False

    def add(fam: str, n_cycles: int, full_b: float, fetched_b=None):
        fams[fam]["full"] += full_b * n_cycles
        fams[fam]["fetched"] += (
            full_b if fetched_b is None else fetched_b
        ) * n_cycles

    for group in model.plan:
        for sig in group.sigs:
            paths = gather_set(sig, geom, xp, cfg, group.name)
            for path in paths:
                key = "/".join(path)
                if key == "moe/experts":
                    pl = geom.moe_placement
                    pe = 3 * d * cfg.moe.d_ff * ws
                    full_b = prefetch.gather_bytes(pl, pe)
                    if predictive_fetch_active(cfg, geom, xp, group.name):
                        # the predictive rounds replace the full gather:
                        # budget-padded speculative round (layer-ahead)
                        # + correction round. Plain predictive pays an
                        # index round on each; sync-free ships a pure-
                        # payload speculative round and packs ALL index
                        # metadata into the correction all-gather.
                        spec_b = resolve_spec_budget(
                            cfg, geom, xp, group.name
                        )
                        corr_b = resolve_demand_budget(
                            cfg, geom, xp, group.name
                        )
                        if sync_free_active(cfg, geom, xp, group.name):
                            any_sync = True
                            by_round = prefetch.sync_free_fetch_bytes(
                                pl, spec_b, corr_b, _routed_tokens(xp),
                                pe, validate=xp.validated,
                            )
                        else:
                            by_round = {
                                "spec": prefetch.demand_fetch_bytes(
                                    pl, spec_b, pe, validate=xp.validated
                                ),
                                "corr": prefetch.demand_fetch_bytes(
                                    pl, corr_b, pe, validate=xp.validated
                                ),
                            }
                        any_rounds = True
                        for rnd in ("spec", "corr"):
                            rounds[rnd] += by_round[rnd] * group.n_cycles
                        fetched = min(
                            full_b, by_round["spec"] + by_round["corr"]
                        )
                        add("moe_experts", group.n_cycles, full_b, fetched)
                    else:
                        add("moe_experts", group.n_cycles, full_b)
                elif key == "attn":
                    a = _axsize(xp, geom.attn_axes)
                    qkv = d * (cfg.q_dim + 2 * cfg.kv_dim) * ws
                    out = cfg.q_dim * d * ws
                    add("attn_qkv", group.n_cycles, qkv * (a - 1) / max(1, a))
                    add("attn_out", group.n_cycles, out * (a - 1) / max(1, a))
                elif key in ("ffn", "moe/shared"):
                    s = _axsize(xp, geom.ffn_axes)
                    f = sig.shared_d_ff if key == "moe/shared" else sig.ffn_dim
                    w = 3 * d * (f or 0) * ws
                    add("dense_ffn", group.n_cycles, w * (s - 1) / max(1, s))
            if (
                sig.is_moe
                and demand_fetch_active(cfg, geom, xp, group.name)
                and not predictive_fetch_active(cfg, geom, xp, group.name)
            ):
                # route-before-gather layers: gather_set excluded the
                # expert bank; the demand fetch happens inside the layer
                pl = geom.moe_placement
                pe = 3 * d * cfg.moe.d_ff * ws
                budget = resolve_demand_budget(cfg, geom, xp, group.name)
                fetched = prefetch.demand_fetch_bytes(
                    pl, budget, pe, validate=xp.validated
                )
                any_rounds = True
                rounds["corr"] += fetched * group.n_cycles
                add("moe_experts", group.n_cycles,
                    prefetch.gather_bytes(pl, pe), fetched)
    if any_sync:
        # the ONE per-step mirror-fold all-gather (routing/position
        # signals) — per STEP, not per layer, so it adds once, outside
        # the group/cycle loops
        mb = float(prefetch.sync_free_mirror_bytes(
            geom.moe_placement, _routed_tokens(xp)
        ))
        rounds["mirror"] = mb
        fams["moe_experts"]["fetched"] += mb
    out = {
        "full": sum(v["full"] for v in fams.values()),
        "fetched": sum(v["fetched"] for v in fams.values()),
        "families": fams,
    }
    if any_rounds:
        out["rounds"] = rounds
    return out


def _extract(lp: dict, paths) -> dict:
    out = {}
    for path in paths:
        sub = lp
        for k in path:
            sub = sub[k]
        out["/".join(path)] = sub
    return out


def _merge(lp: dict, gathered: dict) -> dict:
    if not gathered:
        return lp
    lp = dict(lp)
    for key, sub in gathered.items():
        path = key.split("/")
        node = lp
        for k in path[:-1]:
            node[k] = dict(node[k])
            node = node[k]
        node[path[-1]] = sub
    return lp


def _gather_leading(tree, axes: tuple[str, ...], xp: ExecutionPlan, pol):
    """Legacy merged gather of stacked-storage weights (leading shard
    axis) to full — the *explicit merge step*: every shard, resident
    included, lands once in the canonical contiguous buffer, over the
    family policy's transport. Split mode never calls this for a
    split-active family."""
    size = _axsize(xp, axes)
    if size == 1:
        return tree
    if len(axes) > 1 or pol.transport == "allgather":
        ax = _axes_arg(axes)
        return jax.tree.map(
            lambda w: lax.all_gather(w, ax, axis=0, tiled=True), tree
        )
    pl = make_placement(size, size)
    return prefetch.gather_shards(
        tree, axes[0], pl, mode=pol.transport, num_slices=pol.num_slices
    )


def _leading_placement(axes: tuple[str, ...], xp: ExecutionPlan):
    """Trivial one-slice-per-rank placement for stacked dense families
    (subgroup == the whole axis, local_count == 1)."""
    size = _axsize(xp, axes)
    return make_placement(size, size)


def _gather_flat(tree, axes: tuple[str, ...], xp: ExecutionPlan):
    """Gather flat (last-dim-sharded) cell weights to full.

    Only the 2-D ``w_*`` projection matrices are ZeRO-sharded by the spec
    builder (layer_pspecs); 1-D gains, conv kernels and per-head ``r_*``
    recurrent blocks stay replicated and must pass through untouched.
    """
    if _axsize(xp, axes) == 1:
        return tree
    ax = _axes_arg(axes)
    return {
        k: (
            lax.all_gather(w, ax, axis=w.ndim - 1, tiled=True)
            if (k.startswith("w_") and w.ndim == 2)
            else w
        )
        for k, w in tree.items()
    }


_ATTN_PARTS = (("attn_qkv", ("wq", "wk", "wv")), ("attn_out", ("wo",)))


def _gather_attn(tree: dict, ctx: Ctx):
    """Gather the attention projections as TWO policy families —
    ``attn_qkv`` (wq/wk/wv) and ``attn_out`` (wo) — each under its own
    (layout, transport). Returns a plain merged dict when both parts are
    merged (byte-identical to the legacy whole-family gather) or a
    ``prefetch.AttnBank`` carrying each part's representation when at
    least one is split — which is how a mixed plan runs split QKV next
    to a merged output projection (or vice versa) in one forward."""
    geom, xp = ctx.geom, ctx.xp
    axes = geom.attn_axes
    parts = {}
    for fam, keys in _ATTN_PARTS:
        sub = {k: tree[k] for k in keys}
        pol = xp.policy(fam, ctx.group)
        if dense_split_active(geom, xp, axes, fam, ctx.group):
            parts[fam] = prefetch.gather_split_bank(
                sub, axes[0], _leading_placement(axes, xp),
                mode=pol.transport, num_slices=pol.num_slices,
            )
        else:
            parts[fam] = _gather_leading(sub, axes, xp, pol)
    if not any(isinstance(p, prefetch.SplitBank) for p in parts.values()):
        return {**parts["attn_qkv"], **parts["attn_out"]}
    return prefetch.AttnBank(qkv=parts["attn_qkv"], out=parts["attn_out"])


def _mirror_spec_masks(ctx: Ctx, pred, pl, sbudget: int) -> jax.Array:
    """Sync-free speculative schedule: the ``(G', num_padded)`` predicted
    bitmaps of EVERY subgroup position, derived from the mirrored
    ``PredictState`` alone (global prev/EMA/cache views + the richer
    signals weighted by ``predict_extra_score``). Deterministic in the
    mirror, so the gather site (pipeline, layer-ahead) and the digest
    site (``_moe_demand_apply``, same step, same ``pred``) recompute the
    identical array — that determinism is WHY the speculative round needs
    no index exchange.

    The ``mirror`` fault perturbs the target rank's view of its own row
    here — transiently, at both call sites identically (same pred, same
    step key), never persisted into the state — so the drifted rank
    genuinely derives a different schedule for the digest to catch."""
    geom, xp = ctx.geom, ctx.xp
    prev, ema = pred.prev[0], pred.ema[0]
    cids, cvalid = pred.cache_ids[0], pred.cache_valid[0]
    sig, sigw = pred.sig[0], pred.sigw[0]
    inj = _fault_injector(ctx, geom.expert_axes[0])
    if inj is not None and inj.spec.mirror_rate:
        flag = inj.mirror_flag(_fault_step(ctx))
        p = lax.axis_index(geom.expert_axes[0]) % pl.subgroup_size
        bump = jnp.where(
            jnp.arange(pl.num_padded) % 3 == 0, 10.0, 0.0
        )
        ema = ema.at[p].add(jnp.where(flag, bump, 0.0))
    extra = jax.vmap(prefetch.predict_extra_score)(sig, sigw)

    def one(prev_q, ema_q, ids_q, valid_q, extra_q):
        return prefetch.predict_bitmap(
            prev_q, ema_q, pl, budget=sbudget,
            exclude_ids=ids_q, exclude_valid=valid_q,
            extra_score=extra_q, exclude_peers=xp.exclude_peers,
        )

    return jax.vmap(one)(prev, ema, cids, cvalid, extra)


def _speculative_expert_gather(tree, ctx: Ctx, pred) -> prefetch.DemandBank:
    """The predictive fetch's layer-ahead SPECULATIVE round: a demand
    gather of the predictor's hot set (previous-step routing + EMA, minus
    cache-resident rows), issued from the prefetch pipeline — i.e. during
    the previous layer's attention/compute window, with no dependence on
    this step's routing, so the payload overlaps compute exactly like the
    all-fetch prefetch. The predictor bitmap is shaped to the speculative
    budget per peer, so this round never overflows (misses fall to the
    correction round inside ``_moe_apply``).

    Plain predictive exchanges the bitmaps (``plan_demand_fetch``'s
    all-gather — senders must learn what to serve). SYNC-FREE derives
    every position's bitmap from the mirrored state instead
    (:func:`_mirror_spec_masks`), so this round lowers to payload
    permutes ONLY — zero index metadata on the wire."""
    cfg, geom, xp = ctx.cfg, ctx.geom, ctx.xp
    pl = geom.moe_placement
    axis = geom.expert_axes[0]
    g, local = pl.subgroup_size, pl.local_count
    pol = xp.policy("moe_experts", ctx.group)
    sbudget = resolve_spec_budget(cfg, geom, xp, ctx.group)
    if sync_free_active(cfg, geom, xp, ctx.group):
        sbudget = min(sbudget, local)
        masks = _mirror_spec_masks(ctx, pred, pl, sbudget)
        p = lax.axis_index(axis) % g
        own = lax.dynamic_index_in_dim(masks, p, 0, keepdims=False)
        fetched_ids, valid, _ = prefetch.plan_from_bitmap(
            own, p, g, local, sbudget
        )
        plan = prefetch.DemandPlan(
            masks=masks, fetched_ids=fetched_ids, valid=valid,
            overflow=jnp.bool_(False),
        )
    else:
        wanted = prefetch.predict_bitmap(
            pred.prev[0], pred.ema[0], pl, budget=sbudget,
            exclude_ids=pred.cache_ids[0],
            exclude_valid=pred.cache_valid[0],
            exclude_peers=xp.exclude_peers,
        )
        plan = prefetch.plan_demand_fetch(
            wanted, axis, pl, budget=sbudget, agree_axes=()
        )
    inj = _fault_injector(ctx, axis)
    return prefetch.gather_demand_payload(
        tree, plan, axis, pl, budget=sbudget, mode=pol.transport,
        num_slices=pol.num_slices, injector=inj,
        fault_key=(
            inj.site_key("spec", _fault_step(ctx)) if inj is not None
            else None
        ),
    )


def gather_layer(gsub: dict, ctx: Ctx, pred=None) -> dict:
    """One gather routine for every prefetched family, each under ITS OWN
    policy (``xp.policy(family, group)`` — layout, transport, slicing).

    Split-active families come back as a ``prefetch.SplitBank`` — THE
    canonical gathered representation (remote-only wire traffic, resident
    shard untouched, rotated canonical order); the attention tree splits
    into its qkv/out sub-families (see ``_gather_attn``). A
    predictive-active expert bank (decode) comes back as a compact
    ``prefetch.DemandBank`` instead — the speculative round's fetch of
    the predicted hot set, driven by the layer's ``pred``
    :class:`prefetch.PredictState`. Everything else takes the legacy
    path through the explicit merge (``_gather_leading`` /
    ``gather_shards``), which is the only place a full canonical weight
    buffer is ever created."""
    geom, xp = ctx.geom, ctx.xp
    out = {}
    for key, tree in gsub.items():
        if key in ("rec", "cell"):
            # norms and 1-d params are replicated; only shard-eligible
            # (last dim divisible) leaves were sharded by the spec builder
            out[key] = _gather_flat(tree, geom.cell_axes, xp)
            continue
        if key == "attn":
            out[key] = _gather_attn(tree, ctx)
            continue
        if key in ("ffn", "moe/shared"):
            axes, pl, fam = geom.ffn_axes, None, "dense_ffn"
        elif key == "moe/experts":
            axes, pl, fam = geom.expert_axes, geom.moe_placement, "moe_experts"
            assert pl is not None and len(axes) == 1
            if predictive_fetch_active(ctx.cfg, geom, xp, ctx.group):
                assert pred is not None, (
                    "predictive fetch needs the layer's PredictState in "
                    'the decode state — attach it with '
                    "execution.attach_predict_state(state, model, xp)"
                )
                out[key] = _speculative_expert_gather(tree, ctx, pred)
                continue
        else:
            raise KeyError(key)
        pol = xp.policy(fam, ctx.group)
        if split_bank_active(geom, xp, key, ctx.group):
            out[key] = prefetch.gather_split_bank(
                tree,
                axes[0],
                pl if pl is not None else _leading_placement(axes, xp),
                mode=pol.transport,
                num_slices=pol.num_slices,
            )
        elif pl is not None:
            out[key] = prefetch.gather_shards(
                tree, axes[0], pl, mode=pol.transport,
                num_slices=pol.num_slices,
            )
        else:
            out[key] = _gather_leading(tree, axes, xp, pol)
    return out


# ==========================================================================
# Embedding / head.
# ==========================================================================
def _embed_table(params, ctx: Ctx):
    """Full (V_pad, D) embedding — gathered over the vocab shards."""
    emb = params["embed"]
    if ctx.geom.model_size > 1:
        emb = lax.all_gather(emb, AXIS_MODEL, axis=0, tiled=True)
    return emb


def _compute_dtype(model):
    if model.dtype in (jnp.float8_e4m3fn, jnp.float8_e5m2):
        return jnp.bfloat16
    return model.dtype


def _embed_decode(params, token, ctx: Ctx):
    emb = params["embed"]  # local (V_l, D)
    v_l = emb.shape[0]
    off = lax.axis_index(AXIS_MODEL) * v_l if ctx.geom.model_size > 1 else 0
    idx = token - off
    valid = (idx >= 0) & (idx < v_l)
    cd = _compute_dtype(ctx.model)
    x = emb[jnp.clip(idx, 0, v_l - 1)].astype(cd) * valid[..., None].astype(cd)
    if ctx.geom.model_size > 1:
        x = lax.psum(x, AXIS_MODEL)
    return x


def _head_local(params, ctx: Ctx):
    """Local (D, V_l) head slice for decode/prefill logits."""
    if ctx.cfg.tie_embeddings:
        return params["embed"].T
    return params["lm_head"]


def _mask_vocab_cols(logits, ctx: Ctx, local: bool):
    v = ctx.cfg.vocab_size
    v_tot = ctx.geom.vocab_pad
    if v == v_tot:
        return logits
    n = logits.shape[-1]
    if local and ctx.geom.model_size > 1:
        off = lax.axis_index(AXIS_MODEL) * n
    else:
        off = 0
    cols = off + jnp.arange(n)
    return jnp.where(cols < v, logits, -1e30)


# ==========================================================================
# Attention.
# ==========================================================================
def _w(w, like):
    """Dequantize-on-use: fp8-stored weights compute in the activation
    dtype (the paper's NVFP4-storage analogue)."""
    return w.astype(like.dtype) if w.dtype != like.dtype else w


def _project_heads(h, w, heads, head_dim):
    """h: (B,S,D); w: (A, D, dim/A) stacked -> (B,S,heads,head_dim)."""
    b, s, _ = h.shape
    out = jnp.einsum("bsd,adh->bsah", h, _w(w, h))
    return out.reshape(b, s, heads, head_dim)


def _dedupe_kv(w, geom: Geometry):
    """Gathered kv weights (A, D, kvd/ks) -> (ks, D, kvd/ks)."""
    a = w.shape[0]
    if a > geom.kv_shard:
        w = w[:: a // geom.kv_shard]
    return w


def _attn_split_position(geom: Geometry):
    """Caller position on the (single-axis) attention shard ring."""
    return lax.axis_index(geom.attn_axes[0]) % geom.attn_shards


def _attn_split_qkv(h, bank, ctx: Ctx):
    """q/k/v projections straight off a SplitBank — no merged ``(A, D,
    qd/A)`` weight stack ever exists.

    The split kernel emits per-slice outputs in rotated bank order
    (resident slice first); the roll back to canonical head order happens
    on the *projected activations* (a gather of (T, A, fs) — a factor
    D/fs smaller than the weight merge the paper eliminates, and pure
    index arithmetic on the weight side). KV slices are computed for all
    A stacked positions and deduped post-projection — a GQA-duplicate
    recompute bounded by A/kv_shard on the (small) KV projections.
    """
    cfg, geom = ctx.cfg, ctx.geom
    a = geom.attn_shards
    p = _attn_split_position(geom)
    b, s, dm = h.shape
    h2d = h.reshape(b * s, dm)
    impl = split_gemm_lib.default_dense_impl(ctx.xp.phase)
    canon = (jnp.arange(a) - p) % a  # canonical slice j sits at rotated j-p

    def stack(name):
        out = split_gemm_lib.split_stack_matmul(
            h2d, bank.local[name], bank.remote[name], impl=impl
        )  # (A, T, fs) rotated
        return jnp.take(jnp.moveaxis(out, 0, 1), canon, axis=1)  # (T, A, fs)

    hd = cfg.head_dim
    q = stack("wq").reshape(b, s, cfg.num_heads, hd)
    dup = a // geom.kv_shard
    k = stack("wk")[:, ::dup].reshape(b, s, cfg.num_kv_heads, hd)
    v = stack("wv")[:, ::dup].reshape(b, s, cfg.num_kv_heads, hd)
    return q, k, v


def _attn_split_out(out, bank, ctx: Ctx):
    """Output projection off a SplitBank: roll the attention output's
    head slices into rotated bank order (activation-side, index-only),
    then let the reduce kernel sum per-slice contributions — the sum is
    order-independent, so no post-fix-up is needed."""
    geom = ctx.geom
    a = geom.attn_shards
    p = _attn_split_position(geom)
    b, s = out.shape[:2]
    impl = split_gemm_lib.default_dense_impl(ctx.xp.phase)
    rot = (jnp.arange(a) + p) % a  # rotated slice j is canonical p+j
    out = jnp.take(out.reshape(b, s, a, -1), rot, axis=2)
    out = jnp.moveaxis(out.reshape(b * s, a, -1), 1, 0)  # (A, T, fs)
    y = split_gemm_lib.split_reduce_matmul(
        out, bank.local["wo"], bank.remote["wo"], impl=impl
    )
    return y.reshape(b, s, -1)


def _attn_full(h, aw, sig: LayerSig, ctx: Ctx, lstate):
    """Full-weight attention: replicated, DWDP-gathered merged, the §4.2
    split fast path, or any per-family mix of the two.

    ``aw`` is a flat weight dict (replicated / fully merged), a whole
    ``prefetch.SplitBank`` (both attention families split), or a
    ``prefetch.AttnBank`` whose qkv/out parts carry each family's own
    representation — so ``attn_qkv`` and ``attn_out`` policies compose
    freely (split QKV feeding a merged output projection and vice
    versa). The split QKV path rolls its outputs back to canonical head
    order, which is exactly the order the merged out path consumes."""
    cfg, geom, xp = ctx.cfg, ctx.geom, ctx.xp
    b, s, _ = h.shape
    hd = cfg.head_dim
    if isinstance(aw, prefetch.AttnBank):
        qkv_w, out_w = aw.qkv, aw.out
    else:
        qkv_w = out_w = aw
    if isinstance(qkv_w, prefetch.SplitBank):
        q, k, v = _attn_split_qkv(h, qkv_w, ctx)
    else:
        q = _project_heads(h, qkv_w["wq"], cfg.num_heads, hd)
        wk = _dedupe_kv(qkv_w["wk"], geom)
        wv = _dedupe_kv(qkv_w["wv"], geom)
        k = _project_heads(h, wk, cfg.num_kv_heads, hd)
        v = _project_heads(h, wv, cfg.num_kv_heads, hd)

    if ctx.decode:
        pos = ctx.pos  # (B,) per-row decode positions
        q = apply_rope(q, pos[:, None], cfg.rope_theta)
        k = apply_rope(k, pos[:, None], cfg.rope_theta)
        out, new_state = _attn_decode_cache(q, k, v, sig, ctx, lstate)
    else:
        positions = ctx.q_offset + jnp.arange(s)
        posb = jnp.broadcast_to(positions, (b, s))
        q = apply_rope(q, posb, cfg.rope_theta)
        k = apply_rope(k, posb, cfg.rope_theta)
        if xp.seq_axes:
            ax = _axes_arg(xp.seq_axes)
            k = lax.all_gather(k, ax, axis=1, tiled=True)
            v = lax.all_gather(v, ax, axis=1, tiled=True)
        out = attn_lib.mha_prefill(
            q, k, v, window=sig.window, q_offset=ctx.q_offset,
            block_causal=ctx.xp.block_causal,
        )
        if ctx.capture_len:
            new_state = _capture_kv_state(k, v, sig, ctx)
        else:
            new_state = lstate
    if isinstance(out_w, prefetch.SplitBank):
        return _attn_split_out(out, out_w, ctx), new_state
    a = out_w["wo"].shape[0]
    out = out.reshape(b, out.shape[1], a, -1)
    y = jnp.einsum("bsag,agd->bsd", out, _w(out_w["wo"], out))
    return y, new_state


def _attn_decode_cache(q, k_new, v_new, sig: LayerSig, ctx: Ctx, lstate):
    """Write each row's new token into the (possibly seq-sharded, possibly
    ring) KV cache, then partial-attend + psum-LSE combine across shards.

    Positions are per-row (B,) so continuously-batched rows can sit at
    different depths; the write is a one-hot masked select per row."""
    xp = ctx.xp
    pos = ctx.pos  # (B,)
    l_local = lstate["k"].shape[1]
    n_sh = xp.seq_shards if xp.seq_axes else 1
    l_total = l_local * n_sh
    slot = pos % l_total                      # (B,)
    owner = slot // l_local
    li = slot % l_local
    mine = _shard_index(xp, xp.seq_axes) if xp.seq_axes else jnp.int32(0)

    write = (owner == mine)                   # (B,)
    onehot = (
        jnp.arange(l_local)[None, :] == li[:, None]
    ) & write[:, None]                        # (B, L_local)
    ck = jnp.where(
        onehot[:, :, None, None],
        k_new.astype(lstate["k"].dtype),      # (B,1,Kh,hd) broadcasts over L
        lstate["k"],
    )
    cv = jnp.where(
        onehot[:, :, None, None],
        v_new.astype(lstate["v"].dtype),
        lstate["v"],
    )
    sp = jnp.where(onehot, pos[:, None], lstate["slot_pos"])
    new_state = {"k": ck, "v": cv, "slot_pos": sp}

    out, lse = attn_lib.mha_decode_partial(
        q[:, 0],
        ck.astype(q.dtype),
        cv.astype(q.dtype),
        sp,
        pos,
        window=sig.window,
    )
    if xp.seq_axes:
        m = lax.pmax(lse, xp.seq_axes)
        w = jnp.exp(lse - m)
        num = lax.psum(out.astype(jnp.float32) * w[..., None], xp.seq_axes)
        den = lax.psum(w, xp.seq_axes)
        out = (num / jnp.maximum(den, 1e-30)[..., None]).astype(q.dtype)
    return out[:, None], new_state  # (B,1,H,hd)


def _capture_kv_state(k, v, sig: LayerSig, ctx: Ctx):
    """Turn prefill K/V into a ring-buffer decode state (the disaggregated
    ctx->gen KV transfer payload). Ring slot l holds the latest position
    p < S with p % L == l; slots that never filled stay empty (-1).

    Works under SEQUENCE SHARDING too: the prefill attention path
    all-gathers K/V over the seq axes before attending (``_attn_full``),
    so ``k``/``v`` here always carry the full global sequence — each rank
    simply keeps the ring slots it owns under the decode cache layout
    (``slot // l_local == mine``, matching ``_attn_decode_cache``)."""
    xp = ctx.xp
    b, s = k.shape[0], k.shape[1]
    length = min(sig.window, ctx.capture_len) if sig.window else ctx.capture_len
    n_sh = xp.seq_shards if xp.seq_axes else 1
    assert length % n_sh == 0, (
        f"KV capture ring length {length} "
        f"({'window-limited, window=' + str(sig.window) if sig.window and sig.window < ctx.capture_len else 'capture_len'}) "
        f"must divide over the {n_sh} sequence shards — pick a "
        "cache_len (and, for local-attention layers, a window) divisible "
        "by the seq-shard count, or prefill on an unsharded-sequence mesh"
    )
    l_local = length // n_sh
    mine = _shard_index(xp, xp.seq_axes) if xp.seq_axes else jnp.int32(0)
    l_idx = mine * l_local + jnp.arange(l_local)  # global slots owned here
    pos_l = (s - 1) - ((s - 1 - l_idx) % length)
    valid = pos_l >= 0
    take = jnp.clip(pos_l, 0, s - 1)
    ck = jnp.take(k, take, axis=1) * valid[None, :, None, None].astype(k.dtype)
    cv = jnp.take(v, take, axis=1) * valid[None, :, None, None].astype(v.dtype)
    slot_pos = jnp.broadcast_to(
        jnp.where(valid, pos_l, -1)[None, :], (b, l_local)
    ).astype(jnp.int32)
    return {"k": ck, "v": cv, "slot_pos": slot_pos}


def _qgather_ok(geom: Geometry, xp: ExecutionPlan) -> bool:
    return (
        xp.phase == "decode"
        and getattr(xp, "decode_attn", "gather") == "qgather"
        and geom.attn_axes == ("model",)
        and AXIS_MODEL not in xp.batch_axes
        and geom.model_size > 1
    )


def _attn_decode_qgather(h, aw, sig: LayerSig, ctx: Ctx, lstate):
    """Beyond-paper decode attention for sharded attention weights: keep
    weights LOCAL and all-gather the projected q/k/v activations instead
    (B x 1 x dim — a few hundred KB vs hundreds of MB of weights/layer).
    Requires tokens replicated over "model" (decode with seq-sharded KV).
    """
    cfg, geom, xp = ctx.cfg, ctx.geom, ctx.xp
    b = h.shape[0]
    hd = cfg.head_dim
    g = geom.attn_shards
    ks = geom.kv_shard
    # local feature slices: (B, 1, qd/g) and (B, 1, kvd/ks)
    q_l = jnp.einsum("bsd,adh->bsh", h, _w(aw["wq"], h))
    k_l = jnp.einsum("bsd,adh->bsh", h, _w(aw["wk"], h))
    v_l = jnp.einsum("bsd,adh->bsh", h, _w(aw["wv"], h))
    q = lax.all_gather(q_l, AXIS_MODEL, axis=2, tiled=True)  # (B,1,qd)
    kg = lax.all_gather(k_l, AXIS_MODEL, axis=2, tiled=True)
    vg = lax.all_gather(v_l, AXIS_MODEL, axis=2, tiled=True)
    q = q.reshape(b, 1, cfg.num_heads, hd)
    # kv gathered rank-major contains g/ks duplicates per group: dedupe
    dup = g // ks
    kvd_l = cfg.kv_dim // ks
    k = kg.reshape(b, 1, g, kvd_l)[:, :, ::dup].reshape(
        b, 1, cfg.num_kv_heads, hd
    )
    v = vg.reshape(b, 1, g, kvd_l)[:, :, ::dup].reshape(
        b, 1, cfg.num_kv_heads, hd
    )
    pos = ctx.pos
    q = apply_rope(q, pos[:, None], cfg.rope_theta)
    k = apply_rope(k, pos[:, None], cfg.rope_theta)
    out, new_state = _attn_decode_cache(q, k, v, sig, ctx, lstate)
    # out (B,1,H,hd) replicated over "model" (LSE combine psums it);
    # slice my flat-q features and apply the local wo shard + psum
    qd_l = cfg.q_dim // g
    flat = out.reshape(b, 1, cfg.q_dim)
    my = lax.dynamic_slice_in_dim(
        flat, lax.axis_index(AXIS_MODEL) * qd_l, qd_l, axis=2
    )
    y = jnp.einsum("bsg,agd->bsd", my, _w(aw["wo"], my))
    return lax.psum(y, AXIS_MODEL), new_state


def _attn_tp(h, aw, sig: LayerSig, ctx: Ctx):
    """DEP tensor-parallel attention: gather tokens over "model", compute
    the local head slice, reduce-scatter back — the synchronizing
    activation collectives DWDP removes."""
    cfg, xp = ctx.cfg, ctx.xp
    hd = cfg.head_dim
    g = ctx.geom.attn_shards
    token_axis = 0 if AXIS_MODEL in xp.batch_axes else 1
    hg = lax.all_gather(h, AXIS_MODEL, axis=token_axis, tiled=True)
    b, s, _ = hg.shape
    heads_l = cfg.num_heads // g
    q = _project_heads(hg, aw["wq"], heads_l, hd)
    kv_l = cfg.num_kv_heads // ctx.geom.kv_shard
    k = _project_heads(hg, aw["wk"], kv_l, hd)
    v = _project_heads(hg, aw["wv"], kv_l, hd)
    if token_axis == 1:
        positions = jnp.arange(s)
    else:
        positions = ctx.q_offset + jnp.arange(s)
    posb = jnp.broadcast_to(positions, (b, s))
    q = apply_rope(q, posb, cfg.rope_theta)
    k = apply_rope(k, posb, cfg.rope_theta)
    out = attn_lib.mha_prefill(q, k, v, window=sig.window)
    out = out.reshape(b, s, 1, heads_l * hd)
    y = jnp.einsum("bsag,agd->bsd", out, aw["wo"])
    return lax.psum_scatter(
        y, AXIS_MODEL, scatter_dimension=token_axis, tiled=True
    )


# ==========================================================================
# FFN (dense "virtual experts") + MoE.
# ==========================================================================
def _ffn_full(x2d, fp):
    """x2d: (T,D); fp stacked (S,D,F/S) full content."""
    h = jax.nn.silu(
        jnp.einsum("td,sdf->tsf", x2d, _w(fp["w_gate"], x2d))
    ) * jnp.einsum("td,sdf->tsf", x2d, _w(fp["w_up"], x2d))
    return jnp.einsum("tsf,sfd->td", h, _w(fp["w_down"], x2d))


def _ffn_apply(x2d, fp, ctx: Ctx, gathered=None):
    geom, xp = ctx.geom, ctx.xp
    if not geom.ffn_axes:
        return _ffn_full(x2d, fp)
    if xp.mode in ("dwdp", "hybrid") or not _dep_tp_ok(geom, xp, "ffn"):
        assert gathered is not None, "DWDP FFN weights must be prefetched"
        if isinstance(gathered, prefetch.SplitBank):
            # split layout: y = sum_s swiglu_s(x) over (resident, remote)
            # slice banks — the stacked-FFN sum is order-independent, so
            # the rotated bank order needs no fix-up and no merged
            # (S, D, F/S) buffer ever exists.
            lo, re = gathered.local, gathered.remote
            return split_gemm_lib.split_dense_ffn(
                x2d,
                lo["w_gate"], lo["w_up"], lo["w_down"],
                re["w_gate"], re["w_up"], re["w_down"],
                impl=split_gemm_lib.default_dense_impl(xp.phase),
            )
        return _ffn_full(x2d, gathered)
    # DEP TP over "model"
    if ctx.decode:
        # tokens replicated over "model": partial-F compute + psum
        h = jax.nn.silu(x2d @ _w(fp["w_gate"][0], x2d)) * (
            x2d @ _w(fp["w_up"][0], x2d)
        )
        y = h @ _w(fp["w_down"][0], x2d)
        return lax.psum(y, AXIS_MODEL)
    # sequence-parallel TP: gather tokens, compute local F slice, scatter
    xg = lax.all_gather(x2d, AXIS_MODEL, axis=0, tiled=True)
    h = jax.nn.silu(xg @ _w(fp["w_gate"][0], xg)) * (xg @ _w(fp["w_up"][0], xg))
    y = h @ _w(fp["w_down"][0], xg)
    return lax.psum_scatter(y, AXIS_MODEL, scatter_dimension=0, tiled=True)


def _expert_collective(geom: Geometry, xp: ExecutionPlan):
    """(axis_arg, axis_index_groups) for DEP all-to-all within subgroups."""
    pl = geom.moe_placement
    assert pl is not None
    axes = geom.expert_axes
    if pl.redundancy == 1:
        return _axes_arg(axes), None
    ms = xp.mesh_sizes[axes[-1]]
    g = pl.subgroup_size
    if g <= ms and ms % g == 0:
        groups = [
            [j * g + i for i in range(g)] for j in range(ms // g)
        ]
        return axes[-1], groups
    return _axes_arg(axes), pl.axis_index_groups()


def _rotate_moe(xe, experts, ctx: Ctx):
    """Ring-rotate expert shards through ranks, computing each resident
    shard's contribution. Memory: 2x the local shard instead of the full
    layer (the TPU adaptation of on-demand expert fetch; DESIGN.md §2)."""
    geom, xp = ctx.geom, ctx.xp
    pl = geom.moe_placement
    assert pl is not None
    g = pl.subgroup_size
    local = pl.local_count
    ye0 = jnp.zeros(xe.shape, xe.dtype)
    if g == 1:
        return _grouped_into(xe, ye0, experts, jnp.int32(0), local)
    axes = geom.expert_axes
    ms = xp.mesh_sizes[axes[-1]]

    if g <= ms:
        ax = axes[-1]
        p = lax.axis_index(ax) % g
        pairs = [
            (int(b0 + i), int(b0 + (i + 1) % g))
            for b0 in range(0, ms, g)
            for i in range(g)
        ]

        def body(carry, t):
            cur, ye = carry
            src = (p - t) % g
            ye = _grouped_into(xe, ye, cur, src * local, local)
            cur = jax.tree.map(lambda w: lax.ppermute(w, ax, pairs), cur)
            return (cur, ye), None

        # g-1 permuted steps + one final compute without the realignment
        # permute: total traffic (g-1)/g of the layer set, so redundant
        # placement (smaller g) genuinely reduces wire bytes (paper §2).
        (cur, ye), _ = lax.scan(body, (experts, ye0), jnp.arange(g - 1))
        src_last = (p - (g - 1)) % g
        ye = _grouped_into(xe, ye, cur, src_last * local, local)
        return ye

    # nested: inner ring over "model", outer ring over "data" rows
    assert g % ms == 0 and len(axes) == 2
    dp = g // ms
    d_ax, m_ax = axes
    d_size = xp.mesh_sizes[d_ax]
    dc = lax.axis_index(d_ax) % dp
    m = lax.axis_index(m_ax)
    inner_pairs = [(i, (i + 1) % ms) for i in range(ms)]
    outer_pairs = [
        (int(b0 + i), int(b0 + (i + 1) % dp))
        for b0 in range(0, d_size, dp)
        for i in range(dp)
    ]

    def outer(carry, o):
        cur, ye = carry

        def inner(c2, i):
            cur2, ye2 = c2
            src = ((dc - o) % dp) * ms + ((m - i) % ms)
            ye2 = _grouped_into(xe, ye2, cur2, src * local, local)
            cur2 = jax.tree.map(
                lambda w: lax.ppermute(w, m_ax, inner_pairs), cur2
            )
            return (cur2, ye2), None

        (cur, ye), _ = lax.scan(inner, (cur, ye), jnp.arange(ms))
        cur = jax.tree.map(lambda w: lax.ppermute(w, d_ax, outer_pairs), cur)
        return (cur, ye), None

    (_, ye), _ = lax.scan(outer, (experts, ye0), jnp.arange(dp))
    return ye


def _grouped_into(xe, ye, experts, start, count):
    xe_t = lax.dynamic_slice_in_dim(xe, start, count, axis=0)
    ye_t = moe_lib.grouped_ffn(
        xe_t, experts["w_gate"], experts["w_up"], experts["w_down"]
    )
    return lax.dynamic_update_slice_in_dim(ye, ye_t, start, axis=0)


def _rolled_dispatch(d, roll, e_pad: int, capacity: int):
    """Rotate the dispatch's expert coordinate by ``-roll`` (mod e_pad) so
    the caller's resident experts occupy positions [0, local) — the order
    the split banks arrive in (prefetch.gather_remote_shards). Only
    ``flat_slot`` moves; gates / combine weights are order-independent."""
    exp = d.flat_slot // capacity
    slot = d.flat_slot - exp * capacity
    exp = (exp - roll) % e_pad
    return d._replace(flat_slot=exp * capacity + slot)


def _moe_demand_apply(x2d, experts, d, cap: int, ctx: Ctx,
                      spec_bank=None, pred=None):
    """Route-before-gather MoE execution (``fetch="demand"`` and the
    ``fetch="predictive"`` decode engine).

    The routing decision ``d`` already exists — this is the inverted
    layer order — so the activated-expert bitmap is exact, not a
    prediction. Round 1 (index exchange) always runs: it is a few
    hundred bytes and produces the axis-agreed overflow flag that picks
    the branch. Only the taken branch's payload permutes execute:

    - demand: fetch the activated remote experts compacted to the
      per-peer budget, remap the dispatch's expert coordinate through
      ``fetched_ids`` (resident experts at [0, local) in storage order,
      fetched rows after them — index arithmetic only, the demand
      analogue of the PR 1 rotation roll), and run the
      validity-predicated demand kernel over the compact
      ``(local + fetched)`` bank. No buffer wider than that exists.
    - overflow fallback: the PR 1 split path verbatim (full remote bank,
      rolled dispatch) — exact for any routing, so correctness never
      depends on the budget estimate.

    Predictive decode (``spec_bank``/``pred`` given) refines the demand
    round into a latency engine: the wanted set is first served from the
    cross-step residency cache (``pred.cache*`` — rows fetched on
    earlier steps, bit-identical to re-fetching) and the layer-ahead
    SPECULATIVE round's bank (``spec_bank``, fetched under the previous
    layer's compute window); only the miss set rides the post-routing
    CORRECTION round (``plan_demand_fetch(exclude_ids=...)`` — the same
    bitmap/ascending-id contract over the already-subtracted bitmap).
    The kernel consumes the concatenated (cache | speculative |
    correction) rows as one fetched bank through the same ``fetched_ids``
    remap, so the compute is bitwise-identical to the plain demand and
    all-fetch paths for ANY predictor quality and ANY cache budget; a
    correction overflow falls back to the full gather exactly as demand
    does. The predictor (prev bitmap + EMA) updates branch-independently;
    the cache inserts this step's fetched rows, evicting by EMA hotness.
    """
    cfg, geom, xp = ctx.cfg, ctx.geom, ctx.xp
    pl = geom.moe_placement
    assert pl is not None
    axis = geom.expert_axes[0]
    g, local = pl.subgroup_size, pl.local_count
    e_pad = pl.num_padded
    t = x2d.shape[0]
    pol = xp.policy("moe_experts", ctx.group)
    budget = resolve_demand_budget(cfg, geom, xp, ctx.group)
    p = lax.axis_index(axis) % g
    predictive = pred is not None
    # pallas_call has no VJP; the jnp formulation (still merge-free)
    # carries the ZeRO-style train gathers
    impl = "jnp" if xp.phase == "train" else "pallas"

    # payload validation (fault tolerance): when the plan validates,
    # the source-rank checksum table rides the (tiny) metadata round and
    # every arrived/cached row is re-checksummed — mismatches are masked
    # invalid so they flow into the correction round / axis-agreed
    # full-gather fallback, keeping outputs bitwise-exact under faults.
    all_axes = tuple(xp.mesh_sizes)
    validate = xp.validated
    inj = _fault_injector(ctx, axis)
    table = prefetch.checksum_table(experts, axis, pl) if validate else None
    step_idx = _fault_step(ctx) if validate else None
    n_ranks = math.prod(xp.mesh_sizes.values())

    # activated-expert bitmap from the routing decision. Kept tokens
    # only: dropped tokens carry zero combine weight and dispatch zeroed
    # rows, so their experts need no fetch.
    wanted = (
        jnp.zeros((e_pad,), bool).at[d.top_experts.reshape(-1)].max(d.keep)
    )
    if predictive:
        assert spec_bank is not None
        sync_free = sync_free_active(cfg, geom, xp, ctx.group)
        sbudget = min(resolve_spec_budget(cfg, geom, xp, ctx.group), local)
        cbudget = min(budget, local)
        if sync_free:
            # mirrored views: leading dim = subgroup position. This
            # rank's own slots are the position-p rows.
            m_ema = pred.ema[0]
            m_cids, m_cvalid = pred.cache_ids[0], pred.cache_valid[0]
            cache_ids = lax.dynamic_index_in_dim(
                m_cids, p, 0, keepdims=False
            )
            cache_valid = lax.dynamic_index_in_dim(
                m_cvalid, p, 0, keepdims=False
            )
        else:
            ema = pred.ema[0]
            cache_ids, cache_valid = pred.cache_ids[0], pred.cache_valid[0]
        cache_w = jax.tree.map(lambda w: w[0], pred.cache)
        n_cache = cache_ids.shape[0]
        cache_tamper = jnp.zeros((n_cache,), bool)
        if inj is not None and n_cache:
            # residency-cache corruption: rows rot in place between steps
            cache_tamper = inj.cache_mask(
                inj.site_key("cache", step_idx), n_cache
            )
            cache_w = inj.tamper_rows(
                cache_w, jnp.zeros((n_cache,), bool), cache_tamper
            )
        if sync_free:
            # mirrored-schedule divergence cross-check: every rank
            # re-derives the speculative schedule the pipeline gather
            # used (same pred, same step => identical array) and psums a
            # scalar digest over the subgroup. Any mismatch means some
            # rank's mirror drifted — its speculative payload rows are
            # mislabeled — so the spec bank is discarded everywhere and
            # the step takes the exact full-gather fallback. The digest
            # runs UNCONDITIONALLY (it is the mode's consistency
            # contract), validation on or off.
            masks = _mirror_spec_masks(ctx, pred, pl, sbudget)
            dg = prefetch.schedule_digest(masks)
            tot = lax.psum(
                dg, axis, axis_index_groups=pl.axis_index_groups()
            )
            div_local = jnp.abs(g * dg - tot) > 0.5
            diverged_g = (
                lax.psum(div_local.astype(jnp.float32), all_axes) > 0
            )
        if validate:
            # verify cached + speculative rows BEFORE the exclusion set
            # is built: faulty rows fall out of "have", so the
            # correction round re-fetches them — the in-band repair.
            cache_valid_v, bad_cache = prefetch.verify_rows(
                cache_w, cache_ids, cache_valid, table
            )
            spec_valid_v, bad_spec = prefetch.verify_rows(
                spec_bank.fetched, spec_bank.fetched_ids, spec_bank.valid,
                table,
            )
        else:
            cache_valid_v, spec_valid_v = cache_valid, spec_bank.valid
        # under divergence the spec rows are untrusted on every rank
        # (branch-uniform: diverged_g is psum-agreed)
        spec_valid_eff = (
            spec_valid_v & ~diverged_g if sync_free else spec_valid_v
        )
        have_ids = jnp.concatenate([cache_ids, spec_bank.fetched_ids])
        have_valid = jnp.concatenate([cache_valid_v, spec_valid_eff])
        if sync_free:
            # correction round, sync-free form: the residual (miss)
            # bitmap all-gather is the mode's ONLY per-layer index
            # traffic — the senders need every requester's residual to
            # compact the payload, exactly the demand contract. The
            # routing/position signals that feed the mirrors are
            # returned to ``forward_decode`` instead (PredictState.
            # routed), which unions them across layers and runs ONE
            # per-step mirror fold after the stack.
            residual = wanted & ~prefetch.exclude_bitmap(
                e_pad, have_ids, have_valid
            )
            k_top = d.top_experts.shape[-1]
            routed = prefetch.routed_bitmaps(
                jnp.where(
                    d.keep.reshape(-1, k_top), d.top_experts, e_pad
                ),
                e_pad,
            )
            resid_all = lax.all_gather(
                residual, axis, axis_index_groups=pl.axis_index_groups()
            )
            corr_ids, corr_valid, ovf_raw = prefetch.plan_from_bitmap(
                residual, p, g, local, cbudget
            )
            overflow = (
                lax.psum(ovf_raw.astype(jnp.float32), all_axes) > 0
            )
            plan = prefetch.DemandPlan(
                masks=resid_all, fetched_ids=corr_ids, valid=corr_valid,
                overflow=overflow,
            )
        else:
            plan = prefetch.plan_demand_fetch(
                wanted, axis, pl, budget=budget,
                agree_axes=tuple(xp.mesh_sizes),
                exclude_ids=have_ids, exclude_valid=have_valid,
            )
            # predictor update — pure index arithmetic, branch-independent
            new_prev = wanted
            new_ema = (
                prefetch.EMA_DECAY * ema
                + (1.0 - prefetch.EMA_DECAY) * wanted.astype(jnp.float32)
            )
        # hit/miss accounting (rows of the wanted REMOTE set), split by
        # serving tier: residency cache first, speculative round for the
        # rest — the tiers are id-disjoint by the exclusion chain, the
        # bitmap intersection just makes the split robust to overlap
        local_mask = jnp.zeros((e_pad,), bool).at[
            p * local + jnp.arange(local)
        ].set(True)
        wanted_remote = wanted & ~local_mask
        spec_map = prefetch.exclude_bitmap(
            e_pad, spec_bank.fetched_ids, spec_valid_eff
        )
        cache_map = prefetch.exclude_bitmap(e_pad, cache_ids, cache_valid_v)
        n_want = jnp.sum(wanted_remote).astype(jnp.float32)
        n_cache_hit = jnp.sum(wanted_remote & cache_map).astype(jnp.float32)
        n_spec = jnp.sum(
            wanted_remote & spec_map & ~cache_map
        ).astype(jnp.float32)
        n_pred = jnp.sum(spec_bank.valid).astype(jnp.float32)
    else:
        plan = prefetch.plan_demand_fetch(
            wanted, axis, pl, budget=budget, agree_axes=tuple(xp.mesh_sizes)
        )

    def _remap_and_run(d, fetched, ids, valid):
        # expert-id -> compact-bank position. Experts neither resident
        # nor fetched receive only zero-weight traffic (every kept
        # token's expert is in the bitmap), so they may map anywhere
        # in range; position 0 keeps the scatter dense.
        rows = valid.shape[0]
        pos = jnp.zeros((e_pad,), jnp.int32)
        pos = pos.at[p * local + jnp.arange(local)].set(
            jnp.arange(local, dtype=jnp.int32)
        )
        pos = pos.at[jnp.where(valid, ids, e_pad)].set(
            local + jnp.arange(rows, dtype=jnp.int32), mode="drop"
        )
        exp = d.flat_slot // cap
        slot = d.flat_slot - exp * cap
        d2 = d._replace(flat_slot=pos[exp] * cap + slot)
        xe = moe_lib.dispatch_tokens(x2d, d2, local + rows, cap)
        ye = split_gemm_lib.split_swiglu_demand(
            xe,
            experts["w_gate"], experts["w_up"], experts["w_down"],
            fetched["w_gate"], fetched["w_up"], fetched["w_down"],
            valid,
            impl=impl,
        )
        return moe_lib.combine_tokens(ye, d2, t)

    def full_path(experts, d):
        lo, re = prefetch.gather_remote_shards(
            experts, axis, pl, mode=pol.transport, num_slices=pol.num_slices
        )
        d2 = _rolled_dispatch(d, p * local, e_pad, cap)
        xe = moe_lib.dispatch_tokens(x2d, d2, e_pad, cap)
        ye = split_gemm_lib.split_swiglu(
            xe,
            lo["w_gate"], lo["w_up"], lo["w_down"],
            re["w_gate"], re["w_up"], re["w_down"],
            impl=impl,
        )
        return moe_lib.combine_tokens(ye, d2, t)

    if not predictive:
        if not validate:
            # plain demand: both branches of the cond carry their own
            # payload collectives — only the taken branch's permutes
            # execute.
            def demand_branch(experts, d):
                bank = prefetch.gather_demand_payload(
                    experts, plan, axis, pl, budget=budget,
                    mode=pol.transport, num_slices=pol.num_slices,
                )
                return _remap_and_run(
                    d, bank.fetched, plan.fetched_ids, plan.valid
                )

            y = lax.cond(
                plan.overflow, full_path, demand_branch, experts, d
            )
            return y, None, None
        # validated demand: the payload round + compact kernel run
        # UNCONDITIONALLY and the cond only swaps in the full-gather
        # result — the hoisted pattern the predictive path below uses
        # (see its backend-miscompile note: a fetched bank must never
        # feed the kernel from inside a cond branch). The repair here
        # IS the fallback: any checksum-failed row raises the
        # axis-agreed flag and every rank takes the exact full gather.
        fault_key = (
            inj.site_key("corr", step_idx) if inj is not None else None
        )
        bank = prefetch.gather_demand_payload(
            experts, plan, axis, pl, budget=budget, mode=pol.transport,
            num_slices=pol.num_slices, injector=inj, fault_key=fault_key,
        )
        bank_valid_v, bad_bank = prefetch.verify_rows(
            bank.fetched, bank.fetched_ids, bank.valid, table
        )
        n_bad = lax.psum(jnp.sum(bad_bank.astype(jnp.float32)), all_axes)
        fault_fb = n_bad > 0
        fallback = plan.overflow | fault_fb
        y_compact = _remap_and_run(
            d, bank.fetched, bank.fetched_ids, bank_valid_v
        )
        y = lax.cond(
            fallback, full_path, lambda experts, d: y_compact, experts, d
        )
        inj3 = (
            _injected_counts(inj, fault_key, budget, plan.valid)
            if inj is not None else jnp.zeros((3,), jnp.float32)
        )
        fstats = jnp.concatenate([
            inj3,
            jnp.zeros((1,), jnp.float32),  # injected_cache (no cache)
            jnp.sum(bad_bank.astype(jnp.float32))[None],
            # globally agreed flag: contribute 1/n_ranks so the final
            # psum over every mesh axis reports it once
            (fault_fb.astype(jnp.float32) / n_ranks)[None],
            jnp.zeros((1,), jnp.float32),  # mirror_divergence (sync_free)
            _per_src_detected(bad_bank, min(budget, local), g, p),
        ])
        return y, None, fstats

    # Predictive: the correction round + compact kernel run
    # UNCONDITIONALLY (the modeled cost anyway — and the cache wants the
    # fetched rows even on fallback); the cond only swaps in the exact
    # full-gather result when the miss set overflowed the correction
    # budget. Keeping the compact compute OUT of the cond also sidesteps
    # a backend miscompile observed when a branch closure feeds the
    # speculative bank into the kernel (the cond's hoisted-operand
    # lowering returned wrong values on some ranks).
    corr_key = inj.site_key("corr", step_idx) if inj is not None else None
    bank = prefetch.gather_demand_payload(
        experts, plan, axis, pl, budget=budget, mode=pol.transport,
        num_slices=pol.num_slices, injector=inj, fault_key=corr_key,
    )
    if validate:
        # cached/speculative faults were already repaired above (they
        # fell out of the exclusion set, so the correction round
        # re-fetched them); a fault in the correction bank itself has no
        # further round to fall to, so it raises the same axis-agreed
        # fallback flag the overflow path uses.
        bank_valid_v, bad_corr = prefetch.verify_rows(
            bank.fetched, bank.fetched_ids, bank.valid, table
        )
        n_bad_corr = lax.psum(
            jnp.sum(bad_corr.astype(jnp.float32)), all_axes
        )
        fault_fb = n_bad_corr > 0
        fallback = plan.overflow | fault_fb
    else:
        fault_fb = jnp.bool_(False)
        bank_valid_v = bank.valid
        fallback = plan.overflow
    if sync_free:
        # a drifted mirror forces the exact path too (the spec bank was
        # already masked out above; this swaps in the full gather)
        fallback = fallback | diverged_g
    cat = lambda c, s, b: jnp.concatenate([c, s, b], axis=0)
    fe_all = jax.tree.map(cat, cache_w, spec_bank.fetched, bank.fetched)
    ids_all = cat(cache_ids, spec_bank.fetched_ids, bank.fetched_ids)
    # verified validity throughout: checksum-failed (or divergence-
    # voided) rows never map into the compact bank (a re-fetched
    # duplicate id wins the remap) and score -inf in the cache insert
    # below (corrupt rows are evicted, not re-cached)
    valid_all = cat(cache_valid_v, spec_valid_eff, bank_valid_v)
    y_compact = _remap_and_run(d, fe_all, ids_all, valid_all)
    y = lax.cond(
        fallback,
        full_path,
        lambda experts, d: y_compact,
        experts, d,
    )
    # ---- residency-cache insert: keep the EMA-hottest rows of (current
    # cache | this step's fetches); ids stay unique because both fetch
    # rounds excluded the cache (and each other). Branch-independent:
    # fetched rows are bit-exact expert copies even on the fallback. ----
    if sync_free:
        # mirrored replay: every rank replays EVERY position's cache
        # bookkeeping from exchanged/mirrored inputs only — the derived
        # (masks, resid_all) schedules plus the STRUCTURAL (unverified)
        # carried validity, never the local checksum results, so all
        # mirrors agree bit-for-bit. Eviction scores read the PRE-step
        # mirror EMA (``m_ema`` — the fold moved to the per-step site in
        # ``forward_decode``, after this layer runs); it is mirror-shared,
        # so replay determinism is unchanged. A corrupt row that stays
        # cached is caught again at next step's consume-time verify and
        # re-fetched through the correction round — still exact, one
        # step later.
        def replay(q, resid_q, ema_q, cids_q, cvalid_q, mask_q):
            s_ids, s_valid, _ = prefetch.plan_from_bitmap(
                mask_q, q, g, local, sbudget
            )
            c_ids, c_valid, _ = prefetch.plan_from_bitmap(
                resid_q, q, g, local, cbudget
            )
            ids_q = jnp.concatenate([cids_q, s_ids, c_ids])
            valid_q = jnp.concatenate(
                [cvalid_q, s_valid & ~diverged_g, c_valid]
            )
            # per-peer exclusion: an excluded peer's rows are never
            # cached (they would go stale while the peer is distrusted)
            for peer in xp.exclude_peers:
                valid_q = valid_q & (ids_q // local != peer % g)
            score = jnp.where(valid_q, ema_q[ids_q], -jnp.inf)
            order_q = jnp.argsort(-score)[:n_cache]
            return ids_q[order_q], valid_q[order_q], order_q

        rep_ids, rep_valid, rep_order = jax.vmap(replay)(
            jnp.arange(g), resid_all, m_ema, m_cids, m_cvalid, masks
        )
        nc_ids = lax.dynamic_index_in_dim(rep_ids, p, 0, keepdims=False)
        nc_valid = lax.dynamic_index_in_dim(
            rep_valid, p, 0, keepdims=False
        )
        order = lax.dynamic_index_in_dim(rep_order, p, 0, keepdims=False)
    else:
        score = jnp.where(valid_all, new_ema[ids_all], -jnp.inf)
        order = jnp.argsort(-score)[:n_cache]
        nc_ids = ids_all[order]
        nc_valid = valid_all[order]
    nc_w = jax.tree.map(lambda w: jnp.take(w, order, axis=0), fe_all)
    n_new = jnp.sum(spec_bank.valid) + jnp.sum(bank.valid)
    evicted = jnp.maximum(
        jnp.sum(cache_valid) + n_new - jnp.sum(nc_valid), 0
    ).astype(jnp.float32)
    # honest counters on the overflow fallback: the full gather served
    # EVERY wanted remote row over the wire, so nothing counts as a hit
    # and the whole wanted set counts as missed (the cache insert still
    # runs, so evictions report either way)
    zero = jnp.float32(0.0)
    stats = jnp.where(
        fallback,
        jnp.stack([n_pred, zero, zero, n_want, evicted]),
        jnp.stack(
            [n_pred, n_spec, n_cache_hit,
             jnp.sum(bank.valid).astype(jnp.float32), evicted]
        ),
    )
    if sync_free:
        # predictor fields pass through UNCHANGED — the per-step mirror
        # fold in ``forward_decode`` overwrites them once for every
        # sync-free layer from the one exchanged mirror payload; this
        # layer only contributes its routed bitmaps to that fold.
        new_pred = prefetch.PredictState(
            prev=pred.prev,
            ema=pred.ema,
            cache_ids=rep_ids[None],
            cache_valid=rep_valid[None],
            cache=jax.tree.map(lambda w: w[None], nc_w),
            stats=stats[None],
            aff=pred.aff,
            posb=pred.posb,
            sig=pred.sig,
            sigw=pred.sigw,
            routed=routed[None],
        )
    else:
        new_pred = prefetch.PredictState(
            prev=new_prev[None],
            ema=new_ema[None],
            cache_ids=nc_ids[None],
            cache_valid=nc_valid[None],
            cache=jax.tree.map(lambda w: w[None], nc_w),
            stats=stats[None],
        )
    div_contrib = (
        diverged_g.astype(jnp.float32) / n_ranks if sync_free
        else jnp.float32(0.0)
    )
    if not validate:
        if sync_free:
            # unvalidated sync-free still reports: the divergence digest
            # ran, and the HealthMonitor needs its counter
            fstats = jnp.concatenate([
                jnp.zeros((faults.FAULT_STAT_BASE - 1,), jnp.float32),
                div_contrib[None],
                jnp.zeros((g,), jnp.float32),
            ])
            return y, new_pred, fstats
        return y, new_pred, None
    if inj is not None:
        inj3 = _injected_counts(
            inj, inj.site_key("spec", step_idx), sbudget, spec_bank.valid
        ) + _injected_counts(inj, corr_key, budget, plan.valid)
        inj_cache = jnp.sum((cache_tamper & cache_valid).astype(jnp.float32))
    else:
        inj3 = jnp.zeros((3,), jnp.float32)
        inj_cache = jnp.float32(0.0)
    detected = (
        jnp.sum(bad_cache.astype(jnp.float32))
        + jnp.sum(bad_spec.astype(jnp.float32))
        + jnp.sum(bad_corr.astype(jnp.float32))
    )
    # per-subgroup-position attribution: payload rows by the peer-major
    # bank layout, cache rows by the position owning the expert id
    per_src = (
        _per_src_detected(bad_spec, sbudget, g, p)
        + _per_src_detected(bad_corr, cbudget, g, p)
        + jnp.zeros((g,), jnp.float32).at[cache_ids // local].add(
            bad_cache.astype(jnp.float32)
        )
    )
    fstats = jnp.concatenate([
        inj3,
        inj_cache[None],
        detected[None],
        # globally agreed flags: contribute 1/n_ranks so the final psum
        # over every mesh axis reports each once
        (fault_fb.astype(jnp.float32) / n_ranks)[None],
        div_contrib[None],
        per_src,
    ])
    return y, new_pred, fstats


def _moe_apply(x2d, mp, sig: LayerSig, ctx: Ctx, gathered: dict, rows: int,
               pred=None):
    cfg, geom, xp = ctx.cfg, ctx.geom, ctx.xp
    moe = cfg.moe
    pl = geom.moe_placement
    assert moe is not None and pl is not None
    t = x2d.shape[0]
    e_pad = pl.num_padded
    if xp.capacity_from == "global":
        # Layout-invariant capacity (ROADMAP decision): derive the slot
        # budget per ROW from the *global* per-row token count and
        # restrict capacity competition to the row. Rows never split
        # across ranks under batch sharding, so every mesh reshape of the
        # same global batch drops the identical token set. (Sequence
        # sharding splits rows; the per-rank slice then gets a ceil-
        # divided share — deterministic across batch reshapes, not across
        # seq-shard degree changes.)
        row_tokens = 1 if ctx.decode else xp.seq_len
        cap_row = moe_lib.capacity_for(
            row_tokens, moe.num_experts, moe.top_k, xp.capacity_factor
        )
        if not ctx.decode and xp.seq_shards > 1:
            cap_row = -(-cap_row // xp.seq_shards)
        cap = rows * cap_row
        d = moe_lib.route_topk_rows(
            x2d.reshape(rows, -1, x2d.shape[-1]), mp["router"], moe.top_k,
            cap_row, num_real=moe.num_experts,
        )
    else:
        cap = moe_lib.capacity_for(
            t, moe.num_experts, moe.top_k, xp.capacity_factor
        )
        d = moe_lib.route_topk(
            x2d, mp["router"], moe.top_k, cap, num_real=moe.num_experts
        )
    aux = moe_lib.load_balance_loss(d, e_pad)
    y = None
    new_pred = None
    fstats = None

    if xp.mode == "replicated" or pl.group_size == 1:
        xe = moe_lib.dispatch_tokens(x2d, d, e_pad, cap)
        ye = moe_lib.grouped_ffn(
            xe, mp["experts"]["w_gate"], mp["experts"]["w_up"],
            mp["experts"]["w_down"],
        )
    elif demand_fetch_active(cfg, geom, xp, ctx.group):
        # route-before-gather: the routing above used only the LOCAL
        # router weights, so the expert gather can now be demand-driven.
        # For plain demand, gather_set excluded this layer's expert bank
        # from the prefetch pipeline and the whole fetch happens here;
        # predictive decode layers instead receive the SPECULATIVE round's
        # compact DemandBank from the pipeline (fetched under the
        # previous layer's compute) and only the correction fetch happens
        # here, after routing.
        if predictive_fetch_active(cfg, geom, xp, ctx.group):
            spec = gathered.get("moe/experts")
            assert isinstance(spec, prefetch.DemandBank), (
                "predictive-active layers must prefetch the speculative "
                "demand bank"
            )
            y, new_pred, fstats = _moe_demand_apply(
                x2d, mp["experts"], d, cap, ctx, spec_bank=spec, pred=pred
            )
        else:
            assert "moe/experts" not in gathered, (
                "demand-active layers must not prefetch the expert bank"
            )
            y, _, fstats = _moe_demand_apply(x2d, mp["experts"], d, cap, ctx)
    elif moe_split_active(geom, xp, ctx.group):
        # §4.2 split fast path: tokens dispatch in rotated canonical order
        # (resident experts first), the fused kernel consumes the
        # SplitBank's (resident, remote) trees as two operands — the
        # merged (e_pad, D, F) buffer of the branch below never exists.
        bank = gathered.get("moe/experts")
        assert bank is not None, "split-mode expert bank must be prefetched"
        roll = (
            lax.axis_index(geom.expert_axes[0]) % pl.subgroup_size
        ) * pl.local_count
        d = _rolled_dispatch(d, roll, e_pad, cap)
        xe = moe_lib.dispatch_tokens(x2d, d, e_pad, cap)
        lo, re = bank.local, bank.remote
        ye = split_gemm_lib.split_swiglu(
            xe,
            lo["w_gate"], lo["w_up"], lo["w_down"],
            re["w_gate"], re["w_up"], re["w_down"],
            # pallas_call has no VJP; the jnp formulation (still merge-free)
            # carries the ZeRO-style train gathers
            impl="jnp" if xp.phase == "train" else "pallas",
        )
    elif xp.mode == "dwdp":
        xe = moe_lib.dispatch_tokens(x2d, d, e_pad, cap)
        if geom.moe_exec == "gather":
            full = gathered.get("moe/experts")
            assert full is not None, "gather-mode experts must be prefetched"
            ye = moe_lib.grouped_ffn(
                xe, full["w_gate"], full["w_up"], full["w_down"]
            )
        else:
            ye = _rotate_moe(xe, mp["experts"], ctx)
    else:  # dep / hybrid expert path: all-to-all dispatch/combine
        xe = moe_lib.dispatch_tokens(x2d, d, e_pad, cap)
        ax, groups = _expert_collective(geom, xp)
        xr = lax.all_to_all(
            xe, ax, split_axis=0, concat_axis=1, tiled=True,
            axis_index_groups=groups,
        )
        yr = moe_lib.grouped_ffn(
            xr, mp["experts"]["w_gate"], mp["experts"]["w_up"],
            mp["experts"]["w_down"],
        )
        ye = lax.all_to_all(
            yr, ax, split_axis=1, concat_axis=0, tiled=True,
            axis_index_groups=groups,
        )
    if y is None:
        y = moe_lib.combine_tokens(ye, d, t)
    if "shared" in mp:
        y = y + _ffn_apply(x2d, mp["shared"], ctx, gathered.get("moe/shared"))
    return y, aux, new_pred, fstats


# ==========================================================================
# Recurrent / xLSTM blocks (with RG-LRU cross-shard fix-up).
# ==========================================================================
def _rec_apply(h, rp, ctx: Ctx, lstate):
    xp = ctx.xp
    if ctx.decode or not xp.seq_axes:
        state = lstate if ctx.decode else None
        out, new_state = recurrent_block(h, rp, state)
        keep = ctx.decode or ctx.capture_len
        return out, (new_state if keep else lstate)

    # seq-sharded prefill/train: linear-recurrence fix-up (DESIGN.md §2)
    assert len(xp.seq_axes) == 1, "RG-LRU seq sharding is single-axis"
    ax = xp.seq_axes[0]
    g = xp.mesh_sizes[ax]
    b = h.shape[0]
    branch = h @ rp["w_x"]
    kw = rp["conv_w"].shape[0]
    halo = lax.ppermute(
        branch[:, -(kw - 1):], ax, [(i, i + 1) for i in range(g - 1)]
    )  # shard 0 receives zeros = fresh conv state
    branch, _ = causal_conv1d(branch, rp["conv_w"], halo.astype(branch.dtype))
    A, h_loc = rglru_parts(branch, rp["w_r"], rp["w_i"], rp["a_param"])
    a_last, h_last = A[:, -1], h_loc[:, -1]
    ag = lax.all_gather(a_last, ax)   # (G,B,D)
    hg = lax.all_gather(h_last, ax)
    h0 = jnp.zeros_like(h_last)
    prefixes = [h0]
    for s_i in range(g - 1):
        h0 = ag[s_i] * h0 + hg[s_i]
        prefixes.append(h0)
    h0_mine = jnp.take(
        jnp.stack(prefixes), lax.axis_index(ax), axis=0
    )
    hfix = (h_loc + A * h0_mine[:, None]).astype(h.dtype)
    gate = jax.nn.gelu(h @ rp["w_gate"], approximate=True)
    out = (hfix * gate) @ rp["w_o"]
    return out, lstate


def _cell_apply(h, cp, sig: LayerSig, ctx: Ctx, lstate):
    state = lstate if ctx.decode else None
    fn = mlstm_block if sig.kind == BlockKind.MLSTM else slstm_block
    out, new_state = fn(h, cp, state)
    keep = ctx.decode or ctx.capture_len
    return out, (new_state if keep else lstate)


# ==========================================================================
# One layer.
# ==========================================================================
def apply_layer(x, lp, sig: LayerSig, ctx: Ctx, lstate, gathered: dict,
                pred=None):
    cfg = ctx.cfg
    eps = cfg.norm_eps
    h = rms_norm(x, lp["norm1"], eps)
    aux = jnp.float32(0.0)
    new_pred = None
    fstats = None
    if sig.kind in (BlockKind.GLOBAL_ATTN, BlockKind.LOCAL_ATTN):
        aw = gathered.get("attn", lp["attn"])
        if "attn" in gathered or not ctx.geom.attn_axes:
            out, lstate = _attn_full(h, aw, sig, ctx, lstate)
        elif _qgather_ok(ctx.geom, ctx.xp):
            out, lstate = _attn_decode_qgather(h, lp["attn"], sig, ctx, lstate)
        else:
            out = _attn_tp(h, lp["attn"], sig, ctx)
    elif sig.kind == BlockKind.RECURRENT:
        rp = gathered.get("rec", lp["rec"])
        out, lstate = _rec_apply(h, rp, ctx, lstate)
    else:
        cp = gathered.get("cell", lp["cell"])
        out, lstate = _cell_apply(h, cp, sig, ctx, lstate)
    x = x + out
    if "norm2" in lp:
        h2 = rms_norm(x, lp["norm2"], eps)
        b, s, dm = h2.shape
        h2f = h2.reshape(b * s, dm)
        if sig.is_moe:
            y, aux, new_pred, fstats = _moe_apply(
                h2f, lp["moe"], sig, ctx, gathered, rows=b, pred=pred
            )
        else:
            y = _ffn_apply(h2f, lp["ffn"], ctx, gathered.get("ffn"))
        x = x + y.reshape(b, s, dm)
    return x, lstate, aux, new_pred, fstats


# ==========================================================================
# The layer stack with prefetch double-buffering.
# ==========================================================================
def _fs_add(a, b):
    """None-safe fault-stats accumulation (None = layer not validated)."""
    if b is None:
        return a
    return b if a is None else a + b


def _run_stack(params, x, ctx: Ctx, states):
    model = ctx.model
    aux_total = jnp.float32(0.0)
    new_states: dict = {}
    new_preds: dict = {}
    fs_total = None
    preds_all = states.get("pred") if isinstance(states, dict) else None
    for group in model.plan:
        gp = params["layers"][group.name]
        gs = states["layers"][group.name] if states is not None else None
        ps = preds_all.get(group.name) if preds_all else None
        ctx.group = group.name  # scope per-layer-group policy overrides
        if group.scan and group.n_cycles > 1:
            x, ns, nps, aux, fs = _run_scan_group(group, gp, x, ctx, gs, ps)
        else:
            x, ns, nps, aux, fs = _run_unrolled(group, gp, x, ctx, gs, ps)
        new_states[group.name] = ns
        if nps:
            new_preds[group.name] = nps
        aux_total = aux_total + aux
        fs_total = _fs_add(fs_total, fs)
    return x, new_states, new_preds, aux_total, fs_total


def _run_unrolled(group, gp, x, ctx: Ctx, gs, ps=None):
    aux_total = jnp.float32(0.0)
    new_states = {}
    new_preds = {}
    fs_total = None
    for j, sig in enumerate(group.sigs):
        lp = gp[f"pos{j}"]
        pred = ps.get(f"pos{j}") if ps else None
        paths = gather_set(sig, ctx.geom, ctx.xp, ctx.cfg, group.name)
        gathered = (
            gather_layer(_extract(lp, paths), ctx, pred=pred) if paths else {}
        )
        lstate = gs[f"pos{j}"] if gs is not None else None
        x, ns, aux, npred, fs = apply_layer(
            x, lp, sig, ctx, lstate, gathered, pred=pred
        )
        new_states[f"pos{j}"] = ns
        if npred is not None:
            new_preds[f"pos{j}"] = npred
        aux_total = aux_total + aux
        fs_total = _fs_add(fs_total, fs)
    return x, new_states, new_preds, aux_total, fs_total


def _run_scan_group(group, gp, x, ctx: Ctx, gs, ps=None):
    sigs = group.sigs
    period = len(sigs)
    paths = [
        gather_set(s, ctx.geom, ctx.xp, ctx.cfg, group.name) for s in sigs
    ]
    pipelined = ctx.xp.mode in ("dwdp", "hybrid") and any(paths)
    ps = ps or {}

    def _pred_at(name, cyc):
        """Layer ``name``'s incoming PredictState for cycle ``cyc`` —
        read from the closure-captured stacked state: within one decode
        step every layer's input state is the PREVIOUS step's, so the
        layer-ahead speculative gather may index it before the layer
        runs."""
        if name not in ps:
            return None
        return jax.tree.map(
            lambda w: lax.dynamic_index_in_dim(w, cyc, 0, keepdims=False),
            ps[name],
        )

    g0 = {}
    pos0_g = None
    n_cycles = group.n_cycles
    if pipelined and paths[0]:
        pos0_g = _extract(gp["pos0"], paths[0])  # stacked (n_cycles, ...)
        first = jax.tree.map(lambda w: w[0], pos0_g)
        g0 = gather_layer(first, ctx, pred=_pred_at("pos0", jnp.int32(0)))

    def body(carry, xs):
        x, g = carry
        lp_all, st_all, pd_all, cyc = xs
        aux_c = jnp.float32(0.0)
        new_sts = {}
        new_pds = {}
        fs_c = None
        for j, sig in enumerate(sigs):
            lp = lp_all[f"pos{j}"]
            if pipelined:
                nj = (j + 1) % period
                nxt_paths = paths[nj]
                if not nxt_paths:
                    g_next = {}
                elif nj == 0:
                    # cross-cycle prefetch: index the closure-captured
                    # stacked bank at (cyc+1) mod n — a per-iteration
                    # dynamic slice instead of a whole-bank jnp.roll copy
                    nxt_raw = jax.tree.map(
                        lambda w: lax.dynamic_index_in_dim(
                            w, (cyc + 1) % n_cycles, 0, keepdims=False
                        ),
                        pos0_g,
                    )
                    g_next = gather_layer(
                        nxt_raw, ctx,
                        pred=_pred_at("pos0", (cyc + 1) % n_cycles),
                    )
                else:
                    g_next = gather_layer(
                        _extract(lp_all[f"pos{nj}"], nxt_paths), ctx,
                        pred=pd_all.get(f"pos{nj}") if pd_all else None,
                    )
            else:
                g_next = {}
                g = (
                    gather_layer(
                        _extract(lp, paths[j]), ctx,
                        pred=pd_all.get(f"pos{j}") if pd_all else None,
                    )
                    if paths[j]
                    else {}
                )
            lstate = st_all[f"pos{j}"] if st_all is not None else None
            x, ns, aux, npred, fs = apply_layer(
                x, lp, sig, ctx, lstate, g,
                pred=pd_all.get(f"pos{j}") if pd_all else None,
            )
            new_sts[f"pos{j}"] = ns
            if npred is not None:
                new_pds[f"pos{j}"] = npred
            g = g_next
            aux_c = aux_c + aux
            fs_c = _fs_add(fs_c, fs)
        return (x, g), (new_sts, new_pds, aux_c, fs_c)

    if ctx.xp.phase == "train":
        # remat the cycle: without this, backward saves every layer's
        # *gathered* full weight set (ZeRO-3's classic memory blow-up);
        # with it, backward re-gathers — trading one extra prefetch for
        # O(L x full-layer) HBM.
        body = jax.checkpoint(body)

    (x, _), (new_states, new_preds, auxs, fss) = lax.scan(
        body, (x, g0), (gp, gs, ps, jnp.arange(n_cycles))
    )
    fs_total = jnp.sum(fss, axis=0) if fss is not None else None
    return x, new_states, new_preds, jnp.sum(auxs), fs_total


# ==========================================================================
# Phase entry points (run inside shard_map).
# ==========================================================================
def _positions_offset(ctx: Ctx):
    xp = ctx.xp
    if xp.seq_axes:
        return _shard_index(xp, xp.seq_axes) * xp.local_seq
    return 0  # static: enables block-causal KV skipping


def _input_embed(params, batch, ctx: Ctx):
    cd = _compute_dtype(ctx.model)
    if "embeds" in batch:
        return batch["embeds"].astype(cd)
    emb = _embed_table(params, ctx)
    return emb[batch["tokens"]].astype(cd)


def _last_token_hidden(x, ctx: Ctx):
    xp = ctx.xp
    xl = x[:, -1]
    if xp.seq_axes:
        is_last = (_shard_index(xp, xp.seq_axes) == xp.seq_shards - 1)
        xl = xl * is_last.astype(xl.dtype)
        xl = lax.psum(xl, xp.seq_axes)
    return xl


def forward_prefill(params, batch, ctx: Ctx):
    ctx.q_offset = _positions_offset(ctx)
    x = _input_embed(params, batch, ctx)
    x, new_states, _, _, _ = _run_stack(params, x, ctx, None)
    x = rms_norm(x, params["final_norm"], ctx.cfg.norm_eps)
    xl = _last_token_hidden(x, ctx)
    out_state = None
    if ctx.capture_len:
        b = xl.shape[0]
        # the GLOBAL prefill depth (batch arrays are seq-sharded inside
        # shard_map, so their local length is not the decode position)
        out_state = {
            "pos": jnp.full((b,), ctx.xp.seq_len, jnp.int32),
            "layers": new_states,
        }
    if AXIS_MODEL in ctx.xp.batch_axes:
        # tokens are batch-sharded over the vocab axis: use the gathered
        # (train-style) head so each rank scores its own rows fully
        if ctx.cfg.tie_embeddings:
            head = _embed_table(params, ctx).T
        else:
            head = params["lm_head"]
            if ctx.geom.model_size > 1:
                head = lax.all_gather(head, AXIS_MODEL, axis=1, tiled=True)
        logits = (xl @ head).astype(jnp.float32)
        logits = softcap(logits, ctx.cfg.logit_softcap)
        out = {"last_logits": _mask_vocab_cols(logits, ctx, local=False)}
    else:
        logits = (xl @ _head_local(params, ctx)).astype(jnp.float32)
        logits = softcap(logits, ctx.cfg.logit_softcap)
        out = {"last_logits": _mask_vocab_cols(logits, ctx, local=True)}
    if out_state is not None:
        out["state"] = out_state
    return out


def _fold_mirrors(new_preds: dict, preds_in, ctx: Ctx) -> dict:
    """The sync-free per-STEP mirror fold: union every sync-free layer's
    routed bitmaps (returned through the transient ``PredictState.routed``
    channel), exchange them in ONE packed all-gather over the subgroup,
    fold once with :func:`prefetch.update_predictor` from the pre-step
    mirror state, and write the folded predictor fields into EVERY
    sync-free layer's outgoing state. The predictor models the rank, not
    the layer, so one fold per step replaces the old per-layer packed
    exchange — (n_moe_layers - 1) fewer metadata gathers per step, and
    the per-layer index traffic shrinks to the correction residual
    bitmap alone. Deterministic in the exchanged payload, so the mirrors
    stay bit-identical across ranks exactly as the per-layer fold did.

    No-op (returns ``new_preds`` unchanged) when no layer ran sync-free
    this step — plain predictive layers fold locally in-layer."""
    sf_keys = [
        (gname, pos)
        for gname, gdict in new_preds.items()
        for pos, ps in gdict.items()
        if ps.routed is not None
    ]
    if not sf_keys:
        return new_preds
    geom = ctx.geom
    pl = geom.moe_placement
    axis = geom.expert_axes[0]
    e_pad = pl.num_padded

    def _local_rows(leaf, nd):
        # strip the leading stack dims (scan cycles x rank shard) down
        # to the per-mirror view: (..., *leaf.shape[-nd:]) -> cycle 0
        return leaf.reshape((-1,) + leaf.shape[-nd:])[0]

    routed_u = None
    for gname, pos in sf_keys:
        r = new_preds[gname][pos].routed  # (1, rows, E) | (n, 1, rows, E)
        r = jnp.any(r.reshape((-1,) + r.shape[-2:]), axis=0)
        routed_u = r if routed_u is None else (routed_u | r)
    buckets = prefetch.position_buckets(ctx.pos)
    packed = prefetch.pack_mirror_payload(routed_u, buckets)
    all_packed = lax.all_gather(
        packed, axis, axis_index_groups=pl.axis_index_groups()
    )
    routed_all, buckets_all = prefetch.unpack_mirror_payload(
        all_packed, e_pad
    )
    # pre-step mirror state: identical across sync-free layers by
    # construction (cold init is uniform zeros; every later step writes
    # the same folded fields everywhere), so any layer's incoming state
    # seeds the fold
    g0, p0 = sf_keys[0]
    m = preds_in[g0][p0]
    new_prev, new_ema, new_aff, new_posb, new_sig, new_sigw = jax.vmap(
        prefetch.update_predictor
    )(
        _local_rows(m.ema, 2), _local_rows(m.aff, 3),
        _local_rows(m.posb, 3), _local_rows(m.sigw, 2),
        routed_all, buckets_all,
    )
    folded = {
        "prev": new_prev, "ema": new_ema, "aff": new_aff,
        "posb": new_posb, "sig": new_sig, "sigw": new_sigw,
    }

    def _bcast(v, like):
        return jnp.broadcast_to(
            v.reshape((1,) * (like.ndim - v.ndim) + v.shape), like.shape
        )

    out = {g: dict(d) for g, d in new_preds.items()}
    for gname, pos in sf_keys:
        ps = out[gname][pos]
        out[gname][pos] = ps._replace(
            routed=None,
            **{k: _bcast(v, getattr(ps, k)) for k, v in folded.items()},
        )
    return out


def forward_decode(params, batch, state, ctx: Ctx):
    assert AXIS_MODEL not in ctx.xp.batch_axes
    ctx.pos = state["pos"]
    token = batch["token"]
    x = _embed_decode(params, token, ctx)
    x, new_layer_states, new_preds, _, fstats = _run_stack(
        params, x, ctx, state
    )
    if new_preds:
        new_preds = _fold_mirrors(new_preds, state.get("pred"), ctx)
    x = rms_norm(x, params["final_norm"], ctx.cfg.norm_eps)
    logits = (x[:, 0] @ _w(_head_local(params, ctx), x)).astype(jnp.float32)
    logits = softcap(logits, ctx.cfg.logit_softcap)
    logits = _mask_vocab_cols(logits, ctx, local=True)
    # greedy sharded argmax over the vocab shards
    v_l = logits.shape[-1]
    val = jnp.max(logits, axis=-1)
    idx = jnp.argmax(logits, axis=-1).astype(jnp.int32)
    if ctx.geom.model_size > 1:
        off = lax.axis_index(AXIS_MODEL) * v_l
        vals = lax.all_gather(val, AXIS_MODEL)        # (G, B)
        idxs = lax.all_gather(idx + off, AXIS_MODEL)  # (G, B)
        best = jnp.argmax(vals, axis=0)
        nxt = jnp.take_along_axis(idxs, best[None], axis=0)[0]
    else:
        nxt = idx
    new_state = dict(state)
    new_state["layers"] = new_layer_states
    new_state["pos"] = state["pos"] + 1
    out = {"next_token": nxt[:, None], "state": new_state}
    if new_preds:
        new_state["pred"] = new_preds
        # per-step predictive counters [predicted, spec_hit, cache_hit,
        # miss, evicted] rows, summed over layers and (psum) over ranks
        # -> replicated
        pstates = jax.tree.leaves(
            new_preds,
            is_leaf=lambda t: isinstance(t, prefetch.PredictState),
        )
        stats = sum(
            jnp.sum(p.stats.reshape(-1, 5), axis=0) for p in pstates
        )
        out["pred_stats"] = lax.psum(stats, tuple(ctx.xp.mesh_sizes))
    if fstats is not None:
        # per-step fault counters (see faults.FAULT_STAT_NAMES + per-src
        # tail), summed over layers and (psum) over ranks -> replicated
        out["fault_stats"] = lax.psum(fstats, tuple(ctx.xp.mesh_sizes))
    return out


def _chunked_xent(x2d, head, labels, ctx: Ctx):
    """Memory-bounded sharded cross-entropy: scan over token chunks."""
    t, dm = x2d.shape
    nchunk = -(-t // XENT_CHUNK)
    pad = nchunk * XENT_CHUNK - t
    xpad = jnp.pad(x2d, ((0, pad), (0, 0)))
    lpad = jnp.pad(labels, (0, pad), constant_values=-1)
    v = ctx.cfg.vocab_size
    cap = ctx.cfg.logit_softcap

    @jax.checkpoint  # logits are recomputed in backward, never stored
    def body(carry, i):
        ls, cnt = carry
        xc = lax.dynamic_slice_in_dim(xpad, i * XENT_CHUNK, XENT_CHUNK, 0)
        lc = lax.dynamic_slice_in_dim(lpad, i * XENT_CHUNK, XENT_CHUNK, 0)
        logits = (xc @ head).astype(jnp.float32)
        logits = softcap(logits, cap)
        logits = jnp.where(jnp.arange(logits.shape[-1]) < v, logits, -1e30)
        lse = jax.nn.logsumexp(logits, axis=-1)
        ll = jnp.take_along_axis(
            logits, jnp.clip(lc, 0, v - 1)[:, None], axis=-1
        )[:, 0]
        valid = (lc >= 0).astype(jnp.float32)
        ls = ls + jnp.sum((lse - ll) * valid)
        cnt = cnt + jnp.sum(valid)
        return (ls, cnt), None

    (ls, cnt), _ = lax.scan(
        body, (jnp.float32(0.0), jnp.float32(0.0)), jnp.arange(nchunk)
    )
    return ls, cnt


def forward_train(params, batch, ctx: Ctx):
    """Returns (loss_for_grad, metrics).

    ``loss_for_grad`` is the *local* (per-rank, unreduced) contribution
    divided by the global token count: differentiating it per rank and
    psum-ing grads in ``sync_grads`` yields exactly d(global mean)/dw.
    (Reducing the loss itself before grad would double-count through the
    psum transpose under check_vma=False.) ``metrics`` carry the properly
    psum-reduced scalars.
    """
    ctx.q_offset = _positions_offset(ctx)
    x = _input_embed(params, batch, ctx)
    x, _, _, aux, _ = _run_stack(params, x, ctx, None)
    x = rms_norm(x, params["final_norm"], ctx.cfg.norm_eps)
    b, s, dm = x.shape
    if ctx.cfg.tie_embeddings:
        head = _embed_table(params, ctx).T
    else:
        head = params["lm_head"]
        if ctx.geom.model_size > 1:
            head = lax.all_gather(head, AXIS_MODEL, axis=1, tiled=True)
    ls, cnt = _chunked_xent(
        x.reshape(b * s, dm), head, batch["labels"].reshape(-1), ctx
    )
    all_axes = tuple(ctx.xp.mesh_sizes)
    n_ranks = math.prod(ctx.xp.mesh_sizes.values())
    cnt_g = lax.stop_gradient(lax.psum(cnt, all_axes))
    denom = jnp.maximum(cnt_g, 1.0)
    # If tokens are replicated over idle mesh axes, both psum(ls) and
    # cnt_g carry the same replication factor — it cancels in the mean
    # and in the synced gradient alike, so no explicit correction needed.
    rep = n_ranks // max(1, ctx.xp.batch_shards * ctx.xp.seq_shards)
    loss_local = ls / denom + 0.01 * aux / n_ranks
    loss_g = lax.psum(ls, all_axes) / denom
    aux_g = lax.psum(aux, all_axes) / n_ranks
    return loss_local, {"loss": loss_g, "aux_loss": aux_g, "tokens": cnt_g / rep}


# ==========================================================================
# shard_map-wrapped step builders.
# ==========================================================================
def _grad_sync_axes(spec, mesh_axes: tuple[str, ...]) -> tuple[str, ...]:
    used: set[str] = set()
    for entry in spec:
        if entry is None:
            continue
        if isinstance(entry, tuple):
            used.update(entry)
        else:
            used.add(entry)
    return tuple(a for a in mesh_axes if a not in used)


def sync_grads(grads, pspecs, mesh_axes: tuple[str, ...]):
    """psum each grad over the axes its param is replicated on."""

    def f(g, spec):
        axes = _grad_sync_axes(spec, mesh_axes)
        return lax.psum(g, axes) if axes else g

    return jax.tree.map(
        f, grads, pspecs, is_leaf=lambda x: isinstance(x, P)
    )


def _sharded_global_norm(grads, pspecs, mesh_axes, model: Model):
    """Global grad norm with every logical element counted exactly once:
    sharded leaves psum their sumsq over their shard axes; redundant
    expert copies (already grad-synced, hence identical) divide by R."""
    pl = model.geom.moe_placement
    r_fac = float(pl.redundancy) if pl is not None else 1.0
    terms = []

    def walk(g, spec, in_experts):
        if isinstance(g, dict):
            for k in g:
                walk(g[k], spec[k], in_experts or k == "experts")
            return
        s = jnp.sum(jnp.square(g.astype(jnp.float32)))
        axes = tuple(
            a for a in mesh_axes if a not in _grad_sync_axes(spec, mesh_axes)
        )
        if axes:
            s = lax.psum(s, axes)
        terms.append(s / r_fac if in_experts else s)

    walk(grads, pspecs, False)
    return jnp.sqrt(sum(terms))


def sync_redundant_expert_grads(grads, model: Model, xp: ExecutionPlan):
    """Redundant placement (R > 1) stores each expert on R subgroups; the
    copies must train identically, so their grads are psum'd across the
    subgroups holding the same expert (ranks {p, p+G', p+2G', ...})."""
    pl = model.geom.moe_placement
    if pl is None or pl.redundancy == 1:
        return grads
    groups = [
        [p + s * pl.subgroup_size for s in range(pl.redundancy)]
        for p in range(pl.subgroup_size)
    ]
    ax = _axes_arg(model.geom.expert_axes)

    def fix(tree):
        if isinstance(tree, dict):
            out = {}
            for k, v in tree.items():
                if k == "experts":
                    out[k] = jax.tree.map(
                        lambda g: lax.psum(g, ax, axis_index_groups=groups), v
                    )
                else:
                    out[k] = fix(v)
            return out
        return tree

    new = dict(grads)
    new["layers"] = fix(grads["layers"])
    return new


# ==========================================================================
# Predictive-fetch state lifecycle (decode only).
# ==========================================================================
def init_predict_state(model: Model, xp: ExecutionPlan) -> dict:
    """Cold :class:`prefetch.PredictState` tree for every
    predictive-active MoE layer — ``{group: {posJ: PredictState}}``
    (scan groups stacked over cycles), or ``{}`` when the plan has no
    predictive decode layers.

    Arrays carry a leading per-RANK dim (``prod(mesh_sizes)``): every
    rank routes its own tokens and caches its own fetched remote rows,
    so the state is genuinely per-device — sharded over ALL mesh axes by
    ``predict_state_pspecs``, never replicated. Sync-free layers
    additionally carry a per-SUBGROUP-POSITION dim after it (each rank
    mirrors the predictor + cache *bookkeeping* of every peer in its own
    subgroup; the cached WEIGHTS stay own-rows-only) plus the richer-
    predictor slots (aff/posb/sig/sigw). Cold state = empty predictor +
    invalid cache: the first step's speculative round fetches nothing
    and the correction round degenerates to the plain demand round (or
    its exact overflow fallback), so cold starts are bitwise-safe by
    construction."""
    cfg, geom = model.cfg, model.geom
    n_ranks = math.prod(xp.mesh_sizes.values())
    out: dict = {}
    for group in model.plan:
        gdict = {}
        for j, sig in enumerate(group.sigs):
            if not (
                sig.is_moe
                and predictive_fetch_active(cfg, geom, xp, group.name)
            ):
                continue
            pl = geom.moe_placement
            e_pad = pl.num_padded
            rows = resolve_cache_rows(cfg, geom, xp, group.name)
            dm, fe = cfg.d_model, cfg.moe.d_ff
            wdt = model.dtype
            if sync_free_active(cfg, geom, xp, group.name):
                gsz = pl.subgroup_size
                bl = max(1, xp.local_batch)
                nb = prefetch.N_POS_BUCKETS
                ps = prefetch.PredictState(
                    prev=jnp.zeros((n_ranks, gsz, e_pad), bool),
                    ema=jnp.zeros((n_ranks, gsz, e_pad), jnp.float32),
                    cache_ids=jnp.zeros((n_ranks, gsz, rows), jnp.int32),
                    cache_valid=jnp.zeros((n_ranks, gsz, rows), bool),
                    cache={
                        "w_gate": jnp.zeros((n_ranks, rows, dm, fe), wdt),
                        "w_up": jnp.zeros((n_ranks, rows, dm, fe), wdt),
                        "w_down": jnp.zeros((n_ranks, rows, fe, dm), wdt),
                    },
                    stats=jnp.zeros((n_ranks, 5), jnp.float32),
                    aff=jnp.zeros((n_ranks, gsz, bl, e_pad), jnp.float32),
                    posb=jnp.zeros((n_ranks, gsz, nb, e_pad), jnp.float32),
                    sig=jnp.zeros((n_ranks, gsz, 2, e_pad), jnp.float32),
                    sigw=jnp.zeros((n_ranks, gsz, 2), jnp.float32),
                )
            else:
                ps = prefetch.PredictState(
                    prev=jnp.zeros((n_ranks, e_pad), bool),
                    ema=jnp.zeros((n_ranks, e_pad), jnp.float32),
                    cache_ids=jnp.zeros((n_ranks, rows), jnp.int32),
                    cache_valid=jnp.zeros((n_ranks, rows), bool),
                    cache={
                        "w_gate": jnp.zeros((n_ranks, rows, dm, fe), wdt),
                        "w_up": jnp.zeros((n_ranks, rows, dm, fe), wdt),
                        "w_down": jnp.zeros((n_ranks, rows, fe, dm), wdt),
                    },
                    stats=jnp.zeros((n_ranks, 5), jnp.float32),
                )
            if group.scan:
                ps = jax.tree.map(
                    lambda w: jnp.broadcast_to(
                        w[None], (group.n_cycles,) + w.shape
                    ),
                    ps,
                )
            gdict[f"pos{j}"] = ps
        if gdict:
            out[group.name] = gdict
    return out


def attach_predict_state(state: dict, model: Model, xp: ExecutionPlan) -> dict:
    """Return ``state`` with a cold ``state["pred"]`` attached when the
    plan runs the predictive fetch anywhere (no-op otherwise). The ONE
    call sites need — the decode step threads and updates it from there."""
    pred = init_predict_state(model, xp)
    if not pred:
        return state
    state = dict(state)
    state["pred"] = pred
    return state


def predict_state_pspecs(model: Model, xp: ExecutionPlan) -> dict:
    """PartitionSpecs mirroring :func:`init_predict_state`: the leading
    per-rank dim shards over EVERY mesh axis (the state is per-device,
    not replicated), everything after it is local."""
    cfg, geom = model.cfg, model.geom
    ra = tuple(xp.mesh_sizes)
    out: dict = {}
    for group in model.plan:
        gdict = {}
        for j, sig in enumerate(group.sigs):
            if not (
                sig.is_moe
                and predictive_fetch_active(cfg, geom, xp, group.name)
            ):
                continue
            lead = (None,) if group.scan else ()

            def sp(nd):
                return P(*lead, ra, *([None] * nd))

            if sync_free_active(cfg, geom, xp, group.name):
                gdict[f"pos{j}"] = prefetch.PredictState(
                    prev=sp(2), ema=sp(2), cache_ids=sp(2),
                    cache_valid=sp(2),
                    cache={"w_gate": sp(3), "w_up": sp(3), "w_down": sp(3)},
                    stats=sp(1),
                    aff=sp(3), posb=sp(3), sig=sp(3), sigw=sp(2),
                )
            else:
                gdict[f"pos{j}"] = prefetch.PredictState(
                    prev=sp(1), ema=sp(1), cache_ids=sp(1),
                    cache_valid=sp(1),
                    cache={"w_gate": sp(3), "w_up": sp(3), "w_down": sp(3)},
                    stats=sp(1),
                )
        if gdict:
            out[group.name] = gdict
    return out


def build_inner_fns(model: Model, xp: ExecutionPlan, capture_len: int = 0):
    """Phase-appropriate function to run inside shard_map."""
    if xp.phase == "train":

        def inner(params, batch):
            ctx = Ctx(model=model, xp=xp)
            return forward_train(params, batch, ctx)

        return inner
    if xp.phase == "prefill":

        def inner(params, batch):
            ctx = Ctx(model=model, xp=xp, capture_len=capture_len)
            return forward_prefill(params, batch, ctx)

        return inner

    def inner(params, batch, state):
        ctx = Ctx(model=model, xp=xp)
        return forward_decode(params, batch, state, ctx)

    return inner


def make_step_fn(model: Model, xp: ExecutionPlan, mesh, *, capture_len: int = 0):
    """jit(shard_map(...)) step for the plan's phase.

    - train: (params, opt, batch, lr) -> (params, opt, metrics)
    - prefill: (params, batch) -> {"last_logits"[, "state"]}
      (capture_len > 0 additionally emits the decode state — the
       disaggregated ctx->gen KV transfer payload)
    - decode: (params, batch, state) -> {"next_token", "state"}
    """
    pspecs = model.param_pspecs()
    in_b = input_pspecs(model, xp)
    mesh_axes = tuple(xp.mesh_sizes)
    inner = build_inner_fns(model, xp, capture_len)

    if xp.phase == "train":
        from repro.optim.adamw import AdamWState, adamw_update

        def step(params, opt_state, batch, lr):
            (_, metrics), grads = jax.value_and_grad(
                lambda p: inner(p, batch), has_aux=True
            )(params)
            grads = sync_grads(grads, pspecs, mesh_axes)
            grads = sync_redundant_expert_grads(grads, model, xp)
            gn = _sharded_global_norm(grads, pspecs, mesh_axes, model)
            scale = jnp.minimum(1.0, 1.0 / jnp.maximum(gn, 1e-9))
            grads = jax.tree.map(lambda g: g * scale, grads)
            new_params, new_opt = adamw_update(
                grads, opt_state, params, lr=lr, clip_norm=0.0
            )
            return new_params, new_opt, metrics

        opt_specs = AdamWState(step=P(), m=pspecs, v=pspecs)
        sharded = shard_map(
            step,
            mesh=mesh,
            in_specs=(pspecs, opt_specs, in_b, P()),
            out_specs=(
                pspecs,
                opt_specs,
                {"loss": P(), "aux_loss": P(), "tokens": P()},
            ),
            check_vma=False,
        )
        return jax.jit(sharded, donate_argnums=(0, 1))

    if xp.phase == "prefill":
        out_sp = output_pspecs(model, xp)
        if capture_len:
            out_sp = dict(out_sp)
            out_sp["state"] = state_pspecs(model, xp)
        sharded = shard_map(
            inner,
            mesh=mesh,
            in_specs=(pspecs, in_b),
            out_specs=out_sp,
            check_vma=False,
        )
        return jax.jit(sharded)

    st_specs = state_pspecs(model, xp)
    pred_specs = predict_state_pspecs(model, xp)
    if pred_specs:
        st_specs = dict(st_specs)
        st_specs["pred"] = pred_specs
    out_specs = {
        "next_token": P(xp.batch_spec(), None),
        "state": st_specs,
    }
    if pred_specs:
        out_specs["pred_stats"] = P()  # psum'd inside -> replicated
    if fault_stats_active(model, xp):
        out_specs["fault_stats"] = P()  # psum'd inside -> replicated
    sharded = shard_map(
        inner,
        mesh=mesh,
        in_specs=(pspecs, in_b, st_specs),
        out_specs=out_specs,
        check_vma=False,
    )
    # donate the KV cache / recurrent state: serving updates it in place
    return jax.jit(sharded, donate_argnums=(2,))
