"""Strategy planner: DWDP / DEP / replicated execution plans.

``make_execution_plan`` decides, per (arch x input-shape x mesh):

- which mesh axes carry the batch (pure data parallelism — DWDP's ranks),
- which axes shard the sequence / KV cache (when the batch is too small
  to cover the mesh),
- how the FFN/MoE path executes:
    * ``dwdp``       — weights move (async gather or rotate), activations
                       never cross ranks. The paper's strategy.
    * ``dep``        — activations move (all_to_all for MoE, gather +
                       reduce-scatter for dense TP). The paper's baseline.
    * ``replicated`` — weights fully replicated, pure DP (reference).

- and, per **gathered-weight family**, HOW that family's weights are
  obtained: the :class:`GatherPolicy` / :class:`PolicyTable` surface.

Strategy selection (the ``GatherPolicy`` API)
---------------------------------------------

DWDP's core claim is that each rank can pick the cheapest way to obtain
each weight family independently.  The plan therefore carries a
:class:`PolicyTable` — ``ExecutionPlan.policies`` — mapping each gathered
family to a :class:`GatherPolicy` ``(layout, fetch, transport,
num_slices, budget)``:

- families: ``moe_experts`` (the routed expert bank), ``attn_qkv`` (the
  q/k/v projections), ``attn_out`` (the attention output projection),
  ``dense_ffn`` (dense-FFN slices and always-on shared experts), plus a
  ``default`` entry that backs any family without its own row. Optional
  per-layer-group overrides (``(group, family) -> policy``) refine the
  table for a named scan group of the model plan.
- ``layout``: ``"split"`` (the §4.2 remote-only SplitBank fast path, the
  default) or ``"merged"`` (the explicit-merge baseline).
- ``fetch``: ``"all"`` (every remote slice every layer), ``"demand"``
  (route-before-gather; ``moe_experts`` only, requires the split
  layout), or ``"predictive"`` — the demand-latency engine: an
  expert-hotness predictor (previous-step routing + EMA frequencies,
  carried in a ``prefetch.PredictState`` threaded through the decode
  loop) issues a *speculative* demand round a layer ahead (overlapping
  the previous layer's attention/compute), a fixed-HBM-budget
  cross-step **residency cache** serves re-activated experts with no
  wire at all, and a small post-routing *correction* round covers only
  the miss set. Decode only; elsewhere it lowers exactly as
  ``"demand"``. Bitwise-exact for any predictor quality and any cache
  budget (overflow falls back to the full gather per layer).
- ``transport``: ``"allgather"`` | ``"ring"`` | ``"ring_sliced"`` — the
  prefetch collective schedule, now chosen *per family* instead of one
  engine-wide mode.
- ``num_slices`` (ring_sliced TDM slicing), ``budget`` (per-peer
  demand-fetch rows, 0 = auto) and ``cache_budget`` (predictive
  residency-cache rows per layer, 0 = cache off; auto-resolved against
  the analytic HBM residency headroom) ride along per family.

A heterogeneous table expresses plans the old flat knobs could not, e.g.
**demand-fetch MoE experts over ring_sliced while the small attention
banks allgather merged and the dense-FFN slices ride the split ring**::

    policy = {
        "moe_experts": "split:demand:ring_sliced",
        "attn_qkv":    "merged:all:allgather",
        "attn_out":    "merged:all:allgather",
        "dense_ffn":   "split:all:ring",
    }
    xp = make_execution_plan(model, shape, sizes, policy=policy)

``policy="auto"`` runs :func:`resolve_policies`' roofline-guided
resolver: per family x phase it consults ``roofline.layer_times`` /
``roofline.modeled_step_time`` and picks the policy combination with the
smallest modeled step time.  Its decision rules:

- ``layout="split"`` wherever the engine's split path can engage (single
  gather axis, >1 shards) — the merged merge-copy landing is never
  modeled faster; ``merged`` elsewhere (multi-axis fallback).
- ``fetch="predictive"`` at decode shapes where the overlapped
  speculative round + correction beat the serial demand round (several
  routed rows per rank — at one row the padded speculative payload buys
  nothing and plain ``"demand"`` wins); ``fetch="demand"`` elsewhere at
  partial coverage — ``rows * top_k < remote experts`` (decode,
  small-batch prefill); ``"all"`` otherwise. Predictive picks get a
  ``cache_budget`` sized from the analytic HBM residency headroom
  (``CACHE_HEADROOM_FRAC``).
- ``transport="ring_sliced"`` only above a per-layer remote-bank-size
  threshold (:data:`RING_SLICED_MIN_BYTES`, the §4.3 TDM regime);
  ``"allgather"`` for small banks where slicing buys nothing.

The legacy flat kwargs (``prefetch=``, ``num_slices=``,
``weight_layout=``, ``expert_fetch=``, ``demand_budget=``, ``moe_ffn=``)
survive as deprecated aliases that build a *uniform* table (every family
the same policy) with a ``DeprecationWarning``; combining them with
``policy=`` is a conflict error.  ``capacity_from`` ("local" | "global"
MoE capacity derivation) and ``decode_attn`` ("gather" | "qgather") are
plan-level execution knobs, not gather policies, and stay flat.
"""
from __future__ import annotations

import dataclasses
import json
import math
import warnings
from typing import Any, Mapping, Optional, Union

from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind, InputShape
from repro.models.cache import decode_state_pspecs
from repro.models.transformer import AXIS_MODEL, Model

PyTree = Any

MODES = ("dwdp", "dep", "replicated", "hybrid")
PREFETCH_MODES = ("allgather", "ring", "ring_sliced")
WEIGHT_LAYOUTS = ("merged", "split")
MOE_FFN_MODES = WEIGHT_LAYOUTS  # deprecated alias (PR 1 name)
CAPACITY_FROM = ("local", "global")
EXPERT_FETCH = ("all", "demand", "predictive", "sync_free")

#: The gathered-weight families a PolicyTable addresses. ``default``
#: additionally backs any family without its own entry.
GATHER_FAMILIES = ("moe_experts", "attn_qkv", "attn_out", "dense_ffn")

#: Auto-resolver rule: ring_sliced transport only when a family's
#: per-layer remote bank exceeds this many bytes (the §4.3 TDM regime —
#: below it the transfer is too small for slice-interleaving to help).
RING_SLICED_MIN_BYTES = 32 << 20

#: Auto-resolver rule: fraction of the analytic HBM residency headroom
#: the predictive fetch's cross-step expert residency cache may claim
#: (the rest stays free for allocator slack / fragmentation).
CACHE_HEADROOM_FRAC = 0.5


# --------------------------------------------------------------------------
# GatherPolicy + PolicyTable: the per-family configuration surface.
# --------------------------------------------------------------------------
@dataclasses.dataclass(frozen=True)
class GatherPolicy:
    """How one gathered-weight family is obtained.

    ``layout``: gathered representation — "split" (remote-only SplitBank)
    or "merged" (explicit-merge canonical buffer).
    ``fetch``: expert-gather selection — "all", "demand"
    (route-before-gather), "predictive" (route-before-gather with a
    layer-ahead speculative round + cross-step residency cache; decode
    only, elsewhere it behaves exactly like "demand") or "sync_free"
    (predictive's mirrored-predictor successor: both transfer endpoints
    derive the speculative schedule from mirrored PredictState, so the
    speculative round ships pure payload with ZERO index exchange, and
    richer per-sequence/position predictors starve the correction
    round; decode only, elsewhere exactly "demand"). All non-"all"
    modes are meaningful for ``moe_experts`` only and require the split
    layout.
    ``transport``: the prefetch collective schedule for this family.
    ``num_slices``: ring_sliced TDM slice count.
    ``budget``: per-peer demand-fetch row budget (0 = auto — 2x the
    expected distinct-expert coverage for "demand",
    roofline.demand_budget_rows; the (1x, 0.5x) speculative/correction
    pair for "predictive", roofline.predictive_budget_rows).
    ``cache_budget``: expert rows of the cross-step residency cache per
    predictive layer (0 = cache off; ``policy="auto"`` resolves it
    against the analytic HBM residency headroom). Cache hits skip the
    wire entirely; correctness never depends on the value.
    """

    layout: str = "split"
    fetch: str = "all"
    transport: str = "allgather"
    num_slices: int = 4
    budget: int = 0
    cache_budget: int = 0

    def __post_init__(self):
        if self.layout not in WEIGHT_LAYOUTS:
            raise ValueError(
                f"unknown layout {self.layout!r}; expected one of "
                f"{WEIGHT_LAYOUTS}"
            )
        if self.fetch not in EXPERT_FETCH:
            raise ValueError(
                f"unknown fetch {self.fetch!r}; expected one of "
                f"{EXPERT_FETCH}"
            )
        if self.transport not in PREFETCH_MODES:
            raise ValueError(
                f"unknown transport {self.transport!r}; expected one of "
                f"{PREFETCH_MODES}"
            )
        if self.fetch != "all" and self.layout != "split":
            raise ValueError(
                f'fetch="{self.fetch}" requires the split layout (the '
                f"demand bank is a split-bank refinement); got layout="
                f"{self.layout!r}"
            )
        if self.num_slices < 1:
            raise ValueError(f"num_slices must be >= 1, got {self.num_slices}")
        if self.budget < 0:
            raise ValueError(f"budget must be >= 0, got {self.budget}")
        if self.cache_budget < 0:
            raise ValueError(
                f"cache_budget must be >= 0, got {self.cache_budget}"
            )
        if self.cache_budget and self.fetch not in (
            "predictive", "sync_free"
        ):
            raise ValueError(
                "cache_budget only applies to the predictive/sync_free "
                f"fetch (the residency cache rides the predictive "
                f"rounds); got it with fetch={self.fetch!r}"
            )

    @classmethod
    def parse(cls, spec: Union[str, "GatherPolicy", Mapping]) -> "GatherPolicy":
        """Parse ``"layout[:fetch[:transport[:num_slices[:budget
        [:cache_budget]]]]]"`` (the ``--policy`` CLI spec), a kwargs
        mapping, or pass a policy through. Unknown values raise
        ``ValueError``."""
        if isinstance(spec, GatherPolicy):
            return spec
        if isinstance(spec, Mapping):
            extra = set(spec) - {f.name for f in dataclasses.fields(cls)}
            if extra:
                raise ValueError(
                    f"unknown GatherPolicy fields {sorted(extra)}"
                )
            return cls(**spec)
        parts = [p for p in str(spec).split(":")]
        if not 1 <= len(parts) <= 6 or not all(parts):
            raise ValueError(
                f"bad policy spec {spec!r}; expected "
                "layout[:fetch[:transport[:num_slices[:budget"
                "[:cache_budget]]]]]"
            )
        kw: dict = {"layout": parts[0]}
        if len(parts) > 1:
            kw["fetch"] = parts[1]
        if len(parts) > 2:
            kw["transport"] = parts[2]
        try:
            if len(parts) > 3:
                kw["num_slices"] = int(parts[3])
            if len(parts) > 4:
                kw["budget"] = int(parts[4])
            if len(parts) > 5:
                kw["cache_budget"] = int(parts[5])
        except ValueError:
            raise ValueError(
                f"bad policy spec {spec!r}: num_slices/budget/cache_budget "
                "must be ints"
            ) from None
        return cls(**kw)

    def spec(self) -> str:
        """The canonical ``layout:fetch:transport[:num_slices][:budget]
        [:cache_budget]`` round-trip form of this policy
        (parse(spec()) == self)."""
        s = f"{self.layout}:{self.fetch}:{self.transport}"
        if self.num_slices != 4 or self.budget != 0 or self.cache_budget != 0:
            s += f":{self.num_slices}"
        if self.budget != 0 or self.cache_budget != 0:
            s += f":{self.budget}"
        if self.cache_budget != 0:
            s += f":{self.cache_budget}"
        return s


def _check_family(name: str, *, allow_default: bool = True) -> None:
    ok = GATHER_FAMILIES + (("default",) if allow_default else ())
    if name not in ok:
        raise ValueError(
            f"unknown gather family {name!r}; expected one of {ok}"
        )


def _check_fetch_applies(family: str, pol: GatherPolicy) -> None:
    if pol.fetch != "all" and family not in ("moe_experts", "default"):
        raise ValueError(
            f'fetch="{pol.fetch}" only applies to the moe_experts family '
            f"(route-before-gather is an expert-bank feature); got it for "
            f"{family!r}"
        )


@dataclasses.dataclass(frozen=True)
class PolicyTable:
    """Per-family (optionally per-layer-group) gather policies.

    Lookup order for ``family(name, group)``: the ``(group, name)``
    override, then the ``name`` entry, then ``default``.
    """

    default: GatherPolicy = GatherPolicy()
    families: tuple[tuple[str, GatherPolicy], ...] = ()
    overrides: tuple[tuple[str, str, GatherPolicy], ...] = ()

    def __post_init__(self):
        seen: set = set()
        for name, pol in self.families:
            _check_family(name, allow_default=False)
            _check_fetch_applies(name, pol)
            if name in seen:
                raise ValueError(f"duplicate family entry {name!r}")
            seen.add(name)
        _check_fetch_applies("default", self.default)
        oseen: set = set()
        for group, name, pol in self.overrides:
            _check_family(name, allow_default=False)
            _check_fetch_applies(name, pol)
            if (group, name) in oseen:
                raise ValueError(f"duplicate override {(group, name)!r}")
            oseen.add((group, name))

    def family(self, name: str, group: Optional[str] = None) -> GatherPolicy:
        """The resolved policy for ``name`` (optionally within layer
        group ``group``)."""
        _check_family(name)
        if group is not None:
            for g, n, pol in self.overrides:
                if g == group and n == name:
                    return pol
        for n, pol in self.families:
            if n == name:
                return pol
        return self.default

    @classmethod
    def uniform(cls, *, layout: str = "split", fetch: str = "all",
                transport: str = "allgather", num_slices: int = 4,
                budget: int = 0, cache_budget: int = 0) -> "PolicyTable":
        """One policy for every family — exactly what the deprecated flat
        ExecutionPlan knobs used to express."""
        pol = GatherPolicy(layout=layout, fetch=fetch, transport=transport,
                           num_slices=num_slices, budget=budget,
                           cache_budget=cache_budget)
        if pol.fetch != "all":
            # demand/predictive/sync_free only ever apply to the expert
            # bank; a uniform table of any means that expert fetch +
            # all-fetch for the rest
            return cls(
                default=dataclasses.replace(
                    pol, fetch="all", budget=0, cache_budget=0
                ),
                families=(("moe_experts", pol),),
            )
        return cls(default=pol)

    @classmethod
    def from_dict(cls, d: Mapping[str, Any]) -> "PolicyTable":
        """Build a table from ``{family_or_"default"_or_"group/family":
        spec}`` where each spec is a string (``GatherPolicy.parse``), a
        kwargs mapping, or a GatherPolicy — the ``--policy-file`` JSON
        shape."""
        default = GatherPolicy()
        fams: list[tuple[str, GatherPolicy]] = []
        overrides: list[tuple[str, str, GatherPolicy]] = []
        for key, spec in d.items():
            pol = GatherPolicy.parse(spec)
            if key == "default":
                default = pol
            elif "/" in key:
                group, name = key.split("/", 1)
                overrides.append((group, name, pol))
            else:
                fams.append((key, pol))
        return cls(default=default, families=tuple(fams),
                   overrides=tuple(overrides))

    def to_dict(self) -> dict:
        """JSON-able round-trip form (``from_dict(to_dict()) == self``)."""
        out = {"default": self.default.spec()}
        for name, pol in self.families:
            out[name] = pol.spec()
        for group, name, pol in self.overrides:
            out[f"{group}/{name}"] = pol.spec()
        return out

    def describe(self) -> str:
        return json.dumps(self.to_dict(), sort_keys=True)


PolicyLike = Union[None, str, Mapping, GatherPolicy, PolicyTable]


def _coerce_policy(policy: PolicyLike) -> Optional[PolicyTable]:
    """Everything but "auto" (which needs model/shape context)."""
    if policy is None:
        return PolicyTable()
    if isinstance(policy, PolicyTable):
        return policy
    if isinstance(policy, GatherPolicy):
        return PolicyTable(default=policy)
    if isinstance(policy, Mapping):
        return PolicyTable.from_dict(policy)
    if isinstance(policy, str):
        if policy in ("auto", "auto-online"):
            # "auto-online" resolves like "auto" at plan-build time; the
            # serving engine's OnlinePolicyScheduler additionally
            # re-resolves at phase/batch boundaries against measured
            # drift (runtime/engine.py)
            return None
        return PolicyTable(default=GatherPolicy.parse(policy))
    raise TypeError(f"cannot build a PolicyTable from {policy!r}")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    mode: str                        # dwdp | dep | replicated | hybrid
    phase: str                       # train | prefill | decode
    batch_axes: tuple[str, ...]
    seq_axes: tuple[str, ...]
    mesh_sizes: dict[str, int]       # ordered as the mesh axes
    capacity_factor: float
    global_batch: int
    seq_len: int
    policies: PolicyTable = PolicyTable()
    # Per-family gather policies — THE canonical configuration surface
    # for how every gathered weight family (moe_experts, attn_qkv,
    # attn_out, dense_ffn) is obtained. Read via ``plan.policy(family,
    # group)``; the old flat knobs survive only as deprecated read
    # properties below.
    block_causal: bool = False   # skip fully-masked KV blocks (needs
                                 # unsharded sequence; see DESIGN.md §9)
    decode_attn: str = "gather"  # "gather" weights per layer, or "qgather":
                                 # keep weights sharded and move the (tiny)
                                 # q/k/v activations instead (beyond-paper)
    capacity_from: str = "local"
    # MoE capacity derivation:
    #   "local": capacity_for(local token count) — the PR 1 behavior.
    #     Layouts with different shard counts legitimately drop different
    #     tokens near the capacity edge (the diagnosed llama4 rotate
    #     "divergence").
    #   "global": capacity is derived per ROW from the global sequence
    #     length, and capacity competition is restricted to the row — the
    #     drop set becomes a function of the row alone, so DWDP ranks
    #     drop identical tokens across any batch-sharding mesh reshape
    #     (batch determinism for serving; see execution._moe_apply).
    fault_spec: Optional[Any] = None
    # A core.faults.FaultSpec to inject into the demand/predictive fetch
    # rounds (None = no injection). Setting it implies validate_fetch:
    # the checksum verification + repair path traces into the forward.
    validate_fetch: bool = False
    # Checksum-validate fetched expert rows even without an injector —
    # the production hardening switch (and what the checksum-overhead
    # benchmark measures). Faulty rows are masked invalid and repaired
    # through the correction round / axis-agreed full-gather fallback,
    # so outputs stay bitwise-exact; per-step fault counters ride the
    # decode output ("fault_stats").
    exclude_peers: tuple = ()
    # Subgroup peer indices whose rows are dropped from the SPECULATIVE
    # plan and residency-cache bookkeeping (the HealthMonitor's
    # finer-grained "+excl" degradation rung — avoid a flaky peer
    # without giving up predictive/sync_free fetch entirely). The
    # correction round still fetches from every peer (validated +
    # repaired), so outputs stay bitwise-exact; excluded rows simply
    # always ride the correction round. Static: changing it rebuilds
    # the jitted step.

    @property
    def validated(self) -> bool:
        """Does the demand/predictive fetch path run payload validation
        (checksum ride-along, verification, repair, fault counters)?"""
        return self.fault_spec is not None or self.validate_fetch

    def policy(self, family: str, group: Optional[str] = None) -> GatherPolicy:
        """The resolved gather policy for ``family`` (optionally within
        layer group ``group``) — the one accessor every consumer uses."""
        return self.policies.family(family, group)

    # -- deprecated flat-knob reads (the pre-PolicyTable surface) ----------
    def _flat_warn(self, name: str, hint: str):
        warnings.warn(
            f"ExecutionPlan.{name} is deprecated — the plan carries a "
            f"per-family PolicyTable now; read plan.policy(family) "
            f"({hint})",
            DeprecationWarning,
            stacklevel=3,
        )

    @property
    def prefetch(self) -> str:
        self._flat_warn("prefetch", 'e.g. plan.policy("moe_experts").transport')
        return self.policies.default.transport

    @property
    def num_slices(self) -> int:
        self._flat_warn("num_slices", 'plan.policy(family).num_slices')
        return self.policies.default.num_slices

    @property
    def weight_layout(self) -> str:
        self._flat_warn("weight_layout", 'plan.policy(family).layout')
        return self.policies.default.layout

    @property
    def expert_fetch(self) -> str:
        self._flat_warn("expert_fetch", 'plan.policy("moe_experts").fetch')
        return self.policies.family("moe_experts").fetch

    @property
    def demand_budget(self) -> int:
        self._flat_warn("demand_budget", 'plan.policy("moe_experts").budget')
        return self.policies.family("moe_experts").budget

    @property
    def moe_ffn(self) -> str:
        """Deprecated PR 1 alias for the expert-bank layout (MoE was the
        only split family then)."""
        warnings.warn(
            "ExecutionPlan.moe_ffn is deprecated (PR 1 spelling) — read "
            'plan.policy("moe_experts").layout instead',
            DeprecationWarning,
            stacklevel=2,
        )
        return self.policies.family("moe_experts").layout

    @property
    def batch_shards(self) -> int:
        return math.prod(self.mesh_sizes[a] for a in self.batch_axes)

    @property
    def seq_shards(self) -> int:
        return math.prod(self.mesh_sizes[a] for a in self.seq_axes)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.batch_shards

    @property
    def local_seq(self) -> int:
        return self.seq_len // self.seq_shards

    def batch_spec(self) -> Any:
        return self.batch_axes if self.batch_axes else None

    def seq_spec(self) -> Any:
        return self.seq_axes if self.seq_axes else None


def plan_activation_sharding(
    cfg: ArchConfig, shape: InputShape, mesh_sizes: dict[str, int]
) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Greedy: batch over (pod, data, model) while divisible; remaining axes
    shard the sequence/KV if divisible and the architecture permits it.

    sLSTM recurrence is sequential in time (h_{t-1} feeds the gates) so
    sequence sharding is impossible for xLSTM — those archs replicate over
    the leftover axes (noted in DESIGN.md). RG-LRU and mLSTM are linear
    given the gates; RG-LRU cross-shard fixup is implemented, so hybrids
    may seq-shard.
    """
    order = [
        a for a in ("pod", "data", "model")
        if mesh_sizes.get(a, 1) > 1
    ]
    batch_axes: list[str] = []
    rem = shape.global_batch
    for a in order:
        if rem % mesh_sizes[a] == 0:
            batch_axes.append(a)
            rem //= mesh_sizes[a]
        else:
            break
    left = [a for a in order if a not in batch_axes]
    seq_axes: list[str] = []
    can_seq_shard = not any(
        k in (BlockKind.SLSTM, BlockKind.MLSTM) for k in cfg.block_pattern
    )
    if can_seq_shard:
        s = shape.seq_len
        for a in left:
            if s % mesh_sizes[a] == 0:
                seq_axes.append(a)
                s //= mesh_sizes[a]
            else:
                break
    return tuple(batch_axes), tuple(seq_axes)


# --------------------------------------------------------------------------
# The roofline-guided "auto" resolver.
# --------------------------------------------------------------------------
def _routed_rows(shape: InputShape, batch_shards: int, seq_shards: int) -> int:
    """Per-rank routed token count (mirrors execution._routed_tokens)."""
    lb = max(1, shape.global_batch // max(1, batch_shards))
    if shape.phase == "decode":
        return lb
    return lb * max(1, shape.seq_len // max(1, seq_shards))


def _family_remote_bank_bytes(
    cfg: ArchConfig, geom, family: str, fetch: str, budget: int,
    weight_bytes: int, routed_rows: int = 1,
) -> float:
    """Per-layer remote-bank bytes of one family — the transport rule's
    input (ring_sliced only above RING_SLICED_MIN_BYTES).

    A representative-layer HEURISTIC for the threshold decision only
    (dense_ffn uses the largest of the model's FFN dims rather than the
    per-layer mix): the authoritative per-step accounting the serving
    metrics report is ``execution.gathered_wire_bytes_per_step``, which
    sums the actual per-layer dims."""
    d = cfg.d_model

    def frac(shards: int) -> float:
        return (shards - 1) / shards if shards > 1 else 0.0

    if family == "moe_experts" and cfg.moe is not None and geom.moe_placement:
        pl = geom.moe_placement
        pe = 3 * d * cfg.moe.d_ff * weight_bytes
        rows = (pl.subgroup_size - 1) * pl.local_count
        if fetch == "demand":
            from repro.core.roofline import demand_budget_rows

            b = budget or demand_budget_rows(
                routed_rows * cfg.moe.top_k, cfg.moe.num_experts,
                pl.local_count,
            )
            rows = (pl.subgroup_size - 1) * min(b, pl.local_count)
        elif fetch in ("predictive", "sync_free"):
            from repro.core.roofline import predictive_budget_rows

            if budget > 0:
                spec = corr = min(budget, pl.local_count)
            else:
                spec, corr = predictive_budget_rows(
                    routed_rows * cfg.moe.top_k, cfg.moe.num_experts,
                    pl.local_count,
                )
            rows = (pl.subgroup_size - 1) * (spec + corr)
        return rows * pe
    if family == "attn_qkv":
        return d * (cfg.q_dim + 2 * cfg.kv_dim) * weight_bytes * frac(
            geom.attn_shards
        )
    if family == "attn_out":
        return cfg.q_dim * d * weight_bytes * frac(geom.attn_shards)
    if family == "dense_ffn":
        f = cfg.d_ff or 0
        if cfg.moe is not None:
            f = max(f, cfg.moe.shared_d_ff, cfg.moe.dense_d_ff)
        return 3 * d * f * weight_bytes * frac(geom.ffn_shards)
    return 0.0


@dataclasses.dataclass(frozen=True)
class _Eligibility:
    """Engine-eligibility facts shared by the auto resolver and
    :func:`effective_policies` — ONE computation of which per-family
    paths the engine can actually lower on this (model x shape x mesh),
    mirroring ``execution``'s predicates."""

    rows: int            # per-rank routed tokens (the demand gate input)
    moe_gather: bool     # gather-mode MoE over a real subgroup
    moe_split_ok: bool   # + single expert axis (split/demand eligible)
    demand_ok: bool      # + partial coverage (rows*topk < remote)
    attn_ok: bool        # attention families split-eligible
    ffn_ok: bool         # dense-FFN family split-eligible


def _engine_eligibility(
    model: Model, shape: InputShape, mesh_sizes: dict[str, int]
) -> _Eligibility:
    cfg, geom = model.cfg, model.geom
    batch_axes, seq_axes = plan_activation_sharding(cfg, shape, mesh_sizes)
    bsh = math.prod(mesh_sizes[a] for a in batch_axes) if batch_axes else 1
    ssh = math.prod(mesh_sizes[a] for a in seq_axes) if seq_axes else 1
    rows = _routed_rows(shape, bsh, ssh)
    pl = geom.moe_placement
    moe_gather = (
        cfg.moe is not None and geom.moe_exec == "gather"
        and pl is not None and pl.subgroup_size > 1
    )
    moe_split_ok = moe_gather and len(geom.expert_axes) == 1
    demand_ok = (
        moe_split_ok
        and rows * cfg.moe.top_k < (pl.subgroup_size - 1) * pl.local_count
    )
    return _Eligibility(
        rows=rows,
        moe_gather=moe_gather,
        moe_split_ok=moe_split_ok,
        demand_ok=demand_ok,
        attn_ok=len(geom.attn_axes) == 1 and geom.attn_shards > 1,
        ffn_ok=len(geom.ffn_axes) == 1 and geom.ffn_shards > 1,
    )


def _auto_cache_rows(
    model: Model,
    shape: InputShape,
    mesh_sizes: dict[str, int],
    hw,
    weight_bytes: int,
) -> int:
    """Auto ``cache_budget`` for the predictive fetch: size the per-layer
    expert residency cache against the analytic HBM residency headroom —
    ``CACHE_HEADROOM_FRAC`` of what ``analytic_residency_bytes`` leaves
    free on the target, divided over the MoE layers, 8-aligned and capped
    at the remote bank (caching more than the remote rows buys nothing).
    Returns 0 (cache off) when the model already fills HBM — correctness
    never depends on the value, only hit rate does."""
    from repro.analysis.roofline_report import analytic_residency_bytes
    from repro.core import roofline

    cfg, geom = model.cfg, model.geom
    pl = geom.moe_placement
    if cfg.moe is None or pl is None:
        return 0
    hw = hw or roofline.GB200
    batch_axes, seq_axes = plan_activation_sharding(cfg, shape, mesh_sizes)
    xp = ExecutionPlan(
        mode="dwdp", phase=shape.phase, batch_axes=batch_axes,
        seq_axes=seq_axes, mesh_sizes=dict(mesh_sizes),
        capacity_factor=1.25, global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        policies=PolicyTable.uniform(fetch="predictive"),
    )
    resident = analytic_residency_bytes(
        cfg, geom, xp, shape, dtype_bytes=weight_bytes
    )
    headroom = max(0.0, hw.hbm_bytes - resident) * CACHE_HEADROOM_FRAC
    n_moe = sum(cfg.is_moe_layer(l) for l in range(cfg.num_layers))
    per_expert = 3 * cfg.d_model * cfg.moe.d_ff * weight_bytes
    rows = int(headroom / max(1, n_moe * per_expert))
    remote = (pl.subgroup_size - 1) * pl.local_count
    return min(remote, rows // 8 * 8)


def resolve_policies(
    model: Model,
    shape: InputShape,
    mesh_sizes: dict[str, int],
    policy: PolicyLike = "auto",
    *,
    hw=None,
    weight_bytes: int = 1,
    hit_rates: Optional[Mapping] = None,
) -> PolicyTable:
    """Resolve a ``policy=`` argument into a concrete :class:`PolicyTable`.

    Explicit tables / dicts / specs pass through (validated); ``None``
    yields the uniform default; ``"auto"`` runs the roofline-guided
    resolver: per family x phase it enumerates the engine-eligible
    (layout, fetch) candidates, scores each full combination with
    ``roofline.modeled_step_time`` (the per-layer DWDP critical path
    ``max(compute + landing, overlapped prefetch) + serial round``
    summed over layers — route-before-gather rounds price serially,
    the predictive speculative round overlaps), and keeps the cheapest
    — so the resolved table's modeled step time is <= every uniform
    policy's by construction (uniform tables compared at their
    engine-effective resolution, :func:`effective_policies`).
    ``fetch="predictive"`` is enumerated at decode shapes only and its
    ``cache_budget`` is sized against the analytic HBM residency
    headroom (:func:`_auto_cache_rows`). Transports are then assigned
    by the bank-size rule (ring_sliced only above
    RING_SLICED_MIN_BYTES).

    After the family-level winner is fixed, the resolver refines it
    with PER-LAYER-GROUP ``moe_experts`` overrides: group by group
    (``roofline.layer_group_names``) it re-scores every eligible
    (layout, fetch) candidate as an override scoped to that group and
    keeps the override only when the full-table modeled step time
    strictly improves — so a mixed table is emitted exactly when the
    model says heterogeneity pays (e.g. ``fetch="demand"`` for a
    layer group whose measured predictor hit rate collapsed, the rest
    staying ``sync_free``). ``hit_rates`` feeds that asymmetry: an
    optional ``{group_name: {"predict_hit": r, "cache_hit": r}}``
    mapping of MEASURED per-group rates (an engine's served telemetry
    — the ``policy="auto-online"`` scheduler's re-resolution input)
    replayed into the scoring in place of the closed-form defaults.
    """
    table = _coerce_policy(policy)
    if table is not None:
        return table

    from repro.core import roofline

    cfg, geom = model.cfg, model.geom
    hw = hw or roofline.GB200
    # Score with the PER-RANK routed token count — the same rows the
    # engine's demand gate (execution.demand_fetch_active) and budget
    # rule (demand_budget_rows) see — so the scorer's demand candidates
    # price exactly the payload the lowered program ships. Eligibility
    # facts are shared with effective_policies (ONE mirror of the
    # engine's predicates).
    elig = _engine_eligibility(model, shape, mesh_sizes)
    rows = tokens = elig.rows
    pl = geom.moe_placement
    moe_split_ok = elig.moe_split_ok
    demand_ok = elig.demand_ok
    attn_split_ok = elig.attn_ok
    ffn_split_ok = elig.ffn_ok
    group = pl.subgroup_size if elig.moe_gather else max(
        geom.attn_shards, geom.ffn_shards, 1
    )

    # -- enumerate (layout, fetch) candidates; preferred (cheaper wire /
    # HBM) first so strict-< scoring keeps them on ties ------------------
    # predictive/sync_free only at decode shapes: the predictor +
    # residency cache need the cross-step PredictState the decode loop
    # threads (any other phase runs them as plain demand, so they could
    # never score better). sync_free leads: same payload rounds, minus
    # the speculative bitmap exchange.
    predictive_ok = demand_ok and shape.phase == "decode"
    moe_cands = (
        [("split", "sync_free"), ("split", "predictive")]
        if predictive_ok else []
    )
    if demand_ok:
        moe_cands.append(("split", "demand"))
    if moe_split_ok:
        moe_cands.append(("split", "all"))
    moe_cands.append(("merged", "all"))
    cache_rows = (
        _auto_cache_rows(model, shape, mesh_sizes, hw, weight_bytes)
        if predictive_ok
        else 0
    )

    def dense_cands(ok: bool) -> list[str]:
        return (["split"] if ok else []) + ["merged"]

    attn_gathered = bool(geom.attn_axes)
    ph_map = ch_map = None
    if hit_rates:
        ph_map = {
            g: float(r["predict_hit"])
            for g, r in hit_rates.items()
            if r.get("predict_hit") is not None
        } or None
        ch_map = {
            g: float(r["cache_hit"])
            for g, r in hit_rates.items()
            if r.get("cache_hit") is not None
        } or None

    def score(tab: PolicyTable) -> float:
        return roofline.modeled_step_time(
            cfg, tokens=tokens, group=group, hw=hw,
            policies=tab, kv_len=shape.seq_len,
            attn_gathered=attn_gathered, weight_bytes=weight_bytes,
            cache_hit=ch_map, predict_hit=ph_map,
        )

    def moe_policy(layout: str, fetch: str) -> GatherPolicy:
        return GatherPolicy(
            layout=layout, fetch=fetch,
            cache_budget=(
                cache_rows if fetch in ("predictive", "sync_free") else 0
            ),
        )

    best, best_t = None, float("inf")
    for moe_layout, fetch in moe_cands:
        moe_pol = moe_policy(moe_layout, fetch)
        for qkv_layout in dense_cands(attn_split_ok):
            for out_layout in dense_cands(attn_split_ok):
                for ffn_layout in dense_cands(ffn_split_ok):
                    cand = PolicyTable(
                        default=GatherPolicy(layout=ffn_layout),
                        families=(
                            ("moe_experts", moe_pol),
                            ("attn_qkv", GatherPolicy(layout=qkv_layout)),
                            ("attn_out", GatherPolicy(layout=out_layout)),
                            ("dense_ffn", GatherPolicy(layout=ffn_layout)),
                        ),
                    )
                    t = score(cand)
                    if t < best_t:
                        best, best_t = cand, t

    # -- per-layer-group refinement: moe_experts overrides, group by
    # group, kept only on strict full-table improvement (the PR 4
    # leftover — e.g. fetch="demand" scoped to the one layer group
    # whose measured hit rate collapsed) -------------------------------
    if cfg.moe is not None and pl is not None and len(moe_cands) > 1:
        gnames = roofline.layer_group_names(cfg)
        moe_groups = sorted(
            {gnames[l] for l in range(cfg.num_layers) if cfg.is_moe_layer(l)}
        )
        overrides: list[tuple[str, str, GatherPolicy]] = []
        for gname in moe_groups:
            chosen = None
            for moe_layout, fetch in moe_cands:
                pol = moe_policy(moe_layout, fetch)
                if pol == best.family("moe_experts"):
                    continue
                cand = dataclasses.replace(
                    best,
                    overrides=tuple(overrides)
                    + ((gname, "moe_experts", pol),),
                )
                t = score(cand)
                if t < best_t:
                    chosen, best_t = (gname, "moe_experts", pol), t
            if chosen is not None:
                overrides.append(chosen)
        if overrides:
            best = dataclasses.replace(best, overrides=tuple(overrides))

    # -- transport per family: bank-size rule -----------------------------
    def with_transport(name: str, pol: GatherPolicy) -> GatherPolicy:
        bank = _family_remote_bank_bytes(
            cfg, geom, name, pol.fetch, pol.budget, weight_bytes,
            routed_rows=rows,
        )
        transport = (
            "ring_sliced" if bank >= RING_SLICED_MIN_BYTES else "allgather"
        )
        return dataclasses.replace(pol, transport=transport)

    fams = tuple(
        (name, with_transport(name, pol)) for name, pol in best.families
    )
    ovr = tuple(
        (g, name, with_transport(name, pol))
        for g, name, pol in best.overrides
    )
    return dataclasses.replace(best, families=fams, overrides=ovr)


def effective_policies(
    model: Model,
    shape: InputShape,
    mesh_sizes: dict[str, int],
    table: PolicyTable,
) -> PolicyTable:
    """Demote a table's per-family policies to what the ENGINE actually
    lowers on this (model x shape x mesh): ``split`` falls back to
    ``merged`` for families whose split path cannot engage (multi-axis
    gathers, single-shard axes), ``demand``/``predictive`` fall back to
    ``all`` outside partial coverage, and ``predictive`` runs as
    ``demand`` outside decode (no cross-step PredictState). Use this to
    price a user table honestly — the roofline credits a layout's
    savings only where the engine can realize them."""
    elig = _engine_eligibility(model, shape, mesh_sizes)

    def demote(name: str, pol: GatherPolicy) -> GatherPolicy:
        ok = {"moe_experts": elig.moe_split_ok, "attn_qkv": elig.attn_ok,
              "attn_out": elig.attn_ok, "dense_ffn": elig.ffn_ok}[name]
        layout = pol.layout if (pol.layout == "merged" or ok) else "merged"
        fetch = pol.fetch if name == "moe_experts" else "all"
        if fetch in ("predictive", "sync_free") and shape.phase != "decode":
            fetch = "demand"
        if fetch != "all" and not elig.demand_ok:
            fetch = "all"
        if fetch == "all":
            return GatherPolicy(layout=layout, transport=pol.transport,
                                num_slices=pol.num_slices)
        return dataclasses.replace(
            pol, layout=layout, fetch=fetch,
            # demand carries no residency cache — dropping it here keeps
            # the demoted policy constructible (validated on replace)
            cache_budget=(
                pol.cache_budget
                if fetch in ("predictive", "sync_free") else 0
            ),
        )

    fams = tuple(
        (name, demote(name, table.family(name))) for name in GATHER_FAMILIES
    )
    # per-layer-group overrides demote by the same rules: the engine
    # applies the identical predicates per group, so pricing a mixed
    # table keeps the same honesty contract
    ovr = tuple(
        (g, name, demote(name, pol)) for g, name, pol in table.overrides
    )
    return PolicyTable(default=table.default, families=fams, overrides=ovr)


# --------------------------------------------------------------------------
# Health-degradation ladder (fault tolerance).
# --------------------------------------------------------------------------
#: Aggressiveness rank of the expert-fetch modes: lower = more wire
#: savings, more exposure to peer faults. The HealthMonitor demotes a
#: serving policy DOWN this ladder (sync_free/predictive -> demand ->
#: all) when a peer turns persistently bad — each step removes one
#: dependency on per-peer cooperation (the residency cache / speculative
#: round first, then the demand rounds entirely) — and promotes back on
#: recovery.
_FETCH_RANK = {"sync_free": 0, "predictive": 1, "demand": 2, "all": 3}


def degrade_policy_table(table: PolicyTable, fetch: str) -> PolicyTable:
    """Rewrite every entry of ``table`` whose expert fetch is MORE
    aggressive than ``fetch`` down to ``fetch`` (entries already at or
    below it are untouched). Demotion to ``"demand"`` drops the
    residency cache (it rides the predictive rounds); demotion to
    ``"all"`` drops the demand budget too, keeping layout/transport."""
    if fetch not in _FETCH_RANK:
        raise ValueError(
            f"unknown fetch {fetch!r}; expected one of "
            f"{tuple(_FETCH_RANK)}"
        )

    def demote(pol: GatherPolicy) -> GatherPolicy:
        if _FETCH_RANK[pol.fetch] >= _FETCH_RANK[fetch]:
            return pol
        if fetch == "all":
            return GatherPolicy(layout=pol.layout, transport=pol.transport,
                                num_slices=pol.num_slices)
        return dataclasses.replace(pol, fetch=fetch, cache_budget=0)

    return PolicyTable(
        default=demote(table.default),
        families=tuple((n, demote(p)) for n, p in table.families),
        overrides=tuple((g, n, demote(p)) for g, n, p in table.overrides),
    )


def degradation_ladder(
    table: PolicyTable,
) -> tuple[tuple[str, PolicyTable, Optional[tuple]], ...]:
    """The engine's fault-degradation ladder for a RESOLVED policy
    table: ``((label, table, exclude_peers), ...)`` from level 0 (as
    configured) down through the all-gather fail-silent floor to the
    terminal ``"reshard"`` rung, with no-op fail-silent levels
    collapsed. Labels are the expert-fetch mode each level runs.

    ``exclude_peers`` is ``()`` for the ordinary rungs. When the root
    fetch is predictive/sync_free a finer-grained ``"<fetch>+excl"``
    rung sits between it and the demand demotion: same table, but with
    the (runtime-chosen) worst peer's rows dropped from the speculative
    plan and residency cache — ``None`` here means "the engine fills in
    its HealthMonitor's worst peer when stepping onto the rung".

    The final ``"reshard"`` rung is the FAIL-STOP response — a rank
    died, the subgroup shrinks to the survivors and the split banks
    re-shard over ``G'-1``. It runs the all-gather table (no per-peer
    payload rounds during recovery) but is NOT reachable by the
    HealthMonitor's fail-silent demotions (they cap at ``"all"``): only
    an explicit rank-death quarantine steps onto it, and the post-
    recovery engine runs at the shrunk mesh sizes."""
    root_fetch = table.family("moe_experts").fetch
    out: list[tuple[str, PolicyTable, Optional[tuple]]] = [
        (root_fetch, table, ())
    ]
    if root_fetch in ("predictive", "sync_free"):
        out.append((f"{root_fetch}+excl", table, None))
    for fetch in ("demand", "all"):
        t = degrade_policy_table(table, fetch)
        if t != out[-1][1]:
            out.append((fetch, t, ()))
    out.append(("reshard", degrade_policy_table(table, "all"), ()))
    return tuple(out)


def make_execution_plan(
    model: Model,
    shape: InputShape,
    mesh_sizes: dict[str, int],
    *,
    mode: str = "dwdp",
    policy: PolicyLike = None,
    capacity_factor: float = 1.25,
    block_causal: bool = False,
    decode_attn: str = "gather",
    capacity_from: str = "local",
    hw=None,
    fault_spec=None,
    validate_fetch: bool = False,
    exclude_peers: tuple = (),
    # -- deprecated flat knobs (build a uniform PolicyTable) --------------
    prefetch: Optional[str] = None,
    num_slices: Optional[int] = None,
    weight_layout: Optional[str] = None,
    expert_fetch: Optional[str] = None,
    demand_budget: Optional[int] = None,
    moe_ffn: Optional[str] = None,
) -> ExecutionPlan:
    assert mode in MODES
    legacy = {
        k: v
        for k, v in dict(
            prefetch=prefetch, num_slices=num_slices,
            weight_layout=weight_layout, expert_fetch=expert_fetch,
            demand_budget=demand_budget, moe_ffn=moe_ffn,
        ).items()
        if v is not None
    }
    if legacy:
        warnings.warn(
            f"{', '.join(sorted(legacy))}= are deprecated flat knobs "
            "(pre-GatherPolicy spelling; moe_ffn is the PR 1 name) — pass "
            "policy= (a PolicyTable / per-family dict / spec string / "
            '"auto") instead; building a uniform PolicyTable',
            DeprecationWarning,
            stacklevel=2,
        )
        if policy is not None:
            raise ValueError(
                f"conflicting policy= and deprecated flat knobs "
                f"{sorted(legacy)} — pass only policy="
            )
        if "moe_ffn" in legacy:
            wl = legacy.get("weight_layout")
            if wl is not None and wl != legacy["moe_ffn"]:
                raise ValueError(
                    f"conflicting weight_layout={wl!r} and deprecated "
                    f"moe_ffn={legacy['moe_ffn']!r} — pass only "
                    "weight_layout (or better, policy=)"
                )
            legacy.setdefault("weight_layout", legacy["moe_ffn"])
        policy = PolicyTable.uniform(
            layout=legacy.get("weight_layout", "split"),
            fetch=legacy.get("expert_fetch", "all"),
            transport=legacy.get("prefetch", "allgather"),
            num_slices=legacy.get("num_slices", 4),
            budget=legacy.get("demand_budget", 0),
        )
    policies = resolve_policies(model, shape, mesh_sizes, policy, hw=hw)
    known_groups = {g.name for g in model.plan}
    for g, fam, _ in policies.overrides:
        if g not in known_groups:
            raise ValueError(
                f"policy override names unknown layer group {g!r} "
                f"(for family {fam!r}); this model's groups are "
                f"{sorted(known_groups)}"
            )
    assert capacity_from in CAPACITY_FROM
    if isinstance(fault_spec, str):
        from repro.core.faults import FaultSpec

        fault_spec = FaultSpec.parse(fault_spec)
    batch_axes, seq_axes = plan_activation_sharding(
        model.cfg, shape, mesh_sizes
    )
    return ExecutionPlan(
        mode=mode,
        phase=shape.phase,
        batch_axes=batch_axes,
        seq_axes=seq_axes,
        mesh_sizes=dict(mesh_sizes),
        capacity_factor=capacity_factor,
        global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        policies=policies,
        block_causal=block_causal and not seq_axes,
        decode_attn=decode_attn,
        capacity_from=capacity_from,
        fault_spec=fault_spec,
        validate_fetch=validate_fetch,
        exclude_peers=tuple(int(p) for p in exclude_peers),
    )


# --------------------------------------------------------------------------
# Input / output / state specs.
# --------------------------------------------------------------------------
def input_pspecs(model: Model, xp: ExecutionPlan) -> dict:
    b, s = xp.batch_spec(), xp.seq_spec()
    if xp.phase == "decode":
        return {"token": P(b, None)}
    specs = {}
    if model.cfg.modality == "text":
        specs["tokens"] = P(b, s)
    else:
        specs["embeds"] = P(b, s, None)
    if xp.phase == "train":
        specs["labels"] = P(b, s)
    return specs


def output_pspecs(model: Model, xp: ExecutionPlan) -> dict:
    b = xp.batch_spec()
    if xp.phase == "decode":
        return {"next_token": P(b, None), "state": state_pspecs(model, xp)}
    if xp.phase == "prefill":
        # last-token logits: vocab-sharded over "model" unless the batch
        # already covers the model axis (then the head is gathered)
        if AXIS_MODEL in xp.batch_axes:
            return {"last_logits": P(b, None)}
        return {"last_logits": P(b, AXIS_MODEL)}
    return {"loss": P(), "metrics": P()}


def state_pspecs(model: Model, xp: ExecutionPlan):
    return decode_state_pspecs(model, xp.batch_axes, xp.seq_axes)
