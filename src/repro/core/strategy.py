"""Strategy planner: DWDP / DEP / replicated execution plans.

``make_execution_plan`` decides, per (arch x input-shape x mesh):

- which mesh axes carry the batch (pure data parallelism — DWDP's ranks),
- which axes shard the sequence / KV cache (when the batch is too small
  to cover the mesh),
- how the FFN/MoE path executes:
    * ``dwdp``       — weights move (async gather or rotate), activations
                       never cross ranks. The paper's strategy.
    * ``dep``        — activations move (all_to_all for MoE, gather +
                       reduce-scatter for dense TP). The paper's baseline.
    * ``replicated`` — weights fully replicated, pure DP (reference).

- how gathered weights are *represented* (``weight_layout``): "split"
  (the default §4.2 split-bank fast path — one engine-wide switch, per
  Shift-Parallelism-style layout design, covering MoE experts, attention
  projections and dense-FFN slices alike) or "merged" (the legacy
  explicit-merge baseline),
- how MoE expert weights are *selected* for the gather
  (``expert_fetch``): "all" (every remote expert every layer — the
  split/merged prefetch) or "demand" (route-before-gather: only the
  experts the current layer's routing activated cross the wire, padded
  to a static ``demand_budget`` per peer, with an exact fallback to the
  full remote gather on budget overflow),
- and how MoE capacity is derived (``capacity_from``): from the local
  token count ("local") or layout-invariantly per row from the global
  shape ("global" — deterministic drops across batch-sharding reshapes),

and derives the PartitionSpecs for params, inputs, decode state, outputs.
"""
from __future__ import annotations

import dataclasses
import math
import warnings
from typing import Any, Optional

from jax.sharding import PartitionSpec as P

from repro.configs.base import ArchConfig, BlockKind, InputShape
from repro.models.cache import decode_state_pspecs
from repro.models.transformer import AXIS_MODEL, Model

PyTree = Any

MODES = ("dwdp", "dep", "replicated", "hybrid")
PREFETCH_MODES = ("allgather", "ring", "ring_sliced")
WEIGHT_LAYOUTS = ("merged", "split")
MOE_FFN_MODES = WEIGHT_LAYOUTS  # deprecated alias (PR 1 name)
CAPACITY_FROM = ("local", "global")
EXPERT_FETCH = ("all", "demand")


@dataclasses.dataclass(frozen=True)
class ExecutionPlan:
    mode: str                        # dwdp | dep | replicated
    phase: str                       # train | prefill | decode
    prefetch: str                    # allgather | ring | ring_sliced
    num_slices: int                  # for ring_sliced
    batch_axes: tuple[str, ...]
    seq_axes: tuple[str, ...]
    mesh_sizes: dict[str, int]       # ordered as the mesh axes
    capacity_factor: float
    global_batch: int
    seq_len: int
    block_causal: bool = False   # skip fully-masked KV blocks (needs
                                 # unsharded sequence; see DESIGN.md §9)
    decode_attn: str = "gather"  # "gather" weights per layer, or "qgather":
                                 # keep weights sharded and move the (tiny)
                                 # q/k/v activations instead (beyond-paper)
    weight_layout: str = "split"
    # Engine-wide gathered-weight representation, covering every family
    # the weights-move modes prefetch (MoE experts, attention QKV/O,
    # dense-FFN slices):
    #   "split" (default): §4.2 fast path — the prefetch pipeline emits a
    #     (local_bank, remote_bank) SplitBank; only the remote fraction
    #     crosses the wire and the fused split kernels consume both banks
    #     directly. No merged gathered-weight buffer of ANY family is
    #     ever materialized (asserted structurally on the lowering in
    #     tests/test_multidevice.py).
    #   "merged": legacy explicit-merge mode — prefetch lands the full
    #     canonical (num_padded, ...) / (S, D, F/S) buffer (the §4.2
    #     merge-copy HBM tax) and the plain merged consumers run. Kept
    #     selectable as the paper's baseline and for families the split
    #     path does not cover (multi-axis ZeRO-wide gathers fall back to
    #     it automatically).
    expert_fetch: str = "all"
    # MoE expert-gather selection (only meaningful on the split DWDP
    # gather path):
    #   "all" (default): every remote expert crosses the wire every MoE
    #     layer (the PR 1/2 prefetch — demand-oblivious).
    #   "demand": route-before-gather. The engine inverts the layer
    #     structure for eligible MoE layers: routing (local router
    #     weights, a cheap (T,D)@(D,E) matmul) runs first, then a tiny
    #     index-exchange round + a payload round fetch exactly the
    #     activated remote experts, padded to a static ``demand_budget``
    #     per peer. Auto-eligible only when expected coverage is partial
    #     (local rows * top_k < remote expert count — decode and small-
    #     batch prefill); otherwise the layer silently keeps the "all"
    #     gather, which would be cheaper anyway. Budget overflow falls
    #     back per-layer to the full remote gather, so results are
    #     always exact.
    demand_budget: int = 0
    # Per-peer demand-fetch row budget (static — sets the payload-round
    # wire bytes). 0 = auto: twice the expected per-peer distinct-expert
    # coverage, rounded up to a multiple of 8 (see
    # execution.resolve_demand_budget); clamped to the per-rank expert
    # count, at which point overflow is impossible.
    capacity_from: str = "local"
    # MoE capacity derivation:
    #   "local": capacity_for(local token count) — the PR 1 behavior.
    #     Layouts with different shard counts legitimately drop different
    #     tokens near the capacity edge (the diagnosed llama4 rotate
    #     "divergence").
    #   "global": capacity is derived per ROW from the global sequence
    #     length, and capacity competition is restricted to the row — the
    #     drop set becomes a function of the row alone, so DWDP ranks
    #     drop identical tokens across any batch-sharding mesh reshape
    #     (batch determinism for serving; see execution._moe_apply).

    @property
    def moe_ffn(self) -> str:
        """Deprecated PR 1 alias for ``weight_layout`` (MoE was the only
        split family then); reads forward to the generalized flag."""
        return self.weight_layout

    @property
    def batch_shards(self) -> int:
        return math.prod(self.mesh_sizes[a] for a in self.batch_axes)

    @property
    def seq_shards(self) -> int:
        return math.prod(self.mesh_sizes[a] for a in self.seq_axes)

    @property
    def local_batch(self) -> int:
        return self.global_batch // self.batch_shards

    @property
    def local_seq(self) -> int:
        return self.seq_len // self.seq_shards

    def batch_spec(self) -> Any:
        return self.batch_axes if self.batch_axes else None

    def seq_spec(self) -> Any:
        return self.seq_axes if self.seq_axes else None


def plan_activation_sharding(
    cfg: ArchConfig, shape: InputShape, mesh_sizes: dict[str, int]
) -> tuple[tuple[str, ...], tuple[str, ...]]:
    """Greedy: batch over (pod, data, model) while divisible; remaining axes
    shard the sequence/KV if divisible and the architecture permits it.

    sLSTM recurrence is sequential in time (h_{t-1} feeds the gates) so
    sequence sharding is impossible for xLSTM — those archs replicate over
    the leftover axes (noted in DESIGN.md). RG-LRU and mLSTM are linear
    given the gates; RG-LRU cross-shard fixup is implemented, so hybrids
    may seq-shard.
    """
    order = [
        a for a in ("pod", "data", "model")
        if mesh_sizes.get(a, 1) > 1
    ]
    batch_axes: list[str] = []
    rem = shape.global_batch
    for a in order:
        if rem % mesh_sizes[a] == 0:
            batch_axes.append(a)
            rem //= mesh_sizes[a]
        else:
            break
    left = [a for a in order if a not in batch_axes]
    seq_axes: list[str] = []
    can_seq_shard = not any(
        k in (BlockKind.SLSTM, BlockKind.MLSTM) for k in cfg.block_pattern
    )
    if can_seq_shard:
        s = shape.seq_len
        for a in left:
            if s % mesh_sizes[a] == 0:
                seq_axes.append(a)
                s //= mesh_sizes[a]
            else:
                break
    return tuple(batch_axes), tuple(seq_axes)


def make_execution_plan(
    model: Model,
    shape: InputShape,
    mesh_sizes: dict[str, int],
    *,
    mode: str = "dwdp",
    prefetch: str = "allgather",
    num_slices: int = 4,
    capacity_factor: float = 1.25,
    block_causal: bool = False,
    decode_attn: str = "gather",
    weight_layout: Optional[str] = None,
    capacity_from: str = "local",
    expert_fetch: str = "all",
    demand_budget: int = 0,
    moe_ffn: Optional[str] = None,
) -> ExecutionPlan:
    assert mode in MODES and prefetch in PREFETCH_MODES
    if moe_ffn is not None:
        warnings.warn(
            "moe_ffn= is deprecated (PR 1 spelling); the split layout now "
            "covers every gathered family — pass weight_layout= instead",
            DeprecationWarning,
            stacklevel=2,
        )
        if weight_layout is not None and moe_ffn != weight_layout:
            raise ValueError(
                f"conflicting weight_layout={weight_layout!r} and deprecated "
                f"moe_ffn={moe_ffn!r} — pass only weight_layout"
            )
    if weight_layout is None:
        # moe_ffn is the deprecated PR 1 spelling; honor it when the new
        # flag is not given, else default to the split fast path.
        weight_layout = moe_ffn if moe_ffn is not None else "split"
    assert weight_layout in WEIGHT_LAYOUTS
    assert capacity_from in CAPACITY_FROM
    assert expert_fetch in EXPERT_FETCH
    if expert_fetch == "demand" and weight_layout != "split":
        raise ValueError(
            'expert_fetch="demand" requires the split weight layout (the '
            "demand bank is a split-bank refinement); got "
            f"weight_layout={weight_layout!r}"
        )
    assert demand_budget >= 0
    batch_axes, seq_axes = plan_activation_sharding(
        model.cfg, shape, mesh_sizes
    )
    return ExecutionPlan(
        mode=mode,
        phase=shape.phase,
        prefetch=prefetch,
        num_slices=num_slices,
        batch_axes=batch_axes,
        seq_axes=seq_axes,
        mesh_sizes=dict(mesh_sizes),
        capacity_factor=capacity_factor,
        global_batch=shape.global_batch,
        seq_len=shape.seq_len,
        block_causal=block_causal and not seq_axes,
        decode_attn=decode_attn,
        weight_layout=weight_layout,
        expert_fetch=expert_fetch,
        demand_budget=demand_budget,
        capacity_from=capacity_from,
    )


# --------------------------------------------------------------------------
# Input / output / state specs.
# --------------------------------------------------------------------------
def input_pspecs(model: Model, xp: ExecutionPlan) -> dict:
    b, s = xp.batch_spec(), xp.seq_spec()
    if xp.phase == "decode":
        return {"token": P(b, None)}
    specs = {}
    if model.cfg.modality == "text":
        specs["tokens"] = P(b, s)
    else:
        specs["embeds"] = P(b, s, None)
    if xp.phase == "train":
        specs["labels"] = P(b, s)
    return specs


def output_pspecs(model: Model, xp: ExecutionPlan) -> dict:
    b = xp.batch_spec()
    if xp.phase == "decode":
        return {"next_token": P(b, None), "state": state_pspecs(model, xp)}
    if xp.phase == "prefill":
        # last-token logits: vocab-sharded over "model" unless the batch
        # already covers the model axis (then the head is gathered)
        if AXIS_MODEL in xp.batch_axes:
            return {"last_logits": P(b, None)}
        return {"last_logits": P(b, AXIS_MODEL)}
    return {"loss": P(), "metrics": P()}


def state_pspecs(model: Model, xp: ExecutionPlan):
    return decode_state_pspecs(model, xp.batch_axes, xp.seq_axes)
