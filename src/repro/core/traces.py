"""Seeded synthetic routing traces for the predictor test-bench.

Real MoE decode routing is heavily skewed: a small hot set of experts
takes most of the traffic (Zipf-like popularity), each *sequence* keeps
re-routing to "its" experts (affinity), and the hot set drifts slowly
with generation depth (phase changes). The sync-free mode's acceptance
criterion — speculative hit rate >= 0.9 with a budget far below the
expert count — is a statement about routing with this structure, not
about uniform-random draws (which no budget-bounded predictor can beat).

:func:`zipf_routing_trace` generates such traces deterministically from
a seed: ``(steps, rows, top_k)`` expert ids drawn without replacement
per row per step from a mixture of

- a global Zipf popularity ranking (exponent ``alpha``) over a seeded
  expert permutation,
- a per-row hot set (each row's own permutation of the top experts),
  mixed in with probability ``affinity``,
- and slow drift: every ``drift_every`` steps the global ranking
  rotates by one hot slot, so traces exercise the predictors' decay
  (EMA / affinity / position-bucket) rather than a frozen distribution.

Pure NumPy (the generator feeds host-side test loops and benchmark
drivers; nothing here traces into XLA).
"""
from __future__ import annotations

import numpy as np


def zipf_scores(num_experts: int, alpha: float = 1.2) -> np.ndarray:
    """Unnormalized Zipf popularity by rank: ``1 / rank^alpha``."""
    if num_experts < 1:
        raise ValueError(f"num_experts must be >= 1, got {num_experts}")
    return 1.0 / np.arange(1, num_experts + 1, dtype=np.float64) ** alpha


def zipf_routing_trace(
    steps: int,
    rows: int,
    num_experts: int,
    top_k: int,
    *,
    alpha: float = 1.2,
    affinity: float = 0.6,
    drift_every: int = 0,
    seed: int = 0,
) -> np.ndarray:
    """Seeded skewed routing trace ``(steps, rows, top_k)`` int32.

    ``alpha``: Zipf exponent of the global popularity ranking (0 =
    uniform routing — the adversarial floor for any predictor).
    ``affinity``: probability mass of each row's personal hot set (its
    own seeded permutation of the globally-hottest ``4 * top_k``
    experts), mixed into the global distribution per row.
    ``drift_every``: if > 0, rotate the global ranking by one position
    every that many steps (slow hot-set drift).

    Per row and step the ``top_k`` ids are drawn WITHOUT replacement
    (matching a router's distinct top-k), so every trace slots directly
    into :func:`repro.core.prefetch.routed_bitmaps`.
    """
    if top_k > num_experts:
        raise ValueError(f"top_k {top_k} > num_experts {num_experts}")
    if not 0.0 <= affinity <= 1.0:
        raise ValueError(f"affinity must be in [0, 1], got {affinity}")
    rng = np.random.default_rng(seed)
    base = zipf_scores(num_experts, alpha)
    global_rank = rng.permutation(num_experts)
    hot_n = min(num_experts, 4 * top_k)
    # each row's personal hot set: a seeded shuffle of the global hot set
    row_hot = np.stack(
        [rng.permutation(hot_n) for _ in range(rows)]
    )
    out = np.empty((steps, rows, top_k), np.int32)
    for s in range(steps):
        if drift_every and s and s % drift_every == 0:
            global_rank = np.roll(global_rank, 1)
        p_global = np.empty(num_experts, np.float64)
        p_global[global_rank] = base
        p_global /= p_global.sum()
        for r in range(rows):
            p = (1.0 - affinity) * p_global
            hot_ids = global_rank[row_hot[r]]
            # the row's hot mass, itself rank-skewed within the hot set
            p[hot_ids] += affinity * (base[:hot_n] / base[:hot_n].sum())
            p /= p.sum()
            out[s, r] = rng.choice(
                num_experts, size=top_k, replace=False, p=p
            ).astype(np.int32)
    return out


def from_served_trace(
    bitmaps: np.ndarray,
    top_k: int,
) -> np.ndarray:
    """Convert REAL routed-expert bitmaps captured from a serving run
    (``GenerationServer.routed_bitmaps`` per decode step) into the
    ``(steps, rows, top_k)`` trace format every predictor harness and
    bench consumes — so predictor tuning can replay served routing
    instead of synthetic Zipf draws.

    ``bitmaps``: ``(steps, ranks, num_experts)`` bool (or
    ``(steps, num_experts)`` for a single rank). Each rank's activated
    set per step is split into ceil(n_active / top_k) trace rows of
    ``top_k`` DISTINCT ids (the without-replacement contract of
    :func:`zipf_routing_trace`); every rank keeps a FIXED span of output
    rows across steps (sized by its busiest step) so row identity — the
    signal the affinity predictor learns — survives the conversion.
    Rows with fewer than ``top_k`` active ids are padded with that
    rank's trace-hottest ids not already in the row (trace-global
    hottest as fallback), so padding follows the served skew rather
    than inventing uniform mass."""
    bm = np.asarray(bitmaps).astype(bool)
    if bm.ndim == 2:
        bm = bm[:, None, :]
    if bm.ndim != 3:
        raise ValueError(
            f"bitmaps must be (steps, ranks, E) or (steps, E); "
            f"got shape {bm.shape}"
        )
    steps, ranks, e = bm.shape
    if top_k < 1 or top_k > e:
        raise ValueError(f"top_k must be in [1, {e}], got {top_k}")
    # per-rank and global hotness over the whole trace (padding order)
    rank_counts = bm.sum(axis=0)                      # (ranks, E)
    global_hot = np.argsort(-rank_counts.sum(axis=0), kind="stable")
    # fixed per-rank row spans, sized by the busiest step
    per_rank_rows = np.maximum(
        1, -(-bm.sum(axis=2).max(axis=0) // top_k)
    )                                                  # (ranks,)
    offsets = np.concatenate([[0], np.cumsum(per_rank_rows)])
    total_rows = int(offsets[-1])
    out = np.empty((steps, total_rows, top_k), np.int32)
    for r in range(ranks):
        hot_r = np.argsort(-rank_counts[r], kind="stable")
        pad_order = list(dict.fromkeys(
            [*hot_r.tolist(), *global_hot.tolist()]
        ))
        for s in range(steps):
            active = np.flatnonzero(bm[s, r]).tolist()
            for c in range(int(per_rank_rows[r])):
                ids = active[c * top_k:(c + 1) * top_k]
                if len(ids) < top_k:
                    have = set(ids)
                    for x in pad_order:
                        if len(ids) == top_k:
                            break
                        if x not in have:
                            ids.append(int(x))
                            have.add(x)
                out[s, offsets[r] + c] = ids
    return out


def predictor_hit_rate(
    trace: np.ndarray,
    num_experts: int,
    subgroup_size: int,
    *,
    budget: int,
    rich: bool = True,
) -> float:
    """Replay one rank's mirrored predictor over a routing trace and
    return the speculative hit rate — the public spelling of the
    sync-free acceptance harness, usable on served traces
    (:func:`from_served_trace`) as well as synthetic ones.

    Predicts BEFORE each step from state folded on the steps so far
    (pure :mod:`repro.core.prefetch` arithmetic — exactly what both
    transfer endpoints run), scores hits against the step's actual
    remote wanted set from subgroup position 0, and skips the cold-start
    step (nothing can hit it)."""
    import jax.numpy as jnp

    from repro.core import prefetch
    from repro.core.placement import make_placement

    trace = np.asarray(trace)
    if trace.ndim != 3:
        raise ValueError(
            f"trace must be (steps, rows, top_k), got {trace.shape}"
        )
    pl = make_placement(num_experts, subgroup_size)
    e = pl.num_padded
    steps, rows, _ = trace.shape
    own = jnp.arange(e) // pl.local_count == 0
    ema = jnp.zeros(e)
    prev = jnp.zeros(e, bool)
    posb = jnp.zeros((prefetch.N_POS_BUCKETS, e))
    aff = jnp.zeros((rows, e))
    sigw = jnp.zeros(2)
    sig = jnp.zeros((2, e))
    hit = want = 0.0
    for s in range(steps):
        extra = prefetch.predict_extra_score(sig, sigw) if rich else None
        spec = prefetch.predict_bitmap(
            prev, ema, pl, budget=budget, extra_score=extra
        )
        routed = prefetch.routed_bitmaps(jnp.asarray(trace[s]), e)
        buckets = prefetch.position_buckets(jnp.full((rows,), s))
        wanted_remote = jnp.any(routed, axis=0) & ~own
        if s > 0:
            hit += float(jnp.sum(wanted_remote & spec))
            want += float(jnp.sum(wanted_remote))
        prev, ema, aff, posb, sig, sigw = prefetch.update_predictor(
            ema, aff, posb, sigw, routed, buckets
        )
    return hit / max(want, 1.0)


def trace_skew(trace: np.ndarray, num_experts: int) -> float:
    """Fraction of all draws landing in the trace's own top-``k`` hottest
    experts, where ``k = top_k`` of the trace — 1.0 for a frozen hot set,
    ``top_k / num_experts`` for uniform routing. A quick scalar check
    that a generated trace is actually skewed."""
    k = trace.shape[-1]
    counts = np.bincount(trace.reshape(-1), minlength=num_experts)
    top = np.sort(counts)[::-1][:k].sum()
    return float(top) / float(trace.size)
