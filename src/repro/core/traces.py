"""Seeded synthetic routing traces for the predictor test-bench.

Real MoE decode routing is heavily skewed: a small hot set of experts
takes most of the traffic (Zipf-like popularity), each *sequence* keeps
re-routing to "its" experts (affinity), and the hot set drifts slowly
with generation depth (phase changes). The sync-free mode's acceptance
criterion — speculative hit rate >= 0.9 with a budget far below the
expert count — is a statement about routing with this structure, not
about uniform-random draws (which no budget-bounded predictor can beat).

:func:`zipf_routing_trace` generates such traces deterministically from
a seed: ``(steps, rows, top_k)`` expert ids drawn without replacement
per row per step from a mixture of

- a global Zipf popularity ranking (exponent ``alpha``) over a seeded
  expert permutation,
- a per-row hot set (each row's own permutation of the top experts),
  mixed in with probability ``affinity``,
- and slow drift: every ``drift_every`` steps the global ranking
  rotates by one hot slot, so traces exercise the predictors' decay
  (EMA / affinity / position-bucket) rather than a frozen distribution.

Pure NumPy (the generator feeds host-side test loops and benchmark
drivers; nothing here traces into XLA).
"""
from __future__ import annotations

import numpy as np


def zipf_scores(num_experts: int, alpha: float = 1.2) -> np.ndarray:
    """Unnormalized Zipf popularity by rank: ``1 / rank^alpha``."""
    if num_experts < 1:
        raise ValueError(f"num_experts must be >= 1, got {num_experts}")
    return 1.0 / np.arange(1, num_experts + 1, dtype=np.float64) ** alpha


def zipf_routing_trace(
    steps: int,
    rows: int,
    num_experts: int,
    top_k: int,
    *,
    alpha: float = 1.2,
    affinity: float = 0.6,
    drift_every: int = 0,
    seed: int = 0,
) -> np.ndarray:
    """Seeded skewed routing trace ``(steps, rows, top_k)`` int32.

    ``alpha``: Zipf exponent of the global popularity ranking (0 =
    uniform routing — the adversarial floor for any predictor).
    ``affinity``: probability mass of each row's personal hot set (its
    own seeded permutation of the globally-hottest ``4 * top_k``
    experts), mixed into the global distribution per row.
    ``drift_every``: if > 0, rotate the global ranking by one position
    every that many steps (slow hot-set drift).

    Per row and step the ``top_k`` ids are drawn WITHOUT replacement
    (matching a router's distinct top-k), so every trace slots directly
    into :func:`repro.core.prefetch.routed_bitmaps`.
    """
    if top_k > num_experts:
        raise ValueError(f"top_k {top_k} > num_experts {num_experts}")
    if not 0.0 <= affinity <= 1.0:
        raise ValueError(f"affinity must be in [0, 1], got {affinity}")
    rng = np.random.default_rng(seed)
    base = zipf_scores(num_experts, alpha)
    global_rank = rng.permutation(num_experts)
    hot_n = min(num_experts, 4 * top_k)
    # each row's personal hot set: a seeded shuffle of the global hot set
    row_hot = np.stack(
        [rng.permutation(hot_n) for _ in range(rows)]
    )
    out = np.empty((steps, rows, top_k), np.int32)
    for s in range(steps):
        if drift_every and s and s % drift_every == 0:
            global_rank = np.roll(global_rank, 1)
        p_global = np.empty(num_experts, np.float64)
        p_global[global_rank] = base
        p_global /= p_global.sum()
        for r in range(rows):
            p = (1.0 - affinity) * p_global
            hot_ids = global_rank[row_hot[r]]
            # the row's hot mass, itself rank-skewed within the hot set
            p[hot_ids] += affinity * (base[:hot_n] / base[:hot_n].sum())
            p /= p.sum()
            out[s, r] = rng.choice(
                num_experts, size=top_k, replace=False, p=p
            ).astype(np.int32)
    return out


def trace_skew(trace: np.ndarray, num_experts: int) -> float:
    """Fraction of all draws landing in the trace's own top-``k`` hottest
    experts, where ``k = top_k`` of the trace — 1.0 for a frozen hot set,
    ``top_k / num_experts`` for uniform routing. A quick scalar check
    that a generated trace is actually skewed."""
    k = trace.shape[-1]
    counts = np.bincount(trace.reshape(-1), minlength=num_experts)
    top = np.sort(counts)[::-1][:k].sum()
    return float(top) / float(trace.size)
