"""Deterministic fault injection for the remote-weight fetch stack.

The DWDP fetch paths (demand payload round, predictive speculative
round, cross-step residency cache) assume every peer is healthy and
every fetched expert row arrives intact. This module provides the
*adversary* for that assumption — a seeded, mesh-axis-aware
:class:`FaultInjector` that tampers fetched payload rows in ways a
misbehaving peer or flaky interconnect would:

- ``drop``: the row never arrives (zero-filled buffer) — also the model
  for a peer too slow to meet the transfer window;
- ``zero``: the row arrives zeroed (a lost DMA);
- ``corrupt``: the row arrives with wrong content (bit corruption in
  flight — modeled as ``w -> 1 - w`` so every element changes and the
  checksum delta is large by construction);
- ``cache_corrupt``: a residency-cache row rots in place (HBM
  corruption between steps);
- ``bad_peers``: subgroup positions whose payload rows ALWAYS drop — a
  persistent straggler/failed peer, the storm that drives the engine's
  :class:`~repro.runtime.engine.HealthMonitor` down the policy ladder;
- ``mirror``: one rank's mirrored ``PredictState`` view drifts for a
  step (a lost/duplicated correction payload) — the sync-free
  adversary: the drifted rank derives a DIFFERENT speculative schedule
  than its peers, which the per-step schedule digest must detect and
  convert into the (bitwise-exact) full-gather fallback.

Everything is pure JAX: the injector traces into the jitted forward,
draws its per-row Bernoulli masks from a key chain
``seed -> site salt -> flat mesh rank -> decode step`` (so runs are
reproducible, per-rank decorrelated, and per-step varying), and both
the tamper site (``prefetch.gather_demand_payload``) and the counting
site (``execution._moe_demand_apply``) recompute identical masks from
the same key — injected-row counts never ride the payload.

Fail-stop faults ride a different surface: ``rank_death`` is a
:class:`FaultTrace` event kind, not an in-jit injection — under
``jit``/``shard_map`` a dead rank kills the whole program, so the
recovery path (quarantine the rank, re-plan onto the shrunk subgroup,
migrate/requeue the in-flight slots) lives in the host-side serving
layer (``runtime/serving``) and the simulator's trace replay, not in
the traced forward. :class:`FaultTrace` also replaces the simulator's
synthetic Bernoulli ``fault_rate`` with timestamped (step, kind,
rank/peer) events recorded from a real fault-injected run
(``tests/fixtures/record_fault_trace.py``).

The detection/repair side lives in ``prefetch.verify_rows`` /
``execution._moe_demand_apply``; see docs/robustness.md for the failure
model and what remains out of scope (adversarial corruption below the
checksum tolerance).
"""
from __future__ import annotations

import dataclasses
import zlib
from typing import Optional

import jax
import jax.numpy as jnp
import numpy as np
from jax import lax

from repro.core.placement import Placement

#: Layout of the per-step fault-stats vector emitted by the validated
#: fetch path (length ``FAULT_STAT_BASE + subgroup_size``):
#: ``[injected_drop, injected_zero, injected_corrupt, injected_cache,
#: detected, fault_fallbacks, mirror_divergence,
#: detected_by_src_position...]``. ``mirror_divergence`` counts decode
#: steps on which the sync-free mirrored-predictor schedule digest
#: disagreed across ranks (each divergent step forced the full-gather
#: fallback); it is 0 on every other fetch mode. The per-source tail
#: attributes every detected row to the subgroup position that served
#: it (cache rows to the position owning the expert id) — the per-peer
#: signal the HealthMonitor consumes.
FAULT_STAT_BASE = 7
FAULT_STAT_NAMES = (
    "injected_drop", "injected_zero", "injected_corrupt",
    "injected_cache", "detected", "fault_fallbacks",
    "mirror_divergence",
)


#: Event kinds a :class:`FaultTrace` may carry. The payload kinds map
#: onto the Bernoulli injection sites above (their replay prices a
#: forced full-gather fallback on that decode step, attributed to the
#: event's peer); ``rank_death`` is the fail-stop kind — the named gen
#: rank is quarantined and the replica re-plans onto the survivors.
TRACE_KINDS = ("drop", "zero", "corrupt", "cache", "mirror", "rank_death")
RANK_DEATH = "rank_death"

#: payload-kind -> index into the FAULT_STAT_NAMES prefix (what a
#: replayed event increments; rank_death is accounted host-side in
#: ServingMetrics, never in the traced stats vector)
_TRACE_STAT_INDEX = {"drop": 0, "zero": 1, "corrupt": 2, "cache": 3,
                     "mirror": 6}


@dataclasses.dataclass(frozen=True)
class FaultTrace:
    """A timestamped fault-event trace: what actually went wrong, when,
    and where — recorded from a real fault-injected run (or authored)
    and replayed in place of synthetic Bernoulli draws.

    ``steps`` are decode-step indices (sorted, ties allowed), ``kinds``
    the per-event :data:`TRACE_KINDS` entry, ``ranks`` the subgroup
    position that served the faulty rows (payload kinds) or the flat
    gen rank that died (``rank_death``)."""

    steps: np.ndarray
    kinds: tuple
    ranks: np.ndarray

    def __post_init__(self):
        steps = np.asarray(self.steps, np.int64)
        ranks = np.asarray(self.ranks, np.int64)
        kinds = tuple(str(k) for k in self.kinds)
        if not (len(steps) == len(kinds) == len(ranks)):
            raise ValueError(
                f"FaultTrace arrays disagree: {len(steps)} steps, "
                f"{len(kinds)} kinds, {len(ranks)} ranks"
            )
        if np.any(steps[1:] < steps[:-1]):
            raise ValueError("FaultTrace steps must be sorted ascending")
        if np.any(steps < 0) or np.any(ranks < 0):
            raise ValueError("FaultTrace steps/ranks must be >= 0")
        bad = sorted(set(kinds) - set(TRACE_KINDS))
        if bad:
            raise ValueError(
                f"unknown FaultTrace kinds {bad}; expected {TRACE_KINDS}"
            )
        object.__setattr__(self, "steps", steps)
        object.__setattr__(self, "ranks", ranks)
        object.__setattr__(self, "kinds", kinds)

    def __len__(self) -> int:
        return len(self.steps)

    @classmethod
    def from_events(cls, events) -> "FaultTrace":
        """Build from an iterable of ``(step, kind, rank)`` tuples (any
        order — sorted here)."""
        ev = sorted((int(s), str(k), int(r)) for s, k, r in events)
        return cls(
            steps=np.asarray([e[0] for e in ev], np.int64),
            kinds=tuple(e[1] for e in ev),
            ranks=np.asarray([e[2] for e in ev], np.int64),
        )

    def events_in(self, start: int, stop: int) -> list:
        """``(step, kind, rank)`` events with ``start <= step < stop``."""
        lo = int(np.searchsorted(self.steps, start, side="left"))
        hi = int(np.searchsorted(self.steps, stop, side="left"))
        return [
            (int(self.steps[i]), self.kinds[i], int(self.ranks[i]))
            for i in range(lo, hi)
        ]

    def events_at(self, step: int) -> list:
        """``(kind, rank)`` events at one decode step."""
        return [(k, r) for _, k, r in self.events_in(step, step + 1)]

    def next_event_step(self, step: int) -> Optional[int]:
        """The first event step ``>= step`` (None past the end) — what
        the simulator clamps its multi-step advance to so replayed
        events are never skipped over."""
        i = int(np.searchsorted(self.steps, step, side="left"))
        return int(self.steps[i]) if i < len(self.steps) else None

    def fallback_rate(self, horizon_steps: Optional[int] = None) -> float:
        """Fraction of decode steps carrying at least one PAYLOAD fault
        event — the trace's drop-in replacement for the simulator's
        synthetic Bernoulli ``fault_rate``. ``horizon_steps`` defaults
        to the last event step + 1."""
        payload = [
            int(s) for s, k in zip(self.steps, self.kinds)
            if k != RANK_DEATH
        ]
        if not payload:
            return 0.0
        horizon = int(horizon_steps) if horizon_steps else payload[-1] + 1
        fault_steps = {s for s in payload if s < horizon}
        return len(fault_steps) / max(1, horizon)

    def peer_pressure(self, n_peers: int) -> np.ndarray:
        """Per-subgroup-position payload-fault event counts, normalized
        to [0, 1] — a replayable ``HealthMonitor``-style badness vector
        (``ClusterSimulator.degraded_table``'s ``peer_badness``)."""
        counts = np.zeros(max(1, int(n_peers)), np.float64)
        for k, r in zip(self.kinds, self.ranks):
            if k != RANK_DEATH:
                counts[int(r) % len(counts)] += 1.0
        top = counts.max()
        return counts / top if top > 0 else counts

    def stat_vector(self, step: int, n_peers: int) -> Optional[np.ndarray]:
        """This step's payload events as a fault-stats vector in the
        ``FAULT_STAT_NAMES`` + per-peer-detected-tail layout — what a
        replay feeds ``ServingMetrics.record_fault_stats`` and the
        ``HealthMonitor`` (None when the step carries no payload
        event)."""
        vec = np.zeros(FAULT_STAT_BASE + max(1, int(n_peers)), np.float64)
        any_payload = False
        for kind, rank in self.events_at(step):
            if kind == RANK_DEATH:
                continue
            any_payload = True
            vec[_TRACE_STAT_INDEX[kind]] += 1.0
            vec[4] += 1.0  # detected
            vec[5] += 1.0  # fault_fallbacks
            vec[FAULT_STAT_BASE + int(rank) % max(1, int(n_peers))] += 1.0
        return vec if any_payload else None

    def save(self, path: str) -> None:
        np.savez_compressed(
            path, steps=self.steps,
            kinds=np.asarray(self.kinds, dtype="U16"), ranks=self.ranks,
        )

    @classmethod
    def load(cls, path: str) -> "FaultTrace":
        with np.load(path) as z:
            return cls(
                steps=z["steps"],
                kinds=tuple(str(k) for k in z["kinds"]),
                ranks=z["ranks"],
            )


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static description of the fault environment to inject.

    Rates are per-fetched-row Bernoulli probabilities in [0, 1];
    ``bad_peers`` lists subgroup positions whose served rows always
    drop. All zero / empty = a healthy run (but the validation
    machinery still traces, which is how the checksum-overhead
    benchmark isolates the detection cost)."""

    seed: int = 0
    drop_rate: float = 0.0
    zero_rate: float = 0.0
    corrupt_rate: float = 0.0
    cache_corrupt_rate: float = 0.0
    bad_peers: tuple = ()
    mirror_rate: float = 0.0
    # Per-step probability that ONE rank's mirrored PredictState view
    # drifts (sync_free only): the target rank is drawn rank-
    # independently so all ranks agree who drifted, but only that rank
    # perturbs its own mirror row — producing genuinely divergent
    # speculative schedules for the digest to catch.
    trace: Optional[str] = None
    # Path to a recorded FaultTrace (.npz) replayed by the host-side
    # consumers (ClusterSimulator, serving layer) in place of the
    # Bernoulli rates above. The traced injector ignores it — trace
    # replay is host-level by construction (rank_death cannot be
    # injected inside jit).

    def __post_init__(self):
        for name in ("drop_rate", "zero_rate", "corrupt_rate",
                     "cache_corrupt_rate", "mirror_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultSpec.{name} must be in [0, 1], got {v}")
        object.__setattr__(self, "bad_peers",
                           tuple(int(p) for p in self.bad_peers))
        if any(p < 0 for p in self.bad_peers):
            raise ValueError(
                f"FaultSpec.bad_peers must be non-negative subgroup "
                f"positions, got {self.bad_peers}"
            )

    @property
    def any_faults(self) -> bool:
        return bool(
            self.drop_rate or self.zero_rate or self.corrupt_rate
            or self.cache_corrupt_rate or self.bad_peers
            or self.mirror_rate
        )

    def load_trace(self) -> Optional[FaultTrace]:
        """The recorded :class:`FaultTrace` named by ``trace=`` (None
        when the spec carries no trace)."""
        if self.trace is None:
            return None
        return FaultTrace.load(self.trace)

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse the ``--fault-spec`` flag syntax: comma-separated
        ``key=value`` pairs, e.g. ``"seed=3,drop=0.1,corrupt=0.05,
        peers=2|5"``. Keys: seed, drop, zero, corrupt, cache, mirror,
        peers (``|``-separated subgroup positions), trace (path to a
        recorded FaultTrace .npz)."""
        kw: dict = {}
        names = {
            "seed": "seed", "drop": "drop_rate", "zero": "zero_rate",
            "corrupt": "corrupt_rate", "cache": "cache_corrupt_rate",
            "mirror": "mirror_rate",
        }
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"fault-spec entry {part!r} is not key=value"
                )
            k, v = (s.strip() for s in part.split("=", 1))
            if k == "peers":
                kw["bad_peers"] = tuple(
                    int(p) for p in v.split("|") if p != ""
                )
            elif k == "seed":
                kw["seed"] = int(v)
            elif k == "trace":
                kw["trace"] = v
            elif k in names:
                kw[names[k]] = float(v)
            else:
                raise ValueError(
                    f"unknown fault-spec key {k!r} (expected seed/drop/"
                    f"zero/corrupt/cache/mirror/peers/trace)"
                )
        return cls(**kw)

    def describe(self) -> str:
        parts = [f"seed={self.seed}"]
        for key, name in (("drop", "drop_rate"), ("zero", "zero_rate"),
                          ("corrupt", "corrupt_rate"),
                          ("cache", "cache_corrupt_rate"),
                          ("mirror", "mirror_rate")):
            v = getattr(self, name)
            if v:
                parts.append(f"{key}={v}")
        if self.bad_peers:
            parts.append("peers=" + "|".join(str(p) for p in self.bad_peers))
        if self.trace is not None:
            parts.append(f"trace={self.trace}")
        return ",".join(parts)


def _salt(tag: str) -> int:
    # stable across processes (unlike hash()), positive for fold_in
    return zlib.crc32(tag.encode()) & 0x7FFFFFFF


class FaultInjector:
    """Traced fault-mask generator + row tamperer for one fetch site.

    Constructed per validated layer application from the plan's
    :class:`FaultSpec`; all methods are pure JAX so they trace into
    the jitted forward (including under ``lax.scan``)."""

    def __init__(self, spec: FaultSpec, axis: str, placement: Placement,
                 mesh_sizes: dict):
        self.spec = spec
        self.axis = axis
        self.pl = placement
        self.mesh_sizes = mesh_sizes

    def site_key(self, tag: str, step) -> jax.Array:
        """Key chain ``seed -> site salt -> flat mesh rank -> step``.
        Both the tamper site and the counting site call this with the
        same (tag, step) and recover identical draws."""
        k = jax.random.key(self.spec.seed)
        k = jax.random.fold_in(k, _salt(tag))
        r = jnp.int32(0)
        for a, s in self.mesh_sizes.items():
            r = r * s + lax.axis_index(a)
        k = jax.random.fold_in(k, r)
        return jax.random.fold_in(k, jnp.asarray(step, jnp.int32))

    def mirror_flag(self, step) -> jax.Array:
        """Rank-independent draw for the mirrored-predictor drift fault
        (sync_free): every rank computes the SAME (fired, target-rank)
        pair — key chain ``seed -> "mirror" salt -> step`` with NO rank
        fold — then only the target rank perturbs its own mirror row.
        That asymmetry is the point: the target genuinely derives a
        different speculative schedule than its peers, which the
        psum'd schedule digest must catch. Returns a traced bool:
        "this rank's mirror drifts this step"."""
        if not self.spec.mirror_rate:
            return jnp.asarray(False)
        k = jax.random.key(self.spec.seed)
        k = jax.random.fold_in(k, _salt("mirror"))
        k = jax.random.fold_in(k, jnp.asarray(step, jnp.int32))
        fired = jax.random.uniform(k) < self.spec.mirror_rate
        n_ranks = 1
        for s in self.mesh_sizes.values():
            n_ranks *= s
        target = jax.random.randint(
            jax.random.fold_in(k, 1), (), 0, n_ranks
        )
        r = jnp.int32(0)
        for a, s in self.mesh_sizes.items():
            r = r * s + lax.axis_index(a)
        return fired & (r == target)

    def payload_masks(self, key, budget: int):
        """Per-row (drop, zero, corrupt) masks for one demand payload
        bank of ``(subgroup_size - 1) * budget`` peer-major rows.
        Mutually exclusive by construction; rows served by a
        ``bad_peers`` position always drop."""
        g, local = self.pl.subgroup_size, self.pl.local_count
        budget = min(budget, local)
        rows = (g - 1) * budget
        if rows == 0:
            empty = jnp.zeros((0,), bool)
            return empty, empty, empty
        u = jax.random.uniform(key, (rows, 3))
        drop = u[:, 0] < self.spec.drop_rate
        if self.spec.bad_peers:
            p = lax.axis_index(self.axis) % g
            src = (p + 1 + jnp.arange(rows, dtype=jnp.int32) // budget) % g
            bad = jnp.zeros((rows,), bool)
            for bp in self.spec.bad_peers:
                bad = bad | (src == bp % g)
            drop = drop | bad
        zero = (u[:, 1] < self.spec.zero_rate) & ~drop
        corrupt = (u[:, 2] < self.spec.corrupt_rate) & ~drop & ~zero
        return drop, zero, corrupt

    def cache_mask(self, key, rows: int):
        """Per-slot corruption mask for the residency cache."""
        if rows == 0:
            return jnp.zeros((0,), bool)
        u = jax.random.uniform(key, (rows,))
        return u < self.spec.cache_corrupt_rate

    @staticmethod
    def tamper_rows(tree, drop, corrupt):
        """Apply row faults to a pytree of ``(rows, ...)`` leaves:
        dropped/zeroed rows are zero-filled, corrupted rows map
        ``w -> 1 - w`` (every element changes; the squared-weight
        checksum delta is ~sum(cw) per leaf, far above tolerance)."""

        def f(w):
            shape = (-1,) + (1,) * (w.ndim - 1)
            dm = drop.reshape(shape)
            cm = corrupt.reshape(shape)
            w = jnp.where(dm, jnp.zeros_like(w), w)
            return jnp.where(
                cm, (1.0 - w.astype(jnp.float32)).astype(w.dtype), w
            )

        return jax.tree.map(f, tree)
