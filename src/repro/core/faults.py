"""Deterministic fault injection for the remote-weight fetch stack.

The DWDP fetch paths (demand payload round, predictive speculative
round, cross-step residency cache) assume every peer is healthy and
every fetched expert row arrives intact. This module provides the
*adversary* for that assumption — a seeded, mesh-axis-aware
:class:`FaultInjector` that tampers fetched payload rows in ways a
misbehaving peer or flaky interconnect would:

- ``drop``: the row never arrives (zero-filled buffer) — also the model
  for a peer too slow to meet the transfer window;
- ``zero``: the row arrives zeroed (a lost DMA);
- ``corrupt``: the row arrives with wrong content (bit corruption in
  flight — modeled as ``w -> 1 - w`` so every element changes and the
  checksum delta is large by construction);
- ``cache_corrupt``: a residency-cache row rots in place (HBM
  corruption between steps);
- ``bad_peers``: subgroup positions whose payload rows ALWAYS drop — a
  persistent straggler/failed peer, the storm that drives the engine's
  :class:`~repro.runtime.engine.HealthMonitor` down the policy ladder;
- ``mirror``: one rank's mirrored ``PredictState`` view drifts for a
  step (a lost/duplicated correction payload) — the sync-free
  adversary: the drifted rank derives a DIFFERENT speculative schedule
  than its peers, which the per-step schedule digest must detect and
  convert into the (bitwise-exact) full-gather fallback.

Everything is pure JAX: the injector traces into the jitted forward,
draws its per-row Bernoulli masks from a key chain
``seed -> site salt -> flat mesh rank -> decode step`` (so runs are
reproducible, per-rank decorrelated, and per-step varying), and both
the tamper site (``prefetch.gather_demand_payload``) and the counting
site (``execution._moe_demand_apply``) recompute identical masks from
the same key — injected-row counts never ride the payload.

The detection/repair side lives in ``prefetch.verify_rows`` /
``execution._moe_demand_apply``; see docs/robustness.md for the failure
model and what is out of scope (SPMD rank death, adversarial
corruption below the checksum tolerance).
"""
from __future__ import annotations

import dataclasses
import zlib

import jax
import jax.numpy as jnp
from jax import lax

from repro.core.placement import Placement

#: Layout of the per-step fault-stats vector emitted by the validated
#: fetch path (length ``FAULT_STAT_BASE + subgroup_size``):
#: ``[injected_drop, injected_zero, injected_corrupt, injected_cache,
#: detected, fault_fallbacks, mirror_divergence,
#: detected_by_src_position...]``. ``mirror_divergence`` counts decode
#: steps on which the sync-free mirrored-predictor schedule digest
#: disagreed across ranks (each divergent step forced the full-gather
#: fallback); it is 0 on every other fetch mode. The per-source tail
#: attributes every detected row to the subgroup position that served
#: it (cache rows to the position owning the expert id) — the per-peer
#: signal the HealthMonitor consumes.
FAULT_STAT_BASE = 7
FAULT_STAT_NAMES = (
    "injected_drop", "injected_zero", "injected_corrupt",
    "injected_cache", "detected", "fault_fallbacks",
    "mirror_divergence",
)


@dataclasses.dataclass(frozen=True)
class FaultSpec:
    """Static description of the fault environment to inject.

    Rates are per-fetched-row Bernoulli probabilities in [0, 1];
    ``bad_peers`` lists subgroup positions whose served rows always
    drop. All zero / empty = a healthy run (but the validation
    machinery still traces, which is how the checksum-overhead
    benchmark isolates the detection cost)."""

    seed: int = 0
    drop_rate: float = 0.0
    zero_rate: float = 0.0
    corrupt_rate: float = 0.0
    cache_corrupt_rate: float = 0.0
    bad_peers: tuple = ()
    mirror_rate: float = 0.0
    # Per-step probability that ONE rank's mirrored PredictState view
    # drifts (sync_free only): the target rank is drawn rank-
    # independently so all ranks agree who drifted, but only that rank
    # perturbs its own mirror row — producing genuinely divergent
    # speculative schedules for the digest to catch.

    def __post_init__(self):
        for name in ("drop_rate", "zero_rate", "corrupt_rate",
                     "cache_corrupt_rate", "mirror_rate"):
            v = getattr(self, name)
            if not 0.0 <= v <= 1.0:
                raise ValueError(f"FaultSpec.{name} must be in [0, 1], got {v}")
        object.__setattr__(self, "bad_peers",
                           tuple(int(p) for p in self.bad_peers))
        if any(p < 0 for p in self.bad_peers):
            raise ValueError(
                f"FaultSpec.bad_peers must be non-negative subgroup "
                f"positions, got {self.bad_peers}"
            )

    @property
    def any_faults(self) -> bool:
        return bool(
            self.drop_rate or self.zero_rate or self.corrupt_rate
            or self.cache_corrupt_rate or self.bad_peers
            or self.mirror_rate
        )

    @classmethod
    def parse(cls, text: str) -> "FaultSpec":
        """Parse the ``--fault-spec`` flag syntax: comma-separated
        ``key=value`` pairs, e.g. ``"seed=3,drop=0.1,corrupt=0.05,
        peers=2|5"``. Keys: seed, drop, zero, corrupt, cache, mirror,
        peers (``|``-separated subgroup positions)."""
        kw: dict = {}
        names = {
            "seed": "seed", "drop": "drop_rate", "zero": "zero_rate",
            "corrupt": "corrupt_rate", "cache": "cache_corrupt_rate",
            "mirror": "mirror_rate",
        }
        for part in text.split(","):
            part = part.strip()
            if not part:
                continue
            if "=" not in part:
                raise ValueError(
                    f"fault-spec entry {part!r} is not key=value"
                )
            k, v = (s.strip() for s in part.split("=", 1))
            if k == "peers":
                kw["bad_peers"] = tuple(
                    int(p) for p in v.split("|") if p != ""
                )
            elif k == "seed":
                kw["seed"] = int(v)
            elif k in names:
                kw[names[k]] = float(v)
            else:
                raise ValueError(
                    f"unknown fault-spec key {k!r} "
                    f"(expected seed/drop/zero/corrupt/cache/mirror/peers)"
                )
        return cls(**kw)

    def describe(self) -> str:
        parts = [f"seed={self.seed}"]
        for key, name in (("drop", "drop_rate"), ("zero", "zero_rate"),
                          ("corrupt", "corrupt_rate"),
                          ("cache", "cache_corrupt_rate"),
                          ("mirror", "mirror_rate")):
            v = getattr(self, name)
            if v:
                parts.append(f"{key}={v}")
        if self.bad_peers:
            parts.append("peers=" + "|".join(str(p) for p in self.bad_peers))
        return ",".join(parts)


def _salt(tag: str) -> int:
    # stable across processes (unlike hash()), positive for fold_in
    return zlib.crc32(tag.encode()) & 0x7FFFFFFF


class FaultInjector:
    """Traced fault-mask generator + row tamperer for one fetch site.

    Constructed per validated layer application from the plan's
    :class:`FaultSpec`; all methods are pure JAX so they trace into
    the jitted forward (including under ``lax.scan``)."""

    def __init__(self, spec: FaultSpec, axis: str, placement: Placement,
                 mesh_sizes: dict):
        self.spec = spec
        self.axis = axis
        self.pl = placement
        self.mesh_sizes = mesh_sizes

    def site_key(self, tag: str, step) -> jax.Array:
        """Key chain ``seed -> site salt -> flat mesh rank -> step``.
        Both the tamper site and the counting site call this with the
        same (tag, step) and recover identical draws."""
        k = jax.random.key(self.spec.seed)
        k = jax.random.fold_in(k, _salt(tag))
        r = jnp.int32(0)
        for a, s in self.mesh_sizes.items():
            r = r * s + lax.axis_index(a)
        k = jax.random.fold_in(k, r)
        return jax.random.fold_in(k, jnp.asarray(step, jnp.int32))

    def mirror_flag(self, step) -> jax.Array:
        """Rank-independent draw for the mirrored-predictor drift fault
        (sync_free): every rank computes the SAME (fired, target-rank)
        pair — key chain ``seed -> "mirror" salt -> step`` with NO rank
        fold — then only the target rank perturbs its own mirror row.
        That asymmetry is the point: the target genuinely derives a
        different speculative schedule than its peers, which the
        psum'd schedule digest must catch. Returns a traced bool:
        "this rank's mirror drifts this step"."""
        if not self.spec.mirror_rate:
            return jnp.asarray(False)
        k = jax.random.key(self.spec.seed)
        k = jax.random.fold_in(k, _salt("mirror"))
        k = jax.random.fold_in(k, jnp.asarray(step, jnp.int32))
        fired = jax.random.uniform(k) < self.spec.mirror_rate
        n_ranks = 1
        for s in self.mesh_sizes.values():
            n_ranks *= s
        target = jax.random.randint(
            jax.random.fold_in(k, 1), (), 0, n_ranks
        )
        r = jnp.int32(0)
        for a, s in self.mesh_sizes.items():
            r = r * s + lax.axis_index(a)
        return fired & (r == target)

    def payload_masks(self, key, budget: int):
        """Per-row (drop, zero, corrupt) masks for one demand payload
        bank of ``(subgroup_size - 1) * budget`` peer-major rows.
        Mutually exclusive by construction; rows served by a
        ``bad_peers`` position always drop."""
        g, local = self.pl.subgroup_size, self.pl.local_count
        budget = min(budget, local)
        rows = (g - 1) * budget
        if rows == 0:
            empty = jnp.zeros((0,), bool)
            return empty, empty, empty
        u = jax.random.uniform(key, (rows, 3))
        drop = u[:, 0] < self.spec.drop_rate
        if self.spec.bad_peers:
            p = lax.axis_index(self.axis) % g
            src = (p + 1 + jnp.arange(rows, dtype=jnp.int32) // budget) % g
            bad = jnp.zeros((rows,), bool)
            for bp in self.spec.bad_peers:
                bad = bad | (src == bp % g)
            drop = drop | bad
        zero = (u[:, 1] < self.spec.zero_rate) & ~drop
        corrupt = (u[:, 2] < self.spec.corrupt_rate) & ~drop & ~zero
        return drop, zero, corrupt

    def cache_mask(self, key, rows: int):
        """Per-slot corruption mask for the residency cache."""
        if rows == 0:
            return jnp.zeros((0,), bool)
        u = jax.random.uniform(key, (rows,))
        return u < self.spec.cache_corrupt_rate

    @staticmethod
    def tamper_rows(tree, drop, corrupt):
        """Apply row faults to a pytree of ``(rows, ...)`` leaves:
        dropped/zeroed rows are zero-filled, corrupted rows map
        ``w -> 1 - w`` (every element changes; the squared-weight
        checksum delta is ~sum(cw) per leaf, far above tolerance)."""

        def f(w):
            shape = (-1,) + (1,) * (w.ndim - 1)
            dm = drop.reshape(shape)
            cm = corrupt.reshape(shape)
            w = jnp.where(dm, jnp.zeros_like(w), w)
            return jnp.where(
                cm, (1.0 - w.astype(jnp.float32)).astype(w.dtype), w
            )

        return jax.tree.map(f, tree)
