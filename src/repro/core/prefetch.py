"""Remote-weight gather modes — the TPU adaptation of DWDP's async
copy-engine prefetch (paper §2, §4.3).

Three modes, all running *inside* shard_map on the "model" axis:

- ``allgather``: one fused ``lax.all_gather`` per layer. The NCCL-like
  reference point the paper argues against (single monolithic collective).
- ``ring``: G'-1 chained pairwise ``lax.ppermute`` steps — the TPU-native
  analogue of the paper's serial peer-to-peer copy-engine pulls. Each step
  is a neighbor transfer on the ICI ring; no rank ever blocks on a
  collective wider than one link.
- ``ring_sliced``: the §4.3 time-division-multiplexing mitigation — every
  transfer is split into ``num_slices`` chunks along the feature axis and
  the per-step permutes are issued slice-interleaved, giving the scheduler
  finer-grained units to overlap with compute.

All modes deposit shards in canonical expert order (see placement.py), so
no post-gather merge copy exists — §4.2's merge elimination is structural
here.

Gradients flow through every mode (ppermute transposes to the inverse
permute; all_gather to psum_scatter), which is what makes DWDP usable for
the train_4k shape (ZeRO-3-style gather-forward / scatter-grad).
"""
from __future__ import annotations

import functools
from typing import Any

import jax
import jax.numpy as jnp

from repro.core.placement import Placement

PyTree = Any


def _subgroup_position(axis: str, placement: Placement) -> jax.Array:
    return jax.lax.axis_index(axis) % placement.subgroup_size


def _allgather_one(x: jax.Array, axis: str, placement: Placement) -> jax.Array:
    g = placement.subgroup_size
    if g == 1:
        return x
    out = jax.lax.all_gather(
        x, axis, axis_index_groups=placement.axis_index_groups()
    )  # (G', local, ...)
    return out.reshape((g * x.shape[0],) + x.shape[1:])


def _ring_one(x: jax.Array, axis: str, placement: Placement) -> jax.Array:
    g = placement.subgroup_size
    if g == 1:
        return x
    p = _subgroup_position(axis, placement)
    pairs = placement.ring_pairs()
    out = jnp.zeros((g,) + x.shape, x.dtype)
    zeros_idx = (jnp.int32(0),) * x.ndim
    out = jax.lax.dynamic_update_slice(out, x[None], (p,) + zeros_idx)
    cur = x
    for t in range(g - 1):
        cur = jax.lax.ppermute(cur, axis, pairs)
        src = (p - t - 1) % g
        out = jax.lax.dynamic_update_slice(out, cur[None], (src,) + zeros_idx)
    return out.reshape((g * x.shape[0],) + x.shape[1:])


def _ring_sliced_one(
    x: jax.Array, axis: str, placement: Placement, num_slices: int
) -> jax.Array:
    g = placement.subgroup_size
    if g == 1:
        return x
    feat = x.shape[-1]
    s = num_slices
    while feat % s:
        s -= 1
    if s <= 1:
        return _ring_one(x, axis, placement)
    p = _subgroup_position(axis, placement)
    pairs = placement.ring_pairs()
    curs = jnp.split(x, s, axis=-1)
    outs = [jnp.zeros((g,) + c.shape, x.dtype) for c in curs]
    zeros_idx = (jnp.int32(0),) * x.ndim
    for j in range(s):
        outs[j] = jax.lax.dynamic_update_slice(
            outs[j], curs[j][None], (p,) + zeros_idx
        )
    curs = list(curs)
    # step-major, slice-minor issue order: the TDM round-robin of Listing 1
    for t in range(g - 1):
        src = (p - t - 1) % g
        for j in range(s):
            curs[j] = jax.lax.ppermute(curs[j], axis, pairs)
            outs[j] = jax.lax.dynamic_update_slice(
                outs[j], curs[j][None], (src,) + zeros_idx
            )
    out = jnp.concatenate(outs, axis=-1)
    return out.reshape((g * x.shape[0],) + x.shape[1:])


def gather_shards(
    tree: PyTree,
    axis: str,
    placement: Placement,
    *,
    mode: str = "allgather",
    num_slices: int = 4,
) -> PyTree:
    """Gather a pytree of locally-sharded arrays (leading dim = local shard)
    into full arrays (leading dim = subgroup_size * local) in canonical
    order. This is the DWDP prefetch primitive."""
    if mode == "allgather":
        f = functools.partial(_allgather_one, axis=axis, placement=placement)
    elif mode == "ring":
        f = functools.partial(_ring_one, axis=axis, placement=placement)
    elif mode == "ring_sliced":
        f = functools.partial(
            _ring_sliced_one, axis=axis, placement=placement, num_slices=num_slices
        )
    else:
        raise ValueError(f"unknown prefetch mode {mode!r}")
    return jax.tree.map(f, tree)


def dedupe_gathered(x: jax.Array, placement: Placement) -> jax.Array:
    """Slice a gathered (subgroup*local, ...) buffer down to the canonical
    (num_padded, ...) expert set. With the canonical placement this is the
    identity (num_padded == subgroup*local); kept for clarity."""
    return x[: placement.num_padded]


def gather_bytes(placement: Placement, bytes_per_expert: int) -> int:
    """Remote bytes fetched per rank per layer (analytic, for roofline)."""
    return (placement.subgroup_size - 1) * placement.local_count * bytes_per_expert
