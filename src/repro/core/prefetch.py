"""Remote-weight gather modes — the TPU adaptation of DWDP's async
copy-engine prefetch (paper §2, §4.3).

Three modes, all running *inside* shard_map on the "model" axis:

- ``allgather``: one fused ``lax.all_gather`` per layer. The NCCL-like
  reference point the paper argues against (single monolithic collective).
- ``ring``: G'-1 chained pairwise ``lax.ppermute`` steps — the TPU-native
  analogue of the paper's serial peer-to-peer copy-engine pulls. Each step
  is a neighbor transfer on the ICI ring; no rank ever blocks on a
  collective wider than one link.
- ``ring_sliced``: the §4.3 time-division-multiplexing mitigation — every
  transfer is split into ``num_slices`` chunks along the feature axis and
  the per-step permutes are issued slice-interleaved, giving the scheduler
  finer-grained units to overlap with compute.

Two gather primitives share those modes:

- ``gather_shards``: the merged (legacy) gather. Deposits shards in
  canonical expert order and returns exactly the canonical
  ``(num_padded, ...)`` buffer — the explicit merge step: every shard,
  resident included, is copied into one contiguous buffer (precisely the
  §4.2 merge-copy HBM tax the split layout eliminates).
- ``gather_split_bank``: the §4.2 fast-path gather and the engine's
  *canonical* gathered-weight representation (``weight_layout="split"``,
  the default — shared by MoE experts, attention projections and dense
  FFN slices alike). Returns a :class:`SplitBank` — the
  ``(local_bank, remote_bank)`` pair where the resident shard is passed
  through untouched and only the ``(G'-1) * local`` remote slices cross
  the wire — the resident shard is never concatenated into the wire
  buffer, so no full-layer ``(num_padded, ...)`` weight buffer exists.
  The remote bank is in **rotated canonical order**: position
  ``j * local + i`` holds slice ``((p + 1 + j) % G') * local + i`` for
  caller subgroup position ``p`` — i.e. canonical order rolled so the
  caller's own slices (which lead the rolled order as the local bank)
  are exactly the slices the split kernels predicate as local.
  Consumers compensate with index arithmetic only: MoE rolls its
  dispatch indices by ``p * local`` (``execution._moe_apply``), attention
  rolls the *projected activations* back to canonical head order
  (``execution._attn_full``), and the dense FFN needs nothing at all
  (its slice sum is order-independent).

``merge_split_bank`` is the explicit activation-side merge of a
``SplitBank`` back into the canonical buffer (roll + concat) — it exists
for fallbacks and tests; the engine's legacy mode gathers canonically
via ``gather_shards`` instead so the merged baseline's collectives stay
byte-identical to the paper's reference point.

Which mode a family uses is per-family now: the plan's ``PolicyTable``
names a ``transport`` (and ``num_slices``) per gathered family, so e.g.
the GB-scale expert bank can ride ``ring_sliced`` while the small
attention banks allgather — the call sites in ``core/execution`` pass
each family's own ``policy.transport`` into these primitives.

A third gather strategy rides the same modes: the **on-demand** gather
(``xp.policy("moe_experts").fetch == "demand"`` — the paper's "fetching
missing experts on demand", abstract + §4.3). Where the split gather
still ships every remote expert, the demand gather ships only the
experts the *current layer's routing* activated — which is why the
engine inverts its layer structure from gather-then-route to
route-then-gather for demand-active layers (execution._moe_apply). Two
rounds:

1. **index exchange** (:func:`plan_demand_fetch`): each rank scatters
   its activated-expert set into a tiny ``(num_padded,)`` bitmap and
   all-gathers it inside the subgroup. Both sides of every transfer
   then derive the *same* compaction deterministically (ascending
   expert id, padded to the static per-peer ``budget`` with a validity
   mask), so no expert ids ever need to cross the wire with the
   payload.
2. **payload** (:func:`gather_demand_payload`): each sender
   ``jnp.take``s exactly the requested rows of its resident shard and
   ships them point-to-point (``shift_pairs(t)`` permutes). Demand
   payloads are wanted only by their endpoint, so the chained-ring
   schedule has no forwarding advantage — "ring" shares the direct
   schedule with "allgather", and "ring_sliced" applies the §4.3 TDM
   feature slicing to the payload permutes.

The result is a :class:`DemandBank` — ``(local, fetched, fetched_ids,
valid)`` — consumed by the demand split kernels via dispatch-index
remapping (no merge copy, no full remote bank). A requester wanting
more than ``budget`` experts from one peer raises the (axis-agreed)
overflow flag and the caller falls back to the full remote gather for
that layer, so results are always exact.

A fourth fetch mode builds on the demand rounds: **predictive** fetch
(``fetch == "predictive"``, decode only) takes the demand round off the
critical path. Per demand-active layer a :class:`PredictState` pytree is
threaded through the decode-step state carrying

- an expert-hotness predictor: the previous step's activated-expert
  bitmap (``prev``) plus per-expert EMA activation frequencies
  (``ema``, decay :data:`EMA_DECAY`) — pure index arithmetic;
- a fixed-budget **residency cache** of previously fetched expert rows
  (``cache_ids`` / ``cache_valid`` / ``cache`` weight rows), persisted
  across decode steps so re-activated experts skip the wire entirely;
  eviction is clock/LRU by EMA hotness.

The engine issues a *speculative* demand round for the predicted set
during the previous layer's compute window (it rides the layer-ahead
prefetch pipeline, so it has no data dependence on the current step's
routing and overlaps attention), then after routing lands a small
*correction* round covers only the miss set — ``plan_demand_fetch``'s
``exclude_ids`` compaction argument subtracts the (cache + speculative)
rows so the delta round reuses the same bitmap/ascending-id contract.
The existing budget-overflow ``lax.cond`` fallback is preserved, so
results stay bitwise-exact for any predictor quality, any cache budget
(0 included) and any miss pattern.

Gradients flow through every mode (ppermute transposes to the inverse
permute; all_gather to psum_scatter; take to scatter-add), which is what
makes DWDP usable for the train_4k shape (ZeRO-3-style gather-forward /
scatter-grad).
"""
from __future__ import annotations

import functools
from typing import Any, NamedTuple

import jax
import jax.numpy as jnp

from repro.core.placement import Placement

PyTree = Any


class SplitBank(NamedTuple):
    """First-class output of the split prefetch pipeline.

    ``local``: the resident shard tree, untouched (leading dim = the
    per-rank slice count — never copied, never re-landed).
    ``remote``: the prefetched remote tree, leading dim
    ``(G'-1) * local`` in rotated canonical order (module docstring).

    Registered as a pytree (NamedTuple), so a SplitBank rides the
    layer-stack scan carry exactly like a merged buffer would — the
    double-buffered prefetch pipeline is representation-agnostic.
    """

    local: PyTree
    remote: PyTree


class AttnBank(NamedTuple):
    """Gathered attention projections as TWO policy families.

    ``qkv``: the wq/wk/wv tree — a :class:`SplitBank` (split layout) or a
    plain merged dict, per the plan's ``attn_qkv`` policy.
    ``out``: the wo tree likewise, per the ``attn_out`` policy.

    Exists only when at least one part is split (both-merged gathers
    collapse back into one flat dict so the legacy merged path is
    byte-identical). A NamedTuple, so it rides the layer-stack scan carry
    like any other gathered representation.
    """

    qkv: PyTree
    out: PyTree


class DemandBank(NamedTuple):
    """Output of the on-demand expert fetch (route-before-gather path).

    ``local``: the resident shard tree, untouched (leading dim = the
    per-rank ``local_count`` — never copied, never re-landed).
    ``fetched``: the demand-fetched tree, leading dim
    ``(G' - 1) * budget`` — peer-major (distance 1 first), each peer's
    chunk compacted to ascending expert id and padded to the static
    per-peer ``budget``.
    ``fetched_ids``: ``(fetch_rows,)`` int32 padded-canonical expert id
    of each fetched row (undefined where ``valid`` is False).
    ``valid``: ``(fetch_rows,)`` bool — False rows are padding (their
    weights are clamped duplicates; consumers never dispatch to them).
    """

    local: PyTree
    fetched: PyTree
    fetched_ids: jax.Array
    valid: jax.Array


#: EMA decay for the predictive-fetch hotness tracker: each decode step
#: folds the new activation bitmap in with weight (1 - EMA_DECAY), so the
#: score reflects roughly the last ~1/(1-decay) steps of routing.
EMA_DECAY = 0.875

#: Richer-predictor decays (sync-free mode): per-row expert-affinity EMA
#: (a sequence keeps routing to "its" experts), decode-position bucket
#: histograms (routing drifts with generation depth), and the per-layer
#: signal-weight EMA that learns how much each signal helps THIS layer.
AFF_DECAY = 0.9
POS_DECAY = 0.96875
SIGW_DECAY = 0.875

#: Decode positions are histogrammed into ``N_POS_BUCKETS`` buckets of
#: ``POS_BUCKET_SIZE`` steps each (the last bucket is open-ended).
N_POS_BUCKETS = 4
POS_BUCKET_SIZE = 64


class PredictState(NamedTuple):
    """Per-layer predictor + residency-cache state for the predictive
    expert fetch, threaded through the decode loop (one leading per-rank
    dim — every rank routes its own tokens and caches its own fetches).

    ``prev``: ``(1, num_padded)`` bool — the previous decode step's
    activated-expert bitmap.
    ``ema``: ``(1, num_padded)`` f32 — EMA activation frequency per
    expert (:data:`EMA_DECAY`); scores both the speculative-round
    predictor and cache eviction.
    ``cache_ids`` / ``cache_valid``: ``(1, cache_rows)`` int32 / bool —
    padded-canonical expert id per cache slot (ids are unique among
    valid slots; local experts never enter — only fetched remote rows).
    ``cache``: the cached expert weight rows, ``(1, cache_rows, ...)``
    per leaf — bit-identical copies of previously fetched rows, so
    consuming them is exactly equivalent to re-fetching.
    ``stats``: ``(1, 5)`` f32 per-step counters
    ``[predicted, spec_hit, cache_hit, corr_rows, evicted]`` expert rows
    (serving metrics; speculative-round and residency-cache hits are
    disjoint by construction — the speculative bitmap excludes cached
    ids).

    **Sync-free (mirrored) mode** (``fetch == "sync_free"``): every rank
    maintains the GLOBAL per-rank predictor view, so both transfer
    endpoints derive the identical speculative schedule with zero index
    exchange. The bookkeeping leaves grow a subgroup dim —
    ``prev``/``ema`` become ``(1, G', num_padded)``,
    ``cache_ids``/``cache_valid`` become ``(1, G', cache_rows)`` (mirror
    bookkeeping of every peer's cache; the cached WEIGHTS stay local-only
    ``(1, cache_rows, ...)``) — and the richer-predictor fields engage
    (they are ``None`` in plain predictive mode):

    ``aff``: ``(1, G', rows, num_padded)`` f32 — per-sequence-row
    expert-affinity EMA (:data:`AFF_DECAY`).
    ``posb``: ``(1, G', N_POS_BUCKETS, num_padded)`` f32 — decode-
    position-bucket routing histograms (:data:`POS_DECAY`).
    ``sig``: ``(1, G', 2, num_padded)`` f32 — the two signals collapsed
    to per-expert scores at update time (``[affinity, position]``, each
    normalized to [0, 1]) so predict-time scoring needs no per-row state.
    ``sigw``: ``(1, G', 2)`` f32 — per-layer signal weights, EMA-learned
    from each signal's measured alignment with the step's actual routing
    (:data:`SIGW_DECAY`).

    Every sync-free field is updated ONLY from exchanged payloads —
    per-layer correction residuals plus the ONE per-step mirror
    all-gather (:func:`pack_mirror_payload`) — which all ranks see
    identically, so the mirror never drifts on a healthy step.

    ``routed`` is a TRANSIENT within-step channel, never part of the
    carried state: a sync-free layer returns its own rows' routed
    bitmaps here so ``forward_decode`` can union them across layers and
    run the single per-step mirror fold; the fold strips it back to
    ``None`` before the state leaves the step.
    """

    prev: jax.Array
    ema: jax.Array
    cache_ids: jax.Array
    cache_valid: jax.Array
    cache: PyTree
    stats: jax.Array
    aff: Any = None
    posb: Any = None
    sig: Any = None
    sigw: Any = None
    routed: Any = None


class DemandPlan(NamedTuple):
    """Index-exchange result shared by both transfer endpoints.

    ``masks``: ``(G', num_padded)`` bool — every subgroup peer's wanted
    bitmap (subgroup-position-major, canonical expert ids).
    ``fetched_ids`` / ``valid``: the requester-side view of the compacted
    fetch schedule (see :class:`DemandBank`).
    ``overflow``: scalar bool, agreed across ``agree_axes`` — True when
    ANY rank wants more than ``budget`` experts from one peer, i.e. the
    demand payload round cannot cover the activated set and the caller
    must fall back to the full remote gather.
    """

    masks: jax.Array
    fetched_ids: jax.Array
    valid: jax.Array
    overflow: jax.Array


def _subgroup_position(axis: str, placement: Placement) -> jax.Array:
    return jax.lax.axis_index(axis) % placement.subgroup_size


def _allgather_one(x: jax.Array, axis: str, placement: Placement) -> jax.Array:
    g = placement.subgroup_size
    if g == 1:
        return x
    out = jax.lax.all_gather(
        x, axis, axis_index_groups=placement.axis_index_groups()
    )  # (G', local, ...)
    return out.reshape((g * x.shape[0],) + x.shape[1:])


def _ring_one(x: jax.Array, axis: str, placement: Placement) -> jax.Array:
    g = placement.subgroup_size
    if g == 1:
        return x
    p = _subgroup_position(axis, placement)
    pairs = placement.ring_pairs()
    out = jnp.zeros((g,) + x.shape, x.dtype)
    zeros_idx = (jnp.int32(0),) * x.ndim
    out = jax.lax.dynamic_update_slice(out, x[None], (p,) + zeros_idx)
    cur = x
    for t in range(g - 1):
        cur = jax.lax.ppermute(cur, axis, pairs)
        src = (p - t - 1) % g
        out = jax.lax.dynamic_update_slice(out, cur[None], (src,) + zeros_idx)
    return out.reshape((g * x.shape[0],) + x.shape[1:])


def _ring_sliced_one(
    x: jax.Array, axis: str, placement: Placement, num_slices: int
) -> jax.Array:
    g = placement.subgroup_size
    if g == 1:
        return x
    feat = x.shape[-1]
    s = num_slices
    while feat % s:
        s -= 1
    if s <= 1:
        return _ring_one(x, axis, placement)
    p = _subgroup_position(axis, placement)
    pairs = placement.ring_pairs()
    curs = jnp.split(x, s, axis=-1)
    outs = [jnp.zeros((g,) + c.shape, x.dtype) for c in curs]
    zeros_idx = (jnp.int32(0),) * x.ndim
    for j in range(s):
        outs[j] = jax.lax.dynamic_update_slice(
            outs[j], curs[j][None], (p,) + zeros_idx
        )
    curs = list(curs)
    # step-major, slice-minor issue order: the TDM round-robin of Listing 1
    for t in range(g - 1):
        src = (p - t - 1) % g
        for j in range(s):
            curs[j] = jax.lax.ppermute(curs[j], axis, pairs)
            outs[j] = jax.lax.dynamic_update_slice(
                outs[j], curs[j][None], (src,) + zeros_idx
            )
    out = jnp.concatenate(outs, axis=-1)
    return out.reshape((g * x.shape[0],) + x.shape[1:])


def gather_shards(
    tree: PyTree,
    axis: str,
    placement: Placement,
    *,
    mode: str = "allgather",
    num_slices: int = 4,
) -> PyTree:
    """Gather a pytree of locally-sharded arrays (leading dim = local shard)
    into full arrays in canonical order. This is the DWDP prefetch
    primitive; its output leading dim is always ``placement.num_padded``
    (``subgroup_size * local``) — the one canonical post-gather shape.
    (``Placement.storage_size`` = ``group_size * local`` by contrast is
    the *global, redundancy-expanded* array layout, never a gather
    result.)"""
    if mode == "allgather":
        f = functools.partial(_allgather_one, axis=axis, placement=placement)
    elif mode == "ring":
        f = functools.partial(_ring_one, axis=axis, placement=placement)
    elif mode == "ring_sliced":
        f = functools.partial(
            _ring_sliced_one, axis=axis, placement=placement, num_slices=num_slices
        )
    else:
        raise ValueError(f"unknown prefetch mode {mode!r}")
    return jax.tree.map(lambda x: f(x)[: placement.num_padded], tree)


# --------------------------------------------------------------------------
# Remote-only gather: the §4.2 split-path prefetch.
# --------------------------------------------------------------------------
def _remote_allgather_one(
    x: jax.Array, axis: str, placement: Placement
) -> jax.Array:
    """G'-1 *independent* one-shot permutes (they can all be in flight at
    once — the fused-collective analogue), chunk j pulled from subgroup
    neighbor p+1+j."""
    g = placement.subgroup_size
    chunks = [
        jax.lax.ppermute(x, axis, placement.shift_pairs(t))
        for t in range(1, g)
    ]
    return jnp.concatenate(chunks, axis=0)


def _remote_ring_one(x: jax.Array, axis: str, placement: Placement) -> jax.Array:
    """Chained neighbor passes: after step t every rank holds the shard of
    subgroup neighbor p+t — exactly remote chunk t-1 in rotated order."""
    g = placement.subgroup_size
    step = placement.shift_pairs(1)
    chunks = []
    cur = x
    for _ in range(g - 1):
        cur = jax.lax.ppermute(cur, axis, step)
        chunks.append(cur)
    return jnp.concatenate(chunks, axis=0)


def _remote_ring_sliced_one(
    x: jax.Array, axis: str, placement: Placement, num_slices: int
) -> jax.Array:
    g = placement.subgroup_size
    feat = x.shape[-1]
    s = num_slices
    while feat % s:
        s -= 1
    if s <= 1:
        return _remote_ring_one(x, axis, placement)
    step = placement.shift_pairs(1)
    curs = list(jnp.split(x, s, axis=-1))
    chunks = []
    # step-major, slice-minor issue order: the TDM round-robin of Listing 1
    for _ in range(g - 1):
        for j in range(s):
            curs[j] = jax.lax.ppermute(curs[j], axis, step)
        chunks.append(jnp.concatenate(curs, axis=-1))
    return jnp.concatenate(chunks, axis=0)


def gather_remote_shards(
    tree: PyTree,
    axis: str,
    placement: Placement,
    *,
    mode: str = "allgather",
    num_slices: int = 4,
) -> tuple[PyTree, PyTree]:
    """Remote-only DWDP prefetch: return the ``(local_bank, remote_bank)``
    pair for the split §4.2 fast path.

    ``local_bank`` is the input tree untouched (the resident shard,
    leading dim ``local``); ``remote_bank`` has leading dim
    ``(subgroup_size - 1) * local`` in rotated canonical order (see module
    docstring). Only the remote fraction ``(G'-1)/G'`` of the layer's
    bytes crosses the wire, and no buffer of the full layer's
    ``num_padded`` experts is ever materialized. Differentiable in every
    mode (ppermute transposes to the inverse permute), so the ZeRO-style
    train gathers can ride the same path.
    """
    if placement.subgroup_size == 1:
        empty = jax.tree.map(lambda x: x[:0], tree)
        return tree, empty
    if mode == "allgather":
        f = functools.partial(_remote_allgather_one, axis=axis, placement=placement)
    elif mode == "ring":
        f = functools.partial(_remote_ring_one, axis=axis, placement=placement)
    elif mode == "ring_sliced":
        f = functools.partial(
            _remote_ring_sliced_one,
            axis=axis,
            placement=placement,
            num_slices=num_slices,
        )
    else:
        raise ValueError(f"unknown prefetch mode {mode!r}")
    return tree, jax.tree.map(f, tree)


def gather_split_bank(
    tree: PyTree,
    axis: str,
    placement: Placement,
    *,
    mode: str = "allgather",
    num_slices: int = 4,
) -> SplitBank:
    """Split-layout prefetch: the ``SplitBank`` form of
    ``gather_remote_shards`` — the canonical gathered-weight
    representation every DWDP-gathered family shares."""
    local, remote = gather_remote_shards(
        tree, axis, placement, mode=mode, num_slices=num_slices
    )
    return SplitBank(local=local, remote=remote)


def merge_split_bank(bank: SplitBank, axis: str, placement: Placement) -> PyTree:
    """Explicit merge of a SplitBank into the canonical ``(num_padded,
    ...)`` buffer — the §4.2 merge copy, performed on purpose.

    The rotated-order concat ``[local; remote]`` holds slice
    ``(p + j) % G'`` at position ``j``; rolling by ``p * local`` restores
    canonical order. Differentiable; used by fallbacks and by tests that
    check a bank's content against the merged gather."""
    g = placement.subgroup_size
    if g == 1:
        return bank.local
    p = _subgroup_position(axis, placement)
    shift = p * placement.local_count

    def merge(lo, re):
        merged_rot = jnp.concatenate([lo, re], axis=0)
        idx = (jnp.arange(placement.num_padded) - shift) % placement.num_padded
        return jnp.take(merged_rot, idx, axis=0)

    return jax.tree.map(merge, bank.local, bank.remote)


def gather_bytes(placement: Placement, bytes_per_expert: int) -> int:
    """Remote bytes fetched per rank per layer (analytic, for roofline).
    Identical for merged and split gathers — the split path saves HBM
    merge-copy bytes (see roofline_report), not wire bytes."""
    return (placement.subgroup_size - 1) * placement.local_count * bytes_per_expert


def reshard_split_bank(
    shards: list,
    old: Placement,
    new: Placement,
    dead: int,
    source: PyTree,
) -> list:
    """Fail-stop re-shard of one family's resident shards after a rank
    death: ``G' -> G'-1``.

    ``shards`` holds each OLD subgroup position's resident tree in the
    canonical per-rank layout (leading dim ``old.local_count``, row ids
    per ``Placement.table()`` — what ``merge_split_bank`` would
    concatenate back into the ``(num_padded, ...)`` buffer). The
    survivors' rows redistribute to the NEW placement's ownership
    ranges (the point-to-point wire a real deployment pays —
    ``roofline.rank_death_recovery`` prices it); every row the dead
    rank held is recovered from ``source`` — the checkpoint/source
    weight tree with leading dim ``>= num_experts`` — and NEVER read
    from ``shards[dead]`` (recovery must not trust a failed peer's
    memory; callers may pass garbage there). New padding rows are
    zero, matching a fresh ``make_placement`` shard of ``source``.

    Returns the ``G'-1`` new per-position resident trees."""
    if new.num_experts != old.num_experts:
        raise ValueError(
            f"reshard must keep the expert set: {old.num_experts} != "
            f"{new.num_experts}"
        )
    if new.subgroup_size != old.subgroup_size - 1:
        raise ValueError(
            f"reshard shrinks the subgroup by exactly the dead rank: "
            f"{old.subgroup_size} -> {new.subgroup_size}"
        )
    dead = int(dead) % old.subgroup_size
    e = old.num_experts

    def rows_for(position: int) -> PyTree:
        def build(src_leaf, *shard_leaves):
            out = []
            for j in range(new.local_count):
                r = position * new.local_count + j
                if r >= e:
                    out.append(jnp.zeros_like(src_leaf[0]))
                    continue
                owner = min(r // old.local_count, old.subgroup_size - 1)
                if owner == dead:
                    out.append(jnp.asarray(src_leaf[r]))
                else:
                    out.append(shard_leaves[owner][r - owner * old.local_count])
            return jnp.stack(out, axis=0)

        # the dead shard's leaves are replaced by the source rows at
        # tree-map time, so its contents are structurally unreadable
        safe = [source if i == dead else s for i, s in enumerate(shards)]
        return jax.tree.map(build, source, *safe)

    return [rows_for(p) for p in range(new.subgroup_size)]


# --------------------------------------------------------------------------
# On-demand expert fetch: the two-round route-before-gather primitive.
# --------------------------------------------------------------------------
def _compact_requests(mask_slice: jax.Array, budget: int):
    """Deterministic compaction both transfer endpoints can compute from
    the same bitmap: wanted indices in ascending order, padded to the
    static ``budget``. Returns ``(idx (budget,), valid (budget,), count)``
    — ``idx`` entries past ``count`` are clamped junk covered by
    ``valid``."""
    order = jnp.argsort(~mask_slice)  # stable: True (wanted) first, ascending
    count = jnp.sum(mask_slice.astype(jnp.int32))
    idx = order[:budget].astype(jnp.int32)
    valid = jnp.arange(budget) < jnp.minimum(count, budget)
    return idx, valid, count


def exclude_bitmap(
    num_padded: int, exclude_ids: jax.Array, exclude_valid: jax.Array
) -> jax.Array:
    """Scatter a (ids, valid) row set into a ``(num_padded,)`` bool
    bitmap — the form ``plan_demand_fetch``'s ``exclude_ids`` compaction
    subtracts. Invalid rows are dropped, not scattered."""
    out = jnp.zeros((num_padded,), bool)
    safe = jnp.where(exclude_valid, exclude_ids, num_padded)
    return out.at[safe].set(True, mode="drop")


def plan_demand_fetch(
    wanted: jax.Array,
    axis: str,
    placement: Placement,
    *,
    budget: int,
    agree_axes: tuple[str, ...],
    exclude_ids: Any = None,
    exclude_valid: Any = None,
) -> DemandPlan:
    """Round 1 — the index exchange. ``wanted`` is this rank's
    ``(num_padded,)`` bool activated-expert bitmap (from the routing that
    now runs *before* the gather). All-gathers the bitmaps inside the
    subgroup (a few hundred bytes — the round the payload savings pay
    for) and derives the requester-side fetch schedule.

    ``agree_axes`` must name every mesh axis of the enclosing shard_map:
    the overflow flag gates a ``lax.cond`` whose branches contain
    *different* collectives, and the runtime rendezvous spans all devices
    — every rank (not just this subgroup) must take the same branch.
    Pass ``agree_axes=()`` for plans whose overflow flag is ignored (the
    speculative predictive round clamps instead of falling back), which
    also skips the agreement psum.

    ``exclude_ids`` / ``exclude_valid`` (optional): expert rows the
    requester already holds — the residency-cache contents and the
    speculative round's fetched set — subtracted from ``wanted`` BEFORE
    the bitmap exchange, so the correction round ships only the miss set
    while reusing the exact same ascending-id compaction contract (both
    endpoints see the already-subtracted bitmap).
    """
    g = placement.subgroup_size
    local = placement.local_count
    budget = min(budget, local)
    if exclude_ids is not None:
        wanted = wanted & ~exclude_bitmap(
            placement.num_padded, exclude_ids, exclude_valid
        )
    p = _subgroup_position(axis, placement)
    masks = jax.lax.all_gather(
        wanted, axis, axis_index_groups=placement.axis_index_groups()
    )  # (G', num_padded), subgroup-position-major
    fetched_ids, valid, overflow = plan_from_bitmap(
        wanted, p, g, local, budget
    )
    if agree_axes:
        overflow = jax.lax.psum(overflow.astype(jnp.float32), agree_axes) > 0
    return DemandPlan(
        masks=masks, fetched_ids=fetched_ids, valid=valid, overflow=overflow
    )


def plan_from_bitmap(
    wanted: jax.Array, p: Any, g: int, local: int, budget: int
) -> tuple[jax.Array, jax.Array, jax.Array]:
    """Requester-side fetch schedule of subgroup position ``p`` from one
    ``(num_padded,)`` wanted bitmap: the per-peer ascending-id
    compaction, peer-major (distance 1 first), padded to ``budget``.
    Returns ``(fetched_ids, valid, overflow)`` with a RAW (un-agreed)
    overflow flag. Pure index arithmetic — both transfer endpoints (and,
    in sync-free mode, every mirror replaying peer ``p``'s schedule)
    compute the identical result from the identical bitmap; ``p`` may be
    traced or a Python int."""
    ids, valids = [], []
    overflow = jnp.bool_(False)
    for t in range(1, g):
        o = (p + t) % g
        mslice = jax.lax.dynamic_slice(wanted, (o * local,), (local,))
        idx, valid_t, cnt = _compact_requests(mslice, budget)
        ids.append(o * local + idx)
        valids.append(valid_t)
        overflow = overflow | (cnt > budget)
    fetched_ids = jnp.concatenate(ids) if ids else jnp.zeros((0,), jnp.int32)
    valid = jnp.concatenate(valids) if valids else jnp.zeros((0,), bool)
    return fetched_ids, valid, overflow


def _demand_send_one(
    w: jax.Array,
    idx_by_t: list,
    axis: str,
    placement: Placement,
    mode: str,
    num_slices: int,
) -> jax.Array:
    """Payload permutes for one leaf: for each peer distance t, take the
    rows requester ``p - t`` asked for and ship them with the one-shot
    ``shift_pairs(t)`` permute. Demand payloads are point-to-point by
    nature (only the endpoint wants them), so the chained-ring schedule
    has no forwarding advantage — "ring" shares the direct schedule with
    "allgather"; "ring_sliced" applies the §4.3 TDM feature slicing."""
    g = placement.subgroup_size
    feat = w.shape[-1]
    s = num_slices if mode == "ring_sliced" else 1
    while feat % s:
        s -= 1
    chunks = []
    for t in range(1, g):
        payload = jnp.take(w, idx_by_t[t - 1], axis=0)
        pairs = placement.shift_pairs(t)
        if s > 1:
            slices = [
                jax.lax.ppermute(c, axis, pairs)
                for c in jnp.split(payload, s, axis=-1)
            ]
            chunks.append(jnp.concatenate(slices, axis=-1))
        else:
            chunks.append(jax.lax.ppermute(payload, axis, pairs))
    return jnp.concatenate(chunks, axis=0)


def gather_demand_payload(
    tree: PyTree,
    plan: DemandPlan,
    axis: str,
    placement: Placement,
    *,
    budget: int,
    mode: str = "allgather",
    num_slices: int = 4,
    injector: Any = None,
    fault_key: Any = None,
) -> DemandBank:
    """Round 2 — the payload. Each rank serves every peer's request out
    of its resident shard (``jnp.take`` of exactly the requested rows,
    padded to ``budget``) and receives its own requested rows back,
    peer-major. Only ``(G'-1) * budget`` expert rows cross the wire —
    for decode-scale routing a small fraction of the ``(G'-1) * local``
    the full remote gather ships. Differentiable (take transposes to
    scatter-add, ppermute to the inverse permute).

    ``injector`` / ``fault_key`` (optional): a
    :class:`~repro.core.faults.FaultInjector` plus a derived site key —
    the arrived payload rows are tampered per the injector's
    deterministic drop/zero/corrupt masks, modeling wire faults. The
    caller recomputes the same masks from the same key to count what
    was injected; detection/repair is the caller's checksum
    verification (:func:`verify_rows`)."""
    if mode not in ("allgather", "ring", "ring_sliced"):
        raise ValueError(f"unknown prefetch mode {mode!r}")
    g = placement.subgroup_size
    local = placement.local_count
    budget = min(budget, local)
    if g == 1:
        empty = jax.tree.map(lambda x: x[:0], tree)
        return DemandBank(
            local=tree,
            fetched=empty,
            fetched_ids=jnp.zeros((0,), jnp.int32),
            valid=jnp.zeros((0,), bool),
        )
    p = _subgroup_position(axis, placement)
    idx_by_t = []
    for t in range(1, g):
        q = (p - t) % g  # the requester this rank serves at distance t
        mslice = jax.lax.dynamic_slice(
            plan.masks, (q, p * local), (1, local)
        )[0]
        idx_send, _, _ = _compact_requests(mslice, budget)
        idx_by_t.append(idx_send)
    fetched = jax.tree.map(
        lambda w: _demand_send_one(
            w, idx_by_t, axis, placement, mode, num_slices
        ),
        tree,
    )
    if injector is not None:
        drop, zero, corrupt = injector.payload_masks(fault_key, budget)
        fetched = injector.tamper_rows(fetched, drop | zero, corrupt)
    return DemandBank(
        local=tree,
        fetched=fetched,
        fetched_ids=plan.fetched_ids,
        valid=plan.valid,
    )


def predict_bitmap(
    prev: jax.Array,
    ema: jax.Array,
    placement: Placement,
    *,
    budget: int,
    exclude_ids: Any = None,
    exclude_valid: Any = None,
    extra_score: Any = None,
    exclude_peers: tuple = (),
) -> jax.Array:
    """The speculative round's predicted-expert bitmap: per subgroup
    slice, the top-``budget`` experts by hotness score — previous-step
    activation first (score +2), EMA frequency as the tie-breaking tail —
    minus the rows already resident in the cache. Shaping the *bitmap* to
    at most ``budget`` wanted rows per peer keeps the ascending-id
    compaction lossless for the hot set (nothing hot is clamped away) and
    makes speculative overflow impossible by construction. Cold experts
    (score 0) are never speculated. Pure index arithmetic — no data-
    dependent shapes, no collectives.

    ``extra_score`` (optional): a ``(num_padded,)`` f32 additive score
    term — the sync-free mode's weighted richer-predictor signals
    (:func:`update_predictor`).
    ``exclude_peers`` (optional): static subgroup positions whose experts
    are dropped from the speculative schedule (the per-peer health
    exclusion rung — a persistently bad peer's rows route through the
    validated correction round instead)."""
    e_pad = placement.num_padded
    local = placement.local_count
    budget = min(budget, local)
    score = prev.astype(jnp.float32) * 2.0 + ema
    if extra_score is not None:
        score = score + extra_score
    if exclude_ids is not None:
        score = jnp.where(
            exclude_bitmap(e_pad, exclude_ids, exclude_valid), 0.0, score
        )
    rows = score.reshape(placement.subgroup_size, local)
    for peer in exclude_peers:
        rows = rows.at[int(peer) % placement.subgroup_size].set(0.0)
    top_vals, top_idx = jax.lax.top_k(rows, budget)  # (G', budget)
    base = (
        jnp.arange(placement.subgroup_size, dtype=jnp.int32)[:, None] * local
    )
    ids = (base + top_idx).reshape(-1)
    keep = (top_vals > 0.0).reshape(-1)
    out = jnp.zeros((e_pad,), bool)
    return out.at[jnp.where(keep, ids, e_pad)].set(True, mode="drop")


# --------------------------------------------------------------------------
# Sync-free decode: mirrored-predictor helpers.
#
# In ``fetch == "sync_free"`` the speculative round carries ZERO index
# metadata: every rank derives the (identical) speculative schedule of
# EVERY subgroup peer from mirrored PredictState, so senders and
# requesters agree on the payload compaction without exchanging bitmaps.
# The mirror is kept consistent by construction — its only inputs are the
# packed correction-round payload below (which every rank receives
# identically) — and cross-checked each step by a psum'd schedule digest
# (a scalar, not a bitmap round).
# --------------------------------------------------------------------------
def routed_bitmaps(top_experts: jax.Array, num_padded: int) -> jax.Array:
    """Per-row activated-expert bitmaps ``(rows, num_padded)`` from the
    router's ``(rows, top_k)`` expert ids — the per-row half of the
    packed correction payload (the rows-union is the classic ``wanted``
    bitmap; the per-row split is what feeds the affinity predictor)."""
    rows = top_experts.shape[0]
    out = jnp.zeros((rows, num_padded), bool)
    return out.at[
        jnp.arange(rows)[:, None], top_experts
    ].set(True, mode="drop")


def position_buckets(pos: jax.Array) -> jax.Array:
    """``(rows, N_POS_BUCKETS)`` bool one-hot of each row's decode-
    position bucket (``pos // POS_BUCKET_SIZE``, last bucket
    open-ended)."""
    b = jnp.clip(pos // POS_BUCKET_SIZE, 0, N_POS_BUCKETS - 1)
    return b[..., None] == jnp.arange(N_POS_BUCKETS)


def pack_mirror_payload(routed: jax.Array, buckets: jax.Array) -> jax.Array:
    """Flatten one rank's per-STEP mirror-fold metadata into a single
    bool vector: ``[routed (rows * num_padded,) | buckets
    (rows * N_POS_BUCKETS,)]``. ONE all-gather of this vector per decode
    step feeds every mirror's predictor fold — the routing/position
    signals are layer-agnostic (the predictor models the rank, not the
    layer), so the fold runs once after the stack instead of once per
    layer. The per-layer index traffic that remains is the correction
    residual bitmap alone (it plans the compacted payload fetch, so the
    senders need it per layer)."""
    return jnp.concatenate([routed.reshape(-1), buckets.reshape(-1)])


def unpack_mirror_payload(
    packed: jax.Array, num_padded: int
) -> tuple[jax.Array, jax.Array]:
    """Inverse of :func:`pack_mirror_payload` (leading dims pass
    through, so it unpacks the all-gathered ``(G', total)`` form too;
    ``rows`` is recovered from the packed length)."""
    rows = packed.shape[-1] // (num_padded + N_POS_BUCKETS)
    r_end = rows * num_padded
    routed = packed[..., :r_end].reshape(
        packed.shape[:-1] + (rows, num_padded)
    )
    buckets = packed[..., r_end:].reshape(
        packed.shape[:-1] + (rows, N_POS_BUCKETS)
    )
    return routed, buckets


def predict_extra_score(sig: jax.Array, sigw: jax.Array) -> jax.Array:
    """The richer predictors' additive score term for
    :func:`predict_bitmap`: the per-layer-weighted sum of the collapsed
    signals — ``(2, num_padded)`` x ``(2,)`` -> ``(num_padded,)``. Both
    factors live in [0, 1], so the term can add at most 2.0 — it fills
    the speculative budget with warm candidates but never outranks a
    previous-step activation (score +2) plus any EMA mass."""
    return jnp.einsum("s,se->e", sigw, sig)


def update_predictor(
    ema: jax.Array,
    aff: jax.Array,
    posb: jax.Array,
    sigw: jax.Array,
    routed: jax.Array,
    buckets: jax.Array,
):
    """Fold one step of one rank's exchanged routing into its predictor
    slots. Shared verbatim by the rank itself and by every mirror
    (vmapped over the subgroup dim in sync-free mode), so the fold is
    deterministic in the exchanged payload alone — identical inputs on
    every rank produce bit-identical mirrored state.

    ``routed``: ``(rows, num_padded)`` bool per-row routed bitmaps;
    ``buckets``: ``(rows, N_POS_BUCKETS)`` bool position one-hots (both
    straight out of :func:`unpack_mirror_payload`).
    Returns ``(prev, ema, aff, posb, sig, sigw)`` — ``prev`` is the
    rows-union activation bitmap; ``sig`` holds the two signals
    collapsed to per-expert scores and normalized to [0, 1]; ``sigw``
    is EMA-updated from each signal's measured alignment with the
    experts this step actually routed to (a signal that keeps pointing
    at the right experts earns weight; a useless one decays)."""
    union = jnp.any(routed, axis=0)
    uf = union.astype(jnp.float32)
    new_ema = EMA_DECAY * ema + (1.0 - EMA_DECAY) * uf
    rf = routed.astype(jnp.float32)
    bf = buckets.astype(jnp.float32)
    new_aff = AFF_DECAY * aff + (1.0 - AFF_DECAY) * rf
    new_posb = POS_DECAY * posb + (1.0 - POS_DECAY) * jnp.einsum(
        "bn,be->ne", bf, rf
    )
    aff_sig = jnp.max(new_aff, axis=0)
    pos_sig = jnp.max(bf @ new_posb, axis=0)
    sig = jnp.stack([aff_sig, pos_sig])
    sig = sig / jnp.maximum(jnp.max(sig, axis=1, keepdims=True), 1e-6)
    qual = jnp.sum(sig * uf[None, :], axis=1) / jnp.maximum(jnp.sum(uf), 1.0)
    new_sigw = jnp.clip(
        SIGW_DECAY * sigw + (1.0 - SIGW_DECAY) * qual, 0.0, 1.0
    )
    return union, new_ema, new_aff, new_posb, sig, new_sigw


def schedule_digest(masks: jax.Array) -> jax.Array:
    """Scalar f32 digest of a derived speculative schedule: the
    positionally-weighted sum of the mask bits. Integer-valued by
    construction (small positive integer weights x 0/1 bits), so the
    cross-rank agreement test ``|G' * own - psum(own)| > 0.5`` is exact
    arithmetic, not a float tolerance. Distinct schedules collide only
    on tied weighted sums — the same residual-risk class as the payload
    checksums (docs/robustness.md)."""
    flat = masks.reshape(-1).astype(jnp.float32)
    return jnp.sum(flat * _cs_weights(flat.shape[0]))


def gather_demand_bank(
    tree: PyTree,
    wanted: jax.Array,
    axis: str,
    placement: Placement,
    *,
    budget: int,
    agree_axes: tuple[str, ...],
    mode: str = "allgather",
    num_slices: int = 4,
) -> tuple[DemandBank, jax.Array]:
    """Both demand rounds in one call: ``(DemandBank, overflow)``.
    Callers that gate the payload round behind the overflow fallback
    (execution._moe_apply) use the two-step API instead so only the
    taken branch's permutes execute."""
    plan = plan_demand_fetch(
        wanted, axis, placement, budget=budget, agree_axes=agree_axes
    )
    bank = gather_demand_payload(
        tree, plan, axis, placement, budget=budget, mode=mode,
        num_slices=num_slices,
    )
    return bank, plan.overflow


# --------------------------------------------------------------------------
# Payload validation: per-row checksums riding the tiny metadata round.
# --------------------------------------------------------------------------
#: Relative / absolute tolerance of the checksum compare. The checksum
#: is a positionally-weighted sum of SQUARED elements computed in f32;
#: source and receiver may reduce in different orders (different leading
#: dims), so exact equality is wrong — but any modeled fault (zeroed /
#: dropped / ``w -> 1 - w`` corrupted row) moves the checksum by orders
#: of magnitude more than f32 accumulation noise, so a loose tolerance
#: is both safe against false positives and sound against the injected
#: fault classes. Sub-tolerance corruption is out of scope (documented
#: in docs/robustness.md), like hash collisions for real checksums.
CHECKSUM_RTOL = 1e-2
CHECKSUM_ATOL = 1e-6


def _cs_weights(n: int) -> jax.Array:
    # small coprime-period positional weights: permuting unequal
    # elements within a row moves the checksum too
    return (jnp.arange(n, dtype=jnp.float32) % 61.0) + 1.0


def row_checksums(tree: PyTree) -> jax.Array:
    """``(rows,)`` f32 checksum per leading-dim row of a weight tree:
    sum over leaves of the positionally-weighted squared elements.
    Squaring makes the checksum strictly positive for any nonzero row,
    so zeroed/dropped rows can never collide with the source value.
    Deterministic given the tree's key set (``jax.tree.leaves`` order);
    both transfer endpoints hold the same keys."""
    total = None
    for w in jax.tree.leaves(tree):
        flat = w.reshape(w.shape[0], -1).astype(jnp.float32)
        s = jnp.sum(flat * flat * _cs_weights(flat.shape[1]), axis=1)
        total = s if total is None else total + s
    assert total is not None, "row_checksums of an empty tree"
    return total


def checksum_table(tree: PyTree, axis: str, placement: Placement) -> jax.Array:
    """The checksum wire format: every rank computes ``(local,)`` f32
    checksums of its RESIDENT rows and all-gathers them inside the
    subgroup into the canonical ``(num_padded,)`` table (position ``o``
    owns ids ``[o * local, (o+1) * local)``). 4 bytes/expert — the same
    order of magnitude as the demand bitmap round, riding alongside it;
    ``demand_fetch_bytes`` absorbs it in the per-expert metadata term."""
    local = row_checksums(tree)
    if placement.subgroup_size == 1:
        return local
    out = jax.lax.all_gather(
        local, axis, axis_index_groups=placement.axis_index_groups()
    )  # (G', local)
    return out.reshape(-1)[: placement.num_padded]


def verify_rows(
    tree: PyTree,
    ids: jax.Array,
    valid: jax.Array,
    table: jax.Array,
) -> tuple[jax.Array, jax.Array]:
    """Re-checksum arrived/cached rows against the source table.
    Returns ``(verified_valid, bad)``: ``verified_valid`` is ``valid``
    with checksum-mismatched rows masked out (they flow into the
    correction round / full-gather fallback — the repair path), ``bad``
    flags exactly the valid-but-mismatched rows (the detection
    counters). Padding rows (``valid`` False) are never flagged."""
    if valid.shape[0] == 0:
        return valid, valid
    got = row_checksums(tree)
    want = table[ids]
    ok = jnp.abs(got - want) <= CHECKSUM_RTOL * jnp.abs(want) + CHECKSUM_ATOL
    bad = valid & ~ok
    return valid & ok, bad


def demand_fetch_bytes(
    placement: Placement, budget: int, bytes_per_expert: int,
    *, validate: bool = False,
) -> int:
    """Wire bytes per rank per layer for the demand gather: the payload
    round's ``(G'-1) * budget`` padded expert rows plus the index round's
    bitmap bytes (1 byte/expert from each subgroup peer; +4 bytes/expert
    for the f32 checksum table when ``validate`` — see
    :func:`checksum_table`). Capped at the full remote gather — at full
    budget the two coincide and the index round's bytes are absorbed by
    the cap (matching the roofline twin,
    ``roofline.demand_prefetch_bytes``), so the demand counters never
    report more than the all-fetch counterfactual."""
    g = placement.subgroup_size
    budget = min(budget, placement.local_count)
    meta = placement.num_padded * (5 if validate else 1)
    full = (g - 1) * placement.local_count * bytes_per_expert
    return min(full, (g - 1) * (budget * bytes_per_expert + meta))


def sync_free_fetch_bytes(
    placement: Placement, spec_budget: int, corr_budget: int, rows: int,
    bytes_per_expert: int, *, validate: bool = False,
) -> dict:
    """Per-ROUND wire bytes per rank per layer of the sync-free fetch:
    ``{"spec": ..., "corr": ...}``. The speculative round is PURE
    payload — zero index metadata, the schedule is derived from the
    mirrored predictor on both endpoints. The correction round carries
    its payload plus the residual (miss) bitmap all-gather (1 byte per
    expert from each subgroup peer — the senders need it to compact the
    payload, so it is the ONLY index traffic that stays per-layer) and,
    when ``validate``, the f32 checksum table that rides the same round.
    The routing/position signals that feed the mirrors moved OFF the
    per-layer path entirely: they ship once per step
    (:func:`sync_free_mirror_bytes`)."""
    g = placement.subgroup_size
    e = placement.num_padded
    sb = min(spec_budget, placement.local_count)
    cb = min(corr_budget, placement.local_count)
    meta = e + (4 * e if validate else 0)
    return {
        "spec": (g - 1) * sb * bytes_per_expert,
        "corr": (g - 1) * (cb * bytes_per_expert + meta),
    }


def sync_free_mirror_bytes(placement: Placement, rows: int) -> int:
    """Per-STEP wire bytes of the one mirror-fold all-gather
    (:func:`pack_mirror_payload`: ``rows`` per-row routed bitmaps +
    position one-hots, 1 byte/bit from each subgroup peer). Amortized
    over every sync-free layer in the stack — the fold is per-step, not
    per-layer."""
    g = placement.subgroup_size
    e = placement.num_padded
    return (g - 1) * (rows * e + rows * N_POS_BUCKETS)
